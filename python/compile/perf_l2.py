"""L2 perf audit: instruction census of the lowered HLO modules.

Checks the properties DESIGN.md §8 targets for the JAX graph:
  * no redundant recomputation — each quantizable matmul lowers to exactly
    one dot/dot-general per layer (counted against the layer table);
  * elementwise chains are fusable — report the fusion-relevant op mix;
  * while-loop count matches the Pallas grid structure (interpret mode
    lowers each pallas_call to one loop nest).

Run:  python -m compile.perf_l2 [artifacts_dir]
"""

from __future__ import annotations

import os
import re
import sys
from collections import Counter


OPCODE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*[\w\[\]{}, ]+?\s(\w+)\(")


def census(path: str) -> Counter:
    ops: Counter = Counter()
    with open(path) as f:
        for line in f:
            m = OPCODE_RE.match(line)
            if m:
                ops[m.group(1)] += 1
    return ops


def audit(root: str, model: str) -> dict:
    out = {}
    for kind in ("fwd_quant", "fwd_ref", "sensitivity"):
        p = os.path.join(root, model, f"{kind}.hlo.txt")
        if os.path.exists(p):
            out[kind] = census(p)
    return out


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else "../artifacts"
    for model in ("tiny-s", "tiny-m"):
        if not os.path.isdir(os.path.join(root, model)):
            continue
        print(f"\n=== {model} ===")
        for kind, ops in audit(root, model).items():
            total = sum(ops.values())
            dots = ops.get("dot", 0)
            whiles = ops.get("while", 0)
            print(f"{kind:<12} {total:>6} instrs | dot {dots:>3} | while {whiles:>3} "
                  f"| exp {ops.get('exponential', 0):>3} | top5 "
                  + ", ".join(f"{k}:{v}" for k, v in ops.most_common(5)))


if __name__ == "__main__":
    main()
