"""L1 Pallas kernel: blocked fake-quant matmul (the paper's linear-layer op).

Computes  y[M,K] = fq(x[M,C], m) @ fq(w[K,C], m)^T  (+ bias)  with both
operands quantize-dequantized to ``m`` mantissa bits using per-tensor scales
(computed once outside the kernel, passed in as scalars).

Hardware adaptation (see DESIGN.md #Hardware-Adaptation): the paper's Gaudi-2
MME FP8 path is re-expressed TPU-style — BlockSpec tiles HBM->VMEM transfers,
quantization is applied per-block at load (the Gaudi cast-at-DMA analog), and
the inner product accumulates in f32 as the MXU would.  ``interpret=True``
throughout: the CPU PJRT client cannot execute Mosaic custom-calls, and
correctness is what the interpret path validates (kernels/ref.py oracle).

Block-shape selection targets a VMEM budget (see vmem_footprint) rather than
CPU wallclock; EXPERIMENTS.md #Perf records the footprint/utilization table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.quant import fake_quant_with_scale, fmax_for_mbits, tensor_scale

# Default tile sizes (f32 words): chosen so x-tile + w-tile + out-tile fit in
# a ~1 MiB VMEM budget for the model dims used here (C <= 512).
DEFAULT_BM = 64
DEFAULT_BK = 32


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (pref itself if divisible)."""
    if dim % pref == 0:
        return pref
    b = 1
    for c in range(1, min(dim, pref) + 1):
        if dim % c == 0:
            b = c
    return b


def vmem_footprint(m_dim: int, c_dim: int, k_dim: int, bm: int, bk: int) -> int:
    """Bytes of VMEM held by one grid step (f32 tiles + f32 accumulator)."""
    return 4 * (bm * c_dim + bk * c_dim + bm * bk)


def _kernel(meta_ref, x_ref, w_ref, b_ref, o_ref):
    # meta = [m, fmax, s_x, s_w]
    m = meta_ref[0, 0]
    fmax = meta_ref[0, 1]
    s_x = meta_ref[0, 2]
    s_w = meta_ref[0, 3]
    xq = fake_quant_with_scale(x_ref[...], m, s_x, fmax)
    wq = fake_quant_with_scale(w_ref[...], m, s_w, fmax)
    # MXU-style: f32 accumulation of the (quantized) operand product.
    acc = jax.lax.dot_general(
        xq, wq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] = acc + b_ref[...]


def qmatmul(x, w, b, m, pert=1.0, bm: int = DEFAULT_BM, bk: int = DEFAULT_BK):
    """Fake-quant matmul: y = fq(x) @ fq(w)^T + b.

    x: [M, C] activations, w: [K, C] weights, b: [K] bias (zeros if None),
    m: traced scalar mantissa bits, pert: traced scale-perturbation factor.
    """
    mm, c = x.shape
    k, c2 = w.shape
    assert c == c2, (x.shape, w.shape)
    if b is None:
        b = jnp.zeros((k,), jnp.float32)
    bm = _pick_block(mm, bm)
    bk = _pick_block(k, bk)

    fmax = fmax_for_mbits(m)
    s_x = tensor_scale(x, m, pert)
    s_w = tensor_scale(w, m, pert)
    meta = jnp.stack([m, fmax, s_x, s_w]).reshape(1, 4).astype(jnp.float32)

    return pl.pallas_call(
        _kernel,
        grid=(mm // bm, k // bk),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i, j: (0, 0)),
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, c), lambda i, j: (j, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, k), jnp.float32),
        interpret=True,
    )(meta, x, w, b)
