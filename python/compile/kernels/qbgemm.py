"""L1 Pallas kernel: batched fake-quant GEMM (the paper's BGEMM op).

Covers the two attention BGEMMs the paper quantizes (Fig. 6):
  qk_matmul:  scores[BH, T, T] = fq(q[BH, T, hd]) @ fq(k[BH, T, hd])^T
  av_matmul:  out[BH, T, hd]   = fq(p[BH, T, T])  @ fq(v[BH, T, hd])

Both are expressed as one kernel: z[g, M, K] = fq(a[g, M, C]) @ fq(b[g, C, K]),
gridded over batch groups so several heads' tiles share one VMEM residency
(the Gaudi-2 MME batch loop analog).  interpret=True as everywhere (see
qmatmul.py for the hardware-adaptation rationale).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.quant import fake_quant_with_scale, fmax_for_mbits, tensor_scale

# Batch-group size: how many batch elements one grid step processes.
DEFAULT_GB = 8


def _pick_group(batch: int, pref: int) -> int:
    if batch % pref == 0:
        return pref
    g = 1
    for c in range(1, min(batch, pref) + 1):
        if batch % c == 0:
            g = c
    return g


def vmem_footprint(gb: int, m_dim: int, c_dim: int, k_dim: int) -> int:
    """Bytes of VMEM held by one grid step."""
    return 4 * gb * (m_dim * c_dim + c_dim * k_dim + m_dim * k_dim)


def _kernel(meta_ref, a_ref, b_ref, o_ref):
    m = meta_ref[0, 0]
    fmax = meta_ref[0, 1]
    s_a = meta_ref[0, 2]
    s_b = meta_ref[0, 3]
    aq = fake_quant_with_scale(a_ref[...], m, s_a, fmax)
    bq = fake_quant_with_scale(b_ref[...], m, s_b, fmax)
    # Batched contraction with f32 accumulation: [g,M,C] x [g,C,K] -> [g,M,K].
    o_ref[...] = jax.lax.dot_general(
        aq, bq, (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )


def qbgemm(a, b, m, pert=1.0, gb: int = DEFAULT_GB):
    """Batched fake-quant GEMM: z[g,M,K] = fq(a[g,M,C]) @ fq(b[g,C,K])."""
    g, mm, c = a.shape
    g2, c2, k = b.shape
    assert g == g2 and c == c2, (a.shape, b.shape)
    gb = _pick_group(g, gb)

    fmax = fmax_for_mbits(m)
    s_a = tensor_scale(a, m, pert)
    s_b = tensor_scale(b, m, pert)
    meta = jnp.stack([m, fmax, s_a, s_b]).reshape(1, 4).astype(jnp.float32)

    return pl.pallas_call(
        _kernel,
        grid=(g // gb,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((gb, mm, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, c, k), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((gb, mm, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, mm, k), jnp.float32),
        interpret=True,
    )(meta, a, b)
