"""Pure-jnp oracles for the L1 kernels.

These are the CORE correctness references: pytest (python/tests/) asserts the
Pallas kernels match these bit-for-bit-ish (allclose at f32) across
hypothesis-swept shapes, mantissa widths, and scale perturbations.  They are
also used by the L2 model's ``use_pallas=False`` path (training, sensitivity)
where differentiability / speed matter more than exercising the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.quant import fake_quant


def qmatmul_ref(x, w, b, m, pert=1.0):
    """y[M,K] = fq(x[M,C]) @ fq(w[K,C])^T + b[K]."""
    if b is None:
        b = jnp.zeros((w.shape[0],), jnp.float32)
    xq = fake_quant(x, m, pert)
    wq = fake_quant(w, m, pert)
    return xq @ wq.T + b


def qbgemm_ref(a, b, m, pert=1.0):
    """z[g,M,K] = fq(a[g,M,C]) @ fq(b[g,C,K])."""
    aq = fake_quant(a, m, pert)
    bq = fake_quant(b, m, pert)
    return jnp.einsum("gmc,gck->gmk", aq, bq)


def matmul_ref(x, w, b=None):
    """Unquantized linear: y = x @ w^T + b (training / sensitivity path)."""
    y = x @ w.T
    if b is not None:
        y = y + b
    return y
