""".tbin — tiny named-tensor container (little-endian), shared with rust.

Layout (keep in sync with rust/src/tensorbin/):
  magic   6 bytes  b"TBIN1\\0"
  count   u32      number of tensors
  per tensor:
    name_len u16, name bytes (utf-8)
    dtype    u8   (0 = f32, 1 = i32)
    ndim     u8
    dims     u32 * ndim
    payload  raw little-endian values (4 bytes each)
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"TBIN1\x00"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def write_tbin(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read_tbin(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        data = f.read()
    if data[:6] != MAGIC:
        raise ValueError(f"{path}: bad magic")
    off = 6
    (count,) = struct.unpack_from("<I", data, off)
    off += 4
    out: dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off:off + nlen].decode()
        off += nlen
        dtype, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, DTYPES_INV[dtype], count=n, offset=off)
        off += 4 * n
        out[name] = arr.reshape(dims).copy()
    return out
