"""Emit graph.json — the model computation DAG the rust coordinator consumes.

The DAG feeds two rust subsystems:
  * graph/partition.rs — the paper's Algorithm 2 (sequential single-entry/
    single-exit sub-graphs).  Following Fig. 6 ("residual adds are omitted"),
    residual skip edges are tagged so the partitioner can bypass them.
  * gaudisim/ — the Gaudi-2-like timing model; every node carries engine
    (mme / tpc), MAC count, and tensor byte sizes at the BF16 baseline.

All sizes are computed for the evaluation batch (eval_b x seq), the shape the
paper's TTFT prefill measurements use.
"""

from __future__ import annotations

import json

from compile.model import BLOCK_QLAYERS, ModelCfg, qlayer_kinds, qlayer_names

BF16_BYTES = 2


def _node(nid, kind, engine, qidx, macs, bytes_in, bytes_out, param_bytes,
          c=0, k=0):
    return dict(id=nid, kind=kind, engine=engine, qidx=qidx, macs=int(macs),
                bytes_in=int(bytes_in), bytes_out=int(bytes_out),
                param_bytes=int(param_bytes), c=int(c), k=int(k))


def build_graph(cfg: ModelCfg) -> dict:
    b, t, d, h, ff, v = cfg.eval_b, cfg.seq, cfg.d, cfg.heads, cfg.ff, cfg.vocab
    hd = cfg.hd
    n = b * t            # token rows
    bh = b * h           # batched heads
    e = lambda x: x * BF16_BYTES
    act = n * d          # elements of a [B,T,d] activation

    nodes, edges, res_edges = [], [], []
    qidx = {name: i for i, name in enumerate(qlayer_names(cfg))}

    def add(nid, kind, engine, q=-1, macs=0, bi=0, bo=0, pb=0, c=0, k=0):
        nodes.append(_node(nid, kind, engine, q, macs, bi, bo, pb, c, k))

    def lin(nid, c_in, k_out):
        add(nid, "linear", "mme", qidx[nid], macs=n * c_in * k_out,
            bi=e(n * c_in), bo=e(n * k_out), pb=e(c_in * k_out), c=c_in, k=k_out)

    add("embed", "embed", "tpc", bi=e(n), bo=e(act), pb=e(v * d))
    prev_out = "embed"   # node whose output feeds the next block
    for i in range(cfg.blocks):
        p = f"blk{i}."
        add(p + "rms1", "rmsnorm", "tpc", bi=e(act), bo=e(act), pb=e(d))
        lin(p + "q_proj", d, d)
        lin(p + "k_proj", d, d)
        lin(p + "v_proj", d, d)
        add(p + "rope_q", "rope", "tpc", bi=e(act), bo=e(act))
        add(p + "rope_k", "rope", "tpc", bi=e(act), bo=e(act))
        add(p + "qk_matmul", "bgemm", "mme", qidx[p + "qk_matmul"],
            macs=bh * t * t * hd, bi=e(2 * act), bo=e(bh * t * t), c=hd, k=t)
        add(p + "softmax", "softmax", "tpc", bi=e(bh * t * t), bo=e(bh * t * t))
        add(p + "av_matmul", "bgemm", "mme", qidx[p + "av_matmul"],
            macs=bh * t * t * hd, bi=e(bh * t * t + act), bo=e(act), c=t, k=hd)
        lin(p + "o_proj", d, d)
        add(p + "add1", "add", "tpc", bi=e(2 * act), bo=e(act))
        add(p + "rms2", "rmsnorm", "tpc", bi=e(act), bo=e(act), pb=e(d))
        lin(p + "gate_proj", d, ff)
        lin(p + "up_proj", d, ff)
        add(p + "silu", "silu", "tpc", bi=e(n * ff), bo=e(n * ff))
        add(p + "mul", "mul", "tpc", bi=e(2 * n * ff), bo=e(n * ff))
        lin(p + "down_proj", ff, d)
        add(p + "add2", "add", "tpc", bi=e(2 * act), bo=e(act))

        edges += [
            (prev_out, p + "rms1"),
            (p + "rms1", p + "q_proj"), (p + "rms1", p + "k_proj"),
            (p + "rms1", p + "v_proj"),
            (p + "q_proj", p + "rope_q"), (p + "k_proj", p + "rope_k"),
            (p + "rope_q", p + "qk_matmul"), (p + "rope_k", p + "qk_matmul"),
            (p + "qk_matmul", p + "softmax"),
            (p + "softmax", p + "av_matmul"), (p + "v_proj", p + "av_matmul"),
            (p + "av_matmul", p + "o_proj"),
            (p + "o_proj", p + "add1"),
            (p + "add1", p + "rms2"),
            (p + "rms2", p + "gate_proj"), (p + "rms2", p + "up_proj"),
            (p + "gate_proj", p + "silu"),
            (p + "silu", p + "mul"), (p + "up_proj", p + "mul"),
            (p + "mul", p + "down_proj"),
            (p + "down_proj", p + "add2"),
        ]
        res_edges += [(prev_out, p + "add1"), (p + "add1", p + "add2")]
        prev_out = p + "add2"

    add("rms_f", "rmsnorm", "tpc", bi=e(act), bo=e(act), pb=e(d))
    add("lm_head", "linear", "mme", qidx["lm_head"], macs=n * d * v,
        bi=e(act), bo=e(n * v), pb=e(d * v), c=d, k=v)
    edges += [(prev_out, "rms_f"), ("rms_f", "lm_head")]

    return dict(
        model=cfg.name,
        eval_b=b, seq=t,
        nodes=nodes,
        edges=[list(x) for x in edges],
        residual_edges=[list(x) for x in res_edges],
        qlayers=qlayer_names(cfg),
        qkinds=qlayer_kinds(cfg),
    )


def write_graph(cfg: ModelCfg, path: str) -> dict:
    g = build_graph(cfg)
    with open(path, "w") as f:
        json.dump(g, f, indent=1)
    return g
