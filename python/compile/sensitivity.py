"""L2: the paper's sensitivity program (§2.2, eq. 17-21), lowered to HLO.

One high-precision forward+backward pass per calibration sample r (batch=1,
matching the paper's per-sample math exactly) returning:
  g    — the sample loss g^r (scalar),
  s    — f32[Lq] per-quantizable-layer sensitivities
         s_l^r = ||z_l^r .* dg/dz_l^r||^2   (eq. 19),
         where z is the layer's extended input ([x; w] for linear,
         [x0; x1] for BGEMM).

Implementation: multiplicative ones-taps (see model.fwd) make the tap
gradient equal z .* zdot elementwise, so s_l is just the summed squared
tap-gradient over the layer's components.  The rust coordinator averages
s_l^r and (g^r)^2 over the calibration set (eq. 21) and predicts the loss
MSE of any MP configuration as d = sum_l s_l * alpha_f(l) (eq. 22-23, 6).

Note the paper's memory point holds here too: no optimizer state is kept —
the backward pass only materializes activation-shaped tap gradients.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.model import ModelCfg, fwd, make_taps, qlayer_names, qlayer_kinds


def sensitivity_fn(cfg: ModelCfg):
    """Returns f(params_tuple..., tokens[1,T]) -> (g, s[Lq]) ready to lower."""
    qnames = qlayer_names(cfg)
    qkinds = qlayer_kinds(cfg)

    def run(params: dict, tokens):
        def loss_of_taps(taps):
            _, loss = fwd(cfg, params, tokens, taps=taps, use_pallas=False)
            return loss[0]

        taps = make_taps(cfg, 1)
        g, grads = jax.value_and_grad(loss_of_taps)(taps)
        comps = []
        for name, kind in zip(qnames, qkinds):
            keys = (".a", ".b") if kind == "bgemm" else (".x", ".w")
            s = sum(jnp.sum(jnp.square(grads[name + k])) for k in keys)
            comps.append(s)
        return g, jnp.stack(comps)

    return run


def empirical_loss_noise(cfg: ModelCfg, params, tokens, mbits, pscale,
                         use_pallas=False):
    """Measured loss error (ghat - g) per sample — validation-only helper.

    Used by pytest to check the Taylor/independence model: predicted
    d = sum_l s_l * alpha_f should track E[(ghat - g)^2] for small noise.
    """
    _, g = fwd(cfg, params, tokens, use_pallas=False)
    _, ghat = fwd(cfg, params, tokens, mbits=mbits, pscale=pscale,
                  use_pallas=use_pallas)
    return ghat - g
