"""Build-time trainer for the stand-in LLMs (see DESIGN.md §3).

Plain AdamW on the PAD-masked next-token cross-entropy, pure-jnp forward
(``use_pallas=False``, no quantization) for speed; the trained weights are
frozen into artifacts/<model>/weights.tbin and every runtime experiment is
PTQ on top of them — exactly the paper's setting (no QAT, no fine-tuning).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from compile.corpus import corpus_batch
from compile.model import ModelCfg, fwd, init_params


def adamw_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.float32)}


def adamw_step(params, grads, state, lr, b1=0.9, b2=0.98, eps=1.0e-8, wd=0.01):
    t = state["t"] + 1.0
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k]) for k in params}
    mh = {k: m[k] / (1 - b1 ** t) for k in params}
    vh = {k: v[k] / (1 - b2 ** t) for k in params}
    new = {k: params[k] - lr * (mh[k] / (jnp.sqrt(vh[k]) + eps) + wd * params[k])
           for k in params}
    return new, {"m": m, "v": v, "t": t}


def train(cfg: ModelCfg, verbose: bool = True):
    """Returns (params, history) — history is [(step, loss)] for the manifest."""
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    rng = np.random.default_rng(1000 + cfg.seed)

    def loss_fn(p, tokens):
        _, loss = fwd(cfg, p, tokens, use_pallas=False)
        return loss.mean()

    @jax.jit
    def step(p, o, tokens, lr):
        loss, grads = jax.value_and_grad(loss_fn)(p, tokens)
        p2, o2 = adamw_step(p, grads, o, lr)
        return p2, o2, loss

    history = []
    t0 = time.time()
    for i in range(cfg.train_steps):
        # Cosine decay with short warmup.
        warm = min(1.0, (i + 1) / 50.0)
        decay = 0.5 * (1.0 + np.cos(np.pi * i / cfg.train_steps))
        lr = cfg.lr * warm * (0.1 + 0.9 * decay)
        tokens = jnp.asarray(corpus_batch(rng, cfg, cfg.train_b))
        params, opt, loss = step(params, opt, tokens, jnp.float32(lr))
        if i % 100 == 0 or i == cfg.train_steps - 1:
            history.append((i, float(loss)))
            if verbose:
                print(f"[train {cfg.name}] step {i:5d} loss {float(loss):.4f} "
                      f"({time.time() - t0:.1f}s)", flush=True)
    return params, history
