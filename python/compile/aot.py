"""AOT export: python runs ONCE here; rust never imports python at runtime.

Per model (tiny-s, tiny-m) this produces under artifacts/<model>/:
  weights.tbin          trained parameters (build-time PTQ subject)
  fwd_quant.hlo.txt     L2 fwd with L1 Pallas fake-quant kernels
                        (tokens i32[B,T], mbits f32[Lq], pscale f32[Lq],
                         *weights) -> (logits f32[B,T,V], loss f32[B])
  fwd_ref.hlo.txt       same signature, pure-jnp quant path (cross-check +
                        fast eval mode)
  sensitivity.hlo.txt   (tokens i32[1,T], *weights) -> (g, s f32[Lq])
  graph.json            op DAG for partition + timing simulation
  calib.tbin            calibration sequences  i32[R, T]
  tasks/<t>.tbin        evaluation task datasets
plus artifacts/manifest.json describing everything.

HLO *text* is the interchange format (NOT .serialize()): jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import corpus
from compile.model import (CONFIGS, ModelCfg, fwd, param_order, param_shapes,
                           qlayer_kinds, qlayer_names)
from compile.sensitivity import sensitivity_fn
from compile.tensorbin import read_tbin, write_tbin
from compile.graphdef import write_graph
from compile.quant import FORMATS
from compile.train import train

N_EX = 64  # examples per evaluation task


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fwd(cfg: ModelCfg, use_pallas: bool) -> str:
    order = param_order(cfg)
    shapes = param_shapes(cfg)

    def fn(tokens, mbits, pscale, *weights):
        params = dict(zip(order, weights))
        return fwd(cfg, params, tokens, mbits=mbits, pscale=pscale,
                   use_pallas=use_pallas)

    specs = [
        jax.ShapeDtypeStruct((cfg.eval_b, cfg.seq), jnp.int32),
        jax.ShapeDtypeStruct((cfg.n_qlayers,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.n_qlayers,), jnp.float32),
    ] + [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in order]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_sensitivity(cfg: ModelCfg) -> str:
    order = param_order(cfg)
    shapes = param_shapes(cfg)
    run = sensitivity_fn(cfg)

    def fn(tokens, *weights):
        return run(dict(zip(order, weights)), tokens)

    specs = [jax.ShapeDtypeStruct((1, cfg.seq), jnp.int32)] + [
        jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in order
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def qlayer_table(cfg: ModelCfg) -> list[dict]:
    n = cfg.eval_b * cfg.seq
    bh = cfg.eval_b * cfg.heads
    dims = {"q_proj": (cfg.d, cfg.d), "k_proj": (cfg.d, cfg.d),
            "v_proj": (cfg.d, cfg.d), "o_proj": (cfg.d, cfg.d),
            "gate_proj": (cfg.d, cfg.ff), "up_proj": (cfg.d, cfg.ff),
            "down_proj": (cfg.ff, cfg.d)}
    out = []
    for name, kind in zip(qlayer_names(cfg), qlayer_kinds(cfg)):
        short = name.split(".")[-1]
        if kind == "bgemm":
            macs = bh * cfg.seq * cfg.seq * cfg.hd
            c, k, pcount = cfg.seq, cfg.hd, 0
        elif name == "lm_head":
            c, k = cfg.d, cfg.vocab
            macs, pcount = n * c * k, c * k
        else:
            c, k = dims[short]
            macs, pcount = n * c * k, c * k
        out.append(dict(name=name, kind=kind, c=c, k=k, macs=macs,
                        params=pcount))
    return out


def export_model(cfg: ModelCfg, root: str, force: bool) -> dict:
    mdir = os.path.join(root, cfg.name)
    os.makedirs(os.path.join(mdir, "tasks"), exist_ok=True)
    wpath = os.path.join(mdir, "weights.tbin")

    order = param_order(cfg)
    if os.path.exists(wpath) and not force:
        print(f"[aot] {cfg.name}: reusing cached weights")
        loaded = read_tbin(wpath)
        params = {k: jnp.asarray(v) for k, v in loaded.items()}
        history = []
    else:
        params, history = train(cfg)
        write_tbin(wpath, [(n, np.asarray(params[n])) for n in order])

    print(f"[aot] {cfg.name}: lowering fwd (pallas) ...", flush=True)
    with open(os.path.join(mdir, "fwd_quant.hlo.txt"), "w") as f:
        f.write(lower_fwd(cfg, use_pallas=True))
    print(f"[aot] {cfg.name}: lowering fwd (ref) ...", flush=True)
    with open(os.path.join(mdir, "fwd_ref.hlo.txt"), "w") as f:
        f.write(lower_fwd(cfg, use_pallas=False))
    print(f"[aot] {cfg.name}: lowering sensitivity ...", flush=True)
    with open(os.path.join(mdir, "sensitivity.hlo.txt"), "w") as f:
        f.write(lower_sensitivity(cfg))

    write_graph(cfg, os.path.join(mdir, "graph.json"))

    rng = np.random.default_rng(7 + cfg.seed)
    calib = np.stack([
        np.asarray(corpus.pad_to(corpus.make_line(rng, cfg)[0], cfg.seq), np.int32)
        for _ in range(cfg.calib_r)
    ])
    write_tbin(os.path.join(mdir, "calib.tbin"), [("tokens", calib)])

    tasks_meta = []
    for td in corpus.make_all_tasks(cfg, N_EX, seed=100 + cfg.seed):
        tpath = os.path.join(mdir, "tasks", f"{td.name}.tbin")
        write_tbin(tpath, [("tokens", td.tokens), ("spans", td.spans),
                           ("labels", td.labels)])
        tasks_meta.append(dict(name=td.name, kind=td.kind, k=td.k,
                               n_ex=len(td.labels),
                               path=f"{cfg.name}/tasks/{td.name}.tbin"))

    return dict(
        name=cfg.name, vocab=cfg.vocab, d=cfg.d, blocks=cfg.blocks,
        heads=cfg.heads, ff=cfg.ff, seq=cfg.seq, eval_b=cfg.eval_b,
        calib_r=cfg.calib_r, n_qlayers=cfg.n_qlayers,
        qlayers=qlayer_table(cfg),
        param_order=order,
        param_shapes={n: list(param_shapes(cfg)[n]) for n in order},
        artifacts=dict(
            weights=f"{cfg.name}/weights.tbin",
            fwd_quant=f"{cfg.name}/fwd_quant.hlo.txt",
            fwd_ref=f"{cfg.name}/fwd_ref.hlo.txt",
            sensitivity=f"{cfg.name}/sensitivity.hlo.txt",
            graph=f"{cfg.name}/graph.json",
            calib=f"{cfg.name}/calib.tbin",
        ),
        tasks=tasks_meta,
        train_history=history,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny-s,tiny-m")
    ap.add_argument("--force-train", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    models = []
    for name in args.models.split(","):
        models.append(export_model(CONFIGS[name], args.out, args.force_train))

    manifest = dict(
        formats={k: dict(mbits=v["mbits"], bytes=v["bytes"],
                         fmax=v["fmax"]) for k, v in FORMATS.items()},
        models=models,
    )
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
