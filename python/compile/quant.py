"""Fake-quantization numerics (build-time, jnp).

Implements the paper's quantization-noise model (eq. 15-16): a value kept in a
floating-point format with ``m`` stored mantissa bits suffers a relative
rounding error ~ |z| * 2^-m * U[+-1/2].  We emulate any such format on f32 by
round-to-nearest on the mantissa at ``m`` bits, combined with a per-tensor
scale and saturation for narrow-range formats (FP8-E4M3 fmax=448).

``m`` is *runtime data* (a traced jnp scalar), so a single lowered HLO module
can evaluate every mixed-precision configuration: the rust coordinator feeds a
per-layer ``mantissa_bits`` vector into the compiled executable.

Conventions (mirrored in rust/src/numerics):
  format      m   fmax      bytes
  fp32       23   (none)    4      identity (reference precision)
  bf16        7   (none)    2
  fp16       10   (none)    2
  fp8_e4m3    3   448       1
  fp8_e5m2    2   57344     1
alpha_f = 2^(-2m)/12 is the per-element relative MSE of the rounding noise.
"""

from __future__ import annotations

import jax.numpy as jnp

# Keep in sync with rust/src/numerics/formats.rs.
FORMATS = {
    "fp32": dict(mbits=23, fmax=None, bytes=4),
    "bf16": dict(mbits=7, fmax=None, bytes=2),
    "fp16": dict(mbits=10, fmax=None, bytes=2),
    "fp8_e4m3": dict(mbits=3, fmax=448.0, bytes=1),
    "fp8_e5m2": dict(mbits=2, fmax=57344.0, bytes=1),
}


def alpha(mbits) -> float:
    """Relative MSE of rounding noise for a format with ``mbits`` mantissa bits."""
    return 2.0 ** (-2.0 * mbits) / 12.0


def fmax_for_mbits(m):
    """Saturation range as a function of (traced) mantissa bits.

    Narrow FP8 formats saturate; wider formats have effectively unbounded
    range on our data.  Branch-free so that ``m`` may be a traced value:
      m <= 2 -> e5m2 (57344), m == 3 -> e4m3 (448), else unbounded.
    """
    big = jnp.float32(3.0e38)
    return jnp.where(m <= 2.5, 57344.0, jnp.where(m <= 3.5, 448.0, big))


def round_mantissa(v, m):
    """Round-to-nearest of ``v`` at ``m`` stored mantissa bits (elementwise).

    For |v| in [2^e, 2^{e+1}) the representable grid spacing is 2^{e-m};
    m=23 is (to f32 resolution) the identity.

    The exponent is clamped to [-96, 120]: without it, near-denormal inputs
    (|v| < 2^-104) make exp2(m - e) overflow to +inf and the reconstruction
    inf/inf = NaN poisons the whole forward pass.  Clamping flushes such
    values to 0 (any real format would) and leaves huge values unrounded
    (they saturate via fmax anyway).
    """
    av = jnp.abs(v)
    # Guard zeros: log2(0) = -inf would poison exp2 below.
    e = jnp.floor(jnp.log2(jnp.where(av > 0, av, 1.0)))
    e = jnp.clip(e, -96.0, 120.0)
    f = jnp.exp2(m - e)
    return jnp.where(av > 0, jnp.round(v * f) / f, 0.0)


def tensor_scale(v, m, pert=1.0):
    """Per-tensor quantization scale with perturbation multiplier ``pert``.

    Narrow formats are scaled so max|v| maps onto the representable range;
    wide formats use unit scale.  ``pert`` models the paper's seed protocol
    ("perturb the scales before quantization").
    """
    fmax = fmax_for_mbits(m)
    amax = jnp.max(jnp.abs(v))
    scaled = fmax < 1.0e30
    s = jnp.where(scaled, jnp.where(amax > 0, amax, 1.0) / fmax, 1.0)
    return s * pert


def fake_quant(v, m, pert=1.0):
    """Quantize-dequantize ``v`` to a format with ``m`` mantissa bits."""
    s = tensor_scale(v, m, pert)
    fmax = fmax_for_mbits(m)
    vn = v / s
    q = round_mantissa(vn, m)
    q = jnp.clip(q, -fmax, fmax)
    return q * s


def fake_quant_with_scale(v, m, s, fmax):
    """Quantize-dequantize with a precomputed scale (kernel-internal form)."""
    vn = v / s
    q = round_mantissa(vn, m)
    q = jnp.clip(q, -fmax, fmax)
    return q * s
