"""Synthetic corpus + task datasets (build-time).

Substitution for the paper's real corpora/tasks (see DESIGN.md §3): a small
formal language whose statistics a tiny transformer learns quickly (induction
-head copy/reverse patterns), with four evaluation tasks mirroring the paper's
protocol:

  hella  4-way continuation choice          (HellaSwag analog)
  lamb   last-token prediction, acc + ppl   (LAMBADA analog)
  wino   2-way single-token cloze           (Winogrande analog)
  piqa   2-way procedure (reversal) choice  (PIQA analog)

Line grammar (token ids from model.py):
  [BOS, START] s_1..s_n (REV?) [SEP] payload [END] PAD...
where payload = s_1..s_n (copy) or s_n..s_1 (if REV).  Symbols are drawn from
a per-position-skewed distribution so the corpus also carries plain n-gram
structure (perplexity is meaningful, not just the deterministic span).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from compile.model import PAD, BOS, START, REV, SEP, END, SYM_BASE, ModelCfg

MIN_SEQ, MAX_SEQ = 8, 16


@dataclass
class TaskData:
    """One evaluation task: n_ex examples x k choices, padded to [_, T]."""
    name: str
    kind: str              # "choice" (acc over K spans) or "lastword" (+ppl)
    k: int
    tokens: np.ndarray     # i32 [n_ex * k, T]
    spans: np.ndarray      # i32 [n_ex * k, 2]  (start, end) of scored span
    labels: np.ndarray     # i32 [n_ex]


def _draw_syms(rng: np.random.Generator, n: int, n_syms: int) -> np.ndarray:
    """Zipf-ish symbol draw: gives the corpus non-uniform n-gram statistics."""
    w = 1.0 / (1.0 + np.arange(n_syms)) ** 0.7
    return rng.choice(n_syms, size=n, p=w / w.sum()) + SYM_BASE


def make_line(rng: np.random.Generator, cfg: ModelCfg, rev: bool | None = None):
    """Returns (tokens list, payload_start, seq list, rev flag)."""
    if rev is None:
        rev = bool(rng.integers(2))
    n = int(rng.integers(MIN_SEQ, MAX_SEQ + 1))
    seq = list(_draw_syms(rng, n, cfg.n_syms))
    head = [BOS, START] + seq + ([REV] if rev else []) + [SEP]
    payload = seq[::-1] if rev else seq
    return head + payload + [END], len(head), seq, rev


def pad_to(tokens: list[int], t: int) -> list[int]:
    assert len(tokens) <= t, (len(tokens), t)
    return tokens + [PAD] * (t - len(tokens))


# Fraction of payload symbols corrupted in TRAINING lines.  A noisy corpus
# bounds the model's achievable per-token confidence, keeping evaluation
# examples near the decision margin — the regime where quantization noise
# measurably moves accuracy (as with the paper's real LLMs).  Task datasets
# are generated from CLEAN lines.
TRAIN_NOISE = 0.08


def corpus_batch(rng: np.random.Generator, cfg: ModelCfg, b: int,
                 noise: float = TRAIN_NOISE) -> np.ndarray:
    out = np.zeros((b, cfg.seq), np.int32)
    for i in range(b):
        line, pstart, _, _ = make_line(rng, cfg)
        if noise > 0.0:
            for j in range(pstart, len(line) - 1):
                if line[j] >= SYM_BASE and rng.random() < noise:
                    line[j] = SYM_BASE + int(rng.integers(cfg.n_syms))
        out[i] = pad_to(line, cfg.seq)
    return out


def _confusable(rng, token: int, pool: list[int], n_syms: int) -> int:
    """A distractor symbol: prefer one the model has seen in this sequence
    (hard — membership cues don't help), fall back to a random symbol."""
    options = [tk for tk in pool if tk != token and tk >= SYM_BASE]
    if options:
        return int(options[int(rng.integers(len(options)))])
    alt = SYM_BASE + int(rng.integers(n_syms))
    if alt == token:
        alt = SYM_BASE + (alt - SYM_BASE + 1) % n_syms
    return alt


def _corrupt(rng, span: list[int], pool: list[int], n_syms: int) -> list[int]:
    """Minimally corrupt a span: replace exactly ONE symbol position with a
    confusable symbol.  Near-margin distractors keep the tasks sensitive to
    quantization noise instead of saturating at 100% accuracy."""
    out = list(span)
    sym_pos = [i for i, tk in enumerate(out) if tk >= SYM_BASE]
    if not sym_pos:
        return out
    i = sym_pos[int(rng.integers(len(sym_pos)))]
    out[i] = _confusable(rng, out[i], pool, n_syms)
    return out


def make_hella(rng, cfg: ModelCfg, n_ex: int) -> TaskData:
    """Context = line up to mid-payload; 4 candidate completions."""
    k = 4
    tokens = np.zeros((n_ex * k, cfg.seq), np.int32)
    spans = np.zeros((n_ex * k, 2), np.int32)
    labels = np.zeros((n_ex,), np.int32)
    for e in range(n_ex):
        line, pstart, seq, rev = make_line(rng, cfg)
        cut = pstart + len(seq) // 2
        ctx, true_rest = line[:cut], line[cut:]
        label = int(rng.integers(k))
        labels[e] = label
        seen: set[tuple] = {tuple(true_rest)}
        for c in range(k):
            if c == label:
                rest = true_rest
            else:
                # Distinct single-symbol corruptions (retry on collision).
                for _ in range(16):
                    rest = _corrupt(rng, true_rest, seq, cfg.n_syms)
                    if tuple(rest) not in seen:
                        break
                seen.add(tuple(rest))
            row = e * k + c
            tokens[row] = pad_to(ctx + rest, cfg.seq)
            spans[row] = (cut, cut + len(rest))
    return TaskData("hella", "choice", k, tokens, spans, labels)


def make_lamb(rng, cfg: ModelCfg, n_ex: int) -> TaskData:
    """Predict the final payload token (before END): accuracy + perplexity."""
    tokens = np.zeros((n_ex, cfg.seq), np.int32)
    spans = np.zeros((n_ex, 2), np.int32)
    labels = np.zeros((n_ex,), np.int32)
    for e in range(n_ex):
        line, pstart, seq, rev = make_line(rng, cfg)
        last_pos = len(line) - 2  # final payload token (line ends with END)
        tokens[e] = pad_to(line, cfg.seq)
        spans[e] = (last_pos, last_pos + 1)
        labels[e] = line[last_pos]
    return TaskData("lamb", "lastword", 1, tokens, spans, labels)


def make_wino(rng, cfg: ModelCfg, n_ex: int) -> TaskData:
    """2-way cloze on one mid-payload token."""
    k = 2
    tokens = np.zeros((n_ex * k, cfg.seq), np.int32)
    spans = np.zeros((n_ex * k, 2), np.int32)
    labels = np.zeros((n_ex,), np.int32)
    for e in range(n_ex):
        line, pstart, seq, rev = make_line(rng, cfg)
        j = pstart + int(rng.integers(1, len(seq) - 1))
        true_tok = line[j]
        alt = _confusable(rng, true_tok, seq, cfg.n_syms)
        label = int(rng.integers(k))
        labels[e] = label
        for c in range(k):
            row = line.copy()
            row[j] = true_tok if c == label else alt
            tokens[e * k + c] = pad_to(row, cfg.seq)
            spans[e * k + c] = (j, j + 1)
    return TaskData("wino", "choice", k, tokens, spans, labels)


def make_piqa(rng, cfg: ModelCfg, n_ex: int) -> TaskData:
    """2-way choice between a correct reversal and one with a swapped pair."""
    k = 2
    tokens = np.zeros((n_ex * k, cfg.seq), np.int32)
    spans = np.zeros((n_ex * k, 2), np.int32)
    labels = np.zeros((n_ex,), np.int32)
    for e in range(n_ex):
        line, pstart, seq, _ = make_line(rng, cfg, rev=True)
        payload = line[pstart:-1]
        bad = payload.copy()
        # Swap two distinct adjacent symbols (guaranteed different by retry).
        for _ in range(8):
            j = int(rng.integers(len(bad) - 1))
            if bad[j] != bad[j + 1]:
                bad[j], bad[j + 1] = bad[j + 1], bad[j]
                break
        else:
            bad[0] = SYM_BASE + (bad[0] - SYM_BASE + 1) % cfg.n_syms
        label = int(rng.integers(k))
        labels[e] = label
        for c in range(k):
            pl_c = payload if c == label else bad
            row = line[:pstart] + pl_c + [END]
            tokens[e * k + c] = pad_to(row, cfg.seq)
            spans[e * k + c] = (pstart, pstart + len(pl_c))
    return TaskData("piqa", "choice", k, tokens, spans, labels)


TASK_MAKERS = {"hella": make_hella, "lamb": make_lamb,
               "wino": make_wino, "piqa": make_piqa}


def make_all_tasks(cfg: ModelCfg, n_ex: int, seed: int) -> list[TaskData]:
    return [maker(np.random.default_rng(seed + i), cfg, n_ex)
            for i, (name, maker) in enumerate(sorted(TASK_MAKERS.items()))]
