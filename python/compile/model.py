"""L2: Llama-style transformer in JAX, with runtime-controlled mixed precision.

The model mirrors the block structure the paper partitions (Fig. 6): RMSNorm ->
{q,k,v projections, RoPE, qk BGEMM, softmax, av BGEMM, o projection} ->
residual -> RMSNorm -> {gate/up projections, SiLU*mul, down projection} ->
residual, plus a final RMSNorm and lm_head.  Quantizable layers (paper's
L_lin + L_BGEMM) per block: q,k,v,qk,av,o,gate,up,down — plus lm_head.

Two runtime inputs make a SINGLE lowered HLO module serve every MP config:
  mantissa_bits f32[Lq] — per-quantizable-layer mantissa width (23 = fp32
      identity, 7 = bf16, 3 = fp8_e4m3, ...), consumed as data by the
      fake-quant kernels;
  pscale        f32[Lq] — per-layer quantization-scale perturbation
      multipliers (the paper's seed protocol for accuracy statistics).

Sensitivity tap points: with ``taps`` given (and quantization off), every
quantizable layer's extended input z = [x; w] (or [x0; x1] for BGEMM) is
multiplied elementwise by a ones-tensor tap.  Then d(loss)/d(tap) = z .* dg/dz
exactly, so the paper's sensitivity s_l = ||z .* zdot||^2 (eq. 19) is the
squared norm of the tap gradient — no intermediate capture needed.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from compile.kernels.qmatmul import qmatmul
from compile.kernels.qbgemm import qbgemm
from compile.kernels.ref import qmatmul_ref, qbgemm_ref, matmul_ref

PAD, BOS, START, REV, SEP, END, QM = 0, 1, 2, 3, 4, 5, 6
SYM_BASE = 8  # first "word" symbol; vocab - SYM_BASE usable symbols

# Per-block quantizable layers, in qidx order (paper Fig. 6 naming).
BLOCK_QLAYERS = (
    "q_proj", "k_proj", "v_proj", "qk_matmul", "av_matmul",
    "o_proj", "gate_proj", "up_proj", "down_proj",
)
BGEMM_LAYERS = ("qk_matmul", "av_matmul")


@dataclass(frozen=True)
class ModelCfg:
    name: str
    vocab: int = 64
    d: int = 96
    blocks: int = 2
    heads: int = 4
    ff: int = 192
    seq: int = 48
    eval_b: int = 8
    calib_r: int = 32
    train_steps: int = 900
    train_b: int = 32
    lr: float = 3.0e-3
    seed: int = 0

    @property
    def hd(self) -> int:
        return self.d // self.heads

    @property
    def n_qlayers(self) -> int:
        return len(BLOCK_QLAYERS) * self.blocks + 1  # + lm_head

    @property
    def n_syms(self) -> int:
        return self.vocab - SYM_BASE


CONFIGS = {
    # Stand-ins for the paper's Llama-3.2-1B / Llama-3.1-8B (see DESIGN.md §3).
    "tiny-s": ModelCfg(name="tiny-s", d=96, blocks=2, heads=4, ff=192,
                       train_steps=900, seed=0),
    "tiny-m": ModelCfg(name="tiny-m", d=192, blocks=3, heads=6, ff=384,
                       train_steps=1200, seed=1),
}


def qlayer_names(cfg: ModelCfg) -> list[str]:
    names = []
    for i in range(cfg.blocks):
        names += [f"blk{i}.{n}" for n in BLOCK_QLAYERS]
    names.append("lm_head")
    return names


def qlayer_kinds(cfg: ModelCfg) -> list[str]:
    return ["bgemm" if n.split(".")[-1] in BGEMM_LAYERS else "linear"
            for n in qlayer_names(cfg)]


def param_order(cfg: ModelCfg) -> list[str]:
    """Deterministic parameter ordering — the HLO input order contract with rust."""
    order = ["embed"]
    for i in range(cfg.blocks):
        b = f"blk{i}."
        order += [b + "rms1_g", b + "q_w", b + "k_w", b + "v_w", b + "o_w",
                  b + "rms2_g", b + "gate_w", b + "up_w", b + "down_w"]
    order += ["rms_f_g", "lm_head_w"]
    return order


def param_shapes(cfg: ModelCfg) -> dict[str, tuple[int, ...]]:
    shapes: dict[str, tuple[int, ...]] = {"embed": (cfg.vocab, cfg.d)}
    for i in range(cfg.blocks):
        b = f"blk{i}."
        shapes[b + "rms1_g"] = (cfg.d,)
        shapes[b + "q_w"] = (cfg.d, cfg.d)
        shapes[b + "k_w"] = (cfg.d, cfg.d)
        shapes[b + "v_w"] = (cfg.d, cfg.d)
        shapes[b + "o_w"] = (cfg.d, cfg.d)
        shapes[b + "rms2_g"] = (cfg.d,)
        shapes[b + "gate_w"] = (cfg.ff, cfg.d)
        shapes[b + "up_w"] = (cfg.ff, cfg.d)
        shapes[b + "down_w"] = (cfg.d, cfg.ff)
    shapes["rms_f_g"] = (cfg.d,)
    shapes["lm_head_w"] = (cfg.vocab, cfg.d)
    return shapes


def init_params(cfg: ModelCfg, key) -> dict[str, jnp.ndarray]:
    params = {}
    for name, shape in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        if name.endswith("_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "embed":
            params[name] = jax.random.normal(sub, shape, jnp.float32) * 0.02
        else:
            fan_in = shape[-1]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
    return params


def _rmsnorm(x, g, eps=1.0e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def _rope(x):
    """Rotary embedding over [BH, T, hd]."""
    _, t, hd = x.shape
    half = hd // 2
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    inv = jnp.exp2(-jnp.arange(half, dtype=jnp.float32) * (14.0 / half))
    ang = pos * inv[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def make_taps(cfg: ModelCfg, batch: int) -> dict[str, jnp.ndarray]:
    """Ones-taps for every quantizable layer's extended input components."""
    n = batch * cfg.seq
    shapes = param_shapes(cfg)
    taps: dict[str, jnp.ndarray] = {}
    bh = batch * cfg.heads
    for i in range(cfg.blocks):
        b = f"blk{i}."
        for lname, wname, c in (("q_proj", "q_w", cfg.d), ("k_proj", "k_w", cfg.d),
                                ("v_proj", "v_w", cfg.d), ("o_proj", "o_w", cfg.d),
                                ("gate_proj", "gate_w", cfg.d), ("up_proj", "up_w", cfg.d),
                                ("down_proj", "down_w", cfg.ff)):
            taps[b + lname + ".x"] = jnp.ones((n, c), jnp.float32)
            taps[b + lname + ".w"] = jnp.ones(shapes[b + wname], jnp.float32)
        taps[b + "qk_matmul.a"] = jnp.ones((bh, cfg.seq, cfg.hd), jnp.float32)
        taps[b + "qk_matmul.b"] = jnp.ones((bh, cfg.hd, cfg.seq), jnp.float32)
        taps[b + "av_matmul.a"] = jnp.ones((bh, cfg.seq, cfg.seq), jnp.float32)
        taps[b + "av_matmul.b"] = jnp.ones((bh, cfg.seq, cfg.hd), jnp.float32)
    taps["lm_head.x"] = jnp.ones((n, cfg.d), jnp.float32)
    taps["lm_head.w"] = jnp.ones(shapes["lm_head_w"], jnp.float32)
    return taps


def fwd(cfg: ModelCfg, params, tokens, mbits=None, pscale=None, taps=None,
        use_pallas=True):
    """Forward pass.

    tokens: i32[B, T].  Returns (logits f32[B, T, V], loss f32[B]) where
    loss[b] is the PAD-masked mean next-token cross-entropy of sample b
    (the paper's per-sample loss g^r).
    """
    assert not (taps is not None and mbits is not None), \
        "sensitivity taps are measured at high precision (paper §2.2)"
    batch, t = tokens.shape
    assert t == cfg.seq
    qnames = qlayer_names(cfg)
    qidx = {n: i for i, n in enumerate(qnames)}

    def qlin(x2d, w, name):
        if taps is not None:
            x2d = x2d * taps[name + ".x"]
            w = w * taps[name + ".w"]
        if mbits is None:
            return matmul_ref(x2d, w)
        i = qidx[name]
        op = qmatmul if use_pallas else qmatmul_ref
        return op(x2d, w, None, mbits[i], pscale[i])

    def qbg(a, b, name):
        if taps is not None:
            a = a * taps[name + ".a"]
            b = b * taps[name + ".b"]
        if mbits is None:
            return jnp.einsum("gmc,gck->gmk", a, b)
        i = qidx[name]
        op = qbgemm if use_pallas else qbgemm_ref
        return op(a, b, mbits[i], pscale[i])

    x = params["embed"][tokens]  # [B, T, d]

    for i in range(cfg.blocks):
        b = f"blk{i}."
        # --- attention sub-graph (paper V1) ---
        xn = _rmsnorm(x, params[b + "rms1_g"])
        xn2 = xn.reshape(batch * t, cfg.d)
        q = qlin(xn2, params[b + "q_w"], b + "q_proj")
        k = qlin(xn2, params[b + "k_w"], b + "k_proj")
        v = qlin(xn2, params[b + "v_w"], b + "v_proj")

        def heads(y):
            return (y.reshape(batch, t, cfg.heads, cfg.hd)
                    .transpose(0, 2, 1, 3)
                    .reshape(batch * cfg.heads, t, cfg.hd))

        qh, kh, vh = _rope(heads(q)), _rope(heads(k)), heads(v)
        scores = qbg(qh, kh.transpose(0, 2, 1), b + "qk_matmul") * (cfg.hd ** -0.5)
        mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
        scores = jnp.where(mask[None], scores, -1.0e30)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = qbg(probs, vh, b + "av_matmul")  # [BH, T, hd]
        attn2 = (attn.reshape(batch, cfg.heads, t, cfg.hd)
                 .transpose(0, 2, 1, 3)
                 .reshape(batch * t, cfg.d))
        # --- o_proj sub-graph (paper V2) ---
        o = qlin(attn2, params[b + "o_w"], b + "o_proj")
        x = x + o.reshape(batch, t, cfg.d)

        # --- MLP sub-graphs (paper V3 = {gate, up}, V4 = {down}) ---
        xn = _rmsnorm(x, params[b + "rms2_g"])
        xn2 = xn.reshape(batch * t, cfg.d)
        gate = qlin(xn2, params[b + "gate_w"], b + "gate_proj")
        up = qlin(xn2, params[b + "up_w"], b + "up_proj")
        h = jax.nn.silu(gate) * up
        down = qlin(h, params[b + "down_w"], b + "down_proj")
        x = x + down.reshape(batch, t, cfg.d)

    xn = _rmsnorm(x, params["rms_f_g"])
    logits2 = qlin(xn.reshape(batch * t, cfg.d), params["lm_head_w"], "lm_head")
    logits = logits2.reshape(batch, t, cfg.vocab)

    # PAD-masked per-sample next-token cross-entropy.
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    msk = (targets != PAD).astype(jnp.float32)
    loss = -(ll * msk).sum(axis=1) / jnp.maximum(msk.sum(axis=1), 1.0)
    return logits, loss
