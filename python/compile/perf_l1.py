"""L1 perf analysis: VMEM footprint + MXU-utilization estimates per block shape.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the L1
optimization target is structural (DESIGN.md §8): pick BlockSpec tiles that
(a) fit comfortably in VMEM (~16 MiB/core budget, we target << 4 MiB so
double-buffering fits), and (b) keep the MXU systolic array full
(128x128 tiles; utilization = how much of each dimension a tile covers).

Run:  python -m compile.perf_l1          (prints the sweep table)
Used by EXPERIMENTS.md §Perf and asserted in tests/test_perf_models.py.
"""

from __future__ import annotations

from compile.kernels.qmatmul import vmem_footprint as mm_footprint
from compile.kernels.qbgemm import vmem_footprint as bg_footprint
from compile.model import CONFIGS

MXU = 128  # systolic array side
VMEM_BUDGET = 4 * 1024 * 1024  # leave 4x headroom for double buffering


def mxu_utilization(bm: int, bk: int, c: int) -> float:
    """Fraction of the MXU kept busy by a (bm x c) @ (c x bk) tile issue."""
    um = min(bm, MXU) / MXU
    uk = min(bk, MXU) / MXU
    uc = min(c, MXU) / MXU
    return um * uk * uc


def qmatmul_sweep(cfg, m_dim: int):
    rows = []
    for bm in (16, 32, 64, 128):
        if m_dim % bm:
            continue
        for bk in (16, 32, 64):
            for c in sorted({cfg.d, cfg.ff, cfg.vocab}):
                if c % bk and c != cfg.vocab:
                    pass
                fp = mm_footprint(m_dim, c, bk, bm, bk)
                rows.append((bm, bk, c, fp, mxu_utilization(bm, bk, c)))
    return rows


def chosen_config_report(cfg):
    """The shipped block shapes (qmatmul DEFAULT_BM/BK=64/32, qbgemm gb=8)."""
    m_dim = cfg.eval_b * cfg.seq
    out = []
    for name, c, k in (("q/k/v/o_proj", cfg.d, cfg.d),
                       ("gate/up_proj", cfg.d, cfg.ff),
                       ("down_proj", cfg.ff, cfg.d),
                       ("lm_head", cfg.d, cfg.vocab)):
        bm, bk = min(64, m_dim), min(32, k)
        fp = mm_footprint(m_dim, c, bk, bm, bk)
        out.append((name, bm, bk, c, fp, mxu_utilization(bm, bk, c)))
    bh = cfg.eval_b * cfg.heads
    gb = min(8, bh)
    for name, m, c, k in (("qk_matmul", cfg.seq, cfg.hd, cfg.seq),
                          ("av_matmul", cfg.seq, cfg.seq, cfg.hd)):
        fp = bg_footprint(gb, m, c, k)
        out.append((name, gb, -1, c, fp, mxu_utilization(m, k, c)))
    return out


def main():
    for name, cfg in CONFIGS.items():
        m_dim = cfg.eval_b * cfg.seq
        print(f"\n=== {name} (M = {m_dim}) — shipped block shapes ===")
        print(f"{'layer':<14} {'bm/gb':>6} {'bk':>4} {'C':>5} {'VMEM[KiB]':>10} {'MXU util':>9}")
        for layer, bm, bk, c, fp, util in chosen_config_report(cfg):
            ok = "ok" if fp <= VMEM_BUDGET else "OVER"
            print(f"{layer:<14} {bm:>6} {bk:>4} {c:>5} {fp/1024:>10.1f} {util:>9.3f}  {ok}")
        print(f"\n--- qmatmul block sweep (d-dim layers) ---")
        print(f"{'bm':>4} {'bk':>4} {'C':>5} {'VMEM[KiB]':>10} {'MXU util':>9}")
        for bm, bk, c, fp, util in qmatmul_sweep(cfg, m_dim)[:16]:
            print(f"{bm:>4} {bk:>4} {c:>5} {fp/1024:>10.1f} {util:>9.3f}")


if __name__ == "__main__":
    main()
