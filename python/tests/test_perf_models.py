"""Perf-model invariants (DESIGN.md §8): the shipped kernel block shapes fit
the VMEM budget, the MXU-utilization model behaves, and the lowered HLO has
no redundant matmuls (one dot per quantizable layer per pass)."""

import os

import pytest

from compile.model import CONFIGS
from compile.perf_l1 import (VMEM_BUDGET, chosen_config_report,
                             mxu_utilization)
from compile.perf_l2 import audit

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.parametrize("model", ["tiny-s", "tiny-m"])
def test_shipped_blocks_fit_vmem(model):
    cfg = CONFIGS[model]
    for layer, bm, bk, c, footprint, util in chosen_config_report(cfg):
        assert footprint <= VMEM_BUDGET, (layer, footprint)
        assert 0.0 < util <= 1.0


def test_mxu_utilization_model():
    # Full 128x128x128 tile saturates; halving any dim halves utilization.
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(64, 128, 128) == 0.5
    assert mxu_utilization(64, 64, 128) == 0.25
    # Oversized tiles don't report > 1.
    assert mxu_utilization(256, 256, 256) == 1.0


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "tiny-s")),
                    reason="artifacts not built")
@pytest.mark.parametrize("model", ["tiny-s", "tiny-m"])
def test_hlo_dot_count_matches_layer_table(model):
    cfg = CONFIGS[model]
    ops = audit(ART, model)
    # fwd_ref: one dot per quantizable layer (q,k,v,qk,av,o,gate,up,down
    # per block + lm_head).  XLA may keep a couple of auxiliary dots from
    # rope/softmax lowering; require >= layer count and < 1.5x.
    nq = cfg.n_qlayers
    dots = ops["fwd_ref"].get("dot", 0)
    assert nq <= dots <= int(1.5 * nq) + 2, (dots, nq)
    # Sensitivity is fwd+bwd at high precision: dots roughly 3x fwd
    # (fwd + two grads per matmul), never more than 4x.
    sdots = ops["sensitivity"].get("dot", 0)
    assert 2 * nq <= sdots <= 4 * nq + 4, (sdots, nq)


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "tiny-s")),
                    reason="artifacts not built")
def test_pallas_fwd_structure():
    # interpret-mode pallas_calls lower to per-grid-step computations (XLA
    # unrolls small grids into call/dynamic-slice sequences rather than
    # while loops).  The kernel path must be materially larger than the
    # pure-jnp ref, with identical dot counts (same math, different tiling).
    ops = audit(ART, "tiny-s")
    quant_total = sum(ops["fwd_quant"].values())
    ref_total = sum(ops["fwd_ref"].values())
    assert quant_total > ref_total * 1.2, (quant_total, ref_total)
    assert ops["fwd_quant"].get("dot", 0) == ops["fwd_ref"].get("dot", 0)
    # Block-wise execution shows up as dynamic slicing in the kernel path.
    slices_q = ops["fwd_quant"].get("dynamic-slice", 0) + ops["fwd_quant"].get("slice", 0)
    slices_r = ops["fwd_ref"].get("dynamic-slice", 0) + ops["fwd_ref"].get("slice", 0)
    assert slices_q >= slices_r
