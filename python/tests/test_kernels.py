"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes (incl. non-divisible block dims), mantissa widths,
and scale perturbations; assert_allclose against kernels/ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import qmatmul, _pick_block, vmem_footprint
from compile.kernels.qbgemm import qbgemm, _pick_group
from compile.kernels.ref import qmatmul_ref, qbgemm_ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@given(
    m_dim=st.sampled_from([8, 48, 64, 96, 384]),
    c_dim=st.sampled_from([16, 96, 192]),
    k_dim=st.sampled_from([32, 64, 96, 192]),
    mbits=st.sampled_from([2.0, 3.0, 7.0, 10.0, 23.0]),
    pert=st.sampled_from([1.0, 0.97, 1.05]),
    seed=st.integers(min_value=0, max_value=7),
)
@settings(max_examples=40, deadline=None)
def test_qmatmul_matches_ref(m_dim, c_dim, k_dim, mbits, pert, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m_dim, c_dim), _rand(rng, k_dim, c_dim)
    b = _rand(rng, k_dim)
    got = qmatmul(x, w, b, jnp.float32(mbits), jnp.float32(pert))
    want = qmatmul_ref(x, w, b, jnp.float32(mbits), jnp.float32(pert))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@given(
    g_dim=st.sampled_from([1, 4, 8, 24, 48]),
    m_dim=st.sampled_from([8, 48]),
    c_dim=st.sampled_from([16, 32, 48]),
    k_dim=st.sampled_from([24, 48]),
    mbits=st.sampled_from([2.0, 3.0, 7.0, 23.0]),
    seed=st.integers(min_value=0, max_value=7),
)
@settings(max_examples=30, deadline=None)
def test_qbgemm_matches_ref(g_dim, m_dim, c_dim, k_dim, mbits, seed):
    rng = np.random.default_rng(100 + seed)
    a, b = _rand(rng, g_dim, m_dim, c_dim), _rand(rng, g_dim, c_dim, k_dim)
    got = qbgemm(a, b, jnp.float32(mbits))
    want = qbgemm_ref(a, b, jnp.float32(mbits))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_qmatmul_none_bias():
    rng = np.random.default_rng(0)
    x, w = _rand(rng, 64, 32), _rand(rng, 32, 32)
    got = qmatmul(x, w, None, jnp.float32(23.0), jnp.float32(1.0))
    want = qmatmul_ref(x, w, None, jnp.float32(23.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_qmatmul_identity_at_fp32():
    rng = np.random.default_rng(1)
    x, w = _rand(rng, 64, 96), _rand(rng, 64, 96)
    got = qmatmul(x, w, None, jnp.float32(23.0), jnp.float32(1.0))
    plain = np.asarray(x) @ np.asarray(w).T
    np.testing.assert_allclose(np.asarray(got), plain, rtol=3e-5, atol=3e-5)


def test_qmatmul_under_jit():
    rng = np.random.default_rng(2)
    x, w = _rand(rng, 48, 96), _rand(rng, 32, 96)
    f = jax.jit(lambda x, w, m: qmatmul(x, w, None, m, jnp.float32(1.0)))
    got = f(x, w, jnp.float32(3.0))
    want = qmatmul_ref(x, w, None, jnp.float32(3.0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_quantization_grad_is_zero_through_round():
    # fake-quant uses round(): gradient through the kernel would be degenerate,
    # which is why sensitivity runs at high precision (model.fwd asserts this).
    rng = np.random.default_rng(3)
    x = _rand(rng, 8, 16)
    w = _rand(rng, 8, 16)
    g = jax.grad(lambda x: qmatmul_ref(x, w, None, jnp.float32(3.0)).sum())(x)
    assert np.all(np.isfinite(np.asarray(g)))


def test_pick_block_divides():
    for dim in (7, 31, 48, 64, 96, 384):
        for pref in (8, 32, 64):
            b = _pick_block(dim, pref)
            assert dim % b == 0 and 1 <= b <= max(pref, 1)
    for g in (1, 3, 8, 48):
        gb = _pick_group(g, 8)
        assert g % gb == 0


def test_vmem_footprint_monotone_in_blocks():
    f1 = vmem_footprint(384, 96, 64, 32, 32)
    f2 = vmem_footprint(384, 96, 64, 64, 32)
    assert f2 > f1
