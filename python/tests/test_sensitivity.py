"""The paper's core claim (§2.2 + §3.2): the first-order loss-MSE model
d = sum_l s_l * alpha_f predicts the measured E[(ghat - g)^2]."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus
from compile.model import CONFIGS, fwd, init_params, qlayer_names
from compile.quant import alpha
from compile.sensitivity import sensitivity_fn

CFG = CONFIGS["tiny-s"]
R = 12  # calibration samples for the test


@pytest.fixture(scope="module")
def calib():
    params = init_params(CFG, jax.random.PRNGKey(1))
    rng = np.random.default_rng(42)
    toks = [jnp.asarray(corpus.corpus_batch(rng, CFG, 1)) for _ in range(R)]
    sfn = jax.jit(sensitivity_fn(CFG))
    gs, ss = [], []
    for t in toks:
        g, s = sfn(params, t)
        gs.append(float(g))
        ss.append(np.asarray(s))
    s_mean = np.mean(ss, axis=0)        # eq. (21)
    g2_mean = float(np.mean(np.square(gs)))
    return params, toks, s_mean, g2_mean


def test_sensitivities_positive_finite(calib):
    _, _, s_mean, g2 = calib
    assert s_mean.shape == (CFG.n_qlayers,)
    assert np.all(np.isfinite(s_mean)) and np.all(s_mean >= 0)
    assert np.count_nonzero(s_mean) == CFG.n_qlayers
    assert g2 > 0


def test_sensitivity_spread(calib):
    # Layers must differ in sensitivity — otherwise MP selection is vacuous.
    _, _, s_mean, _ = calib
    assert s_mean.max() / max(s_mean.min(), 1e-30) > 3.0


def _measured_mse(params, toks, mbits, n_noise=8):
    """E over samples and scale-perturbation draws of (ghat - g)^2."""
    errs = []
    rng = np.random.default_rng(0)
    for t in toks:
        _, g = fwd(CFG, params, t, use_pallas=False)
        for _ in range(n_noise):
            ps = jnp.asarray(1.0 + 0.05 * rng.standard_normal(CFG.n_qlayers)
                             .astype(np.float32))
            _, gh = fwd(CFG, params, t, mbits=mbits, pscale=ps,
                        use_pallas=False)
            errs.append(float(gh[0] - g[0]))
    return float(np.mean(np.square(errs)))


@pytest.mark.parametrize("m", [7.0, 5.0])
def test_taylor_prediction_tracks_measurement(calib, m):
    # All layers at m mantissa bits: predicted d = alpha(m) * sum_l s_l.
    params, toks, s_mean, _ = calib
    mbits = jnp.full((CFG.n_qlayers,), m)
    predicted = alpha(m) * float(s_mean.sum())
    measured = _measured_mse(params, toks, mbits)
    assert measured > 0
    # First-order model with independence assumptions: demand the right
    # order of magnitude (paper's Fig. 3a shows the same quality of fit).
    ratio = predicted / measured
    assert 0.1 < ratio < 10.0, (predicted, measured)


def test_additivity_across_layer_groups(calib):
    # Quantizing {first half} and {second half} separately should sum to
    # roughly the MSE of quantizing all (independence assumption, eq. 23/6).
    params, toks, _, _ = calib
    lq = CFG.n_qlayers
    half = lq // 2
    m = 6.0
    base = jnp.full((lq,), 23.0)
    mb_a = base.at[:half].set(m)
    mb_b = base.at[half:].set(m)
    mb_all = jnp.full((lq,), m)
    d_a = _measured_mse(params, toks, mb_a, n_noise=6)
    d_b = _measured_mse(params, toks, mb_b, n_noise=6)
    d_all = _measured_mse(params, toks, mb_all, n_noise=6)
    assert 0.25 < (d_a + d_b) / d_all < 4.0


def test_sensitivity_scales_with_loss_grad(calib):
    # lm_head feeds the loss directly — its sensitivity should be material.
    _, _, s_mean, _ = calib
    names = qlayer_names(CFG)
    lm = s_mean[names.index("lm_head")]
    assert lm > np.percentile(s_mean, 10)
