"""graph.json invariants — the contract with rust graph/partition + gaudisim."""

import numpy as np
import pytest

from compile.graphdef import build_graph
from compile.model import CONFIGS, qlayer_names

CFG = CONFIGS["tiny-s"]


@pytest.fixture(scope="module")
def g():
    return build_graph(CFG)


def _ids(g):
    return [n["id"] for n in g["nodes"]]


def test_unique_ids(g):
    ids = _ids(g)
    assert len(ids) == len(set(ids))


def test_edges_reference_nodes(g):
    ids = set(_ids(g))
    for s, d in g["edges"] + g["residual_edges"]:
        assert s in ids and d in ids


def test_acyclic_topological(g):
    # Kahn's algorithm over all edges must consume every node.
    ids = _ids(g)
    indeg = {i: 0 for i in ids}
    adj = {i: [] for i in ids}
    for s, d in g["edges"] + g["residual_edges"]:
        indeg[d] += 1
        adj[s].append(d)
    queue = [i for i in ids if indeg[i] == 0]
    seen = 0
    while queue:
        v = queue.pop()
        seen += 1
        for w in adj[v]:
            indeg[w] -= 1
            if indeg[w] == 0:
                queue.append(w)
    assert seen == len(ids)


def test_single_source_sink_without_residuals(g):
    ids = set(_ids(g))
    srcs = ids - {d for _, d in g["edges"]}
    sinks = ids - {s for s, _ in g["edges"]}
    assert srcs == {"embed"}
    assert sinks == {"lm_head"}


def test_qidx_bijection(g):
    names = qlayer_names(CFG)
    by_q = {n["qidx"]: n["id"] for n in g["nodes"] if n["qidx"] >= 0}
    assert len(by_q) == CFG.n_qlayers
    for i, name in enumerate(names):
        assert by_q[i] == name
    assert g["qlayers"] == names


def test_engines_and_kinds(g):
    for n in g["nodes"]:
        assert n["engine"] in ("mme", "tpc")
        if n["qidx"] >= 0:
            assert n["engine"] == "mme"
            assert n["kind"] in ("linear", "bgemm")
            assert n["macs"] > 0
        else:
            assert n["macs"] == 0


def test_mac_totals_match_dims(g):
    n = CFG.eval_b * CFG.seq
    byid = {x["id"]: x for x in g["nodes"]}
    assert byid["blk0.q_proj"]["macs"] == n * CFG.d * CFG.d
    assert byid["blk0.gate_proj"]["macs"] == n * CFG.d * CFG.ff
    bh = CFG.eval_b * CFG.heads
    assert byid["blk0.qk_matmul"]["macs"] == bh * CFG.seq * CFG.seq * CFG.hd
    assert byid["lm_head"]["macs"] == n * CFG.d * CFG.vocab


def test_linear_layers_have_param_bytes(g):
    for n in g["nodes"]:
        if n["kind"] == "linear":
            assert n["param_bytes"] == 2 * n["c"] * n["k"]
        if n["kind"] == "bgemm":
            assert n["param_bytes"] == 0


def test_residual_edges_are_skips(g):
    # Every residual edge must short-circuit a path that also exists through
    # the main edges (it is a skip, not the only connection).
    adj = {}
    for s, d in g["edges"]:
        adj.setdefault(s, []).append(d)

    def reachable(a, b):
        stack, seen = [a], set()
        while stack:
            v = stack.pop()
            if v == b:
                return True
            for w in adj.get(v, []):
                if w not in seen:
                    seen.add(w)
                    stack.append(w)
        return False

    for s, d in g["residual_edges"]:
        assert reachable(s, d), (s, d)
