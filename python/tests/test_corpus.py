"""Task-dataset invariants: the rust eval harness relies on these."""

import numpy as np
import pytest

from compile import corpus
from compile.model import CONFIGS, END, PAD, SEP, SYM_BASE

CFG = CONFIGS["tiny-s"]


@pytest.fixture(scope="module")
def tasks():
    return {t.name: t for t in corpus.make_all_tasks(CFG, 16, seed=0)}


def test_line_structure():
    rng = np.random.default_rng(0)
    for _ in range(50):
        line, pstart, seq, rev = corpus.make_line(rng, CFG)
        assert len(line) <= CFG.seq
        assert line[-1] == END
        assert line[pstart - 1] == SEP
        payload = line[pstart:-1]
        assert payload == (seq[::-1] if rev else seq)
        assert all(tk >= SYM_BASE for tk in seq)


def test_batch_shape_and_padding():
    rng = np.random.default_rng(1)
    b = corpus.corpus_batch(rng, CFG, 16)
    assert b.shape == (16, CFG.seq) and b.dtype == np.int32
    assert np.all(b < CFG.vocab) and np.all(b >= 0)
    # PAD only as a suffix.
    for row in b:
        nz = np.nonzero(row == PAD)[0]
        if len(nz):
            assert np.all(row[nz[0]:] == PAD)


@pytest.mark.parametrize("name,k", [("hella", 4), ("lamb", 1),
                                    ("wino", 2), ("piqa", 2)])
def test_task_shapes(tasks, name, k):
    td = tasks[name]
    assert td.k == k
    n = len(td.labels)
    assert td.tokens.shape == (n * k, CFG.seq)
    assert td.spans.shape == (n * k, 2)
    assert np.all(td.labels >= 0)
    if td.kind == "choice":
        assert np.all(td.labels < k)
    else:
        assert np.all(td.labels < CFG.vocab)


def test_spans_valid(tasks):
    for td in tasks.values():
        for row, (s, e) in zip(td.tokens, td.spans):
            assert 0 < s < e <= CFG.seq
            # Scored span is never padding.
            assert np.all(row[s:e] != PAD)


def test_choice_rows_differ_only_where_expected(tasks):
    for name in ("hella", "wino", "piqa"):
        td = tasks[name]
        for ex in range(len(td.labels)):
            rows = td.tokens[ex * td.k:(ex + 1) * td.k]
            spans = td.spans[ex * td.k:(ex + 1) * td.k]
            # All choices share the context before the span start.
            s0 = spans[:, 0].min()
            for r in rows[1:]:
                assert np.array_equal(rows[0][:s0], r[:s0])
            # And at least two rows differ inside the span.
            assert any(not np.array_equal(rows[0], r) for r in rows[1:])


def test_labels_roughly_balanced(tasks):
    td = tasks["hella"]
    counts = np.bincount(td.labels, minlength=td.k)
    assert counts.max() <= len(td.labels)  # sanity
    assert counts.min() >= 0
    # With 16 examples over 4 choices, expect no label to dominate fully.
    assert counts.max() < len(td.labels)


def test_determinism():
    a = corpus.make_all_tasks(CFG, 8, seed=5)
    b = corpus.make_all_tasks(CFG, 8, seed=5)
    for x, y in zip(a, b):
        assert np.array_equal(x.tokens, y.tokens)
        assert np.array_equal(x.labels, y.labels)
    c = corpus.make_all_tasks(CFG, 8, seed=6)
    assert any(not np.array_equal(x.tokens, y.tokens) for x, y in zip(a, c))
