"""Properties of the fake-quant numerics (compile/quant.py)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quant import (FORMATS, alpha, fake_quant, fmax_for_mbits,
                           round_mantissa, tensor_scale)

finite_f32 = st.floats(min_value=-1.0e4, max_value=1.0e4, width=32,
                       allow_nan=False, allow_infinity=False)


def test_alpha_values():
    # alpha_f = 2^-2m / 12 (paper eq. after (16)).
    assert alpha(3) == pytest.approx(2.0 ** -6 / 12.0)
    assert alpha(7) == pytest.approx(2.0 ** -14 / 12.0)
    # Monotone decreasing in m.
    ms = [FORMATS[f]["mbits"] for f in ("fp8_e5m2", "fp8_e4m3", "bf16", "fp16", "fp32")]
    als = [alpha(m) for m in ms]
    assert als == sorted(als, reverse=True)


def test_round_mantissa_identity_at_f32():
    x = jnp.asarray(np.random.default_rng(0).normal(size=256).astype(np.float32))
    y = round_mantissa(x, 23.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-7)


@given(st.integers(min_value=1, max_value=23))
@settings(max_examples=23, deadline=None)
def test_round_mantissa_relative_error_bound(m):
    # |q - v| <= |v| * 2^-m / 2  — matches the noise model (eq. 15).
    x = jnp.asarray(np.random.default_rng(m).normal(size=512).astype(np.float32))
    q = np.asarray(round_mantissa(x, float(m)))
    v = np.asarray(x)
    bound = np.abs(v) * 2.0 ** (-m) * 0.5 * (1 + 1e-5) + 1e-30
    assert np.all(np.abs(q - v) <= bound)


@given(st.integers(min_value=1, max_value=23))
@settings(max_examples=23, deadline=None)
def test_round_mantissa_idempotent(m):
    x = jnp.asarray(np.random.default_rng(m + 99).normal(size=256).astype(np.float32))
    q1 = round_mantissa(x, float(m))
    q2 = round_mantissa(q1, float(m))
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-7)


def test_round_mantissa_preserves_zero_and_sign():
    x = jnp.asarray([0.0, -0.0, 1.5, -1.5, 1e-20, -1e-20], jnp.float32)
    q = np.asarray(round_mantissa(x, 3.0))
    assert q[0] == 0.0 and q[1] == 0.0
    assert q[2] > 0 and q[3] < 0
    assert np.all(np.sign(q[4:]) == np.sign(np.asarray(x[4:])))


def test_fmax_selection():
    assert float(fmax_for_mbits(jnp.float32(2.0))) == 57344.0
    assert float(fmax_for_mbits(jnp.float32(3.0))) == 448.0
    assert float(fmax_for_mbits(jnp.float32(7.0))) > 1e30
    assert float(fmax_for_mbits(jnp.float32(23.0))) > 1e30


def test_fake_quant_fp8_saturation_via_scale():
    # Per-tensor scaling maps max|v| onto fmax: no element exceeds fmax * s.
    v = jnp.asarray([1.0, 100.0, -1000.0, 0.5], jnp.float32)
    q = np.asarray(fake_quant(v, 3.0))
    s = float(tensor_scale(v, jnp.float32(3.0)))
    assert np.max(np.abs(q)) <= 448.0 * s * (1 + 1e-6)
    # Largest element survives scaling approximately.
    assert q[2] == pytest.approx(-1000.0, rel=0.1)


def test_fake_quant_mse_matches_alpha_statistically():
    # E[(q-v)^2] ~= E[v^2] * alpha_f for dense mantissas (eq. 16 aggregated).
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.lognormal(0.0, 1.0, size=200_000).astype(np.float32))
    m = 3.0
    q = np.asarray(fake_quant(v, m))
    rel = (q - np.asarray(v)) / np.asarray(v)
    measured = np.mean(rel ** 2)
    predicted = alpha(m)
    # Rounding noise is not exactly uniform; allow 2x band.
    assert predicted / 2.5 < measured < predicted * 2.5


def test_scale_perturbation_changes_grid():
    v = jnp.asarray(np.random.default_rng(5).normal(size=64).astype(np.float32))
    q1 = np.asarray(fake_quant(v, 3.0, pert=1.0))
    q2 = np.asarray(fake_quant(v, 3.0, pert=1.03))
    assert not np.allclose(q1, q2)
    # ... but both stay close to v.
    np.testing.assert_allclose(q2, np.asarray(v), rtol=0.2, atol=1e-6)


def test_round_mantissa_denormal_safe():
    # Regression: near-denormal inputs must not produce NaN via
    # exp2(m - e) overflow (found by the tiny-m Table-1 sweep).
    v = jnp.asarray([1e-38, -1e-38, 1e-30, 2e-44, 1e30, -1e35], jnp.float32)
    for m in (2.0, 3.0, 7.0, 23.0):
        q = np.asarray(round_mantissa(v, m))
        assert np.all(np.isfinite(q)), (m, q)
    q2 = np.asarray(fake_quant(v, 3.0))
    assert np.all(np.isfinite(q2))
