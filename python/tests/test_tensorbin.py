""".tbin container round-trip (writer here, reader also reimplemented in rust)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.tensorbin import MAGIC, read_tbin, write_tbin


def test_roundtrip_basic(tmp_path):
    p = str(tmp_path / "x.tbin")
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    b = np.arange(6, dtype=np.int32).reshape(2, 3)
    write_tbin(p, [("a", a), ("b", b)])
    out = read_tbin(p)
    assert set(out) == {"a", "b"}
    np.testing.assert_array_equal(out["a"], a)
    np.testing.assert_array_equal(out["b"], b)
    assert out["a"].dtype == np.float32 and out["b"].dtype == np.int32


@given(
    ndim=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=100),
    use_int=st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_roundtrip_random(tmp_path_factory, ndim, seed, use_int):
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(1, 6)) for _ in range(ndim))
    if use_int:
        arr = rng.integers(-1000, 1000, size=shape).astype(np.int32)
    else:
        arr = rng.normal(size=shape).astype(np.float32)
    p = str(tmp_path_factory.mktemp("tb") / "r.tbin")
    write_tbin(p, [("t", arr)])
    out = read_tbin(p)["t"]
    np.testing.assert_array_equal(out, arr)
    assert out.shape == shape


def test_magic_checked(tmp_path):
    p = str(tmp_path / "bad.tbin")
    with open(p, "wb") as f:
        f.write(b"NOTBIN" + b"\x00" * 10)
    with pytest.raises(ValueError):
        read_tbin(p)


def test_rejects_f64(tmp_path):
    p = str(tmp_path / "f64.tbin")
    with pytest.raises(TypeError):
        write_tbin(p, [("x", np.zeros(3, np.float64))])


def test_header_layout(tmp_path):
    p = str(tmp_path / "h.tbin")
    write_tbin(p, [("ab", np.zeros((2,), np.float32))])
    raw = open(p, "rb").read()
    assert raw[:6] == MAGIC
    assert raw[6:10] == (1).to_bytes(4, "little")
    assert raw[10:12] == (2).to_bytes(2, "little")
    assert raw[12:14] == b"ab"
