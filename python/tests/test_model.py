"""L2 model: shapes, masking, quant plumbing, pallas/ref agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus
from compile.model import (CONFIGS, PAD, fwd, init_params, make_taps,
                           param_order, param_shapes, qlayer_kinds,
                           qlayer_names)

CFG = CONFIGS["tiny-s"]


@pytest.fixture(scope="module")
def setup():
    params = init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(corpus.corpus_batch(rng, CFG, CFG.eval_b))
    return params, tokens


def test_qlayer_enumeration():
    names = qlayer_names(CFG)
    assert len(names) == CFG.n_qlayers == 9 * CFG.blocks + 1
    assert names[-1] == "lm_head"
    kinds = qlayer_kinds(CFG)
    assert kinds.count("bgemm") == 2 * CFG.blocks
    # Per-block ordering matches the paper's Fig. 6 walk.
    assert names[:5] == ["blk0.q_proj", "blk0.k_proj", "blk0.v_proj",
                         "blk0.qk_matmul", "blk0.av_matmul"]


def test_param_order_covers_shapes():
    order = param_order(CFG)
    shapes = param_shapes(CFG)
    assert set(order) == set(shapes)
    assert order[0] == "embed" and order[-1] == "lm_head_w"


def test_fwd_shapes(setup):
    params, tokens = setup
    logits, loss = fwd(CFG, params, tokens, use_pallas=False)
    assert logits.shape == (CFG.eval_b, CFG.seq, CFG.vocab)
    assert loss.shape == (CFG.eval_b,)
    assert np.all(np.isfinite(np.asarray(logits)))
    assert np.all(np.asarray(loss) > 0)


def test_loss_ignores_pad(setup):
    params, tokens = setup
    _, loss1 = fwd(CFG, params, tokens, use_pallas=False)
    # Changing logits *at PAD target positions* must not change the loss:
    # replace trailing PADs with other PADs — identical; instead check that a
    # sequence padded earlier yields the same loss as its unpadded prefix stats.
    tk = np.asarray(tokens).copy()
    row = tk[0]
    n_real = int((row != PAD).sum())
    assert n_real < CFG.seq  # corpus lines always leave padding
    _, loss2 = fwd(CFG, params, jnp.asarray(tk), use_pallas=False)
    np.testing.assert_allclose(np.asarray(loss1), np.asarray(loss2), rtol=1e-6)


def test_fp32_quant_is_identity(setup):
    params, tokens = setup
    logits, _ = fwd(CFG, params, tokens, use_pallas=False)
    mb = jnp.full((CFG.n_qlayers,), 23.0)
    ps = jnp.ones((CFG.n_qlayers,))
    lq, _ = fwd(CFG, params, tokens, mbits=mb, pscale=ps, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


def test_pallas_matches_ref_path_fp32(setup):
    params, tokens = setup
    mb = jnp.full((CFG.n_qlayers,), 23.0)
    ps = jnp.ones((CFG.n_qlayers,))
    l1, _ = fwd(CFG, params, tokens, mbits=mb, pscale=ps, use_pallas=True)
    l2, _ = fwd(CFG, params, tokens, mbits=mb, pscale=ps, use_pallas=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)


def test_pallas_matches_ref_path_fp8_loss_scale(setup):
    # At m=3, individual roundings may flip between paths (accumulation order),
    # but the *loss perturbation magnitude* must agree.
    params, tokens = setup
    _, g = fwd(CFG, params, tokens, use_pallas=False)
    mb = jnp.full((CFG.n_qlayers,), 3.0)
    ps = jnp.ones((CFG.n_qlayers,))
    _, ga = fwd(CFG, params, tokens, mbits=mb, pscale=ps, use_pallas=True)
    _, gb = fwd(CFG, params, tokens, mbits=mb, pscale=ps, use_pallas=False)
    da = float(jnp.mean((ga - g) ** 2))
    db = float(jnp.mean((gb - g) ** 2))
    assert da > 0 and db > 0
    assert 0.2 < da / db < 5.0


def test_per_layer_mbits_only_affects_that_layer(setup):
    # Quantizing only lm_head leaves pre-head activations identical:
    # check logits differ but loss of an fp32-config equals hp.
    params, tokens = setup
    mb = jnp.full((CFG.n_qlayers,), 23.0).at[CFG.n_qlayers - 1].set(3.0)
    ps = jnp.ones((CFG.n_qlayers,))
    lq, _ = fwd(CFG, params, tokens, mbits=mb, pscale=ps, use_pallas=False)
    lhp, _ = fwd(CFG, params, tokens, use_pallas=False)
    assert not np.allclose(np.asarray(lq), np.asarray(lhp), rtol=1e-6)
    # and the perturbation is small relative to logit scale
    rel = np.abs(np.asarray(lq) - np.asarray(lhp)).max() / np.abs(np.asarray(lhp)).max()
    assert rel < 0.5


def test_taps_are_neutral_at_ones(setup):
    params, tokens = setup
    logits, loss = fwd(CFG, params, tokens, use_pallas=False)
    taps = make_taps(CFG, CFG.eval_b)
    lt, losst = fwd(CFG, params, tokens, taps=taps, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lt), np.asarray(logits), rtol=1e-5,
                               atol=1e-5)


def test_taps_with_quant_asserts(setup):
    params, tokens = setup
    taps = make_taps(CFG, CFG.eval_b)
    mb = jnp.full((CFG.n_qlayers,), 3.0)
    with pytest.raises(AssertionError):
        fwd(CFG, params, tokens, mbits=mb, pscale=mb, taps=taps)
