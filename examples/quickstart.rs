//! Quickstart: the smallest useful ampq program.
//!
//! Loads the AOT artifacts, partitions the model into sequential sub-graphs
//! (Algorithm 2), calibrates per-layer sensitivity on the real compiled
//! fwd+bwd (the paper's eq. 21), measures per-group time gains on the
//! Gaudi-2-like simulator, and solves the IP (eq. 5) at one threshold.
//!
//! Run: cargo run --release --example quickstart [-- --model tiny-s --tau 0.004]

use ampq::coordinator::{optimize, Pipeline};
use ampq::gaudisim::{HwModel, MpConfig};
use ampq::metrics::Objective;
use ampq::model::Manifest;
use ampq::numerics::PAPER_FORMATS;
use ampq::runtime::FwdMode;
use ampq::util::Args;
use anyhow::Result;
use std::path::Path;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let model = args.get_or("model", "tiny-s");
    let tau = args.f64_or("tau", 0.004)?;

    // 1. Load artifacts (HLO text + weights + graph + calibration data).
    let manifest = Manifest::load(Path::new(args.get_or("artifacts", "artifacts")))?;

    // 2. Partition + calibrate (Algorithm 1, steps 1-2).
    let pl = Pipeline::new(&manifest, model, FwdMode::Ref, HwModel::default(),
                           PAPER_FORMATS.to_vec())?;
    println!(
        "{model}: {} sequential sub-graphs over {} quantizable layers; E[g^2] = {:.4}",
        pl.partition.groups.len(),
        pl.info.n_qlayers,
        pl.calibration.eg2
    );

    // 3. Measure per-group empirical time gains (Algorithm 1, step 3).
    let tm = pl.measure_time(0, 5)?;
    println!("baseline TTFT {:.1} us (simulated Gaudi-2-like)", tm.base_ttft);

    // 4. Solve the IP at tau (Algorithm 1, step 4).
    let family = pl.family(Objective::EmpiricalTime, &tm);
    let out = optimize(&family.groups, &pl.calibration, tau)?;
    println!(
        "tau = {tau}: quantized {} / {} layers, predicted gain {:.1} us, \
         predicted loss-MSE {:.3e} (budget {:.3e})",
        out.config.n_quantized(),
        out.config.len(),
        out.solution.gain,
        out.predicted_mse,
        out.budget
    );
    println!("config bits (0=BF16, 1=FP8): {}", out.config.bits_label());

    // 5. Check the chosen config against a direct simulator measurement.
    let direct = pl.simulated_ttft(&out.config, 1, 5);
    let base = pl.simulated_ttft(&MpConfig::all_bf16(pl.info.n_qlayers), 2, 5);
    println!(
        "direct re-measurement: TTFT {:.1} -> {:.1} us ({:.1}% reduction)",
        base,
        direct,
        100.0 * (base - direct) / base
    );
    Ok(())
}
