//! Quickstart: the smallest useful ampq program, on the staged planning API.
//!
//! An `Engine` materializes the stage artifacts (partition -> calibration ->
//! time measurement) once — loading them from artifacts/cache/ when present —
//! and a `Planner` answers the actual query in microseconds, returning a
//! self-contained, JSON-serializable `Plan`.
//!
//! With `--demo` everything runs on the synthetic transformer (no AOT
//! artifacts or PJRT needed) — this is what CI executes.  `--device` picks a
//! hardware profile from the backend registry (`gaudi2`, `gaudi3`,
//! `generic-gpu`, `cpu-roofline`) or a JSON profile file.
//!
//! Run: cargo run --release --example quickstart [-- --demo --device gaudi3]

use ampq::backend::Registry;
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest};
use ampq::util::Args;
use anyhow::Result;
use std::path::PathBuf;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["demo"])?;
    let demo = args.flag("demo");
    let model = args.get_or("model", if demo { "demo" } else { "tiny-s" });
    let tau = args.f64_or("tau", 0.004)?;
    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let device = Registry::builtin().resolve(args.get_or("device", "gaudi2"))?;

    // 1. Point an Engine at the AOT artifacts (or the synthetic demo model)
    //    and the target device; stage products cache on disk per device.
    let mut engine = Engine::new()
        .with_artifacts_root(root.clone())
        .with_cache_dir(root.join("cache"))
        .with_device(device);
    if demo {
        let (graph, qlayers, calibration) = demo_model(2, 7);
        engine.register_synthetic("demo", graph, qlayers, calibration);
    }

    // 2. Materialize (or load) the stage artifacts and get a Planner.
    let planner = engine.planner(model)?;
    println!(
        "{model} on {}: {} sequential sub-graphs over {} quantizable layers; E[g^2] = {:.4}",
        planner.device().name,
        planner.partitioned().partition.groups.len(),
        planner.n_qlayers(),
        planner.calibration().eg2
    );
    let c = engine.counters();
    println!(
        "stage passes this run: {} partition, {} calibration, {} measurement ({} from cache)",
        c.partition_passes, c.calibration_passes, c.measurement_passes, c.cache_loads
    );

    // 3. One planning query (eq. 5) — microseconds, no recomputation.  The
    //    builder composes constraints; add `.with_memory_cap(bytes)` for a
    //    joint loss-MSE + weight-byte solve.
    let plan = planner.solve(
        &PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
    )?;
    println!("{}", plan.summary());

    // 4. The Plan is a self-contained artifact: ship it as JSON.
    println!("{}", plan.to_json().to_string());
    Ok(())
}
