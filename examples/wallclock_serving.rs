//! Serving-style wall-clock driver: batched requests through the REAL
//! AOT-compiled Pallas forward on the PJRT CPU client — no simulator, no
//! python.  Reports throughput and latency percentiles per MP configuration,
//! proving the artifact path (L1 Pallas -> L2 JAX -> HLO text -> rust PJRT)
//! composes into a deployable request loop.
//!
//! Run: cargo run --release --example wallclock_serving [-- --model tiny-s --requests 32]

use ampq::gaudisim::MpConfig;
use ampq::model::Manifest;
use ampq::numerics::Format;
use ampq::runtime::{FwdMode, ModelRuntime, Runtime};
use ampq::util::{stats, Args, Rng};
use anyhow::Result;
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let model = args.get_or("model", "tiny-s");
    let n_requests = args.usize_or("requests", 32)?;

    let manifest = Manifest::load(Path::new(args.get_or("artifacts", "artifacts")))?;
    let rt = Runtime::new()?;
    let info = manifest.model(model)?.clone();
    println!("loading {model} (pallas fwd) on {} ...", rt.platform());
    let t0 = Instant::now();
    let mr = ModelRuntime::load(&rt, &manifest.root, &info, FwdMode::Pallas)?;
    println!("compiled in {:.2}s", t0.elapsed().as_secs_f64());

    // Synthesize a request stream from the calibration distribution.
    let calib = info.load_calib(&manifest.root)?;
    let mut rng = Rng::new(42);
    let batches: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| {
            (0..info.eval_b)
                .map(|_| calib[rng.below(calib.len())].clone())
                .collect::<Vec<_>>()
                .concat()
        })
        .collect();

    let nq = info.n_qlayers;
    let ones = vec![1.0f32; nq];
    for (name, cfg) in [
        ("BF16 (baseline)", MpConfig::all_bf16(nq)),
        ("FP8 (all quantized)", MpConfig::uniform(nq, Format::Fp8E4m3)),
    ] {
        // Warmup then serve.
        mr.fwd(&batches[0], &cfg, &ones)?;
        let mut lat = Vec::with_capacity(batches.len());
        let serve0 = Instant::now();
        let mut checksum = 0.0f64;
        for b in &batches {
            let t = Instant::now();
            let out = mr.fwd(b, &cfg, &ones)?;
            lat.push(t.elapsed().as_secs_f64() * 1e3);
            checksum += out.loss.iter().map(|&x| x as f64).sum::<f64>();
        }
        let wall = serve0.elapsed().as_secs_f64();
        let seqs = (n_requests * info.eval_b) as f64;
        println!(
            "{name:<22} {:>6.1} seq/s | batch latency p50 {:>7.2} ms  p95 {:>7.2} ms  mean {:>7.2} ms | mean loss {:.4}",
            seqs / wall,
            stats::median(&lat),
            stats::percentile(&lat, 95.0),
            stats::mean(&lat),
            checksum / seqs
        );
    }
    println!(
        "(CPU fake-quant ADDS work, so FP8 is not faster here — Gaudi-2-shaped \
         gains come from the simulator; this driver proves the real artifact path.)"
    );
    Ok(())
}
