//! Serving-style wall-clock driver: batched requests through the REAL
//! AOT-compiled Pallas forward on the PJRT CPU client — no simulator, no
//! python.  Reports throughput and latency percentiles per MP configuration,
//! proving the artifact path (L1 Pallas -> L2 JAX -> HLO text -> rust PJRT)
//! composes into a deployable request loop.  The runtime handle comes from
//! the same Engine that serves planning queries.
//!
//! Run: cargo run --release --example wallclock_serving [-- --model tiny-s --requests 32]

use ampq::gaudisim::MpConfig;
use ampq::numerics::Format;
use ampq::plan::Engine;
use ampq::runtime::FwdMode;
use ampq::util::{stats, Args, Rng};
use anyhow::Result;
use std::path::PathBuf;
use std::time::Instant;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let model = args.get_or("model", "tiny-s");
    let n_requests = args.usize_or("requests", 32)?;

    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut engine = Engine::new()
        .with_artifacts_root(root.clone())
        .with_fwd_mode(FwdMode::Pallas);
    let info = engine.info(model)?;

    // Synthesize a request stream from the calibration distribution.
    let calib = info.load_calib(&root)?;
    let mut rng = Rng::new(42);
    let batches: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| {
            (0..info.eval_b)
                .map(|_| calib[rng.below(calib.len())].clone())
                .collect::<Vec<_>>()
                .concat()
        })
        .collect();

    println!("loading {model} (pallas fwd) ...");
    let t0 = Instant::now();
    let mr = engine.runtime(model)?;
    println!("compiled in {:.2}s", t0.elapsed().as_secs_f64());

    let nq = info.n_qlayers;
    let ones = vec![1.0f32; nq];
    for (name, cfg) in [
        ("BF16 (baseline)", MpConfig::all_bf16(nq)),
        ("FP8 (all quantized)", MpConfig::uniform(nq, Format::Fp8E4m3)),
    ] {
        // Warmup then serve.
        mr.fwd(&batches[0], &cfg, &ones)?;
        let mut lat = Vec::with_capacity(batches.len());
        let serve0 = Instant::now();
        let mut checksum = 0.0f64;
        for b in &batches {
            let t = Instant::now();
            let out = mr.fwd(b, &cfg, &ones)?;
            lat.push(t.elapsed().as_secs_f64() * 1e3);
            checksum += out.loss.iter().map(|&x| x as f64).sum::<f64>();
        }
        let wall = serve0.elapsed().as_secs_f64();
        let seqs = (n_requests * info.eval_b) as f64;
        println!(
            "{name:<22} {:>6.1} seq/s | batch latency p50 {:>7.2} ms  p95 {:>7.2} ms  mean {:>7.2} ms | mean loss {:.4}",
            seqs / wall,
            stats::median(&lat),
            stats::percentile(&lat, 95.0),
            stats::mean(&lat),
            checksum / seqs
        );
    }
    println!(
        "(CPU fake-quant ADDS work, so FP8 is not faster here — Gaudi-2-shaped \
         gains come from the simulator; this driver proves the real artifact path.)"
    );
    Ok(())
}
