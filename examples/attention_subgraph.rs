//! The paper's Fig. 1 scenario: why per-GROUP time measurement is needed.
//!
//! Measures the attention sub-graph (q, k, v, qk_matmul, av_matmul) of a
//! transformer block under all 2^5 MP configurations and compares:
//!   * measured per-group gain (ground truth under the simulator),
//!   * the sum of per-layer gain measurements (the naive predictor),
//!   * the MAC-based theoretical gain, scale+bias fitted.
//!
//! Uses only the stage-1 artifact + the simulator, so it runs without PJRT —
//! and with --demo, without AOT artifacts at all.
//!
//! Run: cargo run --release --example attention_subgraph [-- --model tiny-m | --demo]

use ampq::gaudisim::{HwModel, Simulator};
use ampq::metrics::tt_layer_gain;
use ampq::numerics::{Format, PAPER_FORMATS};
use ampq::plan::demo::demo_model;
use ampq::plan::Engine;
use ampq::exec::ExecPool;
use ampq::timing::{measure_groups, measure_per_layer, SimTtft};
use ampq::util::{stats, Args};
use anyhow::{anyhow, Result};
use std::path::PathBuf;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &["demo"])?;
    let demo = args.flag("demo");
    let model = args.get_or("model", if demo { "demo" } else { "tiny-m" });
    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));

    let mut engine = Engine::new().with_artifacts_root(root);
    if demo {
        let (graph, qlayers, calibration) = demo_model(2, 7);
        engine.register_synthetic("demo", graph, qlayers, calibration);
    }
    let part = engine.partitioned(model)?;
    let graph = engine.graph(model)?;

    let gi = part
        .partition
        .groups
        .iter()
        .position(|g| g.len() == 5)
        .ok_or_else(|| anyhow!("no attention group"))?;
    let qnames: Vec<&str> = part.partition.groups[gi]
        .qidxs
        .iter()
        .map(|&q| part.qlayers[q].name.as_str())
        .collect();
    println!("attention sub-graph V{gi}: {}", qnames.join(", "));

    let hw = HwModel { noise_std: 0.005, ..HwModel::default() };
    let sim = Simulator::new(&graph, hw);
    let src = SimTtft { sim, seed: 7, reps: 5 };
    let pool = ExecPool::default();
    let tm = measure_groups(&src, &part.partition, &PAPER_FORMATS, &pool)?;
    let per_layer = measure_per_layer(&src, &PAPER_FORMATS, &pool)?;
    let group = &tm.groups[gi];

    let mut rows: Vec<(String, f64, f64, f64)> = group
        .configs
        .iter()
        .zip(&group.gains)
        .map(|(fmts, &measured)| {
            let label: String =
                fmts.iter().map(|f| if *f == Format::Bf16 { '0' } else { '1' }).collect();
            let summed: f64 = group
                .qidxs
                .iter()
                .zip(fmts)
                .map(|(&q, &f)| per_layer[q][if f == Format::Bf16 { 0 } else { 1 }])
                .sum();
            let theo: f64 = group
                .qidxs
                .iter()
                .zip(fmts)
                .map(|(&q, &f)| tt_layer_gain(&part.qlayers[q], f, engine.device()))
                .sum();
            (label, measured, summed, theo)
        })
        .collect();
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    let (a, b) = stats::linfit(
        &rows.iter().map(|r| r.3).collect::<Vec<_>>(),
        &rows.iter().map(|r| r.1).collect::<Vec<_>>(),
    );
    println!(
        "{:>8} {:>14} {:>18} {:>20}",
        "config", "measured[us]", "sum-per-layer[us]", "theoretical-fit[us]"
    );
    for (label, m, s, t) in &rows {
        println!("{label:>8} {m:>14.2} {s:>18.2} {:>20.2}", a * t + b);
    }

    let gaps: Vec<f64> = rows.iter().map(|r| (r.2 - r.1).abs()).collect();
    let tgaps: Vec<f64> = rows.iter().map(|r| (a * r.3 + b - r.1).abs()).collect();
    let max_gain = rows.last().unwrap().1;
    println!(
        "\nmean |error| vs measured: per-layer sum {:.1} us ({:.0}% of max gain), \
         fitted theoretical {:.1} us ({:.0}% of max gain)",
        stats::mean(&gaps),
        100.0 * stats::mean(&gaps) / max_gain,
        stats::mean(&tgaps),
        100.0 * stats::mean(&tgaps) / max_gain
    );
    println!(
        "=> neither per-layer summation nor MAC counting predicts branched-sub-graph \
         timing; measuring each group directly (the paper's method) is required."
    );
    Ok(())
}
