//! END-TO-END DRIVER (DESIGN.md §6): the full paper pipeline on a real
//! workload, proving all three layers compose:
//!
//!   L1/L2  the AOT-compiled JAX+Pallas forward & sensitivity executables
//!          run through PJRT from rust (no python at runtime);
//!   L3     Engine stages (partition -> calibration -> time measurement) ->
//!          Planner queries -> task evaluation, comparing IP-ET vs Random
//!          vs Prefix.
//!
//! Prints the paper's headline: IP-ET achieves better accuracy at equal or
//! lower TTFT than both baselines.  Results are recorded in EXPERIMENTS.md.
//!
//! Run: cargo run --release --example e2e_pipeline [-- --model tiny-s --seeds 3]

use ampq::coordinator::Strategy;
use ampq::evalharness::{load_all_tasks, CachedEvaluator};
use ampq::figures::sweep::{aggregate, run_sweep, SweepInputs};
use ampq::metrics::Objective;
use ampq::plan::Engine;
use ampq::util::Args;
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::time::Instant;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let model = args.get_or("model", "tiny-s");
    let n_seeds = args.u64_or("seeds", 3)?;
    let t0 = Instant::now();

    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut engine = Engine::new()
        .with_artifacts_root(root.clone())
        .with_cache_dir(root.join("cache"));

    let planner = engine.planner(model)?;
    println!(
        "[{:6.1}s] staged artifacts ready: {} groups, calibration R={}, E[g^2]={:.4}, \
         baseline TTFT {:.1} us",
        t0.elapsed().as_secs_f64(),
        planner.partitioned().partition.groups.len(),
        planner.calibration().n_samples,
        planner.calibration().eg2,
        planner.measurements().base_ttft
    );

    let info = engine.info(model)?;
    let graph = engine.graph(model)?;
    let tasks_root = engine
        .artifacts_root()
        .ok_or_else(|| anyhow!("no artifacts root"))?
        .to_path_buf();
    let tasks = load_all_tasks(&tasks_root, &info)?;
    let device = engine.device().clone();
    let mr = engine.runtime(model)?;
    let mut eval = CachedEvaluator::new(mr, &tasks);
    let inputs = SweepInputs {
        planner: &planner,
        qlayers: &info.qlayers,
        graph: &graph,
        device,
        tasks: &tasks,
    };

    let taus = [0.0, 0.001, 0.002, 0.003, 0.004, 0.005, 0.006, 0.007];
    let sweep = run_sweep(
        &inputs,
        Objective::EmpiricalTime,
        &taus,
        n_seeds,
        0.02,
        &[Strategy::Ip, Strategy::Random, Strategy::Prefix],
        &mut eval,
    )?;
    println!(
        "[{:6.1}s] evaluated {} sweep points ({} unique forward configs)",
        t0.elapsed().as_secs_f64(),
        sweep.points.len(),
        eval.cache_len()
    );

    println!("\nbaseline (all-BF16): TTFT {:.1} us, task acc {:?}", sweep.baseline.ttft_us,
        sweep.task_names.iter().zip(&sweep.baseline.task_acc)
            .map(|(n, a)| format!("{n}={a:.3}")).collect::<Vec<_>>());

    println!("\n== accuracy-vs-TTFT (avg over {} tasks, {} seeds) ==", sweep.task_names.len(), n_seeds);
    println!("{:>8} | {:>22} | {:>22} | {:>22}", "tau", "IP-ET", "Random", "Prefix");
    let agg_ip = aggregate(&sweep, Strategy::Ip);
    let agg_rnd = aggregate(&sweep, Strategy::Random);
    let agg_pre = aggregate(&sweep, Strategy::Prefix);
    for i in 0..agg_ip.len() {
        let cell = |a: &ampq::figures::sweep::AggPoint| {
            format!("{:7.1}us {:+.3}±{:.3}%", a.ttft_us, a.acc_diff_mean, a.acc_diff_std)
        };
        println!(
            "{:>8.4} | {:>22} | {:>22} | {:>22}",
            agg_ip[i].tau, cell(&agg_ip[i]), cell(&agg_rnd[i]), cell(&agg_pre[i])
        );
    }

    // Headline: at the most aggressive tau, compare accuracy at the
    // IP's TTFT against what baselines need for similar accuracy.
    let last = agg_ip.last().unwrap();
    let base_ttft = sweep.baseline.ttft_us;
    println!(
        "\nheadline: IP-ET at tau={:.3}% reaches TTFT {:.1} us ({:.1}% faster than BF16) \
         with avg accuracy diff {:+.3}%",
        last.tau * 100.0,
        last.ttft_us,
        100.0 * (base_ttft - last.ttft_us) / base_ttft,
        last.acc_diff_mean
    );
    for (name, agg) in [("Random", &agg_rnd), ("Prefix", &agg_pre)] {
        let a = agg.last().unwrap();
        println!(
            "          {name} at the same budget: TTFT {:.1} us, accuracy diff {:+.3}%",
            a.ttft_us, a.acc_diff_mean
        );
    }
    let ip_better_count = (0..agg_ip.len())
        .filter(|&i| {
            agg_ip[i].acc_diff_mean >= agg_rnd[i].acc_diff_mean - 1e-9
                || agg_ip[i].ttft_us <= agg_rnd[i].ttft_us + 1e-9
        })
        .count();
    println!(
        "IP-ET dominates Random (better acc or faster) at {}/{} thresholds",
        ip_better_count,
        agg_ip.len()
    );
    println!("[{:6.1}s] done", t0.elapsed().as_secs_f64());
    Ok(())
}
