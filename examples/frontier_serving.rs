//! Frontier serving: drive a concurrent `PlanService` with a batch of mixed
//! multi-constraint requests against the synthetic demo model — the 0.3
//! serving story end to end, no AOT artifacts or PJRT needed.
//!
//! 1. An `Engine` stages the demo model once and wraps its planner in a
//!    `PlanService` (Send + Sync, clones share state).
//! 2. `Planner::frontier` precomputes the tau -> gain Pareto curve; lookups
//!    against it are O(log n) and bypass the IP solver entirely.
//! 3. A batch of requests — pointwise solves, loss+memory two-constraint
//!    queries, and frontier lookups — is answered across worker threads;
//!    the frontier is swept exactly once no matter how many threads race.
//!
//! Run: cargo run --release --example frontier_serving [-- --blocks 2 --threads 4]

use ampq::coordinator::Strategy;
use ampq::exec::{ExecCfg, ExecPool};
use ampq::metrics::Objective;
use ampq::plan::demo::demo_model;
use ampq::plan::{Engine, PlanRequest, ServeRequest};
use ampq::util::Args;
use anyhow::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let blocks = args.usize_or("blocks", 2)?;
    let threads = args.usize_or("threads", 4)?;

    // 1. Stage the synthetic model once; wrap it in a concurrent service.
    let (graph, qlayers, calibration) = demo_model(blocks, 7);
    let mut engine = Engine::new();
    engine.register_synthetic("demo", graph, qlayers, calibration);
    let svc = engine.service(&["demo"])?;

    // 2. Precompute and print the empirical-time Pareto frontier.
    let t0 = Instant::now();
    let frontier = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip)?;
    println!(
        "frontier(IP-ET): {} Pareto points over tau in [0, {:.5}], swept in {:.1} ms",
        frontier.len(),
        frontier.tau_max,
        t0.elapsed().as_secs_f64() * 1e3
    );
    for p in frontier.points.iter().take(6) {
        println!(
            "  tau>={:.5}  mse={:.3e}  gain={:>8.2} us  nq={}",
            p.tau,
            p.predicted_mse,
            p.gain,
            p.config.n_quantized()
        );
    }
    if frontier.len() > 6 {
        println!("  ... {} more points", frontier.len() - 6);
    }

    // 3. A mixed batch: pointwise solves across objectives, two-constraint
    //    (loss + memory cap) requests, and cached-frontier lookups.
    let probe = svc.solve(
        "demo",
        &PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.007),
    )?;
    let mut reqs: Vec<ServeRequest> = Vec::new();
    for &tau in &[0.001, 0.002, 0.003, 0.004, 0.005, 0.006, 0.007] {
        reqs.push(ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
        ));
        reqs.push(
            ServeRequest::new(
                "demo",
                PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
            )
            .via_frontier(),
        );
        reqs.push(ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::Memory)
                .with_loss_budget(tau)
                .with_memory_cap(probe.weight_bytes * 1.05),
        ));
        reqs.push(ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::TheoreticalTime)
                .with_loss_budget(tau)
                .with_strategy(Strategy::Prefix),
        ));
    }

    let t1 = Instant::now();
    let answers = svc.serve_batch(&reqs, &ExecPool::new(ExecCfg::new(threads)))?;
    let elapsed = t1.elapsed();
    println!(
        "\nserved {} mixed requests on {} threads in {:.1} ms ({:.1} us/request, {} frontier sweeps total)",
        answers.len(),
        threads,
        elapsed.as_secs_f64() * 1e3,
        elapsed.as_secs_f64() * 1e6 / answers.len() as f64,
        svc.frontier_solves()
    );
    for (req, a) in reqs.iter().zip(&answers).take(8) {
        let gain = a.get("gain")?.f64()?;
        println!(
            "  {:<5} {:<7} tau={:<6} {} gain={:.2}",
            req.request.objective.key(),
            req.request.strategy.key(),
            req.request.tau.map(|t| format!("{t}")).unwrap_or_else(|| "-".into()),
            if req.via_frontier { "frontier" } else { "solve   " },
            gain
        );
    }
    println!("  ...");

    // The service shares ONE planner and ONE frontier across every thread:
    // stage passes stay at one per stage for the whole batch.
    let c = engine.counters();
    println!(
        "stage passes for the entire run: {} partition, {} calibration, {} measurement",
        c.partition_passes, c.calibration_passes, c.measurement_passes
    );
    Ok(())
}
