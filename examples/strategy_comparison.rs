//! Table-1-style strategy comparison across both models and all three
//! objective families (IP-ET / IP-TT / IP-M vs Random / Prefix).
//!
//! A reduced-scale version of `ampq figures --fig table1` suitable for a
//! quick interactive run; pass --seeds/--models for larger sweeps.
//!
//! Run: cargo run --release --example strategy_comparison [-- --seeds 2]

use ampq::coordinator::{Pipeline, Strategy};
use ampq::evalharness::{load_all_tasks, CachedEvaluator};
use ampq::figures::sweep::run_sweep;
use ampq::gaudisim::HwModel;
use ampq::metrics::Objective;
use ampq::model::Manifest;
use ampq::numerics::PAPER_FORMATS;
use ampq::report;
use ampq::runtime::FwdMode;
use ampq::util::Args;
use anyhow::Result;
use std::path::Path;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let n_seeds = args.u64_or("seeds", 2)?;
    let models: Vec<&str> = args.get_or("models", "tiny-s,tiny-m").split(',').collect();
    let taus = [0.0, 0.002, 0.004, 0.007];

    let manifest = Manifest::load(Path::new(args.get_or("artifacts", "artifacts")))?;
    let header: Vec<String> = ["model", "family", "strategy", "avg acc diff [%]", "lamb ppl diff [%]"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let mut rows: Vec<Vec<String>> = Vec::new();

    for model in &models {
        let pl = Pipeline::new(&manifest, model, FwdMode::Ref, HwModel::default(),
                               PAPER_FORMATS.to_vec())?;
        let tm = pl.measure_time(0, 5)?;
        let tasks = load_all_tasks(&manifest.root, &pl.info)?;
        let mut eval = CachedEvaluator::new(&pl.mr, &tasks);
        let lamb = tasks.iter().position(|t| t.meta.name == "lamb").unwrap();

        for objective in [Objective::EmpiricalTime, Objective::TheoreticalTime, Objective::Memory] {
            let family = pl.family(objective, &tm);
            let sweep = run_sweep(
                &pl, &family, &tasks, &taus, n_seeds, 0.02,
                &[Strategy::Random, Strategy::Prefix, Strategy::Ip], &mut eval,
            )?;
            for strategy in [Strategy::Random, Strategy::Prefix, Strategy::Ip] {
                let pts: Vec<_> =
                    sweep.points.iter().filter(|p| p.strategy == strategy).collect();
                let accd: Vec<f64> = pts
                    .iter()
                    .map(|p| {
                        p.task_acc
                            .iter()
                            .zip(&sweep.baseline.task_acc)
                            .map(|(a, b)| (a - b) * 100.0)
                            .sum::<f64>()
                            / p.task_acc.len() as f64
                    })
                    .collect();
                let ppld: Vec<f64> = pts
                    .iter()
                    .map(|p| (p.task_ppl[lamb] / sweep.baseline.task_ppl[lamb] - 1.0) * 100.0)
                    .collect();
                rows.push(vec![
                    model.to_string(),
                    objective.name().into(),
                    strategy.name().into(),
                    report::pm(ampq::util::stats::mean(&accd), ampq::util::stats::std(&accd)),
                    report::pm(ampq::util::stats::mean(&ppld), ampq::util::stats::std(&ppld)),
                ]);
            }
        }
        println!("({model} done)");
    }

    println!("\n{}", report::format_table(&header, &rows));
    println!("(paper Table 1 shape: IP rows should dominate Random/Prefix within each family)");
    Ok(())
}
