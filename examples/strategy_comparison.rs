//! Table-1-style strategy comparison across both models and all three
//! objective families (IP-ET / IP-TT / IP-M vs Random / Prefix), driven by
//! one Engine: each model pays one calibration + one measurement pass, and
//! all nine (family, strategy) sweeps are pure Planner queries.
//!
//! A reduced-scale version of `ampq figures --fig table1` suitable for a
//! quick interactive run; pass --seeds/--models for larger sweeps.
//!
//! Run: cargo run --release --example strategy_comparison [-- --seeds 2]

use ampq::coordinator::Strategy;
use ampq::evalharness::{load_all_tasks, CachedEvaluator};
use ampq::figures::sweep::{run_sweep, SweepInputs};
use ampq::metrics::Objective;
use ampq::plan::Engine;
use ampq::report;
use ampq::util::Args;
use anyhow::{anyhow, Result};
use std::path::PathBuf;

fn main() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[])?;
    let n_seeds = args.u64_or("seeds", 2)?;
    let models: Vec<&str> = args.get_or("models", "tiny-s,tiny-m").split(',').collect();
    let taus = [0.0, 0.002, 0.004, 0.007];

    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let mut engine = Engine::new()
        .with_artifacts_root(root.clone())
        .with_cache_dir(root.join("cache"));

    let header: Vec<String> = ["model", "family", "strategy", "avg acc diff [%]", "lamb ppl diff [%]"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let mut rows: Vec<Vec<String>> = Vec::new();

    for model in &models {
        let planner = engine.planner(model)?;
        let info = engine.info(model)?;
        let graph = engine.graph(model)?;
        let tasks_root = engine
            .artifacts_root()
            .ok_or_else(|| anyhow!("no artifacts root"))?
            .to_path_buf();
        let tasks = load_all_tasks(&tasks_root, &info)?;
        let device = engine.device().clone();
        let lamb = tasks.iter().position(|t| t.meta.name == "lamb").unwrap();
        let mr = engine.runtime(model)?;
        let mut eval = CachedEvaluator::new(mr, &tasks);
        let inputs = SweepInputs {
            planner: &planner,
            qlayers: &info.qlayers,
            graph: &graph,
            device,
            tasks: &tasks,
        };

        for objective in Objective::ALL {
            let sweep = run_sweep(
                &inputs, objective, &taus, n_seeds, 0.02,
                &[Strategy::Random, Strategy::Prefix, Strategy::Ip], &mut eval,
            )?;
            for strategy in [Strategy::Random, Strategy::Prefix, Strategy::Ip] {
                let pts: Vec<_> =
                    sweep.points.iter().filter(|p| p.strategy == strategy).collect();
                let accd: Vec<f64> = pts
                    .iter()
                    .map(|p| {
                        p.task_acc
                            .iter()
                            .zip(&sweep.baseline.task_acc)
                            .map(|(a, b)| (a - b) * 100.0)
                            .sum::<f64>()
                            / p.task_acc.len() as f64
                    })
                    .collect();
                let ppld: Vec<f64> = pts
                    .iter()
                    .map(|p| (p.task_ppl[lamb] / sweep.baseline.task_ppl[lamb] - 1.0) * 100.0)
                    .collect();
                rows.push(vec![
                    model.to_string(),
                    objective.name().into(),
                    strategy.name().into(),
                    report::pm(ampq::util::stats::mean(&accd), ampq::util::stats::std(&accd)),
                    report::pm(ampq::util::stats::mean(&ppld), ampq::util::stats::std(&ppld)),
                ]);
            }
        }
        println!("({model} done)");
    }

    println!("\n{}", report::format_table(&header, &rows));
    println!("(paper Table 1 shape: IP rows should dominate Random/Prefix within each family)");
    Ok(())
}
