//! Exact MCKP via depth-first branch & bound with LP-relaxation pruning.
//!
//! Groups are branched in descending "spread" (max-min gain) order so strong
//! decisions come first; at each node the LP bound of the remaining suffix
//! prunes hopeless subtrees.  Paper-scale instances (J <= ~40 groups, up to
//! 32 choices) solve in well under a millisecond; a node cap keeps worst-case
//! behaviour bounded (falls back to the greedy incumbent, still feasible).

use super::greedy;
use super::hull::HullPoint;
use super::lp_relax;
use super::problem::{Mckp, Solution};

const NODE_CAP: usize = 5_000_000;

struct Ctx<'a> {
    p: &'a Mckp,
    order: Vec<usize>,
    /// suffix_hulls[i] = hulls of groups order[i..] (re-indexed).
    hulls: Vec<Vec<HullPoint>>,
    /// min cost of suffix starting at order position i.
    suffix_min_cost: Vec<f64>,
    best: Solution,
    nodes: usize,
}

pub fn solve(p: &Mckp) -> Solution {
    // Incumbent: greedy (always produces min-cost fallback at worst).
    let incumbent = greedy::solve(p);
    if !incumbent.feasible {
        // Even all-min-cost exceeds budget: nothing better exists.
        return incumbent;
    }

    let hulls = lp_relax::hulls(p);
    // Branch order: descending gain spread.
    let mut order: Vec<usize> = (0..p.n_groups()).collect();
    let spread = |j: usize| -> f64 {
        let g = &p.gains[j];
        g.iter().cloned().fold(f64::MIN, f64::max) - g.iter().cloned().fold(f64::MAX, f64::min)
    };
    order.sort_by(|&a, &b| spread(b).partial_cmp(&spread(a)).unwrap());

    let n = p.n_groups();
    let mut suffix_min_cost = vec![0.0f64; n + 1];
    for i in (0..n).rev() {
        let j = order[i];
        let mc = p.costs[j].iter().cloned().fold(f64::MAX, f64::min);
        suffix_min_cost[i] = suffix_min_cost[i + 1] + mc;
    }

    let mut ctx = Ctx {
        p,
        hulls,
        suffix_min_cost,
        best: incumbent,
        nodes: 0,
        order,
    };
    let mut choice = vec![0usize; n];
    dfs(&mut ctx, 0, 0.0, 0.0, &mut choice);
    ctx.best
}

fn suffix_lp_bound(ctx: &Ctx, pos: usize, remaining_budget: f64) -> f64 {
    // LP relaxation over groups order[pos..] with the remaining budget:
    // start at min-cost hull points, apply increments in efficiency order.
    let mut base_gain = 0.0;
    let mut base_cost = 0.0;
    let mut incs: Vec<(f64, f64)> = Vec::new(); // (efficiency-ordered dgain, dcost)
    for i in pos..ctx.order.len() {
        let h = &ctx.hulls[ctx.order[i]];
        base_gain += h[0].gain;
        base_cost += h[0].cost;
        for t in 1..h.len() {
            incs.push((h[t].gain - h[t - 1].gain, h[t].cost - h[t - 1].cost));
        }
    }
    let mut remaining = remaining_budget - base_cost;
    if remaining < 0.0 {
        // Suffix can't even afford its min-cost choices — signal prune.
        return f64::MIN;
    }
    incs.sort_by(|a, b| (b.0 / b.1).partial_cmp(&(a.0 / a.1)).unwrap_or(std::cmp::Ordering::Equal));
    let mut bound = base_gain;
    for (dg, dc) in incs {
        if remaining <= 0.0 {
            break;
        }
        if dc <= remaining {
            bound += dg;
            remaining -= dc;
        } else {
            bound += dg * (remaining / dc);
            break;
        }
    }
    bound
}

fn dfs(ctx: &mut Ctx, pos: usize, gain: f64, cost: f64, choice: &mut Vec<usize>) {
    ctx.nodes += 1;
    if ctx.nodes > NODE_CAP {
        return;
    }
    if pos == ctx.order.len() {
        if cost <= ctx.p.budget + 1e-12 && gain > ctx.best.gain + 1e-12 {
            // Un-permute the choice vector.
            let mut c = vec![0usize; choice.len()];
            for (i, &j) in ctx.order.iter().enumerate() {
                c[j] = choice[i];
            }
            ctx.best = ctx.p.solution_from(c);
        }
        return;
    }
    // Feasibility + optimality prune.
    if cost + ctx.suffix_min_cost[pos] > ctx.p.budget + 1e-12 {
        return;
    }
    let bound = gain + suffix_lp_bound(ctx, pos, ctx.p.budget - cost);
    if bound <= ctx.best.gain + 1e-12 {
        return;
    }
    let j = ctx.order[pos];
    // Visit choices in descending gain (find good incumbents early).
    let mut idxs: Vec<usize> = (0..ctx.p.gains[j].len()).collect();
    idxs.sort_by(|&a, &b| ctx.p.gains[j][b].partial_cmp(&ctx.p.gains[j][a]).unwrap());
    for i in idxs {
        let c = cost + ctx.p.costs[j][i];
        if c > ctx.p.budget + 1e-12 {
            continue;
        }
        choice[pos] = i;
        dfs(ctx, pos + 1, gain + ctx.p.gains[j][i], c, choice);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::problem::gen::random;
    use crate::util::Rng;

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = Rng::new(1234);
        for trial in 0..300 {
            let p = random(&mut rng, 5, 5);
            let exact = p.brute_force();
            let bb = solve(&p);
            assert_eq!(bb.feasible, exact.feasible, "trial {trial}");
            if exact.feasible {
                assert!(
                    (bb.gain - exact.gain).abs() < 1e-9,
                    "trial {trial}: bb {} vs brute {}",
                    bb.gain,
                    exact.gain
                );
                assert!(bb.cost <= p.budget + 1e-9);
            }
        }
    }

    #[test]
    fn respects_budget_always() {
        let mut rng = Rng::new(99);
        for _ in 0..100 {
            let p = random(&mut rng, 8, 6);
            let s = solve(&p);
            if s.feasible {
                assert!(s.cost <= p.budget + 1e-9);
            }
            assert_eq!(s.choice.len(), p.n_groups());
            for (j, &c) in s.choice.iter().enumerate() {
                assert!(c < p.gains[j].len());
            }
        }
    }

    #[test]
    fn attention_scale_instance_fast() {
        // Paper-scale: 10 groups of 32 configs (2^5 attention groups).
        let mut rng = Rng::new(5);
        let mut gains = Vec::new();
        let mut costs = Vec::new();
        for _ in 0..10 {
            gains.push((0..32).map(|_| rng.f64() * 10.0).collect::<Vec<_>>());
            costs.push((0..32).map(|_| rng.f64()).collect::<Vec<_>>());
        }
        let p = Mckp::new(gains, costs, 5.0).unwrap();
        let t0 = std::time::Instant::now();
        let s = solve(&p);
        assert!(s.feasible);
        assert!(t0.elapsed().as_millis() < 2000);
    }

    #[test]
    fn infeasible_budget() {
        let p = Mckp::new(vec![vec![5.0]], vec![vec![3.0]], 1.0).unwrap();
        let s = solve(&p);
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]);
    }

    #[test]
    fn zero_budget_picks_zero_cost() {
        let p = Mckp::new(
            vec![vec![0.0, 9.0], vec![0.0, 9.0]],
            vec![vec![0.0, 1.0], vec![0.0, 1.0]],
            0.0,
        )
        .unwrap();
        let s = solve(&p);
        assert!(s.feasible);
        assert_eq!(s.choice, vec![0, 0]);
    }
}
