//! Exact MCKP via branch & bound with LP-relaxation pruning over every
//! cost dimension — depth-first sequentially, or fanned out over a
//! deterministic subproblem queue (`solve_with`).
//!
//! Groups are branched in descending "spread" (max-min gain) order so strong
//! decisions come first; at each node the suffix is pruned on (a) per-dim
//! min-cost feasibility and (b) the tightest single-dimension LP bound —
//! each single-constraint relaxation upper-bounds the multi-constraint
//! optimum, so their minimum is a valid bound.  Paper-scale instances
//! (J <= ~40 groups, up to 32 choices) solve in well under a millisecond; a
//! node cap keeps worst-case behaviour bounded by returning the best
//! solution found so far — the feasible greedy incumbent in the
//! single-constraint case, but a capped multi-constraint search that has
//! not reached any feasible leaf yet reports the infeasible fallback even
//! if feasible assignments exist (never observed below the cap).
//!
//! Multi-constraint instances may have NO feasible assignment even when
//! each dimension is satisfiable alone; in that case the search proves it
//! and the min-primary-cost fallback is returned with `feasible = false`.
//!
//! ## Parallel determinism
//!
//! `solve_with` must return BIT-IDENTICAL output at any thread count (the
//! exec layer's contract), which rules out the classic racy
//! shared-incumbent design.  Instead:
//!
//! * Large instances decompose into a fixed subproblem tree (choice
//!   prefixes up to a split depth) that is a pure function of the instance
//!   — never of the thread count.  Subproblems are drained through an
//!   [`crate::exec::WorkQueue`] (workers expand prefix nodes and push the
//!   children, an irregular load).
//! * Each leaf subproblem is solved by the same DFS used sequentially,
//!   with its local incumbent starting at the (deterministic) greedy gain
//!   — so every report is a pure function of `(instance, subproblem)`.
//! * Reports are reduced in subproblem (DFS preorder) key order with
//!   strict-improvement acceptance, reproducing the sequential tie-break.
//! * A shared atomic incumbent (the "floor": the best gain reported so
//!   far, any order) lets workers skip whole subproblems — but only when
//!   the subproblem's root LP bound sits a one-sided safety margin BELOW
//!   the floor (2*EPS plus a relative term absorbing summation noise).
//!   A skipped subproblem's best is then strictly below the final reduced
//!   maximum, so skipping can never change the argmax: the floor
//!   accelerates without entering the result.
//!
//! Small instances route to the sequential DFS at every thread count, so
//! the "same instance -> same code path" invariant holds there too.

use super::greedy;
use super::hull::HullPoint;
use super::lp_relax;
use super::problem::{Mckp, Solution};
use super::EPS;
use crate::exec::{ExecPool, WorkQueue};
use std::sync::atomic::{AtomicU64, Ordering};

const NODE_CAP: usize = 5_000_000;
/// Instances with fewer total assignments than this solve sequentially at
/// any thread count (subproblem bookkeeping would dominate the microsecond
/// serving-path solves).
const PAR_MIN_ASSIGNMENTS: usize = 1 << 20;
/// Decomposition targets at least this many leaf subproblems...
const SPLIT_TARGET: usize = 128;
/// ...expanding choice prefixes at most this deep.
const MAX_SPLIT_DEPTH: usize = 4;

/// Immutable search context shared by every subproblem.
struct Shared<'a> {
    p: &'a Mckp,
    order: Vec<usize>,
    /// hulls[d][j] = dim-d efficient frontier of group j (original index).
    hulls: Vec<Vec<Vec<HullPoint>>>,
    /// suffix_min[d][i] = min dim-d cost of groups order[i..].
    suffix_min: Vec<Vec<f64>>,
    /// Per-position choice visit order (descending gain), shared so the
    /// prefix expansion and the DFS branch identically.
    idxs: Vec<Vec<usize>>,
}

/// Mutable state of one DFS run (one subproblem, or the whole tree).
struct Search {
    /// Strict-improvement threshold: leaves must exceed this to be taken.
    best_gain: f64,
    /// Accepted leaf in branch order (un-permuted lazily at the end).
    best: Option<Vec<usize>>,
    nodes: usize,
    /// Node budget of THIS run: the whole of NODE_CAP sequentially, or a
    /// proportional share per subproblem when decomposed — so the total
    /// worst-case work stays ~NODE_CAP either way (and per-run caps are
    /// pure functions of the instance, keeping truncation deterministic).
    cap: usize,
}

fn build_shared(p: &Mckp) -> Shared<'_> {
    let hulls: Vec<Vec<Vec<HullPoint>>> =
        (0..p.n_dims()).map(|d| lp_relax::hulls_for(p, d)).collect();
    // Branch order: descending gain spread.
    let mut order: Vec<usize> = (0..p.n_groups()).collect();
    let spread = |j: usize| -> f64 {
        let g = &p.gains[j];
        g.iter().cloned().fold(f64::MIN, f64::max) - g.iter().cloned().fold(f64::MAX, f64::min)
    };
    order.sort_by(|&a, &b| spread(b).total_cmp(&spread(a)).then(a.cmp(&b)));

    let n = p.n_groups();
    let mut suffix_min = vec![vec![0.0f64; n + 1]; p.n_dims()];
    for d in 0..p.n_dims() {
        for i in (0..n).rev() {
            let j = order[i];
            let mc = p.costs[d].table[j].iter().cloned().fold(f64::MAX, f64::min);
            suffix_min[d][i] = suffix_min[d][i + 1] + mc;
        }
    }
    // Visit choices in descending gain (find good incumbents early).
    let idxs: Vec<Vec<usize>> = order
        .iter()
        .map(|&j| {
            let mut ix: Vec<usize> = (0..p.gains[j].len()).collect();
            ix.sort_by(|&a, &b| p.gains[j][b].total_cmp(&p.gains[j][a]));
            ix
        })
        .collect();
    Shared { p, order, hulls, suffix_min, idxs }
}

/// The greedy incumbent plus the quick infeasibility checks shared by both
/// entry points.  `Err(solution)` means "answer immediately".
fn incumbent(p: &Mckp) -> Result<Solution, Solution> {
    // Incumbent: greedy (always produces min-cost fallback at worst).
    let incumbent = greedy::solve(p);
    if !incumbent.feasible {
        if p.is_single() {
            // Even all-min-cost exceeds the budget: nothing better exists.
            return Err(incumbent);
        }
        // Multi-constraint: per-dim independent minima prove infeasibility;
        // otherwise a feasible assignment may still exist — search for it.
        for d in 0..p.n_dims() {
            if p.independent_min_cost(d) > p.budgets[d] + EPS {
                return Err(incumbent);
            }
        }
    }
    Ok(incumbent)
}

pub fn solve(p: &Mckp) -> Solution {
    solve_with(p, &ExecPool::sequential())
}

/// Observation-only search introspection, surfaced as span counters.
/// Atomics because decomposed leaves run on pool threads; NOTHING in the
/// search reads these back, so they cannot perturb the result.
#[derive(Default)]
struct BbStats {
    nodes: AtomicU64,
    subs_skipped: AtomicU64,
}

/// Solve across `pool`; output is bit-identical at any thread count.
pub fn solve_with(p: &Mckp, pool: &ExecPool) -> Solution {
    let mut sp = crate::obs::span("solver.branch_bound");
    sp.counter("groups", p.n_groups() as f64);
    let inc = match incumbent(p) {
        Ok(s) => s,
        Err(s) => {
            sp.counter("pruned_at_root", 1.0);
            return s;
        }
    };
    let sh = build_shared(p);
    // Route purely by instance size: small instances take the sequential
    // DFS even on a wide pool, so thread count never selects the code path.
    let assignments = p
        .gains
        .iter()
        .fold(1usize, |acc, g| acc.saturating_mul(g.len()));
    let stats = BbStats::default();
    let sol = if p.n_groups() < MAX_SPLIT_DEPTH || assignments < PAR_MIN_ASSIGNMENTS {
        solve_sequential(&sh, inc, &stats)
    } else {
        solve_decomposed(&sh, inc, pool, &stats)
    };
    sp.counter("nodes", stats.nodes.load(Ordering::Relaxed) as f64);
    sp.counter("subs_skipped", stats.subs_skipped.load(Ordering::Relaxed) as f64);
    sp.counter("feasible", if sol.feasible { 1.0 } else { 0.0 });
    sol
}

fn solve_sequential(sh: &Shared, inc: Solution, stats: &BbStats) -> Solution {
    let inc_gain = if inc.feasible { inc.gain } else { f64::NEG_INFINITY };
    let mut st = Search { best_gain: inc_gain, best: None, nodes: 0, cap: NODE_CAP };
    let mut choice = vec![0usize; sh.p.n_groups()];
    let mut cost = vec![0.0f64; sh.p.n_dims()];
    dfs(sh, &mut st, 0, 0.0, &mut cost, &mut choice);
    stats.nodes.fetch_add(st.nodes as u64, Ordering::Relaxed);
    finish(sh, st, inc)
}

/// Un-permute an accepted branch-order choice vector into a Solution.
fn materialize(sh: &Shared, branch_choice: &[usize]) -> Solution {
    let mut c = vec![0usize; branch_choice.len()];
    for (i, &j) in sh.order.iter().enumerate() {
        c[j] = branch_choice[i];
    }
    sh.p.solution_from(c)
}

fn finish(sh: &Shared, st: Search, inc: Solution) -> Solution {
    match st.best {
        Some(bc) => materialize(sh, &bc),
        None => inc,
    }
}

/// One subproblem: a choice prefix over `sh.order[..pos]`.
struct Sub {
    /// DFS-preorder key: the rank of each prefix choice in its group's
    /// visit order.  Lexicographic key order == sequential DFS order.
    key: Vec<u16>,
    pos: usize,
    gain: f64,
    cost: Vec<f64>,
    choice: Vec<usize>,
}

/// Monotone max on an f64 stored as bits (gains only grow, so a CAS loop
/// on the decoded value suffices; NEG_INFINITY round-trips fine).
fn atomic_max_f64(a: &AtomicU64, v: f64) {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        if f64::from_bits(cur) >= v {
            return;
        }
        match a.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// Hard ceiling on the prefix-expansion product, bounding both the
/// subproblem count and how thin the per-subproblem node budget gets.
const MAX_SUBPROBLEMS: usize = 4096;

/// Depth (pure in the instance) to which choice prefixes are expanded,
/// and the resulting prefix product (an upper bound on the subproblem
/// count, used to share NODE_CAP proportionally).
fn split_depth(sh: &Shared) -> (usize, usize) {
    let mut depth = 0usize;
    let mut count = 1usize;
    while depth < sh.order.len() && depth < MAX_SPLIT_DEPTH && count < SPLIT_TARGET {
        let next = count.saturating_mul(sh.p.gains[sh.order[depth]].len());
        if next > MAX_SUBPROBLEMS {
            break;
        }
        count = next;
        depth += 1;
    }
    (depth, count)
}

/// The tightest single-dimension LP bound at a subproblem root (same
/// arithmetic the DFS uses for its optimality prune).
fn root_bound(sh: &Shared, sub: &Sub) -> f64 {
    let mut bound = f64::INFINITY;
    for d in 0..sh.p.n_dims() {
        let b = sub.gain + suffix_lp_bound(sh, d, sub.pos, sh.p.budgets[d] - sub.cost[d]);
        bound = bound.min(b);
    }
    bound
}

fn solve_decomposed(sh: &Shared, inc: Solution, pool: &ExecPool, stats: &BbStats) -> Solution {
    let inc_gain = if inc.feasible { inc.gain } else { f64::NEG_INFINITY };
    let (depth, prefix_product) = split_depth(sh);
    // Share the sequential node budget across the (at most prefix_product)
    // leaf subproblems, so decomposition cannot multiply the worst-case
    // work.  The floor keeps tiny shares from starving well-pruned
    // subtrees; both terms are pure in the instance.
    let sub_cap = (NODE_CAP / prefix_product.max(1)).max(1024);
    // Shared incumbent floor: best REPORTED gain so far (any completion
    // order).  Only ever used to skip subproblems provably strictly below
    // the final maximum — see the module docs.
    let floor = AtomicU64::new(inc_gain.to_bits());
    // Skip margin: 2*EPS for the bound semantics plus a relative term
    // absorbing float summation noise (a subtree's re-summed gain can sit
    // a few ulps-per-term ABOVE its accumulated root bound; the skip must
    // stay strictly one-sided for the floor to be result-invariant).
    let gain_mag: f64 = sh
        .p
        .gains
        .iter()
        .map(|g| g.iter().fold(0.0f64, |m, x| m.max(x.abs())))
        .sum();
    let skip_margin = 2.0 * EPS + 1e-9 * (1.0 + gain_mag);

    let root = Sub {
        key: Vec::new(),
        pos: 0,
        gain: 0.0,
        cost: vec![0.0f64; sh.p.n_dims()],
        choice: vec![0usize; sh.p.n_groups()],
    };
    let reports: Vec<(Vec<u16>, Solution)> =
        WorkQueue::run(pool, vec![root], |sub: Sub, q: &WorkQueue<Sub>| {
            if sub.pos < depth {
                // Prefix node: expand children in DFS choice order.
                let j = sh.order[sub.pos];
                'children: for (rank, &i) in sh.idxs[sub.pos].iter().enumerate() {
                    for d in 0..sh.p.n_dims() {
                        let c = sub.cost[d] + sh.p.costs[d].table[j][i];
                        if c + sh.suffix_min[d][sub.pos + 1] > sh.p.budgets[d] + EPS {
                            continue 'children;
                        }
                    }
                    let mut key = sub.key.clone();
                    key.push(rank as u16);
                    let mut cost = sub.cost.clone();
                    for (d, c) in cost.iter_mut().enumerate() {
                        *c += sh.p.costs[d].table[j][i];
                    }
                    let mut choice = sub.choice.clone();
                    choice[sub.pos] = i;
                    q.push(Sub {
                        key,
                        pos: sub.pos + 1,
                        gain: sub.gain + sh.p.gains[j][i],
                        cost,
                        choice,
                    });
                }
                return None;
            }
            // Leaf subproblem.  Skip when provably strictly below the final
            // maximum (the one-sided margin means a skipped subproblem can
            // never tie the reduced argmax, so timing cannot leak in).
            let fl = f64::from_bits(floor.load(Ordering::Relaxed));
            if root_bound(sh, &sub) <= fl - skip_margin {
                stats.subs_skipped.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            let mut st = Search { best_gain: inc_gain, best: None, nodes: 0, cap: sub_cap };
            let mut cost = sub.cost.clone();
            let mut choice = sub.choice.clone();
            dfs(sh, &mut st, sub.pos, sub.gain, &mut cost, &mut choice);
            stats.nodes.fetch_add(st.nodes as u64, Ordering::Relaxed);
            let found = st.best.as_deref().map(|bc| materialize(sh, bc));
            match found {
                Some(sol) => {
                    atomic_max_f64(&floor, sol.gain);
                    Some((sub.key, sol))
                }
                None => None,
            }
        });

    // Ordered reduction: strict improvement in DFS-preorder key order
    // reproduces the sequential first-found tie-break.
    let mut best = inc;
    let mut best_gain = inc_gain;
    for (_, sol) in reports {
        if sol.gain > best_gain {
            best_gain = sol.gain;
            best = sol;
        }
    }
    best
}

fn suffix_lp_bound(sh: &Shared, d: usize, pos: usize, remaining_budget: f64) -> f64 {
    // LP relaxation of dim d over groups order[pos..] with the remaining
    // budget: start at min-cost hull points, apply increments in efficiency
    // order.
    let mut base_gain = 0.0;
    let mut base_cost = 0.0;
    let mut incs: Vec<(f64, f64)> = Vec::new(); // (efficiency-ordered dgain, dcost)
    for i in pos..sh.order.len() {
        let h = &sh.hulls[d][sh.order[i]];
        base_gain += h[0].gain;
        base_cost += h[0].cost;
        for t in 1..h.len() {
            incs.push((h[t].gain - h[t - 1].gain, h[t].cost - h[t - 1].cost));
        }
    }
    let mut remaining = remaining_budget - base_cost;
    if remaining < 0.0 {
        // Suffix can't even afford its min-cost choices — signal prune.
        return f64::MIN;
    }
    // Total order via the shared `solver::efficiency` (hulls strictly
    // increase in cost, so 0/0 never forms, but degenerate tables must not
    // reorder unstably between runs).
    incs.sort_by(|a, b| {
        super::efficiency(b.0, b.1).total_cmp(&super::efficiency(a.0, a.1))
    });
    let mut bound = base_gain;
    for (dg, dc) in incs {
        if remaining <= 0.0 {
            break;
        }
        if dc <= remaining {
            bound += dg;
            remaining -= dc;
        } else {
            bound += dg * (remaining / dc);
            break;
        }
    }
    bound
}

fn dfs(
    sh: &Shared,
    st: &mut Search,
    pos: usize,
    gain: f64,
    cost: &mut Vec<f64>,
    choice: &mut Vec<usize>,
) {
    st.nodes += 1;
    if st.nodes > st.cap {
        return;
    }
    if pos == sh.order.len() {
        // Strict acceptance: the first leaf attaining a new maximum wins,
        // so the accepted leaf is the subtree argmax independent of any
        // floor-based skipping around this subtree.
        if gain > st.best_gain && sh.p.fits(cost) {
            st.best_gain = gain;
            st.best = Some(choice.clone());
        }
        return;
    }
    // Feasibility prune (every dimension).
    for d in 0..sh.p.n_dims() {
        if cost[d] + sh.suffix_min[d][pos] > sh.p.budgets[d] + EPS {
            return;
        }
    }
    // Optimality prune: each single-dimension LP relaxation upper-bounds
    // the multi-constraint optimum, so the FIRST one at or below the
    // incumbent already proves the subtree cannot strictly improve.
    for d in 0..sh.p.n_dims() {
        let bound = gain + suffix_lp_bound(sh, d, pos, sh.p.budgets[d] - cost[d]);
        if bound <= st.best_gain {
            return;
        }
    }
    let j = sh.order[pos];
    'choices: for &i in &sh.idxs[pos] {
        for d in 0..sh.p.n_dims() {
            if cost[d] + sh.p.costs[d].table[j][i] > sh.p.budgets[d] + EPS {
                continue 'choices;
            }
        }
        for (d, c) in cost.iter_mut().enumerate() {
            *c += sh.p.costs[d].table[j][i];
        }
        choice[pos] = i;
        dfs(sh, st, pos + 1, gain + sh.p.gains[j][i], cost, choice);
        for (d, c) in cost.iter_mut().enumerate() {
            *c -= sh.p.costs[d].table[j][i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCfg;
    use crate::solver::problem::gen::{random, random_multi};
    use crate::solver::CostDim;
    use crate::util::Rng;

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = Rng::new(1234);
        for trial in 0..300 {
            let p = random(&mut rng, 5, 5);
            let exact = p.brute_force();
            let bb = solve(&p);
            assert_eq!(bb.feasible, exact.feasible, "trial {trial}");
            if exact.feasible {
                assert!(
                    (bb.gain - exact.gain).abs() < 1e-9,
                    "trial {trial}: bb {} vs brute {}",
                    bb.gain,
                    exact.gain
                );
                assert!(bb.cost <= p.budget() + 1e-9);
            }
        }
    }

    #[test]
    fn matches_brute_force_on_multi_constraint_instances() {
        let mut rng = Rng::new(7777);
        for trial in 0..300 {
            let dims = 2 + (trial % 2) as usize;
            let p = random_multi(&mut rng, 4, 4, dims);
            let exact = p.brute_force();
            let bb = solve(&p);
            assert_eq!(bb.feasible, exact.feasible, "trial {trial}");
            if exact.feasible {
                assert!(
                    (bb.gain - exact.gain).abs() < 1e-9,
                    "trial {trial}: bb {} vs brute {}",
                    bb.gain,
                    exact.gain
                );
                assert!(p.fits(&bb.costs), "trial {trial}");
            }
        }
    }

    #[test]
    fn parallel_solve_is_bit_identical_to_sequential() {
        // The decomposed path must reproduce the single-thread result
        // EXACTLY — gains, costs, and the chosen assignment.
        let mut rng = Rng::new(0xDE7E12);
        let pools = [
            ExecPool::sequential(),
            ExecPool::new(ExecCfg::new(2)),
            ExecPool::new(ExecCfg::new(8)),
        ];
        for trial in 0..40 {
            let dims = 1 + (trial % 3 == 0) as usize;
            // Big enough to cross the decomposition threshold.
            let p = random_multi(&mut rng, 10, 8, dims);
            let base = solve_with(&p, &pools[0]);
            for pool in &pools[1..] {
                let par = solve_with(&p, pool);
                assert_eq!(base, par, "trial {trial}");
            }
        }
    }

    #[test]
    fn parallel_solve_stays_exact() {
        // The decomposed path is still an exact solver.
        let mut rng = Rng::new(0xBEEF);
        let pool = ExecPool::new(ExecCfg::new(4));
        for trial in 0..20 {
            let p = random_multi(&mut rng, 7, 5, 2);
            let exact = p.brute_force();
            let bb = solve_with(&p, &pool);
            assert_eq!(bb.feasible, exact.feasible, "trial {trial}");
            if exact.feasible {
                assert!((bb.gain - exact.gain).abs() < 1e-9, "trial {trial}");
                assert!(p.fits(&bb.costs), "trial {trial}");
            }
        }
    }

    #[test]
    fn respects_budget_always() {
        let mut rng = Rng::new(99);
        for _ in 0..100 {
            let p = random(&mut rng, 8, 6);
            let s = solve(&p);
            if s.feasible {
                assert!(s.cost <= p.budget() + 1e-9);
            }
            assert_eq!(s.choice.len(), p.n_groups());
            for (j, &c) in s.choice.iter().enumerate() {
                assert!(c < p.gains[j].len());
            }
        }
    }

    #[test]
    fn attention_scale_instance_fast() {
        // Paper-scale: 10 groups of 32 configs (2^5 attention groups).
        let mut rng = Rng::new(5);
        let mut gains = Vec::new();
        let mut costs = Vec::new();
        for _ in 0..10 {
            gains.push((0..32).map(|_| rng.f64() * 10.0).collect::<Vec<_>>());
            costs.push((0..32).map(|_| rng.f64()).collect::<Vec<_>>());
        }
        let p = Mckp::new(gains, costs, 5.0).unwrap();
        let t0 = std::time::Instant::now();
        let s = solve(&p);
        assert!(s.feasible);
        assert!(t0.elapsed().as_millis() < 2000);
    }

    #[test]
    fn two_dim_attention_scale_instance_fast() {
        let mut rng = Rng::new(6);
        let mut gains = Vec::new();
        let mut mse = Vec::new();
        let mut bytes = Vec::new();
        for _ in 0..10 {
            gains.push((0..32).map(|_| rng.f64() * 10.0).collect::<Vec<_>>());
            mse.push((0..32).map(|_| rng.f64()).collect::<Vec<_>>());
            bytes.push((0..32).map(|_| rng.f64() * 2.0).collect::<Vec<_>>());
        }
        let p = Mckp::multi(
            gains,
            vec![CostDim::new("mse", mse), CostDim::new("bytes", bytes)],
            vec![5.0, 12.0],
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let s = solve(&p);
        assert!(s.feasible);
        assert!(p.fits(&s.costs));
        assert!(t0.elapsed().as_millis() < 4000);
    }

    #[test]
    fn finds_feasible_when_greedy_start_violates_secondary_budget() {
        // Min-primary-cost start (choice 0 everywhere) violates the bytes
        // cap; the only feasible assignment flips both groups to choice 1.
        let p = Mckp::multi(
            vec![vec![0.0, 4.0], vec![0.0, 3.0]],
            vec![
                CostDim::new("mse", vec![vec![0.0, 1.0], vec![0.0, 1.0]]),
                CostDim::new("bytes", vec![vec![4.0, 1.0], vec![4.0, 1.0]]),
            ],
            vec![10.0, 3.0],
        )
        .unwrap();
        let s = solve(&p);
        assert!(s.feasible);
        assert_eq!(s.choice, vec![1, 1]);
        assert_eq!(s.gain, 7.0);
    }

    #[test]
    fn infeasible_budget() {
        let p = Mckp::new(vec![vec![5.0]], vec![vec![3.0]], 1.0).unwrap();
        let s = solve(&p);
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]);
    }

    #[test]
    fn jointly_infeasible_multi_returns_fallback() {
        let p = Mckp::multi(
            vec![vec![1.0, 5.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 3.0]]),
                CostDim::new("b", vec![vec![3.0, 0.0]]),
            ],
            vec![1.0, 1.0],
        )
        .unwrap();
        let s = solve(&p);
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]);
        assert_eq!(s, p.brute_force());
    }

    #[test]
    fn zero_budget_picks_zero_cost() {
        let p = Mckp::new(
            vec![vec![0.0, 9.0], vec![0.0, 9.0]],
            vec![vec![0.0, 1.0], vec![0.0, 1.0]],
            0.0,
        )
        .unwrap();
        let s = solve(&p);
        assert!(s.feasible);
        assert_eq!(s.choice, vec![0, 0]);
    }
}
