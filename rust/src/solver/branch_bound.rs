//! Exact MCKP via depth-first branch & bound with LP-relaxation pruning,
//! over every cost dimension.
//!
//! Groups are branched in descending "spread" (max-min gain) order so strong
//! decisions come first; at each node the suffix is pruned on (a) per-dim
//! min-cost feasibility and (b) the tightest single-dimension LP bound —
//! each single-constraint relaxation upper-bounds the multi-constraint
//! optimum, so their minimum is a valid bound.  Paper-scale instances
//! (J <= ~40 groups, up to 32 choices) solve in well under a millisecond; a
//! node cap keeps worst-case behaviour bounded by returning the best
//! solution found so far — the feasible greedy incumbent in the
//! single-constraint case, but a capped multi-constraint search that has
//! not reached any feasible leaf yet reports the infeasible fallback even
//! if feasible assignments exist (never observed below the cap).
//!
//! Multi-constraint instances may have NO feasible assignment even when
//! each dimension is satisfiable alone; in that case the search proves it
//! and the min-primary-cost fallback is returned with `feasible = false`.

use super::greedy;
use super::hull::HullPoint;
use super::lp_relax;
use super::problem::{Mckp, Solution};
use super::EPS;

const NODE_CAP: usize = 5_000_000;

struct Ctx<'a> {
    p: &'a Mckp,
    order: Vec<usize>,
    /// hulls[d][j] = dim-d efficient frontier of group j (original index).
    hulls: Vec<Vec<Vec<HullPoint>>>,
    /// suffix_min[d][i] = min dim-d cost of groups order[i..].
    suffix_min: Vec<Vec<f64>>,
    best: Solution,
    /// Gain of the best FEASIBLE solution found (-inf before the first).
    best_gain: f64,
    nodes: usize,
}

pub fn solve(p: &Mckp) -> Solution {
    // Incumbent: greedy (always produces min-cost fallback at worst).
    let incumbent = greedy::solve(p);
    if !incumbent.feasible {
        if p.is_single() {
            // Even all-min-cost exceeds the budget: nothing better exists.
            return incumbent;
        }
        // Multi-constraint: per-dim independent minima prove infeasibility;
        // otherwise a feasible assignment may still exist — search for it.
        for d in 0..p.n_dims() {
            if p.independent_min_cost(d) > p.budgets[d] + EPS {
                return incumbent;
            }
        }
    }
    let best_gain = if incumbent.feasible { incumbent.gain } else { f64::NEG_INFINITY };

    let hulls: Vec<Vec<Vec<HullPoint>>> =
        (0..p.n_dims()).map(|d| lp_relax::hulls_for(p, d)).collect();
    // Branch order: descending gain spread.
    let mut order: Vec<usize> = (0..p.n_groups()).collect();
    let spread = |j: usize| -> f64 {
        let g = &p.gains[j];
        g.iter().cloned().fold(f64::MIN, f64::max) - g.iter().cloned().fold(f64::MAX, f64::min)
    };
    order.sort_by(|&a, &b| spread(b).partial_cmp(&spread(a)).unwrap());

    let n = p.n_groups();
    let mut suffix_min = vec![vec![0.0f64; n + 1]; p.n_dims()];
    for d in 0..p.n_dims() {
        for i in (0..n).rev() {
            let j = order[i];
            let mc = p.costs[d].table[j].iter().cloned().fold(f64::MAX, f64::min);
            suffix_min[d][i] = suffix_min[d][i + 1] + mc;
        }
    }

    let mut ctx = Ctx {
        p,
        hulls,
        suffix_min,
        best: incumbent,
        best_gain,
        nodes: 0,
        order,
    };
    let mut choice = vec![0usize; n];
    let mut cost = vec![0.0f64; p.n_dims()];
    dfs(&mut ctx, 0, 0.0, &mut cost, &mut choice);
    ctx.best
}

fn suffix_lp_bound(ctx: &Ctx, d: usize, pos: usize, remaining_budget: f64) -> f64 {
    // LP relaxation of dim d over groups order[pos..] with the remaining
    // budget: start at min-cost hull points, apply increments in efficiency
    // order.
    let mut base_gain = 0.0;
    let mut base_cost = 0.0;
    let mut incs: Vec<(f64, f64)> = Vec::new(); // (efficiency-ordered dgain, dcost)
    for i in pos..ctx.order.len() {
        let h = &ctx.hulls[d][ctx.order[i]];
        base_gain += h[0].gain;
        base_cost += h[0].cost;
        for t in 1..h.len() {
            incs.push((h[t].gain - h[t - 1].gain, h[t].cost - h[t - 1].cost));
        }
    }
    let mut remaining = remaining_budget - base_cost;
    if remaining < 0.0 {
        // Suffix can't even afford its min-cost choices — signal prune.
        return f64::MIN;
    }
    incs.sort_by(|a, b| (b.0 / b.1).partial_cmp(&(a.0 / a.1)).unwrap_or(std::cmp::Ordering::Equal));
    let mut bound = base_gain;
    for (dg, dc) in incs {
        if remaining <= 0.0 {
            break;
        }
        if dc <= remaining {
            bound += dg;
            remaining -= dc;
        } else {
            bound += dg * (remaining / dc);
            break;
        }
    }
    bound
}

fn dfs(ctx: &mut Ctx, pos: usize, gain: f64, cost: &mut Vec<f64>, choice: &mut Vec<usize>) {
    ctx.nodes += 1;
    if ctx.nodes > NODE_CAP {
        return;
    }
    if pos == ctx.order.len() {
        if gain > ctx.best_gain + EPS && ctx.p.fits(cost) {
            // Un-permute the choice vector.
            let mut c = vec![0usize; choice.len()];
            for (i, &j) in ctx.order.iter().enumerate() {
                c[j] = choice[i];
            }
            ctx.best = ctx.p.solution_from(c);
            ctx.best_gain = ctx.best.gain;
        }
        return;
    }
    // Feasibility prune (every dimension).
    for d in 0..ctx.p.n_dims() {
        if cost[d] + ctx.suffix_min[d][pos] > ctx.p.budgets[d] + EPS {
            return;
        }
    }
    // Optimality prune: each single-dimension LP relaxation upper-bounds
    // the multi-constraint optimum, so the FIRST one at or below the
    // incumbent already proves the subtree hopeless — stop bounding there.
    for d in 0..ctx.p.n_dims() {
        let bound = gain + suffix_lp_bound(ctx, d, pos, ctx.p.budgets[d] - cost[d]);
        if bound <= ctx.best_gain + EPS {
            return;
        }
    }
    let j = ctx.order[pos];
    // Visit choices in descending gain (find good incumbents early).
    let mut idxs: Vec<usize> = (0..ctx.p.gains[j].len()).collect();
    idxs.sort_by(|&a, &b| ctx.p.gains[j][b].partial_cmp(&ctx.p.gains[j][a]).unwrap());
    'choices: for i in idxs {
        for d in 0..ctx.p.n_dims() {
            if cost[d] + ctx.p.costs[d].table[j][i] > ctx.p.budgets[d] + EPS {
                continue 'choices;
            }
        }
        for (d, c) in cost.iter_mut().enumerate() {
            *c += ctx.p.costs[d].table[j][i];
        }
        choice[pos] = i;
        dfs(ctx, pos + 1, gain + ctx.p.gains[j][i], cost, choice);
        for (d, c) in cost.iter_mut().enumerate() {
            *c -= ctx.p.costs[d].table[j][i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::problem::gen::{random, random_multi};
    use crate::solver::CostDim;
    use crate::util::Rng;

    #[test]
    fn matches_brute_force_on_random_instances() {
        let mut rng = Rng::new(1234);
        for trial in 0..300 {
            let p = random(&mut rng, 5, 5);
            let exact = p.brute_force();
            let bb = solve(&p);
            assert_eq!(bb.feasible, exact.feasible, "trial {trial}");
            if exact.feasible {
                assert!(
                    (bb.gain - exact.gain).abs() < 1e-9,
                    "trial {trial}: bb {} vs brute {}",
                    bb.gain,
                    exact.gain
                );
                assert!(bb.cost <= p.budget() + 1e-9);
            }
        }
    }

    #[test]
    fn matches_brute_force_on_multi_constraint_instances() {
        let mut rng = Rng::new(7777);
        for trial in 0..300 {
            let dims = 2 + (trial % 2) as usize;
            let p = random_multi(&mut rng, 4, 4, dims);
            let exact = p.brute_force();
            let bb = solve(&p);
            assert_eq!(bb.feasible, exact.feasible, "trial {trial}");
            if exact.feasible {
                assert!(
                    (bb.gain - exact.gain).abs() < 1e-9,
                    "trial {trial}: bb {} vs brute {}",
                    bb.gain,
                    exact.gain
                );
                assert!(p.fits(&bb.costs), "trial {trial}");
            }
        }
    }

    #[test]
    fn respects_budget_always() {
        let mut rng = Rng::new(99);
        for _ in 0..100 {
            let p = random(&mut rng, 8, 6);
            let s = solve(&p);
            if s.feasible {
                assert!(s.cost <= p.budget() + 1e-9);
            }
            assert_eq!(s.choice.len(), p.n_groups());
            for (j, &c) in s.choice.iter().enumerate() {
                assert!(c < p.gains[j].len());
            }
        }
    }

    #[test]
    fn attention_scale_instance_fast() {
        // Paper-scale: 10 groups of 32 configs (2^5 attention groups).
        let mut rng = Rng::new(5);
        let mut gains = Vec::new();
        let mut costs = Vec::new();
        for _ in 0..10 {
            gains.push((0..32).map(|_| rng.f64() * 10.0).collect::<Vec<_>>());
            costs.push((0..32).map(|_| rng.f64()).collect::<Vec<_>>());
        }
        let p = Mckp::new(gains, costs, 5.0).unwrap();
        let t0 = std::time::Instant::now();
        let s = solve(&p);
        assert!(s.feasible);
        assert!(t0.elapsed().as_millis() < 2000);
    }

    #[test]
    fn two_dim_attention_scale_instance_fast() {
        let mut rng = Rng::new(6);
        let mut gains = Vec::new();
        let mut mse = Vec::new();
        let mut bytes = Vec::new();
        for _ in 0..10 {
            gains.push((0..32).map(|_| rng.f64() * 10.0).collect::<Vec<_>>());
            mse.push((0..32).map(|_| rng.f64()).collect::<Vec<_>>());
            bytes.push((0..32).map(|_| rng.f64() * 2.0).collect::<Vec<_>>());
        }
        let p = Mckp::multi(
            gains,
            vec![CostDim::new("mse", mse), CostDim::new("bytes", bytes)],
            vec![5.0, 12.0],
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let s = solve(&p);
        assert!(s.feasible);
        assert!(p.fits(&s.costs));
        assert!(t0.elapsed().as_millis() < 4000);
    }

    #[test]
    fn finds_feasible_when_greedy_start_violates_secondary_budget() {
        // Min-primary-cost start (choice 0 everywhere) violates the bytes
        // cap; the only feasible assignment flips both groups to choice 1.
        let p = Mckp::multi(
            vec![vec![0.0, 4.0], vec![0.0, 3.0]],
            vec![
                CostDim::new("mse", vec![vec![0.0, 1.0], vec![0.0, 1.0]]),
                CostDim::new("bytes", vec![vec![4.0, 1.0], vec![4.0, 1.0]]),
            ],
            vec![10.0, 3.0],
        )
        .unwrap();
        let s = solve(&p);
        assert!(s.feasible);
        assert_eq!(s.choice, vec![1, 1]);
        assert_eq!(s.gain, 7.0);
    }

    #[test]
    fn infeasible_budget() {
        let p = Mckp::new(vec![vec![5.0]], vec![vec![3.0]], 1.0).unwrap();
        let s = solve(&p);
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]);
    }

    #[test]
    fn jointly_infeasible_multi_returns_fallback() {
        let p = Mckp::multi(
            vec![vec![1.0, 5.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 3.0]]),
                CostDim::new("b", vec![vec![3.0, 0.0]]),
            ],
            vec![1.0, 1.0],
        )
        .unwrap();
        let s = solve(&p);
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]);
        assert_eq!(s, p.brute_force());
    }

    #[test]
    fn zero_budget_picks_zero_cost() {
        let p = Mckp::new(
            vec![vec![0.0, 9.0], vec![0.0, 9.0]],
            vec![vec![0.0, 1.0], vec![0.0, 1.0]],
            0.0,
        )
        .unwrap();
        let s = solve(&p);
        assert!(s.feasible);
        assert_eq!(s.choice, vec![0, 0]);
    }
}
