//! Parametric one-pass frontier solver: a dominance-pruned dynamic program
//! over the sequential sub-graph chain.
//!
//! The paper's whole premise (eq. 5) is that both the objective gain and
//! every constraint cost are ADDITIVE over the chain of sequential
//! sub-graphs.  That structure means the set of Pareto-optimal
//! `(gain, cost-vector)` prefixes after group j is everything a later group
//! can ever need: a prefix that another prefix matches or beats in gain AND
//! every cost dimension cannot be completed into a strictly better full
//! assignment than its dominator completed the same way.  [`frontier_with`]
//! propagates those states left to right — merge each group's choices into
//! every surviving state, prune dominated states — and reads the ENTIRE
//! gain-vs-primary-cost Pareto curve off the final state set.  A K-knot
//! frontier therefore costs one DP sweep instead of K branch & bound
//! solves.
//!
//! * **Single-constraint** instances: the sweep is EXACT — every knot's
//!   gain equals a pointwise [`branch_bound`] solve at that knot's budget
//!   (property-tested against the oracle in `tests/parametric.rs`).
//! * **Multi-constraint** instances: dominance runs over the full
//!   `(gain, every-cost)` vector, so the sweep stays exact until the state
//!   cap bites; past the cap states are thinned deterministically and every
//!   resulting point is flagged `exact = false`.  [`harden_with`] re-solves
//!   flagged knots with branch & bound for callers that consume incomplete
//!   curves directly — the planning layer instead abandons incomplete
//!   curves for its per-tau bisection oracle, since thinning can also DROP
//!   knots that no per-knot re-solve can restore.
//!
//! Dominance uses exact float comparisons; the shared [`EPS`] tolerance
//! enters exactly where the pointwise solvers use it — budget feasibility
//! (`cost <= budget + EPS`) — so tie-breaking is consistent end to end.
//!
//! ## Determinism
//!
//! State expansion fans out over an [`ExecPool`] in fixed-size chunks whose
//! boundaries are a pure function of the surviving state count — never of
//! the thread count — and chunk results are concatenated in chunk order.
//! Pruning then sorts by a TOTAL order (`f64::total_cmp` on the cost/gain
//! coordinates, then the `(parent, choice)` key), so the curve is
//! bit-identical at any `--threads` setting: the exec layer's contract.

use super::branch_bound;
use super::problem::Mckp;
use super::EPS;
use crate::exec::ExecPool;

/// Kept-state cap per merge on single-constraint instances.  The 2-d
/// Pareto set of partial sums stays far below this on paper-scale chains;
/// the cap only bounds adversarial inputs.
const MAX_STATES_SINGLE: usize = 32_768;
/// Kept-state cap per merge on multi-constraint instances, where the
/// dominance filter is O(candidates x kept) — this is the "dominance
/// bound" that makes multi-constraint curves near-exact instead of
/// worst-case exponential.
const MAX_STATES_MULTI: usize = 2_048;
/// States per fan-out chunk of the merge (pure in the state count).
/// Shared with the distributed coordinator, whose remote task boundaries
/// must match the in-process chunking exactly.
pub(crate) const EXPAND_CHUNK: usize = 512;

/// One DP state: a choice prefix's accumulated (gain, costs), linked to
/// its parent state so full choice vectors are reconstructed only for the
/// states that survive to the end.
///
/// `pub(crate)` (fields included) so the distributed coordinator
/// (`crate::dist`) can ship state chunks to worker processes and run the
/// SAME expansion/prune code on both sides of the wire.
#[derive(Clone, Debug)]
pub(crate) struct Node {
    pub(crate) gain: f64,
    /// Per-dimension accumulated cost, summed in group order — bit-equal
    /// to [`Mckp::evaluate`] of the reconstructed choice.
    pub(crate) costs: Vec<f64>,
    /// Index into the previous level's kept states (u32::MAX at the root).
    pub(crate) parent: u32,
    pub(crate) choice: u32,
}

/// One knot of the parametric curve: a full assignment Pareto-optimal in
/// (gain, primary cost) among all assignments fitting every secondary
/// budget.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamPoint {
    pub choice: Vec<usize>,
    pub gain: f64,
    /// Per-dimension cost; summation order matches [`Mckp::evaluate`]
    /// bit-for-bit (`costs[0]` is the primary / loss-MSE dimension).
    pub costs: Vec<f64>,
    /// False when the state cap thinned the sweep this point came from:
    /// the knot is then a dominance-bounded lower estimate, not a proven
    /// optimum — see [`harden_with`].
    pub exact: bool,
}

impl ParamPoint {
    /// Primary-dimension cost of this knot.
    pub fn cost(&self) -> f64 {
        self.costs[0]
    }
}

/// The full gain-vs-primary-cost Pareto curve of one [`Mckp`] instance.
///
/// Empty iff NO assignment satisfies every budget (the pointwise solvers'
/// `feasible = false` case); otherwise `points[0]` is the min-primary-cost
/// assignment — exactly what an infeasible pointwise solve falls back to.
#[derive(Clone, Debug, PartialEq)]
pub struct ParametricCurve {
    /// Strictly increasing in BOTH primary cost and gain.
    pub points: Vec<ParamPoint>,
    /// True when the sweep was exhaustive: no thinning anywhere, so the
    /// knot SET is complete and every knot is a proven optimum.  False
    /// after thinning — even once [`harden_with`] proves the surviving
    /// knots optimal, knots dropped between them stay missing.
    pub exact: bool,
}

impl ParametricCurve {
    /// Highest-gain knot whose primary cost fits `budget` (shared EPS
    /// slack) — the pointwise optimum at that budget when the curve is
    /// exact.  None when even the cheapest assignment exceeds `budget`.
    pub fn at_budget(&self, budget: f64) -> Option<&ParamPoint> {
        let k = self.points.partition_point(|p| p.costs[0] <= budget + EPS);
        if k == 0 {
            None
        } else {
            Some(&self.points[k - 1])
        }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// [`frontier_with`] on the sequential pool.
pub fn frontier(p: &Mckp) -> ParametricCurve {
    frontier_with(p, &ExecPool::sequential())
}

/// One-pass parametric sweep of the whole gain-vs-primary-cost Pareto
/// curve, fanning the per-group state merge out over `pool`.  Output is
/// bit-identical at any thread count.
pub fn frontier_with(p: &Mckp, pool: &ExecPool) -> ParametricCurve {
    let n = p.n_groups();
    let mut root_sp = crate::obs::span("solver.frontier");
    root_sp.counter("groups", n as f64);
    let suffix_min = suffix_mins(p);
    let mut levels: Vec<Vec<Node>> = Vec::with_capacity(n + 1);
    levels.push(root_level(p.n_dims()));
    let mut truncated = false;
    for j in 0..n {
        let mut sp = crate::obs::span("solver.dp.group");
        sp.counter("group", j as f64);
        let prev = &levels[j];
        // State-merge fan-out: fixed-size chunks of the surviving states
        // expand in parallel; concatenation is in chunk order, so the
        // candidate list is identical at any thread count.
        let cands: Vec<Node> = pool
            .par_chunks(prev, EXPAND_CHUNK, |start, chunk| {
                expand_chunk(p, &suffix_min, j, start, chunk)
            })
            .into_iter()
            .flatten()
            .collect();
        let n_cands = cands.len();
        sp.counter("candidates", n_cands as f64);
        let (kept, thinned) = prune_level(p, cands);
        sp.counter("kept", kept.len() as f64);
        sp.counter("pruned", (n_cands - kept.len()) as f64);
        sp.counter("thinned", if thinned { 1.0 } else { 0.0 });
        truncated |= thinned;
        levels.push(kept);
    }
    let curve = finish(n, &levels, truncated);
    root_sp.counter("knots", curve.points.len() as f64);
    root_sp.counter("exact", if curve.exact { 1.0 } else { 0.0 });
    curve
}

/// `suffix_min[d][j]` = min dim-d cost over groups j.. — a state whose
/// cost plus this lower bound already exceeds a budget can never be
/// completed feasibly and is pruned at expansion.
pub(crate) fn suffix_mins(p: &Mckp) -> Vec<Vec<f64>> {
    let n = p.n_groups();
    let mut suffix_min = vec![vec![0.0f64; n + 1]; p.n_dims()];
    for (d, sm) in suffix_min.iter_mut().enumerate() {
        for j in (0..n).rev() {
            let mc = p.costs[d].table[j].iter().cloned().fold(f64::MAX, f64::min);
            sm[j] = sm[j + 1] + mc;
        }
    }
    suffix_min
}

/// The DP's root: one empty prefix.
pub(crate) fn root_level(dims: usize) -> Vec<Node> {
    vec![Node { gain: 0.0, costs: vec![0.0; dims], parent: u32::MAX, choice: 0 }]
}

/// Expand one fixed-size chunk of level-`j` states with every group-`j`
/// choice, budget-pruned through the suffix lower bounds.  This is the
/// unit of remote work in the distributed path: coordinator and worker
/// both call THIS function, so sharding cannot change a single bit.
pub(crate) fn expand_chunk(
    p: &Mckp,
    suffix_min: &[Vec<f64>],
    j: usize,
    start: usize,
    chunk: &[Node],
) -> Vec<Node> {
    let dims = p.n_dims();
    let k = p.gains[j].len();
    let mut out: Vec<Node> = Vec::with_capacity(chunk.len() * k);
    for (off, s) in chunk.iter().enumerate() {
        let parent = (start + off) as u32;
        'choices: for i in 0..k {
            let mut costs = s.costs.clone();
            for d in 0..dims {
                let c = costs[d] + p.costs[d].table[j][i];
                if c + suffix_min[d][j + 1] > p.budgets[d] + EPS {
                    continue 'choices;
                }
                costs[d] = c;
            }
            out.push(Node { gain: s.gain + p.gains[j][i], costs, parent, choice: i as u32 });
        }
    }
    out
}

/// Sort + Pareto-prune + (past the cap) thin one level's candidates.
/// Returns the kept antichain and whether thinning bit.  Pure in the
/// candidate list, so any sharding that reproduces the candidate order
/// reproduces the level exactly.
pub(crate) fn prune_level(p: &Mckp, mut cands: Vec<Node>) -> (Vec<Node>, bool) {
    let dims = p.n_dims();
    let cap = if dims == 1 { MAX_STATES_SINGLE } else { MAX_STATES_MULTI };
    // Total-order sort: primary cost asc, gain desc, secondary costs
    // asc, then the (parent, choice) key — deterministic down to exact
    // ties, NaN-total by construction (`total_cmp`).
    cands.sort_by(|a, b| {
        a.costs[0]
            .total_cmp(&b.costs[0])
            .then(b.gain.total_cmp(&a.gain))
            .then_with(|| {
                for d in 1..dims {
                    let o = a.costs[d].total_cmp(&b.costs[d]);
                    if o != std::cmp::Ordering::Equal {
                        return o;
                    }
                }
                (a.parent, a.choice).cmp(&(b.parent, b.choice))
            })
    });

    let mut kept: Vec<Node> = Vec::new();
    if dims == 1 {
        // 2-d Pareto sweep: in cost order, keep strictly rising gain.
        let mut best_gain = f64::NEG_INFINITY;
        for c in cands {
            if c.gain > best_gain {
                best_gain = c.gain;
                kept.push(c);
            }
        }
    } else {
        // n-d dominance: a candidate survives unless an already-kept
        // state matches or beats it in gain AND every cost.  (The sort
        // order guarantees no later candidate can dominate an earlier
        // kept one, so `kept` stays an antichain.)
        for c in cands {
            let dominated = kept
                .iter()
                .any(|a| a.gain >= c.gain && (0..dims).all(|d| a.costs[d] <= c.costs[d]));
            if !dominated {
                kept.push(c);
            }
        }
    }
    if kept.len() > cap {
        (thin(kept, cap), true)
    } else {
        (kept, false)
    }
}

/// Reconstruct every surviving state's full choice vector through the
/// parent links, then project onto the primary-cost curve.
pub(crate) fn finish(n: usize, levels: &[Vec<Node>], truncated: bool) -> ParametricCurve {
    let mut points: Vec<ParamPoint> = Vec::with_capacity(levels[n].len());
    for node in &levels[n] {
        let mut choice = vec![0usize; n];
        let mut level = n;
        let mut parent = node.parent;
        let mut ch = node.choice;
        while level > 0 {
            choice[level - 1] = ch as usize;
            level -= 1;
            if level > 0 {
                let pn = &levels[level][parent as usize];
                ch = pn.choice;
                parent = pn.parent;
            }
        }
        points.push(ParamPoint {
            choice,
            gain: node.gain,
            costs: node.costs.clone(),
            exact: !truncated,
        });
    }
    ParametricCurve { points: project(points), exact: !truncated }
}

/// Project points onto the strictly-increasing (primary cost, gain) curve
/// (total-order sort; ties resolve to the lexicographically smallest
/// choice, deterministically).
fn project(mut points: Vec<ParamPoint>) -> Vec<ParamPoint> {
    points.sort_by(|a, b| {
        a.costs[0]
            .total_cmp(&b.costs[0])
            .then(b.gain.total_cmp(&a.gain))
            .then_with(|| a.choice.cmp(&b.choice))
    });
    let mut curve: Vec<ParamPoint> = Vec::new();
    for pt in points {
        if curve.last().map_or(true, |l| pt.gain > l.gain) {
            curve.push(pt);
        }
    }
    curve
}

/// Deterministic thinning past the state cap: an even-by-index subset of
/// the cost-ordered survivors, always including both endpoints.  Purely a
/// function of the survivor list — thinned sweeps stay bit-identical
/// across thread counts — but optimality may be lost, hence the
/// `exact = false` flags downstream.
fn thin(kept: Vec<Node>, cap: usize) -> Vec<Node> {
    debug_assert!(cap >= 2 && kept.len() > cap);
    let len = kept.len();
    let mut out: Vec<Node> = Vec::with_capacity(cap);
    let mut last = usize::MAX;
    for i in 0..cap {
        let idx = i * (len - 1) / (cap - 1);
        if idx != last {
            out.push(kept[idx].clone());
            last = idx;
        }
    }
    out
}

/// Branch & bound fallback for flagged knots: re-solve each non-exact
/// point at its own primary-cost budget (secondary budgets unchanged),
/// replace it with the proven optimum, and re-project the curve.  One
/// exact IP solve per flagged knot — the pre-parametric per-tau price,
/// paid only where the dominance cap actually bit.  (Each task clones the
/// instance to override its budget; the clone is strictly cheaper than
/// the branch & bound solve that follows it.)
///
/// Hardening proves every SURVIVING knot optimal (their `exact` flags flip
/// true), but it cannot resurrect knots the thinning dropped between them
/// — so the curve-level `exact` stays FALSE: the knot set may be
/// incomplete, and `at_budget` between survivors may under-report.
/// Callers needing the full contract must fall back to per-budget solves
/// (see `Planner::frontier`).
pub fn harden_with(p: &Mckp, curve: ParametricCurve, pool: &ExecPool) -> ParametricCurve {
    if curve.exact {
        return curve;
    }
    let flagged: Vec<usize> = curve
        .points
        .iter()
        .enumerate()
        .filter(|(_, pt)| !pt.exact)
        .map(|(i, _)| i)
        .collect();
    let mut sp = crate::obs::span("solver.harden");
    sp.counter("flagged", flagged.len() as f64);
    let solved = pool.par_map(flagged.len(), |fi| {
        let mut q = p.clone();
        q.budgets[0] = curve.points[flagged[fi]].costs[0];
        branch_bound::solve(&q)
    });
    sp.counter("proved", solved.iter().filter(|s| s.feasible).count() as f64);
    drop(sp);
    let mut points = curve.points;
    for (fi, &i) in flagged.iter().enumerate() {
        let s = &solved[fi];
        if s.feasible {
            points[i] = ParamPoint {
                choice: s.choice.clone(),
                gain: s.gain,
                costs: s.costs.clone(),
                exact: true,
            };
        }
    }
    ParametricCurve { points: project(points), exact: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCfg;
    use crate::solver::problem::gen::{random, random_multi};
    use crate::solver::CostDim;
    use crate::util::Rng;

    /// Brute-force oracle: max gain among assignments with primary cost
    /// <= budget and every secondary cost within its budget.
    fn oracle_gain(p: &Mckp, primary_budget: f64) -> Option<f64> {
        let mut q = p.clone();
        q.budgets[0] = primary_budget;
        let s = q.brute_force();
        if s.feasible {
            Some(s.gain)
        } else {
            None
        }
    }

    #[test]
    fn curve_is_strictly_increasing_and_exact_on_random_instances() {
        let mut rng = Rng::new(0xC0FFEE);
        for trial in 0..80 {
            let p = random(&mut rng, 5, 5);
            let c = frontier(&p);
            assert!(c.exact, "trial {trial}");
            for w in c.points.windows(2) {
                assert!(w[1].costs[0] > w[0].costs[0], "trial {trial}: cost not increasing");
                assert!(w[1].gain > w[0].gain, "trial {trial}: gain not increasing");
            }
            // Every knot is the pointwise optimum at its own budget.
            for pt in &c.points {
                let (g, costs) = p.evaluate(&pt.choice);
                assert_eq!(g.to_bits(), pt.gain.to_bits(), "trial {trial}");
                assert_eq!(costs[0].to_bits(), pt.costs[0].to_bits(), "trial {trial}");
                let o = oracle_gain(&p, pt.costs[0]).expect("knot must be feasible");
                assert!(
                    (o - pt.gain).abs() < 1e-9,
                    "trial {trial}: knot gain {} vs oracle {o}",
                    pt.gain
                );
            }
        }
    }

    #[test]
    fn multi_constraint_curve_respects_every_budget() {
        let mut rng = Rng::new(0xBEEF5);
        for trial in 0..150 {
            let p = random_multi(&mut rng, 4, 4, 2);
            let c = frontier(&p);
            let exact = p.brute_force();
            if c.points.is_empty() {
                assert!(!exact.feasible, "trial {trial}: empty curve but feasible instance");
                continue;
            }
            assert!(exact.feasible, "trial {trial}");
            for pt in &c.points {
                let (_, costs) = p.evaluate(&pt.choice);
                for (d, (&cd, &b)) in costs.iter().zip(&p.budgets).enumerate() {
                    assert!(cd <= b + EPS, "trial {trial}: dim {d} cost {cd} > budget {b}");
                }
                let o = oracle_gain(&p, pt.costs[0]).expect("knot feasible");
                assert!((o - pt.gain).abs() < 1e-9, "trial {trial}");
            }
            // Top knot is the full-budget optimum.
            let top = c.points.last().unwrap();
            assert!((top.gain - exact.gain).abs() < 1e-9, "trial {trial}");
        }
    }

    #[test]
    fn between_knots_the_lower_knot_rules() {
        let p = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            10.0,
        )
        .unwrap();
        let c = frontier(&p);
        // Knots: (0, 0), (2, 8), (3, 10), (5, 18).
        assert_eq!(c.points.len(), 4);
        assert_eq!(c.at_budget(1.9).unwrap().gain, 0.0);
        assert_eq!(c.at_budget(2.0).unwrap().gain, 8.0);
        assert_eq!(c.at_budget(2.9).unwrap().gain, 8.0);
        assert_eq!(c.at_budget(3.0).unwrap().gain, 10.0);
        assert_eq!(c.at_budget(4.9).unwrap().gain, 10.0);
        assert_eq!(c.at_budget(5.0).unwrap().gain, 18.0);
        assert!(c.at_budget(-1.0).is_none());
    }

    #[test]
    fn secondary_budget_filters_the_curve() {
        // Dim 1 forbids group 0's upgrade entirely.
        let p = Mckp::multi(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![
                CostDim::new("mse", vec![vec![0.0, 1.0], vec![0.0, 2.0]]),
                CostDim::new("bytes", vec![vec![0.0, 9.0], vec![0.0, 1.0]]),
            ],
            vec![10.0, 2.0],
        )
        .unwrap();
        let c = frontier(&p);
        assert_eq!(c.points.len(), 2);
        assert_eq!(c.points[0].gain, 0.0);
        assert_eq!(c.points[1].gain, 8.0);
        assert_eq!(c.points[1].choice, vec![0, 1]);
    }

    #[test]
    fn infeasible_secondary_budgets_yield_an_empty_curve() {
        let p = Mckp::multi(
            vec![vec![1.0, 5.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 3.0]]),
                CostDim::new("b", vec![vec![3.0, 0.0]]),
            ],
            vec![1.0, 1.0],
        )
        .unwrap();
        assert!(frontier(&p).is_empty());
    }

    #[test]
    fn zero_groups_is_a_single_zero_point() {
        let p = Mckp::new(vec![], vec![], 1.0).unwrap();
        let c = frontier(&p);
        assert_eq!(c.points.len(), 1);
        assert_eq!(c.points[0].gain, 0.0);
        assert_eq!(c.points[0].choice, Vec::<usize>::new());
        assert!(c.exact);
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut rng = Rng::new(0xD15C0);
        let pools = [
            ExecPool::sequential(),
            ExecPool::new(ExecCfg::new(2)),
            ExecPool::new(ExecCfg::new(8)),
        ];
        for trial in 0..40 {
            let dims = 1 + (trial % 3 == 0) as usize;
            let p = random_multi(&mut rng, 8, 6, dims);
            let base = frontier_with(&p, &pools[0]);
            for pool in &pools[1..] {
                assert_eq!(base, frontier_with(&p, pool), "trial {trial}");
            }
        }
    }

    #[test]
    fn harden_proves_flagged_knots_but_not_completeness() {
        // Fabricate a thinned curve with one wrong, non-exact knot and
        // check harden_with replaces it with the B&B optimum at that
        // knot's budget — while the curve-level flag stays false (knots
        // dropped by thinning cannot be resurrected).
        let p = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            10.0,
        )
        .unwrap();
        let bad = ParametricCurve {
            points: vec![ParamPoint {
                choice: vec![0, 0],
                gain: 0.0,
                costs: vec![2.0],
                exact: false,
            }],
            exact: false,
        };
        let fixed = harden_with(&p, bad, &ExecPool::sequential());
        assert_eq!(fixed.points.len(), 1);
        // At budget 2.0 the optimum IS choice [0, 1] / gain 8.
        assert_eq!(fixed.points[0].gain, 8.0);
        assert_eq!(fixed.points[0].choice, vec![0, 1]);
        assert!(fixed.points[0].exact, "hardened knot is proven optimal");
        assert!(!fixed.exact, "the knot SET may still be incomplete");
    }
}
