//! Parametric one-pass frontier solver: a dominance-pruned dynamic program
//! over the sequential sub-graph chain.
//!
//! The paper's whole premise (eq. 5) is that both the objective gain and
//! every constraint cost are ADDITIVE over the chain of sequential
//! sub-graphs.  That structure means the set of Pareto-optimal
//! `(gain, cost-vector)` prefixes after group j is everything a later group
//! can ever need: a prefix that another prefix matches or beats in gain AND
//! every cost dimension cannot be completed into a strictly better full
//! assignment than its dominator completed the same way.  [`frontier_with`]
//! propagates those states left to right — merge each group's choices into
//! every surviving state, prune dominated states — and reads the ENTIRE
//! gain-vs-primary-cost Pareto curve off the final state set.  A K-knot
//! frontier therefore costs one DP sweep instead of K branch & bound
//! solves.
//!
//! * **Single-constraint** instances: the sweep is EXACT — every knot's
//!   gain equals a pointwise [`branch_bound`] solve at that knot's budget
//!   (property-tested against the oracle in `tests/parametric.rs`).
//! * **Multi-constraint** instances: dominance runs over the full
//!   `(gain, every-cost)` vector, so the sweep stays exact until the state
//!   cap bites; past the cap states are thinned deterministically and every
//!   resulting point is flagged `exact = false`.  [`harden_with`] re-solves
//!   flagged knots with branch & bound for callers that consume incomplete
//!   curves directly — the planning layer instead abandons incomplete
//!   curves for its per-tau bisection oracle, since thinning can also DROP
//!   knots that no per-knot re-solve can restore.
//!
//! Dominance uses exact float comparisons; the shared [`EPS`] tolerance
//! enters exactly where the pointwise solvers use it — budget feasibility
//! (`cost <= budget + EPS`) — so tie-breaking is consistent end to end.
//!
//! ## Memory layout
//!
//! DP levels live in [`LevelSoa`]: four flat columns (`gain`, node-major
//! `cost`, `parent`, `choice`) instead of a `Vec` of parent-linked state
//! structs.  A level is four allocations however many states it holds,
//! expansion writes straight into recycled column buffers (a
//! [`Scratch`] free list), and [`FrontierDp`] retains the committed
//! levels as an arena across `Planner::frontier` calls.  Allocation
//! reuse never changes a computed value, so the layout is invisible to
//! the bit-identity contracts.  See DESIGN.md §4h.
//!
//! ## Grid-quantized pruning
//!
//! [`frontier_quantized`] snaps cost vectors onto an epsilon grid before
//! the exact total-order sort and keeps one winner per grid cell.  The
//! exact path ([`frontier_with`]) is untouched when the grid is
//! disabled; when a rejection is not provably harmless (the cell winner
//! does not dominate the loser outright) the curve and its knots drop
//! their `exact` flags, so quantized curves never masquerade as proven
//! optima.
//!
//! ## Incremental re-solve
//!
//! [`FrontierDp`] commits the DP levels of its last solve — solved
//! budget-FREE, with feasibility filtered once at the end — and on the
//! next solve re-merges only from the first group whose gain/cost tables
//! actually changed.  Pure tau-range or memory-cap (budget) changes
//! re-run no merges at all.  [`FrontierDelta`] reports the reuse.
//!
//! ## Determinism
//!
//! State expansion fans out over an [`ExecPool`] in fixed-size chunks whose
//! boundaries are a pure function of the surviving state count — never of
//! the thread count — and chunk results are concatenated in chunk order.
//! Pruning then sorts by a TOTAL order (`f64::total_cmp` on the cost/gain
//! coordinates, then the `(parent, choice)` key), so the curve is
//! bit-identical at any `--threads` setting: the exec layer's contract.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use super::branch_bound;
use super::problem::Mckp;
use super::EPS;
use crate::exec::scratch::Scratch;
use crate::exec::ExecPool;

/// Kept-state cap per merge on single-constraint instances.  The 2-d
/// Pareto set of partial sums stays far below this on paper-scale chains;
/// the cap only bounds adversarial inputs.
const MAX_STATES_SINGLE: usize = 32_768;
/// Kept-state cap per merge on multi-constraint instances, where the
/// dominance filter is O(candidates x kept) — this is the "dominance
/// bound" that makes multi-constraint curves near-exact instead of
/// worst-case exponential.
const MAX_STATES_MULTI: usize = 2_048;
/// States per fan-out chunk of the merge (pure in the state count).
/// Shared with the distributed coordinator, whose remote task boundaries
/// must match the in-process chunking exactly.
pub(crate) const EXPAND_CHUNK: usize = 512;

/// One DP level in structure-of-arrays layout: row `i` is the state
/// `(gain[i], cost[i*dims..(i+1)*dims], parent[i], choice[i])`, with
/// `cost` node-major and `parent` indexing the previous level's rows
/// (`u32::MAX` at the root).  Replaces the per-merge `Vec` of
/// parent-linked `Node` structs: one level is four flat allocations,
/// recycled across merges and — via [`FrontierDp`] — across
/// `Planner::frontier` calls.
///
/// Public so the distributed coordinator can ship level slices to worker
/// processes (`dist::protocol::{level_to_json, level_from_json}`) and
/// run the SAME expansion code on both sides of the wire.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LevelSoa {
    dims: usize,
    gain: Vec<f64>,
    cost: Vec<f64>,
    parent: Vec<u32>,
    choice: Vec<u32>,
}

impl LevelSoa {
    pub fn new(dims: usize) -> LevelSoa {
        LevelSoa { dims, ..LevelSoa::default() }
    }

    /// Number of cost dimensions per state row.
    pub fn dims(&self) -> usize {
        self.dims
    }

    pub fn len(&self) -> usize {
        self.gain.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gain.is_empty()
    }

    /// Drop all rows and re-dimension, KEEPING the four column
    /// allocations — the arena-recycling entry point.
    pub fn reset(&mut self, dims: usize) {
        self.dims = dims;
        self.gain.clear();
        self.cost.clear();
        self.parent.clear();
        self.choice.clear();
    }

    /// Reserve room for `rows` additional states.
    pub fn reserve(&mut self, rows: usize) {
        self.gain.reserve(rows);
        self.cost.reserve(rows * self.dims);
        self.parent.reserve(rows);
        self.choice.reserve(rows);
    }

    pub fn push(&mut self, gain: f64, costs: &[f64], parent: u32, choice: u32) {
        debug_assert_eq!(costs.len(), self.dims);
        self.gain.push(gain);
        self.cost.extend_from_slice(costs);
        self.parent.push(parent);
        self.choice.push(choice);
    }

    pub fn gain(&self, i: usize) -> f64 {
        self.gain[i]
    }

    /// Per-dimension accumulated costs of row `i`, summed in group order
    /// — bit-equal to [`Mckp::evaluate`] of the reconstructed choice.
    pub fn costs(&self, i: usize) -> &[f64] {
        &self.cost[i * self.dims..(i + 1) * self.dims]
    }

    pub fn parent(&self, i: usize) -> u32 {
        self.parent[i]
    }

    pub fn choice(&self, i: usize) -> u32 {
        self.choice[i]
    }

    /// Move every row of `other` onto the end of `self` (splices
    /// expansion fragments back together in chunk order; `other` is left
    /// empty with its capacity intact).
    pub fn append(&mut self, other: &mut LevelSoa) {
        debug_assert_eq!(self.dims, other.dims);
        self.gain.append(&mut other.gain);
        self.cost.append(&mut other.cost);
        self.parent.append(&mut other.parent);
        self.choice.append(&mut other.choice);
    }

    /// Copy row `i` of `src` onto the end of `self`.
    fn push_row(&mut self, src: &LevelSoa, i: usize) {
        self.push(src.gain[i], src.costs(i), src.parent[i], src.choice[i]);
    }

    /// Heap bytes currently reserved by the four columns (arena
    /// accounting for [`DpStats`]).
    pub fn heap_bytes(&self) -> usize {
        (self.gain.capacity() + self.cost.capacity()) * std::mem::size_of::<f64>()
            + (self.parent.capacity() + self.choice.capacity()) * std::mem::size_of::<u32>()
    }
}

/// One knot of the parametric curve: a full assignment Pareto-optimal in
/// (gain, primary cost) among all assignments fitting every secondary
/// budget.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamPoint {
    pub choice: Vec<usize>,
    pub gain: f64,
    /// Per-dimension cost; summation order matches [`Mckp::evaluate`]
    /// bit-for-bit (`costs[0]` is the primary / loss-MSE dimension).
    pub costs: Vec<f64>,
    /// False when the state cap thinned — or the quantization grid
    /// inexactly pruned — the sweep this point came from: the knot is
    /// then a dominance-bounded lower estimate, not a proven optimum —
    /// see [`harden_with`].
    pub exact: bool,
}

impl ParamPoint {
    /// Primary-dimension cost of this knot.
    pub fn cost(&self) -> f64 {
        self.costs[0]
    }
}

/// The full gain-vs-primary-cost Pareto curve of one [`Mckp`] instance.
///
/// Empty iff NO assignment satisfies every budget (the pointwise solvers'
/// `feasible = false` case); otherwise `points[0]` is the min-primary-cost
/// assignment — exactly what an infeasible pointwise solve falls back to.
#[derive(Clone, Debug, PartialEq)]
pub struct ParametricCurve {
    /// Strictly increasing in BOTH primary cost and gain.
    pub points: Vec<ParamPoint>,
    /// True when the sweep was exhaustive: no thinning and no inexact
    /// grid rejection anywhere, so the knot SET is complete and every
    /// knot is a proven optimum.  False after thinning — even once
    /// [`harden_with`] proves the surviving knots optimal, knots dropped
    /// between them stay missing.
    pub exact: bool,
}

impl ParametricCurve {
    /// Highest-gain knot whose primary cost fits `budget` (shared EPS
    /// slack) — the pointwise optimum at that budget when the curve is
    /// exact.  None when even the cheapest assignment exceeds `budget`.
    pub fn at_budget(&self, budget: f64) -> Option<&ParamPoint> {
        let k = self.points.partition_point(|p| p.costs[0] <= budget + EPS);
        if k == 0 {
            None
        } else {
            Some(&self.points[k - 1])
        }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// [`frontier_with`] on the sequential pool.
pub fn frontier(p: &Mckp) -> ParametricCurve {
    frontier_with(p, &ExecPool::sequential())
}

/// One-pass parametric sweep of the whole gain-vs-primary-cost Pareto
/// curve, fanning the per-group state merge out over `pool`.  Output is
/// bit-identical at any thread count.
pub fn frontier_with(p: &Mckp, pool: &ExecPool) -> ParametricCurve {
    sweep(p, pool, None)
}

/// [`frontier_with`] with grid-quantized dominance pruning: cost vectors
/// snap onto a `cell`-sized grid and only the best-gain state per cell
/// reaches the exact sort.  `cell <= 0` disables the grid (the exact
/// path, bit-identical to [`frontier_with`]).  Rejections that the exact
/// dominance sweep would have made anyway keep the curve `exact`; any
/// other rejection clears the `exact` flags — the curve is then a
/// lower-envelope estimate whose knots are still real, feasible
/// assignments (gains never overstate the optimum).
pub fn frontier_quantized(p: &Mckp, pool: &ExecPool, cell: f64) -> ParametricCurve {
    sweep(p, pool, if cell > 0.0 { Some(cell) } else { None })
}

/// The classic bounded sweep: suffix-budget filtering at expansion,
/// optional grid quantization at pruning.
fn sweep(p: &Mckp, pool: &ExecPool, grid: Option<f64>) -> ParametricCurve {
    let n = p.n_groups();
    let mut root_sp = crate::obs::span("solver.frontier");
    root_sp.counter("groups", n as f64);
    let suffix_min = suffix_mins(p);
    let scratch: Scratch<LevelSoa> = Scratch::default();
    let mut levels: Vec<LevelSoa> = Vec::with_capacity(n + 1);
    levels.push(root_level(p.n_dims()));
    let mut truncated = false;
    for j in 0..n {
        let mut sp = crate::obs::span("solver.dp.group");
        sp.counter("group", j as f64);
        // State-merge fan-out: fixed-size chunks of the surviving states
        // expand in parallel; concatenation is in chunk order, so the
        // candidate list is identical at any thread count.
        let cands = expand_level(p, Some(&suffix_min), j, &levels[j], pool, &scratch);
        let n_cands = cands.len();
        sp.counter("candidates", n_cands as f64);
        let (kept, thinned, inexact) = prune_level_with(p, &cands, grid);
        scratch.put(cands);
        sp.counter("kept", kept.len() as f64);
        sp.counter("pruned", (n_cands - kept.len()) as f64);
        sp.counter("thinned", if thinned { 1.0 } else { 0.0 });
        truncated |= thinned || inexact;
        levels.push(kept);
    }
    let curve = finish(n, &levels, truncated, None);
    root_sp.counter("knots", curve.points.len() as f64);
    root_sp.counter("exact", if curve.exact { 1.0 } else { 0.0 });
    curve
}

/// `suffix_min[d][j]` = min dim-d cost over groups j.. — a state whose
/// cost plus this lower bound already exceeds a budget can never be
/// completed feasibly and is pruned at expansion.
pub(crate) fn suffix_mins(p: &Mckp) -> Vec<Vec<f64>> {
    let n = p.n_groups();
    let mut suffix_min = vec![vec![0.0f64; n + 1]; p.n_dims()];
    for (d, sm) in suffix_min.iter_mut().enumerate() {
        for j in (0..n).rev() {
            let mc = p.costs[d].table[j].iter().cloned().fold(f64::MAX, f64::min);
            sm[j] = sm[j + 1] + mc;
        }
    }
    suffix_min
}

/// The DP's root: one empty prefix.
pub(crate) fn root_level(dims: usize) -> LevelSoa {
    let mut root = LevelSoa::new(dims);
    root.push(0.0, &vec![0.0; dims], u32::MAX, 0);
    root
}

/// Expand rows `range` of level-`j` states with every group-`j` choice
/// into `out`, numbering parents `parent_base + row`.  With
/// `suffix_min = Some(..)` candidates are budget-pruned through the
/// suffix lower bounds (the classic bounded sweep); `None` expands
/// budget-free ([`FrontierDp`]'s reusable levels, feasibility-filtered
/// once in [`finish`]).
fn expand_range(
    p: &Mckp,
    suffix_min: Option<&[Vec<f64>]>,
    j: usize,
    parent_base: usize,
    states: &LevelSoa,
    range: std::ops::Range<usize>,
    out: &mut LevelSoa,
) {
    let dims = states.dims;
    debug_assert_eq!(dims, p.n_dims());
    let k = p.gains[j].len();
    for off in range {
        let parent = (parent_base + off) as u32;
        let costs = states.costs(off);
        'choices: for i in 0..k {
            let base = out.cost.len();
            for d in 0..dims {
                let c = costs[d] + p.costs[d].table[j][i];
                if let Some(sm) = suffix_min {
                    if c + sm[d][j + 1] > p.budgets[d] + EPS {
                        out.cost.truncate(base);
                        continue 'choices;
                    }
                }
                out.cost.push(c);
            }
            out.gain.push(states.gain[off] + p.gains[j][i]);
            out.parent.push(parent);
            out.choice.push(i as u32);
        }
    }
}

/// Expand one fixed-size chunk of level-`j` states (rows `0..len`, with
/// absolute parent indices starting at `start`) with every group-`j`
/// choice, budget-pruned through the suffix lower bounds.  This is the
/// unit of remote work in the distributed path: coordinator and worker
/// both call THIS expansion, so sharding cannot change a single bit.
pub(crate) fn expand_chunk(
    p: &Mckp,
    suffix_min: &[Vec<f64>],
    j: usize,
    start: usize,
    states: &LevelSoa,
) -> LevelSoa {
    let mut out = LevelSoa::new(states.dims());
    out.reserve(states.len() * p.gains[j].len());
    expand_range(p, Some(suffix_min), j, start, states, 0..states.len(), &mut out);
    out
}

/// In-process level expansion: fan rows out over `pool` in
/// [`EXPAND_CHUNK`]-sized index ranges, writing into recycled `scratch`
/// buffers, and splice the fragments back in chunk order.
fn expand_level(
    p: &Mckp,
    suffix_min: Option<&[Vec<f64>]>,
    j: usize,
    prev: &LevelSoa,
    pool: &ExecPool,
    scratch: &Scratch<LevelSoa>,
) -> LevelSoa {
    let dims = p.n_dims();
    let k = p.gains[j].len();
    let n_chunks = prev.len().div_ceil(EXPAND_CHUNK);
    let mut frags = pool.par_map(n_chunks, |ci| {
        let lo = ci * EXPAND_CHUNK;
        let hi = (lo + EXPAND_CHUNK).min(prev.len());
        let mut out = scratch.take();
        out.reset(dims);
        out.reserve((hi - lo) * k);
        expand_range(p, suffix_min, j, 0, prev, lo..hi, &mut out);
        out
    });
    if frags.len() == 1 {
        return frags.pop().expect("one fragment");
    }
    let mut cands = scratch.take();
    cands.reset(dims);
    cands.reserve(frags.iter().map(LevelSoa::len).sum());
    for mut f in frags {
        cands.append(&mut f);
        scratch.put(f);
    }
    cands
}

/// Sort + Pareto-prune + (past the cap) thin one level's candidates.
/// Returns the kept antichain and the thinning bit.  Pure in the
/// candidate list, so any sharding that reproduces the candidate order
/// reproduces the level exactly.
pub(crate) fn prune_level(p: &Mckp, cands: &LevelSoa) -> (LevelSoa, bool) {
    let (kept, thinned, _) = prune_level_with(p, cands, None);
    (kept, thinned)
}

/// [`prune_level`] with an optional quantization grid: `Some(cell)` runs
/// the grid pre-pass first.  The third flag is true when some grid
/// rejection was NOT provably harmless (see [`grid_survivors`]).
fn prune_level_with(p: &Mckp, cands: &LevelSoa, grid: Option<f64>) -> (LevelSoa, bool, bool) {
    let dims = p.n_dims();
    let cap = if dims == 1 { MAX_STATES_SINGLE } else { MAX_STATES_MULTI };
    let (mut idx, grid_inexact) = match grid {
        Some(cell) => grid_survivors(cands, cell),
        None => ((0..cands.len() as u32).collect(), false),
    };
    // Total-order sort: primary cost asc, gain desc, secondary costs
    // asc, then the (parent, choice) key — deterministic down to exact
    // ties, NaN-total by construction (`total_cmp`).  Row keys are
    // unique in (parent, choice), so the order is strict and
    // `sort_unstable` cannot introduce nondeterminism.
    idx.sort_unstable_by(|&ia, &ib| {
        let (a, b) = (ia as usize, ib as usize);
        cands.cost[a * dims]
            .total_cmp(&cands.cost[b * dims])
            .then(cands.gain[b].total_cmp(&cands.gain[a]))
            .then_with(|| {
                for d in 1..dims {
                    let o = cands.cost[a * dims + d].total_cmp(&cands.cost[b * dims + d]);
                    if o != std::cmp::Ordering::Equal {
                        return o;
                    }
                }
                (cands.parent[a], cands.choice[a]).cmp(&(cands.parent[b], cands.choice[b]))
            })
    });

    let mut kept = LevelSoa::new(dims);
    if dims == 1 {
        // 2-d Pareto sweep: in cost order, keep strictly rising gain.
        let mut best_gain = f64::NEG_INFINITY;
        for &ia in &idx {
            let i = ia as usize;
            if cands.gain[i] > best_gain {
                best_gain = cands.gain[i];
                kept.push_row(cands, i);
            }
        }
    } else {
        // n-d dominance: a candidate survives unless an already-kept
        // state matches or beats it in gain AND every cost.  (The sort
        // order guarantees no later candidate can dominate an earlier
        // kept one, so `kept` stays an antichain.)
        for &ia in &idx {
            let i = ia as usize;
            let dominated = (0..kept.len()).any(|a| {
                kept.gain[a] >= cands.gain[i]
                    && (0..dims).all(|d| kept.cost[a * dims + d] <= cands.cost[i * dims + d])
            });
            if !dominated {
                kept.push_row(cands, i);
            }
        }
    }
    if kept.len() > cap {
        (thin(&kept, cap), true, grid_inexact)
    } else {
        (kept, false, grid_inexact)
    }
}

/// Grid pre-pass: bucket candidates by their per-dimension cost cell
/// (`floor(cost / cell)`), keep one winner per bucket — max gain, ties to
/// the earliest candidate — and reject the rest before the exact sort.
/// Buckets are looked up by key only (map iteration order is never
/// observed) and survivors keep candidate order, so the pass is
/// deterministic at any thread count.  A rejection is *harmless* when
/// the bucket winner outright dominates the loser — the exact sweep
/// would prune it too, so the output is bit-identical and stays exact.
/// The returned flag is true only when some rejection was not harmless:
/// curve gains may then under-estimate the optimum and `exact` must
/// drop.
fn grid_survivors(cands: &LevelSoa, cell: f64) -> (Vec<u32>, bool) {
    let dims = cands.dims;
    let inv = 1.0 / cell;
    let len = cands.len();
    let mut keys: Vec<i64> = Vec::with_capacity(len * dims);
    for &c in &cands.cost {
        // f64 -> i64 casts saturate, so even overflowed products map
        // deterministically (if coarsely) onto the grid.
        keys.push((c * inv).floor() as i64);
    }
    let mut winner: HashMap<&[i64], u32> = HashMap::with_capacity(len);
    for i in 0..len {
        match winner.entry(&keys[i * dims..(i + 1) * dims]) {
            Entry::Vacant(v) => {
                v.insert(i as u32);
            }
            Entry::Occupied(mut o) => {
                if cands.gain[i] > cands.gain[*o.get() as usize] {
                    o.insert(i as u32);
                }
            }
        }
    }
    let mut idx: Vec<u32> = Vec::with_capacity(winner.len());
    let mut inexact = false;
    for i in 0..len {
        let w = winner[&keys[i * dims..(i + 1) * dims]] as usize;
        if w == i {
            idx.push(i as u32);
        } else if !inexact {
            let dominated = cands.gain[w] >= cands.gain[i]
                && (0..dims).all(|d| cands.cost[w * dims + d] <= cands.cost[i * dims + d]);
            inexact = !dominated;
        }
    }
    (idx, inexact)
}

/// Reconstruct every surviving state's full choice vector through the
/// parent links, then project onto the primary-cost curve.  With
/// `budgets = Some(..)` final states exceeding any budget (shared EPS
/// slack) are skipped first — how [`FrontierDp`] turns its budget-free
/// levels into the bounded curve; the classic sweep passes `None`
/// because its expansion filter already enforced feasibility.
pub(crate) fn finish(
    n: usize,
    levels: &[LevelSoa],
    truncated: bool,
    budgets: Option<&[f64]>,
) -> ParametricCurve {
    let last = &levels[n];
    let mut points: Vec<ParamPoint> = Vec::with_capacity(last.len());
    'states: for s in 0..last.len() {
        if let Some(budgets) = budgets {
            for (d, &b) in budgets.iter().enumerate() {
                if last.cost[s * last.dims + d] > b + EPS {
                    continue 'states;
                }
            }
        }
        let mut choice = vec![0usize; n];
        let mut level = n;
        let mut parent = last.parent[s];
        let mut ch = last.choice[s];
        while level > 0 {
            choice[level - 1] = ch as usize;
            level -= 1;
            if level > 0 {
                let pl = &levels[level];
                ch = pl.choice[parent as usize];
                parent = pl.parent[parent as usize];
            }
        }
        points.push(ParamPoint {
            choice,
            gain: last.gain[s],
            costs: last.costs(s).to_vec(),
            exact: !truncated,
        });
    }
    ParametricCurve { points: project(points), exact: !truncated }
}

/// Project points onto the strictly-increasing (primary cost, gain) curve
/// (total-order sort; ties resolve to the lexicographically smallest
/// choice, deterministically).
fn project(mut points: Vec<ParamPoint>) -> Vec<ParamPoint> {
    points.sort_by(|a, b| {
        a.costs[0]
            .total_cmp(&b.costs[0])
            .then(b.gain.total_cmp(&a.gain))
            .then_with(|| a.choice.cmp(&b.choice))
    });
    let mut curve: Vec<ParamPoint> = Vec::new();
    for pt in points {
        if curve.last().map_or(true, |l| pt.gain > l.gain) {
            curve.push(pt);
        }
    }
    curve
}

/// Deterministic thinning past the state cap: an even-by-index subset of
/// the cost-ordered survivors, always including both endpoints.  Purely a
/// function of the survivor list — thinned sweeps stay bit-identical
/// across thread counts — but optimality may be lost, hence the
/// `exact = false` flags downstream.
fn thin(kept: &LevelSoa, cap: usize) -> LevelSoa {
    debug_assert!(cap >= 2 && kept.len() > cap);
    let len = kept.len();
    let mut out = LevelSoa::new(kept.dims);
    out.reserve(cap);
    let mut last = usize::MAX;
    for i in 0..cap {
        let idx = i * (len - 1) / (cap - 1);
        if idx != last {
            out.push_row(kept, idx);
            last = idx;
        }
    }
    out
}

/// How much committed DP state one [`FrontierDp::solve_delta`] call
/// reused versus re-solved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrontierDelta {
    /// Committed group-merge levels reused as-is (root excluded).
    pub reused_levels: usize,
    /// Group merges actually re-run this call.
    pub solved_groups: usize,
    /// Total states across the reused levels.
    pub reused_states: usize,
    /// True when no committed state was available or shape-compatible
    /// (or the solve bailed to the classic sweep): everything ran from
    /// the root and nothing carried over.
    pub full_solve: bool,
}

/// Arena accounting for one [`FrontierDp`]: the bench harness records
/// these alongside wall time so the memory-layout trajectory is visible
/// in `BENCH_solver.json`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DpStats {
    /// Peak live DP states (retained levels + in-flight candidates)
    /// observed across this arena's lifetime.
    pub peak_live_states: usize,
    /// Heap bytes currently reserved by the committed level columns.
    pub arena_bytes: usize,
}

/// Committed levels of the last [`FrontierDp`] solve.  Solved
/// budget-FREE (no suffix filter) and grid-off, so they stay valid
/// verbatim across pure tau-range / memory-cap changes and are
/// feasibility-filtered per call in [`finish`].
#[derive(Debug)]
struct Committed {
    problem: Mckp,
    levels: Vec<LevelSoa>,
}

/// Incremental parametric frontier solver: retains the DP level arenas
/// of its last solve and, on the next one, re-merges only from the first
/// group whose gain/cost tables changed bitwise.  Budget-only changes
/// (tau range, memory cap) re-run no merges at all.
///
/// Output is bit-identical to [`frontier_with`] on the same instance —
/// the equality is property-tested in `tests/incremental.rs` and argued
/// in DESIGN.md §4h: levels are solved budget-free, the final level is
/// feasibility-filtered by the same `cost <= budget + EPS` rule the
/// bounded sweep applies at its last group, and budget-free pruning
/// never discards a state the bounded sweep keeps.  If a budget-free
/// level ever exceeds the state cap (adversarial shapes the suffix
/// filter would have contained), the solver discards its arena and
/// delegates to the classic sweep, thinned flags and all.
#[derive(Debug, Default)]
pub struct FrontierDp {
    committed: Option<Committed>,
    scratch: Scratch<LevelSoa>,
    stats: DpStats,
}

impl FrontierDp {
    /// [`FrontierDp::solve_delta`] without the reuse report.
    pub fn solve(&mut self, p: &Mckp, pool: &ExecPool) -> ParametricCurve {
        self.solve_delta(p, pool).0
    }

    /// Arena accounting across this solver's lifetime.
    pub fn stats(&self) -> DpStats {
        self.stats
    }

    /// Whether a committed instance (reusable DP levels) is resident.
    pub fn has_commit(&self) -> bool {
        self.committed.is_some()
    }

    /// Solve `p`'s parametric frontier, reusing committed DP levels
    /// wherever `p`'s tables are bit-identical to the last solve's, and
    /// report what was reused.  Bit-identical to a from-scratch
    /// [`frontier_with`] at any thread count.
    pub fn solve_delta(&mut self, p: &Mckp, pool: &ExecPool) -> (ParametricCurve, FrontierDelta) {
        let n = p.n_groups();
        if n == 0 {
            // Degenerate chain: nothing worth committing.
            self.committed = None;
            let full = FrontierDelta { full_solve: true, ..FrontierDelta::default() };
            return (frontier_with(p, pool), full);
        }
        let mut root_sp = crate::obs::span("solver.frontier");
        root_sp.counter("groups", n as f64);

        // Diff-classify against the committed instance.  Budget changes
        // never dirty a level: committed levels are budget-free and the
        // feasibility filter runs once in `finish`.
        let (mut levels, first_dirty, full_solve) = match self.committed.take() {
            Some(c) if c.problem.same_shape(p) => {
                let dirty = c.problem.first_divergent_group(p).unwrap_or(n);
                let mut lv = c.levels;
                lv.truncate(dirty + 1);
                (lv, dirty, false)
            }
            _ => (vec![root_level(p.n_dims())], 0, true),
        };
        let reused_states: usize = levels.iter().skip(1).map(LevelSoa::len).sum();
        root_sp.counter("reused_levels", first_dirty as f64);
        root_sp.counter("solved_levels", (n - first_dirty) as f64);

        let mut thinned_out = false;
        for j in first_dirty..n {
            let mut sp = crate::obs::span("solver.dp.group");
            sp.counter("group", j as f64);
            let cands = expand_level(p, None, j, &levels[j], pool, &self.scratch);
            let n_cands = cands.len();
            sp.counter("candidates", n_cands as f64);
            let (kept, thinned) = prune_level(p, &cands);
            let live = levels.iter().map(LevelSoa::len).sum::<usize>() + n_cands + kept.len();
            self.stats.peak_live_states = self.stats.peak_live_states.max(live);
            self.scratch.put(cands);
            sp.counter("kept", kept.len() as f64);
            sp.counter("pruned", (n_cands - kept.len()) as f64);
            sp.counter("thinned", if thinned { 1.0 } else { 0.0 });
            if thinned {
                thinned_out = true;
                break;
            }
            levels.push(kept);
        }
        if thinned_out {
            // The budget-free antichain blew the state cap — the suffix
            // filter is load-bearing on this instance.  Drop the arena
            // and delegate to the classic bounded sweep so curve bytes
            // (including any thinned flags) match it exactly.
            drop(root_sp);
            self.committed = None;
            let full = FrontierDelta { full_solve: true, ..FrontierDelta::default() };
            return (frontier_with(p, pool), full);
        }
        let curve = finish(n, &levels, false, Some(&p.budgets));
        root_sp.counter("knots", curve.points.len() as f64);
        root_sp.counter("exact", 1.0);
        self.stats.arena_bytes = levels.iter().map(LevelSoa::heap_bytes).sum();
        let delta = FrontierDelta {
            reused_levels: first_dirty,
            solved_groups: n - first_dirty,
            reused_states,
            full_solve,
        };
        self.committed = Some(Committed { problem: p.clone(), levels });
        (curve, delta)
    }
}

/// Branch & bound fallback for flagged knots: re-solve each non-exact
/// point at its own primary-cost budget (secondary budgets unchanged),
/// replace it with the proven optimum, and re-project the curve.  One
/// exact IP solve per flagged knot — the pre-parametric per-tau price,
/// paid only where the dominance cap actually bit.  (Each task clones the
/// instance to override its budget; the clone is strictly cheaper than
/// the branch & bound solve that follows it.)
///
/// Hardening proves every SURVIVING knot optimal (their `exact` flags flip
/// true), but it cannot resurrect knots the thinning dropped between them
/// — so the curve-level `exact` stays FALSE: the knot set may be
/// incomplete, and `at_budget` between survivors may under-report.
/// Callers needing the full contract must fall back to per-budget solves
/// (see `Planner::frontier`).
pub fn harden_with(p: &Mckp, curve: ParametricCurve, pool: &ExecPool) -> ParametricCurve {
    if curve.exact {
        return curve;
    }
    let flagged: Vec<usize> = curve
        .points
        .iter()
        .enumerate()
        .filter(|(_, pt)| !pt.exact)
        .map(|(i, _)| i)
        .collect();
    let mut sp = crate::obs::span("solver.harden");
    sp.counter("flagged", flagged.len() as f64);
    let solved = pool.par_map(flagged.len(), |fi| {
        let mut q = p.clone();
        q.budgets[0] = curve.points[flagged[fi]].costs[0];
        branch_bound::solve(&q)
    });
    sp.counter("proved", solved.iter().filter(|s| s.feasible).count() as f64);
    drop(sp);
    let mut points = curve.points;
    for (fi, &i) in flagged.iter().enumerate() {
        let s = &solved[fi];
        if s.feasible {
            points[i] = ParamPoint {
                choice: s.choice.clone(),
                gain: s.gain,
                costs: s.costs.clone(),
                exact: true,
            };
        }
    }
    ParametricCurve { points: project(points), exact: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCfg;
    use crate::solver::problem::gen::{random, random_multi};
    use crate::solver::CostDim;
    use crate::util::Rng;

    /// Brute-force oracle: max gain among assignments with primary cost
    /// <= budget and every secondary cost within its budget.
    fn oracle_gain(p: &Mckp, primary_budget: f64) -> Option<f64> {
        let mut q = p.clone();
        q.budgets[0] = primary_budget;
        let s = q.brute_force();
        if s.feasible {
            Some(s.gain)
        } else {
            None
        }
    }

    #[test]
    fn curve_is_strictly_increasing_and_exact_on_random_instances() {
        let mut rng = Rng::new(0xC0FFEE);
        for trial in 0..80 {
            let p = random(&mut rng, 5, 5);
            let c = frontier(&p);
            assert!(c.exact, "trial {trial}");
            for w in c.points.windows(2) {
                assert!(w[1].costs[0] > w[0].costs[0], "trial {trial}: cost not increasing");
                assert!(w[1].gain > w[0].gain, "trial {trial}: gain not increasing");
            }
            // Every knot is the pointwise optimum at its own budget.
            for pt in &c.points {
                let (g, costs) = p.evaluate(&pt.choice);
                assert_eq!(g.to_bits(), pt.gain.to_bits(), "trial {trial}");
                assert_eq!(costs[0].to_bits(), pt.costs[0].to_bits(), "trial {trial}");
                let o = oracle_gain(&p, pt.costs[0]).expect("knot must be feasible");
                assert!(
                    (o - pt.gain).abs() < 1e-9,
                    "trial {trial}: knot gain {} vs oracle {o}",
                    pt.gain
                );
            }
        }
    }

    #[test]
    fn multi_constraint_curve_respects_every_budget() {
        let mut rng = Rng::new(0xBEEF5);
        for trial in 0..150 {
            let p = random_multi(&mut rng, 4, 4, 2);
            let c = frontier(&p);
            let exact = p.brute_force();
            if c.points.is_empty() {
                assert!(!exact.feasible, "trial {trial}: empty curve but feasible instance");
                continue;
            }
            assert!(exact.feasible, "trial {trial}");
            for pt in &c.points {
                let (_, costs) = p.evaluate(&pt.choice);
                for (d, (&cd, &b)) in costs.iter().zip(&p.budgets).enumerate() {
                    assert!(cd <= b + EPS, "trial {trial}: dim {d} cost {cd} > budget {b}");
                }
                let o = oracle_gain(&p, pt.costs[0]).expect("knot feasible");
                assert!((o - pt.gain).abs() < 1e-9, "trial {trial}");
            }
            // Top knot is the full-budget optimum.
            let top = c.points.last().unwrap();
            assert!((top.gain - exact.gain).abs() < 1e-9, "trial {trial}");
        }
    }

    #[test]
    fn between_knots_the_lower_knot_rules() {
        let p = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            10.0,
        )
        .unwrap();
        let c = frontier(&p);
        // Knots: (0, 0), (2, 8), (3, 10), (5, 18).
        assert_eq!(c.points.len(), 4);
        assert_eq!(c.at_budget(1.9).unwrap().gain, 0.0);
        assert_eq!(c.at_budget(2.0).unwrap().gain, 8.0);
        assert_eq!(c.at_budget(2.9).unwrap().gain, 8.0);
        assert_eq!(c.at_budget(3.0).unwrap().gain, 10.0);
        assert_eq!(c.at_budget(4.9).unwrap().gain, 10.0);
        assert_eq!(c.at_budget(5.0).unwrap().gain, 18.0);
        assert!(c.at_budget(-1.0).is_none());
    }

    #[test]
    fn secondary_budget_filters_the_curve() {
        // Dim 1 forbids group 0's upgrade entirely.
        let p = Mckp::multi(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![
                CostDim::new("mse", vec![vec![0.0, 1.0], vec![0.0, 2.0]]),
                CostDim::new("bytes", vec![vec![0.0, 9.0], vec![0.0, 1.0]]),
            ],
            vec![10.0, 2.0],
        )
        .unwrap();
        let c = frontier(&p);
        assert_eq!(c.points.len(), 2);
        assert_eq!(c.points[0].gain, 0.0);
        assert_eq!(c.points[1].gain, 8.0);
        assert_eq!(c.points[1].choice, vec![0, 1]);
    }

    #[test]
    fn infeasible_secondary_budgets_yield_an_empty_curve() {
        let p = Mckp::multi(
            vec![vec![1.0, 5.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 3.0]]),
                CostDim::new("b", vec![vec![3.0, 0.0]]),
            ],
            vec![1.0, 1.0],
        )
        .unwrap();
        assert!(frontier(&p).is_empty());
    }

    #[test]
    fn zero_groups_is_a_single_zero_point() {
        let p = Mckp::new(vec![], vec![], 1.0).unwrap();
        let c = frontier(&p);
        assert_eq!(c.points.len(), 1);
        assert_eq!(c.points[0].gain, 0.0);
        assert_eq!(c.points[0].choice, Vec::<usize>::new());
        assert!(c.exact);

        // The incremental solver delegates the degenerate chain too.
        let mut dp = FrontierDp::default();
        let (c2, delta) = dp.solve_delta(&p, &ExecPool::sequential());
        assert_eq!(c2, c);
        assert!(delta.full_solve);
    }

    #[test]
    fn bit_identical_across_thread_counts() {
        let mut rng = Rng::new(0xD15C0);
        let pools = [
            ExecPool::sequential(),
            ExecPool::new(ExecCfg::new(2)),
            ExecPool::new(ExecCfg::new(8)),
        ];
        for trial in 0..40 {
            let dims = 1 + (trial % 3 == 0) as usize;
            let p = random_multi(&mut rng, 8, 6, dims);
            let base = frontier_with(&p, &pools[0]);
            for pool in &pools[1..] {
                assert_eq!(base, frontier_with(&p, pool), "trial {trial}");
            }
        }
    }

    #[test]
    fn arena_solver_matches_the_classic_sweep_and_reuses_levels() {
        let mut rng = Rng::new(0xA2E4A);
        let pool = ExecPool::sequential();
        for trial in 0..40 {
            let dims = 1 + (trial % 3 == 0) as usize;
            let p = random_multi(&mut rng, 6, 5, dims);
            let classic = frontier_with(&p, &pool);
            let mut dp = FrontierDp::default();
            let (cold, d_cold) = dp.solve_delta(&p, &pool);
            assert_eq!(cold, classic, "trial {trial}: cold solve");
            assert!(d_cold.full_solve, "trial {trial}");
            // Identical instance: every level reused, same bytes out.
            let (warm, d_warm) = dp.solve_delta(&p, &pool);
            assert_eq!(warm, classic, "trial {trial}: warm solve");
            assert_eq!(d_warm.solved_groups, 0, "trial {trial}");
            assert_eq!(d_warm.reused_levels, p.n_groups(), "trial {trial}");
            assert!(!d_warm.full_solve, "trial {trial}");
            assert!(dp.stats().arena_bytes > 0, "trial {trial}");
        }
    }

    #[test]
    fn grid_with_harmless_cells_is_bit_identical_and_exact() {
        // Integer-valued tables: with cell = 0.5 distinct cost vectors
        // land in distinct buckets, so every grid rejection is an exact
        // same-cost dominance the plain sweep performs too.
        let mut rng = Rng::new(0x617D);
        let pool = ExecPool::sequential();
        for trial in 0..40 {
            let dims = 1 + (trial % 2);
            let mut p = random_multi(&mut rng, 5, 4, dims);
            for g in p.gains.iter_mut().flatten() {
                *g = (*g * 3.0).round();
            }
            for cd in p.costs.iter_mut() {
                for c in cd.table.iter_mut().flatten() {
                    *c = (*c * 3.0).round();
                }
            }
            let exact = frontier_with(&p, &pool);
            assert_eq!(frontier_quantized(&p, &pool, 0.5), exact, "trial {trial}");
            // cell <= 0 disables the grid outright.
            assert_eq!(frontier_quantized(&p, &pool, 0.0), exact, "trial {trial}");
        }
    }

    #[test]
    fn coarse_grid_flags_inexact_and_never_overstates() {
        let mut rng = Rng::new(0x6AA55);
        let pool = ExecPool::sequential();
        let mut saw_inexact = false;
        for trial in 0..40 {
            let p = random(&mut rng, 5, 5);
            let exact = frontier_with(&p, &pool);
            let q = frontier_quantized(&p, &pool, 2.5);
            if !q.exact {
                saw_inexact = true;
                assert!(q.points.iter().all(|pt| !pt.exact), "trial {trial}");
            }
            for pt in &q.points {
                // Every quantized knot is a real assignment, evaluated
                // bit-faithfully...
                let (g, costs) = p.evaluate(&pt.choice);
                assert_eq!(g.to_bits(), pt.gain.to_bits(), "trial {trial}");
                assert_eq!(costs[0].to_bits(), pt.costs[0].to_bits(), "trial {trial}");
                // ...that never beats the exact curve at its own budget.
                let best = exact.at_budget(pt.costs[0]).expect("exact curve covers the knot");
                assert!(pt.gain <= best.gain + 1e-9, "trial {trial}");
            }
        }
        assert!(saw_inexact, "a coarse grid must reject something across 40 trials");
    }

    #[test]
    fn harden_proves_flagged_knots_but_not_completeness() {
        // Fabricate a thinned curve with one wrong, non-exact knot and
        // check harden_with replaces it with the B&B optimum at that
        // knot's budget — while the curve-level flag stays false (knots
        // dropped by thinning cannot be resurrected).
        let p = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            10.0,
        )
        .unwrap();
        let bad = ParametricCurve {
            points: vec![ParamPoint {
                choice: vec![0, 0],
                gain: 0.0,
                costs: vec![2.0],
                exact: false,
            }],
            exact: false,
        };
        let fixed = harden_with(&p, bad, &ExecPool::sequential());
        assert_eq!(fixed.points.len(), 1);
        // At budget 2.0 the optimum IS choice [0, 1] / gain 8.
        assert_eq!(fixed.points[0].gain, 8.0);
        assert_eq!(fixed.points[0].choice, vec![0, 1]);
        assert!(fixed.points[0].exact, "hardened knot is proven optimal");
        assert!(!fixed.exact, "the knot SET may still be incomplete");
    }
}
