//! LP relaxation of MCKP (Dantzig-style over convex-hull increments).
//!
//! Start every group at its min-cost hull point; greedily apply hull
//! "upgrade increments" in decreasing gain/cost efficiency until the budget
//! is exhausted; the last upgrade may be fractional.  The result upper-bounds
//! the integer optimum and is exact for the LP.

use super::hull::{efficient_frontier, HullPoint};
use super::problem::Mckp;

#[derive(Clone, Debug)]
pub struct LpSolution {
    /// Upper bound on the integer optimum.
    pub bound: f64,
    /// Integral part of the LP solution (hull point index per group).
    pub base_choice: Vec<usize>,
    pub base_gain: f64,
    pub base_cost: f64,
}

struct Increment {
    group: usize,
    to_point: usize, // hull index
    dcost: f64,
    dgain: f64,
}

pub fn hulls(p: &Mckp) -> Vec<Vec<HullPoint>> {
    p.costs
        .iter()
        .zip(&p.gains)
        .map(|(c, g)| efficient_frontier(c, g))
        .collect()
}

/// Solve the LP relaxation; `hulls` from [`hulls`] (precomputable).
pub fn solve_with_hulls(p: &Mckp, hulls: &[Vec<HullPoint>]) -> LpSolution {
    let mut incs: Vec<Increment> = Vec::new();
    for (j, h) in hulls.iter().enumerate() {
        for t in 1..h.len() {
            incs.push(Increment {
                group: j,
                to_point: t,
                dcost: h[t].cost - h[t - 1].cost,
                dgain: h[t].gain - h[t - 1].gain,
            });
        }
    }
    // Decreasing efficiency. Hull increments within a group are already
    // decreasing, so the greedy order applies them consistently (point t
    // before t+1).
    incs.sort_by(|a, b| {
        (b.dgain / b.dcost)
            .partial_cmp(&(a.dgain / a.dcost))
            .unwrap_or(std::cmp::Ordering::Equal)
    });

    let mut level = vec![0usize; hulls.len()];
    let mut gain: f64 = hulls.iter().map(|h| h[0].gain).sum();
    let mut cost: f64 = hulls.iter().map(|h| h[0].cost).sum();
    let mut bound = gain;
    let mut remaining = p.budget - cost;

    for inc in incs {
        // Only apply in-order upgrades (t must be the current level + 1).
        if inc.to_point != level[inc.group] + 1 {
            continue;
        }
        if remaining <= 0.0 {
            break;
        }
        if inc.dcost <= remaining {
            remaining -= inc.dcost;
            level[inc.group] += 1;
            gain += inc.dgain;
            cost += inc.dcost;
            bound = gain;
        } else {
            // Fractional tail: LP takes a fraction of this increment.
            bound = gain + inc.dgain * (remaining / inc.dcost);
            break;
        }
    }

    let base_choice = level.iter().zip(hulls).map(|(&t, h)| h[t].choice).collect();
    LpSolution { bound: bound.max(gain), base_choice, base_gain: gain, base_cost: cost }
}

pub fn solve(p: &Mckp) -> LpSolution {
    solve_with_hulls(p, &hulls(p))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::problem::gen::random;
    use crate::util::Rng;

    #[test]
    fn bound_dominates_brute_force() {
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let p = random(&mut rng, 4, 4);
            let exact = p.brute_force();
            let lp = solve(&p);
            if exact.feasible {
                assert!(
                    lp.bound >= exact.gain - 1e-9,
                    "lp bound {} < exact {}",
                    lp.bound,
                    exact.gain
                );
            }
        }
    }

    #[test]
    fn integral_when_budget_generous() {
        let p = Mckp::new(
            vec![vec![0.0, 5.0], vec![0.0, 7.0]],
            vec![vec![0.0, 1.0], vec![0.0, 2.0]],
            100.0,
        )
        .unwrap();
        let lp = solve(&p);
        assert_eq!(lp.base_choice, vec![1, 1]);
        assert!((lp.bound - 12.0).abs() < 1e-12);
        assert!((lp.base_gain - 12.0).abs() < 1e-12);
    }

    #[test]
    fn fractional_tail() {
        // One group, upgrade costs 2 but only 1 budget: bound = half the gain.
        let p = Mckp::new(vec![vec![0.0, 10.0]], vec![vec![0.0, 2.0]], 1.0).unwrap();
        let lp = solve(&p);
        assert!((lp.bound - 5.0).abs() < 1e-12);
        assert_eq!(lp.base_choice, vec![0]);
    }

    #[test]
    fn base_solution_feasible() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let p = random(&mut rng, 5, 5);
            let lp = solve(&p);
            let (g, c) = p.evaluate(&lp.base_choice);
            let min_cost: f64 = p
                .costs
                .iter()
                .map(|cs| cs.iter().cloned().fold(f64::MAX, f64::min))
                .sum();
            if min_cost <= p.budget {
                assert!(c <= p.budget + 1e-9);
            }
            assert!((g - lp.base_gain).abs() < 1e-9);
        }
    }
}
