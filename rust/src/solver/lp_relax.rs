//! LP relaxation of MCKP (Dantzig-style over convex-hull increments).
//!
//! Single budget: start every group at its min-cost hull point; greedily
//! apply hull "upgrade increments" in decreasing gain/cost efficiency until
//! the budget is exhausted; the last upgrade may be fractional.  The result
//! upper-bounds the integer optimum and is exact for the LP.
//!
//! Multiple budgets go through a surrogate (Lagrangian) relaxation: the D
//! constraints are aggregated with non-negative weights into ONE knapsack
//! constraint.  Any original-feasible assignment satisfies the aggregate,
//! so for ANY weight vector the single-constraint LP bound of the aggregate
//! upper-bounds the multi-constraint integer optimum; a short subgradient
//! loop on the weights tightens the bound.

use super::hull::{efficient_frontier, HullPoint};
use super::problem::Mckp;
use super::EPS;

#[derive(Clone, Debug)]
pub struct LpSolution {
    /// Upper bound on the integer optimum.
    pub bound: f64,
    /// Integral part of the LP solution (hull point index per group).  For
    /// multi-budget instances this comes from the aggregate knapsack and
    /// may violate individual budgets — it is a bound witness, not a plan.
    pub base_choice: Vec<usize>,
    pub base_gain: f64,
    /// Primary-dimension cost of `base_choice`.
    pub base_cost: f64,
}

struct Increment {
    group: usize,
    to_point: usize, // hull index
    dcost: f64,
    dgain: f64,
}

/// Primary-dimension efficient frontiers (dim 0).
pub fn hulls(p: &Mckp) -> Vec<Vec<HullPoint>> {
    hulls_for(p, 0)
}

/// Efficient frontiers of one cost dimension.
pub fn hulls_for(p: &Mckp, d: usize) -> Vec<Vec<HullPoint>> {
    p.costs[d]
        .table
        .iter()
        .zip(&p.gains)
        .map(|(c, g)| efficient_frontier(c, g))
        .collect()
}

/// Solve the PRIMARY-dimension LP relaxation; `hulls` from [`hulls`]
/// (precomputable).  Extra dimensions are ignored — dropping constraints
/// only raises the bound, so the result is still a valid upper bound.
pub fn solve_with_hulls(p: &Mckp, hulls: &[Vec<HullPoint>]) -> LpSolution {
    let mut incs: Vec<Increment> = Vec::new();
    for (j, h) in hulls.iter().enumerate() {
        for t in 1..h.len() {
            incs.push(Increment {
                group: j,
                to_point: t,
                dcost: h[t].cost - h[t - 1].cost,
                dgain: h[t].gain - h[t - 1].gain,
            });
        }
    }
    // Decreasing efficiency. Hull increments within a group are already
    // decreasing, so the greedy order applies them consistently (point t
    // before t+1).  Total order (the shared `solver::efficiency` ranks
    // degenerate dcosts +inf; ties break on the (group, point) key) so
    // degenerate hulls sort deterministically.
    let eff = |i: &Increment| super::efficiency(i.dgain, i.dcost);
    incs.sort_by(|a, b| {
        eff(b)
            .total_cmp(&eff(a))
            .then((a.group, a.to_point).cmp(&(b.group, b.to_point)))
    });

    let mut level = vec![0usize; hulls.len()];
    let mut gain: f64 = hulls.iter().map(|h| h[0].gain).sum();
    let mut cost: f64 = hulls.iter().map(|h| h[0].cost).sum();
    let mut bound = gain;
    let mut remaining = p.budget() - cost;

    for inc in incs {
        // Only apply in-order upgrades (t must be the current level + 1).
        if inc.to_point != level[inc.group] + 1 {
            continue;
        }
        if remaining <= 0.0 {
            break;
        }
        if inc.dcost <= remaining {
            remaining -= inc.dcost;
            level[inc.group] += 1;
            gain += inc.dgain;
            cost += inc.dcost;
            bound = gain;
        } else {
            // Fractional tail: LP takes a fraction of this increment.
            bound = gain + inc.dgain * (remaining / inc.dcost);
            break;
        }
    }

    let base_choice = level.iter().zip(hulls).map(|(&t, h)| h[t].choice).collect();
    LpSolution { bound: bound.max(gain), base_choice, base_gain: gain, base_cost: cost }
}

/// Aggregate the D cost dimensions into one with weights `w >= 0`.
fn aggregate(p: &Mckp, w: &[f64]) -> Mckp {
    let table: Vec<Vec<f64>> = (0..p.n_groups())
        .map(|j| {
            (0..p.gains[j].len())
                .map(|i| (0..p.n_dims()).map(|d| w[d] * p.costs[d].table[j][i]).sum())
                .collect()
        })
        .collect();
    let budget = w.iter().zip(&p.budgets).map(|(wd, b)| wd * b).sum();
    Mckp::new(p.gains.clone(), table, budget).expect("aggregate of a valid Mckp is valid")
}

/// Surrogate/Lagrangian bound for the multi-budget case (see module docs).
/// Valid for any weights; `iters` subgradient steps tighten it.
pub fn lagrangian(p: &Mckp, iters: usize) -> LpSolution {
    // Scale-normalize: weight each dimension by 1/budget so constraints are
    // comparable; zero budgets get a floor.
    let scale: Vec<f64> = p.budgets.iter().map(|b| b.max(EPS)).collect();
    let mut w: Vec<f64> = scale.iter().map(|s| 1.0 / s).collect();
    let mut best: Option<LpSolution> = None;
    let mut step = 0.5;
    for _ in 0..iters.max(1) {
        let agg = aggregate(p, &w);
        let lp = solve_with_hulls(&agg, &hulls(&agg));
        // Re-evaluate the integral base on the ORIGINAL dimensions.
        let (g, costs) = p.evaluate(&lp.base_choice);
        let candidate = LpSolution {
            bound: lp.bound,
            base_choice: lp.base_choice,
            base_gain: g,
            base_cost: costs[0],
        };
        if best.as_ref().map_or(true, |b| candidate.bound < b.bound) {
            best = Some(candidate);
        }
        // Subgradient on relative violations: raise the weight of every
        // violated dimension; a violation-free base cannot improve further.
        let mut moved = false;
        for d in 0..p.n_dims() {
            let viol = (costs[d] - p.budgets[d]) / scale[d];
            if viol > 0.0 {
                w[d] *= 1.0 + step * viol.min(4.0);
                moved = true;
            }
        }
        if !moved {
            break;
        }
        step *= 0.7;
    }
    best.expect("at least one iteration ran")
}

pub fn solve(p: &Mckp) -> LpSolution {
    if p.is_single() {
        solve_with_hulls(p, &hulls(p))
    } else {
        lagrangian(p, 24)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::problem::gen::{random, random_multi};
    use crate::util::Rng;

    #[test]
    fn bound_dominates_brute_force() {
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let p = random(&mut rng, 4, 4);
            let exact = p.brute_force();
            let lp = solve(&p);
            if exact.feasible {
                assert!(
                    lp.bound >= exact.gain - 1e-9,
                    "lp bound {} < exact {}",
                    lp.bound,
                    exact.gain
                );
            }
        }
    }

    #[test]
    fn lagrangian_bound_dominates_brute_force_multi() {
        let mut rng = Rng::new(4242);
        for trial in 0..200 {
            let p = random_multi(&mut rng, 4, 4, 2);
            let exact = p.brute_force();
            let lp = solve(&p);
            if exact.feasible {
                assert!(
                    lp.bound >= exact.gain - 1e-9,
                    "trial {trial}: lagrangian bound {} < exact {}",
                    lp.bound,
                    exact.gain
                );
            }
        }
    }

    #[test]
    fn integral_when_budget_generous() {
        let p = Mckp::new(
            vec![vec![0.0, 5.0], vec![0.0, 7.0]],
            vec![vec![0.0, 1.0], vec![0.0, 2.0]],
            100.0,
        )
        .unwrap();
        let lp = solve(&p);
        assert_eq!(lp.base_choice, vec![1, 1]);
        assert!((lp.bound - 12.0).abs() < 1e-12);
        assert!((lp.base_gain - 12.0).abs() < 1e-12);
    }

    #[test]
    fn fractional_tail() {
        // One group, upgrade costs 2 but only 1 budget: bound = half the gain.
        let p = Mckp::new(vec![vec![0.0, 10.0]], vec![vec![0.0, 2.0]], 1.0).unwrap();
        let lp = solve(&p);
        assert!((lp.bound - 5.0).abs() < 1e-12);
        assert_eq!(lp.base_choice, vec![0]);
    }

    #[test]
    fn base_solution_feasible() {
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let p = random(&mut rng, 5, 5);
            let lp = solve(&p);
            let (g, c) = p.evaluate(&lp.base_choice);
            let min_cost: f64 = p
                .primary()
                .iter()
                .map(|cs| cs.iter().cloned().fold(f64::MAX, f64::min))
                .sum();
            if min_cost <= p.budget() {
                assert!(c[0] <= p.budget() + 1e-9);
            }
            assert!((g - lp.base_gain).abs() < 1e-9);
        }
    }
}
