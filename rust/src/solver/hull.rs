//! Per-group efficient frontier (convex hull) for MCKP relaxations.
//!
//! For a group's (cost, gain) choices, the LP relaxation only ever mixes
//! points on the upper-left convex hull: dominated points (higher cost, no
//! more gain) and concave points are discarded.  Consecutive hull points
//! define "upgrade increments" with decreasing gain/cost efficiency.

/// One hull point: a surviving choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HullPoint {
    pub choice: usize,
    pub cost: f64,
    pub gain: f64,
}

/// Upper-left convex hull in (cost, gain), sorted by increasing cost.
/// Always contains the min-cost point.
pub fn efficient_frontier(costs: &[f64], gains: &[f64]) -> Vec<HullPoint> {
    let mut pts: Vec<HullPoint> = (0..costs.len())
        .map(|i| HullPoint { choice: i, cost: costs[i], gain: gains[i] })
        .collect();
    // Sort by cost, then by descending gain so the best at equal cost wins
    // (total order: degenerate tables must not panic the comparator).
    pts.sort_by(|a, b| {
        a.cost
            .total_cmp(&b.cost)
            .then(b.gain.total_cmp(&a.gain))
            .then(a.choice.cmp(&b.choice))
    });
    // Drop dominated points (non-increasing gain as cost grows).  Exactly
    // equal costs need no special case: the sort puts the best gain first,
    // so a same-cost successor always fails the gain test.  Near-equal
    // costs with strictly more gain are KEPT — collapsing them (as a
    // tolerance-based dedup once did) would under-report the group's
    // achievable gain and silently break the LP bound branch & bound
    // prunes with.
    let mut frontier: Vec<HullPoint> = Vec::new();
    for p in pts {
        if let Some(last) = frontier.last() {
            if p.gain <= last.gain + 1e-15 {
                continue;
            }
        }
        frontier.push(p);
    }
    // Enforce concavity (upper hull): efficiencies must be decreasing.
    let mut hull: Vec<HullPoint> = Vec::new();
    for p in frontier {
        while hull.len() >= 2 {
            let a = hull[hull.len() - 2];
            let b = hull[hull.len() - 1];
            let e_ab = (b.gain - a.gain) / (b.cost - a.cost);
            let e_bp = (p.gain - b.gain) / (p.cost - b.cost);
            if e_bp >= e_ab - 1e-15 {
                hull.pop(); // b is under the chord a-p
            } else {
                break;
            }
        }
        hull.push(p);
    }
    hull
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point() {
        let h = efficient_frontier(&[2.0], &[5.0]);
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].choice, 0);
    }

    #[test]
    fn drops_dominated() {
        // choice 1 costs more but gains less than choice 0.
        let h = efficient_frontier(&[1.0, 2.0], &[5.0, 4.0]);
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].choice, 0);
    }

    #[test]
    fn keeps_pareto_chain() {
        let h = efficient_frontier(&[0.0, 1.0, 2.0], &[0.0, 10.0, 15.0]);
        assert_eq!(h.iter().map(|p| p.choice).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn removes_concave_point() {
        // Middle point is below the chord from first to last.
        let h = efficient_frontier(&[0.0, 1.0, 2.0], &[0.0, 1.0, 10.0]);
        assert_eq!(h.iter().map(|p| p.choice).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn equal_cost_takes_best_gain() {
        let h = efficient_frontier(&[1.0, 1.0, 2.0], &[3.0, 7.0, 9.0]);
        assert_eq!(h[0].choice, 1);
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn near_equal_costs_keep_the_better_gain() {
        // Two choices a denormal cost step apart: the higher-gain point
        // must survive (a tolerance dedup here once under-reported the
        // group's achievable gain, breaking the LP bound's soundness).
        let h = efficient_frontier(&[0.0, 1e-300, 2e-300], &[0.0, 5.0, 10.0]);
        let best = h.last().unwrap();
        assert_eq!(best.choice, 2);
        assert_eq!(best.gain, 10.0);
        // The min-cost point is still present (greedy's start / LP base).
        assert_eq!(h[0].choice, 0);
    }

    #[test]
    fn efficiencies_decrease() {
        let costs = [0.0, 0.5, 1.1, 1.9, 3.0, 4.5];
        let gains = [0.0, 4.0, 6.5, 8.0, 9.0, 9.5];
        let h = efficient_frontier(&costs, &gains);
        for w in h.windows(3) {
            let e1 = (w[1].gain - w[0].gain) / (w[1].cost - w[0].cost);
            let e2 = (w[2].gain - w[1].gain) / (w[2].cost - w[1].cost);
            assert!(e2 <= e1 + 1e-12);
        }
    }
}
