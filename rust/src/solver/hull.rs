//! Per-group efficient frontier (convex hull) for MCKP relaxations.
//!
//! For a group's (cost, gain) choices, the LP relaxation only ever mixes
//! points on the upper-left convex hull: dominated points (higher cost, no
//! more gain) and concave points are discarded.  Consecutive hull points
//! define "upgrade increments" with decreasing gain/cost efficiency.

/// One hull point: a surviving choice.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HullPoint {
    pub choice: usize,
    pub cost: f64,
    pub gain: f64,
}

/// Upper-left convex hull in (cost, gain), sorted by increasing cost.
/// Always contains the min-cost point.
pub fn efficient_frontier(costs: &[f64], gains: &[f64]) -> Vec<HullPoint> {
    let mut pts: Vec<HullPoint> = (0..costs.len())
        .map(|i| HullPoint { choice: i, cost: costs[i], gain: gains[i] })
        .collect();
    // Sort by cost, then by descending gain so the best at equal cost wins.
    pts.sort_by(|a, b| {
        a.cost
            .partial_cmp(&b.cost)
            .unwrap()
            .then(b.gain.partial_cmp(&a.gain).unwrap())
    });
    // Drop dominated points (non-increasing gain as cost grows).
    let mut frontier: Vec<HullPoint> = Vec::new();
    for p in pts {
        if let Some(last) = frontier.last() {
            if p.gain <= last.gain + 1e-15 {
                continue;
            }
            if (p.cost - last.cost).abs() < 1e-18 {
                continue; // same cost, lower/equal gain already covered
            }
        }
        frontier.push(p);
    }
    // Enforce concavity (upper hull): efficiencies must be decreasing.
    let mut hull: Vec<HullPoint> = Vec::new();
    for p in frontier {
        while hull.len() >= 2 {
            let a = hull[hull.len() - 2];
            let b = hull[hull.len() - 1];
            let e_ab = (b.gain - a.gain) / (b.cost - a.cost);
            let e_bp = (p.gain - b.gain) / (p.cost - b.cost);
            if e_bp >= e_ab - 1e-15 {
                hull.pop(); // b is under the chord a-p
            } else {
                break;
            }
        }
        hull.push(p);
    }
    hull
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_point() {
        let h = efficient_frontier(&[2.0], &[5.0]);
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].choice, 0);
    }

    #[test]
    fn drops_dominated() {
        // choice 1 costs more but gains less than choice 0.
        let h = efficient_frontier(&[1.0, 2.0], &[5.0, 4.0]);
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].choice, 0);
    }

    #[test]
    fn keeps_pareto_chain() {
        let h = efficient_frontier(&[0.0, 1.0, 2.0], &[0.0, 10.0, 15.0]);
        assert_eq!(h.iter().map(|p| p.choice).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn removes_concave_point() {
        // Middle point is below the chord from first to last.
        let h = efficient_frontier(&[0.0, 1.0, 2.0], &[0.0, 1.0, 10.0]);
        assert_eq!(h.iter().map(|p| p.choice).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn equal_cost_takes_best_gain() {
        let h = efficient_frontier(&[1.0, 1.0, 2.0], &[3.0, 7.0, 9.0]);
        assert_eq!(h[0].choice, 1);
        assert_eq!(h.len(), 2);
    }

    #[test]
    fn efficiencies_decrease() {
        let costs = [0.0, 0.5, 1.1, 1.9, 3.0, 4.5];
        let gains = [0.0, 4.0, 6.5, 8.0, 9.0, 9.5];
        let h = efficient_frontier(&costs, &gains);
        for w in h.windows(3) {
            let e1 = (w[1].gain - w[0].gain) / (w[1].cost - w[0].cost);
            let e2 = (w[2].gain - w[1].gain) / (w[2].cost - w[1].cost);
            assert!(e2 <= e1 + 1e-12);
        }
    }
}
