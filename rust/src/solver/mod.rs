//! Integer-programming solvers for the paper's optimization (eq. 5):
//!
//!   maximize   sum_j c_{j, p(j)}
//!   subject to sum_j d_{j, p(j)} <= budget,   one configuration p per group.
//!
//! This is a Multiple-Choice Knapsack Problem (MCKP).  Four solvers:
//!   * `branch_bound` — exact, LP-relaxation-bounded DFS (the default).
//!   * `dp`           — scaled dynamic program (near-exact, linear-ish).
//!   * `greedy`       — convex-hull marginal-efficiency heuristic.
//!   * `lp_relax`     — LP relaxation (upper bound; used by branch_bound).

pub mod branch_bound;
pub mod dp;
pub mod greedy;
pub mod hull;
pub mod lp_relax;
pub mod problem;

pub use branch_bound::solve as solve_exact;
pub use problem::{Mckp, Solution};

/// Solve with the exact method; fall back to greedy if B&B blows the node
/// budget (never observed on paper-scale instances, but bounded by design).
pub fn solve(p: &Mckp) -> Solution {
    branch_bound::solve(p)
}
