//! Integer-programming solvers for the paper's optimization (eq. 5),
//! generalized to multiple knapsack constraints:
//!
//!   maximize   sum_j c_{j, p(j)}
//!   subject to sum_j d^k_{j, p(j)} <= budget_k  for every cost dimension k,
//!              one configuration p per group.
//!
//! With one dimension this is the classic Multiple-Choice Knapsack Problem
//! (MCKP); the planning layer adds a second dimension (weight bytes) for
//! memory-capped requests.  Four solvers:
//!   * `branch_bound` — exact, LP-relaxation-bounded DFS, prunes on every
//!     cost dimension (the default); large instances fan out over a
//!     deterministic subproblem queue (`solve_with`) with bit-identical
//!     output at any thread count.
//!   * `dp`           — scaled dynamic program over the primary dimension
//!     (near-exact, linear-ish; single-constraint fast path).
//!   * `greedy`       — convex-hull marginal-efficiency heuristic; upgrades
//!     are applied only while every budget still fits.
//!   * `lp_relax`     — LP relaxation (upper bound; used by branch_bound).
//!     Multi-budget instances go through a surrogate/Lagrangian weighting.
//!
//! `Mckp::brute_force` stays as the cross-solver oracle for tests.

pub mod branch_bound;
pub mod dp;
pub mod greedy;
pub mod hull;
pub mod lp_relax;
pub mod problem;

pub use branch_bound::solve as solve_exact;
pub use problem::{CostDim, Mckp, Solution};

/// Shared feasibility tolerance: a cost may exceed its budget by at most
/// EPS and still count as feasible.  Every solver and the planning layer
/// use this one constant so tie-breaking is consistent end to end.
pub const EPS: f64 = 1e-12;

/// Solve with the exact method; fall back to greedy if B&B blows the node
/// budget (never observed on paper-scale instances, but bounded by design).
pub fn solve(p: &Mckp) -> Solution {
    branch_bound::solve(p)
}

/// Like [`solve`], fanned out over `pool` for large instances.  Output is
/// bit-identical to `solve` at any thread count (the exec layer's
/// determinism contract; see `branch_bound`'s module docs for the proof
/// sketch).
pub fn solve_with(p: &Mckp, pool: &crate::exec::ExecPool) -> Solution {
    branch_bound::solve_with(p, pool)
}
