//! Integer-programming solvers for the paper's optimization (eq. 5),
//! generalized to multiple knapsack constraints:
//!
//!   maximize   sum_j c_{j, p(j)}
//!   subject to sum_j d^k_{j, p(j)} <= budget_k  for every cost dimension k,
//!              one configuration p per group.
//!
//! With one dimension this is the classic Multiple-Choice Knapsack Problem
//! (MCKP); the planning layer adds a second dimension (weight bytes) for
//! memory-capped requests.  Four solvers:
//!   * `branch_bound` — exact, LP-relaxation-bounded DFS, prunes on every
//!     cost dimension (the default); large instances fan out over a
//!     deterministic subproblem queue (`solve_with`) with bit-identical
//!     output at any thread count.
//!   * `dp`           — scaled dynamic program over the primary dimension
//!     (near-exact, linear-ish; single-constraint fast path).
//!   * `greedy`       — convex-hull marginal-efficiency heuristic; upgrades
//!     are applied only while every budget still fits.
//!   * `lp_relax`     — LP relaxation (upper bound; used by branch_bound).
//!     Multi-budget instances go through a surrogate/Lagrangian weighting.
//!   * `parametric`   — one-pass chain DP over the group sequence yielding
//!     the ENTIRE gain-vs-primary-cost Pareto curve (exact
//!     single-constraint; dominance-bounded near-exact multi-constraint
//!     with per-point exactness flags and a branch & bound fallback).
//!     Levels live in arena-recycled structure-of-arrays columns
//!     (`LevelSoa`), an optional epsilon grid pre-prunes dominated states
//!     (`frontier_quantized`), and a persistent `FrontierDp` re-solves
//!     committed instances incrementally after budget or single-group
//!     table changes.  Backs `Planner::frontier` so a K-knot frontier
//!     costs one sweep, not K exact solves — and a warm re-solve far less.
//!
//! `Mckp::brute_force` stays as the cross-solver oracle for tests.  Every
//! float sort in this module is total (`f64::total_cmp` or an explicit
//! NaN-free key): degenerate inputs produce pruned/ordered states, never a
//! comparator panic.

pub mod branch_bound;
pub mod dp;
pub mod greedy;
pub mod hull;
pub mod lp_relax;
pub mod parametric;
pub mod problem;

pub use branch_bound::solve as solve_exact;
pub use problem::{CostDim, Mckp, Solution};

/// Shared feasibility tolerance: a cost may exceed its budget by at most
/// EPS and still count as feasible.  Every solver and the planning layer
/// use this one constant so tie-breaking is consistent end to end.
pub const EPS: f64 = 1e-12;

/// Marginal efficiency of a hull upgrade, shared by greedy, the LP
/// relaxation, and branch & bound's suffix bound so their orderings can
/// never desynchronize.  Total by construction: hull costs strictly
/// increase, but a degenerate (non-positive) dcost ranks +inf so free
/// upgrades sort first and 0/0 never forms a NaN comparator.
pub(crate) fn efficiency(dgain: f64, dcost: f64) -> f64 {
    if dcost <= 0.0 {
        f64::INFINITY
    } else {
        dgain / dcost
    }
}

/// Solve with the exact method; fall back to greedy if B&B blows the node
/// budget (never observed on paper-scale instances, but bounded by design).
pub fn solve(p: &Mckp) -> Solution {
    branch_bound::solve(p)
}

/// Like [`solve`], fanned out over `pool` for large instances.  Output is
/// bit-identical to `solve` at any thread count (the exec layer's
/// determinism contract; see `branch_bound`'s module docs for the proof
/// sketch).
pub fn solve_with(p: &Mckp, pool: &crate::exec::ExecPool) -> Solution {
    branch_bound::solve_with(p, pool)
}
