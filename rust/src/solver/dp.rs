//! Scaled dynamic program for MCKP — the single-constraint fast path.
//!
//! Costs are discretized onto `buckets` grid points of the budget (rounding
//! UP, so every returned solution is truly feasible); DP over groups x
//! buckets maximizes gain.  With the default 8192 buckets the approximation
//! loss is < J/8192 of the budget — indistinguishable from exact on paper
//! instances (verified against branch & bound in tests).
//!
//! The DP operates on the PRIMARY dimension only.  On multi-constraint
//! instances it stays a heuristic: the returned `feasible` flag reflects
//! every budget, but optimality holds only single-dim — use
//! [`crate::solver::branch_bound`] (the `solver::solve` default) there.

use super::problem::{Mckp, Solution};
use super::EPS;

pub const DEFAULT_BUCKETS: usize = 8192;

pub fn solve(p: &Mckp) -> Solution {
    solve_buckets(p, DEFAULT_BUCKETS)
}

pub fn solve_buckets(p: &Mckp, buckets: usize) -> Solution {
    let n = p.n_groups();
    let budget = p.budget();
    let min_cost = p.independent_min_cost(0);
    if min_cost > budget + EPS {
        return p.fallback();
    }
    if budget <= 0.0 {
        // Only zero-cost choices are usable.
        return zero_budget(p);
    }

    let scale = buckets as f64 / budget;
    let q = |c: f64| -> usize { (c * scale).ceil() as usize };

    const NEG: f64 = f64::MIN / 4.0;
    // dp[b] = best gain using budget <= b; choice backtracking per group.
    let mut dp = vec![NEG; buckets + 1];
    dp[0] = 0.0;
    let mut back: Vec<Vec<u32>> = Vec::with_capacity(n);

    for j in 0..n {
        let mut next = vec![NEG; buckets + 1];
        let mut choice_at = vec![u32::MAX; buckets + 1];
        for (i, (&c, &g)) in p.primary()[j].iter().zip(&p.gains[j]).enumerate() {
            let qc = q(c);
            if qc > buckets {
                continue;
            }
            for b in qc..=buckets {
                let prev = dp[b - qc];
                if prev > NEG / 2.0 && prev + g > next[b] {
                    next[b] = prev + g;
                    choice_at[b] = i as u32;
                }
            }
        }
        dp = next;
        back.push(choice_at);
    }

    // Best bucket.
    let mut best_b = 0usize;
    let mut best_g = NEG;
    for b in 0..=buckets {
        if dp[b] > best_g {
            best_g = dp[b];
            best_b = b;
        }
    }
    if best_g <= NEG / 2.0 {
        return p.fallback();
    }
    // Backtrack.
    let mut choice = vec![0usize; n];
    let mut b = best_b;
    for j in (0..n).rev() {
        let i = back[j][b] as usize;
        choice[j] = i;
        b -= q(p.primary()[j][i]);
    }
    p.solution_from(choice)
}

fn zero_budget(p: &Mckp) -> Solution {
    let choice: Vec<usize> = p
        .primary()
        .iter()
        .zip(&p.gains)
        .map(|(cs, gs)| {
            let mut best: Option<usize> = None;
            for i in 0..cs.len() {
                if cs[i] <= 0.0 && best.map_or(true, |b| gs[i] > gs[b]) {
                    best = Some(i);
                }
            }
            best.unwrap_or(0)
        })
        .collect();
    p.solution_from(choice)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::branch_bound;
    use crate::solver::problem::gen::random;
    use crate::util::Rng;

    #[test]
    fn near_exact_on_random_instances() {
        let mut rng = Rng::new(77);
        for trial in 0..200 {
            let p = random(&mut rng, 5, 5);
            let e = branch_bound::solve(&p);
            let d = solve(&p);
            assert_eq!(d.feasible, e.feasible, "trial {trial}");
            if e.feasible {
                assert!(d.cost <= p.budget() + 1e-9, "trial {trial}");
                // ceil-rounding may lose a bucket's worth of budget per group.
                assert!(
                    d.gain >= e.gain * 0.95 - 1e-9,
                    "trial {trial}: dp {} vs exact {}",
                    d.gain,
                    e.gain
                );
            }
        }
    }

    #[test]
    fn always_feasible_solutions() {
        let mut rng = Rng::new(31);
        for _ in 0..100 {
            let p = random(&mut rng, 6, 4);
            let d = solve(&p);
            if d.feasible {
                assert!(d.cost <= p.budget() + 1e-9);
            }
        }
    }

    #[test]
    fn coarse_buckets_still_feasible() {
        let mut rng = Rng::new(8);
        for _ in 0..50 {
            let p = random(&mut rng, 4, 4);
            let d = solve_buckets(&p, 16);
            if d.feasible {
                assert!(d.cost <= p.budget() + 1e-9);
            }
        }
    }

    #[test]
    fn zero_budget_zero_cost() {
        let p = Mckp::new(
            vec![vec![2.0, 9.0], vec![1.0, 5.0]],
            vec![vec![0.0, 1.0], vec![0.0, 2.0]],
            0.0,
        )
        .unwrap();
        let d = solve(&p);
        assert!(d.feasible);
        assert_eq!(d.choice, vec![0, 0]);
        assert_eq!(d.gain, 3.0);
    }
}
