//! Greedy MCKP: start all-min-cost, apply convex-hull upgrades in global
//! efficiency order while they fit.  Fast, feasible, and typically within a
//! few percent of optimal — used as the branch & bound incumbent and as an
//! ablation point (DESIGN.md calls out solver choice as a design ablation).
//!
//! Hulls and the efficiency order live on the PRIMARY dimension; with extra
//! dimensions an upgrade is only applied while EVERY budget still fits, so
//! the result is always feasible when the min-primary-cost start is.  (A
//! multi-constraint instance whose start violates a secondary budget falls
//! back infeasible here; branch & bound then searches for a feasible
//! assignment itself.)

use super::hull::HullPoint;
use super::lp_relax;
use super::problem::{Mckp, Solution};
use super::EPS;

pub fn solve(p: &Mckp) -> Solution {
    let hulls = lp_relax::hulls(p);
    solve_with_hulls(p, &hulls)
}

pub fn solve_with_hulls(p: &Mckp, hulls: &[Vec<HullPoint>]) -> Solution {
    let dims = p.n_dims();
    let mut level = vec![0usize; hulls.len()];
    // Start at the min-primary-cost hull points, tracking every dimension.
    let mut cost: Vec<f64> = (0..dims)
        .map(|d| {
            hulls
                .iter()
                .enumerate()
                .map(|(j, h)| p.costs[d].table[j][h[0].choice])
                .sum()
        })
        .collect();

    if !p.fits(&cost) {
        return p.fallback();
    }

    struct Inc {
        group: usize,
        to: usize,
        dcost: f64,
        dgain: f64,
    }
    let mut incs: Vec<Inc> = Vec::new();
    for (j, h) in hulls.iter().enumerate() {
        for t in 1..h.len() {
            incs.push(Inc {
                group: j,
                to: t,
                dcost: h[t].cost - h[t - 1].cost,
                dgain: h[t].gain - h[t - 1].gain,
            });
        }
    }
    // Zero-cost upgrades are free along the primary dimension: the shared
    // `solver::efficiency` ranks them +inf so they apply unconditionally
    // first (degenerate cost tables with equal-cost hull points otherwise
    // produce 0/0 = NaN ratios whose ordering is unstable).  The sort is
    // total — NaN-free efficiencies by construction, `total_cmp` plus the
    // (group, to) key for exact ties — so degenerate tables reorder
    // deterministically instead of panicking.
    let eff = |i: &Inc| super::efficiency(i.dgain, i.dcost);
    incs.sort_by(|a, b| {
        eff(b)
            .total_cmp(&eff(a))
            .then((a.group, a.to).cmp(&(b.group, b.to)))
    });

    for inc in incs {
        if inc.to != level[inc.group] + 1 {
            continue;
        }
        let j = inc.group;
        let from = hulls[j][inc.to - 1].choice;
        let to = hulls[j][inc.to].choice;
        let fits = (0..dims).all(|d| {
            cost[d] + p.costs[d].table[j][to] - p.costs[d].table[j][from]
                <= p.budgets[d] + EPS
        });
        if fits {
            for (d, c) in cost.iter_mut().enumerate() {
                *c += p.costs[d].table[j][to] - p.costs[d].table[j][from];
            }
            level[j] = inc.to;
        }
    }

    let choice: Vec<usize> = level.iter().zip(hulls).map(|(&t, h)| h[t].choice).collect();
    p.solution_from(choice)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::branch_bound;
    use crate::solver::problem::gen::{random, random_multi};
    use crate::util::Rng;

    #[test]
    fn feasible_and_below_exact() {
        let mut rng = Rng::new(2024);
        for _ in 0..200 {
            let p = random(&mut rng, 5, 5);
            let g = solve(&p);
            let e = branch_bound::solve(&p);
            assert_eq!(g.feasible, e.feasible);
            if e.feasible {
                assert!(g.cost <= p.budget() + 1e-9);
                assert!(g.gain <= e.gain + 1e-9);
            }
        }
    }

    #[test]
    fn usually_near_optimal() {
        let mut rng = Rng::new(55);
        let mut total_ratio = 0.0;
        let mut n = 0;
        for _ in 0..100 {
            let p = random(&mut rng, 6, 4);
            let e = branch_bound::solve(&p);
            if !e.feasible || e.gain <= 1e-9 {
                continue;
            }
            let g = solve(&p);
            total_ratio += g.gain / e.gain;
            n += 1;
        }
        assert!(n > 50);
        assert!(total_ratio / n as f64 > 0.9, "avg ratio {}", total_ratio / n as f64);
    }

    #[test]
    fn generous_budget_takes_best() {
        let p = Mckp::new(
            vec![vec![0.0, 3.0, 7.0], vec![1.0, 2.0]],
            vec![vec![0.0, 1.0, 2.0], vec![0.0, 1.0]],
            100.0,
        )
        .unwrap();
        let s = solve(&p);
        assert_eq!(s.gain, 9.0);
    }

    #[test]
    fn degenerate_equal_cost_tables_match_brute_force() {
        // Two choices at (numerically) the same cost plus denormal cost
        // steps: the ratio sort must stay total and the free upgrades must
        // apply first — regression for the 0/inf efficiency ordering.
        let cases = vec![
            // Exactly equal costs inside a group.
            Mckp::new(
                vec![vec![0.0, 3.0, 7.0], vec![0.0, 4.0]],
                vec![vec![1.0, 1.0, 1.0], vec![0.0, 2.0]],
                3.5,
            )
            .unwrap(),
            // Denormal cost steps (efficiencies overflow toward +inf).
            Mckp::new(
                vec![vec![0.0, 5.0, 10.0], vec![0.0, 1.0]],
                vec![vec![0.0, 1e-300, 2e-300], vec![0.0, 1.0]],
                0.5,
            )
            .unwrap(),
            // A zero-cost upgrade beside a paid one.
            Mckp::new(
                vec![vec![0.0, 2.0], vec![0.0, 9.0]],
                vec![vec![0.0, 0.0], vec![0.0, 5.0]],
                0.0,
            )
            .unwrap(),
        ];
        for (i, p) in cases.iter().enumerate() {
            let g = solve(p);
            let exact = p.brute_force();
            assert_eq!(g.feasible, exact.feasible, "case {i}");
            assert!(g.cost <= p.budget() + 1e-9, "case {i}");
            assert!(g.gain <= exact.gain + 1e-9, "case {i}");
        }
        // The free-upgrade case is solved optimally by greedy alone.
        let free = solve(&cases[2]);
        assert_eq!(free.gain, 2.0);
        // And the denormal case takes the (near-free) 10-gain upgrade.
        let denormal = solve(&cases[1]);
        assert_eq!(denormal.gain, 10.0);
    }

    #[test]
    fn multi_dim_solutions_fit_every_budget() {
        let mut rng = Rng::new(91);
        for trial in 0..200 {
            let p = random_multi(&mut rng, 5, 4, 2);
            let g = solve(&p);
            if g.feasible {
                assert!(p.fits(&g.costs), "trial {trial}");
            }
            let e = branch_bound::solve(&p);
            if e.feasible && g.feasible {
                assert!(g.gain <= e.gain + 1e-9, "trial {trial}");
            }
        }
    }
}
