//! MCKP problem definition + brute-force reference (tests only).

use anyhow::{bail, Result};

/// maximize sum_j gains[j][p_j]  s.t.  sum_j costs[j][p_j] <= budget.
#[derive(Clone, Debug)]
pub struct Mckp {
    pub gains: Vec<Vec<f64>>,
    pub costs: Vec<Vec<f64>>,
    pub budget: f64,
}

/// A (possibly infeasible-budget) assignment of one choice per group.
#[derive(Clone, Debug, PartialEq)]
pub struct Solution {
    pub choice: Vec<usize>,
    pub gain: f64,
    pub cost: f64,
    /// False when even the min-cost assignment exceeds the budget; in that
    /// case `choice` IS that min-cost assignment (the paper's tau=0 edge:
    /// fall back to the all-baseline configuration).
    pub feasible: bool,
}

impl Mckp {
    pub fn new(gains: Vec<Vec<f64>>, costs: Vec<Vec<f64>>, budget: f64) -> Result<Mckp> {
        if gains.len() != costs.len() {
            bail!("gains/costs group count mismatch");
        }
        for (j, (g, c)) in gains.iter().zip(&costs).enumerate() {
            if g.is_empty() || g.len() != c.len() {
                bail!("group {j}: bad choice count ({} vs {})", g.len(), c.len());
            }
            if c.iter().any(|x| !x.is_finite() || *x < 0.0) {
                bail!("group {j}: costs must be finite and non-negative");
            }
            if g.iter().any(|x| !x.is_finite()) {
                bail!("group {j}: gains must be finite");
            }
        }
        Ok(Mckp { gains, costs, budget })
    }

    pub fn n_groups(&self) -> usize {
        self.gains.len()
    }

    pub fn evaluate(&self, choice: &[usize]) -> (f64, f64) {
        let gain = choice.iter().enumerate().map(|(j, &p)| self.gains[j][p]).sum();
        let cost = choice.iter().enumerate().map(|(j, &p)| self.costs[j][p]).sum();
        (gain, cost)
    }

    /// Min-cost assignment (ties broken by higher gain) — the fallback and
    /// the B&B root.
    pub fn min_cost_choice(&self) -> Vec<usize> {
        self.costs
            .iter()
            .zip(&self.gains)
            .map(|(cs, gs)| {
                let mut best = 0usize;
                for i in 1..cs.len() {
                    if cs[i] < cs[best] || (cs[i] == cs[best] && gs[i] > gs[best]) {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    pub fn solution_from(&self, choice: Vec<usize>) -> Solution {
        let (gain, cost) = self.evaluate(&choice);
        Solution { feasible: cost <= self.budget + 1e-12, choice, gain, cost }
    }

    /// Exhaustive search — O(prod |choices|), tests only.
    pub fn brute_force(&self) -> Solution {
        let mut best: Option<Solution> = None;
        let mut choice = vec![0usize; self.n_groups()];
        loop {
            let sol = self.solution_from(choice.clone());
            if sol.feasible {
                let better = match &best {
                    None => true,
                    Some(b) => sol.gain > b.gain + 1e-12,
                };
                if better {
                    best = Some(sol);
                }
            }
            // Odometer increment.
            let mut j = 0;
            loop {
                if j == self.n_groups() {
                    return best.unwrap_or_else(|| {
                        let mut s = self.solution_from(self.min_cost_choice());
                        s.feasible = false;
                        s
                    });
                }
                choice[j] += 1;
                if choice[j] < self.gains[j].len() {
                    break;
                }
                choice[j] = 0;
                j += 1;
            }
        }
    }
}

#[cfg(test)]
pub mod gen {
    use super::*;
    use crate::util::Rng;

    /// Random MCKP instance for property tests.
    pub fn random(rng: &mut Rng, max_groups: usize, max_choices: usize) -> Mckp {
        let j = rng.range(1, max_groups + 1);
        let mut gains = Vec::new();
        let mut costs = Vec::new();
        for _ in 0..j {
            let k = rng.range(1, max_choices + 1);
            gains.push((0..k).map(|_| rng.f64() * 10.0).collect());
            costs.push((0..k).map(|_| rng.f64() * 5.0).collect());
        }
        let total_min: f64 = costs.iter().map(|c: &Vec<f64>| c.iter().cloned().fold(f64::MAX, f64::min)).sum();
        let total_max: f64 = costs.iter().map(|c: &Vec<f64>| c.iter().cloned().fold(0.0, f64::max)).sum();
        let budget = total_min + rng.f64() * (total_max - total_min).max(0.1);
        Mckp::new(gains, costs, budget).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        assert!(Mckp::new(vec![vec![1.0]], vec![vec![1.0], vec![2.0]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![]], vec![vec![]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![1.0]], vec![vec![-1.0]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![f64::NAN]], vec![vec![1.0]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![1.0, 2.0]], vec![vec![0.0, 1.0]], 1.0).is_ok());
    }

    #[test]
    fn brute_force_simple() {
        // Two groups; budget forces the cheap option in one of them.
        let p = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            4.0,
        )
        .unwrap();
        let s = p.brute_force();
        assert!(s.feasible);
        assert_eq!(s.gain, 10.0);
        assert_eq!(s.choice, vec![1, 0]);
    }

    #[test]
    fn infeasible_falls_back() {
        let p = Mckp::new(vec![vec![1.0, 5.0]], vec![vec![2.0, 3.0]], 1.0).unwrap();
        let s = p.brute_force();
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]); // min-cost
    }

    #[test]
    fn min_cost_tie_prefers_gain() {
        let p = Mckp::new(vec![vec![1.0, 5.0]], vec![vec![2.0, 2.0]], 10.0).unwrap();
        assert_eq!(p.min_cost_choice(), vec![1]);
    }
}
