//! Multi-constraint MCKP problem definition + brute-force reference
//! (tests only).
//!
//! The 0.2 problem had a single loss-MSE budget; 0.3 generalizes to a
//! vector of cost dimensions (`costs[d].table[j][p]`) with one budget per
//! dimension, so the planner can express "maximize time gain subject to
//! loss-MSE <= tau AND weight bytes <= cap" as one solve.  The
//! single-budget form stays available through the thin [`Mckp::new`]
//! constructor so the DP/hull fast paths survive unchanged.

use super::EPS;
use anyhow::{bail, Result};

/// One cost dimension of a multi-constraint MCKP: a diagnostic label plus
/// the per-group, per-choice cost table (same shape as `gains`).
#[derive(Clone, Debug, PartialEq)]
pub struct CostDim {
    pub label: String,
    /// table[j][p] — cost of choice p in group j along this dimension.
    pub table: Vec<Vec<f64>>,
}

impl CostDim {
    pub fn new(label: impl Into<String>, table: Vec<Vec<f64>>) -> CostDim {
        CostDim { label: label.into(), table }
    }
}

/// maximize sum_j gains[j][p_j]  s.t. for every dimension d:
/// sum_j costs[d].table[j][p_j] <= budgets[d].
#[derive(Clone, Debug)]
pub struct Mckp {
    pub gains: Vec<Vec<f64>>,
    pub costs: Vec<CostDim>,
    pub budgets: Vec<f64>,
}

/// A (possibly infeasible-budget) assignment of one choice per group.
#[derive(Clone, Debug, PartialEq)]
pub struct Solution {
    pub choice: Vec<usize>,
    pub gain: f64,
    /// Primary-dimension (dim 0) cost of `choice`.
    pub cost: f64,
    /// Cost along every dimension (`costs[0] == cost`).
    pub costs: Vec<f64>,
    /// False when no assignment satisfies every budget; in that case
    /// `choice` IS the min-primary-cost assignment (the paper's tau=0 edge:
    /// fall back to the all-baseline configuration).
    pub feasible: bool,
}

impl Mckp {
    /// Single-constraint constructor (the paper's eq. 5) — the thin shim
    /// the DP/hull fast paths key on.
    pub fn new(gains: Vec<Vec<f64>>, costs: Vec<Vec<f64>>, budget: f64) -> Result<Mckp> {
        Mckp::multi(gains, vec![CostDim::new("cost", costs)], vec![budget])
    }

    /// Multi-constraint constructor: one [`CostDim`] + budget per dimension.
    pub fn multi(gains: Vec<Vec<f64>>, costs: Vec<CostDim>, budgets: Vec<f64>) -> Result<Mckp> {
        if costs.is_empty() || costs.len() != budgets.len() {
            bail!(
                "need one budget per cost dimension ({} dims, {} budgets)",
                costs.len(),
                budgets.len()
            );
        }
        for dim in &costs {
            if dim.table.len() != gains.len() {
                bail!(
                    "gains/costs group count mismatch ({} vs {} in dim '{}')",
                    gains.len(),
                    dim.table.len(),
                    dim.label
                );
            }
        }
        for (j, g) in gains.iter().enumerate() {
            if g.is_empty() {
                bail!("group {j}: empty choice set");
            }
            if g.iter().any(|x| !x.is_finite()) {
                bail!("group {j}: gains must be finite");
            }
            for dim in &costs {
                let c = &dim.table[j];
                if c.len() != g.len() {
                    bail!(
                        "group {j}: bad choice count ({} vs {}) in dim '{}'",
                        g.len(),
                        c.len(),
                        dim.label
                    );
                }
                if c.iter().any(|x| !x.is_finite() || *x < 0.0) {
                    bail!("group {j}: costs must be finite and non-negative in dim '{}'", dim.label);
                }
            }
        }
        Ok(Mckp { gains, costs, budgets })
    }

    pub fn n_groups(&self) -> usize {
        self.gains.len()
    }

    pub fn n_dims(&self) -> usize {
        self.costs.len()
    }

    pub fn is_single(&self) -> bool {
        self.costs.len() == 1
    }

    /// Primary-dimension cost table (dim 0 — loss MSE in the planner).
    pub fn primary(&self) -> &[Vec<f64>] {
        &self.costs[0].table
    }

    /// Primary-dimension budget (dim 0).
    pub fn budget(&self) -> f64 {
        self.budgets[0]
    }

    /// (gain, per-dimension cost) of a full assignment.
    pub fn evaluate(&self, choice: &[usize]) -> (f64, Vec<f64>) {
        let gain = choice.iter().enumerate().map(|(j, &p)| self.gains[j][p]).sum();
        let costs = self
            .costs
            .iter()
            .map(|dim| choice.iter().enumerate().map(|(j, &p)| dim.table[j][p]).sum())
            .collect();
        (gain, costs)
    }

    /// True when a cost vector fits every budget (shared EPS slack).
    pub fn fits(&self, costs: &[f64]) -> bool {
        costs.iter().zip(&self.budgets).all(|(c, b)| *c <= *b + EPS)
    }

    /// Min-primary-cost assignment (ties broken by higher gain) — the
    /// fallback and the B&B root.
    pub fn min_cost_choice(&self) -> Vec<usize> {
        self.primary()
            .iter()
            .zip(&self.gains)
            .map(|(cs, gs)| {
                let mut best = 0usize;
                for i in 1..cs.len() {
                    if cs[i] < cs[best] || (cs[i] == cs[best] && gs[i] > gs[best]) {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }

    /// Lower bound on dimension d: the sum of each group's cheapest choice
    /// along d alone (choices may differ per dim — a bound, not an
    /// assignment).  Exceeding a budget here proves joint infeasibility.
    pub fn independent_min_cost(&self, d: usize) -> f64 {
        self.costs[d]
            .table
            .iter()
            .map(|cs| cs.iter().cloned().fold(f64::MAX, f64::min))
            .sum()
    }

    /// True when `other` has the same group / choice-count / dimension
    /// shape, i.e. DP levels built for `self` are index-compatible with
    /// `other`'s tables.  Values are NOT compared — see
    /// [`Mckp::first_divergent_group`].
    pub fn same_shape(&self, other: &Mckp) -> bool {
        self.n_dims() == other.n_dims()
            && self.gains.len() == other.gains.len()
            && self.gains.iter().zip(&other.gains).all(|(a, b)| a.len() == b.len())
    }

    /// First group whose gain table or any dimension's cost table differs
    /// BITWISE from `other`'s (`None` when every table is bit-identical).
    /// Budgets are deliberately not compared: the incremental frontier
    /// solver's committed levels are budget-free, so pure tau-range or
    /// memory-cap changes dirty nothing.  Requires [`Mckp::same_shape`].
    pub fn first_divergent_group(&self, other: &Mckp) -> Option<usize> {
        debug_assert!(self.same_shape(other));
        (0..self.n_groups()).find(|&j| {
            let gains_differ = self.gains[j]
                .iter()
                .zip(&other.gains[j])
                .any(|(a, b)| a.to_bits() != b.to_bits());
            gains_differ
                || self.costs.iter().zip(&other.costs).any(|(da, db)| {
                    da.table[j].iter().zip(&db.table[j]).any(|(a, b)| a.to_bits() != b.to_bits())
                })
        })
    }

    pub fn solution_from(&self, choice: Vec<usize>) -> Solution {
        let (gain, costs) = self.evaluate(&choice);
        Solution { feasible: self.fits(&costs), choice, gain, cost: costs[0], costs }
    }

    /// The infeasible fallback: min-primary-cost choice, `feasible = false`.
    pub fn fallback(&self) -> Solution {
        let mut s = self.solution_from(self.min_cost_choice());
        s.feasible = false;
        s
    }

    /// Exhaustive search over every dimension — the cross-solver oracle
    /// (tests only; O(prod |choices|)).
    pub fn brute_force(&self) -> Solution {
        let mut best: Option<Solution> = None;
        let mut choice = vec![0usize; self.n_groups()];
        loop {
            let sol = self.solution_from(choice.clone());
            if sol.feasible {
                let better = match &best {
                    None => true,
                    Some(b) => sol.gain > b.gain + EPS,
                };
                if better {
                    best = Some(sol);
                }
            }
            // Odometer increment.
            let mut j = 0;
            loop {
                if j == self.n_groups() {
                    return best.unwrap_or_else(|| self.fallback());
                }
                choice[j] += 1;
                if choice[j] < self.gains[j].len() {
                    break;
                }
                choice[j] = 0;
                j += 1;
            }
        }
    }
}

/// Random-instance generators shared by unit, property, and integration
/// tests (compiled unconditionally so `tests/` crates can reuse one
/// distribution instead of drifting copies).
pub mod gen {
    use super::*;
    use crate::util::Rng;

    /// Random single-constraint MCKP instance for property tests.
    pub fn random(rng: &mut Rng, max_groups: usize, max_choices: usize) -> Mckp {
        let j = rng.range(1, max_groups + 1);
        let mut gains = Vec::new();
        let mut costs = Vec::new();
        for _ in 0..j {
            let k = rng.range(1, max_choices + 1);
            gains.push((0..k).map(|_| rng.f64() * 10.0).collect());
            costs.push((0..k).map(|_| rng.f64() * 5.0).collect());
        }
        let total_min: f64 = costs.iter().map(|c: &Vec<f64>| c.iter().cloned().fold(f64::MAX, f64::min)).sum();
        let total_max: f64 = costs.iter().map(|c: &Vec<f64>| c.iter().cloned().fold(0.0, f64::max)).sum();
        let budget = total_min + rng.f64() * (total_max - total_min).max(0.1);
        Mckp::new(gains, costs, budget).unwrap()
    }

    /// Random multi-constraint instance: like [`random`] but with `dims`
    /// independent cost dimensions, each budgeted between its independent
    /// minimum and maximum so feasibility is non-trivial either way.
    pub fn random_multi(
        rng: &mut Rng,
        max_groups: usize,
        max_choices: usize,
        dims: usize,
    ) -> Mckp {
        let j = rng.range(1, max_groups + 1);
        let sizes: Vec<usize> = (0..j).map(|_| rng.range(1, max_choices + 1)).collect();
        let gains: Vec<Vec<f64>> = sizes
            .iter()
            .map(|&k| (0..k).map(|_| rng.f64() * 10.0).collect())
            .collect();
        let mut costs = Vec::new();
        let mut budgets = Vec::new();
        for d in 0..dims {
            let table: Vec<Vec<f64>> = sizes
                .iter()
                .map(|&k| (0..k).map(|_| rng.f64() * 5.0).collect())
                .collect();
            let lo: f64 = table.iter().map(|c| c.iter().cloned().fold(f64::MAX, f64::min)).sum();
            let hi: f64 = table.iter().map(|c| c.iter().cloned().fold(0.0f64, f64::max)).sum();
            budgets.push(lo + rng.f64() * (hi - lo).max(0.1));
            costs.push(CostDim::new(format!("dim{d}"), table));
        }
        Mckp::multi(gains, costs, budgets).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        assert!(Mckp::new(vec![vec![1.0]], vec![vec![1.0], vec![2.0]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![]], vec![vec![]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![1.0]], vec![vec![-1.0]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![f64::NAN]], vec![vec![1.0]], 1.0).is_err());
        assert!(Mckp::new(vec![vec![1.0, 2.0]], vec![vec![0.0, 1.0]], 1.0).is_ok());
    }

    #[test]
    fn multi_validation() {
        // Budget count must match dimension count.
        assert!(Mckp::multi(
            vec![vec![1.0]],
            vec![CostDim::new("a", vec![vec![1.0]])],
            vec![1.0, 2.0],
        )
        .is_err());
        assert!(Mckp::multi(vec![vec![1.0]], vec![], vec![]).is_err());
        // Every dimension must have the full group shape.
        assert!(Mckp::multi(
            vec![vec![1.0, 2.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 1.0]]),
                CostDim::new("b", vec![vec![0.0]]),
            ],
            vec![1.0, 1.0],
        )
        .is_err());
        let p = Mckp::multi(
            vec![vec![1.0, 2.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 1.0]]),
                CostDim::new("b", vec![vec![2.0, 0.5]]),
            ],
            vec![1.0, 1.0],
        )
        .unwrap();
        assert_eq!(p.n_dims(), 2);
        assert!(!p.is_single());
    }

    #[test]
    fn brute_force_simple() {
        // Two groups; budget forces the cheap option in one of them.
        let p = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            4.0,
        )
        .unwrap();
        let s = p.brute_force();
        assert!(s.feasible);
        assert_eq!(s.gain, 10.0);
        assert_eq!(s.choice, vec![1, 0]);
        assert_eq!(s.costs, vec![s.cost]);
    }

    #[test]
    fn brute_force_respects_second_dimension() {
        // Dim 0 would allow both upgrades; dim 1 only allows group 1's.
        let p = Mckp::multi(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![
                CostDim::new("mse", vec![vec![0.0, 1.0], vec![0.0, 1.0]]),
                CostDim::new("bytes", vec![vec![0.0, 5.0], vec![0.0, 1.0]]),
            ],
            vec![10.0, 2.0],
        )
        .unwrap();
        let s = p.brute_force();
        assert!(s.feasible);
        assert_eq!(s.choice, vec![0, 1]);
        assert_eq!(s.gain, 8.0);
        assert!((s.costs[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn infeasible_falls_back() {
        let p = Mckp::new(vec![vec![1.0, 5.0]], vec![vec![2.0, 3.0]], 1.0).unwrap();
        let s = p.brute_force();
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]); // min-cost
    }

    #[test]
    fn jointly_infeasible_multi_falls_back() {
        // Each dim is satisfiable alone (with different choices) but no
        // single choice fits both budgets.
        let p = Mckp::multi(
            vec![vec![1.0, 5.0]],
            vec![
                CostDim::new("a", vec![vec![0.0, 3.0]]),
                CostDim::new("b", vec![vec![3.0, 0.0]]),
            ],
            vec![1.0, 1.0],
        )
        .unwrap();
        let s = p.brute_force();
        assert!(!s.feasible);
        assert_eq!(s.choice, vec![0]); // min primary cost
    }

    #[test]
    fn shape_and_divergence_diffing() {
        let base = Mckp::new(
            vec![vec![0.0, 10.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0], vec![0.0, 2.0]],
            4.0,
        )
        .unwrap();
        // Identical tables: same shape, no divergent group — even when
        // only the budget changed.
        let mut budget_only = base.clone();
        budget_only.budgets[0] = 1.5;
        assert!(base.same_shape(&budget_only));
        assert_eq!(base.first_divergent_group(&budget_only), None);
        // Group 1's gain table changes: divergence starts there.
        let mut g1 = base.clone();
        g1.gains[1][1] = 9.0;
        assert!(base.same_shape(&g1));
        assert_eq!(base.first_divergent_group(&g1), Some(1));
        // A cost-table change counts too, at its own group.
        let mut c0 = base.clone();
        c0.costs[0].table[0][1] = 3.5;
        assert_eq!(base.first_divergent_group(&c0), Some(0));
        // -0.0 vs 0.0 is a BITWISE divergence (conservative on purpose).
        let mut negz = base.clone();
        negz.gains[0][0] = -0.0;
        assert_eq!(base.first_divergent_group(&negz), Some(0));
        // Different choice counts: not the same shape.
        let wider = Mckp::new(
            vec![vec![0.0, 10.0, 11.0], vec![0.0, 8.0]],
            vec![vec![0.0, 3.0, 4.0], vec![0.0, 2.0]],
            4.0,
        )
        .unwrap();
        assert!(!base.same_shape(&wider));
    }

    #[test]
    fn min_cost_tie_prefers_gain() {
        let p = Mckp::new(vec![vec![1.0, 5.0]], vec![vec![2.0, 2.0]], 10.0).unwrap();
        assert_eq!(p.min_cost_choice(), vec![1]);
    }

    #[test]
    fn independent_min_cost_per_dim() {
        let p = Mckp::multi(
            vec![vec![0.0, 1.0]],
            vec![
                CostDim::new("a", vec![vec![2.0, 5.0]]),
                CostDim::new("b", vec![vec![7.0, 3.0]]),
            ],
            vec![10.0, 10.0],
        )
        .unwrap();
        assert_eq!(p.independent_min_cost(0), 2.0);
        assert_eq!(p.independent_min_cost(1), 3.0);
    }
}
