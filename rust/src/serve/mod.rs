//! The resident planning daemon (`ampq serve --listen`).
//!
//! A zero-dependency HTTP/1.1 service over the staged planning API:
//!
//! * [`http`] — hand-rolled request parsing (size/time limits,
//!   keep-alive) and chunked NDJSON responses;
//! * [`queue`] — the bounded admission queue (all-or-nothing admission,
//!   503 + `Retry-After` on overflow);
//! * [`metrics`] — request/status counters and fixed-bucket latency
//!   histograms behind `GET /metrics`;
//! * [`daemon`] — the accept loop, router, solver worker pool, and
//!   graceful shutdown;
//! * [`client`] — the minimal HTTP client driving the integration tests
//!   and the `ampq_client` CI smoke binary.
//!
//! See DESIGN.md §4e for the endpoint table and streaming schema.

pub mod client;
pub mod daemon;
pub mod http;
pub mod metrics;
pub mod queue;

pub use daemon::{Daemon, ServeConfig, ShutdownHandle};
pub use metrics::{Histogram, Metrics};
pub use queue::AdmissionQueue;
