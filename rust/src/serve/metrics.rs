//! Daemon observability: per-endpoint/status request counters and
//! fixed-bucket latency histograms, rendered in the Prometheus text
//! exposition format for `GET /metrics`.
//!
//! The histogram buckets are log-spaced powers of two over 1us..~67s —
//! fixed at construction, so recording is a lock-free pair of atomic
//! increments and quantile estimates (p50/p99) are a cumulative walk
//! with linear interpolation inside the matched bucket.  (An earlier
//! version returned the bucket's upper bound outright, overstating small
//! latencies by up to 2x — the estimate now lands within the bucket, so
//! the absolute error is bounded by the bucket width.)

use crate::dist::DistMetrics;
use crate::util::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const N_BUCKETS: usize = 27;

/// Fixed log-spaced latency histogram (microseconds).
pub struct Histogram {
    /// Upper bound of bucket i: `2^i` us; the last bucket is unbounded.
    counts: [AtomicU64; N_BUCKETS + 1],
    total: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, us: f64) {
        let us = us.max(0.0);
        let mut idx = N_BUCKETS; // overflow bucket
        for i in 0..N_BUCKETS {
            if us <= (1u64 << i) as f64 {
                idx = i;
                break;
            }
        }
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us.round() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Estimated q-quantile latency (us): the rank is located in its
    /// bucket, then linearly interpolated between the bucket's bounds by
    /// rank position.  The estimate always lies within the matched
    /// bucket, so its absolute error is bounded by that bucket's width
    /// (and a bucket's last rank still maps to its exact upper bound).
    /// 0 when nothing was recorded; `q` in [0, 1]; +Inf only for samples
    /// in the unbounded overflow bucket.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                if i >= N_BUCKETS {
                    return f64::INFINITY;
                }
                let lower = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                let upper = (1u64 << i) as f64;
                let frac = (target - seen) as f64 / c as f64;
                return lower + (upper - lower) * frac;
            }
            seen += c;
        }
        f64::INFINITY
    }
}

/// All daemon counters.  Shared (`Arc`) between the accept loop, the
/// worker pool, and the /metrics renderer.
#[derive(Default)]
pub struct Metrics {
    /// (endpoint, status) -> count.  Unknown paths are bucketed under
    /// "other" so a scanner can't grow the map without bound.
    requests: Mutex<BTreeMap<(String, u16), u64>>,
    pub plan_latency: Histogram,
    pub frontier_latency: Histogram,
    queue_rejected: AtomicU64,
    request_timeouts: AtomicU64,
    /// Supervision counters of the dist worker fleet the daemon staged
    /// with (`--dist-workers N`); `None` when staging ran in-process.
    dist: Mutex<Option<DistMetrics>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, endpoint: &str, status: u16) {
        let mut m = self.requests.lock().expect("metrics lock poisoned");
        *m.entry((endpoint.to_string(), status)).or_insert(0) += 1;
    }

    pub fn requests_for(&self, endpoint: &str, status: u16) -> u64 {
        let m = self.requests.lock().expect("metrics lock poisoned");
        m.get(&(endpoint.to_string(), status)).copied().unwrap_or(0)
    }

    pub fn total_requests(&self) -> u64 {
        let m = self.requests.lock().expect("metrics lock poisoned");
        m.values().sum()
    }

    pub fn inc_rejected(&self) {
        self.queue_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn rejected(&self) -> u64 {
        self.queue_rejected.load(Ordering::Relaxed)
    }

    pub fn inc_timeouts(&self) {
        self.request_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn timeouts(&self) -> u64 {
        self.request_timeouts.load(Ordering::Relaxed)
    }

    /// Install (or refresh) the dist fleet's supervision counters so
    /// `/metrics` exposes them.  The daemon snapshots the coordinator
    /// after staging — the fleet is shut down before the listener binds,
    /// so these are final values, not a live view.
    pub fn set_dist(&self, m: DistMetrics) {
        *self.dist.lock().expect("metrics lock poisoned") = Some(m);
    }

    pub fn dist(&self) -> Option<DistMetrics> {
        self.dist.lock().expect("metrics lock poisoned").clone()
    }

    /// Prometheus text exposition.  `extra` carries gauges owned elsewhere
    /// (frontier cache hit/solve counters, queue depth, ...).
    pub fn render(&self, extra: &[(&str, f64)]) -> String {
        let mut out = String::new();
        out.push_str("# TYPE ampq_requests_total counter\n");
        {
            let m = self.requests.lock().expect("metrics lock poisoned");
            for ((endpoint, status), count) in m.iter() {
                out.push_str(&format!(
                    "ampq_requests_total{{endpoint=\"{endpoint}\",status=\"{status}\"}} {count}\n"
                ));
            }
        }
        out.push_str("# TYPE ampq_queue_rejected_total counter\n");
        out.push_str(&format!("ampq_queue_rejected_total {}\n", self.rejected()));
        out.push_str("# TYPE ampq_request_timeouts_total counter\n");
        out.push_str(&format!("ampq_request_timeouts_total {}\n", self.timeouts()));
        if let Some(d) = self.dist() {
            for (k, v) in [
                ("tasks", d.tasks),
                ("retries", d.retries),
                ("deadline_expiries", d.deadline_expiries),
                ("worker_crashes", d.worker_crashes),
                ("respawns", d.respawns),
            ] {
                out.push_str(&format!("# TYPE ampq_dist_{k}_total counter\n"));
                out.push_str(&format!("ampq_dist_{k}_total {v}\n"));
            }
        }
        for (name, hist) in
            [("plan", &self.plan_latency), ("frontier", &self.frontier_latency)]
        {
            out.push_str(&format!("# TYPE ampq_{name}_latency_us summary\n"));
            for (label, q) in [("0.5", 0.5), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "ampq_{name}_latency_us{{quantile=\"{label}\"}} {}\n",
                    fmt_val(hist.quantile_us(q))
                ));
            }
            out.push_str(&format!("ampq_{name}_latency_us_count {}\n", hist.count()));
            out.push_str(&format!("ampq_{name}_latency_us_sum {}\n", hist.sum_us()));
        }
        for (k, v) in extra {
            out.push_str(&format!("ampq_{k} {}\n", fmt_val(*v)));
        }
        out
    }

    /// The same counters as [`Metrics::render`], as a JSON object — served
    /// when a `/metrics` client sends `Accept: application/json`.
    pub fn render_json(&self, extra: &[(&str, f64)]) -> Json {
        let requests = {
            let m = self.requests.lock().expect("metrics lock poisoned");
            Json::Arr(
                m.iter()
                    .map(|((endpoint, status), count)| {
                        Json::Obj(vec![
                            ("endpoint".into(), Json::Str(endpoint.clone())),
                            ("status".into(), Json::Num(*status as f64)),
                            ("count".into(), Json::Num(*count as f64)),
                        ])
                    })
                    .collect(),
            )
        };
        // Overflow-bucket quantiles are +Inf, which JSON cannot carry.
        let num_or_null = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let hist = |h: &Histogram| {
            Json::Obj(vec![
                ("p50_us".into(), num_or_null(h.quantile_us(0.5))),
                ("p99_us".into(), num_or_null(h.quantile_us(0.99))),
                ("count".into(), Json::Num(h.count() as f64)),
                ("sum_us".into(), Json::Num(h.sum_us() as f64)),
            ])
        };
        let mut kv = vec![
            ("requests".to_string(), requests),
            ("queue_rejected".to_string(), Json::Num(self.rejected() as f64)),
            ("request_timeouts".to_string(), Json::Num(self.timeouts() as f64)),
            ("plan_latency".to_string(), hist(&self.plan_latency)),
            ("frontier_latency".to_string(), hist(&self.frontier_latency)),
        ];
        if let Some(d) = self.dist() {
            kv.push((
                "dist".to_string(),
                Json::Obj(vec![
                    ("tasks".into(), Json::Num(d.tasks as f64)),
                    ("retries".into(), Json::Num(d.retries as f64)),
                    ("deadline_expiries".into(), Json::Num(d.deadline_expiries as f64)),
                    ("worker_crashes".into(), Json::Num(d.worker_crashes as f64)),
                    ("respawns".into(), Json::Num(d.respawns as f64)),
                ]),
            ));
        }
        kv.push((
            "gauges".to_string(),
            Json::Obj(extra.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect()),
        ));
        Json::Obj(kv)
    }
}

fn fmt_val(v: f64) -> String {
    if v.is_infinite() {
        "+Inf".to_string()
    } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_interpolate_within_the_bucket() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0, "empty histogram reports 0");
        for _ in 0..90 {
            h.record(100.0); // bucket (64, 128]
        }
        for _ in 0..10 {
            h.record(5000.0); // bucket (4096, 8192]
        }
        assert_eq!(h.count(), 100);
        // p50: rank 50 of 90 in (64, 128] -> 64 + 64 * 50/90.
        let p50 = h.quantile_us(0.5);
        assert!((p50 - (64.0 + 64.0 * 50.0 / 90.0)).abs() < 1e-9, "p50 {p50}");
        // p99: rank 99, the 9th of 10 in (4096, 8192] -> 4096 + 4096 * 0.9.
        let p99 = h.quantile_us(0.99);
        assert!((p99 - (4096.0 + 4096.0 * 0.9)).abs() < 1e-9, "p99 {p99}");
        assert_eq!(h.sum_us(), 90 * 100 + 10 * 5000);
    }

    #[test]
    fn histogram_quantile_error_is_bounded_by_bucket_width() {
        // The regression this fix pins: a single 100us sample used to
        // report p50 = 128us (the bucket bound, a 28% overstatement; 1.xus
        // samples were overstated up to 2x).  Interpolation must land
        // within the sample's bucket and within one bucket width of truth.
        for &sample in &[1.5, 3.0, 100.0, 900.0, 5000.0] {
            let h = Histogram::new();
            h.record(sample);
            let est = h.quantile_us(0.5);
            let width = {
                let mut i = 0;
                while sample > (1u64 << i) as f64 {
                    i += 1;
                }
                let lower = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                ((1u64 << i) as f64, lower)
            };
            let (upper, lower) = width;
            assert!(est > lower && est <= upper, "{sample}: est {est} outside bucket");
            assert!((est - sample).abs() <= upper - lower, "{sample}: err too large");
        }
        // A bucket's last rank still reports the exact upper bound, so
        // quantiles never UNDERstate by more than the bucket width either.
        let h = Histogram::new();
        h.record(1024.0);
        assert_eq!(h.quantile_us(1.0), 1024.0);
    }

    #[test]
    fn histogram_quantiles_are_monotone_in_q() {
        let h = Histogram::new();
        for v in [2.0, 10.0, 70.0, 300.0, 2000.0, 9000.0, 40000.0] {
            for _ in 0..5 {
                h.record(v);
            }
        }
        let mut prev = 0.0;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let est = h.quantile_us(q);
            assert!(est >= prev, "quantile not monotone at q={q}: {est} < {prev}");
            prev = est;
        }
    }

    #[test]
    fn histogram_overflow_bucket_is_inf() {
        let h = Histogram::new();
        h.record(1e12);
        assert!(h.quantile_us(0.5).is_infinite());
    }

    #[test]
    fn render_is_parseable_line_oriented_text() {
        let m = Metrics::new();
        m.record_request("/v1/plan", 200);
        m.record_request("/v1/plan", 200);
        m.record_request("/v1/plan", 503);
        m.record_request("/healthz", 200);
        m.inc_rejected();
        m.plan_latency.record(900.0);
        let text = m.render(&[("frontier_cache_hits_total", 3.0)]);
        assert!(text
            .contains("ampq_requests_total{endpoint=\"/v1/plan\",status=\"200\"} 2\n"));
        assert!(text
            .contains("ampq_requests_total{endpoint=\"/v1/plan\",status=\"503\"} 1\n"));
        assert!(text.contains("ampq_queue_rejected_total 1\n"));
        assert!(text.contains("ampq_plan_latency_us{quantile=\"0.5\"} 1024\n"));
        assert!(text.contains("ampq_plan_latency_us_count 1\n"));
        assert!(text.contains("ampq_frontier_cache_hits_total 3\n"));
        assert_eq!(m.requests_for("/v1/plan", 200), 2);
        assert_eq!(m.total_requests(), 4);
    }

    #[test]
    fn json_rendering_mirrors_the_text_counters() {
        let m = Metrics::new();
        m.record_request("/v1/plan", 200);
        m.inc_timeouts();
        m.plan_latency.record(900.0);
        m.frontier_latency.record(1e12); // overflow bucket -> null quantiles
        let j = m.render_json(&[("queue_depth", 4.0)]);
        let text = j.to_string();
        let back = Json::parse(&text).expect("render_json must emit valid JSON");
        let reqs = back.get("requests").unwrap().arr().unwrap();
        assert_eq!(reqs.len(), 1);
        assert_eq!(reqs[0].get("endpoint").unwrap().str().unwrap(), "/v1/plan");
        assert_eq!(reqs[0].get("count").unwrap().f64().unwrap(), 1.0);
        assert_eq!(back.get("request_timeouts").unwrap().f64().unwrap(), 1.0);
        let plan = back.get("plan_latency").unwrap();
        assert_eq!(plan.get("count").unwrap().f64().unwrap(), 1.0);
        assert_eq!(plan.get("p50_us").unwrap().f64().unwrap(), 1024.0);
        assert!(matches!(
            back.get("frontier_latency").unwrap().get("p50_us").unwrap(),
            Json::Null
        ));
        let gauges = back.get("gauges").unwrap();
        assert_eq!(gauges.get("queue_depth").unwrap().f64().unwrap(), 4.0);
    }

    #[test]
    fn dist_supervision_counters_render_when_installed() {
        let m = Metrics::new();
        assert!(
            !m.render(&[]).contains("ampq_dist_"),
            "no dist lines without a fleet"
        );
        m.set_dist(DistMetrics {
            tasks: 12,
            retries: 3,
            deadline_expiries: 1,
            worker_crashes: 2,
            respawns: 2,
        });
        let text = m.render(&[]);
        assert!(text.contains("# TYPE ampq_dist_tasks_total counter\n"));
        assert!(text.contains("ampq_dist_tasks_total 12\n"));
        assert!(text.contains("ampq_dist_retries_total 3\n"));
        assert!(text.contains("ampq_dist_deadline_expiries_total 1\n"));
        assert!(text.contains("ampq_dist_worker_crashes_total 2\n"));
        assert!(text.contains("ampq_dist_respawns_total 2\n"));
    }
}
