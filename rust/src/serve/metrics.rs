//! Daemon observability: per-endpoint/status request counters and
//! fixed-bucket latency histograms, rendered in the Prometheus text
//! exposition format for `GET /metrics`.
//!
//! The histogram buckets are log-spaced powers of two over 1us..~67s —
//! fixed at construction, so recording is a lock-free pair of atomic
//! increments and quantile estimates (p50/p99) are a cumulative walk
//! returning the matched bucket's upper bound.  Estimates are therefore
//! quantized to bucket resolution (a factor of 2), which is exactly the
//! fidelity a serving dashboard needs and all the determinism a test can
//! assert against.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const N_BUCKETS: usize = 27;

/// Fixed log-spaced latency histogram (microseconds).
pub struct Histogram {
    /// Upper bound of bucket i: `2^i` us; the last bucket is unbounded.
    counts: [AtomicU64; N_BUCKETS + 1],
    total: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    pub fn record(&self, us: f64) {
        let us = us.max(0.0);
        let mut idx = N_BUCKETS; // overflow bucket
        for i in 0..N_BUCKETS {
            if us <= (1u64 << i) as f64 {
                idx = i;
                break;
            }
        }
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us.round() as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Upper bound (us) of the bucket containing the q-quantile sample;
    /// 0 when nothing was recorded.  `q` in [0, 1].
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c.load(Ordering::Relaxed);
            if seen >= target {
                return if i < N_BUCKETS {
                    (1u64 << i) as f64
                } else {
                    f64::INFINITY
                };
            }
        }
        f64::INFINITY
    }
}

/// All daemon counters.  Shared (`Arc`) between the accept loop, the
/// worker pool, and the /metrics renderer.
#[derive(Default)]
pub struct Metrics {
    /// (endpoint, status) -> count.  Unknown paths are bucketed under
    /// "other" so a scanner can't grow the map without bound.
    requests: Mutex<BTreeMap<(String, u16), u64>>,
    pub plan_latency: Histogram,
    pub frontier_latency: Histogram,
    queue_rejected: AtomicU64,
    request_timeouts: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self, endpoint: &str, status: u16) {
        let mut m = self.requests.lock().expect("metrics lock poisoned");
        *m.entry((endpoint.to_string(), status)).or_insert(0) += 1;
    }

    pub fn requests_for(&self, endpoint: &str, status: u16) -> u64 {
        let m = self.requests.lock().expect("metrics lock poisoned");
        m.get(&(endpoint.to_string(), status)).copied().unwrap_or(0)
    }

    pub fn total_requests(&self) -> u64 {
        let m = self.requests.lock().expect("metrics lock poisoned");
        m.values().sum()
    }

    pub fn inc_rejected(&self) {
        self.queue_rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn rejected(&self) -> u64 {
        self.queue_rejected.load(Ordering::Relaxed)
    }

    pub fn inc_timeouts(&self) {
        self.request_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn timeouts(&self) -> u64 {
        self.request_timeouts.load(Ordering::Relaxed)
    }

    /// Prometheus text exposition.  `extra` carries gauges owned elsewhere
    /// (frontier cache hit/solve counters, queue depth, ...).
    pub fn render(&self, extra: &[(&str, f64)]) -> String {
        let mut out = String::new();
        out.push_str("# TYPE ampq_requests_total counter\n");
        {
            let m = self.requests.lock().expect("metrics lock poisoned");
            for ((endpoint, status), count) in m.iter() {
                out.push_str(&format!(
                    "ampq_requests_total{{endpoint=\"{endpoint}\",status=\"{status}\"}} {count}\n"
                ));
            }
        }
        out.push_str("# TYPE ampq_queue_rejected_total counter\n");
        out.push_str(&format!("ampq_queue_rejected_total {}\n", self.rejected()));
        out.push_str("# TYPE ampq_request_timeouts_total counter\n");
        out.push_str(&format!("ampq_request_timeouts_total {}\n", self.timeouts()));
        for (name, hist) in
            [("plan", &self.plan_latency), ("frontier", &self.frontier_latency)]
        {
            out.push_str(&format!("# TYPE ampq_{name}_latency_us summary\n"));
            for (label, q) in [("0.5", 0.5), ("0.99", 0.99)] {
                out.push_str(&format!(
                    "ampq_{name}_latency_us{{quantile=\"{label}\"}} {}\n",
                    fmt_val(hist.quantile_us(q))
                ));
            }
            out.push_str(&format!("ampq_{name}_latency_us_count {}\n", hist.count()));
            out.push_str(&format!("ampq_{name}_latency_us_sum {}\n", hist.sum_us()));
        }
        for (k, v) in extra {
            out.push_str(&format!("ampq_{k} {}\n", fmt_val(*v)));
        }
        out
    }
}

fn fmt_val(v: f64) -> String {
    if v.is_infinite() {
        "+Inf".to_string()
    } else if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_hit_bucket_bounds() {
        let h = Histogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0, "empty histogram reports 0");
        for _ in 0..90 {
            h.record(100.0); // bucket bound 128
        }
        for _ in 0..10 {
            h.record(5000.0); // bucket bound 8192
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile_us(0.5), 128.0);
        assert_eq!(h.quantile_us(0.99), 8192.0);
        assert_eq!(h.sum_us(), 90 * 100 + 10 * 5000);
    }

    #[test]
    fn histogram_overflow_bucket_is_inf() {
        let h = Histogram::new();
        h.record(1e12);
        assert!(h.quantile_us(0.5).is_infinite());
    }

    #[test]
    fn render_is_parseable_line_oriented_text() {
        let m = Metrics::new();
        m.record_request("/v1/plan", 200);
        m.record_request("/v1/plan", 200);
        m.record_request("/v1/plan", 503);
        m.record_request("/healthz", 200);
        m.inc_rejected();
        m.plan_latency.record(900.0);
        let text = m.render(&[("frontier_cache_hits_total", 3.0)]);
        assert!(text
            .contains("ampq_requests_total{endpoint=\"/v1/plan\",status=\"200\"} 2\n"));
        assert!(text
            .contains("ampq_requests_total{endpoint=\"/v1/plan\",status=\"503\"} 1\n"));
        assert!(text.contains("ampq_queue_rejected_total 1\n"));
        assert!(text.contains("ampq_plan_latency_us{quantile=\"0.5\"} 1024\n"));
        assert!(text.contains("ampq_plan_latency_us_count 1\n"));
        assert!(text.contains("ampq_frontier_cache_hits_total 3\n"));
        assert_eq!(m.requests_for("/v1/plan", 200), 2);
        assert_eq!(m.total_requests(), 4);
    }
}
