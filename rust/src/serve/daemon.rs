//! The resident planning daemon: a TCP accept loop, a bounded admission
//! queue feeding a fixed solver worker pool, and an HTTP router over the
//! shared [`PlanService`].
//!
//! Request flow: a connection thread parses one HTTP request
//! ([`super::http`]), turns it into jobs, and submits them to the
//! [`AdmissionQueue`] — all-or-nothing, so overflow is an immediate `503`
//! + `Retry-After` instead of a half-admitted batch.  Worker threads
//! (one per exec worker) pop jobs and answer them on the `PlanService`;
//! the connection thread reassembles replies in request order and streams
//! batch/frontier results as newline-delimited JSON chunks.  Every
//! per-request deadline is enforced twice: a worker popping an expired
//! job refuses to burn a solve on it, and the connection thread gives up
//! waiting shortly after the deadline either way (`504`).
//!
//! Answers are BIT-IDENTICAL to direct [`PlanService::answer`] calls at
//! any worker count: the daemon adds routing and transport, never a
//! different solve path (`tests/serve_daemon.rs` asserts the bytes).
//!
//! Shutdown (SIGTERM/ctrl-c via [`ShutdownHandle`]): stop accepting,
//! let in-flight connections finish their current request, drain the
//! queue, then flush a metrics summary to stderr.

use super::http::{self, ChunkedWriter, Limits, Request};
use super::metrics::Metrics;
use super::queue::AdmissionQueue;
use crate::backend::DeviceProfile;
use crate::coordinator::Strategy;
use crate::metrics::Objective;
use crate::plan::service::{error_entry, indexed};
use crate::plan::{Frontier, PlanService, ServeRequest};
use crate::util::Json;
use anyhow::Result;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How often idle loops re-check the shutdown flag.
const POLL: Duration = Duration::from_millis(100);
const ACCEPT_POLL: Duration = Duration::from_millis(20);
/// Slack past a request's deadline before the connection stops waiting
/// for its reply: covers the reply-channel hop for a job that STARTED
/// just inside the deadline.
const REPLY_GRACE: Duration = Duration::from_millis(250);

/// Daemon tuning; `ampq serve --listen` maps its flags onto this.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    /// Maximum jobs queued ahead of the workers (admission bound).
    pub queue_depth: usize,
    /// Solver worker threads (the engine's exec budget by default).
    pub workers: usize,
    /// Frontier-cache entry cap installed on the service (0 = unbounded).
    pub cache_cap: usize,
    /// Per-request deadline from admission to reply.
    pub request_timeout: Duration,
    pub limits: Limits,
    /// Test hook: artificial per-job latency, so overflow and deadline
    /// tests are deterministic instead of racing real solve times.
    pub debug_delay: Duration,
    /// Record spans for every request (observation-only; answers are
    /// bit-identical either way).  On by default so `/v1/trace/:id` works
    /// out of the box; `--no-trace` turns it off.
    pub tracing: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8787".to_string(),
            queue_depth: 64,
            workers: 2,
            cache_cap: 32,
            request_timeout: Duration::from_secs(10),
            limits: Limits::default(),
            debug_delay: Duration::ZERO,
            tracing: true,
        }
    }
}

/// Flip-once switch shared by signal handlers, tests, and the daemon's
/// own loops.
#[derive(Clone)]
pub struct ShutdownHandle(Arc<AtomicBool>);

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_shutdown(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

enum JobKind {
    Answer(ServeRequest),
    Frontier {
        model: String,
        device: Option<String>,
        objective: Objective,
        strategy: Strategy,
    },
}

enum JobOutcome {
    Answer(Json),
    Frontier { frontier: Arc<Frontier>, device: String },
    Failed(String),
    TimedOut,
}

struct Job {
    kind: JobKind,
    index: usize,
    deadline: Instant,
    reply: mpsc::Sender<(usize, JobOutcome)>,
    /// Trace id of the request that submitted this job, so solver spans
    /// recorded on a worker thread stitch under the request's trace.
    trace: String,
}

pub struct Daemon {
    svc: PlanService,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
    devices: Json,
    shutdown: Arc<AtomicBool>,
}

impl Daemon {
    /// `devices` is the profile set advertised on `GET /v1/devices`
    /// (serialized once here — the registry itself is not `Clone`).
    pub fn new(svc: PlanService, devices: Vec<DeviceProfile>, cfg: ServeConfig) -> Daemon {
        if cfg.cache_cap > 0 {
            svc.set_cache_cap(cfg.cache_cap);
        }
        // Enable-only: never flip a process-wide ON back off from a
        // constructor (tests may run several daemons in one process).
        if cfg.tracing {
            crate::obs::set_enabled(true);
        }
        let devices = Json::Obj(vec![(
            "devices".to_string(),
            Json::Arr(devices.iter().map(|d| d.to_json()).collect()),
        )]);
        Daemon {
            svc,
            cfg,
            metrics: Arc::new(Metrics::new()),
            devices,
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn handle(&self) -> ShutdownHandle {
        ShutdownHandle(self.shutdown.clone())
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn service(&self) -> &PlanService {
        &self.svc
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Bind the configured listen address.
    pub fn bind(&self) -> Result<TcpListener> {
        Ok(TcpListener::bind(&self.cfg.addr)?)
    }

    /// Serve until the shutdown flag flips, then drain and return.
    pub fn run(&self, listener: TcpListener) -> Result<()> {
        listener.set_nonblocking(true)?;
        let queue: AdmissionQueue<Job> = AdmissionQueue::new(self.cfg.queue_depth);
        let conns = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..self.cfg.workers.max(1) {
                let q = &queue;
                s.spawn(move || self.worker_loop(q));
            }
            while !self.shutdown.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        conns.fetch_add(1, Ordering::SeqCst);
                        let q = &queue;
                        let c = &conns;
                        s.spawn(move || {
                            self.handle_conn(stream, q);
                            c.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(_) => std::thread::sleep(ACCEPT_POLL),
                }
            }
            // Graceful drain: no new connections (listener drops below),
            // in-flight connections finish their current request, then the
            // queue closes and the workers run it dry.
            drop(listener);
            let drain_deadline =
                Instant::now() + self.cfg.request_timeout + Duration::from_secs(2);
            while conns.load(Ordering::SeqCst) > 0 && Instant::now() < drain_deadline {
                std::thread::sleep(Duration::from_millis(10));
            }
            queue.close();
        });
        eprintln!(
            "ampq serve: shutdown after {} requests ({} queue rejections, {} timeouts); \
             {} frontier sweeps, {} cache hits",
            self.metrics.total_requests(),
            self.metrics.rejected(),
            self.metrics.timeouts(),
            self.svc.frontier_solves(),
            self.svc.frontier_hits(),
        );
        Ok(())
    }

    // ---- worker side -----------------------------------------------------

    fn worker_loop(&self, queue: &AdmissionQueue<Job>) {
        while let Some(job) = queue.pop() {
            self.run_job(job);
        }
    }

    fn run_job(&self, job: Job) {
        if !self.cfg.debug_delay.is_zero() {
            std::thread::sleep(self.cfg.debug_delay);
        }
        let outcome = if Instant::now() > job.deadline {
            // Expired while queued: don't burn a solve on it.  The
            // connection side owns the timeout metric.
            JobOutcome::TimedOut
        } else {
            // Re-install the submitting request's trace on THIS worker
            // thread, so solver spans nest under the request.
            crate::obs::with_trace(&job.trace, || {
                let mut sp = crate::obs::span("daemon.job");
                sp.counter("index", job.index as f64);
                let out = self.job_outcome(&job);
                drop(sp);
                out
            })
        };
        // A dropped receiver (peer gone, batch already timed out) is fine.
        let _ = job.reply.send((job.index, outcome));
    }

    fn job_outcome(&self, job: &Job) -> JobOutcome {
        let t0 = Instant::now();
        match &job.kind {
            JobKind::Answer(req) => match self.svc.answer(req) {
                Ok(j) => {
                    self.metrics.plan_latency.record(t0.elapsed().as_secs_f64() * 1e6);
                    JobOutcome::Answer(j)
                }
                Err(e) => JobOutcome::Failed(format!("{e:#}")),
            },
            JobKind::Frontier { model, device, objective, strategy } => {
                let solved = self
                    .svc
                    .planner_for(model, device.as_deref())
                    .map(|p| p.device().name.clone())
                    .and_then(|dev| {
                        self.svc
                            .frontier_for(model, device.as_deref(), *objective, *strategy)
                            .map(|f| (f, dev))
                    });
                match solved {
                    Ok((frontier, device)) => {
                        self.metrics
                            .frontier_latency
                            .record(t0.elapsed().as_secs_f64() * 1e6);
                        JobOutcome::Frontier { frontier, device }
                    }
                    Err(e) => JobOutcome::Failed(format!("{e:#}")),
                }
            }
        }
    }

    // ---- connection side -------------------------------------------------

    fn handle_conn(&self, mut stream: TcpStream, queue: &AdmissionQueue<Job>) {
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POLL)).ok();
        let shutdown = self.shutdown.clone();
        let stop = move || shutdown.load(Ordering::SeqCst);
        loop {
            let req = match http::read_request(&mut stream, &self.cfg.limits, &stop) {
                Ok(Some(r)) => r,
                Ok(None) => return,
                Err(e) => {
                    let status = e.status();
                    if status != 0 {
                        self.metrics.record_request("other", status);
                        let _ = http::respond(
                            &mut stream,
                            status,
                            "application/json",
                            error_body(&e.message()).as_bytes(),
                            false,
                            &[],
                        );
                    }
                    return;
                }
            };
            let keep = req.keep_alive && !stop();
            if self.route(&mut stream, &req, queue, keep).is_err() {
                return; // peer went away mid-response
            }
            if !keep {
                return;
            }
        }
    }

    /// Validate / stamp the request's trace id, install it on this thread,
    /// and dispatch.  An `x-ampq-trace` header is honored when valid (400
    /// when not); absent one, every request gets a fresh id — echoed back
    /// on the response either way (see `http::respond`), so a client can
    /// always come back with `GET /v1/trace/:id`.
    fn route(
        &self,
        stream: &mut TcpStream,
        req: &Request,
        queue: &AdmissionQueue<Job>,
        keep: bool,
    ) -> std::io::Result<()> {
        let trace = match req.header("x-ampq-trace") {
            Some(h) => match crate::obs::validate_trace_id(h) {
                Ok(()) => h.to_string(),
                Err(e) => {
                    return self.error(
                        stream,
                        endpoint_label(&req.path),
                        400,
                        &format!("invalid x-ampq-trace header: {e:#}"),
                        keep,
                        &[],
                    )
                }
            },
            None => crate::obs::fresh_trace_id(),
        };
        crate::obs::with_trace(&trace, || {
            let mut sp = crate::obs::span(&format!("daemon.{}", endpoint_label(&req.path)));
            sp.counter("body_bytes", req.body.len() as f64);
            let r = self.route_inner(stream, req, queue, keep);
            drop(sp);
            r
        })
    }

    fn route_inner(
        &self,
        stream: &mut TcpStream,
        req: &Request,
        queue: &AdmissionQueue<Job>,
        keep: bool,
    ) -> std::io::Result<()> {
        const KNOWN: [&str; 6] =
            ["/healthz", "/metrics", "/v1/models", "/v1/devices", "/v1/plan", "/v1/frontier"];
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                self.simple(stream, "/healthz", 200, "text/plain", b"ok\n", keep)
            }
            ("GET", "/metrics") => {
                // Content negotiation: Prometheus text by default, the
                // same counters as JSON on `Accept: application/json`.
                if req.header("accept").map_or(false, |a| a.contains("application/json")) {
                    let body = self.metrics.render_json(&self.metric_extras(queue));
                    self.simple(
                        stream,
                        "/metrics",
                        200,
                        "application/json",
                        body.to_string().as_bytes(),
                        keep,
                    )
                } else {
                    let text = self.render_metrics(queue);
                    self.simple(stream, "/metrics", 200, "text/plain", text.as_bytes(), keep)
                }
            }
            ("GET", path) if path.starts_with("/v1/trace/") => {
                let id = &path["/v1/trace/".len()..];
                if crate::obs::validate_trace_id(id).is_err() {
                    return self.error(stream, "/v1/trace", 400, "invalid trace id", keep, &[]);
                }
                match crate::obs::trace_tree(id) {
                    Some(tree) => self.simple(
                        stream,
                        "/v1/trace",
                        200,
                        "application/json",
                        tree.to_string().as_bytes(),
                        keep,
                    ),
                    None => self.error(
                        stream,
                        "/v1/trace",
                        404,
                        &format!("no spans recorded for trace '{id}'"),
                        keep,
                        &[],
                    ),
                }
            }
            (_, path) if path.starts_with("/v1/trace/") => self.error(
                stream,
                "/v1/trace",
                405,
                &format!("method {} not allowed on /v1/trace/:id", req.method),
                keep,
                &[],
            ),
            ("GET", "/v1/models") => {
                let body = Json::Obj(vec![(
                    "models".to_string(),
                    Json::Arr(self.svc.models().into_iter().map(Json::Str).collect()),
                )]);
                self.simple(
                    stream,
                    "/v1/models",
                    200,
                    "application/json",
                    body.to_string().as_bytes(),
                    keep,
                )
            }
            ("GET", "/v1/devices") => self.simple(
                stream,
                "/v1/devices",
                200,
                "application/json",
                self.devices.to_string().as_bytes(),
                keep,
            ),
            ("POST", "/v1/plan") => self.handle_plan(stream, req, queue, keep),
            ("POST", "/v1/frontier") => self.handle_frontier(stream, req, queue, keep),
            (_, path) if KNOWN.contains(&path) => self.error(
                stream,
                path,
                405,
                &format!("method {} not allowed on {path}", req.method),
                keep,
                &[],
            ),
            _ => self.error(stream, "other", 404, "no such endpoint", keep, &[]),
        }
    }

    fn simple(
        &self,
        stream: &mut TcpStream,
        endpoint: &str,
        status: u16,
        content_type: &str,
        body: &[u8],
        keep: bool,
    ) -> std::io::Result<()> {
        self.metrics.record_request(endpoint, status);
        http::respond(stream, status, content_type, body, keep, &[])
    }

    fn error(
        &self,
        stream: &mut TcpStream,
        endpoint: &str,
        status: u16,
        msg: &str,
        keep: bool,
        extra: &[(&str, &str)],
    ) -> std::io::Result<()> {
        self.metrics.record_request(endpoint, status);
        http::respond(stream, status, "application/json", error_body(msg).as_bytes(), keep, extra)
    }

    fn metric_extras(&self, queue: &AdmissionQueue<Job>) -> [(&'static str, f64); 5] {
        [
            ("frontier_cache_hits_total", self.svc.frontier_hits() as f64),
            ("frontier_cache_solves_total", self.svc.frontier_solves() as f64),
            ("frontier_cache_entries", self.svc.frontier_cache_len() as f64),
            ("queue_depth", queue.len() as f64),
            ("queue_capacity", queue.depth() as f64),
        ]
    }

    fn render_metrics(&self, queue: &AdmissionQueue<Job>) -> String {
        self.metrics.render(&self.metric_extras(queue))
    }

    // ---- /v1/plan --------------------------------------------------------

    fn handle_plan(
        &self,
        stream: &mut TcpStream,
        req: &Request,
        queue: &AdmissionQueue<Job>,
        keep: bool,
    ) -> std::io::Result<()> {
        let parsed = match parse_json_body(&req.body) {
            Ok(j) => j,
            Err(msg) => return self.error(stream, "/v1/plan", 400, &msg, keep, &[]),
        };
        match parsed {
            Json::Arr(entries) => self.plan_batch(stream, &entries, queue, keep),
            obj => self.plan_single(stream, &obj, queue, keep),
        }
    }

    fn plan_single(
        &self,
        stream: &mut TcpStream,
        obj: &Json,
        queue: &AdmissionQueue<Job>,
        keep: bool,
    ) -> std::io::Result<()> {
        let sreq = match ServeRequest::from_json(obj) {
            Ok(r) => r,
            Err(e) => return self.error(stream, "/v1/plan", 400, &format!("{e:#}"), keep, &[]),
        };
        let deadline = Instant::now() + self.cfg.request_timeout;
        let (tx, rx) = mpsc::channel();
        let job =
            Job { kind: JobKind::Answer(sreq), index: 0, deadline, reply: tx, trace: job_trace() };
        if queue.submit(job).is_err() {
            self.metrics.inc_rejected();
            return self.error(
                stream,
                "/v1/plan",
                503,
                "admission queue full",
                keep,
                &[("Retry-After", "1")],
            );
        }
        match rx.recv_timeout(until(deadline) + REPLY_GRACE) {
            Ok((_, JobOutcome::Answer(j))) => self.simple(
                stream,
                "/v1/plan",
                200,
                "application/json",
                j.to_string().as_bytes(),
                keep,
            ),
            Ok((_, JobOutcome::Failed(msg))) => {
                self.error(stream, "/v1/plan", 400, &msg, keep, &[])
            }
            Ok((_, JobOutcome::TimedOut)) | Err(_) => {
                self.metrics.inc_timeouts();
                self.error(stream, "/v1/plan", 504, "request deadline exceeded", keep, &[])
            }
            Ok((_, JobOutcome::Frontier { .. })) => {
                self.error(stream, "/v1/plan", 500, "internal: mismatched outcome", keep, &[])
            }
        }
    }

    /// Batch planning streams per-request progress: one NDJSON line per
    /// entry, emitted in request order as answers land, errors inline.
    fn plan_batch(
        &self,
        stream: &mut TcpStream,
        entries: &[Json],
        queue: &AdmissionQueue<Job>,
        keep: bool,
    ) -> std::io::Result<()> {
        let n = entries.len();
        let deadline = Instant::now() + self.cfg.request_timeout;
        let (tx, rx) = mpsc::channel();
        let mut done: std::collections::BTreeMap<usize, Json> = std::collections::BTreeMap::new();
        let mut jobs = Vec::new();
        for (i, e) in entries.iter().enumerate() {
            match ServeRequest::from_json(e) {
                Ok(r) => jobs.push(Job {
                    kind: JobKind::Answer(r),
                    index: i,
                    deadline,
                    reply: tx.clone(),
                    trace: job_trace(),
                }),
                Err(e) => {
                    done.insert(i, error_entry(i, &format!("{e:#}")));
                }
            }
        }
        drop(tx);
        if queue.submit_all(jobs).is_err() {
            self.metrics.inc_rejected();
            return self.error(
                stream,
                "/v1/plan",
                503,
                &format!("admission queue cannot take {n} more requests"),
                keep,
                &[("Retry-After", "1")],
            );
        }
        self.metrics.record_request("/v1/plan", 200);
        let mut w = ChunkedWriter::begin(stream, 200, "application/x-ndjson", keep)?;
        w.line(&batch_header(n).to_string())?;
        let mut errors = 0usize;
        let mut next = 0usize;
        while next < n {
            if let Some(line) = done.remove(&next) {
                if is_error_line(&line) {
                    errors += 1;
                }
                w.line(&line.to_string())?;
                next += 1;
                continue;
            }
            match rx.recv_timeout(until(deadline) + REPLY_GRACE) {
                Ok((i, outcome)) => {
                    done.insert(i, self.outcome_line(i, outcome));
                }
                Err(_) => {
                    // Batch deadline: every unanswered entry reports it.
                    self.metrics.inc_timeouts();
                    for i in next..n {
                        done.entry(i)
                            .or_insert_with(|| error_entry(i, "request deadline exceeded"));
                    }
                }
            }
        }
        w.line(&batch_footer(n, errors).to_string())?;
        w.finish()
    }

    fn outcome_line(&self, i: usize, outcome: JobOutcome) -> Json {
        match outcome {
            JobOutcome::Answer(j) => indexed(i, j),
            JobOutcome::Failed(msg) => error_entry(i, &msg),
            JobOutcome::TimedOut => {
                self.metrics.inc_timeouts();
                error_entry(i, "request deadline exceeded")
            }
            JobOutcome::Frontier { .. } => error_entry(i, "internal: mismatched outcome"),
        }
    }

    // ---- /v1/frontier ----------------------------------------------------

    fn handle_frontier(
        &self,
        stream: &mut TcpStream,
        req: &Request,
        queue: &AdmissionQueue<Job>,
        keep: bool,
    ) -> std::io::Result<()> {
        let parsed = match parse_json_body(&req.body) {
            Ok(j) => j,
            Err(msg) => return self.error(stream, "/v1/frontier", 400, &msg, keep, &[]),
        };
        let (entries, batch) = match parsed {
            Json::Arr(v) => (v, true),
            obj => (vec![obj], false),
        };
        let n = entries.len();
        let deadline = Instant::now() + self.cfg.request_timeout;
        let (tx, rx) = mpsc::channel();
        let mut done: std::collections::BTreeMap<usize, Result<JobOutcome, String>> =
            std::collections::BTreeMap::new();
        let mut jobs = Vec::new();
        for (i, e) in entries.iter().enumerate() {
            match parse_frontier_query(e) {
                Ok(kind) => jobs.push(Job {
                    kind,
                    index: i,
                    deadline,
                    reply: tx.clone(),
                    trace: job_trace(),
                }),
                Err(msg) if batch => {
                    done.insert(i, Err(msg));
                }
                Err(msg) => {
                    return self.error(stream, "/v1/frontier", 400, &msg, keep, &[]);
                }
            }
        }
        drop(tx);
        if queue.submit_all(jobs).is_err() {
            self.metrics.inc_rejected();
            return self.error(
                stream,
                "/v1/frontier",
                503,
                "admission queue full",
                keep,
                &[("Retry-After", "1")],
            );
        }
        if !batch {
            // Single query: wait for the sweep, then stream its knots.
            return match rx.recv_timeout(until(deadline) + REPLY_GRACE) {
                Ok((_, JobOutcome::Frontier { frontier, device })) => {
                    self.metrics.record_request("/v1/frontier", 200);
                    let mut w =
                        ChunkedWriter::begin(stream, 200, "application/x-ndjson", keep)?;
                    stream_frontier(&mut w, &frontier, &device, None)?;
                    w.finish()
                }
                Ok((_, JobOutcome::Failed(msg))) => {
                    self.error(stream, "/v1/frontier", 400, &msg, keep, &[])
                }
                Ok((_, JobOutcome::TimedOut)) | Err(_) => {
                    self.metrics.inc_timeouts();
                    self.error(
                        stream,
                        "/v1/frontier",
                        504,
                        "request deadline exceeded",
                        keep,
                        &[],
                    )
                }
                Ok((_, JobOutcome::Answer(_))) => self.error(
                    stream,
                    "/v1/frontier",
                    500,
                    "internal: mismatched outcome",
                    keep,
                    &[],
                ),
            };
        }
        self.metrics.record_request("/v1/frontier", 200);
        let mut w = ChunkedWriter::begin(stream, 200, "application/x-ndjson", keep)?;
        w.line(&batch_header(n).to_string())?;
        let mut errors = 0usize;
        let mut next = 0usize;
        while next < n {
            if let Some(r) = done.remove(&next) {
                match r {
                    Ok(JobOutcome::Frontier { frontier, device }) => {
                        stream_frontier(&mut w, &frontier, &device, Some(next))?;
                    }
                    Ok(JobOutcome::TimedOut) => {
                        self.metrics.inc_timeouts();
                        errors += 1;
                        w.line(&error_entry(next, "request deadline exceeded").to_string())?;
                    }
                    Ok(JobOutcome::Failed(msg)) | Err(msg) => {
                        errors += 1;
                        w.line(&error_entry(next, &msg).to_string())?;
                    }
                    Ok(JobOutcome::Answer(_)) => {
                        errors += 1;
                        w.line(&error_entry(next, "internal: mismatched outcome").to_string())?;
                    }
                }
                next += 1;
                continue;
            }
            match rx.recv_timeout(until(deadline) + REPLY_GRACE) {
                Ok((i, outcome)) => {
                    done.insert(i, Ok(outcome));
                }
                Err(_) => {
                    self.metrics.inc_timeouts();
                    for i in next..n {
                        done.entry(i).or_insert_with(|| {
                            Err("request deadline exceeded".to_string())
                        });
                    }
                }
            }
        }
        w.line(&batch_footer(n, errors).to_string())?;
        w.finish()
    }
}

// ---- free helpers --------------------------------------------------------

/// Metrics/span label of a request path: the known endpoints by name,
/// `/v1/trace/:id` collapsed to one label, everything else "other" (so a
/// scanner cannot grow the metrics map or span names without bound).
fn endpoint_label(path: &str) -> &'static str {
    match path {
        "/healthz" => "/healthz",
        "/metrics" => "/metrics",
        "/v1/models" => "/v1/models",
        "/v1/devices" => "/v1/devices",
        "/v1/plan" => "/v1/plan",
        "/v1/frontier" => "/v1/frontier",
        p if p.starts_with("/v1/trace/") => "/v1/trace",
        _ => "other",
    }
}

/// Trace id jobs inherit from the submitting request's thread context.
fn job_trace() -> String {
    crate::obs::current_trace().unwrap_or_else(|| crate::obs::LOCAL_TRACE.to_string())
}

fn until(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now())
}

fn error_body(msg: &str) -> String {
    Json::Obj(vec![
        ("kind".to_string(), Json::Str("error".to_string())),
        ("error".to_string(), Json::Str(msg.to_string())),
    ])
    .to_string()
}

fn batch_header(n: usize) -> Json {
    Json::Obj(vec![
        ("kind".to_string(), Json::Str("batch".to_string())),
        ("n".to_string(), Json::Num(n as f64)),
    ])
}

fn batch_footer(n: usize, errors: usize) -> Json {
    Json::Obj(vec![
        ("kind".to_string(), Json::Str("done".to_string())),
        ("n".to_string(), Json::Num(n as f64)),
        ("errors".to_string(), Json::Num(errors as f64)),
    ])
}

fn is_error_line(j: &Json) -> bool {
    j.opt("kind").and_then(|k| k.str().ok()) == Some("error")
}

fn parse_json_body(body: &[u8]) -> Result<Json, String> {
    let text = std::str::from_utf8(body).map_err(|_| "non-utf8 body".to_string())?;
    Json::parse(text).map_err(|e| format!("bad json body: {e:#}"))
}

/// Frontier query schema: `{"model": M, "objective"?: K, "strategy"?: K,
/// "device"?: D}` — objective/strategy default to the IP empirical-time
/// curve, like the CLI.
fn parse_frontier_query(e: &Json) -> Result<JobKind, String> {
    let model = e
        .get("model")
        .and_then(|m| m.str())
        .map_err(|e| format!("{e:#}"))?
        .to_string();
    let objective = match e.opt("objective") {
        None => Objective::EmpiricalTime,
        Some(o) => {
            let key = o.str().map_err(|e| format!("'objective': {e:#}"))?;
            Objective::from_key(key).ok_or_else(|| format!("unknown objective '{key}'"))?
        }
    };
    let strategy = match e.opt("strategy") {
        None => Strategy::Ip,
        Some(s) => {
            let key = s.str().map_err(|e| format!("'strategy': {e:#}"))?;
            Strategy::from_key(key).ok_or_else(|| format!("unknown strategy '{key}'"))?
        }
    };
    let device = match e.opt("device") {
        None => None,
        Some(d) => Some(d.str().map_err(|e| format!("'device': {e:#}"))?.to_string()),
    };
    Ok(JobKind::Frontier { model, device, objective, strategy })
}

/// Stream one frontier as NDJSON: a header, one line per knot (in the
/// DP's materialization order — ascending tau), and a footer.  `index`
/// stamps batch entries so interleaved consumers can attribute lines.
fn stream_frontier(
    w: &mut ChunkedWriter,
    f: &Frontier,
    device: &str,
    index: Option<usize>,
) -> std::io::Result<()> {
    let stamp = |mut kv: Vec<(String, Json)>| -> Json {
        if let Some(i) = index {
            kv.insert(1, ("index".to_string(), Json::Num(i as f64)));
        }
        Json::Obj(kv)
    };
    w.line(
        &stamp(vec![
            ("kind".to_string(), Json::Str("frontier_header".to_string())),
            ("model".to_string(), Json::Str(f.model.clone())),
            ("device".to_string(), Json::Str(device.to_string())),
            ("objective".to_string(), Json::Str(f.objective.key().to_string())),
            ("strategy".to_string(), Json::Str(f.strategy.key().to_string())),
            ("eg2".to_string(), Json::Num(f.eg2)),
            ("tau_max".to_string(), Json::Num(f.tau_max)),
            ("points".to_string(), Json::Num(f.points.len() as f64)),
        ])
        .to_string(),
    )?;
    for (k, p) in f.points.iter().enumerate() {
        w.line(
            &stamp(vec![
                ("kind".to_string(), Json::Str("knot".to_string())),
                ("i".to_string(), Json::Num(k as f64)),
                ("tau".to_string(), Json::Num(p.tau)),
                ("predicted_mse".to_string(), Json::Num(p.predicted_mse)),
                ("gain".to_string(), Json::Num(p.gain)),
                ("config".to_string(), crate::plan::artifact::formats_to_json(&p.config.0)),
            ])
            .to_string(),
        )?;
    }
    w.line(
        &stamp(vec![
            ("kind".to_string(), Json::Str("frontier_done".to_string())),
            ("points".to_string(), Json::Num(f.points.len() as f64)),
        ])
        .to_string(),
    )
}
