//! Minimal HTTP/1.1 server plumbing on std `TcpStream` (hyper is not
//! vendored in this image).
//!
//! Just enough of RFC 9112 for the planning daemon and its test client:
//! request-line + header parsing with hard size/time limits,
//! `Content-Length` bodies, keep-alive, `Expect: 100-continue`, and
//! chunked *responses* (the NDJSON streaming endpoints).  Chunked request
//! bodies are rejected — every client we control sends a length.
//!
//! Reads poll: the stream carries a short read timeout and
//! [`read_request`] re-checks a caller-supplied stop flag between idle
//! reads, so keep-alive connections notice a daemon shutdown within one
//! poll interval instead of holding the drain hostage.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Hard limits on one request (and how long a started one may dribble in).
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    pub max_header_bytes: usize,
    pub max_body_bytes: usize,
    /// Deadline from the first byte of a request to its last.
    pub read_timeout: std::time::Duration,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_header_bytes: 16 * 1024,
            max_body_bytes: 1024 * 1024,
            read_timeout: std::time::Duration::from_secs(5),
        }
    }
}

/// One parsed request.  Header names are lowercased; values are trimmed.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Option<String>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection may serve another request after this one.
    pub keep_alive: bool,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read.  Each maps to one response status;
/// after any of these the connection closes (framing is unreliable).
#[derive(Debug)]
pub enum HttpError {
    /// 400 — malformed request line, header, or truncated body.
    BadRequest(String),
    /// 413 — headers or declared body over the limits.
    TooLarge(String),
    /// 408 — a started request did not finish within the read deadline.
    Timeout,
    /// Transport died; nothing can be written back.
    Io(std::io::Error),
}

impl HttpError {
    pub fn status(&self) -> u16 {
        match self {
            HttpError::BadRequest(_) => 400,
            HttpError::TooLarge(_) => 413,
            HttpError::Timeout => 408,
            HttpError::Io(_) => 0,
        }
    }

    pub fn message(&self) -> String {
        match self {
            HttpError::BadRequest(m) => m.clone(),
            HttpError::TooLarge(m) => m.clone(),
            HttpError::Timeout => "request read deadline exceeded".to_string(),
            HttpError::Io(e) => format!("io: {e}"),
        }
    }
}

fn is_poll_timeout(e: &std::io::Error) -> bool {
    // Read timeouts surface as WouldBlock on unix and TimedOut on windows.
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read one request.  `Ok(None)` means the peer closed between requests
/// or `stop` went true while the connection was idle — either way the
/// connection is done cleanly.  The stream must carry a short read
/// timeout (that is the stop-flag poll interval).
pub fn read_request(
    stream: &mut TcpStream,
    limits: &Limits,
    stop: &dyn Fn() -> bool,
) -> Result<Option<Request>, HttpError> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut started: Option<Instant> = None;
    // ---- header section --------------------------------------------------
    let header_end = loop {
        if let Some(end) = find_header_end(&buf) {
            break end;
        }
        if buf.len() > limits.max_header_bytes {
            return Err(HttpError::TooLarge(format!(
                "header section over {} bytes",
                limits.max_header_bytes
            )));
        }
        if let Some(t0) = started {
            if t0.elapsed() > limits.read_timeout {
                return Err(HttpError::Timeout);
            }
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                return if buf.is_empty() {
                    Ok(None)
                } else {
                    Err(HttpError::BadRequest("connection closed mid-header".into()))
                };
            }
            Ok(n) => {
                if started.is_none() {
                    started = Some(Instant::now());
                }
                buf.extend_from_slice(&tmp[..n]);
            }
            Err(e) if is_poll_timeout(&e) => {
                if buf.is_empty() {
                    if stop() {
                        return Ok(None);
                    }
                    continue;
                }
                // Mid-request: keep reading until the per-request deadline.
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    };

    let head = std::str::from_utf8(&buf[..header_end])
        .map_err(|_| HttpError::BadRequest("non-utf8 header section".into()))?;
    let mut lines = head.split("\r\n");
    let request_line =
        lines.next().ok_or_else(|| HttpError::BadRequest("empty request".into()))?;
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::BadRequest("missing method".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpError::BadRequest("missing request target".into()))?;
    let version = parts
        .next()
        .ok_or_else(|| HttpError::BadRequest("missing HTTP version".into()))?;
    if !matches!(version, "HTTP/1.1" | "HTTP/1.0") {
        return Err(HttpError::BadRequest(format!("unsupported version '{version}'")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| HttpError::BadRequest(format!("malformed header '{line}'")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let header = |name: &str| -> Option<&str> {
        headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    };

    if header("transfer-encoding").is_some() {
        return Err(HttpError::BadRequest("chunked request bodies not supported".into()));
    }
    let keep_alive = match header("connection").map(str::to_ascii_lowercase) {
        Some(c) if c.contains("close") => false,
        Some(c) if c.contains("keep-alive") => true,
        _ => version == "HTTP/1.1",
    };

    // ---- body ------------------------------------------------------------
    let content_length: usize = match header("content-length") {
        None => 0,
        Some(v) => v
            .parse()
            .map_err(|_| HttpError::BadRequest(format!("bad content-length '{v}'")))?,
    };
    if content_length > limits.max_body_bytes {
        return Err(HttpError::TooLarge(format!(
            "declared body of {content_length} bytes over the {} limit",
            limits.max_body_bytes
        )));
    }
    if content_length > 0 && header("expect").map(str::to_ascii_lowercase).as_deref()
        == Some("100-continue")
    {
        stream
            .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
            .map_err(HttpError::Io)?;
    }
    let mut body = buf[header_end..].to_vec();
    let t0 = started.unwrap_or_else(Instant::now);
    while body.len() < content_length {
        if t0.elapsed() > limits.read_timeout {
            return Err(HttpError::Timeout);
        }
        match stream.read(&mut tmp) {
            Ok(0) => return Err(HttpError::BadRequest("connection closed mid-body".into())),
            Ok(n) => body.extend_from_slice(&tmp[..n]),
            Err(e) if is_poll_timeout(&e) => continue,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
    body.truncate(content_length); // drop any pipelined spill-over
    Ok(Some(Request { method, path, query, headers, body, keep_alive }))
}

/// Byte offset just past the `\r\n\r\n` terminator, if present.
fn find_header_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|i| i + 4)
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one fixed-length response.  `extra` appends verbatim headers
/// (e.g. `Retry-After` on a 503).
pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra: &[(&str, &str)],
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    // Echo the request's trace id (the daemon installs it on this thread
    // before routing), so a client can fetch `GET /v1/trace/:id` without
    // having stamped its own header.
    if let Some(t) = crate::obs::current_trace() {
        head.push_str(&format!("x-ampq-trace: {t}\r\n"));
    }
    for (k, v) in extra {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// A chunked-transfer response in progress: one chunk per NDJSON line, a
/// zero chunk on [`ChunkedWriter::finish`].
pub struct ChunkedWriter<'a> {
    stream: &'a mut TcpStream,
}

impl<'a> ChunkedWriter<'a> {
    pub fn begin(
        stream: &'a mut TcpStream,
        status: u16,
        content_type: &str,
        keep_alive: bool,
    ) -> std::io::Result<ChunkedWriter<'a>> {
        let mut head = format!(
            "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nConnection: {}\r\n",
            reason(status),
            if keep_alive { "keep-alive" } else { "close" },
        );
        if let Some(t) = crate::obs::current_trace() {
            head.push_str(&format!("x-ampq-trace: {t}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        Ok(ChunkedWriter { stream })
    }

    /// Write one line (a trailing `\n` is appended) as one chunk, flushed
    /// immediately so clients see knots as they materialize.
    pub fn line(&mut self, s: &str) -> std::io::Result<()> {
        let mut chunk = format!("{:x}\r\n", s.len() + 1).into_bytes();
        chunk.extend_from_slice(s.as_bytes());
        chunk.extend_from_slice(b"\n\r\n");
        self.stream.write_all(&chunk)?;
        self.stream.flush()
    }

    pub fn finish(self) -> std::io::Result<()> {
        self.stream.write_all(b"0\r\n\r\n")?;
        self.stream.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    /// A connected (client, server) socket pair on the loopback.
    fn pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        (client, server)
    }

    fn never() -> bool {
        false
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let (mut client, mut server) = pair();
        client
            .write_all(
                b"POST /v1/plan?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 4\r\n\r\nabcd",
            )
            .unwrap();
        let r = read_request(&mut server, &Limits::default(), &never).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/plan");
        assert_eq!(r.query.as_deref(), Some("x=1"));
        assert_eq!(r.body, b"abcd");
        assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(r.header("host"), Some("a"));
    }

    #[test]
    fn keep_alive_serves_sequential_requests() {
        // One request at a time (no pipelining — spill-over past a request
        // is dropped by design), same connection for both.
        let (mut client, mut server) = pair();
        client.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        let a = read_request(&mut server, &Limits::default(), &never).unwrap().unwrap();
        assert_eq!(a.path, "/healthz");
        assert!(a.keep_alive);
        client
            .write_all(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap();
        let b = read_request(&mut server, &Limits::default(), &never).unwrap().unwrap();
        assert_eq!(b.path, "/metrics");
        assert!(!b.keep_alive);
    }

    #[test]
    fn clean_eof_is_none() {
        let (client, mut server) = pair();
        drop(client);
        assert!(read_request(&mut server, &Limits::default(), &never).unwrap().is_none());
    }

    #[test]
    fn idle_stop_flag_is_none() {
        let (_client, mut server) = pair();
        assert!(read_request(&mut server, &Limits::default(), &|| true).unwrap().is_none());
    }

    #[test]
    fn oversized_declared_body_is_413() {
        let (mut client, mut server) = pair();
        client
            .write_all(b"POST /v1/plan HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n")
            .unwrap();
        let e = read_request(&mut server, &Limits::default(), &never).unwrap_err();
        assert_eq!(e.status(), 413);
    }

    #[test]
    fn oversized_headers_are_413() {
        let (mut client, mut server) = pair();
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        req.extend_from_slice(format!("X-Pad: {}\r\n", "y".repeat(64 * 1024)).as_bytes());
        client.write_all(&req).unwrap();
        let e = read_request(&mut server, &Limits::default(), &never).unwrap_err();
        assert_eq!(e.status(), 413);
    }

    #[test]
    fn malformed_request_line_is_400() {
        let (mut client, mut server) = pair();
        client.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        let e = read_request(&mut server, &Limits::default(), &never).unwrap_err();
        assert_eq!(e.status(), 400);
    }

    #[test]
    fn truncated_body_times_out() {
        let (mut client, mut server) = pair();
        client
            .write_all(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc")
            .unwrap();
        let limits = Limits {
            read_timeout: Duration::from_millis(60),
            ..Limits::default()
        };
        let e = read_request(&mut server, &limits, &never).unwrap_err();
        assert_eq!(e.status(), 408);
    }

    #[test]
    fn respond_and_chunked_roundtrip() {
        let (mut client, mut server) = pair();
        respond(&mut server, 200, "text/plain", b"ok\n", false, &[("Retry-After", "1")])
            .unwrap();
        {
            let mut w =
                ChunkedWriter::begin(&mut server, 200, "application/x-ndjson", false).unwrap();
            w.line("{\"a\":1}").unwrap();
            w.line("{\"b\":2}").unwrap();
            w.finish().unwrap();
        }
        drop(server);
        let mut all = String::new();
        client.read_to_string(&mut all).unwrap();
        assert!(all.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(all.contains("Retry-After: 1\r\n"));
        assert!(all.contains("ok\n"));
        assert!(all.contains("Transfer-Encoding: chunked"));
        assert!(all.contains("{\"a\":1}\n"));
        assert!(all.contains("0\r\n\r\n"));
    }
}
