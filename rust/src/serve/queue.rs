//! Bounded admission queue between connection handlers and the solver
//! worker pool.
//!
//! Admission is all-or-nothing per submission: a batch either fits under
//! the configured depth in one shot or is rejected whole (the HTTP layer
//! turns a rejection into `503` + `Retry-After`), so a burst can never
//! deadlock half-admitted.  Items are handed back on rejection — nothing
//! is silently dropped.  [`AdmissionQueue::close`] wakes every blocked
//! worker; `pop` then drains what was already admitted before reporting
//! end-of-queue, which is exactly the graceful-shutdown drain.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct State<T> {
    q: VecDeque<T>,
    closed: bool,
}

pub struct AdmissionQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
    depth: usize,
}

impl<T> AdmissionQueue<T> {
    /// `depth` is the maximum number of queued (not yet popped) items.
    pub fn new(depth: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: Mutex::new(State { q: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            depth: depth.max(1),
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect("admission queue lock poisoned").q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit one item, or hand it back if the queue is full or closed.
    pub fn submit(&self, item: T) -> Result<(), T> {
        match self.submit_all(vec![item]) {
            Ok(()) => Ok(()),
            // lint: allow(D4) submit_all hands back exactly the rejected batch; popping a 1-element batch cannot fail
            Err(mut items) => Err(items.pop().expect("rejected batch returns its items")),
        }
    }

    /// Admit `items` atomically: all of them or none (handed back).
    pub fn submit_all(&self, items: Vec<T>) -> Result<(), Vec<T>> {
        if items.is_empty() {
            return Ok(());
        }
        let mut s = self.state.lock().expect("admission queue lock poisoned");
        if s.closed || s.q.len() + items.len() > self.depth {
            return Err(items);
        }
        let n = items.len();
        s.q.extend(items);
        drop(s);
        if n == 1 {
            self.ready.notify_one();
        } else {
            self.ready.notify_all();
        }
        Ok(())
    }

    /// Next admitted item; blocks while the queue is open and empty.
    /// `None` means closed AND drained — the worker should exit.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().expect("admission queue lock poisoned");
        loop {
            if let Some(item) = s.q.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).expect("admission queue lock poisoned");
        }
    }

    /// Stop admitting; wake every blocked worker.  Already-admitted items
    /// still drain through `pop`.
    pub fn close(&self) {
        self.state.lock().expect("admission queue lock poisoned").closed = true;
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn overflow_hands_items_back() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(2);
        assert!(q.submit(1).is_ok());
        assert!(q.submit(2).is_ok());
        assert_eq!(q.submit(3), Err(3));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.submit(3).is_ok(), "popping frees a slot");
    }

    #[test]
    fn batch_admission_is_all_or_nothing() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(3);
        assert!(q.submit(0).is_ok());
        // 3 more would need 4 slots: rejected whole, queue untouched.
        assert_eq!(q.submit_all(vec![1, 2, 3]), Err(vec![1, 2, 3]));
        assert_eq!(q.len(), 1);
        assert!(q.submit_all(vec![1, 2]).is_ok());
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn close_drains_then_ends() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(4);
        q.submit(7).unwrap();
        q.submit(8).unwrap();
        q.close();
        assert_eq!(q.submit(9), Err(9), "closed queue admits nothing");
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), Some(8));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocked_workers_wake_on_close() {
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(1));
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || q.pop())
            })
            .collect();
        q.submit(42).unwrap();
        q.close();
        let mut got: Vec<Option<u32>> =
            workers.into_iter().map(|w| w.join().unwrap()).collect();
        got.sort();
        assert_eq!(got, vec![None, None, Some(42)]);
    }
}
