//! Minimal HTTP/1.1 client for the planning daemon: keep-alive requests,
//! `Content-Length` and chunked response bodies.  Drives
//! `tests/serve_daemon.rs` and the `ampq_client` smoke binary — NOT a
//! general-purpose client (no TLS, no redirects, no request streaming).

use anyhow::{anyhow, bail, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// One parsed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    /// Lowercased names, trimmed values.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    pub fn text(&self) -> Result<String> {
        String::from_utf8(self.body.clone()).map_err(|_| anyhow!("non-utf8 response body"))
    }

    /// Non-empty NDJSON lines of the body.
    pub fn lines(&self) -> Result<Vec<String>> {
        Ok(self
            .text()?
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(str::to_string)
            .collect())
    }
}

/// One keep-alive connection to the daemon.
pub struct Client {
    r: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Self::connect_with_timeout(addr, Duration::from_secs(30))
    }

    pub fn connect_with_timeout(addr: &str, timeout: Duration) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        stream.set_nodelay(true).ok();
        Ok(Client { r: BufReader::new(stream) })
    }

    /// Issue one request and read the full response (chunked bodies are
    /// decoded).  The connection stays usable for the next request as
    /// long as the server kept it alive.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<Response> {
        self.request_with_headers(method, path, body, &[])
    }

    /// [`Client::request`] with extra request headers (e.g.
    /// `("x-ampq-trace", id)` to stitch this request into a trace).
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        headers: &[(&str, &str)],
    ) -> Result<Response> {
        let payload = body.unwrap_or("");
        let mut head = format!(
            "{method} {path} HTTP/1.1\r\nHost: ampq\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
            payload.len()
        );
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        let stream = self.r.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(payload.as_bytes())?;
        stream.flush()?;
        self.read_response()
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = Vec::new();
        self.r.read_until(b'\n', &mut line)?;
        if line.is_empty() {
            bail!("connection closed mid-response");
        }
        while matches!(line.last(), Some(b'\n') | Some(b'\r')) {
            line.pop();
        }
        String::from_utf8(line).map_err(|_| anyhow!("non-utf8 response line"))
    }

    fn read_response(&mut self) -> Result<Response> {
        let status_line = self.read_line()?;
        let mut parts = status_line.split(' ');
        let version = parts.next().unwrap_or("");
        if !version.starts_with("HTTP/1.") {
            bail!("bad status line '{status_line}'");
        }
        let status: u16 = parts
            .next()
            .ok_or_else(|| anyhow!("bad status line '{status_line}'"))?
            .parse()
            .map_err(|_| anyhow!("bad status in '{status_line}'"))?;
        let mut headers: Vec<(String, String)> = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
            }
        }
        // An interim 100 Continue is followed by the real response.
        if status == 100 {
            return self.read_response();
        }
        let header = |name: &str| -> Option<&str> {
            headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
        };
        let body = if header("transfer-encoding")
            .map(|v| v.to_ascii_lowercase().contains("chunked"))
            .unwrap_or(false)
        {
            self.read_chunked()?
        } else {
            let n: usize = match header("content-length") {
                Some(v) => v.parse().map_err(|_| anyhow!("bad content-length '{v}'"))?,
                None => 0,
            };
            let mut body = vec![0u8; n];
            self.r.read_exact(&mut body)?;
            body
        };
        Ok(Response { status, headers, body })
    }

    fn read_chunked(&mut self) -> Result<Vec<u8>> {
        let mut body = Vec::new();
        loop {
            let size_line = self.read_line()?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| anyhow!("bad chunk size '{size_line}'"))?;
            if size == 0 {
                // Trailer section: blank line terminates.
                loop {
                    if self.read_line()?.is_empty() {
                        break;
                    }
                }
                return Ok(body);
            }
            let start = body.len();
            body.resize(start + size, 0);
            self.r.read_exact(&mut body[start..])?;
            let mut crlf = [0u8; 2];
            self.r.read_exact(&mut crlf)?;
            if &crlf != b"\r\n" {
                bail!("chunk not terminated by CRLF");
            }
        }
    }
}

/// One-shot convenience: connect, request, disconnect.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<Response> {
    Client::connect(addr)?.request(method, path, body)
}

/// One-shot convenience with extra request headers.
pub fn request_with_headers(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    headers: &[(&str, &str)],
) -> Result<Response> {
    Client::connect(addr)?.request_with_headers(method, path, body, headers)
}

/// Retry policy for [`request_with_retry`]: a 503 carrying `Retry-After`
/// earns up to `budget` additional attempts, each waiting the server's
/// hint clamped to `max_wait`.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Additional attempts after the first (0 = never retry).
    pub budget: usize,
    /// Cap on one server-hinted wait (defends against absurd hints).
    pub max_wait: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { budget: 3, max_wait: Duration::from_secs(2) }
    }
}

/// Final response of a retried request plus how many attempts it took
/// (1 = answered first try).
#[derive(Clone, Debug)]
pub struct RetriedResponse {
    pub response: Response,
    pub attempts: usize,
}

/// One-shot request honoring `Retry-After` on 503 under a capped retry
/// budget.  Reconnects per attempt (the daemon may close a rejected
/// connection).  Returns immediately on anything other than a 503 that
/// carries the header — success, other statuses, a hint-less 503 — and
/// propagates transport errors; an exhausted budget returns the last 503.
pub fn request_with_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    policy: RetryPolicy,
) -> Result<RetriedResponse> {
    request_with_retry_headers(addr, method, path, body, &[], policy)
}

/// [`request_with_retry`] with extra request headers on every attempt.
pub fn request_with_retry_headers(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    headers: &[(&str, &str)],
    policy: RetryPolicy,
) -> Result<RetriedResponse> {
    let mut attempts = 0usize;
    loop {
        attempts += 1;
        let response = request_with_headers(addr, method, path, body, headers)?;
        if response.status != 503 || attempts > policy.budget {
            return Ok(RetriedResponse { response, attempts });
        }
        let Some(hint) = response.header("retry-after") else {
            return Ok(RetriedResponse { response, attempts });
        };
        // The header is integer seconds (RFC 9110); a malformed value
        // retries immediately rather than failing the request.
        let secs = hint.trim().parse::<f64>().unwrap_or(0.0).max(0.0);
        let wait = secs.min(policy.max_wait.as_secs_f64());
        std::thread::sleep(Duration::from_secs_f64(wait));
    }
}
