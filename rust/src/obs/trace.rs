//! The tracer core: spans, the thread-local trace context, the
//! process-wide lock-sharded span registry, and cross-process span
//! shipping (capture / adopt) for the dist worker fleet.
//!
//! Design constraints (the reason this module looks the way it does):
//!
//! * **Observation-only.**  Nothing here is ever read back by planning
//!   or solving code.  Spans flow one way — from [`SpanGuard::drop`]
//!   into a ring buffer — and the only shared mutable state is a set of
//!   monotonically increasing atomics.  Disabled tracing costs one
//!   relaxed atomic load per would-be span.
//! * **No allocation-order dependence.**  Span ids come from one global
//!   counter, so their VALUES depend on thread interleaving — which is
//!   why no computation may branch on them, and why deterministic
//!   consumers (exports, the `/v1/trace/:id` tree) sort by
//!   `(start_us, id)` and never by id alone across traces.
//! * **Bounded memory.**  Each of the [`N_SHARDS`] rings holds at most
//!   [`SHARD_CAP`] spans; a full ring overwrites its oldest entry.  A
//!   resident daemon can trace forever without growing.

use crate::util::Json;
use anyhow::{bail, Result};
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Maximum accepted `x-ampq-trace` header / trace-id length.
pub const MAX_TRACE_ID_LEN: usize = 64;

/// Ring shards; a thread writes to `tid % N_SHARDS`, so unrelated
/// threads rarely contend on one lock.
const N_SHARDS: usize = 16;

/// Spans retained per shard before the ring overwrites its oldest.
const SHARD_CAP: usize = 4096;

/// The trace id used for spans recorded outside any installed context
/// (CLI runs with `--trace FILE`, library use without a daemon).
pub const LOCAL_TRACE: &str = "local";

/// One completed span: a named, timed slice of work with introspection
/// counters attached.  `parent == 0` marks a root.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub trace: String,
    pub id: u64,
    pub parent: u64,
    pub name: String,
    /// Microseconds since this process's tracer epoch (monotonic clock).
    pub start_us: u64,
    pub dur_us: u64,
    /// Recording process (worker spans keep theirs after [`adopt`]).
    pub pid: u64,
    /// Tracer-assigned thread lane (small, stable per thread).
    pub tid: u64,
    /// Introspection counters, in recording order.
    pub counters: Vec<(String, f64)>,
}

impl Span {
    /// Wire encoding (worker -> coordinator span shipping).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("trace".into(), Json::Str(self.trace.clone())),
            ("id".into(), Json::Num(self.id as f64)),
            ("parent".into(), Json::Num(self.parent as f64)),
            ("name".into(), Json::Str(self.name.clone())),
            ("start_us".into(), Json::Num(self.start_us as f64)),
            ("dur_us".into(), Json::Num(self.dur_us as f64)),
            ("pid".into(), Json::Num(self.pid as f64)),
            ("tid".into(), Json::Num(self.tid as f64)),
            (
                "counters".into(),
                Json::Obj(
                    self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Span> {
        let counters = match j.opt("counters") {
            Some(Json::Obj(kv)) => kv
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.f64()?)))
                .collect::<Result<Vec<_>>>()?,
            Some(other) => bail!("span counters must be an object, got {other:?}"),
            None => Vec::new(),
        };
        Ok(Span {
            trace: j.get("trace")?.str()?.to_string(),
            id: j.get("id")?.f64()? as u64,
            parent: j.get("parent")?.f64()? as u64,
            name: j.get("name")?.str()?.to_string(),
            start_us: j.get("start_us")?.f64()? as u64,
            dur_us: j.get("dur_us")?.f64()? as u64,
            pid: j.get("pid")?.f64()? as u64,
            tid: j.get("tid")?.f64()? as u64,
            counters,
        })
    }
}

/// Fixed-capacity overwrite-oldest span buffer.
struct Ring {
    buf: Vec<Span>,
    /// Overwrite cursor once `buf` is full.
    next: usize,
    dropped: u64,
}

impl Ring {
    const fn new() -> Ring {
        Ring { buf: Vec::new(), next: 0, dropped: 0 }
    }

    fn push(&mut self, s: Span) {
        if self.buf.len() < SHARD_CAP {
            self.buf.push(s);
        } else {
            self.buf[self.next] = s;
            self.next = (self.next + 1) % SHARD_CAP;
            self.dropped += 1;
        }
    }
}

struct Registry {
    enabled: AtomicBool,
    shards: Vec<Mutex<Ring>>,
    /// Span ids start at 1; 0 is the "no parent" sentinel.
    next_span: AtomicU64,
    next_tid: AtomicU64,
    next_trace: AtomicU64,
    wire_out: AtomicU64,
    wire_in: AtomicU64,
}

static REG: OnceLock<Registry> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn reg() -> &'static Registry {
    REG.get_or_init(|| Registry {
        enabled: AtomicBool::new(false),
        shards: (0..N_SHARDS).map(|_| Mutex::new(Ring::new())).collect(),
        next_span: AtomicU64::new(1),
        next_tid: AtomicU64::new(1),
        next_trace: AtomicU64::new(1),
        wire_out: AtomicU64::new(0),
        wire_in: AtomicU64::new(0),
    })
}

/// Microseconds since the process's tracer epoch (monotonic).
fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

struct Ctx {
    trace: Option<String>,
    /// Open span ids, innermost last.
    stack: Vec<u64>,
    /// Nested [`capture`] depth; > 0 diverts completed spans to
    /// `captured` instead of the global rings.
    capture: usize,
    captured: Vec<Span>,
    tid: u64,
}

thread_local! {
    static CTX: RefCell<Ctx> = RefCell::new(Ctx {
        trace: None,
        stack: Vec::new(),
        capture: 0,
        captured: Vec::new(),
        tid: reg().next_tid.fetch_add(1, Ordering::Relaxed),
    });
}

/// Is global span recording on?  (Scoped [`capture`] works regardless.)
pub fn enabled() -> bool {
    reg().enabled.load(Ordering::Relaxed)
}

/// Turn global span recording on or off.  Purely additive: toggling
/// never touches already-recorded spans.
pub fn set_enabled(on: bool) {
    reg().enabled.store(on, Ordering::Relaxed);
}

/// Drop every retained span (tests; never required for correctness).
pub fn clear() {
    for shard in &reg().shards {
        let mut ring = shard.lock().expect("span ring poisoned");
        ring.buf.clear();
        ring.next = 0;
        ring.dropped = 0;
    }
}

/// Validate a caller-supplied trace id (the `x-ampq-trace` header):
/// 1..=[`MAX_TRACE_ID_LEN`] chars from `[A-Za-z0-9._-]`.  Anything else
/// — control bytes, header-injection attempts, oversized ids — errors.
pub fn validate_trace_id(s: &str) -> Result<()> {
    if s.is_empty() {
        bail!("trace id is empty");
    }
    if s.len() > MAX_TRACE_ID_LEN {
        bail!("trace id exceeds {MAX_TRACE_ID_LEN} bytes ({} given)", s.len());
    }
    if let Some(c) =
        s.chars().find(|c| !(c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')))
    {
        bail!("trace id contains illegal character {c:?}");
    }
    Ok(())
}

/// A fresh process-unique trace id (stamped on requests that arrive
/// without an `x-ampq-trace` header).
pub fn fresh_trace_id() -> String {
    let n = reg().next_trace.fetch_add(1, Ordering::Relaxed);
    format!("t{:x}-{:x}", std::process::id(), n)
}

/// Install `trace` as this thread's trace context for the duration of
/// `f`; the previous context (if any) is restored afterwards.
pub fn with_trace<R>(trace: &str, f: impl FnOnce() -> R) -> R {
    let prev = CTX.with(|c| {
        let mut c = c.borrow_mut();
        std::mem::replace(&mut c.trace, Some(trace.to_string()))
    });
    let r = f();
    CTX.with(|c| c.borrow_mut().trace = prev);
    r
}

/// The trace id installed on this thread, if any.
pub fn current_trace() -> Option<String> {
    CTX.with(|c| c.borrow().trace.clone())
}

/// Open a span.  Inert (and allocation-free) unless global recording is
/// on or this thread is inside a [`capture`]; the span closes — and is
/// delivered — when the guard drops.
pub fn span(name: &str) -> SpanGuard {
    let capturing = CTX.with(|c| c.borrow().capture > 0);
    if !capturing && !enabled() {
        return SpanGuard {
            active: false,
            id: 0,
            parent: 0,
            trace: String::new(),
            name: String::new(),
            start: None,
            start_us: 0,
            counters: Vec::new(),
        };
    }
    let id = reg().next_span.fetch_add(1, Ordering::Relaxed);
    let (trace, parent) = CTX.with(|c| {
        let mut c = c.borrow_mut();
        let parent = c.stack.last().copied().unwrap_or(0);
        c.stack.push(id);
        (c.trace.clone().unwrap_or_else(|| LOCAL_TRACE.to_string()), parent)
    });
    SpanGuard {
        active: true,
        id,
        parent,
        trace,
        name: name.to_string(),
        start: Some(Instant::now()),
        start_us: now_us(),
        counters: Vec::new(),
    }
}

/// An open span; records itself on drop.
pub struct SpanGuard {
    active: bool,
    id: u64,
    parent: u64,
    trace: String,
    name: String,
    start: Option<Instant>,
    start_us: u64,
    counters: Vec<(String, f64)>,
}

impl SpanGuard {
    /// Set counter `name` to `v` (overwrites an earlier value).
    pub fn counter(&mut self, name: &str, v: f64) {
        if !self.active {
            return;
        }
        match self.counters.iter_mut().find(|(k, _)| k == name) {
            Some((_, old)) => *old = v,
            None => self.counters.push((name.to_string(), v)),
        }
    }

    /// Accumulate `v` into counter `name` (starting from 0).
    pub fn add(&mut self, name: &str, v: f64) {
        if !self.active {
            return;
        }
        match self.counters.iter_mut().find(|(k, _)| k == name) {
            Some((_, old)) => *old += v,
            None => self.counters.push((name.to_string(), v)),
        }
    }

    /// This span's id — the parent for spans [`adopt`]ed from a worker.
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let dur_us = self.start.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0);
        let span_tid = CTX.with(|c| {
            let mut c = c.borrow_mut();
            // Tolerate out-of-order drops: remove this id wherever it is.
            if let Some(pos) = c.stack.iter().rposition(|&x| x == self.id) {
                c.stack.remove(pos);
            }
            c.tid
        });
        let span = Span {
            trace: std::mem::take(&mut self.trace),
            id: self.id,
            parent: self.parent,
            name: std::mem::take(&mut self.name),
            start_us: self.start_us,
            dur_us,
            pid: u64::from(std::process::id()),
            tid: span_tid,
            counters: std::mem::take(&mut self.counters),
        };
        deliver(span, span_tid);
    }
}

fn deliver(span: Span, tid: u64) {
    let diverted = CTX.with(|c| {
        let mut c = c.borrow_mut();
        if c.capture > 0 {
            c.captured.push(span.clone());
            true
        } else {
            false
        }
    });
    if diverted {
        return;
    }
    let shard = &reg().shards[(tid as usize) % N_SHARDS];
    shard.lock().expect("span ring poisoned").push(span);
}

/// Run `f` with span capture on: every span this thread completes inside
/// is returned instead of entering the global rings (spans record even
/// with global tracing off).  This is how a dist worker collects the
/// spans it ships back in its response frame.
pub fn capture<R>(f: impl FnOnce() -> R) -> (R, Vec<Span>) {
    let mark = CTX.with(|c| {
        let mut c = c.borrow_mut();
        c.capture += 1;
        c.captured.len()
    });
    let r = f();
    let spans = CTX.with(|c| {
        let mut c = c.borrow_mut();
        c.capture -= 1;
        c.captured.split_off(mark)
    });
    (r, spans)
}

/// Merge spans recorded in another process into the local registry:
/// fresh local ids, roots re-parented under `parent`, trace id forced to
/// `trace`, and timestamps shifted so the latest incoming end time lands
/// at the local "now" (the response just arrived, so the work just
/// finished).  Relative structure and durations are preserved.
pub fn adopt(spans: Vec<Span>, trace: &str, parent: u64) {
    if spans.is_empty() {
        return;
    }
    let max_end = spans.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(0);
    let shift = now_us().saturating_sub(max_end);
    let ids: std::collections::BTreeMap<u64, u64> = spans
        .iter()
        .map(|s| (s.id, reg().next_span.fetch_add(1, Ordering::Relaxed)))
        .collect();
    for mut s in spans {
        let old_parent = s.parent;
        s.id = ids[&s.id];
        s.parent = ids.get(&old_parent).copied().unwrap_or(parent);
        s.trace = trace.to_string();
        s.start_us += shift;
        let tid = s.tid;
        let shard = &reg().shards[(tid as usize) % N_SHARDS];
        shard.lock().expect("span ring poisoned").push(s);
    }
}

/// Every retained span, sorted by `(start_us, id)` so output is stable
/// regardless of which shard a span landed in.
pub fn snapshot() -> Vec<Span> {
    let mut out = Vec::new();
    for shard in &reg().shards {
        out.extend(shard.lock().expect("span ring poisoned").buf.iter().cloned());
    }
    out.sort_by(|a, b| a.start_us.cmp(&b.start_us).then(a.id.cmp(&b.id)));
    out
}

/// Retained spans of one trace, `(start_us, id)`-sorted.
pub fn spans_for(trace: &str) -> Vec<Span> {
    let mut out: Vec<Span> = snapshot().into_iter().filter(|s| s.trace == trace).collect();
    out.sort_by(|a, b| a.start_us.cmp(&b.start_us).then(a.id.cmp(&b.id)));
    out
}

/// Count bytes written to the dist wire (frame header included).
pub fn wire_count_out(n: usize) {
    reg().wire_out.fetch_add(n as u64, Ordering::Relaxed);
}

/// Count bytes read from the dist wire (frame header included).
pub fn wire_count_in(n: usize) {
    reg().wire_in.fetch_add(n as u64, Ordering::Relaxed);
}

/// Total (written, read) dist wire bytes this process has moved.
pub fn wire_totals() -> (u64, u64) {
    (reg().wire_out.load(Ordering::Relaxed), reg().wire_in.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        // No global toggle here (tests share the process): capture is off
        // and we only assert the guard is a no-op carrier.
        let was = enabled();
        if !was {
            let mut g = span("never.recorded");
            g.counter("x", 1.0);
            assert_eq!(g.id(), 0);
        }
    }

    #[test]
    fn capture_collects_nested_spans_with_parents() {
        let ((), spans) = capture(|| {
            let outer = span("outer");
            {
                let mut inner = span("inner");
                inner.counter("kept", 3.0);
                inner.add("kept", 2.0);
            }
            drop(outer);
        });
        assert_eq!(spans.len(), 2);
        // Inner closes first.
        assert_eq!(spans[0].name, "inner");
        assert_eq!(spans[1].name, "outer");
        assert_eq!(spans[0].parent, spans[1].id);
        assert_eq!(spans[1].parent, 0);
        assert_eq!(spans[0].counters, vec![("kept".to_string(), 5.0)]);
    }

    #[test]
    fn capture_respects_trace_context() {
        let ((), spans) = with_trace("abc-123", || {
            capture(|| {
                let _s = span("work");
            })
        });
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].trace, "abc-123");
        assert_eq!(current_trace(), None, "context must be restored");
        let ((), spans) = capture(|| {
            let _s = span("work");
        });
        assert_eq!(spans[0].trace, LOCAL_TRACE);
    }

    #[test]
    fn trace_id_validation() {
        validate_trace_id("abc-123_x.Y").unwrap();
        validate_trace_id(&"a".repeat(MAX_TRACE_ID_LEN)).unwrap();
        assert!(validate_trace_id("").is_err());
        assert!(validate_trace_id(&"a".repeat(MAX_TRACE_ID_LEN + 1)).is_err());
        assert!(validate_trace_id("x y").is_err());
        assert!(validate_trace_id("inject\r\nx-evil: 1").is_err());
        assert!(validate_trace_id("naïve").is_err());
    }

    #[test]
    fn fresh_ids_validate_and_differ() {
        let a = fresh_trace_id();
        let b = fresh_trace_id();
        validate_trace_id(&a).unwrap();
        validate_trace_id(&b).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn span_json_roundtrip() {
        let s = Span {
            trace: "t1-2".into(),
            id: 7,
            parent: 3,
            name: "solver.dp.group".into(),
            start_us: 10,
            dur_us: 4,
            pid: 99,
            tid: 2,
            counters: vec![("kept".into(), 12.0), ("thinned".into(), 0.0)],
        };
        let text = s.to_json().to_string();
        let back = Span::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn adopt_reparents_and_renumbers() {
        let ((), worker_spans) = capture(|| {
            let root = span("worker.task");
            {
                let _child = span("worker.step");
            }
            drop(root);
        });
        assert_eq!(worker_spans.len(), 2);
        let ((), local) = capture(|| {
            // Adoption goes to the global ring; capture only proves the
            // remap logic on a copy here.
            let parent = span("coord.task");
            let pid = parent.id();
            drop(parent);
            adopt(worker_spans.clone(), "trace-x", pid);
        });
        assert_eq!(local.len(), 1);
        let got = spans_for("trace-x");
        let root = got.iter().find(|s| s.name == "worker.task").expect("root adopted");
        let child = got.iter().find(|s| s.name == "worker.step").expect("child adopted");
        assert_eq!(root.parent, local[0].id);
        assert_eq!(child.parent, root.id);
        assert_ne!(root.id, worker_spans[1].id, "ids must be renumbered");
    }

    #[test]
    fn ring_overwrites_oldest_beyond_cap() {
        let mut ring = Ring::new();
        for i in 0..(SHARD_CAP + 10) {
            ring.push(Span {
                trace: "r".into(),
                id: i as u64 + 1,
                parent: 0,
                name: "x".into(),
                start_us: i as u64,
                dur_us: 0,
                pid: 0,
                tid: 0,
                counters: Vec::new(),
            });
        }
        assert_eq!(ring.buf.len(), SHARD_CAP);
        assert_eq!(ring.dropped, 10);
        // The ten oldest ids are gone.
        assert!(ring.buf.iter().all(|s| s.id > 10));
    }

    #[test]
    fn wire_counters_accumulate() {
        let (o0, i0) = wire_totals();
        wire_count_out(10);
        wire_count_in(3);
        let (o1, i1) = wire_totals();
        assert!(o1 >= o0 + 10);
        assert!(i1 >= i0 + 3);
    }
}
