//! Span exporters: Chrome trace-event / Perfetto JSON (load the file at
//! `ui.perfetto.dev` or `chrome://tracing`) and the nested span tree
//! behind the daemon's `GET /v1/trace/:id`.
//!
//! The trace-event schema emitted here (validated by
//! `scripts/check_trace.py` in CI):
//!
//! ```json
//! {"traceEvents": [
//!    {"name": "solver.parametric", "cat": "ampq", "ph": "X",
//!     "ts": 120, "dur": 480, "pid": 4242, "tid": 1,
//!     "args": {"trace": "t1-9", "span_id": 3, "parent": 1,
//!              "states_kept": 512.0, "states_pruned": 1024.0}}
//!  ],
//!  "displayTimeUnit": "ms"}
//! ```
//!
//! Every event is a complete (`"ph": "X"`) slice; `ts`/`dur` are
//! microseconds on the process-local monotonic clock.  Worker-process
//! spans keep their own `pid`, so Perfetto renders the fleet as separate
//! process tracks stitched by the shared `trace`/`parent` args.

use super::trace::{snapshot, spans_for, Span};
use crate::util::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// One span as a Chrome trace-event "complete" slice.
fn event(s: &Span) -> Json {
    let mut args = vec![
        ("trace".to_string(), Json::Str(s.trace.clone())),
        ("span_id".to_string(), Json::Num(s.id as f64)),
        ("parent".to_string(), Json::Num(s.parent as f64)),
    ];
    for (k, v) in &s.counters {
        args.push((k.clone(), Json::Num(*v)));
    }
    Json::Obj(vec![
        ("name".to_string(), Json::Str(s.name.clone())),
        ("cat".to_string(), Json::Str("ampq".to_string())),
        ("ph".to_string(), Json::Str("X".to_string())),
        ("ts".to_string(), Json::Num(s.start_us as f64)),
        ("dur".to_string(), Json::Num(s.dur_us as f64)),
        ("pid".to_string(), Json::Num(s.pid as f64)),
        ("tid".to_string(), Json::Num(s.tid as f64)),
        ("args".to_string(), Json::Obj(args)),
    ])
}

/// Encode `spans` as a Perfetto-loadable trace-event JSON document.
pub fn chrome_trace(spans: &[Span]) -> Json {
    Json::Obj(vec![
        ("traceEvents".to_string(), Json::Arr(spans.iter().map(event).collect())),
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
    ])
}

/// Write every retained span to `path` as Perfetto JSON.
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    let spans = snapshot();
    std::fs::write(path, chrome_trace(&spans).to_string())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(())
}

/// The nested span tree of one trace — `GET /v1/trace/:id`'s body — or
/// `None` when no span of that trace is retained.  Children are ordered
/// by `(start_us, id)`; spans whose parent was evicted from a ring
/// surface as extra roots rather than vanishing.
pub fn trace_tree(trace: &str) -> Option<Json> {
    let spans = spans_for(trace);
    if spans.is_empty() {
        return None;
    }
    let present: std::collections::BTreeSet<u64> = spans.iter().map(|s| s.id).collect();
    let roots: Vec<&Span> =
        spans.iter().filter(|s| s.parent == 0 || !present.contains(&s.parent)).collect();
    let nodes = roots.iter().map(|r| tree_node(r, &spans)).collect();
    Some(Json::Obj(vec![
        ("trace".to_string(), Json::Str(trace.to_string())),
        ("span_count".to_string(), Json::Num(spans.len() as f64)),
        ("roots".to_string(), Json::Arr(nodes)),
    ]))
}

fn tree_node(s: &Span, all: &[Span]) -> Json {
    let children: Vec<Json> =
        all.iter().filter(|c| c.parent == s.id).map(|c| tree_node(c, all)).collect();
    Json::Obj(vec![
        ("name".to_string(), Json::Str(s.name.clone())),
        ("span_id".to_string(), Json::Num(s.id as f64)),
        ("start_us".to_string(), Json::Num(s.start_us as f64)),
        ("dur_us".to_string(), Json::Num(s.dur_us as f64)),
        ("pid".to_string(), Json::Num(s.pid as f64)),
        ("tid".to_string(), Json::Num(s.tid as f64)),
        (
            "counters".to_string(),
            Json::Obj(s.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect()),
        ),
        ("children".to_string(), Json::Arr(children)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{adopt, capture, span, with_trace};

    fn sample_spans() -> Vec<Span> {
        let ((), spans) = with_trace("export-test", || {
            capture(|| {
                let mut root = span("request");
                {
                    let mut dp = span("solver.parametric");
                    dp.counter("states_kept", 12.0);
                    dp.counter("states_pruned", 34.0);
                }
                root.counter("status", 200.0);
            })
        });
        spans
    }

    #[test]
    fn chrome_trace_schema_holds() {
        let spans = sample_spans();
        let doc = chrome_trace(&spans);
        let events = doc.get("traceEvents").unwrap().arr().unwrap();
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.get("cat").unwrap().str().unwrap(), "ampq");
            assert_eq!(e.get("ph").unwrap().str().unwrap(), "X");
            assert!(e.get("ts").unwrap().f64().unwrap() >= 0.0);
            assert!(e.get("dur").unwrap().f64().unwrap() >= 0.0);
            e.get("pid").unwrap().f64().unwrap();
            e.get("tid").unwrap().f64().unwrap();
            assert_eq!(
                e.get("args").unwrap().get("trace").unwrap().str().unwrap(),
                "export-test"
            );
        }
        // Counters ride in args.
        let dp = events
            .iter()
            .find(|e| e.get("name").unwrap().str().unwrap() == "solver.parametric")
            .unwrap();
        assert_eq!(dp.get("args").unwrap().get("states_kept").unwrap().f64().unwrap(), 12.0);
        // The document parses back (what Perfetto does).
        Json::parse(&doc.to_string()).unwrap();
    }

    #[test]
    fn trace_tree_nests_children_under_roots() {
        // Adopt into the global registry under a unique trace id (tests
        // share the process's rings).
        let spans = sample_spans();
        let unique = "export-tree-test-1";
        adopt(spans, unique, 0);
        let tree = trace_tree(unique).expect("tree must exist");
        assert_eq!(tree.get("trace").unwrap().str().unwrap(), unique);
        assert_eq!(tree.get("span_count").unwrap().usize().unwrap(), 2);
        let roots = tree.get("roots").unwrap().arr().unwrap();
        assert_eq!(roots.len(), 1);
        assert_eq!(roots[0].get("name").unwrap().str().unwrap(), "request");
        let children = roots[0].get("children").unwrap().arr().unwrap();
        assert_eq!(children.len(), 1);
        assert_eq!(children[0].get("name").unwrap().str().unwrap(), "solver.parametric");
        assert_eq!(
            children[0].get("counters").unwrap().get("states_pruned").unwrap().f64().unwrap(),
            34.0
        );
        assert!(trace_tree("no-such-trace-id-ever").is_none());
    }
}
