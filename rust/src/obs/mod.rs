//! Observability: span-based structured tracing and introspection
//! counters across every layer of the planning stack — zero external
//! dependencies, and **observation-only** by contract.
//!
//! * [`trace`] — the [`Tracer`](trace) core: monotonic-clock spans in
//!   lock-sharded ring buffers behind a process-wide registry, a
//!   thread-local trace context (install with [`with_trace`], honor an
//!   incoming `x-ampq-trace` header with [`validate_trace_id`]), scoped
//!   capture for shipping worker-process spans over the dist wire
//!   ([`capture`] / [`adopt`]), and global wire-byte counters.
//! * [`export`] — the Chrome trace-event / Perfetto JSON exporter
//!   (`ampq trace --out trace.json`, `--trace FILE` on plan / frontier /
//!   fleet) and the span-tree renderer behind `GET /v1/trace/:id`.
//!
//! The hard rule, enforced by `tests/obs.rs`: tracing never changes a
//! planned artifact, a frontier, or a daemon answer — outputs are
//! byte-identical with tracing on or off, at any `--threads` or
//! `--workers` count.  Spans and counters are recorded through side
//! channels (thread-local context, sharded rings, atomics) that no
//! computation ever reads back; when tracing is off, the per-span cost
//! is one relaxed atomic load.
//!
//! See DESIGN.md §4g for the span model, the trace-context propagation
//! rules (HTTP header + dist frames), and the determinism argument.

pub mod export;
pub mod trace;

pub use export::{chrome_trace, trace_tree, write_chrome_trace};
pub use trace::{
    adopt, capture, clear, current_trace, enabled, fresh_trace_id, set_enabled, snapshot, span,
    spans_for, validate_trace_id, wire_count_in, wire_count_out, wire_totals, with_trace, Span,
    SpanGuard, LOCAL_TRACE, MAX_TRACE_ID_LEN,
};
