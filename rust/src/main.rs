//! ampq CLI — the L3 coordinator entrypoint.
//!
//! Subcommands (see README):
//!   partition  — print the Algorithm-2 sub-graph partition (paper Fig. 6)
//!   calibrate  — run sensitivity calibration, print s_l and E[g^2]
//!   measure    — per-group empirical time-gain tables (paper §2.3.1)
//!   optimize   — solve the IP at one tau, print the chosen configuration
//!   evaluate   — evaluate a strategy's configuration on the tasks
//!   pipeline   — Algorithm 1 end to end with a tau sweep summary
//!   figures    — regenerate paper figures/tables into results/
//!   ttft       — wall-clock TTFT of the real compiled forward (PJRT)

use ampq::coordinator::{paper_tau_grid, select_config, Pipeline, Strategy};
use ampq::evalharness::{evaluate, load_all_tasks};
use ampq::figures::{fig1, fig2, fig3, table1, ExpParams, FigureCtx};
use ampq::gaudisim::{HwModel, MpConfig};
use ampq::metrics::Objective;
use ampq::model::Manifest;
use ampq::numerics::{Format, PAPER_FORMATS};
use ampq::runtime::FwdMode;
use ampq::sensitivity::validate::draw_pscale;
use ampq::timing::{measure_groups, TtftSource, WallTtft};
use ampq::util::{Args, Rng};
use anyhow::{bail, Result};
use std::path::PathBuf;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: ampq <partition|calibrate|measure|optimize|evaluate|pipeline|figures|ttft> \
  [--model tiny-s] [--artifacts artifacts] [--out results] [--tau 0.004] \
  [--objective et|tt|m] [--strategy ip|random|prefix] [--seeds N] [--quick] [--fwd pallas|ref]";

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["quick", "all", "help"])?;
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = args.positional[0].as_str();
    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let manifest = Manifest::load(&root)?;
    let model = args.get_or("model", "tiny-s").to_string();
    let fwd_mode = match args.get_or("fwd", "ref") {
        "pallas" => FwdMode::Pallas,
        "ref" => FwdMode::Ref,
        m => bail!("unknown --fwd '{m}'"),
    };

    match cmd {
        "partition" => cmd_partition(&manifest, &model),
        "calibrate" => cmd_calibrate(&manifest, &model, fwd_mode),
        "measure" => cmd_measure(&manifest, &model, fwd_mode, &args),
        "optimize" => cmd_optimize(&manifest, &model, fwd_mode, &args),
        "evaluate" => cmd_evaluate(&manifest, &model, fwd_mode, &args),
        "pipeline" => cmd_pipeline(&manifest, &model, fwd_mode, &args),
        "figures" => cmd_figures(manifest, fwd_mode, &args),
        "ttft" => cmd_ttft(&manifest, &model, &args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn load_pipeline(manifest: &Manifest, model: &str, fwd: FwdMode) -> Result<Pipeline> {
    Pipeline::new(manifest, model, fwd, HwModel::default(), PAPER_FORMATS.to_vec())
}

fn parse_objective(args: &Args) -> Result<Objective> {
    Ok(match args.get_or("objective", "et") {
        "et" => Objective::EmpiricalTime,
        "tt" => Objective::TheoreticalTime,
        "m" => Objective::Memory,
        o => bail!("unknown --objective '{o}'"),
    })
}

fn parse_strategy(args: &Args) -> Result<Strategy> {
    Ok(match args.get_or("strategy", "ip") {
        "ip" => Strategy::Ip,
        "random" => Strategy::Random,
        "prefix" => Strategy::Prefix,
        s => bail!("unknown --strategy '{s}'"),
    })
}

fn cmd_partition(manifest: &Manifest, model: &str) -> Result<()> {
    let info = manifest.model(model)?;
    let graph = info.load_graph(&manifest.root)?;
    let part = ampq::graph::partition::partition(&graph)?;
    println!(
        "model {model}: {} nodes, {} quantizable layers -> {} sequential sub-graphs",
        graph.nodes.len(),
        graph.qlayers.len(),
        part.groups.len()
    );
    for (j, g) in part.groups.iter().enumerate() {
        let names: Vec<&str> = g.qidxs.iter().map(|&q| graph.qlayers[q].as_str()).collect();
        println!(
            "  V{j:<2} ({} layers, {} configs): {}",
            g.len(),
            g.n_configs(PAPER_FORMATS.len()),
            names.join(", ")
        );
    }
    println!(
        "total per-group measurements: {} (vs {:.2e} for exhaustive whole-model search)",
        part.n_measurements(PAPER_FORMATS.len()),
        (PAPER_FORMATS.len() as f64).powi(graph.qlayers.len() as i32)
    );
    Ok(())
}

fn cmd_calibrate(manifest: &Manifest, model: &str, fwd: FwdMode) -> Result<()> {
    let pl = load_pipeline(manifest, model, fwd)?;
    let c = &pl.calibration;
    println!(
        "model {model}: R={} samples, E[g]={:.4}, E[g^2]={:.4}",
        c.n_samples, c.g_mean, c.eg2
    );
    println!("{:<22} {:>14} {:>14}", "layer", "s_l", "d_l(fp8)");
    for (l, q) in pl.info.qlayers.iter().enumerate() {
        println!(
            "{:<22} {:>14.6} {:>14.3e}",
            q.name,
            c.s[l],
            c.layer_mse(l, Format::Fp8E4m3)
        );
    }
    Ok(())
}

fn cmd_measure(manifest: &Manifest, model: &str, fwd: FwdMode, args: &Args) -> Result<()> {
    let pl = load_pipeline(manifest, model, fwd)?;
    let reps = args.usize_or("reps", 5)?;
    let tm = pl.measure_time(args.u64_or("seed", 0)?, reps)?;
    println!("model {model}: baseline TTFT {:.1} us (simulated Gaudi-2-like)", tm.base_ttft);
    for g in &tm.groups {
        let names: Vec<&str> =
            g.qidxs.iter().map(|&q| pl.info.qlayers[q].name.as_str()).collect();
        println!("group {} [{}]:", g.group, names.join(", "));
        for (cfg, gain) in g.configs.iter().zip(&g.gains) {
            let label: String =
                cfg.iter().map(|f| if *f == Format::Bf16 { '0' } else { '1' }).collect();
            println!("    {label}  gain {:>9.2} us", gain);
        }
    }
    Ok(())
}

fn cmd_optimize(manifest: &Manifest, model: &str, fwd: FwdMode, args: &Args) -> Result<()> {
    let pl = load_pipeline(manifest, model, fwd)?;
    let tau = args.f64_or("tau", 0.004)?;
    let objective = parse_objective(args)?;
    let tm = pl.measure_time(0, args.usize_or("reps", 5)?)?;
    let family = pl.family(objective, &tm);
    let out = ampq::coordinator::optimize(&family.groups, &pl.calibration, tau)?;
    println!(
        "model {model} {} tau={tau}: feasible={} gain={:.3} predicted-mse={:.3e} budget={:.3e}",
        objective.name(),
        out.solution.feasible,
        out.solution.gain,
        out.predicted_mse,
        out.budget
    );
    println!("config ({} of {} layers quantized):", out.config.n_quantized(), out.config.len());
    for (l, q) in pl.info.qlayers.iter().enumerate() {
        println!("  {:<22} {}", q.name, out.config.get(l).name());
    }
    Ok(())
}

fn cmd_evaluate(manifest: &Manifest, model: &str, fwd: FwdMode, args: &Args) -> Result<()> {
    let pl = load_pipeline(manifest, model, fwd)?;
    let tau = args.f64_or("tau", 0.004)?;
    let objective = parse_objective(args)?;
    let strategy = parse_strategy(args)?;
    let seed = args.u64_or("seed", 0)?;
    let tm = pl.measure_time(0, 5)?;
    let family = pl.family(objective, &tm);
    let cfg = select_config(&family, strategy, &pl.calibration, tau, seed)?;
    let tasks = load_all_tasks(&manifest.root, &pl.info)?;
    let mut rng = Rng::new(seed);
    let ps = draw_pscale(pl.info.n_qlayers, args.f64_or("sigma", 0.02)?, &mut rng);
    println!(
        "model {model} {} {} tau={tau} seed={seed}: config {}",
        objective.name(),
        strategy.name(),
        cfg.bits_label()
    );
    let bf16 = MpConfig::all_bf16(pl.info.n_qlayers);
    let ones = vec![1.0f32; pl.info.n_qlayers];
    for task in &tasks {
        let base = evaluate(&pl.mr, task, &bf16, &ones)?;
        let r = evaluate(&pl.mr, task, &cfg, &ps)?;
        println!(
            "  {:<6} acc {:.4} (diff {:+.4}) ppl {:.4} (diff {:+.2}%)",
            task.meta.name,
            r.acc,
            r.acc - base.acc,
            r.ppl,
            (r.ppl / base.ppl - 1.0) * 100.0
        );
    }
    Ok(())
}

fn cmd_pipeline(manifest: &Manifest, model: &str, fwd: FwdMode, args: &Args) -> Result<()> {
    let pl = load_pipeline(manifest, model, fwd)?;
    let objective = parse_objective(args)?;
    println!("== Algorithm 1 on {model} ({}) ==", objective.name());
    println!(
        "[1] partition: {} groups, {} measurements",
        pl.partition.groups.len(),
        pl.partition.n_measurements(PAPER_FORMATS.len())
    );
    println!(
        "[2] calibration: R={} E[g]={:.4} E[g^2]={:.4}",
        pl.calibration.n_samples, pl.calibration.g_mean, pl.calibration.eg2
    );
    let tm = pl.measure_time(0, args.usize_or("reps", 5)?)?;
    println!("[3] time gains measured: baseline TTFT {:.1} us", tm.base_ttft);
    let family = pl.family(objective, &tm);
    println!("[4] IP sweep:");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12} {:>10}",
        "tau", "nq", "gain", "pred-mse", "budget", "ttft[us]"
    );
    for tau in paper_tau_grid() {
        let out = ampq::coordinator::optimize(&family.groups, &pl.calibration, tau)?;
        let ttft = pl.simulated_ttft(&out.config, 1, 5);
        println!(
            "{:>8.4} {:>6} {:>12.3} {:>12.3e} {:>12.3e} {:>10.1}",
            tau,
            out.config.n_quantized(),
            out.solution.gain,
            out.predicted_mse,
            out.budget,
            ttft
        );
    }
    Ok(())
}

fn cmd_figures(manifest: Manifest, fwd: FwdMode, args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "results"));
    let mut params = if args.flag("quick") { ExpParams::quick() } else { ExpParams::default() };
    params.fwd_mode = fwd;
    params.n_seeds = args.u64_or("seeds", params.n_seeds)?;
    let models: Vec<String> = args
        .get_or("models", "tiny-s,tiny-m")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let which = args.get_or("fig", "all").to_string();
    let ctx = FigureCtx::new(manifest, params, out);

    for model in &models {
        if which == "all" || which == "1" {
            fig1::run(&ctx, model)?;
        }
        if which == "all" || which == "2" {
            fig2::run(&ctx, model)?;
        }
        if which == "all" || which == "3" || which == "3a" || which == "3b" {
            fig3::run(&ctx, model)?;
        }
        if which == "all" || which == "table1" || which == "4" || which == "5"
            || which == "7" || which == "8" || which == "9"
        {
            table1::run(&ctx, model)?;
        }
    }
    if which == "all" || which == "table1" {
        table1::combine(&ctx, &models)?;
    }
    println!("figures written to {}", ctx.out.display());
    Ok(())
}

fn cmd_ttft(manifest: &Manifest, model: &str, args: &Args) -> Result<()> {
    // Wall-clock TTFT of the REAL compiled forward on this host — proves the
    // measurement harness drives actual PJRT executables (secondary mode;
    // CPU fake-quant adds ops, so gains are not Gaudi-shaped).
    let rt = ampq::runtime::Runtime::new()?;
    let info = manifest.model(model)?.clone();
    let mode = match args.get_or("fwd", "pallas") {
        "pallas" => FwdMode::Pallas,
        _ => FwdMode::Ref,
    };
    let mr = ampq::runtime::ModelRuntime::load(&rt, &manifest.root, &info, mode)?;
    let calib = info.load_calib(&manifest.root)?;
    let tokens: Vec<i32> = calib[..info.eval_b].concat();
    let mut src = WallTtft { mr: &mr, tokens, reps: args.usize_or("reps", 5)? };
    let base = src.measure(&MpConfig::all_bf16(info.n_qlayers))?;
    let fp8 = src.measure(&MpConfig::uniform(info.n_qlayers, Format::Fp8E4m3))?;
    println!(
        "model {model} [{}] wall-clock fwd on {}: bf16-config {:.1} us, fp8-config {:.1} us / batch of {}",
        if mode == FwdMode::Pallas { "pallas" } else { "ref" },
        rt.platform(),
        base,
        fp8,
        info.eval_b
    );
    // Per-group measurement demo over the wall clock (paper Algorithm 1.3).
    let graph = info.load_graph(&manifest.root)?;
    let part = ampq::graph::partition::partition(&graph)?;
    let tm = measure_groups(&mut src, &part, &PAPER_FORMATS)?;
    println!("wall-clock per-group gains (us): ");
    for g in &tm.groups {
        let best = g.gains.iter().cloned().fold(f64::MIN, f64::max);
        println!("  group {:<2} ({} cfgs): max gain {:+.1}", g.group, g.gains.len(), best);
    }
    Ok(())
}
