//! ampq CLI — the L3 coordinator entrypoint, built on the staged planning
//! API (`plan::Engine` -> stage artifacts -> `plan::Planner` -> `Plan`).
//!
//! Subcommands (see README for the full table):
//!   partition  — stage-1 artifact: the Algorithm-2 sub-graph partition
//!   calibrate  — stage-2 artifact: sensitivities s_l and E[g^2]
//!   measure    — stage-3 artifact: per-group time-gain tables (§2.3.1)
//!   optimize   — one planning query -> Plan (config + MSE + gain);
//!                multi-constraint via --memory-cap
//!   evaluate   — evaluate a Plan's configuration on the tasks (PJRT)
//!   pipeline   — Algorithm 1 end to end: stages 1-3 + IP tau sweep
//!   sweep      — batch-solve tau x objective x strategy from cached
//!                artifacts (one calibration + one measurement, total)
//!   frontier   — precompute the tau -> gain Pareto frontier
//!   serve      — answer a JSON batch of plan/frontier requests on a
//!                concurrent PlanService
//!   figures    — regenerate paper figures/tables into results/
//!   ttft       — wall-clock TTFT of the real compiled forward (PJRT)
//!
//! Stage artifacts cache under <artifacts>/cache/<model>/ (disable with
//! --no-cache).  `--json` prints machine-readable lines in the Plan/artifact
//! serde format.  `--demo` registers a synthetic model ("demo") so
//! everything except evaluate/ttft runs without AOT artifacts.

// lint: allow-file(D3) CLI stopwatch lines ('done in 1.2s' on stderr); never serialized into artifacts or plans

use ampq::backend::{DeviceProfile, Registry};
use ampq::coordinator::{paper_tau_grid, Strategy};
use ampq::evalharness::{evaluate, evaluate_plan, load_all_tasks};
use ampq::exec::{ExecCfg, ExecPool};
use ampq::figures::{fig1, fig2, fig3, table1, ExpParams, FigureCtx};
use ampq::gaudisim::MpConfig;
use ampq::metrics::Objective;
use ampq::numerics::Format;
use ampq::plan::demo::demo_model;
use ampq::plan::request::check_budget;
use ampq::plan::{load_requests, Engine, Frontier, Plan, PlanRequest};
use ampq::runtime::FwdMode;
use ampq::timing::{measure_groups, TtftSource, WallTtft};
use ampq::util::{Args, Json};
use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: ampq <command> [options]

commands:
  partition   stage-1 artifact: Algorithm-2 sub-graph partition (Fig. 6)
  calibrate   stage-2 artifact: sensitivity calibration s_l, E[g^2]
  measure     stage-3 artifact: per-group empirical time-gain tables
              (simulated on the --device profile)
  optimize    solve one multi-constraint query -> Plan (alias: plan)
  evaluate    evaluate a Plan's configuration on the eval tasks (needs PJRT)
  pipeline    Algorithm 1 end to end: stages 1-3 + IP tau sweep
  sweep       batch-solve the tau x objective x strategy grid from cache
  frontier    precompute the tau -> gain Pareto frontier for one
              (model, objective, strategy); the IP curve is ONE
              parametric DP sweep, not a solve per tau
  serve       answer a JSON array of requests (--requests FILE) on a
              concurrent PlanService; entries may carry \"device\".
              with --listen ADDR: run as a resident daemon serving
              POST /v1/plan, POST /v1/frontier (NDJSON streaming),
              GET /v1/models, /v1/devices, /v1/trace/:id, /metrics
              (Prometheus text, or JSON with Accept: application/json),
              /healthz
  devices     list the built-in hardware device profiles
  compare     plan on several devices (--devices a,b,c) and print their
              Pareto frontiers side by side
  fleet       schedule the --models x --devices calibration + measurement
              + frontier matrix over a worker process fleet; artifacts
              are byte-identical at any --workers count (0 = in-process)
  worker      distributed-planning worker (spawned by the coordinator;
              speaks frames on stdin/stdout, or --connect HOST:PORT)
  figures     regenerate paper figures/tables into results/
  ttft        wall-clock TTFT of the real compiled forward (needs PJRT)
  lint        determinism & soundness static analysis over the crate
              (rules D1-D5, see DESIGN.md 4i); exits non-zero on any
              finding that is neither suppressed nor baselined
  trace       record a traced demo run (plan + frontier; with
              --workers N also a fleet cell, stitching worker-process
              spans into the tree) and export Chrome trace-event JSON
              to --out [trace.json] — open in Perfetto / about:tracing

options:
  --model NAME          model from artifacts/manifest.json [tiny-s]
  --artifacts DIR       artifacts root [artifacts]
  --no-cache            disable the stage cache under <artifacts>/cache/
  --device NAME|FILE    hardware profile: a registry name (see `ampq
                        devices`) or a JSON profile file [gaudi2]
  --devices a,b,c       compare: device list (names and/or JSON files);
                        serve --listen: extra devices to pre-stage
  --out DIR             figures output dir [results]
  --tau X               loss-NRMSE threshold [0.004]
  --memory-cap BYTES    additional stored-weight-byte cap (optimize)
  --requests FILE       serve: JSON array of plan/frontier requests
  --listen ADDR         serve: bind a resident planning daemon on ADDR
                        (e.g. 127.0.0.1:8787) instead of batch mode
  --models a,b,c        serve --listen: models to stage [--model]
  --queue-depth N       serve --listen: admission queue bound; overflow
                        answers 503 + Retry-After [64]
  --cache-cap N         serve --listen: frontier cache entry cap (LRU
                        eviction; 0 = unbounded) [32]
  --request-timeout MS  serve --listen: per-request deadline; expiry
                        answers 504 [10000]
  --threads N           worker threads for parallel stages, solves,
                        frontier sweeps, and serve batches
                        [AMPQ_THREADS or available parallelism;
                        1 = exact sequential path — output is
                        bit-identical either way]
  --taus a,b,c          explicit tau grid [paper grid 0..0.007]
  --objective et|tt|m   IP objective family [et; sweep: all]
  --strategy ip|random|prefix
                        selection strategy [ip; sweep: all]
  --seed N --seeds N    strategy RNG seed / number of seeds
  --measure-seed N      seed of the simulator measurement pass
                        [0x714e33; `measure` also honors --seed]
  --reps N              TTFT iterations per measurement [5]
  --sigma X             scale-perturbation sigma [0.02]
  --fwd pallas|ref      forward artifact [ref; ttft: pallas]
  --workers N           fleet: worker process count (0 = in-process) [2]
  --dist-workers N      serve --listen: stage measurement passes through
                        N worker processes (0 = in-process) [0]
  --transport stdio|tcp fleet: coordinator<->worker transport [stdio]
  --task-deadline MS    fleet: per-task deadline before the worker is
                        killed and the task re-issued [30000]
  --max-retries N       fleet: re-issues allowed per task [3]
  --retry-backoff MS    fleet: pause before a worker respawn [50]
  --trace FILE          record spans for this run and write Chrome
                        trace-event (Perfetto) JSON to FILE on success;
                        observation-only — every output is bit-identical
                        with and without it
  --no-trace            serve --listen: do not record spans (requests
                        still carry and echo x-ampq-trace ids)
  --baseline FILE       lint: baseline file [<src root>/../lint-baseline.json]
  --no-baseline         lint: ignore the baseline file entirely
  --write-baseline      lint: rewrite the baseline to cover current findings
  --fix-hints           lint: print a fix hint under each finding
  --json                machine-readable JSON lines (Plan serde format;
                        lint: the full findings report)
  --demo                register a synthetic model 'demo' (no artifacts
                        or PJRT needed; sets the default --model)
  --blocks N            demo model depth [2]";

/// Everything needed to build one Engine; `serve` and `compare` build one
/// per device from the same spec.
struct EngineSpec {
    root: PathBuf,
    fwd_mode: FwdMode,
    measure_seed: u64,
    reps: usize,
    no_cache: bool,
    demo: bool,
    blocks: usize,
    demo_seed: u64,
    exec: ExecCfg,
}

impl EngineSpec {
    fn engine(&self, device: DeviceProfile) -> Engine {
        let mut engine = Engine::new()
            .with_artifacts_root(self.root.clone())
            .with_fwd_mode(self.fwd_mode)
            .with_measure_protocol(self.measure_seed, self.reps)
            .with_exec(self.exec)
            .with_device(device);
        if !self.no_cache {
            engine = engine.with_cache_dir(self.root.join("cache"));
        }
        if self.demo {
            let (graph, qlayers, calibration) = demo_model(self.blocks, self.demo_seed);
            engine.register_synthetic("demo", graph, qlayers, calibration);
        }
        engine
    }
}

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(
        raw,
        &[
            "quick",
            "all",
            "help",
            "json",
            "demo",
            "no-cache",
            "no-trace",
            "fix-hints",
            "write-baseline",
            "no-baseline",
        ],
    )?;
    if args.flag("help") || args.positional.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = args.positional[0].as_str();
    // --trace FILE: record spans for the whole run and export them as
    // Chrome trace-event JSON at the end.  Observation-only: outputs are
    // bit-identical with and without it (tests/obs.rs pins this).
    let trace_out: Option<PathBuf> = args.get("trace").map(PathBuf::from);
    if trace_out.is_some() {
        ampq::obs::set_enabled(true);
    }
    // The distributed subcommands dispatch before any engine/device setup:
    // `worker` is spawned in bulk by a coordinator and must start speaking
    // frames immediately; `fleet` builds its own per-cell pipelines; the
    // `trace` demo builds its own synthetic engine.
    match cmd {
        "worker" => return cmd_worker(&args),
        "fleet" => return finish_traced(cmd_fleet(&args), trace_out.as_deref()),
        "trace" => return cmd_trace(&args),
        "lint" => return cmd_lint(&args),
        _ => {}
    }
    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let fwd_default = if cmd == "ttft" { "pallas" } else { "ref" };
    let fwd_mode = match args.get_or("fwd", fwd_default) {
        "pallas" => FwdMode::Pallas,
        "ref" => FwdMode::Ref,
        m => bail!("unknown --fwd '{m}'"),
    };
    let json = args.flag("json");
    let demo = args.flag("demo");

    // Measurement protocol: --measure-seed everywhere; the `measure`
    // subcommand also honors plain --seed (pre-0.2 behavior).  --seed on
    // other commands seeds strategies, not the measurement pass.
    let default_seed = ampq::plan::engine::DEFAULT_MEASURE_SEED;
    let measure_seed = if args.get("measure-seed").is_some() {
        args.u64_or("measure-seed", default_seed)?
    } else if cmd == "measure" {
        args.u64_or("seed", default_seed)?
    } else {
        default_seed
    };

    let registry = Registry::builtin();
    let device = match args.get("device") {
        None => DeviceProfile::gaudi2(),
        Some(spec) => registry.resolve(spec)?,
    };
    // Global worker budget: explicit --threads wins, else AMPQ_THREADS /
    // available parallelism.  Every output is bit-identical across
    // settings (the exec layer's determinism contract).
    let exec = match args.get("threads") {
        None => ExecCfg::from_env(),
        Some(_) => ExecCfg::new(args.usize_or("threads", 1)?),
    };
    let spec = EngineSpec {
        root,
        fwd_mode,
        measure_seed,
        reps: args.usize_or("reps", 5)?,
        no_cache: args.flag("no-cache"),
        demo,
        blocks: args.usize_or("blocks", 2)?,
        demo_seed: args.u64_or("seed", 0)?,
        exec,
    };
    let mut engine = spec.engine(device);
    let model = args
        .get_or("model", if demo { "demo" } else { "tiny-s" })
        .to_string();

    let result = match cmd {
        "partition" => cmd_partition(&mut engine, &model, json),
        "calibrate" => cmd_calibrate(&mut engine, &model, json),
        "measure" => cmd_measure(&mut engine, &model, json),
        "optimize" | "plan" => cmd_optimize(&mut engine, &model, &args, json),
        "evaluate" => cmd_evaluate(&mut engine, &model, &args),
        "pipeline" => cmd_pipeline(&mut engine, &model, &args, json),
        "sweep" => cmd_sweep(&mut engine, &model, &args, json),
        "frontier" => cmd_frontier(&mut engine, &model, &args, json),
        "serve" => {
            if args.get("listen").is_some() {
                cmd_serve_listen(&mut engine, &spec, &model, &args)
            } else {
                cmd_serve(&mut engine, &spec, &args, json)
            }
        }
        "devices" => cmd_devices(&registry, json),
        "compare" => cmd_compare(&spec, &registry, &model, &args, json),
        "figures" => cmd_figures(engine, &args, fwd_mode),
        "ttft" => cmd_ttft(&mut engine, &model, &args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    };
    finish_traced(result, trace_out.as_deref())
}

/// Flush recorded spans to `--trace FILE` after a successful command.
/// Failures keep their original error (a half-run trace is rarely what
/// the flag was for, and the error must not be masked by export issues).
fn finish_traced(result: Result<()>, out: Option<&std::path::Path>) -> Result<()> {
    let Some(path) = out else { return result };
    if result.is_ok() {
        ampq::obs::write_chrome_trace(path)?;
        eprintln!(
            "trace: {} span(s) written to {}",
            ampq::obs::snapshot().len(),
            path.display()
        );
    }
    result
}

fn parse_objective(args: &Args) -> Result<Objective> {
    let key = args.get_or("objective", "et");
    Objective::from_key(key).ok_or_else(|| anyhow!("unknown --objective '{key}'"))
}

fn parse_strategy(args: &Args) -> Result<Strategy> {
    let key = args.get_or("strategy", "ip");
    Strategy::from_key(key).ok_or_else(|| anyhow!("unknown --strategy '{key}'"))
}

fn parse_taus(args: &Args) -> Result<Vec<f64>> {
    match args.get("taus") {
        None => Ok(paper_tau_grid()),
        Some(s) => s
            .split(',')
            .map(|t| {
                let tau = t
                    .trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow!("--taus '{t}': {e}"))?;
                check_budget("--taus", tau)?;
                Ok(tau)
            })
            .collect(),
    }
}

fn cmd_partition(engine: &mut Engine, model: &str, json: bool) -> Result<()> {
    let art = engine.partitioned(model)?;
    if json {
        println!("{}", art.to_json().to_string());
        return Ok(());
    }
    let nf = art.formats.len();
    println!(
        "model {model}: {} quantizable layers -> {} sequential sub-graphs",
        art.n_qlayers(),
        art.partition.groups.len()
    );
    for (j, g) in art.partition.groups.iter().enumerate() {
        let names: Vec<&str> = g.qidxs.iter().map(|&q| art.qlayers[q].name.as_str()).collect();
        println!(
            "  V{j:<2} ({} layers, {} configs): {}",
            g.len(),
            g.n_configs(nf)?,
            names.join(", ")
        );
    }
    println!(
        "total per-group measurements: {} (vs {:.2e} for exhaustive whole-model search)",
        art.partition.n_measurements(nf)?,
        (nf as f64).powi(art.n_qlayers() as i32)
    );
    Ok(())
}

fn cmd_calibrate(engine: &mut Engine, model: &str, json: bool) -> Result<()> {
    let part = engine.partitioned(model)?;
    let art = engine.calibrated(model)?;
    if json {
        println!("{}", art.to_json().to_string());
        return Ok(());
    }
    let c = &art.calibration;
    println!(
        "model {model}: R={} samples, E[g]={:.4}, E[g^2]={:.4}",
        c.n_samples, c.g_mean, c.eg2
    );
    println!("{:<22} {:>14} {:>14}", "layer", "s_l", "d_l(fp8)");
    for (l, q) in part.qlayers.iter().enumerate() {
        println!(
            "{:<22} {:>14.6} {:>14.3e}",
            q.name,
            c.s[l],
            c.layer_mse(l, Format::Fp8E4m3)
        );
    }
    Ok(())
}

fn cmd_measure(engine: &mut Engine, model: &str, json: bool) -> Result<()> {
    let part = engine.partitioned(model)?;
    let art = engine.measured(model)?;
    if json {
        println!("{}", art.to_json().to_string());
        return Ok(());
    }
    let tm = &art.measurements;
    println!(
        "model {model}: baseline TTFT {:.1} us (simulated {}, seed {}, {} reps)",
        tm.base_ttft, art.device.name, art.seed, art.reps
    );
    for g in &tm.groups {
        let names: Vec<&str> =
            g.qidxs.iter().map(|&q| part.qlayers[q].name.as_str()).collect();
        println!("group {} [{}]:", g.group, names.join(", "));
        for (cfg, gain) in g.configs.iter().zip(&g.gains) {
            let label: String =
                cfg.iter().map(|f| if *f == Format::Bf16 { '0' } else { '1' }).collect();
            println!("    {label}  gain {:>9.2} us", gain);
        }
    }
    Ok(())
}

/// Build a [`PlanRequest`] from the shared CLI options.  "nan"/"-1" parse
/// as valid f64s; `check_budget` rejects them HERE so a bad flag is one
/// clear CLI error instead of a per-request failure (or, pre-hardening, a
/// comparator panic deep in a frontier sort).
fn build_request(args: &Args) -> Result<PlanRequest> {
    let tau = args.f64_or("tau", 0.004)?;
    check_budget("--tau", tau)?;
    let mut req = PlanRequest::new(parse_objective(args)?)
        .with_strategy(parse_strategy(args)?)
        .with_loss_budget(tau)
        .with_seed(args.u64_or("seed", 0)?);
    if args.get("memory-cap").is_some() {
        let cap = args.f64_or("memory-cap", 0.0)?;
        check_budget("--memory-cap", cap)?;
        req = req.with_memory_cap(cap);
    }
    Ok(req)
}

fn cmd_optimize(engine: &mut Engine, model: &str, args: &Args, json: bool) -> Result<()> {
    let req = build_request(args)?;
    let part = engine.partitioned(model)?;
    let planner = engine.planner(model)?;
    let plan = planner.solve(&req)?;
    if json {
        println!("{}", plan.to_json().to_string());
        return Ok(());
    }
    println!("{}", plan.summary());
    println!("config ({} of {} layers quantized):", plan.config.n_quantized(), plan.config.len());
    for (l, q) in part.qlayers.iter().enumerate() {
        println!("  {:<22} {}", q.name, plan.config.get(l).name());
    }
    Ok(())
}

fn cmd_evaluate(engine: &mut Engine, model: &str, args: &Args) -> Result<()> {
    let req = build_request(args)?;
    let (objective, strategy) = (req.objective, req.strategy);
    let (tau, seed) = (req.tau.unwrap_or(0.004), req.seed);
    let sigma = args.f64_or("sigma", 0.02)?;
    let planner = engine.planner(model)?;
    let plan = planner.solve(&req)?;
    let info = engine.info(model)?;
    let root = engine
        .artifacts_root()
        .ok_or_else(|| anyhow!("evaluate needs an artifacts root"))?
        .to_path_buf();
    let tasks = load_all_tasks(&root, &info)?;
    let mr = engine.runtime(model)?;
    println!(
        "model {model} {} {} tau={tau} seed={seed}: config {}",
        objective.name(),
        strategy.name(),
        plan.config.bits_label()
    );
    let bf16 = MpConfig::all_bf16(info.n_qlayers);
    let ones = vec![1.0f32; info.n_qlayers];
    let results = evaluate_plan(mr, &tasks, &plan, sigma)?;
    for (task, r) in tasks.iter().zip(&results) {
        let base = evaluate(mr, task, &bf16, &ones)?;
        println!(
            "  {:<6} acc {:.4} (diff {:+.4}) ppl {:.4} (diff {:+.2}%)",
            task.meta.name,
            r.acc,
            r.acc - base.acc,
            r.ppl,
            (r.ppl / base.ppl - 1.0) * 100.0
        );
    }
    Ok(())
}

fn cmd_pipeline(engine: &mut Engine, model: &str, args: &Args, json: bool) -> Result<()> {
    let objective = parse_objective(args)?;
    let taus = parse_taus(args)?;
    let part = engine.partitioned(model)?;
    if !json {
        println!("== Algorithm 1 on {model} ({}) ==", objective.name());
        println!(
            "[1] partition: {} groups, {} measurements",
            part.partition.groups.len(),
            part.partition.n_measurements(part.formats.len())?
        );
    }
    let planner = engine.planner(model)?;
    if !json {
        let c = planner.calibration();
        println!(
            "[2] calibration: R={} E[g]={:.4} E[g^2]={:.4}",
            c.n_samples, c.g_mean, c.eg2
        );
        println!(
            "[3] time gains measured: baseline TTFT {:.1} us",
            planner.measurements().base_ttft
        );
        println!("[4] IP sweep:");
        println!(
            "{:>8} {:>6} {:>12} {:>12} {:>12} {:>10}",
            "tau", "nq", "gain", "pred-mse", "budget", "ttft[us]"
        );
    }
    for &tau in &taus {
        let plan =
            planner.solve(&PlanRequest::new(objective).with_loss_budget(tau))?;
        if json {
            println!("{}", plan.to_json().to_string());
        } else {
            println!(
                "{:>8.4} {:>6} {:>12.3} {:>12.3e} {:>12.3e} {:>10.1}",
                tau,
                plan.config.n_quantized(),
                plan.gain,
                plan.predicted_mse,
                plan.budget,
                plan.predicted_ttft_us
            );
        }
    }
    if !json {
        let c = engine.counters();
        println!(
            "(stage passes: {} partition, {} calibration, {} measurement; {} cache loads)",
            c.partition_passes, c.calibration_passes, c.measurement_passes, c.cache_loads
        );
    }
    Ok(())
}

fn cmd_sweep(engine: &mut Engine, model: &str, args: &Args, json: bool) -> Result<()> {
    let taus = parse_taus(args)?;
    let objectives: Vec<Objective> = match args.get("objective") {
        None => Objective::ALL.to_vec(),
        Some(_) => vec![parse_objective(args)?],
    };
    let strategies: Vec<Strategy> = match args.get("strategy") {
        None => Strategy::ALL.to_vec(),
        Some(_) => vec![parse_strategy(args)?],
    };
    let seed = args.u64_or("seed", 0)?;

    let t0 = Instant::now();
    let planner = engine.planner(model)?;
    let stage_time = t0.elapsed();
    let t1 = Instant::now();
    let plans = planner.sweep(&objectives, &strategies, &taus, seed)?;
    let solve_time = t1.elapsed();

    if json {
        for p in &plans {
            println!("{}", p.to_json().to_string());
        }
    } else {
        println!(
            "== sweep {model}: {} objectives x {} strategies x {} taus = {} plans ==",
            objectives.len(),
            strategies.len(),
            taus.len(),
            plans.len()
        );
        for p in &plans {
            println!("{}", p.summary());
        }
    }
    let c = engine.counters();
    let per_plan_us = solve_time.as_secs_f64() * 1e6 / plans.len().max(1) as f64;
    eprintln!(
        "sweep {model}: artifacts {:.1} ms ({} partition, {} calibration, {} measurement \
         passes, {} cache loads); {} plans solved in {:.1} ms ({:.1} us/plan)",
        stage_time.as_secs_f64() * 1e3,
        c.partition_passes,
        c.calibration_passes,
        c.measurement_passes,
        c.cache_loads,
        plans.len(),
        solve_time.as_secs_f64() * 1e3,
        per_plan_us
    );
    Ok(())
}

fn cmd_frontier(engine: &mut Engine, model: &str, args: &Args, json: bool) -> Result<()> {
    let objective = parse_objective(args)?;
    let strategy = parse_strategy(args)?;
    let planner = engine.planner(model)?;
    let t0 = Instant::now();
    let f = planner.frontier(objective, strategy)?;
    let elapsed = t0.elapsed();
    if json {
        println!("{}", f.to_json().to_string());
        return Ok(());
    }
    println!(
        "frontier {model} {} {}: {} Pareto points over tau in [0, {:.5}] ({:.1} ms)",
        objective.name(),
        strategy.name(),
        f.points.len(),
        f.tau_max,
        elapsed.as_secs_f64() * 1e3
    );
    println!("{:>10} {:>12} {:>12} {:>6}", "tau>=", "pred-mse", "gain", "nq");
    for p in &f.points {
        println!(
            "{:>10.5} {:>12.3e} {:>12.3} {:>6}",
            p.tau,
            p.predicted_mse,
            p.gain,
            p.config.n_quantized()
        );
    }
    Ok(())
}

fn cmd_serve(engine: &mut Engine, spec: &EngineSpec, args: &Args, json: bool) -> Result<()> {
    let path = PathBuf::from(
        args.get("requests")
            .ok_or_else(|| anyhow!("serve needs --requests <file.json>"))?,
    );
    let mut reqs = load_requests(&Json::parse_file(&path)?)?;
    // Canonicalize device specs up front: entries may name a registry
    // profile OR a JSON profile file; routing keys are always the
    // profile's own name.  The local registry starts from the built-ins
    // PLUS the engine's own (possibly file-loaded) serving default, so
    // entries can name the default device too; file-loaded profiles are
    // registered so the staging loop below resolves them by name.
    let mut registry = Registry::builtin();
    registry.register(engine.device().clone());
    // spec -> canonical name memo, so a file spec repeated across N
    // entries is read and validated once, not N times.
    let mut canon: Vec<(String, String)> = Vec::new();
    for r in reqs.iter_mut() {
        if let Some(d) = r.request.device.take() {
            if let Some((_, name)) = canon.iter().find(|(s, _)| *s == d) {
                r.request.device = Some(name.clone());
                continue;
            }
            let profile = registry.resolve(&d)?;
            // A file-loaded profile must not silently shadow a DIFFERENT
            // profile already known under the same name — that would
            // answer requests with the wrong hardware.
            if let Ok(existing) = registry.get(&profile.name) {
                if existing != profile {
                    bail!(
                        "device spec '{d}' redefines profile '{}' inconsistently with the \
                         serving default, an earlier entry, or a built-in; rename the profile",
                        profile.name
                    );
                }
            }
            let name = profile.name.clone();
            registry.register(profile);
            canon.push((d, name.clone()));
            r.request.device = Some(name);
        }
    }
    let mut models: Vec<&str> = reqs.iter().map(|r| r.model.as_str()).collect();
    models.sort();
    models.dedup();
    // Stage the default-device engine only for the models some request
    // actually queries on it (no device field, or naming it explicitly) —
    // a batch that is entirely device-scoped elsewhere must not pay
    // default-device measurement passes.
    let default_name = engine.device().name.clone();
    let mut default_models: Vec<&str> = reqs
        .iter()
        .filter(|r| r.request.device.as_deref().map_or(true, |d| d == default_name))
        .map(|r| r.model.as_str())
        .collect();
    default_models.sort();
    default_models.dedup();
    // Lossy staging: a model that fails to stage answers its requests
    // with indexed error entries instead of killing the batch.
    let svc = ampq::plan::PlanService::new();
    for (m, err) in svc.stage_from_engine(engine, &default_models) {
        eprintln!("serve: skipping model '{m}': {err}");
    }
    // Requests may target other devices: stage exactly the (model, device)
    // pairs the batch references (the default engine's own device name is
    // already registered by the staging above).
    let mut pairs: Vec<(&str, &str)> = reqs
        .iter()
        .filter_map(|r| {
            r.request
                .device
                .as_deref()
                .filter(|d| *d != engine.device().name)
                .map(|d| (r.model.as_str(), d))
        })
        .collect();
    pairs.sort();
    pairs.dedup();
    let mut dev_engines: Vec<(String, Engine)> = Vec::new();
    for (model, dname) in pairs {
        if !dev_engines.iter().any(|(n, _)| n.as_str() == dname) {
            let profile = registry.resolve(dname)?;
            dev_engines.push((dname.to_string(), spec.engine(profile)));
        }
        let dev_engine =
            &mut dev_engines.iter_mut().find(|(n, _)| n.as_str() == dname).unwrap().1;
        match dev_engine.planner(model) {
            Ok(p) => svc.register_for_device(model, dname, p)?,
            Err(e) => eprintln!("serve: skipping '{model}' on '{dname}': {e:#}"),
        }
    }
    let pool = ExecPool::new(spec.exec);
    let t0 = Instant::now();
    // Lossy batch semantics: one bad request (unknown model, NaN tau, ...)
    // yields an indexed error line, never a poisoned batch — the same
    // per-entry answer schema the daemon streams on POST /v1/plan.
    let answers = svc.serve_batch_lossy(&reqs, &pool);
    let elapsed = t0.elapsed();
    let mut failures = 0usize;
    for a in &answers {
        let kind = a.opt("kind").and_then(|k| k.str().ok());
        if kind == Some("error") {
            failures += 1;
        }
        if json {
            println!("{}", a.to_string());
        } else if kind == Some("error") {
            println!(
                "request {} failed: {}",
                a.get("index")?.usize()?,
                a.get("error")?.str()?
            );
        } else if kind == Some("plan") {
            println!("{}", Plan::from_json(a)?.summary());
        } else {
            println!(
                "{} {} {} tau={:.4} gain={:.3} mse={:.3e} (frontier)",
                a.get("model")?.str()?,
                a.get("objective")?.str()?,
                a.get("strategy")?.str()?,
                a.get("tau")?.f64()?,
                a.get("gain")?.f64()?,
                a.get("predicted_mse")?.f64()?
            );
        }
    }
    eprintln!(
        "serve: {} requests ({} failed) over {} models on {} threads in {:.1} ms \
         ({:.1} us/request); {} frontier sweeps",
        reqs.len(),
        failures,
        models.len(),
        pool.threads(),
        elapsed.as_secs_f64() * 1e3,
        elapsed.as_secs_f64() * 1e6 / reqs.len().max(1) as f64,
        svc.frontier_solves()
    );
    Ok(())
}

/// Shutdown flag flipped by SIGINT/SIGTERM.  Static (not per-daemon)
/// because a C signal handler cannot carry context; a watcher thread in
/// [`cmd_serve_listen`] forwards it to the daemon's own handle.
static SIGNALLED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    // SAFETY: installs an async-signal-safe handler (a single atomic
    // store) for SIGINT(2)/SIGTERM(15) through the C `signal` entry
    // point; no Rust state is touched from signal context.
    unsafe {
        signal(2, on_signal);
        signal(15, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

fn cmd_serve_listen(
    engine: &mut Engine,
    spec: &EngineSpec,
    model: &str,
    args: &Args,
) -> Result<()> {
    use ampq::serve::{Daemon, ServeConfig};
    let addr = args.get("listen").unwrap_or("127.0.0.1:8787").to_string();
    let queue_depth = args.usize_or("queue-depth", 64)?;
    let cache_cap = args.usize_or("cache-cap", 32)?;
    let timeout_ms = args.u64_or("request-timeout", 10_000)?;
    let workers = spec.exec.threads.max(1);
    let model_list: Vec<String> = args
        .get_or("models", model)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let refs: Vec<&str> = model_list.iter().map(String::as_str).collect();
    // Optionally stage measurement passes through a worker fleet: the
    // coordinator produces bit-identical Measured artifacts, so serving
    // behavior is unchanged — only who computed the TTFTs differs.  The
    // fleet exists for staging only and drains before the daemon binds.
    let dist_workers = args.usize_or("dist-workers", 0)?;
    let coord = if dist_workers > 0 {
        let cfg = ampq::dist::DistConfig { workers: dist_workers, ..Default::default() };
        let c = std::sync::Arc::new(std::sync::Mutex::new(ampq::dist::Coordinator::new(cfg)?));
        let hook = c.clone();
        engine.set_measure_hook(Some(Box::new(move |ms| {
            hook.lock().unwrap().measure_stage(ms)
        })));
        eprintln!("ampq serve: staging measurements over {dist_workers} worker process(es)");
        Some(c)
    } else {
        None
    };
    // Daemon startup is strict: a model that cannot stage fails loudly
    // here, instead of answering 400 to every request later.
    let svc = engine.service(&refs)?;
    // Optionally pre-stage extra devices so requests naming them route
    // without a cold staging pass on the serving path.
    let mut registry = Registry::builtin();
    registry.register(engine.device().clone());
    if let Some(devs) = args.get("devices") {
        for d in devs.split(',') {
            let d = d.trim();
            if d.is_empty() {
                continue;
            }
            let profile = registry.resolve(d)?;
            if profile.name == engine.device().name {
                continue;
            }
            let name = profile.name.clone();
            registry.register(profile.clone());
            let mut dev_engine = spec.engine(profile);
            if let Some(c) = &coord {
                let hook = c.clone();
                dev_engine.set_measure_hook(Some(Box::new(move |ms| {
                    hook.lock().unwrap().measure_stage(ms)
                })));
            }
            for m in &refs {
                svc.register_for_device(m, &name, dev_engine.planner(m)?)?;
            }
        }
    }
    // Staging is done: drain the worker fleet before going resident, but
    // snapshot its supervision counters first — they surface on /metrics
    // as ampq_dist_* so operators can see how staging went.
    let mut dist_metrics = None;
    if let Some(c) = &coord {
        engine.set_measure_hook(None);
        let mut c = c.lock().unwrap();
        c.shutdown();
        dist_metrics = Some(c.metrics().clone());
    }
    let devices: Vec<DeviceProfile> = registry.iter().cloned().collect();
    let cfg = ServeConfig {
        addr,
        queue_depth,
        workers,
        cache_cap,
        request_timeout: std::time::Duration::from_millis(timeout_ms),
        tracing: !args.flag("no-trace"),
        ..ServeConfig::default()
    };
    let daemon = Daemon::new(svc, devices, cfg);
    if let Some(m) = dist_metrics {
        daemon.metrics().set_dist(m);
    }
    let listener = daemon.bind()?;
    let local = listener.local_addr()?;
    install_signal_handlers();
    let handle = daemon.handle();
    // Detached watcher forwarding SIGINT/SIGTERM to the daemon's own
    // shutdown handle; dies with the process either way.
    std::thread::spawn(move || loop {
        if SIGNALLED.load(std::sync::atomic::Ordering::SeqCst) {
            handle.shutdown();
            return;
        }
        if handle.is_shutdown() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    eprintln!(
        "ampq serve: listening on {local} ({} models, {workers} workers, queue depth \
         {queue_depth}, cache cap {cache_cap}, request timeout {timeout_ms} ms)",
        model_list.len()
    );
    daemon.run(listener)
}

/// `ampq lint [PATHS…]`: run the determinism & soundness pass (rules
/// D1-D5) over the crate, or over explicit files/dirs.  Exit status is the
/// contract CI relies on: non-zero iff any finding is neither suppressed
/// (`// lint: allow(…)`) nor covered by the baseline file.
fn cmd_lint(args: &Args) -> Result<()> {
    use ampq::analyze::{self, LintConfig};

    // Default roots adapt to the invocation directory: `rust/src` +
    // `rust/tests` from the repo root, `src` + `tests` from `rust/`.
    let explicit: Vec<PathBuf> = args.positional[1..].iter().map(PathBuf::from).collect();
    let (roots, default_baseline) = if PathBuf::from("rust/src").is_dir() {
        (
            vec![PathBuf::from("rust/src"), PathBuf::from("rust/tests")],
            PathBuf::from("rust/lint-baseline.json"),
        )
    } else {
        (
            vec![PathBuf::from("src"), PathBuf::from("tests")],
            PathBuf::from("lint-baseline.json"),
        )
    };
    let paths = if explicit.is_empty() { roots } else { explicit };
    let baseline = if args.flag("no-baseline") {
        None
    } else {
        Some(args.get("baseline").map(PathBuf::from).unwrap_or(default_baseline))
    };
    let cfg = LintConfig { paths, baseline: baseline.clone() };
    let report = analyze::run(&cfg)?;

    if args.flag("write-baseline") {
        let path = baseline.ok_or_else(|| anyhow!("--write-baseline needs a baseline path"))?;
        let all: Vec<&analyze::Finding> =
            report.findings.iter().chain(report.baselined.iter()).collect();
        std::fs::write(&path, analyze::baseline_json(&all).to_string() + "\n")?;
        println!(
            "lint: baseline rewritten with {} entr{} -> {}",
            all.len(),
            if all.len() == 1 { "y" } else { "ies" },
            path.display()
        );
        return Ok(());
    }

    if args.flag("json") {
        println!("{}", report.to_json().to_string());
    } else {
        for f in &report.findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
            println!("    {}", f.excerpt);
            if args.flag("fix-hints") {
                println!("    hint: {}", f.hint);
            }
        }
        for s in &report.suppressed {
            println!(
                "{}:{}: [{}] suppressed: {} ({})",
                s.finding.file, s.finding.line, s.finding.rule, s.finding.message, s.reason
            );
        }
        for e in &report.stale_baseline {
            println!("stale baseline entry: [{}] {} `{}`", e.rule, e.file, e.excerpt);
        }
        println!(
            "lint: {} file(s), {} finding(s), {} suppressed (audited), {} baselined, {} stale",
            report.files_scanned,
            report.findings.len(),
            report.suppressed.len(),
            report.baselined.len(),
            report.stale_baseline.len()
        );
    }
    if !report.clean() {
        bail!("lint: {} non-baselined finding(s)", report.findings.len());
    }
    Ok(())
}

/// `ampq worker` — one member of a distributed planning fleet.  Speaks
/// the length-prefixed JSON protocol on stdin/stdout (default) or dials
/// back to the coordinator's TCP listener (`--connect HOST:PORT`).
fn cmd_worker(args: &Args) -> Result<()> {
    match args.get("connect") {
        Some(addr) => ampq::dist::worker::serve_tcp(addr),
        None => {
            let stdin = std::io::stdin();
            let stdout = std::io::stdout();
            ampq::dist::worker::serve(stdin.lock(), stdout.lock())
        }
    }
}

/// `ampq fleet` — schedule the models x devices calibration + measurement
/// + frontier matrix over a worker fleet (`--workers 0` = in-process
/// reference path).  Artifacts land under --out; the summary goes to
/// stdout only, so output trees stay `diff -r`-comparable.
fn cmd_fleet(args: &Args) -> Result<()> {
    use ampq::dist::{DistConfig, FleetConfig};
    let split = |s: &str| -> Vec<String> {
        s.split(',').map(|x| x.trim().to_string()).filter(|x| !x.is_empty()).collect()
    };
    let dist = DistConfig {
        task_deadline: Duration::from_millis(args.u64_or("task-deadline", 30_000)?),
        max_retries: args.usize_or("max-retries", 3)?,
        retry_backoff: Duration::from_millis(args.u64_or("retry-backoff", 50)?),
        debug_kill_after: match args.get("debug-kill-after") {
            None => None,
            Some(v) => Some(v.parse().map_err(|e| anyhow!("--debug-kill-after: {e}"))?),
        },
        transport: match args.get_or("transport", "stdio") {
            "stdio" => ampq::dist::Transport::Stdio,
            "tcp" => ampq::dist::Transport::Tcp,
            t => bail!("unknown --transport '{t}' (stdio|tcp)"),
        },
        ..DistConfig::default()
    };
    let cfg = FleetConfig {
        models: split(args.get_or("models", "demo")),
        devices: split(args.get_or("devices", "gaudi2")),
        workers: args.usize_or("workers", 2)?,
        out: PathBuf::from(args.get_or("out", "fleet-out")),
        blocks: args.usize_or("blocks", 2)?,
        dist,
    };
    let t0 = Instant::now();
    let report = ampq::dist::run_fleet(&cfg)?;
    print!("{}", ampq::dist::render_summary(&report, cfg.workers));
    println!("total {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}

/// `ampq trace` — record a fully-traced demo run and export the span
/// tree as Chrome trace-event JSON.  Plans and sweeps a frontier on the
/// synthetic model; with `--workers N` it also runs one fleet cell so
/// worker-process spans are shipped back and stitched into the same
/// tree (artifacts go to a scratch dir that is removed afterwards).
fn cmd_trace(args: &Args) -> Result<()> {
    use ampq::obs;
    let out = PathBuf::from(args.get_or("out", "trace.json"));
    let workers = args.usize_or("workers", 0)?;
    let blocks = args.usize_or("blocks", 2)?;
    let tau = args.f64_or("tau", 0.004)?;
    check_budget("--tau", tau)?;
    let objective = parse_objective(args)?;
    obs::set_enabled(true);
    obs::clear();
    let spec = EngineSpec {
        root: PathBuf::from(args.get_or("artifacts", "artifacts")),
        fwd_mode: FwdMode::Ref,
        measure_seed: ampq::plan::engine::DEFAULT_MEASURE_SEED,
        reps: args.usize_or("reps", 5)?,
        no_cache: true,
        demo: true,
        blocks,
        demo_seed: args.u64_or("seed", 0)?,
        exec: match args.get("threads") {
            None => ExecCfg::from_env(),
            Some(_) => ExecCfg::new(args.usize_or("threads", 1)?),
        },
    };
    let mut engine = spec.engine(DeviceProfile::gaudi2());
    let trace_id = obs::fresh_trace_id();
    obs::with_trace(&trace_id, || -> Result<()> {
        let mut sp = obs::span("cli.trace");
        sp.counter("blocks", blocks as f64);
        sp.counter("workers", workers as f64);
        let planner = engine.planner("demo")?;
        let plan = planner.solve(&PlanRequest::new(objective).with_loss_budget(tau))?;
        println!("{}", plan.summary());
        let f = planner.frontier(objective, Strategy::Ip)?;
        println!(
            "frontier: {} Pareto points over tau in [0, {:.5}]",
            f.points.len(),
            f.tau_max
        );
        if workers > 0 {
            let tmp =
                std::env::temp_dir().join(format!("ampq-trace-{}", std::process::id()));
            let cfg = ampq::dist::FleetConfig {
                models: vec!["demo".into()],
                devices: vec!["gaudi2".into()],
                workers,
                out: tmp.clone(),
                blocks,
                dist: ampq::dist::DistConfig::default(),
            };
            let report = ampq::dist::run_fleet(&cfg);
            let _ = std::fs::remove_dir_all(&tmp);
            let report = report?;
            println!(
                "fleet cell: {} cell(s) over {workers} worker(s), {} task(s), {} retries",
                report.cells.len(),
                report.metrics.tasks,
                report.metrics.retries
            );
        }
        drop(sp);
        Ok(())
    })?;
    obs::write_chrome_trace(&out)?;
    println!(
        "trace {trace_id}: {} span(s) written to {} (open in Perfetto / about:tracing)",
        obs::snapshot().len(),
        out.display()
    );
    Ok(())
}

fn cmd_devices(registry: &Registry, json: bool) -> Result<()> {
    if json {
        let arr: Vec<Json> = registry.iter().map(|p| p.to_json()).collect();
        println!("{}", Json::Arr(arr).to_string());
        return Ok(());
    }
    println!(
        "{:<14} {:>4} {:>4} {:>12} {:>10} {:>10} {:>7} {:>7} {:>8} {:>10}  {}",
        "device", "mme", "tpc", "macs/us/mme", "tpc B/us", "hbm B/us", "launch", "fusion",
        "fp8-rate", "hbm-cap", "formats"
    );
    for p in registry.iter() {
        let formats: Vec<&str> = p.supported.iter().map(|f| f.name()).collect();
        println!(
            "{:<14} {:>4} {:>4} {:>12.0} {:>10.0} {:>10.0} {:>7.1} {:>7} {:>8.1} {:>9.0}G  {}",
            p.name,
            p.n_mme,
            p.n_tpc,
            p.mme_macs_per_us,
            p.tpc_bytes_per_us,
            p.hbm_bytes_per_us,
            p.launch_us,
            if p.enable_fusion { "yes" } else { "no" },
            p.mme_rate(Format::Fp8E4m3),
            p.hbm_capacity_bytes / 1e9,
            formats.join(",")
        );
    }
    println!("(use --device NAME on any command, or --device FILE.json for a custom profile)");
    Ok(())
}

fn cmd_compare(
    spec: &EngineSpec,
    registry: &Registry,
    model: &str,
    args: &Args,
    json: bool,
) -> Result<()> {
    let objective = parse_objective(args)?;
    let names = args
        .get("devices")
        .ok_or_else(|| anyhow!("compare needs --devices a,b,c (see `ampq devices`)"))?;
    let mut reports: Vec<(String, f64, Frontier)> = Vec::new();
    for spec_name in names.split(',') {
        let profile = registry.resolve(spec_name.trim())?;
        let mut engine = spec.engine(profile.clone());
        let planner = engine.planner(model)?;
        let frontier = planner.frontier(objective, Strategy::Ip)?;
        reports.push((profile.name, planner.measurements().base_ttft, frontier));
    }
    if json {
        let arr: Vec<Json> = reports
            .iter()
            .map(|(name, base, f)| {
                Json::Obj(vec![
                    ("device".into(), Json::Str(name.clone())),
                    ("base_ttft_us".into(), Json::Num(*base)),
                    ("frontier".into(), f.to_json()),
                ])
            })
            .collect();
        println!("{}", Json::Arr(arr).to_string());
        return Ok(());
    }

    println!("== cross-device comparison: {model}, {} (IP) ==", objective.name());
    println!(
        "{:<14} {:>14} {:>8} {:>10} {:>12}",
        "device", "base-TTFT[us]", "points", "tau_max", "max-gain"
    );
    for (name, base, f) in &reports {
        let max_gain = f.points.last().map(|p| p.gain).unwrap_or(0.0);
        println!(
            "{:<14} {:>14.1} {:>8} {:>10.5} {:>12.3}",
            name,
            base,
            f.points.len(),
            f.tau_max,
            max_gain
        );
    }

    // Side-by-side frontier: one row per paper tau, one column per device
    // showing the optimal gain (and quantized-layer count) at that budget.
    let mut header = format!("{:>8} |", "tau");
    for (name, _, _) in &reports {
        header.push_str(&format!(" {:>20} |", name));
    }
    println!("\n{header}");
    for tau in paper_tau_grid() {
        let mut row = format!("{tau:>8.4} |");
        for (_, _, f) in &reports {
            let p = f.at(tau);
            row.push_str(&format!(
                " {:>12.3} (nq {:>2}) |",
                p.gain,
                p.config.n_quantized()
            ));
        }
        println!("{row}");
    }
    println!(
        "(gain units: us of TTFT for {}; nq = layers quantized at that budget)",
        objective.name()
    );
    Ok(())
}

fn cmd_figures(engine: Engine, args: &Args, fwd_mode: FwdMode) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "results"));
    let mut params = if args.flag("quick") { ExpParams::quick() } else { ExpParams::default() };
    params.fwd_mode = fwd_mode;
    params.n_seeds = args.u64_or("seeds", params.n_seeds)?;
    // Figures run on whatever --device the engine was built for.
    params.device = engine.device().clone();
    let models: Vec<String> = args
        .get_or("models", "tiny-s,tiny-m")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let which = args.get_or("fig", "all").to_string();
    let mut ctx = FigureCtx::new(engine, params, out);

    for model in &models {
        if which == "all" || which == "1" {
            fig1::run(&mut ctx, model)?;
        }
        if which == "all" || which == "2" {
            fig2::run(&mut ctx, model)?;
        }
        if which == "all" || which == "3" || which == "3a" || which == "3b" {
            fig3::run(&mut ctx, model)?;
        }
        if which == "all" || which == "table1" || which == "4" || which == "5"
            || which == "7" || which == "8" || which == "9"
        {
            table1::run(&mut ctx, model)?;
        }
    }
    if which == "all" || which == "table1" {
        table1::combine(&ctx, &models)?;
    }
    println!("figures written to {}", ctx.out.display());
    Ok(())
}

fn cmd_ttft(engine: &mut Engine, model: &str, args: &Args) -> Result<()> {
    // Wall-clock TTFT of the REAL compiled forward on this host — proves the
    // measurement harness drives actual PJRT executables (secondary mode;
    // CPU fake-quant adds ops, so gains are not Gaudi-shaped).
    let info = engine.info(model)?;
    let root = engine
        .artifacts_root()
        .ok_or_else(|| anyhow!("ttft needs an artifacts root"))?
        .to_path_buf();
    let calib = info.load_calib(&root)?;
    let part = engine.partitioned(model)?;
    let mr = engine.runtime(model)?;
    let tokens: Vec<i32> = calib[..info.eval_b].concat();
    let src = WallTtft { mr, tokens, reps: args.usize_or("reps", 5)? };
    let base = src.measure(&MpConfig::all_bf16(info.n_qlayers), 0)?;
    let fp8 = src.measure(&MpConfig::uniform(info.n_qlayers, Format::Fp8E4m3), 1)?;
    println!(
        "model {model} [{}] wall-clock fwd: bf16-config {:.1} us, fp8-config {:.1} us / batch of {}",
        if mr.fwd_mode == FwdMode::Pallas { "pallas" } else { "ref" },
        base,
        fp8,
        info.eval_b
    );
    // Per-group measurement demo over the wall clock (paper Algorithm 1.3).
    // Wall-clock timing is contention-sensitive: always sequential, even
    // when --threads asks for a wide pool.
    let tm = measure_groups(&src, &part.partition, &part.formats, &ExecPool::sequential())?;
    println!("wall-clock per-group gains (us): ");
    for g in &tm.groups {
        let best = g.gains.iter().cloned().fold(f64::MIN, f64::max);
        println!("  group {:<2} ({} cfgs): max gain {:+.1}", g.group, g.gains.len(), best);
    }
    Ok(())
}
