//! Hardware model parameters and per-op roofline cost.

use crate::backend::{DeviceProfile, RateTable};
use crate::graph::{Engine, Node};
use crate::numerics::Format;

/// Simulator parameter block (defaults shaped after Gaudi 2's architecture:
/// 2 MME units, a TPC pool, HBM roofline; absolute rates are scaled to this
/// testbed — the paper's method only needs *relative* behaviour).  Any
/// device becomes a parameter block via [`HwModel::from_profile`].
#[derive(Clone, Debug, PartialEq)]
pub struct HwModel {
    /// Parallel matrix engines.
    pub n_mme: usize,
    /// Parallel vector engines.
    pub n_tpc: usize,
    /// BF16 MACs per microsecond per MME engine.
    pub mme_macs_per_us: f64,
    /// Vector-engine processed bytes per microsecond per TPC engine.
    pub tpc_bytes_per_us: f64,
    /// HBM bandwidth, bytes per microsecond (shared).
    pub hbm_bytes_per_us: f64,
    /// Kernel launch overhead, microseconds (fused chains pay once).
    pub launch_us: f64,
    /// Multiplicative std-dev of measurement noise.
    pub noise_std: f64,
    /// Elementwise-chain fusion on the vector engine (ablation toggle).
    pub enable_fusion: bool,
    /// Per-format MME throughput multipliers vs BF16 (device data — the
    /// old `Format::mme_rate` hard-coding).
    pub mme_rates: RateTable,
}

impl Default for HwModel {
    fn default() -> Self {
        HwModel {
            n_mme: 2,
            n_tpc: 4,
            mme_macs_per_us: 100_000.0,
            tpc_bytes_per_us: 12_000.0,
            hbm_bytes_per_us: 40_000.0,
            launch_us: 1.5,
            noise_std: 0.01,
            enable_fusion: true,
            mme_rates: RateTable::gaudi2(),
        }
    }
}

impl HwModel {
    /// The simulator parameters of a device profile.
    pub fn from_profile(p: &DeviceProfile) -> HwModel {
        HwModel {
            n_mme: p.n_mme,
            n_tpc: p.n_tpc,
            mme_macs_per_us: p.mme_macs_per_us,
            tpc_bytes_per_us: p.tpc_bytes_per_us,
            hbm_bytes_per_us: p.hbm_bytes_per_us,
            launch_us: p.launch_us,
            noise_std: p.noise_std,
            enable_fusion: p.enable_fusion,
            mme_rates: p.mme_rates,
        }
    }

    /// Duration of one node executed in `fmt` (quantizable nodes only use
    /// fmt; others are BF16 by construction), EXCLUDING launch overhead
    /// (the scheduler adds it, once per fused chain).
    pub fn op_time_us(&self, node: &Node, fmt: Format) -> f64 {
        match node.engine {
            Engine::Mme => {
                let compute = node.macs as f64 / (self.mme_macs_per_us * self.mme_rates.get(fmt));
                // Operands (activations in + weights) move at the format's
                // byte width; outputs are produced at BF16.
                let ratio = fmt.bytes() as f64 / Format::Bf16.bytes() as f64;
                let bytes = (node.bytes_in + node.param_bytes) as f64 * ratio
                    + node.bytes_out as f64;
                let mem = bytes / self.hbm_bytes_per_us;
                compute.max(mem)
            }
            Engine::Tpc => {
                let work = (node.bytes_in + node.bytes_out) as f64 / self.tpc_bytes_per_us;
                let mem = (node.bytes_in + node.bytes_out) as f64 / self.hbm_bytes_per_us;
                work.max(mem)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::testutil::n;

    #[test]
    fn fp8_speeds_up_mme() {
        let hw = HwModel::default();
        let mut node = n("l", 0);
        node.macs = 10_000_000; // compute-bound
        let t_bf16 = hw.op_time_us(&node, Format::Bf16);
        let t_fp8 = hw.op_time_us(&node, Format::Fp8E4m3);
        assert!((t_bf16 / t_fp8 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn memory_bound_op_gains_less_than_2x() {
        let hw = HwModel::default();
        let mut node = n("l", 0);
        node.macs = 1000; // trivial compute
        node.bytes_in = 1_000_000;
        node.bytes_out = 1_000_000;
        node.param_bytes = 0;
        let t_bf16 = hw.op_time_us(&node, Format::Bf16);
        let t_fp8 = hw.op_time_us(&node, Format::Fp8E4m3);
        assert!(t_fp8 < t_bf16);
        // Output bytes unchanged -> speedup strictly below 2x.
        assert!(t_bf16 / t_fp8 < 2.0);
    }

    #[test]
    fn tpc_ignores_format() {
        let hw = HwModel::default();
        let node = n("sm", -1); // tpc
        assert_eq!(
            hw.op_time_us(&node, Format::Bf16),
            hw.op_time_us(&node, Format::Fp8E4m3)
        );
    }

    #[test]
    fn gaudi2_profile_is_the_default_model() {
        // The gaudi2 built-in must reproduce the pre-backend defaults
        // exactly, field for field.
        assert_eq!(HwModel::from_profile(&DeviceProfile::gaudi2()), HwModel::default());
    }

    #[test]
    fn cpu_profile_removes_the_fp8_speedup() {
        let hw = HwModel::from_profile(&DeviceProfile::cpu_roofline());
        let mut node = n("l", 0);
        node.macs = 10_000_000; // compute-bound on the weak CPU MME
        assert_eq!(
            hw.op_time_us(&node, Format::Bf16),
            hw.op_time_us(&node, Format::Fp8E4m3)
        );
    }

    #[test]
    fn times_positive_monotone_in_work() {
        let hw = HwModel::default();
        let mut a = n("a", 0);
        let mut b = n("b", 1);
        a.macs = 1_000_000;
        b.macs = 2_000_000;
        assert!(hw.op_time_us(&a, Format::Bf16) > 0.0);
        assert!(hw.op_time_us(&b, Format::Bf16) > hw.op_time_us(&a, Format::Bf16));
    }
}
