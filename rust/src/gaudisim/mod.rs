//! Gaudi-2-like timing simulator (DESIGN.md §3 substitution).
//!
//! The paper measures empirical time gain on an Intel Gaudi 2; this image
//! has no accelerator, so we simulate the *phenomenon* the paper's method
//! exploits (§2.3.1 / Fig. 1):
//!
//!   * per-op roofline: time = max(compute, memory) + launch overhead,
//!     with FP8 running 2x MAC rate on the matrix engines and moving half
//!     the operand bytes;
//!   * a list scheduler over the full DAG with a small pool of parallel
//!     MME and TPC engines — concurrent layers inside a branched sub-graph
//!     overlap, so per-layer time gains do NOT add within a group;
//!   * elementwise-chain fusion on the vector engine (single launch,
//!     intermediates stay on-chip) — the "compiler is free to fuse" effect;
//!   * multiplicative measurement noise on every TTFT sample.
//!
//! Sequential sub-graphs, by contrast, cannot overlap (data dependency), so
//! their gained times DO add — exactly the paper's additivity structure.

pub mod hw;
pub mod schedule;

pub use hw::HwModel;
pub use schedule::Simulator;

use crate::numerics::Format;

/// A full-model MP configuration: one format per quantizable layer.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct MpConfig(pub Vec<Format>);

impl MpConfig {
    pub fn uniform(n: usize, f: Format) -> MpConfig {
        MpConfig(vec![f; n])
    }

    pub fn all_bf16(n: usize) -> MpConfig {
        Self::uniform(n, Format::Bf16)
    }

    pub fn get(&self, qidx: usize) -> Format {
        self.0[qidx]
    }

    pub fn set(&mut self, qidx: usize, f: Format) {
        self.0[qidx] = f;
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Count of layers not at the baseline format.
    pub fn n_quantized(&self) -> usize {
        self.0.iter().filter(|&&f| f != Format::Bf16).count()
    }

    /// Mantissa-bit vector for the compiled HLO's `mbits` input.
    pub fn mbits_f32(&self) -> Vec<f32> {
        self.0.iter().map(|f| f.mbits() as f32).collect()
    }

    /// Compact human-readable tag, e.g. "01101" (paper Fig. 1 labels:
    /// 0 = BF16, 1 = FP8).
    pub fn bits_label(&self) -> String {
        self.0
            .iter()
            .map(|f| if *f == Format::Bf16 { '0' } else { '1' })
            .collect()
    }
}

/// Enumerate all F^L configurations of `formats` over `layer_count` slots
/// (the columns of the paper's Q_j matrix), in lexicographic order with the
/// LAST layer varying fastest.
pub fn enumerate_configs(formats: &[Format], layer_count: usize) -> Vec<Vec<Format>> {
    let f = formats.len();
    let total = f.pow(layer_count as u32);
    let mut out = Vec::with_capacity(total);
    for p in 0..total {
        let mut cfg = Vec::with_capacity(layer_count);
        for l in 0..layer_count {
            let digit = (p / f.pow((layer_count - 1 - l) as u32)) % f;
            cfg.push(formats[digit]);
        }
        out.push(cfg);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_basics() {
        let mut c = MpConfig::all_bf16(3);
        assert_eq!(c.n_quantized(), 0);
        c.set(1, Format::Fp8E4m3);
        assert_eq!(c.n_quantized(), 1);
        assert_eq!(c.bits_label(), "010");
        assert_eq!(c.mbits_f32(), vec![7.0, 3.0, 7.0]);
    }

    #[test]
    fn enumerate_counts() {
        let fs = [Format::Bf16, Format::Fp8E4m3];
        let cfgs = enumerate_configs(&fs, 5);
        assert_eq!(cfgs.len(), 32);
        // All distinct.
        let mut labels: Vec<String> = cfgs
            .iter()
            .map(|c| MpConfig(c.clone()).bits_label())
            .collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), 32);
    }

    #[test]
    fn enumerate_order_last_fastest() {
        let fs = [Format::Bf16, Format::Fp8E4m3];
        let cfgs = enumerate_configs(&fs, 2);
        let labels: Vec<String> = cfgs.iter().map(|c| MpConfig(c.clone()).bits_label()).collect();
        assert_eq!(labels, vec!["00", "01", "10", "11"]);
    }
}
