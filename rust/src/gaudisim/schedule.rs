//! DAG list-scheduler: engine pools, elementwise fusion, TTFT measurement.

use super::hw::HwModel;
use super::MpConfig;
use crate::backend::DeviceProfile;
use crate::graph::{Engine, Graph};
use crate::numerics::Format;
use crate::util::Rng;

/// Simulator bound to one model graph.
pub struct Simulator<'g> {
    pub hw: HwModel,
    graph: &'g Graph,
    topo: Vec<usize>,
    preds: Vec<Vec<usize>>,
    succ: Vec<Vec<usize>>,
    /// Indegree of each node over the full edge set (cloned per makespan
    /// call to drive the ready list).
    indeg0: Vec<u32>,
    /// Topo rank (deterministic tie-break).
    rank: Vec<usize>,
    /// fused[v]: v is a TPC op absorbed into its single TPC predecessor's
    /// kernel (no launch, input stays on-chip).
    fused: Vec<bool>,
}

impl<'g> Simulator<'g> {
    pub fn new(graph: &'g Graph, hw: HwModel) -> Simulator<'g> {
        let topo = graph.topo_order(true).expect("acyclic");
        let preds = graph.predecessors(true);
        let succ = graph.successors(true);
        let fused = (0..graph.nodes.len())
            .map(|v| {
                hw.enable_fusion
                    && graph.nodes[v].engine == Engine::Tpc
                    && preds[v].len() == 1
                    && graph.nodes[preds[v][0]].engine == Engine::Tpc
                    && succ[preds[v][0]].len() == 1
            })
            .collect();
        let indeg0 = preds.iter().map(|p| p.len() as u32).collect();
        let mut rank = vec![0usize; graph.nodes.len()];
        for (r, &v) in topo.iter().enumerate() {
            rank[v] = r;
        }
        Simulator { hw, graph, topo, preds, succ, indeg0, rank, fused }
    }

    /// Simulator parameterized by a device profile (see `backend`).
    pub fn for_device(graph: &'g Graph, device: &DeviceProfile) -> Simulator<'g> {
        Simulator::new(graph, HwModel::from_profile(device))
    }

    pub fn graph(&self) -> &Graph {
        self.graph
    }

    fn duration(&self, v: usize, cfg: &MpConfig) -> f64 {
        let node = &self.graph.nodes[v];
        let fmt = if node.qidx >= 0 { cfg.get(node.qidx as usize) } else { Format::Bf16 };
        let mut t = self.hw.op_time_us(node, fmt);
        if self.fused[v] {
            // Input arrives on-chip from the fused predecessor: only the
            // output side of the vector work remains.
            let saved = node.bytes_in as f64
                / self.hw.tpc_bytes_per_us.min(self.hbm());
            t = (t - saved).max(node.bytes_out as f64 / self.hbm());
        } else {
            t += self.hw.launch_us;
        }
        t
    }

    fn hbm(&self) -> f64 {
        self.hw.hbm_bytes_per_us
    }

    /// Deterministic makespan (us) of the full graph under `cfg` — the
    /// noise-free TTFT.  Greedy list scheduling: repeatedly place the
    /// schedulable node with the earliest (start, topo-rank) on the engine
    /// instance that can start it first.
    ///
    /// §Perf: ready-list + indegree tracking — each iteration scans only the
    /// currently-ready nodes (a handful) instead of the whole node set;
    /// selection semantics are identical to the reference scan
    /// (`makespan_scan`, kept for the bench regression check).
    pub fn makespan(&self, cfg: &MpConfig) -> f64 {
        let n = self.graph.nodes.len();
        debug_assert_eq!(cfg.len(), self.graph.qlayers.len());
        let mut finish = vec![0.0f64; n];
        let mut indeg = self.indeg0.clone();
        let mut mme = vec![0.0f64; self.hw.n_mme];
        let mut tpc = vec![0.0f64; self.hw.n_tpc];
        // ready holds (ready_time = max pred finish, node).
        let mut ready: Vec<(f64, usize)> = (0..n)
            .filter(|&v| indeg[v] == 0)
            .map(|v| (0.0, v))
            .collect();
        let mut makespan = 0.0f64;

        while !ready.is_empty() {
            // Pick the ready node with the earliest (start, rank).
            let mut best_i = 0usize;
            let mut best_key = (f64::MAX, usize::MAX);
            for (i, &(rt, v)) in ready.iter().enumerate() {
                let pool = match self.graph.nodes[v].engine {
                    Engine::Mme => &mme,
                    Engine::Tpc => &tpc,
                };
                let engine_free = pool.iter().cloned().fold(f64::MAX, f64::min);
                let key = (rt.max(engine_free), self.rank[v]);
                if key < best_key {
                    best_key = key;
                    best_i = i;
                }
            }
            let (_, v) = ready.swap_remove(best_i);
            let start = best_key.0;
            let pool = match self.graph.nodes[v].engine {
                Engine::Mme => &mut mme,
                Engine::Tpc => &mut tpc,
            };
            let (ei, _) = pool
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .unwrap();
            let end = start + self.duration(v, cfg);
            pool[ei] = end;
            finish[v] = end;
            makespan = makespan.max(end);
            for &w in &self.succ[v] {
                indeg[w] -= 1;
                if indeg[w] == 0 {
                    let rt = self.preds[w].iter().map(|&p| finish[p]).fold(0.0f64, f64::max);
                    ready.push((rt, w));
                }
            }
        }
        makespan
    }

    /// Reference O(n^2)-scan implementation (pre-optimization); retained so
    /// bench_sim can verify the ready-list version is equivalent and faster.
    pub fn makespan_scan(&self, cfg: &MpConfig) -> f64 {
        let n = self.graph.nodes.len();
        let mut finish = vec![f64::NAN; n];
        let mut scheduled = vec![false; n];
        let mut mme = vec![0.0f64; self.hw.n_mme];
        let mut tpc = vec![0.0f64; self.hw.n_tpc];
        let mut remaining = n;
        while remaining > 0 {
            let mut best: Option<(f64, usize, usize)> = None;
            for &v in &self.topo {
                if scheduled[v] || self.preds[v].iter().any(|&p| !scheduled[p]) {
                    continue;
                }
                let ready = self.preds[v].iter().map(|&p| finish[p]).fold(0.0f64, f64::max);
                let pool = match self.graph.nodes[v].engine {
                    Engine::Mme => &mme,
                    Engine::Tpc => &tpc,
                };
                let engine_free = pool.iter().cloned().fold(f64::MAX, f64::min);
                let start = ready.max(engine_free);
                let cand = (start, self.rank[v], v);
                if best.map_or(true, |b| (cand.0, cand.1) < (b.0, b.1)) {
                    best = Some(cand);
                }
            }
            let (start, _, v) = best.expect("schedulable node exists (acyclic)");
            let pool = match self.graph.nodes[v].engine {
                Engine::Mme => &mut mme,
                Engine::Tpc => &mut tpc,
            };
            let (ei, _) = pool
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .unwrap();
            let t = self.duration(v, cfg);
            let end = start + t;
            pool[ei] = end;
            finish[v] = end;
            scheduled[v] = true;
            remaining -= 1;
        }
        finish.iter().cloned().fold(0.0, f64::max)
    }

    /// One noisy TTFT sample (paper: wall-clock measurement).
    pub fn ttft_sample(&self, cfg: &MpConfig, rng: &mut Rng) -> f64 {
        let m = self.makespan(cfg);
        m * (1.0 + self.hw.noise_std * rng.normal()).max(0.5)
    }

    /// Averaged measurement over `reps` iterations (paper uses 5).
    pub fn measure_ttft(&self, cfg: &MpConfig, rng: &mut Rng, reps: usize) -> f64 {
        let xs: Vec<f64> = (0..reps).map(|_| self.ttft_sample(cfg, rng)).collect();
        crate::util::stats::mean(&xs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gaudisim::enumerate_configs;
    use crate::graph::partition::partition;
    use crate::graph::testutil::n;
    use crate::graph::Graph;
    use crate::numerics::{Format, PAPER_FORMATS};

    fn attention_like() -> Graph {
        // s -> {q, k, v}; q,k -> qk -> sm -> av; v -> av; av -> o -> t
        let mut nodes = vec![
            n("s", -1), n("q", 0), n("k", 1), n("v", 2), n("qk", 3),
            n("sm", -1), n("av", 4), n("o", 5), n("t", -1),
        ];
        for nd in nodes.iter_mut() {
            if nd.qidx >= 0 {
                nd.macs = 2_000_000;
                nd.bytes_in = 20_000;
                nd.bytes_out = 20_000;
                nd.param_bytes = 50_000;
            }
        }
        let edges = vec![
            (0, 1), (0, 2), (0, 3), (1, 4), (2, 4), (4, 5), (5, 6), (3, 6), (6, 7), (7, 8),
        ];
        Graph::synthetic(nodes, edges)
    }

    fn nonoise() -> HwModel {
        HwModel { noise_std: 0.0, ..HwModel::default() }
    }

    #[test]
    fn fp8_reduces_makespan() {
        let g = attention_like();
        let sim = Simulator::new(&g, nonoise());
        let base = sim.makespan(&MpConfig::all_bf16(6));
        let fp8 = sim.makespan(&MpConfig::uniform(6, Format::Fp8E4m3));
        assert!(fp8 < base, "fp8 {fp8} !< bf16 {base}");
        assert!(base > 0.0);
    }

    #[test]
    fn deterministic() {
        let g = attention_like();
        let sim = Simulator::new(&g, nonoise());
        let c = MpConfig::all_bf16(6);
        assert_eq!(sim.makespan(&c), sim.makespan(&c));
    }

    #[test]
    fn monotone_quantizing_never_hurts() {
        // Quantizing one more layer can only shrink (or keep) the makespan
        // in this model (per-op durations shrink, scheduler is greedy —
        // check empirically over all configs of the attention graph).
        let g = attention_like();
        let sim = Simulator::new(&g, nonoise());
        for cfg in enumerate_configs(&PAPER_FORMATS, 6) {
            let t = sim.makespan(&MpConfig(cfg.clone()));
            for l in 0..6 {
                if cfg[l] == Format::Bf16 {
                    let mut c2 = cfg.clone();
                    c2[l] = Format::Fp8E4m3;
                    let t2 = sim.makespan(&MpConfig(c2));
                    assert!(t2 <= t * 1.02, "quantizing layer {l} slowed {t} -> {t2}");
                }
            }
        }
    }

    #[test]
    fn per_layer_gains_not_additive_within_branched_group() {
        // The Fig. 1 phenomenon: sum of per-layer gains != group gain.
        let g = attention_like();
        let sim = Simulator::new(&g, nonoise());
        let nq = 6;
        let base = sim.makespan(&MpConfig::all_bf16(nq));
        let mut sum_gains = 0.0;
        for l in 0..3 {
            // q, k, v — the concurrent trio
            let mut c = MpConfig::all_bf16(nq);
            c.set(l, Format::Fp8E4m3);
            sum_gains += base - sim.makespan(&c);
        }
        let mut call = MpConfig::all_bf16(nq);
        for l in 0..3 {
            call.set(l, Format::Fp8E4m3);
        }
        let group_gain = base - sim.makespan(&call);
        let rel_gap = (sum_gains - group_gain).abs() / group_gain.max(1e-9);
        assert!(rel_gap > 0.10, "expected non-additivity, gap {rel_gap}");
    }

    #[test]
    fn gains_additive_across_sequential_groups() {
        // Chain of two independent linear stages: gains add (within a few %).
        let mut nodes = vec![n("s", -1), n("a", 0), n("m", -1), n("b", 1), n("t", -1)];
        for nd in nodes.iter_mut() {
            if nd.qidx >= 0 {
                nd.macs = 3_000_000;
            }
        }
        let g = Graph::synthetic(nodes, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let sim = Simulator::new(&g, nonoise());
        let base = sim.makespan(&MpConfig::all_bf16(2));
        let mut ca = MpConfig::all_bf16(2);
        ca.set(0, Format::Fp8E4m3);
        let mut cb = MpConfig::all_bf16(2);
        cb.set(1, Format::Fp8E4m3);
        let sum = (base - sim.makespan(&ca)) + (base - sim.makespan(&cb));
        let both = base - sim.makespan(&MpConfig::uniform(2, Format::Fp8E4m3));
        assert!((sum - both).abs() / both < 0.05, "sum {sum} vs both {both}");
    }

    #[test]
    fn readylist_equals_reference_scan() {
        // §Perf: the optimized scheduler must be semantically identical to
        // the reference implementation on every config.
        let g = attention_like();
        let sim = Simulator::new(&g, nonoise());
        for cfg in enumerate_configs(&PAPER_FORMATS, 6) {
            let c = MpConfig(cfg);
            assert_eq!(sim.makespan(&c), sim.makespan_scan(&c));
        }
    }

    #[test]
    fn noise_averages_to_truth() {
        let g = attention_like();
        let sim = Simulator::new(&g, HwModel { noise_std: 0.05, ..HwModel::default() });
        let truth = sim.makespan(&MpConfig::all_bf16(6));
        let mut rng = Rng::new(0);
        let measured = sim.measure_ttft(&MpConfig::all_bf16(6), &mut rng, 200);
        assert!((measured - truth).abs() / truth < 0.02);
    }

    #[test]
    fn partition_groups_are_time_additive() {
        // Partition the attention-like graph, then check group-gain
        // additivity (the paper's §3.2 validation, noise-free).
        let g = attention_like();
        let p = partition(&g).unwrap();
        assert!(p.groups.len() >= 2);
        let sim = Simulator::new(&g, nonoise());
        let nq = 6;
        let base = sim.makespan(&MpConfig::all_bf16(nq));
        let mut sum = 0.0;
        for gr in &p.groups {
            let mut c = MpConfig::all_bf16(nq);
            for &q in &gr.qidxs {
                c.set(q, Format::Fp8E4m3);
            }
            sum += base - sim.makespan(&c);
        }
        let all = base - sim.makespan(&MpConfig::uniform(nq, Format::Fp8E4m3));
        assert!((sum - all).abs() / all < 0.08, "sum {sum} vs all {all}");
    }
}
