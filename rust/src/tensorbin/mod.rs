//! .tbin named-tensor container — reader/writer mirroring
//! python/compile/tensorbin.py (see that file for the layout spec).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 6] = b"TBIN1\0";

/// A named tensor loaded from (or destined for) a .tbin file.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32 { data, .. } => data.len(),
            Tensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }
}

/// Ordered collection of named tensors (order preserved from the file).
#[derive(Clone, Debug, Default)]
pub struct TensorFile {
    pub names: Vec<String>,
    pub tensors: BTreeMap<String, Tensor>,
}

impl TensorFile {
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not found"))
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.tensors.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.tensors.insert(name.to_string(), t);
    }
}

fn rd_u16(b: &[u8], off: &mut usize) -> Result<u16> {
    let v = b
        .get(*off..*off + 2)
        .ok_or_else(|| anyhow!("truncated .tbin at {off:?}"))?;
    *off += 2;
    Ok(u16::from_le_bytes([v[0], v[1]]))
}

fn rd_u32(b: &[u8], off: &mut usize) -> Result<u32> {
    let v = b
        .get(*off..*off + 4)
        .ok_or_else(|| anyhow!("truncated .tbin at {off:?}"))?;
    *off += 4;
    Ok(u32::from_le_bytes([v[0], v[1], v[2], v[3]]))
}

pub fn read(path: &Path) -> Result<TensorFile> {
    let data = std::fs::read(path).map_err(|e| anyhow!("read {}: {e}", path.display()))?;
    if data.len() < 10 || &data[..6] != MAGIC {
        bail!("{}: bad .tbin magic", path.display());
    }
    let mut off = 6usize;
    let count = rd_u32(&data, &mut off)?;
    let mut out = TensorFile::default();
    for _ in 0..count {
        let nlen = rd_u16(&data, &mut off)? as usize;
        let name = std::str::from_utf8(
            data.get(off..off + nlen).ok_or_else(|| anyhow!("truncated name"))?,
        )?
        .to_string();
        off += nlen;
        let dtype = *data.get(off).ok_or_else(|| anyhow!("truncated dtype"))?;
        let ndim = *data.get(off + 1).ok_or_else(|| anyhow!("truncated ndim"))?;
        off += 2;
        let mut dims = Vec::with_capacity(ndim as usize);
        for _ in 0..ndim {
            dims.push(rd_u32(&data, &mut off)? as usize);
        }
        let n: usize = dims.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
        let bytes = data
            .get(off..off + 4 * n)
            .ok_or_else(|| anyhow!("truncated payload for '{name}'"))?;
        off += 4 * n;
        let tensor = match dtype {
            0 => Tensor::F32 {
                dims,
                data: bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            },
            1 => Tensor::I32 {
                dims,
                data: bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            },
            d => bail!("{name}: unknown dtype {d}"),
        };
        out.insert(&name, tensor);
    }
    Ok(out)
}

pub fn write(path: &Path, tf: &TensorFile) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(tf.names.len() as u32).to_le_bytes())?;
    for name in &tf.names {
        let t = &tf.tensors[name];
        f.write_all(&(name.len() as u16).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        let (dtype, dims): (u8, &[usize]) = match t {
            Tensor::F32 { dims, .. } => (0, dims),
            Tensor::I32 { dims, .. } => (1, dims),
        };
        f.write_all(&[dtype, dims.len() as u8])?;
        for d in dims {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        match t {
            Tensor::F32 { data, .. } => {
                for x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            Tensor::I32 { data, .. } => {
                for x in data {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("ampq_tbin_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let mut tf = TensorFile::default();
        tf.insert("a", Tensor::F32 { dims: vec![2, 3], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] });
        tf.insert("b", Tensor::I32 { dims: vec![4], data: vec![-1, 0, 1, 2] });
        let p = tmp("roundtrip");
        write(&p, &tf).unwrap();
        let back = read(&p).unwrap();
        assert_eq!(back.names, vec!["a", "b"]);
        assert_eq!(back.get("a").unwrap(), &tf.tensors["a"]);
        assert_eq!(back.get("b").unwrap(), &tf.tensors["b"]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let p = tmp("badmagic");
        std::fs::write(&p, b"NOTBIN\x00\x00\x00\x00").unwrap();
        assert!(read(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncation_rejected() {
        let mut tf = TensorFile::default();
        tf.insert("x", Tensor::F32 { dims: vec![8], data: vec![0.0; 8] });
        let p = tmp("trunc");
        write(&p, &tf).unwrap();
        let mut raw = std::fs::read(&p).unwrap();
        raw.truncate(raw.len() - 5);
        std::fs::write(&p, &raw).unwrap();
        assert!(read(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_tensor_error() {
        let tf = TensorFile::default();
        assert!(tf.get("nope").is_err());
    }

    #[test]
    fn dtype_mismatch_error() {
        let t = Tensor::F32 { dims: vec![1], data: vec![0.0] };
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }
}
