//! Model computation DAG — loaded from artifacts/<model>/graph.json.
//!
//! Feeds the partitioner (the paper's Algorithm 2) and the gaudisim timing
//! model.  Residual skip edges are kept separately: the paper's Fig. 6
//! partitions the graph "with residual adds omitted", while the timing
//! simulation uses the full edge set.

pub mod partition;

use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Matrix engine (Gaudi MME / TPU MXU analog) — linear + BGEMM ops.
    Mme,
    /// Vector engine (Gaudi TPC / TPU VPU analog) — everything else.
    Tpc,
}

#[derive(Clone, Debug)]
pub struct Node {
    pub id: String,
    pub kind: String,
    pub engine: Engine,
    /// Index into the model's quantizable-layer table, or -1.
    pub qidx: i32,
    pub macs: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub param_bytes: u64,
    /// Linear/BGEMM contraction dims (0 for non-quantizable ops).
    pub c: usize,
    pub k: usize,
}

impl Node {
    pub fn quantizable(&self) -> bool {
        self.qidx >= 0
    }
}

#[derive(Clone, Debug)]
pub struct Graph {
    pub model: String,
    pub eval_b: usize,
    pub seq: usize,
    pub nodes: Vec<Node>,
    /// Main dataflow edges (node indices).
    pub edges: Vec<(usize, usize)>,
    /// Residual skip edges (node indices) — excluded by the partitioner.
    pub residual_edges: Vec<(usize, usize)>,
    pub qlayers: Vec<String>,
    pub qkinds: Vec<String>,
    index: HashMap<String, usize>,
}

impl Graph {
    pub fn from_json(j: &Json) -> Result<Graph> {
        let mut nodes = Vec::new();
        let mut index = HashMap::new();
        for nj in j.get("nodes")?.arr()? {
            let id = nj.get("id")?.str()?.to_string();
            let engine = match nj.get("engine")?.str()? {
                "mme" => Engine::Mme,
                "tpc" => Engine::Tpc,
                e => bail!("unknown engine '{e}'"),
            };
            index.insert(id.clone(), nodes.len());
            nodes.push(Node {
                id,
                kind: nj.get("kind")?.str()?.to_string(),
                engine,
                qidx: nj.get("qidx")?.i64()? as i32,
                macs: nj.get("macs")?.f64()? as u64,
                bytes_in: nj.get("bytes_in")?.f64()? as u64,
                bytes_out: nj.get("bytes_out")?.f64()? as u64,
                param_bytes: nj.get("param_bytes")?.f64()? as u64,
                c: nj.get("c")?.usize()?,
                k: nj.get("k")?.usize()?,
            });
        }
        let read_edges = |key: &str| -> Result<Vec<(usize, usize)>> {
            let mut out = Vec::new();
            for e in j.get(key)?.arr()? {
                let pair = e.arr()?;
                let s = pair[0].str()?;
                let d = pair[1].str()?;
                let si = *index.get(s).ok_or_else(|| anyhow!("edge src '{s}' unknown"))?;
                let di = *index.get(d).ok_or_else(|| anyhow!("edge dst '{d}' unknown"))?;
                out.push((si, di));
            }
            Ok(out)
        };
        let g = Graph {
            model: j.get("model")?.str()?.to_string(),
            eval_b: j.get("eval_b")?.usize()?,
            seq: j.get("seq")?.usize()?,
            edges: read_edges("edges")?,
            residual_edges: read_edges("residual_edges")?,
            qlayers: j.get("qlayers")?.arr()?.iter().map(|x| Ok(x.str()?.to_string())).collect::<Result<_>>()?,
            qkinds: j.get("qkinds")?.arr()?.iter().map(|x| Ok(x.str()?.to_string())).collect::<Result<_>>()?,
            nodes,
            index,
        };
        g.check()?;
        Ok(g)
    }

    pub fn load(path: &std::path::Path) -> Result<Graph> {
        Graph::from_json(&Json::parse_file(path)?)
    }

    /// Exact inverse of [`Graph::from_json`]: the emitted JSON parses back
    /// into an identical graph (the node `index` is rebuilt on parse).
    /// This is what lets a distributed coordinator ship a graph to worker
    /// processes and have both sides time the SAME model bit-for-bit.
    pub fn to_json(&self) -> Json {
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                Json::Obj(vec![
                    ("id".into(), Json::Str(n.id.clone())),
                    ("kind".into(), Json::Str(n.kind.clone())),
                    (
                        "engine".into(),
                        Json::Str(match n.engine {
                            Engine::Mme => "mme".into(),
                            Engine::Tpc => "tpc".into(),
                        }),
                    ),
                    ("qidx".into(), Json::Num(n.qidx as f64)),
                    ("macs".into(), Json::Num(n.macs as f64)),
                    ("bytes_in".into(), Json::Num(n.bytes_in as f64)),
                    ("bytes_out".into(), Json::Num(n.bytes_out as f64)),
                    ("param_bytes".into(), Json::Num(n.param_bytes as f64)),
                    ("c".into(), Json::Num(n.c as f64)),
                    ("k".into(), Json::Num(n.k as f64)),
                ])
            })
            .collect();
        let pairs = |edges: &[(usize, usize)]| {
            Json::Arr(
                edges
                    .iter()
                    .map(|&(s, d)| {
                        Json::Arr(vec![
                            Json::Str(self.nodes[s].id.clone()),
                            Json::Str(self.nodes[d].id.clone()),
                        ])
                    })
                    .collect(),
            )
        };
        let strs = |xs: &[String]| {
            Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
        };
        Json::Obj(vec![
            ("model".into(), Json::Str(self.model.clone())),
            ("eval_b".into(), Json::Num(self.eval_b as f64)),
            ("seq".into(), Json::Num(self.seq as f64)),
            ("nodes".into(), Json::Arr(nodes)),
            ("edges".into(), pairs(&self.edges)),
            ("residual_edges".into(), pairs(&self.residual_edges)),
            ("qlayers".into(), strs(&self.qlayers)),
            ("qkinds".into(), strs(&self.qkinds)),
        ])
    }

    /// Construct directly (tests / synthetic graphs).
    pub fn synthetic(nodes: Vec<Node>, edges: Vec<(usize, usize)>) -> Graph {
        let index = nodes.iter().enumerate().map(|(i, n)| (n.id.clone(), i)).collect();
        let qlayers = nodes.iter().filter(|n| n.quantizable()).map(|n| n.id.clone()).collect();
        let qkinds = nodes.iter().filter(|n| n.quantizable()).map(|n| n.kind.clone()).collect();
        Graph {
            model: "synthetic".into(),
            eval_b: 1,
            seq: 1,
            nodes,
            edges,
            residual_edges: vec![],
            qlayers,
            qkinds,
            index,
        }
    }

    fn check(&self) -> Result<()> {
        // qidx must biject onto [0, n_q).
        let mut seen = vec![false; self.qlayers.len()];
        for n in &self.nodes {
            if n.qidx >= 0 {
                let q = n.qidx as usize;
                if q >= seen.len() || seen[q] {
                    bail!("bad qidx {} on node {}", n.qidx, n.id);
                }
                seen[q] = true;
                if self.qlayers[q] != n.id {
                    bail!("qidx {} maps to '{}' but qlayers says '{}'", q, n.id, self.qlayers[q]);
                }
            }
        }
        if !seen.iter().all(|&x| x) {
            bail!("not all quantizable layers present in graph");
        }
        if self.topo_order(true).is_none() {
            bail!("graph has a cycle");
        }
        Ok(())
    }

    pub fn node_index(&self, id: &str) -> Result<usize> {
        self.index.get(id).copied().ok_or_else(|| anyhow!("node '{id}' unknown"))
    }

    /// Adjacency list; `with_residual` includes skip edges.
    pub fn successors(&self, with_residual: bool) -> Vec<Vec<usize>> {
        let mut adj = vec![Vec::new(); self.nodes.len()];
        for &(s, d) in &self.edges {
            adj[s].push(d);
        }
        if with_residual {
            for &(s, d) in &self.residual_edges {
                adj[s].push(d);
            }
        }
        adj
    }

    pub fn predecessors(&self, with_residual: bool) -> Vec<Vec<usize>> {
        let mut adj = vec![Vec::new(); self.nodes.len()];
        for &(s, d) in &self.edges {
            adj[d].push(s);
        }
        if with_residual {
            for &(s, d) in &self.residual_edges {
                adj[d].push(s);
            }
        }
        adj
    }

    /// Kahn topological order over the chosen edge set; None if cyclic.
    pub fn topo_order(&self, with_residual: bool) -> Option<Vec<usize>> {
        let succ = self.successors(with_residual);
        let mut indeg = vec![0usize; self.nodes.len()];
        for vs in &succ {
            for &d in vs {
                indeg[d] += 1;
            }
        }
        let mut queue: std::collections::VecDeque<usize> =
            (0..self.nodes.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &d in &succ[v] {
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    queue.push_back(d);
                }
            }
        }
        (order.len() == self.nodes.len()).then_some(order)
    }

    /// Longest path length (in edges) from any source, per node
    /// (Algorithm 2's path_len, computed by DP over the topo order).
    pub fn longest_path(&self, with_residual: bool) -> Vec<usize> {
        let order = self.topo_order(with_residual).expect("acyclic");
        let succ = self.successors(with_residual);
        let mut pl = vec![0usize; self.nodes.len()];
        for &v in &order {
            for &d in &succ[v] {
                pl[d] = pl[d].max(pl[v] + 1);
            }
        }
        pl
    }

    /// Sources / sinks over main edges.
    pub fn source(&self) -> Result<usize> {
        let pred = self.predecessors(false);
        let srcs: Vec<usize> = (0..self.nodes.len()).filter(|&i| pred[i].is_empty()).collect();
        if srcs.len() != 1 {
            bail!("expected single source, found {}", srcs.len());
        }
        Ok(srcs[0])
    }

    pub fn sink(&self) -> Result<usize> {
        let succ = self.successors(false);
        let sinks: Vec<usize> = (0..self.nodes.len()).filter(|&i| succ[i].is_empty()).collect();
        if sinks.len() != 1 {
            bail!("expected single sink, found {}", sinks.len());
        }
        Ok(sinks[0])
    }

    /// Total parameter bytes at the BF16 baseline (for memory metrics).
    pub fn total_param_bytes(&self) -> u64 {
        self.nodes.iter().map(|n| n.param_bytes).sum()
    }
}

#[cfg(test)]
pub mod testutil {
    use super::*;

    /// Build a quick synthetic node.
    pub fn n(id: &str, qidx: i32) -> Node {
        Node {
            id: id.into(),
            kind: if qidx >= 0 { "linear".into() } else { "op".into() },
            engine: if qidx >= 0 { Engine::Mme } else { Engine::Tpc },
            qidx,
            macs: if qidx >= 0 { 1000 } else { 0 },
            bytes_in: 64,
            bytes_out: 64,
            param_bytes: if qidx >= 0 { 128 } else { 0 },
            c: 8,
            k: 8,
        }
    }

    /// a -> b -> c chain with q layers at b.
    pub fn chain() -> Graph {
        Graph::synthetic(
            vec![n("a", -1), n("b", 0), n("c", 1)],
            vec![(0, 1), (1, 2)],
        )
    }

    /// Diamond: s -> {x, y} -> m -> t.
    pub fn diamond() -> Graph {
        Graph::synthetic(
            vec![n("s", -1), n("x", 0), n("y", 1), n("m", 2), n("t", -1)],
            vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn topo_and_longest_path() {
        let g = diamond();
        let topo = g.topo_order(false).unwrap();
        assert_eq!(topo.len(), 5);
        let pl = g.longest_path(false);
        assert_eq!(pl, vec![0, 1, 1, 2, 3]);
    }

    #[test]
    fn source_sink() {
        let g = diamond();
        assert_eq!(g.source().unwrap(), 0);
        assert_eq!(g.sink().unwrap(), 4);
    }

    #[test]
    fn cycle_detected() {
        let g = Graph::synthetic(vec![n("a", -1), n("b", -1)], vec![(0, 1), (1, 0)]);
        assert!(g.topo_order(false).is_none());
    }

    #[test]
    fn from_json_roundtrip() {
        let src = r#"{
          "model": "t", "eval_b": 2, "seq": 4,
          "nodes": [
            {"id":"a","kind":"embed","engine":"tpc","qidx":-1,"macs":0,"bytes_in":8,"bytes_out":8,"param_bytes":0,"c":0,"k":0},
            {"id":"b","kind":"linear","engine":"mme","qidx":0,"macs":100,"bytes_in":8,"bytes_out":8,"param_bytes":32,"c":2,"k":2}
          ],
          "edges": [["a","b"]],
          "residual_edges": [],
          "qlayers": ["b"],
          "qkinds": ["linear"]
        }"#;
        let g = Graph::from_json(&Json::parse(src).unwrap()).unwrap();
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.node_index("b").unwrap(), 1);
        assert!(g.nodes[1].quantizable());
        assert_eq!(g.total_param_bytes(), 32);
    }

    #[test]
    fn to_json_roundtrips_synthetic_graphs() {
        let (g, _, _) = crate::plan::demo::demo_model(2, 5);
        let back = Graph::from_json(&Json::parse(&g.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.model, g.model);
        assert_eq!(back.edges, g.edges);
        assert_eq!(back.residual_edges, g.residual_edges);
        assert_eq!(back.qlayers, g.qlayers);
        assert_eq!(back.qkinds, g.qkinds);
        assert_eq!(back.nodes.len(), g.nodes.len());
        for (a, b) in back.nodes.iter().zip(&g.nodes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.engine, b.engine);
            assert_eq!(a.qidx, b.qidx);
            assert_eq!(a.macs, b.macs);
            assert_eq!(a.bytes_in, b.bytes_in);
            assert_eq!(a.param_bytes, b.param_bytes);
            assert_eq!((a.c, a.k), (b.c, b.k));
        }
        // Serialization is stable: emit -> parse -> emit is a fixpoint.
        assert_eq!(back.to_json().to_string(), g.to_json().to_string());
    }

    #[test]
    fn bad_qidx_rejected() {
        let src = r#"{
          "model": "t", "eval_b": 1, "seq": 1,
          "nodes": [
            {"id":"a","kind":"linear","engine":"mme","qidx":1,"macs":1,"bytes_in":1,"bytes_out":1,"param_bytes":1,"c":1,"k":1}
          ],
          "edges": [], "residual_edges": [],
          "qlayers": ["a"], "qkinds": ["linear"]
        }"#;
        assert!(Graph::from_json(&Json::parse(src).unwrap()).is_err());
    }
}
