//! The paper's Algorithm 2: partition the model DAG into an ordered sequence
//! of single-entry/single-exit (SESE) sub-graphs that execute strictly
//! sequentially at run time, so their gained times add (§2.3.1, Appendix B).
//!
//! Residual skip edges are excluded from the walk, matching Fig. 6
//! ("residual adds are omitted for clarity").

use super::Graph;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeSet;

/// One sequential sub-graph V_j.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SubGraph {
    /// Every node swept into this SESE region (graph node indices, sorted).
    pub all_nodes: Vec<usize>,
    /// The quantizable members (graph node indices, in qidx order).
    pub qnodes: Vec<usize>,
    /// Their indices into the model's quantizable-layer table.
    pub qidxs: Vec<usize>,
}

impl SubGraph {
    pub fn len(&self) -> usize {
        self.qidxs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.qidxs.is_empty()
    }

    /// Number of MP configurations for this group: F^{L_j}.  Errors when
    /// the count overflows `usize` — a group that long cannot be measured
    /// configuration-by-configuration anyway, and the callers must refuse
    /// it explicitly instead of panicking (debug) or wrapping (release).
    pub fn n_configs(&self, n_formats: usize) -> Result<usize> {
        u32::try_from(self.qidxs.len())
            .ok()
            .and_then(|len| n_formats.checked_pow(len))
            .ok_or_else(|| {
                anyhow!(
                    "config space too large: {n_formats}^{} (group of {} layers) overflows usize",
                    self.qidxs.len(),
                    self.qidxs.len()
                )
            })
    }
}

/// Partition of the whole model: ordered groups {V_j}.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    pub groups: Vec<SubGraph>,
}

impl Partition {
    /// Total quantizable layers covered.
    pub fn n_qlayers(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }

    /// Map qidx -> group index.
    pub fn group_of(&self) -> Vec<usize> {
        let n = self.n_qlayers();
        let mut out = vec![usize::MAX; n];
        for (j, g) in self.groups.iter().enumerate() {
            for &q in &g.qidxs {
                out[q] = j;
            }
        }
        out
    }

    /// Total number of per-group timing measurements: sum_j F^{L_j}.
    /// Errors when any group's config space (or the total) overflows.
    pub fn n_measurements(&self, n_formats: usize) -> Result<usize> {
        self.groups.iter().try_fold(0usize, |acc, g| {
            let n = g.n_configs(n_formats)?;
            acc.checked_add(n)
                .ok_or_else(|| anyhow!("config space too large: total measurement count overflows"))
        })
    }
}

/// Algorithm 2 (paper Appendix B), walking main edges only.
pub fn partition(graph: &Graph) -> Result<Partition> {
    let succ = graph.successors(false);
    let pl = graph.longest_path(false);
    let start = graph.source()?;
    let end = graph.sink()?;

    let mut groups: Vec<SubGraph> = Vec::new();
    let mut vertex = start;
    let mut covered: BTreeSet<usize> = BTreeSet::new();

    // The source itself forms the first candidate region.
    let mut pending: Vec<usize> = vec![vertex];
    flush(graph, &mut groups, &mut pending, &mut covered);

    while vertex != end {
        let mut region: Vec<usize> = Vec::new();
        let mut frontier: BTreeSet<usize> = succ[vertex].iter().copied().collect();
        let mut cur_len = pl[vertex] + 1;
        // Sweep vertices whose longest-path rank has been reached into the
        // region until the frontier narrows to a single vertex — that vertex
        // is the region's single exit.
        let mut guard = 0usize;
        while frontier.len() > 1 {
            let snapshot: Vec<usize> = frontier.iter().copied().collect();
            for v in snapshot {
                if pl[v] <= cur_len {
                    frontier.remove(&v);
                    region.push(v);
                    for &w in &succ[v] {
                        frontier.insert(w);
                    }
                }
            }
            cur_len += 1;
            guard += 1;
            if guard > graph.nodes.len() + 2 {
                bail!("partition did not converge (malformed DAG?)");
            }
        }
        let Some(&exit) = frontier.iter().next() else {
            bail!("dead-end before reaching sink (node '{}')", graph.nodes[vertex].id);
        };
        vertex = exit;
        region.push(vertex);
        flush(graph, &mut groups, &mut region, &mut covered);
    }

    // Every quantizable layer must be covered exactly once.
    let covered_q: usize = groups.iter().map(|g| g.len()).sum();
    if covered_q != graph.qlayers.len() {
        bail!("partition covered {covered_q} of {} quantizable layers", graph.qlayers.len());
    }
    Ok(Partition { groups })
}

/// Pop non-quantizable vertices; append as a group if any remain
/// (Algorithm 2 lines 21-24).
fn flush(graph: &Graph, groups: &mut Vec<SubGraph>, region: &mut Vec<usize>,
         covered: &mut BTreeSet<usize>) {
    let mut qnodes: Vec<usize> = region
        .iter()
        .copied()
        .filter(|&v| graph.nodes[v].quantizable() && !covered.contains(&v))
        .collect();
    qnodes.sort_by_key(|&v| graph.nodes[v].qidx);
    let mut all: Vec<usize> = region.drain(..).collect();
    all.sort_unstable();
    all.dedup();
    for &v in &qnodes {
        covered.insert(v);
    }
    if !qnodes.is_empty() {
        let qidxs = qnodes.iter().map(|&v| graph.nodes[v].qidx as usize).collect();
        groups.push(SubGraph { all_nodes: all, qnodes, qidxs });
    }
}

/// Validate the SESE property of each group (used by tests & `ampq partition`):
/// all main-edge crossings into the region come through one entry frontier
/// and leave through the group's last vertex region.
pub fn validate_sequential(graph: &Graph, part: &Partition) -> Result<()> {
    // Groups must be disjoint in qidxs and ordered topologically.
    let mut seen = BTreeSet::new();
    for g in &part.groups {
        for &q in &g.qidxs {
            if !seen.insert(q) {
                bail!("qidx {q} appears in two groups");
            }
        }
    }
    let pl = graph.longest_path(false);
    let mut last_max = 0usize;
    for (j, g) in part.groups.iter().enumerate() {
        let lo = g.qnodes.iter().map(|&v| pl[v]).min().unwrap();
        let hi = g.qnodes.iter().map(|&v| pl[v]).max().unwrap();
        if j > 0 && lo <= last_max.saturating_sub(0) && lo < last_max {
            bail!("group {j} overlaps previous in depth ({lo} < {last_max})");
        }
        last_max = hi.max(last_max);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::testutil::{chain, diamond, n};
    use crate::graph::Graph;

    #[test]
    fn chain_gives_singleton_groups() {
        let g = chain();
        let p = partition(&g).unwrap();
        assert_eq!(p.groups.len(), 2);
        assert!(p.groups.iter().all(|gr| gr.len() == 1));
        assert_eq!(p.group_of(), vec![0, 1]);
    }

    #[test]
    fn diamond_merges_branches() {
        let g = diamond();
        let p = partition(&g).unwrap();
        // {x, y, m} is a single SESE region; t is non-quantizable.
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].qidxs, vec![0, 1, 2]);
        assert_eq!(p.groups[0].n_configs(2).unwrap(), 8);
    }

    #[test]
    fn n_configs_overflow_is_an_error_not_a_panic() {
        // 2^64 layers' worth of configs cannot fit a usize: the count must
        // surface as an explicit error.
        let g = SubGraph {
            all_nodes: (0..70).collect(),
            qnodes: (0..70).collect(),
            qidxs: (0..70).collect(),
        };
        let err = g.n_configs(2).unwrap_err();
        assert!(format!("{err:#}").contains("config space too large"));
        // And the per-partition total propagates it.
        let p = Partition { groups: vec![g] };
        assert!(p.n_measurements(2).is_err());
        // Small groups still count exactly.
        let small = SubGraph { all_nodes: vec![0], qnodes: vec![0], qidxs: vec![0] };
        assert_eq!(small.n_configs(3).unwrap(), 3);
    }

    #[test]
    fn wide_fanout_converges() {
        // s -> {a,b,c,d} -> m -> t : one group of 5 q-layers.
        let nodes = vec![
            n("s", -1), n("a", 0), n("b", 1), n("c", 2), n("d", 3), n("m", 4), n("t", -1),
        ];
        let edges = vec![(0, 1), (0, 2), (0, 3), (0, 4), (1, 5), (2, 5), (3, 5), (4, 5), (5, 6)];
        let g = Graph::synthetic(nodes, edges);
        let p = partition(&g).unwrap();
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].len(), 5);
    }

    #[test]
    fn asymmetric_depth_branches() {
        // s -> a -> b -> m ; s -> c -> m ; m -> t  (unequal branch depths)
        let nodes = vec![n("s", -1), n("a", 0), n("b", 1), n("c", 2), n("m", 3), n("t", -1)];
        let edges = vec![(0, 1), (1, 2), (0, 3), (2, 4), (3, 4), (4, 5)];
        let g = Graph::synthetic(nodes, edges);
        let p = partition(&g).unwrap();
        assert_eq!(p.groups.len(), 1);
        let mut q = p.groups[0].qidxs.clone();
        q.sort_unstable();
        assert_eq!(q, vec![0, 1, 2, 3]);
        validate_sequential(&g, &p).unwrap();
    }

    #[test]
    fn sequential_chain_after_merge() {
        // diamond followed by two sequential linears.
        let nodes = vec![
            n("s", -1), n("x", 0), n("y", 1), n("m", 2), n("p", 3), n("q", 4), n("t", -1),
        ];
        let edges = vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5), (5, 6)];
        let g = Graph::synthetic(nodes, edges);
        let p = partition(&g).unwrap();
        assert_eq!(p.groups.len(), 3);
        assert_eq!(p.groups[0].qidxs, vec![0, 1, 2]);
        assert_eq!(p.groups[1].qidxs, vec![3]);
        assert_eq!(p.groups[2].qidxs, vec![4]);
        assert_eq!(p.n_measurements(2).unwrap(), 8 + 2 + 2);
        validate_sequential(&g, &p).unwrap();
    }

    #[test]
    fn group_of_total() {
        let g = diamond();
        let p = partition(&g).unwrap();
        assert_eq!(p.n_qlayers(), 3);
        assert!(p.group_of().iter().all(|&j| j == 0));
    }
}
