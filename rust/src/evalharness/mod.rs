//! Task evaluation harness (lm-evaluation-harness stand-in, DESIGN.md §3).
//!
//! Loads the .tbin task datasets, runs the compiled forward under a given MP
//! configuration, and scores:
//!   * "choice" tasks (hella/wino/piqa): accuracy of argmax over the K
//!     candidate spans' summed log-likelihood;
//!   * "lastword" (lamb): greedy accuracy at the final token + perplexity
//!     over the scored span.
//! Matches the paper's protocol: accuracy reported as difference vs the
//! BF16/high-precision baseline, mean +- std over perturbation seeds.

use crate::gaudisim::MpConfig;
use crate::model::{ModelInfo, TaskMeta};
use crate::plan::Plan;
use crate::runtime::ModelRuntime;
use crate::sensitivity::validate::draw_pscale;
use crate::tensorbin;
use crate::util::Rng;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// A loaded task dataset.
#[derive(Clone, Debug)]
pub struct TaskData {
    pub meta: TaskMeta,
    /// Row-major [n_rows, T] token ids (n_rows = n_ex * k).
    pub tokens: Vec<i32>,
    /// (start, end) scored span per row.
    pub spans: Vec<(usize, usize)>,
    /// Per example: correct choice index ("choice") or token id ("lastword").
    pub labels: Vec<i32>,
    pub seq: usize,
}

impl TaskData {
    pub fn n_rows(&self) -> usize {
        self.meta.n_ex * self.meta.k
    }
}

pub fn load_task(root: &Path, meta: &TaskMeta, seq: usize) -> Result<TaskData> {
    let tf = tensorbin::read(&root.join(&meta.path))?;
    let tokens_t = tf.get("tokens")?;
    let dims = tokens_t.dims();
    if dims.len() != 2 || dims[1] != seq || dims[0] != meta.n_ex * meta.k {
        bail!("{}: tokens shape {:?}", meta.name, dims);
    }
    let spans_raw = tf.get("spans")?.as_i32()?;
    let spans: Vec<(usize, usize)> = spans_raw
        .chunks(2)
        .map(|c| (c[0] as usize, c[1] as usize))
        .collect();
    let labels = tf.get("labels")?.as_i32()?.to_vec();
    if spans.len() != meta.n_ex * meta.k || labels.len() != meta.n_ex {
        bail!("{}: spans/labels shape mismatch", meta.name);
    }
    Ok(TaskData {
        meta: meta.clone(),
        tokens: tokens_t.as_i32()?.to_vec(),
        spans,
        labels,
        seq,
    })
}

pub fn load_all_tasks(root: &Path, info: &ModelInfo) -> Result<Vec<TaskData>> {
    info.tasks.iter().map(|t| load_task(root, t, info.seq)).collect()
}

/// Scores of one evaluation run.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    pub acc: f64,
    /// Perplexity over scored spans (meaningful for "lastword").
    pub ppl: f64,
    /// Mean span log-likelihood (diagnostics).
    pub mean_ll: f64,
}

/// Span log-likelihoods for every row of a task, batched through the
/// compiled forward.  logits[t] predicts token t+1, so span (s, e) is scored
/// by positions s-1 .. e-2.
fn span_lls(
    mr: &ModelRuntime,
    task: &TaskData,
    cfg: &MpConfig,
    pscale: &[f32],
) -> Result<(Vec<f64>, Vec<usize>)> {
    let b = mr.info.eval_b;
    let t = task.seq;
    let v = mr.info.vocab;
    let n_rows = task.n_rows();
    if n_rows % b != 0 {
        bail!("{}: rows {} not a multiple of batch {}", task.meta.name, n_rows, b);
    }
    let mut lls = vec![0.0f64; n_rows];
    let mut argmax_at_start = vec![0usize; n_rows];
    for (bi, rows) in task.tokens.chunks(b * t).enumerate() {
        let out = mr.fwd(rows, cfg, pscale)?;
        for r in 0..b {
            let row = bi * b + r;
            let (s, e) = task.spans[row];
            let toks = &rows[r * t..(r + 1) * t];
            let mut ll = 0.0f64;
            for pos in s..e {
                // logits index: (r, pos-1, :)
                let base = (r * t + pos - 1) * v;
                let lg = &out.logits[base..base + v];
                ll += log_softmax_at(lg, toks[pos] as usize);
            }
            lls[row] = ll;
            // Greedy prediction at span start (for "lastword" accuracy).
            let base = (r * t + s - 1) * v;
            let lg = &out.logits[base..base + v];
            argmax_at_start[row] = argmax(lg);
        }
    }
    Ok((lls, argmax_at_start))
}

fn log_softmax_at(logits: &[f32], idx: usize) -> f64 {
    let m = logits.iter().cloned().fold(f32::MIN, f32::max) as f64;
    let z: f64 = logits.iter().map(|&x| ((x as f64) - m).exp()).sum();
    (logits[idx] as f64) - m - z.ln()
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Evaluate one task under one configuration + scale-perturbation draw.
pub fn evaluate(
    mr: &ModelRuntime,
    task: &TaskData,
    cfg: &MpConfig,
    pscale: &[f32],
) -> Result<EvalResult> {
    let (lls, argmax_start) = span_lls(mr, task, cfg, pscale)?;
    let k = task.meta.k;
    let mut correct = 0usize;
    let mut ll_sum = 0.0f64;
    let mut tok_count = 0usize;
    for ex in 0..task.meta.n_ex {
        if task.meta.kind == "choice" {
            let slice = &lls[ex * k..(ex + 1) * k];
            let mut best = 0usize;
            for (i, &x) in slice.iter().enumerate() {
                if x > slice[best] {
                    best = i;
                }
            }
            if best == task.labels[ex] as usize {
                correct += 1;
            }
        } else {
            // lastword: greedy match of the span's first token.
            let row = ex * k;
            if argmax_start[row] == task.labels[ex] as usize {
                correct += 1;
            }
        }
        for c in 0..k {
            let row = ex * k + c;
            let (s, e) = task.spans[row];
            ll_sum += lls[row];
            tok_count += e - s;
        }
    }
    let mean_ll_per_tok = ll_sum / tok_count.max(1) as f64;
    Ok(EvalResult {
        acc: correct as f64 / task.meta.n_ex as f64,
        ppl: (-mean_ll_per_tok).exp(),
        mean_ll: ll_sum / task.n_rows() as f64,
    })
}

/// Evaluate a [`Plan`]'s configuration on every task, drawing the paper's
/// scale-perturbation vector deterministically from the plan's recorded
/// seed — the staged-API entry point behind `ampq evaluate`.
pub fn evaluate_plan(
    mr: &ModelRuntime,
    tasks: &[TaskData],
    plan: &Plan,
    sigma: f64,
) -> Result<Vec<EvalResult>> {
    let mut rng = Rng::new(plan.seed.wrapping_mul(0x9e37_79b9));
    let ps = draw_pscale(plan.config.len(), sigma, &mut rng);
    tasks
        .iter()
        .map(|task| evaluate(mr, task, &plan.config, &ps))
        .collect()
}

/// Evaluate with caching across (config, seed) repeats — strategy sweeps
/// re-visit the same configuration constantly (e.g. all-BF16 at low tau).
pub struct CachedEvaluator<'a> {
    mr: &'a ModelRuntime,
    tasks: &'a [TaskData],
    cache: HashMap<(String, String, u64), Vec<EvalResult>>,
}

impl<'a> CachedEvaluator<'a> {
    pub fn new(mr: &'a ModelRuntime, tasks: &'a [TaskData]) -> Self {
        CachedEvaluator { mr, tasks, cache: HashMap::new() }
    }

    /// Results for all tasks under (cfg, seed); pscale must be the seed's
    /// deterministic draw (callers use sensitivity::validate::draw_pscale).
    pub fn eval_all(
        &mut self,
        cfg: &MpConfig,
        seed: u64,
        pscale: &[f32],
    ) -> Result<Vec<EvalResult>> {
        let key = (cfg.bits_label(), format!("{}", self.mr.fwd_mode as u8), seed);
        if let Some(hit) = self.cache.get(&key) {
            return Ok(hit.clone());
        }
        let mut out = Vec::with_capacity(self.tasks.len());
        for task in self.tasks {
            out.push(evaluate(self.mr, task, cfg, pscale)?);
        }
        self.cache.insert(key, out.clone());
        Ok(out)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let logits = [1.0f32, 2.0, 3.0, 0.5];
        let total: f64 = (0..4).map(|i| log_softmax_at(&logits, i).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Highest logit has highest probability.
        assert!(log_softmax_at(&logits, 2) > log_softmax_at(&logits, 0));
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
