//! Artifact manifest: model descriptions, layer tables, artifact paths.
//!
//! Parses artifacts/manifest.json written by python/compile/aot.py — the
//! single contract between the build-time python layers and this runtime.

use crate::graph::Graph;
use crate::tensorbin::{self, TensorFile};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

/// Quantizable layer kind (paper's L_lin vs L_BGEMM).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Linear,
    Bgemm,
}

/// One quantizable layer's static description.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QLayer {
    pub name: String,
    pub kind: LayerKind,
    /// Contraction (input) dim C_l.
    pub c: usize,
    /// Output dim K_l.
    pub k: usize,
    /// MAC count at the evaluation batch (N C K, or BGEMM equivalent).
    pub macs: u64,
    /// Parameter element count (0 for BGEMM).
    pub params: u64,
}

/// Evaluation-task metadata.
#[derive(Clone, Debug)]
pub struct TaskMeta {
    pub name: String,
    /// "choice" (argmax over K spans) or "lastword" (accuracy + ppl).
    pub kind: String,
    pub k: usize,
    pub n_ex: usize,
    pub path: String,
}

/// Paths (relative to the artifacts root) of one model's artifacts.
#[derive(Clone, Debug)]
pub struct ArtifactPaths {
    pub weights: String,
    pub fwd_quant: String,
    pub fwd_ref: String,
    pub sensitivity: String,
    pub graph: String,
    pub calib: String,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d: usize,
    pub blocks: usize,
    pub heads: usize,
    pub ff: usize,
    pub seq: usize,
    pub eval_b: usize,
    pub calib_r: usize,
    pub n_qlayers: usize,
    pub qlayers: Vec<QLayer>,
    pub param_order: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub paths: ArtifactPaths,
    pub tasks: Vec<TaskMeta>,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub models: Vec<ModelInfo>,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&root.join("manifest.json"))?;
        let mut models = Vec::new();
        for mj in j.get("models")?.arr()? {
            models.push(parse_model(mj)?);
        }
        if models.is_empty() {
            bail!("manifest has no models");
        }
        Ok(Manifest { root: root.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                let names: Vec<&str> = self.models.iter().map(|m| m.name.as_str()).collect();
                anyhow!("model '{name}' not in manifest (have: {names:?})")
            })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

impl ModelInfo {
    pub fn load_graph(&self, root: &Path) -> Result<Graph> {
        let g = Graph::load(&root.join(&self.paths.graph))?;
        if g.qlayers.len() != self.n_qlayers {
            bail!("graph qlayers {} != manifest {}", g.qlayers.len(), self.n_qlayers);
        }
        Ok(g)
    }

    pub fn load_weights(&self, root: &Path) -> Result<TensorFile> {
        let tf = tensorbin::read(&root.join(&self.paths.weights))?;
        for name in &self.param_order {
            tf.get(name)?;
        }
        Ok(tf)
    }

    pub fn load_calib(&self, root: &Path) -> Result<Vec<Vec<i32>>> {
        let tf = tensorbin::read(&root.join(&self.paths.calib))?;
        let t = tf.get("tokens")?;
        let dims = t.dims();
        if dims.len() != 2 || dims[1] != self.seq {
            bail!("calib tokens shape {:?} (want [_, {}])", dims, self.seq);
        }
        let data = t.as_i32()?;
        Ok(data.chunks(self.seq).map(|c| c.to_vec()).collect())
    }

    /// qidx of a layer by name.
    pub fn qidx(&self, name: &str) -> Result<usize> {
        self.qlayers
            .iter()
            .position(|q| q.name == name)
            .ok_or_else(|| anyhow!("qlayer '{name}' unknown"))
    }

    /// Total parameter elements over quantizable linear layers
    /// (the memory-gain denominator).
    pub fn total_qparams(&self) -> u64 {
        self.qlayers.iter().map(|q| q.params).sum()
    }
}

fn parse_model(mj: &Json) -> Result<ModelInfo> {
    let qlayers = mj
        .get("qlayers")?
        .arr()?
        .iter()
        .map(|q| {
            Ok(QLayer {
                name: q.get("name")?.str()?.to_string(),
                kind: match q.get("kind")?.str()? {
                    "linear" => LayerKind::Linear,
                    "bgemm" => LayerKind::Bgemm,
                    k => bail!("unknown layer kind '{k}'"),
                },
                c: q.get("c")?.usize()?,
                k: q.get("k")?.usize()?,
                macs: q.get("macs")?.f64()? as u64,
                params: q.get("params")?.f64()? as u64,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let pj = mj.get("artifacts")?;
    let paths = ArtifactPaths {
        weights: pj.get("weights")?.str()?.to_string(),
        fwd_quant: pj.get("fwd_quant")?.str()?.to_string(),
        fwd_ref: pj.get("fwd_ref")?.str()?.to_string(),
        sensitivity: pj.get("sensitivity")?.str()?.to_string(),
        graph: pj.get("graph")?.str()?.to_string(),
        calib: pj.get("calib")?.str()?.to_string(),
    };

    let param_order: Vec<String> = mj
        .get("param_order")?
        .arr()?
        .iter()
        .map(|x| Ok(x.str()?.to_string()))
        .collect::<Result<_>>()?;
    let shapes_j = mj.get("param_shapes")?;
    let param_shapes = param_order
        .iter()
        .map(|n| {
            shapes_j
                .get(n)?
                .arr()?
                .iter()
                .map(|d| d.usize())
                .collect::<Result<Vec<_>>>()
        })
        .collect::<Result<Vec<_>>>()?;

    let tasks = mj
        .get("tasks")?
        .arr()?
        .iter()
        .map(|t| {
            Ok(TaskMeta {
                name: t.get("name")?.str()?.to_string(),
                kind: t.get("kind")?.str()?.to_string(),
                k: t.get("k")?.usize()?,
                n_ex: t.get("n_ex")?.usize()?,
                path: t.get("path")?.str()?.to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let info = ModelInfo {
        name: mj.get("name")?.str()?.to_string(),
        vocab: mj.get("vocab")?.usize()?,
        d: mj.get("d")?.usize()?,
        blocks: mj.get("blocks")?.usize()?,
        heads: mj.get("heads")?.usize()?,
        ff: mj.get("ff")?.usize()?,
        seq: mj.get("seq")?.usize()?,
        eval_b: mj.get("eval_b")?.usize()?,
        calib_r: mj.get("calib_r")?.usize()?,
        n_qlayers: mj.get("n_qlayers")?.usize()?,
        qlayers,
        param_order,
        param_shapes,
        paths,
        tasks,
    };
    if info.qlayers.len() != info.n_qlayers {
        bail!("{}: qlayer table size mismatch", info.name);
    }
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    #[ignore = "requires AOT artifacts (run `make artifacts` / python compile first)"]
    fn loads_real_manifest() {
        let m = Manifest::load(&artifacts_root()).expect("run `make artifacts` first");
        assert!(m.models.len() >= 2);
        let s = m.model("tiny-s").unwrap();
        assert_eq!(s.n_qlayers, 9 * s.blocks + 1);
        assert_eq!(s.qlayers.len(), s.n_qlayers);
        assert_eq!(s.tasks.len(), 4);
        assert!(m.model("nope").is_err());
    }

    #[test]
    #[ignore = "requires AOT artifacts (run `make artifacts` / python compile first)"]
    fn qlayer_kinds_consistent() {
        let m = Manifest::load(&artifacts_root()).unwrap();
        for info in &m.models {
            let bgemms = info.qlayers.iter().filter(|q| q.kind == LayerKind::Bgemm).count();
            assert_eq!(bgemms, 2 * info.blocks);
            for q in &info.qlayers {
                assert!(q.macs > 0);
                match q.kind {
                    LayerKind::Linear => assert!(q.params > 0),
                    LayerKind::Bgemm => assert_eq!(q.params, 0),
                }
            }
        }
    }

    #[test]
    #[ignore = "requires AOT artifacts (run `make artifacts` / python compile first)"]
    fn graph_and_weights_load() {
        let m = Manifest::load(&artifacts_root()).unwrap();
        let info = m.model("tiny-s").unwrap();
        let g = info.load_graph(&m.root).unwrap();
        assert_eq!(g.qlayers.len(), info.n_qlayers);
        let w = info.load_weights(&m.root).unwrap();
        // Shapes match the manifest contract.
        for (name, shape) in info.param_order.iter().zip(&info.param_shapes) {
            let t = w.get(name).unwrap();
            assert_eq!(t.dims(), &shape[..], "{name}");
        }
        let calib = info.load_calib(&m.root).unwrap();
        assert_eq!(calib.len(), info.calib_r);
    }
}
