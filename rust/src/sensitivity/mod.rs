//! Sensitivity calibration (paper §2.2) and the additive loss-MSE predictor.
//!
//! `calibrate` runs the AOT sensitivity executable (high-precision fwd+bwd,
//! batch=1) over the calibration set, averaging per-layer sensitivities
//! s_l (eq. 21) and the loss second moment E[g^2].  `Calibration::loss_mse`
//! then predicts the loss MSE of ANY mixed-precision configuration as
//! d = sum_l s_l * alpha_{f(l)}  (eq. 22, 23, 6) — the quantity the IP
//! constrains to tau^2 E[g^2].

pub mod validate;

use crate::exec::ExecPool;
use crate::gaudisim::MpConfig;
use crate::numerics::Format;
use crate::runtime::ModelRuntime;
use anyhow::{bail, Result};

/// Calibrated sensitivity state for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// Per-layer average sensitivity s_l (eq. 21).
    pub s: Vec<f64>,
    /// E[g^2] over the calibration set.
    pub eg2: f64,
    /// Mean loss E[g] (diagnostics).
    pub g_mean: f64,
    pub n_samples: usize,
}

/// Run the sensitivity executable over the calibration sequences, one
/// independent pass per sample fanned out across `pool`.  Per-sample
/// results come back in sample order and are averaged sequentially, so the
/// calibration is bit-identical to a plain loop at any thread count (the
/// exec layer's determinism contract).
pub fn calibrate(mr: &ModelRuntime, calib: &[Vec<i32>], pool: &ExecPool) -> Result<Calibration> {
    if calib.is_empty() {
        bail!("empty calibration set");
    }
    let nq = mr.info.n_qlayers;
    let samples: Vec<(f32, Vec<f32>)> =
        pool.try_par_map(calib.len(), |i| mr.sensitivity(&calib[i]))?;
    let mut s = vec![0.0f64; nq];
    let mut g2 = 0.0f64;
    let mut g1 = 0.0f64;
    for (g, sl) in &samples {
        for (acc, x) in s.iter_mut().zip(sl) {
            *acc += *x as f64;
        }
        g2 += (*g as f64) * (*g as f64);
        g1 += *g as f64;
    }
    let r = calib.len() as f64;
    for x in s.iter_mut() {
        *x /= r;
    }
    Ok(Calibration { s, eg2: g2 / r, g_mean: g1 / r, n_samples: calib.len() })
}

impl Calibration {
    /// Predicted loss MSE of one layer in format f: d_{l,f} (eq. 22).
    pub fn layer_mse(&self, qidx: usize, f: Format) -> f64 {
        self.s[qidx] * f.alpha()
    }

    /// Predicted loss MSE of a full configuration (eq. 6 with eq. 23).
    pub fn loss_mse(&self, cfg: &MpConfig) -> f64 {
        cfg.0
            .iter()
            .enumerate()
            .map(|(l, &f)| self.layer_mse(l, f))
            .sum()
    }

    /// Predicted loss MSE contribution of a group configuration
    /// d_{j,p} (eq. 23).
    pub fn group_mse(&self, qidxs: &[usize], formats: &[Format]) -> f64 {
        qidxs
            .iter()
            .zip(formats)
            .map(|(&q, &f)| self.layer_mse(q, f))
            .sum()
    }

    /// The IP budget tau^2 * E[g^2] for a normalized-RMSE threshold tau.
    pub fn budget(&self, tau: f64) -> f64 {
        tau * tau * self.eg2
    }

    /// Normalized RMSE sqrt(d / E[g^2]) of a configuration — comparable
    /// directly against tau.
    pub fn normalized_rmse(&self, cfg: &MpConfig) -> f64 {
        (self.loss_mse(cfg) / self.eg2).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_calibration() -> Calibration {
        Calibration { s: vec![1.0, 4.0, 0.25], eg2: 16.0, g_mean: 4.0, n_samples: 8 }
    }

    #[test]
    fn additive_over_layers() {
        let c = fake_calibration();
        let cfg = MpConfig(vec![Format::Fp8E4m3, Format::Bf16, Format::Fp8E4m3]);
        let expect = 1.0 * Format::Fp8E4m3.alpha()
            + 4.0 * Format::Bf16.alpha()
            + 0.25 * Format::Fp8E4m3.alpha();
        assert!((c.loss_mse(&cfg) - expect).abs() < 1e-15);
    }

    #[test]
    fn group_mse_subsets() {
        let c = fake_calibration();
        let d = c.group_mse(&[0, 2], &[Format::Fp8E4m3, Format::Fp8E4m3]);
        let expect = (1.0 + 0.25) * Format::Fp8E4m3.alpha();
        assert!((d - expect).abs() < 1e-15);
    }

    #[test]
    fn budget_and_rmse() {
        let c = fake_calibration();
        assert!((c.budget(0.5) - 4.0).abs() < 1e-12);
        let cfg = MpConfig::uniform(3, Format::Fp32);
        assert!(c.normalized_rmse(&cfg) < 1e-6);
        let cfg8 = MpConfig::uniform(3, Format::Fp8E4m3);
        assert!(c.normalized_rmse(&cfg8) > c.normalized_rmse(&MpConfig::all_bf16(3)));
    }

    #[test]
    fn fp8_dominates_bf16_mse() {
        let c = fake_calibration();
        for l in 0..3 {
            assert!(c.layer_mse(l, Format::Fp8E4m3) > c.layer_mse(l, Format::Bf16));
        }
    }
}
