//! Measured loss MSE (paper §3.2, Fig. 3a): run the REAL quantized forward
//! over the calibration set and compare E[(ghat - g)^2] against the additive
//! Taylor prediction.  This is the validation the paper uses to justify the
//! IP's constraint model.

use crate::gaudisim::MpConfig;
use crate::runtime::ModelRuntime;
use crate::util::Rng;
use anyhow::Result;

/// Draw a scale-perturbation vector (the paper's seed protocol).
pub fn draw_pscale(n: usize, sigma: f64, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| (1.0 + sigma * rng.normal()) as f32).collect()
}

/// Measured loss MSE of `cfg`: mean over calibration batches and
/// `n_draws` perturbation draws of (ghat - g)^2, where g is the fp32 loss.
pub fn measured_loss_mse(
    mr: &ModelRuntime,
    calib: &[Vec<i32>],
    cfg: &MpConfig,
    n_draws: usize,
    sigma: f64,
    rng: &mut Rng,
) -> Result<f64> {
    let b = mr.info.eval_b;
    let nq = mr.info.n_qlayers;
    let mut errs: Vec<f64> = Vec::new();
    for batch in calib.chunks(b) {
        if batch.len() < b {
            break; // HLO batch is static; drop the ragged tail
        }
        let tokens: Vec<i32> = batch.concat();
        let hp = mr.fwd_fp32(&tokens)?;
        for _ in 0..n_draws {
            let ps = draw_pscale(nq, sigma, rng);
            let q = mr.fwd(&tokens, cfg, &ps)?;
            for (gh, g) in q.loss.iter().zip(&hp.loss) {
                errs.push((*gh as f64 - *g as f64).powi(2));
            }
        }
    }
    Ok(crate::util::stats::mean(&errs))
}

/// Paper Fig. 3a row: (tau, predicted d, measured E[(ghat-g)^2]).
#[derive(Clone, Debug)]
pub struct MseValidationPoint {
    pub tau: f64,
    pub predicted: f64,
    pub measured: f64,
}
