//! Deterministic parallel execution layer.
//!
//! Every hot path in the planning pipeline is embarrassingly parallel —
//! per-sample sensitivity calibration (§2.2), per-(group, configuration)
//! time-gain measurement (§2.3.1), per-tau IP solves when sweeping Pareto
//! frontiers, and the subproblem tree of the branch & bound solver — but
//! the gaudi2 acceptance tests pin planning output bit-for-bit, so "just
//! spawn threads" is not enough.  This module provides the scaffolding that
//! makes fan-out safe under that contract:
//!
//! * [`ExecCfg`] — the worker-thread budget, plumbed from the global
//!   `--threads` CLI flag (or the `AMPQ_THREADS` env var); `threads == 1`
//!   is the exact sequential path.
//! * [`ExecPool`] — a scoped worker pool over [`std::thread::scope`] with
//!   ordered [`ExecPool::par_map`] / [`ExecPool::par_chunks`] primitives:
//!   `out[i]` is always `f(i)` regardless of which worker ran it, so a
//!   reduction over the output in index order is bit-identical to the
//!   sequential loop.
//! * [`WorkQueue`] — a dynamic task queue for irregular loads (workers may
//!   push subtasks while draining), returning key-tagged results that the
//!   caller folds in deterministic key order.
//! * [`Scratch`] — a shared free list of recycled buffers, so per-chunk
//!   fan-out allocations (the parametric DP's level fragments) are reused
//!   across merges instead of re-allocated; recycling never changes a
//!   computed value, only where it is written.
//!
//! **The determinism contract.**  Parallel output must be bit-identical to
//! `threads == 1` output.  The pool guarantees ordered delivery, but the
//! contract also constrains *task bodies*: each task must be a pure
//! function of its index/payload (no shared mutable state, no
//! iteration-order-dependent RNG).  Randomized tasks therefore draw from
//! [`crate::util::Rng::stream`] — a splittable generator keyed by
//! `(seed, task index)` — so the noise a task sees does not depend on
//! which worker ran it or what ran before.  Cross-task communication is
//! allowed only when provably result-invariant (see
//! `solver::branch_bound`'s shared incumbent floor, which only ever skips
//! subproblems that cannot contain the final argmax).

pub mod pool;
pub mod queue;
pub mod scratch;

pub use pool::ExecPool;
pub use queue::WorkQueue;
pub use scratch::Scratch;

/// Worker-thread budget for the parallel execution layer.
///
/// `threads == 1` runs everything inline on the calling thread — the exact
/// sequential path, with no pool machinery on the stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecCfg {
    pub threads: usize,
}

/// Env var overriding the default thread budget (used by CI to exercise
/// the parallel paths under `cargo test`).
pub const THREADS_ENV: &str = "AMPQ_THREADS";

impl ExecCfg {
    /// A budget of exactly `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ExecCfg {
        ExecCfg { threads: threads.max(1) }
    }

    /// The exact sequential path.
    pub fn sequential() -> ExecCfg {
        ExecCfg { threads: 1 }
    }

    /// Default budget: `AMPQ_THREADS` if set (and parseable), else the
    /// machine's available parallelism, else 1.
    pub fn from_env() -> ExecCfg {
        if let Ok(v) = std::env::var(THREADS_ENV) {
            if let Ok(n) = v.trim().parse::<usize>() {
                return ExecCfg::new(n);
            }
        }
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ExecCfg::new(n)
    }
}

impl Default for ExecCfg {
    fn default() -> Self {
        ExecCfg::from_env()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_clamps_to_one() {
        assert_eq!(ExecCfg::new(0).threads, 1);
        assert_eq!(ExecCfg::new(7).threads, 7);
        assert_eq!(ExecCfg::sequential().threads, 1);
        assert!(ExecCfg::from_env().threads >= 1);
    }
}
