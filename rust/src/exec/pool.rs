//! Scoped worker pool with ordered, deterministic map primitives.
//!
//! Workers are spawned per call inside a [`std::thread::scope`], so tasks
//! may freely borrow from the caller's stack.  Spawn cost (~tens of us per
//! worker) is irrelevant for the coarse tasks this pool carries
//! (simulator measurements, IP solves, calibration samples); callers with
//! microsecond-scale tasks batch them via [`ExecPool::par_chunks`].

use super::ExecCfg;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// A worker pool of fixed thread budget.  Cheap to construct and `Copy`;
/// holds no threads between calls.
#[derive(Clone, Copy, Debug)]
pub struct ExecPool {
    threads: usize,
}

impl Default for ExecPool {
    /// The environment's budget ([`ExecCfg::from_env`]).
    fn default() -> Self {
        ExecPool::new(ExecCfg::from_env())
    }
}

impl ExecPool {
    pub fn new(cfg: ExecCfg) -> ExecPool {
        ExecPool { threads: cfg.threads.max(1) }
    }

    /// The exact sequential path (`par_map` degenerates to a plain loop).
    pub fn sequential() -> ExecPool {
        ExecPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn cfg(&self) -> ExecCfg {
        ExecCfg { threads: self.threads }
    }

    /// Ordered parallel map: returns `[f(0), f(1), .., f(n-1)]`.  Tasks are
    /// handed to workers through a shared index counter (a work queue, so
    /// uneven task costs balance), but the output order is always index
    /// order — a fold over it is bit-identical to the sequential loop
    /// whenever `f` is a pure function of its index.
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    *slots[i].lock().expect("par_map slot lock poisoned") = Some(v);
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .expect("par_map slot lock poisoned")
                    .expect("par_map task completed")
            })
            .collect()
    }

    /// Fallible ordered map.  Every task runs to completion (a failure does
    /// not cancel in-flight work); afterwards the FIRST error in index
    /// order is returned, so the surfaced error does not depend on thread
    /// timing.
    pub fn try_par_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        let results = self.par_map(n, f);
        results.into_iter().collect()
    }

    /// Ordered map over fixed-size chunks of `items`: `f(start, chunk)` for
    /// each chunk, results in chunk order.  The chunking is a pure function
    /// of `(items.len(), chunk_size)` — never of the thread count — so
    /// output is identical at any parallelism.  Use for fine-grained tasks
    /// where per-task dispatch would dominate.
    pub fn par_chunks<T, R, F>(&self, items: &[T], chunk_size: usize, f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &[T]) -> R + Sync,
    {
        let chunk = chunk_size.max(1);
        let n_chunks = items.len().div_ceil(chunk);
        self.par_map(n_chunks, |c| {
            let start = c * chunk;
            let end = (start + chunk).min(items.len());
            f(start, &items[start..end])
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn par_map_is_ordered_and_complete() {
        for threads in [1, 2, 8] {
            let pool = ExecPool::new(ExecCfg::new(threads));
            let out = pool.par_map(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn par_map_matches_sequential_bitwise() {
        // Float reduction in index order must be identical at any width.
        let seq = ExecPool::sequential();
        let par = ExecPool::new(ExecCfg::new(4));
        let f = |i: usize| ((i as f64) * 0.1).sin() / (1.0 + i as f64);
        let a: f64 = seq.par_map(1000, f).iter().sum();
        let b: f64 = par.par_map(1000, f).iter().sum();
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn try_par_map_returns_first_error_in_index_order() {
        let pool = ExecPool::new(ExecCfg::new(4));
        let out: Result<Vec<usize>> = pool.try_par_map(64, |i| {
            if i == 41 || i == 7 {
                Err(anyhow!("task {i} failed"))
            } else {
                Ok(i)
            }
        });
        let msg = format!("{:#}", out.unwrap_err());
        assert!(msg.contains("task 7"), "{msg}");
    }

    #[test]
    fn par_chunks_covers_every_item_once() {
        let items: Vec<usize> = (0..103).collect();
        for threads in [1, 3] {
            let pool = ExecPool::new(ExecCfg::new(threads));
            let sums = pool.par_chunks(&items, 10, |start, chunk| {
                assert_eq!(chunk[0], start);
                chunk.iter().sum::<usize>()
            });
            assert_eq!(sums.len(), 11);
            assert_eq!(sums.iter().sum::<usize>(), items.iter().sum::<usize>());
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let pool = ExecPool::new(ExecCfg::new(4));
        assert!(pool.par_map(0, |i| i).is_empty());
        assert_eq!(pool.par_map(1, |i| i + 10), vec![10]);
        assert!(pool.par_chunks(&[] as &[u8], 4, |_, c| c.len()).is_empty());
    }
}
