//! Dynamic work queue for irregular loads.
//!
//! [`ExecPool::par_map`] needs the task list up front; tree-shaped work
//! (branch & bound subproblems, adaptive refinement) discovers tasks while
//! running.  [`WorkQueue::run`] drains a queue that workers may push onto
//! mid-task, then hands the caller every emitted result sorted by its key
//! — so as long as each task's output is a pure function of the task (and
//! the caller's fold is a function of the sorted results), the outcome is
//! bit-identical at any thread count, regardless of which worker ran what
//! in which order.

use super::ExecPool;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct QueueState<T> {
    tasks: VecDeque<T>,
    /// Tasks currently being processed (the queue is only exhausted when
    /// it is empty AND nothing in flight can still push).
    in_flight: usize,
}

/// Handle a running task uses to enqueue subtasks.
pub struct WorkQueue<T> {
    state: Mutex<QueueState<T>>,
    ready: Condvar,
}

impl<T: Send> WorkQueue<T> {
    fn new(seed: Vec<T>) -> WorkQueue<T> {
        WorkQueue {
            state: Mutex::new(QueueState { tasks: VecDeque::from(seed), in_flight: 0 }),
            ready: Condvar::new(),
        }
    }

    /// Enqueue a subtask (callable from inside a worker).
    pub fn push(&self, task: T) {
        let mut st = self.state.lock().expect("work queue lock poisoned");
        st.tasks.push_back(task);
        self.ready.notify_one();
    }

    /// Pop the next task, waiting while other workers might still push.
    /// Returns None when the queue has fully drained.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("work queue lock poisoned");
        loop {
            if let Some(t) = st.tasks.pop_front() {
                st.in_flight += 1;
                return Some(t);
            }
            if st.in_flight == 0 {
                return None;
            }
            st = self.ready.wait(st).expect("work queue lock poisoned");
        }
    }

    /// Mark one popped task as finished.  Called from a drop guard so a
    /// panicking task still releases its in-flight slot — otherwise the
    /// other workers would wait on the condvar forever and the panic
    /// could never propagate out of the scope.
    fn done(&self) {
        let mut st = self.state.lock().expect("work queue lock poisoned");
        st.in_flight -= 1;
        // Wake everyone: the queue may now be exhausted (empty + idle), and
        // waiters deciding that need a look at the state.
        if st.tasks.is_empty() && st.in_flight == 0 {
            self.ready.notify_all();
        }
    }

    /// Drain `seed` (plus everything workers push) across the pool.  Each
    /// task may emit one `(key, result)`; the emitted pairs come back
    /// sorted by key.  Keys must be unique per emitting task — derive them
    /// from the task's position in the (deterministic) task tree.
    pub fn run<K, R, F>(pool: &ExecPool, seed: Vec<T>, work: F) -> Vec<(K, R)>
    where
        K: Ord + Send,
        R: Send,
        F: Fn(T, &WorkQueue<T>) -> Option<(K, R)> + Sync,
    {
        let queue = WorkQueue::new(seed);
        let results: Mutex<Vec<(K, R)>> = Mutex::new(Vec::new());
        let drain = || {
            while let Some(task) = queue.pop() {
                // The guard releases the in-flight slot even if `work`
                // panics, so sibling workers drain and the panic can
                // propagate out of the scope instead of deadlocking it.
                let _done = DoneGuard(&queue);
                if let Some(kr) = work(task, &queue) {
                    results.lock().expect("result lock poisoned").push(kr);
                }
            }
        };
        let workers = pool.threads();
        if workers == 1 {
            drain();
        } else {
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(&drain);
                }
            });
        }
        let mut out = results.into_inner().expect("result lock poisoned");
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

/// Releases one in-flight slot on drop (see [`WorkQueue::done`]).
struct DoneGuard<'a, T: Send>(&'a WorkQueue<T>);

impl<T: Send> Drop for DoneGuard<'_, T> {
    fn drop(&mut self) {
        self.0.done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCfg;

    /// Recursively split ranges until small, then emit their sums — an
    /// irregular tree whose sorted output must not depend on thread count.
    fn range_sums(pool: &ExecPool) -> Vec<(Vec<u32>, u64)> {
        WorkQueue::run(
            pool,
            vec![(vec![], 0u64, 1000u64)],
            |(key, lo, hi), q: &WorkQueue<(Vec<u32>, u64, u64)>| {
                if hi - lo > 100 {
                    let mid = (lo + hi) / 2;
                    let mut k0 = key.clone();
                    k0.push(0);
                    let mut k1 = key;
                    k1.push(1);
                    q.push((k0, lo, mid));
                    q.push((k1, mid, hi));
                    None
                } else {
                    Some((key, (lo..hi).sum::<u64>()))
                }
            },
        )
    }

    #[test]
    fn irregular_tree_is_thread_count_invariant() {
        let seq = range_sums(&ExecPool::sequential());
        let par = range_sums(&ExecPool::new(ExecCfg::new(8)));
        assert_eq!(seq, par);
        let total: u64 = seq.iter().map(|(_, s)| s).sum();
        assert_eq!(total, (0..1000u64).sum::<u64>());
        assert!(seq.len() > 8, "splitting actually happened");
    }

    #[test]
    fn empty_seed_returns_empty() {
        let pool = ExecPool::new(ExecCfg::new(4));
        let out: Vec<(usize, usize)> =
            WorkQueue::run(&pool, Vec::<usize>::new(), |t, _| Some((t, t)));
        assert!(out.is_empty());
    }

    #[test]
    fn results_sorted_by_key() {
        let pool = ExecPool::new(ExecCfg::new(3));
        let out = WorkQueue::run(&pool, (0..50usize).rev().collect(), |t, _| Some((t, t * 2)));
        let keys: Vec<usize> = out.iter().map(|(k, _)| *k).collect();
        assert_eq!(keys, (0..50).collect::<Vec<_>>());
    }
}
