//! Recycled-buffer free lists for the parallel execution layer.
//!
//! The parametric DP's state merge allocates one fragment buffer per
//! fan-out chunk per group — thousands of short-lived column vectors on a
//! paper-scale sweep.  [`Scratch`] keeps the retired buffers on a shared
//! free list so each merge reuses the previous merge's allocations
//! instead of hitting the allocator.  Recycling changes WHERE results are
//! written, never WHAT is written, so it is invisible to the exec layer's
//! `--threads N ≡ --threads 1` bit-identity contract; which buffer a
//! worker happens to pop is the only nondeterminism, and no computed
//! value ever depends on it.

use std::sync::Mutex;

/// A lock-guarded free list of reusable buffers.
///
/// [`Scratch::take`] pops a retired buffer (or makes a fresh
/// `T::default()`); callers clear/refill it and hand it back with
/// [`Scratch::put`] once the contents have been consumed.  Shareable
/// across worker threads by reference.
#[derive(Debug)]
pub struct Scratch<T> {
    free: Mutex<Vec<T>>,
}

impl<T> Default for Scratch<T> {
    fn default() -> Self {
        Scratch { free: Mutex::new(Vec::new()) }
    }
}

impl<T: Default> Scratch<T> {
    pub fn new() -> Scratch<T> {
        Scratch::default()
    }

    /// Pop a retired buffer, or build a fresh default one.  The buffer
    /// arrives as its PREVIOUS user left it — callers reset it before
    /// writing.
    pub fn take(&self) -> T {
        self.free.lock().expect("scratch free list poisoned").pop().unwrap_or_default()
    }

    /// Retire a buffer back onto the free list for the next taker.
    pub fn put(&self, buf: T) {
        self.free.lock().expect("scratch free list poisoned").push(buf);
    }

    /// Buffers currently parked on the free list.
    pub fn idle(&self) -> usize {
        self.free.lock().expect("scratch free list poisoned").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_on_an_empty_list_builds_a_default() {
        let s: Scratch<Vec<u8>> = Scratch::new();
        assert_eq!(s.idle(), 0);
        assert!(s.take().is_empty());
    }

    #[test]
    fn put_then_take_recycles_the_allocation() {
        let s: Scratch<Vec<u8>> = Scratch::new();
        let mut buf = s.take();
        buf.reserve(1024);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        s.put(buf);
        assert_eq!(s.idle(), 1);
        let again = s.take();
        assert_eq!(again.capacity(), cap);
        assert_eq!(again.as_ptr(), ptr);
        assert_eq!(s.idle(), 0);
    }

    #[test]
    fn shared_across_threads() {
        let s: Scratch<Vec<u64>> = Scratch::new();
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let s = &s;
                scope.spawn(move || {
                    for i in 0..32 {
                        let mut buf = s.take();
                        buf.clear();
                        buf.push(t * 100 + i);
                        assert_eq!(buf.last(), Some(&(t * 100 + i)));
                        s.put(buf);
                    }
                });
            }
        });
        assert!(s.idle() >= 1 && s.idle() <= 4, "free list holds the retired buffers");
    }
}
