//! Per-group empirical time-gain measurement (paper §2.3.1).
//!
//! "The time gain of the p-th MP configuration of the j-th group is measured
//!  by subtracting the end-to-end TTFT of the model with the j-th group
//!  configured correspondingly (others BF16) from the end-to-end TTFT of the
//!  model in BF16."
//!
//! `TtftSource` abstracts where TTFT comes from: the Gaudi-2-like simulator
//! (primary; see gaudisim) or wall-clock timing of the real compiled HLO on
//! the CPU PJRT client (secondary — proves the harness drives real
//! executables; CPU fake-quant adds ops, so its gains are not Gaudi-shaped).
//!
//! Measurement enumeration fans out across an [`ExecPool`]: every
//! measurement in a pass is assigned a stable stream index in sequential
//! enumeration order, sources draw their noise from
//! [`Rng::stream`]`(seed, index)`, and results are reduced in index order —
//! so the produced gain tables are bit-identical at any thread count.
//! Wall-clock sources are the exception: timing is contention-sensitive,
//! so `measure_groups` on a [`WallTtft`] should be given
//! [`ExecPool::sequential`].

use crate::backend::DeviceProfile;
use crate::exec::ExecPool;
use crate::gaudisim::{enumerate_configs, MpConfig, Simulator};
use crate::graph::partition::Partition;
use crate::graph::Graph;
use crate::numerics::Format;
use crate::runtime::ModelRuntime;
use crate::util::{stats, Rng};
use anyhow::{Context, Result};

/// Provider of one averaged TTFT measurement for a full-model config.
///
/// `stream` is the measurement's stable noise-stream index, assigned by
/// the caller in sequential enumeration order: a source measuring the same
/// `(cfg, stream)` must return the same value no matter which worker calls
/// it or what was measured before (the exec layer's determinism contract).
pub trait TtftSource: Sync {
    fn measure(&self, cfg: &MpConfig, stream: u64) -> Result<f64>;
    /// Number of quantizable layers (config length).
    fn n_qlayers(&self) -> usize;
}

/// Simulator-backed TTFT (the paper's hardware stand-in; any device via
/// [`SimTtft::for_device`]).  Noise is drawn from the per-measurement
/// stream of `seed`, so measurements are order- and thread-independent.
pub struct SimTtft<'g> {
    pub sim: Simulator<'g>,
    /// Base seed; measurement `stream` draws from `Rng::stream(seed, stream)`.
    pub seed: u64,
    /// Paper protocol: average of 5 iterations.
    pub reps: usize,
}

impl<'g> SimTtft<'g> {
    /// A TTFT source simulating `device` (see `backend::DeviceProfile`)
    /// under the given measurement protocol.
    pub fn for_device(
        graph: &'g Graph,
        device: &DeviceProfile,
        seed: u64,
        reps: usize,
    ) -> SimTtft<'g> {
        SimTtft { sim: Simulator::for_device(graph, device), seed, reps }
    }
}

impl<'g> TtftSource for SimTtft<'g> {
    fn measure(&self, cfg: &MpConfig, stream: u64) -> Result<f64> {
        let mut rng = Rng::stream(self.seed, stream);
        Ok(self.sim.measure_ttft(cfg, &mut rng, self.reps))
    }

    fn n_qlayers(&self) -> usize {
        self.sim.graph().qlayers.len()
    }
}

/// Wall-clock TTFT of the real compiled forward on this host.  Ignores the
/// stream index (time is not seedable); measure it on a sequential pool.
pub struct WallTtft<'a> {
    pub mr: &'a ModelRuntime,
    pub tokens: Vec<i32>,
    pub reps: usize,
}

impl<'a> TtftSource for WallTtft<'a> {
    fn measure(&self, cfg: &MpConfig, _stream: u64) -> Result<f64> {
        let ps = vec![1.0f32; self.mr.info.n_qlayers];
        // Warm-up once, then average `reps` timed runs (paper: 5).
        self.mr.fwd(&self.tokens, cfg, &ps)?;
        let mut xs = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = std::time::Instant::now();
            self.mr.fwd(&self.tokens, cfg, &ps)?;
            xs.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        Ok(stats::mean(&xs))
    }

    fn n_qlayers(&self) -> usize {
        self.mr.info.n_qlayers
    }
}

/// Measured gains for one group: gains[p] aligns with configs[p]
/// (columns of the paper's Q_j matrix).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupGains {
    pub group: usize,
    pub qidxs: Vec<usize>,
    pub configs: Vec<Vec<Format>>,
    /// c^ET_{j,p} — TTFT(baseline) - TTFT(config), microseconds.
    pub gains: Vec<f64>,
}

/// Full measurement product: baseline TTFT + per-group gain tables.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeMeasurements {
    pub base_ttft: f64,
    pub groups: Vec<GroupGains>,
}

impl TimeMeasurements {
    /// Predicted TTFT of a full config under group additivity (eq. 7):
    /// baseline minus the sum of matching group gains.
    pub fn predict_ttft(&self, cfg: &MpConfig) -> f64 {
        self.base_ttft - self.predict_gain(cfg)
    }

    /// Predicted total gain c (eq. 7) for a full configuration.
    pub fn predict_gain(&self, cfg: &MpConfig) -> f64 {
        let mut total = 0.0;
        for g in &self.groups {
            let key: Vec<Format> = g.qidxs.iter().map(|&q| cfg.get(q)).collect();
            let p = g
                .configs
                .iter()
                .position(|c| c == &key)
                .expect("config enumerations cover all format combinations");
            total += g.gains[p];
        }
        total
    }
}

/// The chunk size for fanned-out measurement lists: fixed (never derived
/// from the pool width) so the task batching is a pure function of the
/// measurement plan.  Shared with the distributed coordinator so remote
/// task batches mirror the in-process chunking.
pub const MEASURE_CHUNK: usize = 8;

/// One measurement of the flattened (group, config) plan: set `cfg`, time
/// it on stream `k + 1` where `k` is the task's plan index.
#[derive(Clone, Debug)]
pub struct MeasureTask {
    pub group: usize,
    pub cfg: MpConfig,
}

/// The flattened measurement plan of one partition x format menu — the
/// SINGLE source of task enumeration order for both the in-process
/// [`measure_groups`] fan-out and the distributed coordinator, so a TTFT
/// table assembled from remote results is bit-identical to the local one.
pub struct MeasurePlan {
    pub tasks: Vec<MeasureTask>,
    /// Per-group config enumerations, aligned with the task order.
    pub group_configs: Vec<Vec<Vec<Format>>>,
    pub qidxs: Vec<Vec<usize>>,
}

impl MeasurePlan {
    /// Noise-stream index of task `k` (stream 0 is the baseline).
    pub fn stream(k: usize) -> u64 {
        k as u64 + 1
    }

    /// Assemble the measurement product from the baseline TTFT and one
    /// TTFT per task in plan order — the exact reduction the in-process
    /// path performs.
    pub fn assemble(&self, base: f64, ttfts: &[f64]) -> TimeMeasurements {
        assert_eq!(ttfts.len(), self.tasks.len(), "one TTFT per planned task");
        let mut groups: Vec<GroupGains> = self
            .group_configs
            .iter()
            .zip(&self.qidxs)
            .enumerate()
            .map(|(j, (configs, qidxs))| GroupGains {
                group: j,
                qidxs: qidxs.clone(),
                configs: configs.clone(),
                gains: Vec::new(),
            })
            .collect();
        for (task, &t) in self.tasks.iter().zip(ttfts) {
            groups[task.group].gains.push(base - t);
        }
        TimeMeasurements { base_ttft: base, groups }
    }
}

/// Flatten the (group, config) measurement plan in sequential enumeration
/// order.  Refuses absurd config spaces up front (checked F^{L_j}).
pub fn measure_plan(part: &Partition, formats: &[Format], nq: usize) -> Result<MeasurePlan> {
    let total = part
        .n_measurements(formats.len())
        .context("cannot enumerate per-group measurements")?;
    let mut tasks: Vec<MeasureTask> = Vec::with_capacity(total);
    let mut group_configs: Vec<Vec<Vec<Format>>> = Vec::with_capacity(part.groups.len());
    for g in &part.groups {
        let configs = enumerate_configs(formats, g.qidxs.len());
        for cfg_fmts in &configs {
            let mut cfg = MpConfig::all_bf16(nq);
            for (&q, &f) in g.qidxs.iter().zip(cfg_fmts) {
                cfg.set(q, f);
            }
            tasks.push(MeasureTask { group: group_configs.len(), cfg });
        }
        group_configs.push(configs);
    }
    let qidxs = part.groups.iter().map(|g| g.qidxs.clone()).collect();
    Ok(MeasurePlan { tasks, group_configs, qidxs })
}

/// Measure every group x config (paper Algorithm 1, line 3), fanned out
/// over `pool`.  Stream 0 is the baseline; streams 1.. follow the
/// sequential (group, config) enumeration order, so the gain tables are
/// bit-identical at any thread count.
pub fn measure_groups<S: TtftSource>(
    src: &S,
    part: &Partition,
    formats: &[Format],
    pool: &ExecPool,
) -> Result<TimeMeasurements> {
    let nq = src.n_qlayers();
    let plan = measure_plan(part, formats, nq)?;
    let base = src.measure(&MpConfig::all_bf16(nq), 0)?;

    let chunked: Vec<Result<Vec<f64>>> =
        pool.par_chunks(&plan.tasks, MEASURE_CHUNK, |start, chunk| {
            chunk
                .iter()
                .enumerate()
                .map(|(k, t)| src.measure(&t.cfg, MeasurePlan::stream(start + k)))
                .collect()
        });

    let mut ttfts: Vec<f64> = Vec::with_capacity(plan.tasks.len());
    for chunk in chunked {
        ttfts.extend(chunk?);
    }
    Ok(plan.assemble(base, &ttfts))
}

/// Per-layer gains (the naive baseline of Fig. 1): gain of quantizing each
/// single layer alone, summed later to "predict" group gains.  Fanned out
/// like [`measure_groups`]; stream indices follow the sequential
/// (layer, format) enumeration.
pub fn measure_per_layer<S: TtftSource>(
    src: &S,
    formats: &[Format],
    pool: &ExecPool,
) -> Result<Vec<Vec<f64>>> {
    let nq = src.n_qlayers();
    let nf = formats.len();
    if nf == 0 {
        return Ok(vec![Vec::new(); nq]);
    }
    let base = src.measure(&MpConfig::all_bf16(nq), 0)?;
    let cells: Vec<Result<f64>> = pool.par_map(nq * nf, |i| {
        let (q, fi) = (i / nf, i % nf);
        let f = formats[fi];
        if f == Format::Bf16 {
            return Ok(0.0);
        }
        let mut cfg = MpConfig::all_bf16(nq);
        cfg.set(q, f);
        Ok(base - src.measure(&cfg, i as u64 + 1)?)
    });
    let mut flat = Vec::with_capacity(nq * nf);
    for c in cells {
        flat.push(c?);
    }
    Ok(flat.chunks(nf).map(|row| row.to_vec()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{ExecCfg, ExecPool};
    use crate::gaudisim::HwModel;
    use crate::graph::partition::partition;
    use crate::graph::testutil::n;
    use crate::graph::Graph;
    use crate::numerics::PAPER_FORMATS;

    fn small_graph() -> Graph {
        let mut nodes =
            vec![n("s", -1), n("a", 0), n("b", 1), n("m", -1), n("c", 2), n("t", -1)];
        for nd in nodes.iter_mut() {
            if nd.qidx >= 0 {
                nd.macs = 2_000_000;
            }
        }
        // s -> {a, b} -> m -> c -> t
        Graph::synthetic(nodes, vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5)])
    }

    fn sim_src(g: &Graph) -> SimTtft<'_> {
        SimTtft {
            sim: Simulator::new(g, HwModel { noise_std: 0.0, ..HwModel::default() }),
            seed: 0,
            reps: 1,
        }
    }

    #[test]
    fn measures_all_group_configs() {
        let g = small_graph();
        let part = partition(&g).unwrap();
        let src = sim_src(&g);
        let tm =
            measure_groups(&src, &part, &PAPER_FORMATS, &ExecPool::sequential()).unwrap();
        assert_eq!(tm.groups.len(), part.groups.len());
        for (gg, pg) in tm.groups.iter().zip(&part.groups) {
            assert_eq!(gg.gains.len(), 2usize.pow(pg.qidxs.len() as u32));
            // BF16-only config has zero gain by construction.
            let all_bf16 = gg
                .configs
                .iter()
                .position(|c| c.iter().all(|f| *f == Format::Bf16))
                .unwrap();
            assert!(gg.gains[all_bf16].abs() < 1e-9);
            // FP8-everything is the max gain in this monotone simulator.
            let max = gg.gains.iter().cloned().fold(f64::MIN, f64::max);
            let all_fp8 = gg
                .configs
                .iter()
                .position(|c| c.iter().all(|f| *f == Format::Fp8E4m3))
                .unwrap();
            assert!(gg.gains[all_fp8] >= max - 1e-9);
        }
    }

    #[test]
    fn parallel_measurement_is_bit_identical() {
        // WITH noise: the per-measurement RNG streams must line up exactly
        // across thread counts.
        let g = small_graph();
        let part = partition(&g).unwrap();
        let src = SimTtft {
            sim: Simulator::new(&g, HwModel::default()),
            seed: 0x714e33,
            reps: 5,
        };
        let seq =
            measure_groups(&src, &part, &PAPER_FORMATS, &ExecPool::sequential()).unwrap();
        let par = measure_groups(
            &src,
            &part,
            &PAPER_FORMATS,
            &ExecPool::new(ExecCfg::new(4)),
        )
        .unwrap();
        assert_eq!(seq, par);
        let pl_seq = measure_per_layer(&src, &PAPER_FORMATS, &ExecPool::sequential()).unwrap();
        let pl_par =
            measure_per_layer(&src, &PAPER_FORMATS, &ExecPool::new(ExecCfg::new(4))).unwrap();
        assert_eq!(pl_seq, pl_par);
    }

    #[test]
    fn predict_matches_direct_measurement() {
        // Group additivity in the noise-free simulator: predicted TTFT of the
        // all-FP8 config tracks its direct measurement.
        let g = small_graph();
        let part = partition(&g).unwrap();
        let src = sim_src(&g);
        let tm =
            measure_groups(&src, &part, &PAPER_FORMATS, &ExecPool::sequential()).unwrap();
        let full = MpConfig::uniform(3, Format::Fp8E4m3);
        let direct = src.measure(&full, 0).unwrap();
        let predicted = tm.predict_ttft(&full);
        assert!(
            (direct - predicted).abs() / direct < 0.08,
            "direct {direct} vs predicted {predicted}"
        );
    }

    #[test]
    fn per_layer_table_shape() {
        let g = small_graph();
        let src = sim_src(&g);
        let t = measure_per_layer(&src, &PAPER_FORMATS, &ExecPool::sequential()).unwrap();
        assert_eq!(t.len(), 3);
        for row in &t {
            assert_eq!(row.len(), 2);
            assert_eq!(row[0], 0.0); // bf16 column
            assert!(row[1] >= 0.0);
        }
    }
}
