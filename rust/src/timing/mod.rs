//! Per-group empirical time-gain measurement (paper §2.3.1).
//!
//! "The time gain of the p-th MP configuration of the j-th group is measured
//!  by subtracting the end-to-end TTFT of the model with the j-th group
//!  configured correspondingly (others BF16) from the end-to-end TTFT of the
//!  model in BF16."
//!
//! `TtftSource` abstracts where TTFT comes from: the Gaudi-2-like simulator
//! (primary; see gaudisim) or wall-clock timing of the real compiled HLO on
//! the CPU PJRT client (secondary — proves the harness drives real
//! executables; CPU fake-quant adds ops, so its gains are not Gaudi-shaped).

use crate::backend::DeviceProfile;
use crate::gaudisim::{enumerate_configs, MpConfig, Simulator};
use crate::graph::partition::Partition;
use crate::graph::Graph;
use crate::numerics::Format;
use crate::runtime::ModelRuntime;
use crate::util::{stats, Rng};
use anyhow::Result;

/// Provider of one averaged TTFT measurement for a full-model config.
pub trait TtftSource {
    fn measure(&mut self, cfg: &MpConfig) -> Result<f64>;
    /// Number of quantizable layers (config length).
    fn n_qlayers(&self) -> usize;
}

/// Simulator-backed TTFT (the paper's hardware stand-in; any device via
/// [`SimTtft::for_device`]).
pub struct SimTtft<'g> {
    pub sim: Simulator<'g>,
    pub rng: Rng,
    /// Paper protocol: average of 5 iterations.
    pub reps: usize,
}

impl<'g> SimTtft<'g> {
    /// A TTFT source simulating `device` (see `backend::DeviceProfile`)
    /// under the given measurement protocol.
    pub fn for_device(
        graph: &'g Graph,
        device: &DeviceProfile,
        seed: u64,
        reps: usize,
    ) -> SimTtft<'g> {
        SimTtft { sim: Simulator::for_device(graph, device), rng: Rng::new(seed), reps }
    }
}

impl<'g> TtftSource for SimTtft<'g> {
    fn measure(&mut self, cfg: &MpConfig) -> Result<f64> {
        Ok(self.sim.measure_ttft(cfg, &mut self.rng, self.reps))
    }

    fn n_qlayers(&self) -> usize {
        self.sim.graph().qlayers.len()
    }
}

/// Wall-clock TTFT of the real compiled forward on this host.
pub struct WallTtft<'a> {
    pub mr: &'a ModelRuntime,
    pub tokens: Vec<i32>,
    pub reps: usize,
}

impl<'a> TtftSource for WallTtft<'a> {
    fn measure(&mut self, cfg: &MpConfig) -> Result<f64> {
        let ps = vec![1.0f32; self.mr.info.n_qlayers];
        // Warm-up once, then average `reps` timed runs (paper: 5).
        self.mr.fwd(&self.tokens, cfg, &ps)?;
        let mut xs = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = std::time::Instant::now();
            self.mr.fwd(&self.tokens, cfg, &ps)?;
            xs.push(t0.elapsed().as_secs_f64() * 1e6);
        }
        Ok(stats::mean(&xs))
    }

    fn n_qlayers(&self) -> usize {
        self.mr.info.n_qlayers
    }
}

/// Measured gains for one group: gains[p] aligns with configs[p]
/// (columns of the paper's Q_j matrix).
#[derive(Clone, Debug, PartialEq)]
pub struct GroupGains {
    pub group: usize,
    pub qidxs: Vec<usize>,
    pub configs: Vec<Vec<Format>>,
    /// c^ET_{j,p} — TTFT(baseline) - TTFT(config), microseconds.
    pub gains: Vec<f64>,
}

/// Full measurement product: baseline TTFT + per-group gain tables.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeMeasurements {
    pub base_ttft: f64,
    pub groups: Vec<GroupGains>,
}

impl TimeMeasurements {
    /// Predicted TTFT of a full config under group additivity (eq. 7):
    /// baseline minus the sum of matching group gains.
    pub fn predict_ttft(&self, cfg: &MpConfig) -> f64 {
        self.base_ttft - self.predict_gain(cfg)
    }

    /// Predicted total gain c (eq. 7) for a full configuration.
    pub fn predict_gain(&self, cfg: &MpConfig) -> f64 {
        let mut total = 0.0;
        for g in &self.groups {
            let key: Vec<Format> = g.qidxs.iter().map(|&q| cfg.get(q)).collect();
            let p = g
                .configs
                .iter()
                .position(|c| c == &key)
                .expect("config enumerations cover all format combinations");
            total += g.gains[p];
        }
        total
    }
}

/// Measure every group x config (paper Algorithm 1, line 3).
pub fn measure_groups<S: TtftSource>(
    src: &mut S,
    part: &Partition,
    formats: &[Format],
) -> Result<TimeMeasurements> {
    let nq = src.n_qlayers();
    let base = src.measure(&MpConfig::all_bf16(nq))?;
    let mut groups = Vec::with_capacity(part.groups.len());
    for (j, g) in part.groups.iter().enumerate() {
        let configs = enumerate_configs(formats, g.qidxs.len());
        let mut gains = Vec::with_capacity(configs.len());
        for cfg_fmts in &configs {
            let mut cfg = MpConfig::all_bf16(nq);
            for (&q, &f) in g.qidxs.iter().zip(cfg_fmts) {
                cfg.set(q, f);
            }
            let t = src.measure(&cfg)?;
            gains.push(base - t);
        }
        groups.push(GroupGains { group: j, qidxs: g.qidxs.clone(), configs, gains });
    }
    Ok(TimeMeasurements { base_ttft: base, groups })
}

/// Per-layer gains (the naive baseline of Fig. 1): gain of quantizing each
/// single layer alone, summed later to "predict" group gains.
pub fn measure_per_layer<S: TtftSource>(
    src: &mut S,
    formats: &[Format],
) -> Result<Vec<Vec<f64>>> {
    let nq = src.n_qlayers();
    let base = src.measure(&MpConfig::all_bf16(nq))?;
    let mut out = Vec::with_capacity(nq);
    for q in 0..nq {
        let mut per_fmt = Vec::with_capacity(formats.len());
        for &f in formats {
            if f == Format::Bf16 {
                per_fmt.push(0.0);
                continue;
            }
            let mut cfg = MpConfig::all_bf16(nq);
            cfg.set(q, f);
            per_fmt.push(base - src.measure(&cfg)?);
        }
        out.push(per_fmt);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gaudisim::HwModel;
    use crate::graph::partition::partition;
    use crate::graph::testutil::n;
    use crate::graph::Graph;
    use crate::numerics::PAPER_FORMATS;

    fn small_graph() -> Graph {
        let mut nodes =
            vec![n("s", -1), n("a", 0), n("b", 1), n("m", -1), n("c", 2), n("t", -1)];
        for nd in nodes.iter_mut() {
            if nd.qidx >= 0 {
                nd.macs = 2_000_000;
            }
        }
        // s -> {a, b} -> m -> c -> t
        Graph::synthetic(nodes, vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (4, 5)])
    }

    fn sim_src(g: &Graph) -> SimTtft<'_> {
        SimTtft {
            sim: Simulator::new(g, HwModel { noise_std: 0.0, ..HwModel::default() }),
            rng: Rng::new(0),
            reps: 1,
        }
    }

    #[test]
    fn measures_all_group_configs() {
        let g = small_graph();
        let part = partition(&g).unwrap();
        let mut src = sim_src(&g);
        let tm = measure_groups(&mut src, &part, &PAPER_FORMATS).unwrap();
        assert_eq!(tm.groups.len(), part.groups.len());
        for (gg, pg) in tm.groups.iter().zip(&part.groups) {
            assert_eq!(gg.gains.len(), 2usize.pow(pg.qidxs.len() as u32));
            // BF16-only config has zero gain by construction.
            let all_bf16 = gg
                .configs
                .iter()
                .position(|c| c.iter().all(|f| *f == Format::Bf16))
                .unwrap();
            assert!(gg.gains[all_bf16].abs() < 1e-9);
            // FP8-everything is the max gain in this monotone simulator.
            let max = gg.gains.iter().cloned().fold(f64::MIN, f64::max);
            let all_fp8 = gg
                .configs
                .iter()
                .position(|c| c.iter().all(|f| *f == Format::Fp8E4m3))
                .unwrap();
            assert!(gg.gains[all_fp8] >= max - 1e-9);
        }
    }

    #[test]
    fn predict_matches_direct_measurement() {
        // Group additivity in the noise-free simulator: predicted TTFT of the
        // all-FP8 config tracks its direct measurement.
        let g = small_graph();
        let part = partition(&g).unwrap();
        let mut src = sim_src(&g);
        let tm = measure_groups(&mut src, &part, &PAPER_FORMATS).unwrap();
        let full = MpConfig::uniform(3, Format::Fp8E4m3);
        let direct = src.measure(&full).unwrap();
        let predicted = tm.predict_ttft(&full);
        assert!(
            (direct - predicted).abs() / direct < 0.08,
            "direct {direct} vs predicted {predicted}"
        );
    }

    #[test]
    fn per_layer_table_shape() {
        let g = small_graph();
        let mut src = sim_src(&g);
        let t = measure_per_layer(&mut src, &PAPER_FORMATS).unwrap();
        assert_eq!(t.len(), 3);
        for row in &t {
            assert_eq!(row.len(), 2);
            assert_eq!(row[0], 0.0); // bf16 column
            assert!(row[1] >= 0.0);
        }
    }
}
