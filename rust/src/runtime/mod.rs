//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the rust hot path.  Python is never involved here.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id proto incompatibility.

use crate::gaudisim::MpConfig;
use crate::model::ModelInfo;
use crate::tensorbin::Tensor;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Shared PJRT CPU client (compile + execute).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn compile(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
    }
}

/// Which forward artifact to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwdMode {
    /// fwd_quant.hlo.txt — the L1 Pallas kernel path (the real system).
    Pallas,
    /// fwd_ref.hlo.txt — pure-jnp quant path (fast sweeps / cross-checks).
    Ref,
}

/// Output of one forward execution.
#[derive(Clone, Debug)]
pub struct FwdOut {
    /// Logits, row-major [B, T, V].
    pub logits: Vec<f32>,
    /// Per-sample PAD-masked mean CE loss, [B].
    pub loss: Vec<f32>,
}

/// A model bound to compiled executables + uploaded weights.
pub struct ModelRuntime {
    pub info: ModelInfo,
    fwd: xla::PjRtLoadedExecutable,
    sens: xla::PjRtLoadedExecutable,
    /// Weight literals in param_order — reused across every call.
    weights: Vec<xla::Literal>,
    pub fwd_mode: FwdMode,
}

fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal shape {:?} vs data len {}", dims, data.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("literal shape {:?} vs data len {}", dims, data.len());
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

impl ModelRuntime {
    /// Compile the forward + sensitivity executables and upload weights.
    pub fn load(rt: &Runtime, root: &Path, info: &ModelInfo, mode: FwdMode) -> Result<ModelRuntime> {
        let fwd_path = match mode {
            FwdMode::Pallas => &info.paths.fwd_quant,
            FwdMode::Ref => &info.paths.fwd_ref,
        };
        let fwd = rt.compile(&root.join(fwd_path))?;
        let sens = rt.compile(&root.join(&info.paths.sensitivity))?;

        let wfile = info.load_weights(root)?;
        let mut weights = Vec::with_capacity(info.param_order.len());
        for (name, shape) in info.param_order.iter().zip(&info.param_shapes) {
            let t = wfile.get(name)?;
            match t {
                Tensor::F32 { data, .. } => weights.push(literal_f32(data, shape)?),
                Tensor::I32 { .. } => bail!("{name}: weights must be f32"),
            }
        }
        Ok(ModelRuntime { info: info.clone(), fwd, sens, weights, fwd_mode: mode })
    }

    /// Forward pass: tokens is row-major [B, T] with B == info.eval_b.
    pub fn fwd(&self, tokens: &[i32], config: &MpConfig, pscale: &[f32]) -> Result<FwdOut> {
        let b = self.info.eval_b;
        let t = self.info.seq;
        if tokens.len() != b * t {
            bail!("tokens len {} != {}x{}", tokens.len(), b, t);
        }
        let mbits = config.mbits_f32();
        if mbits.len() != self.info.n_qlayers || pscale.len() != self.info.n_qlayers {
            bail!("config/pscale length mismatch");
        }
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 + self.weights.len());
        let tok_lit = literal_i32(tokens, &[b, t])?;
        let mb_lit = literal_f32(&mbits, &[mbits.len()])?;
        let ps_lit = literal_f32(pscale, &[pscale.len()])?;
        args.push(&tok_lit);
        args.push(&mb_lit);
        args.push(&ps_lit);
        for w in &self.weights {
            args.push(w);
        }
        let result = self
            .fwd
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("fwd execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fwd fetch: {e:?}"))?;
        let (logits_l, loss_l) = lit.to_tuple2().map_err(|e| anyhow!("fwd tuple: {e:?}"))?;
        let logits = logits_l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let loss = loss_l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        if logits.len() != b * t * self.info.vocab || loss.len() != b {
            bail!("fwd output shape unexpected");
        }
        Ok(FwdOut { logits, loss })
    }

    /// High-precision forward (fp32 identity quantization).
    pub fn fwd_fp32(&self, tokens: &[i32]) -> Result<FwdOut> {
        let cfg = MpConfig::uniform(self.info.n_qlayers, crate::numerics::Format::Fp32);
        let ones = vec![1.0f32; self.info.n_qlayers];
        self.fwd(tokens, &cfg, &ones)
    }

    /// Sensitivity pass for ONE calibration sample (tokens: [T]).
    /// Returns (g, s[Lq]) — eq. (19) per sample.
    pub fn sensitivity(&self, tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let t = self.info.seq;
        if tokens.len() != t {
            bail!("sensitivity tokens len {} != {}", tokens.len(), t);
        }
        let tok_lit = literal_i32(tokens, &[1, t])?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + self.weights.len());
        args.push(&tok_lit);
        for w in &self.weights {
            args.push(w);
        }
        let result = self
            .sens
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow!("sensitivity execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sensitivity fetch: {e:?}"))?;
        let (g_l, s_l) = lit.to_tuple2().map_err(|e| anyhow!("sens tuple: {e:?}"))?;
        let g = g_l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        let s = s_l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        if s.len() != self.info.n_qlayers {
            bail!("sensitivity output length {}", s.len());
        }
        Ok((g, s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;
    use crate::numerics::Format;
    use std::path::PathBuf;

    fn root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn runtime_for(mode: FwdMode) -> (Manifest, Runtime, ModelRuntime) {
        let m = Manifest::load(&root()).expect("make artifacts first");
        let rt = Runtime::new().unwrap();
        let info = m.model("tiny-s").unwrap().clone();
        let mr = ModelRuntime::load(&rt, &m.root, &info, mode).unwrap();
        (m, rt, mr)
    }

    #[test]
    #[ignore = "requires real PJRT bindings + AOT artifacts (vendored xla stub cannot execute)"]
    fn fwd_executes_and_shapes() {
        let (m, _rt, mr) = runtime_for(FwdMode::Ref);
        let calib = mr.info.load_calib(&m.root).unwrap();
        let b = mr.info.eval_b;
        let tokens: Vec<i32> = calib[..b].concat();
        let out = mr.fwd_fp32(&tokens).unwrap();
        assert_eq!(out.loss.len(), b);
        assert!(out.loss.iter().all(|l| l.is_finite() && *l > 0.0));
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    #[ignore = "requires real PJRT bindings + AOT artifacts (vendored xla stub cannot execute)"]
    fn quantization_perturbs_loss() {
        let (m, _rt, mr) = runtime_for(FwdMode::Ref);
        let calib = mr.info.load_calib(&m.root).unwrap();
        let b = mr.info.eval_b;
        let tokens: Vec<i32> = calib[..b].concat();
        let hp = mr.fwd_fp32(&tokens).unwrap();
        let fp8 = MpConfig::uniform(mr.info.n_qlayers, Format::Fp8E4m3);
        let ones = vec![1.0f32; mr.info.n_qlayers];
        let q = mr.fwd(&tokens, &fp8, &ones).unwrap();
        let diff: f32 = hp
            .loss
            .iter()
            .zip(&q.loss)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-6, "fp8 must perturb the loss");
        // BF16 perturbs much less than FP8.
        let bf16 = MpConfig::all_bf16(mr.info.n_qlayers);
        let qb = mr.fwd(&tokens, &bf16, &ones).unwrap();
        let diff_b: f32 = hp
            .loss
            .iter()
            .zip(&qb.loss)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff_b < diff, "bf16 {diff_b} should perturb less than fp8 {diff}");
    }

    #[test]
    #[ignore = "requires real PJRT bindings + AOT artifacts (vendored xla stub cannot execute)"]
    fn sensitivity_runs() {
        let (m, _rt, mr) = runtime_for(FwdMode::Ref);
        let calib = mr.info.load_calib(&m.root).unwrap();
        let (g, s) = mr.sensitivity(&calib[0]).unwrap();
        assert!(g > 0.0 && g.is_finite());
        assert_eq!(s.len(), mr.info.n_qlayers);
        assert!(s.iter().all(|x| x.is_finite() && *x >= 0.0));
        assert!(s.iter().any(|x| *x > 0.0));
    }

    #[test]
    #[ignore = "requires real PJRT bindings + AOT artifacts (vendored xla stub cannot execute)"]
    fn pallas_and_ref_agree_at_fp32() {
        let m = Manifest::load(&root()).unwrap();
        let rt = Runtime::new().unwrap();
        let info = m.model("tiny-s").unwrap().clone();
        let mr_p = ModelRuntime::load(&rt, &m.root, &info, FwdMode::Pallas).unwrap();
        let mr_r = ModelRuntime::load(&rt, &m.root, &info, FwdMode::Ref).unwrap();
        let calib = info.load_calib(&m.root).unwrap();
        let tokens: Vec<i32> = calib[..info.eval_b].concat();
        let a = mr_p.fwd_fp32(&tokens).unwrap();
        let b = mr_r.fwd_fp32(&tokens).unwrap();
        for (x, y) in a.logits.iter().zip(&b.logits) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }
}
