//! Pluggable hardware backends.
//!
//! The paper measures time gains on one device (Intel Gaudi 2); this
//! subsystem makes the device a *parameter*.  A [`DeviceProfile`] bundles
//! every hardware number the planner consumes — engine counts, the
//! per-format MME [`RateTable`], TPC/HBM rooflines, launch overhead, the
//! fusion flag, the supported-format mask, and HBM capacity — and a
//! [`Registry`] resolves device names (four built-ins plus user JSON
//! files) to profiles.
//!
//! Downstream construction points:
//! * `gaudisim::HwModel::from_profile` / `Simulator::for_device` — the
//!   timing simulator for a device;
//! * `timing::SimTtft::for_device` — a TTFT source for a device;
//! * `metrics::theoretical_groups` — eq.-24 MAC gains use the device's
//!   rate table (the old `Format::mme_rate` hard-coding is gone);
//! * `plan::Engine::with_device` — stages Measured artifacts keyed by
//!   device, so measurements for different devices never collide;
//! * `plan::PlanRequest::with_device` / `plan::PlanService` — per-device
//!   request routing;
//! * `ampq devices` / `ampq plan --device` / `ampq compare --devices` —
//!   the CLI surface.

pub mod profile;
pub mod registry;

pub use profile::{DeviceProfile, RateTable};
pub use registry::{Registry, DEFAULT_DEVICE};
