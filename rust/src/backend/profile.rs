//! Device profiles: the data that makes the time-gain predictor
//! hardware-aware.
//!
//! The paper's sensitivity side (eq. 21-23) is hardware-agnostic, but its
//! gain side is not: per-format MME throughput, engine counts, rooflines and
//! launch overhead all belong to a *device*, not to the algorithm.  A
//! [`DeviceProfile`] captures exactly that parameter set, serializes through
//! `util::Json` (round-trips exactly), and is the single source every
//! hardware-touching layer is constructed from: `gaudisim::HwModel`
//! (simulator parameters), `metrics::theoretical_groups` (per-MAC delta_T),
//! and the `Strategy` format menus (supported-format mask).  Adding a device
//! is a data file, not a code fork.

use crate::numerics::{Format, N_FORMATS};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Per-format MME throughput multipliers relative to BF16 (1.0 = one BF16
/// MAC time per MAC; 2.0 = twice the MAC rate).  Replaces the old
/// `Format::mme_rate` hard-coding — throughput is device data.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateTable {
    rates: [f64; N_FORMATS],
}

impl RateTable {
    /// Every format at the same `rate`.
    pub fn uniform(rate: f64) -> RateTable {
        RateTable { rates: [rate; N_FORMATS] }
    }

    /// Gaudi-2-like rates: FP8 MACs run 2x, FP32 at half rate, FP16/BF16
    /// at baseline (the values `Format::mme_rate` used to hard-code).
    pub fn gaudi2() -> RateTable {
        RateTable::uniform(1.0)
            .with(Format::Fp32, 0.5)
            .with(Format::Fp8E4m3, 2.0)
            .with(Format::Fp8E5m2, 2.0)
    }

    pub fn get(&self, f: Format) -> f64 {
        self.rates[f.index()]
    }

    pub fn set(&mut self, f: Format, rate: f64) {
        self.rates[f.index()] = rate;
    }

    pub fn with(mut self, f: Format, rate: f64) -> RateTable {
        self.set(f, rate);
        self
    }

    /// Per-MAC time gain of format f vs the BF16 baseline, delta_T,f
    /// (paper eq. 24): 1 - rate(bf16)/rate(f) in units of "BF16 MAC times".
    pub fn delta_t(&self, f: Format) -> f64 {
        1.0 - self.get(Format::Bf16) / self.get(f)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            Format::ALL
                .iter()
                .map(|f| (f.name().to_string(), Json::Num(self.get(*f))))
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<RateTable> {
        let mut t = RateTable::uniform(1.0);
        for f in Format::ALL {
            let rate = j.get(f.name())?.f64()?;
            if !rate.is_finite() || rate <= 0.0 {
                bail!("mme rate for {} must be positive and finite (got {rate})", f.name());
            }
            t.set(f, rate);
        }
        Ok(t)
    }
}

/// Everything the planner needs to know about one accelerator: engine
/// counts, per-format MME rate table, TPC/HBM rooflines, launch overhead,
/// fusion capability, supported-format mask, and HBM capacity.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceProfile {
    /// Registry key; also stamps Measured artifacts and Plans.
    pub name: String,
    /// Parallel matrix engines.
    pub n_mme: usize,
    /// Parallel vector engines.
    pub n_tpc: usize,
    /// BF16 MACs per microsecond per MME engine.
    pub mme_macs_per_us: f64,
    /// Vector-engine processed bytes per microsecond per TPC engine.
    pub tpc_bytes_per_us: f64,
    /// HBM bandwidth, bytes per microsecond (shared).
    pub hbm_bytes_per_us: f64,
    /// Kernel launch overhead, microseconds (fused chains pay once).
    pub launch_us: f64,
    /// Multiplicative std-dev of TTFT measurement noise.
    pub noise_std: f64,
    /// Elementwise-chain fusion on the vector engine.
    pub enable_fusion: bool,
    /// Per-format MME throughput multipliers vs BF16.
    pub mme_rates: RateTable,
    /// Formats the device can execute; planning menus are restricted to
    /// this mask.  Must contain the BF16 baseline.
    pub supported: Vec<Format>,
    /// Total HBM capacity in bytes (profile metadata; per-request memory
    /// caps are expressed on the PlanRequest).
    pub hbm_capacity_bytes: f64,
}

impl DeviceProfile {
    /// Today's defaults: the Gaudi-2-like testbed every pre-backend
    /// measurement ran on (bit-for-bit identical simulator behaviour).
    pub fn gaudi2() -> DeviceProfile {
        DeviceProfile {
            name: "gaudi2".into(),
            n_mme: 2,
            n_tpc: 4,
            mme_macs_per_us: 100_000.0,
            tpc_bytes_per_us: 12_000.0,
            hbm_bytes_per_us: 40_000.0,
            launch_us: 1.5,
            noise_std: 0.01,
            enable_fusion: true,
            mme_rates: RateTable::gaudi2(),
            supported: Format::ALL.to_vec(),
            hbm_capacity_bytes: 96.0e9,
        }
    }

    /// Gaudi-3-like: 2x MME throughput and 2x HBM bandwidth over gaudi2,
    /// larger HBM pool; same relative format rates.
    pub fn gaudi3() -> DeviceProfile {
        DeviceProfile {
            name: "gaudi3".into(),
            mme_macs_per_us: 200_000.0,
            hbm_bytes_per_us: 80_000.0,
            hbm_capacity_bytes: 128.0e9,
            ..DeviceProfile::gaudi2()
        }
    }

    /// A generic GPU: four symmetric MME/TPC engine pairs, fast FP16
    /// (2x like FP8), no FP8-E5M2 support, higher launch overhead.
    pub fn generic_gpu() -> DeviceProfile {
        DeviceProfile {
            name: "generic-gpu".into(),
            n_mme: 4,
            n_tpc: 4,
            mme_macs_per_us: 80_000.0,
            tpc_bytes_per_us: 16_000.0,
            hbm_bytes_per_us: 60_000.0,
            launch_us: 3.0,
            noise_std: 0.01,
            enable_fusion: true,
            mme_rates: RateTable::gaudi2().with(Format::Fp16, 2.0),
            supported: vec![Format::Fp32, Format::Fp16, Format::Bf16, Format::Fp8E4m3],
            hbm_capacity_bytes: 80.0e9,
        }
    }

    /// A CPU roofline: one engine pair, compute-bound MME work, and NO
    /// per-format throughput advantage — quantizing buys bytes, not time.
    pub fn cpu_roofline() -> DeviceProfile {
        DeviceProfile {
            name: "cpu-roofline".into(),
            n_mme: 1,
            n_tpc: 1,
            mme_macs_per_us: 5_000.0,
            tpc_bytes_per_us: 8_000.0,
            hbm_bytes_per_us: 40_000.0,
            launch_us: 5.0,
            noise_std: 0.0,
            enable_fusion: false,
            mme_rates: RateTable::uniform(1.0).with(Format::Fp32, 0.5),
            supported: Format::ALL.to_vec(),
            hbm_capacity_bytes: 512.0e9,
        }
    }

    pub fn supports(&self, f: Format) -> bool {
        self.supported.contains(&f)
    }

    /// Restrict a requested format menu to this device's supported set
    /// (menu order preserved).
    pub fn restrict_menu(&self, menu: &[Format]) -> Vec<Format> {
        menu.iter().copied().filter(|f| self.supports(*f)).collect()
    }

    /// MME throughput multiplier of `f` vs BF16.
    pub fn mme_rate(&self, f: Format) -> f64 {
        self.mme_rates.get(f)
    }

    /// Per-MAC time gain delta_T,f of this device (paper eq. 24).
    pub fn delta_t(&self, f: Format) -> f64 {
        self.mme_rates.delta_t(f)
    }

    /// Filesystem-safe key for per-device cache files.  When sanitization
    /// would alter the name, a stable FNV-1a hash of the ORIGINAL name is
    /// appended so distinct device names ("my accel" vs "my-accel") never
    /// share a cache file.
    pub fn fs_key(&self) -> String {
        let safe: String = self
            .name
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '-' })
            .collect();
        if safe == self.name {
            return safe;
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.name.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        format!("{safe}-{h:016x}")
    }

    /// Structural sanity: positive rooflines, at least one engine of each
    /// kind, BF16 in the supported mask.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("device profile needs a non-empty name");
        }
        if self.n_mme == 0 || self.n_tpc == 0 {
            bail!("device '{}' needs at least one MME and one TPC engine", self.name);
        }
        for (what, v) in [
            ("mme_macs_per_us", self.mme_macs_per_us),
            ("tpc_bytes_per_us", self.tpc_bytes_per_us),
            ("hbm_bytes_per_us", self.hbm_bytes_per_us),
        ] {
            if !v.is_finite() || v <= 0.0 {
                bail!("device '{}': {what} must be positive and finite (got {v})", self.name);
            }
        }
        for (what, v) in [
            ("launch_us", self.launch_us),
            ("noise_std", self.noise_std),
            ("hbm_capacity_bytes", self.hbm_capacity_bytes),
        ] {
            if !v.is_finite() || v < 0.0 {
                bail!("device '{}': {what} must be non-negative and finite (got {v})", self.name);
            }
        }
        for f in Format::ALL {
            let rate = self.mme_rates.get(f);
            if !rate.is_finite() || rate <= 0.0 {
                bail!(
                    "device '{}': mme rate for {} must be positive and finite (got {rate})",
                    self.name,
                    f.name()
                );
            }
        }
        if !self.supports(Format::Bf16) {
            bail!("device '{}' must support the BF16 baseline format", self.name);
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("n_mme".into(), Json::Num(self.n_mme as f64)),
            ("n_tpc".into(), Json::Num(self.n_tpc as f64)),
            ("mme_macs_per_us".into(), Json::Num(self.mme_macs_per_us)),
            ("tpc_bytes_per_us".into(), Json::Num(self.tpc_bytes_per_us)),
            ("hbm_bytes_per_us".into(), Json::Num(self.hbm_bytes_per_us)),
            ("launch_us".into(), Json::Num(self.launch_us)),
            ("noise_std".into(), Json::Num(self.noise_std)),
            ("enable_fusion".into(), Json::Bool(self.enable_fusion)),
            ("mme_rates".into(), self.mme_rates.to_json()),
            (
                "supported_formats".into(),
                Json::Arr(
                    self.supported
                        .iter()
                        .map(|f| Json::Str(f.name().to_string()))
                        .collect(),
                ),
            ),
            ("hbm_capacity_bytes".into(), Json::Num(self.hbm_capacity_bytes)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DeviceProfile> {
        let supported = j
            .get("supported_formats")?
            .arr()?
            .iter()
            .map(|x| {
                let name = x.str()?;
                Format::from_name(name).ok_or_else(|| anyhow!("unknown format '{name}'"))
            })
            .collect::<Result<Vec<_>>>()?;
        let enable_fusion = match j.get("enable_fusion")? {
            Json::Bool(b) => *b,
            _ => bail!("'enable_fusion' must be a bool"),
        };
        let p = DeviceProfile {
            name: j.get("name")?.str()?.to_string(),
            n_mme: j.get("n_mme")?.usize()?,
            n_tpc: j.get("n_tpc")?.usize()?,
            mme_macs_per_us: j.get("mme_macs_per_us")?.f64()?,
            tpc_bytes_per_us: j.get("tpc_bytes_per_us")?.f64()?,
            hbm_bytes_per_us: j.get("hbm_bytes_per_us")?.f64()?,
            launch_us: j.get("launch_us")?.f64()?,
            noise_std: j.get("noise_std")?.f64()?,
            enable_fusion,
            mme_rates: RateTable::from_json(j.get("mme_rates")?)?,
            supported,
            hbm_capacity_bytes: j.get("hbm_capacity_bytes")?.f64()?,
        };
        p.validate()?;
        Ok(p)
    }

    /// Load and validate one profile from a user JSON file.
    pub fn load_file(path: &Path) -> Result<DeviceProfile> {
        DeviceProfile::from_json(&Json::parse_file(path)?)
            .map_err(|e| anyhow!("device profile {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaudi2_rates_match_the_old_hardcoding() {
        let t = RateTable::gaudi2();
        assert_eq!(t.get(Format::Fp32), 0.5);
        assert_eq!(t.get(Format::Fp16), 1.0);
        assert_eq!(t.get(Format::Bf16), 1.0);
        assert_eq!(t.get(Format::Fp8E4m3), 2.0);
        assert_eq!(t.get(Format::Fp8E5m2), 2.0);
        // The eq.-24 deltas the IP-TT family is built from.
        assert_eq!(t.delta_t(Format::Bf16), 0.0);
        assert_eq!(t.delta_t(Format::Fp8E4m3), 0.5);
        assert_eq!(t.delta_t(Format::Fp32), -1.0);
    }

    #[test]
    fn cpu_roofline_has_no_fp8_rate_advantage() {
        let p = DeviceProfile::cpu_roofline();
        assert_eq!(p.delta_t(Format::Fp8E4m3), 0.0);
        assert_eq!(p.n_mme, 1);
        assert!(!p.enable_fusion);
    }

    #[test]
    fn profile_json_roundtrip_exact() {
        for p in [
            DeviceProfile::gaudi2(),
            DeviceProfile::gaudi3(),
            DeviceProfile::generic_gpu(),
            DeviceProfile::cpu_roofline(),
        ] {
            let text = p.to_json().to_string();
            let back = DeviceProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, p, "{}", p.name);
        }
    }

    #[test]
    fn validation_rejects_broken_profiles() {
        let mut p = DeviceProfile::gaudi2();
        p.n_mme = 0;
        assert!(p.validate().is_err());
        let mut p = DeviceProfile::gaudi2();
        p.supported = vec![Format::Fp8E4m3];
        assert!(p.validate().is_err(), "bf16 baseline must be supported");
        let mut p = DeviceProfile::gaudi2();
        p.hbm_bytes_per_us = 0.0;
        assert!(p.validate().is_err());
        let mut p = DeviceProfile::gaudi2();
        p.mme_rates.set(Format::Fp8E4m3, 0.0);
        assert!(p.validate().is_err(), "zero MME rates must be rejected");
        // from_json re-validates: a doctored file is rejected.
        let mut j = DeviceProfile::gaudi2().to_json();
        if let Json::Obj(kv) = &mut j {
            for (k, v) in kv.iter_mut() {
                if k == "n_tpc" {
                    *v = Json::Num(0.0);
                }
            }
        }
        assert!(DeviceProfile::from_json(&j).is_err());
    }

    #[test]
    fn menu_restriction_respects_the_mask() {
        let gpu = DeviceProfile::generic_gpu();
        assert!(!gpu.supports(Format::Fp8E5m2));
        assert_eq!(
            gpu.restrict_menu(&[Format::Bf16, Format::Fp8E5m2, Format::Fp8E4m3]),
            vec![Format::Bf16, Format::Fp8E4m3]
        );
    }

    #[test]
    fn fs_key_sanitizes_and_disambiguates() {
        // Clean names pass through untouched (built-in cache file names
        // stay human-readable and stable).
        assert_eq!(DeviceProfile::gaudi2().fs_key(), "gaudi2");
        assert_eq!(DeviceProfile::cpu_roofline().fs_key(), "cpu-roofline");
        // Names needing sanitization get a stable hash suffix, so two
        // names that sanitize identically still get distinct cache files.
        let mut a = DeviceProfile::gaudi2();
        a.name = "my accel".into();
        let mut b = DeviceProfile::gaudi2();
        b.name = "my-accel".into();
        let mut c = DeviceProfile::gaudi2();
        c.name = "my/accel".into();
        assert!(a.fs_key().starts_with("my-accel-"));
        assert_eq!(b.fs_key(), "my-accel");
        assert_ne!(a.fs_key(), b.fs_key());
        assert_ne!(a.fs_key(), c.fs_key());
        assert_eq!(a.fs_key(), a.fs_key(), "key must be deterministic");
    }
}
