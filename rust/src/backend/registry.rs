//! A name -> [`DeviceProfile`] registry with the four built-in devices and
//! user-supplied JSON profiles.

use super::profile::DeviceProfile;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// The device every pre-backend measurement implicitly ran on.
pub const DEFAULT_DEVICE: &str = "gaudi2";

/// Device profile registry.  `Registry::builtin()` carries the four
/// shipped devices; `load`/`register` add user profiles.
pub struct Registry {
    profiles: BTreeMap<String, DeviceProfile>,
}

impl Registry {
    pub fn empty() -> Registry {
        Registry { profiles: BTreeMap::new() }
    }

    /// The built-in device set: `gaudi2` (today's defaults), `gaudi3`
    /// (2x MME/HBM), `generic-gpu` (4 symmetric engines, fp16-fast),
    /// `cpu-roofline` (1 engine, no fp8 speedup).
    pub fn builtin() -> Registry {
        let mut r = Registry::empty();
        for p in [
            DeviceProfile::gaudi2(),
            DeviceProfile::gaudi3(),
            DeviceProfile::generic_gpu(),
            DeviceProfile::cpu_roofline(),
        ] {
            r.register(p);
        }
        r
    }

    /// Register (or replace) a profile under its own name.
    pub fn register(&mut self, profile: DeviceProfile) {
        self.profiles.insert(profile.name.clone(), profile);
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.profiles.keys().cloned().collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &DeviceProfile> {
        self.profiles.values()
    }

    pub fn get(&self, name: &str) -> Result<DeviceProfile> {
        self.profiles.get(name).cloned().ok_or_else(|| {
            anyhow!(
                "unknown device '{name}' (known: {})",
                self.names().join(", ")
            )
        })
    }

    /// Load a user JSON profile file, register it, and return its name.
    pub fn load(&mut self, path: &Path) -> Result<String> {
        let p = DeviceProfile::load_file(path)?;
        let name = p.name.clone();
        self.register(p);
        Ok(name)
    }

    /// Resolve a CLI device spec: a registered name, or a path to a JSON
    /// profile file.
    pub fn resolve(&self, spec: &str) -> Result<DeviceProfile> {
        if let Ok(p) = self.get(spec) {
            return Ok(p);
        }
        let path = Path::new(spec);
        if path.exists() {
            return DeviceProfile::load_file(path);
        }
        Err(anyhow!(
            "device '{spec}' is neither a registered profile (known: {}) nor a JSON file",
            self.names().join(", ")
        ))
    }
}

impl Default for Registry {
    fn default() -> Self {
        Registry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_set_is_complete() {
        let r = Registry::builtin();
        assert_eq!(
            r.names(),
            vec!["cpu-roofline", "gaudi2", "gaudi3", "generic-gpu"]
        );
        assert_eq!(r.get(DEFAULT_DEVICE).unwrap(), DeviceProfile::gaudi2());
        assert!(r.get("tpu-v9").is_err());
    }

    #[test]
    fn load_and_resolve_user_profiles() {
        let dir = std::env::temp_dir().join(format!("ampq_registry_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("accel.json");
        let mut custom = DeviceProfile::gaudi2();
        custom.name = "my-accel".into();
        custom.mme_macs_per_us = 123_456.0;
        std::fs::write(&path, custom.to_json().to_string()).unwrap();

        let mut r = Registry::builtin();
        let name = r.load(&path).unwrap();
        assert_eq!(name, "my-accel");
        assert_eq!(r.get("my-accel").unwrap(), custom);
        // resolve() accepts both names and paths.
        assert_eq!(r.resolve("my-accel").unwrap(), custom);
        assert_eq!(
            Registry::builtin().resolve(path.to_str().unwrap()).unwrap(),
            custom
        );
        assert!(Registry::builtin().resolve("no/such/file.json").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
