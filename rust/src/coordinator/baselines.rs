//! Baseline strategies (paper §3.1): Random and Prefix.  Both flip layers
//! from BF16 to FP8 while the *predicted* loss MSE stays within the same
//! tau^2 E[g^2] budget the IP uses, so the comparison isolates layer
//! SELECTION quality.

use crate::gaudisim::MpConfig;
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::util::Rng;

/// Layers eligible for quantization (IP-M runs restrict to linear layers).
pub type Eligible = Vec<bool>;

/// Random strategy: visit layers in a random order, flip each to `fmt` if
/// the running predicted loss MSE still fits the budget.
pub fn random_config(
    calib: &Calibration,
    tau: f64,
    eligible: &Eligible,
    fmt: Format,
    rng: &mut Rng,
) -> MpConfig {
    let nq = calib.s.len();
    let budget = calib.budget(tau);
    let mut cfg = MpConfig::all_bf16(nq);
    let mut d = calib.loss_mse(&cfg);
    let mut order: Vec<usize> = (0..nq).filter(|&l| eligible[l]).collect();
    rng.shuffle(&mut order);
    for l in order {
        let delta = calib.layer_mse(l, fmt) - calib.layer_mse(l, Format::Bf16);
        if d + delta <= budget {
            cfg.set(l, fmt);
            d += delta;
        }
    }
    cfg
}

/// Prefix strategy: quantize layers in model order (0, 1, 2, ...) until the
/// budget would be exceeded; skip ineligible layers.
pub fn prefix_config(
    calib: &Calibration,
    tau: f64,
    eligible: &Eligible,
    fmt: Format,
) -> MpConfig {
    let nq = calib.s.len();
    let budget = calib.budget(tau);
    let mut cfg = MpConfig::all_bf16(nq);
    let mut d = calib.loss_mse(&cfg);
    for l in 0..nq {
        if !eligible[l] {
            continue;
        }
        let delta = calib.layer_mse(l, fmt) - calib.layer_mse(l, Format::Bf16);
        if d + delta > budget {
            break; // strictly sequential: stop at the first overflow
        }
        cfg.set(l, fmt);
        d += delta;
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    fn calib() -> Calibration {
        Calibration {
            s: vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
            eg2: 1.0,
            g_mean: 1.0,
            n_samples: 4,
        }
    }

    fn all_eligible(n: usize) -> Eligible {
        vec![true; n]
    }

    #[test]
    fn both_respect_budget() {
        let c = calib();
        let mut rng = Rng::new(1);
        for tau in [0.02, 0.05, 0.1, 0.3] {
            let r = random_config(&c, tau, &all_eligible(6), Format::Fp8E4m3, &mut rng);
            let p = prefix_config(&c, tau, &all_eligible(6), Format::Fp8E4m3);
            assert!(c.loss_mse(&r) <= c.budget(tau) + 1e-15);
            assert!(c.loss_mse(&p) <= c.budget(tau) + 1e-15);
        }
    }

    #[test]
    fn prefix_is_a_prefix() {
        let c = calib();
        let p = prefix_config(&c, 0.08, &all_eligible(6), Format::Fp8E4m3);
        let quantized: Vec<bool> = p.0.iter().map(|f| *f == Format::Fp8E4m3).collect();
        // Once a BF16 appears, everything after must be BF16.
        let first_bf16 = quantized.iter().position(|&q| !q).unwrap_or(6);
        assert!(quantized[first_bf16..].iter().all(|&q| !q));
        assert!(p.n_quantized() > 0);
    }

    #[test]
    fn random_varies_with_seed_but_same_budget() {
        // Equal sensitivities + a budget that fits only ~half the layers:
        // which half gets quantized depends on the shuffle order.
        let c = calib();
        // Budget ~= all-BF16 MSE + 3 FP8 upgrades.
        let upgrade = c.layer_mse(0, Format::Fp8E4m3) - c.layer_mse(0, Format::Bf16);
        let tau = ((c.loss_mse(&MpConfig::all_bf16(6)) + 3.2 * upgrade) / c.eg2).sqrt();
        let cfgs: Vec<String> = (0..10)
            .map(|seed| {
                let mut rng = Rng::new(seed);
                random_config(&c, tau, &all_eligible(6), Format::Fp8E4m3, &mut rng)
                    .bits_label()
            })
            .collect();
        let mut distinct = cfgs.clone();
        distinct.sort();
        distinct.dedup();
        assert!(distinct.len() > 1, "random strategy should vary across seeds");
    }

    #[test]
    fn ineligible_layers_stay_bf16() {
        let c = calib();
        let mut eligible = all_eligible(6);
        eligible[2] = false;
        eligible[4] = false;
        let mut rng = Rng::new(3);
        let r = random_config(&c, 10.0, &eligible, Format::Fp8E4m3, &mut rng);
        assert_eq!(r.get(2), Format::Bf16);
        assert_eq!(r.get(4), Format::Bf16);
        assert_eq!(r.n_quantized(), 4);
        let p = prefix_config(&c, 10.0, &eligible, Format::Fp8E4m3);
        assert_eq!(p.get(2), Format::Bf16);
        assert_eq!(p.n_quantized(), 4);
    }

    #[test]
    fn generous_budget_quantizes_all_eligible() {
        let c = calib();
        let mut rng = Rng::new(0);
        let r = random_config(&c, 100.0, &all_eligible(6), Format::Fp8E4m3, &mut rng);
        assert_eq!(r.n_quantized(), 6);
    }
}
