//! Strategy families and selection (paper §3.1 comparison set), consumed
//! by the staged planning API (`plan::Planner`).

use crate::backend::DeviceProfile;
use crate::exec::ExecPool;
use crate::gaudisim::MpConfig;
use crate::graph::partition::Partition;
use crate::metrics::{self, GroupChoices, Objective};
use crate::model::{LayerKind, QLayer};
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::timing::TimeMeasurements;
use crate::util::Rng;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// One strategy family: the IP objective + the baseline eligibility mask.
#[derive(Clone, Debug, PartialEq)]
pub struct Family {
    pub objective: Objective,
    /// The format menu this family's configurations draw from (already
    /// restricted to the device's supported-format mask).
    pub formats: Vec<Format>,
    pub groups: Vec<GroupChoices>,
    pub eligible: Vec<bool>,
    /// Per-group `configuration -> column` maps, precomputed so per-query
    /// gain lookups are O(|group|) hashes instead of an O(|configs|) linear
    /// scan per group (frontier sweeps issue thousands of lookups).
    index: Vec<HashMap<Vec<Format>, usize>>,
}

impl Family {
    pub fn new(
        objective: Objective,
        formats: Vec<Format>,
        groups: Vec<GroupChoices>,
        eligible: Vec<bool>,
    ) -> Family {
        let index = groups
            .iter()
            .map(|g| {
                g.configs
                    .iter()
                    .enumerate()
                    .map(|(p, c)| (c.clone(), p))
                    .collect::<HashMap<Vec<Format>, usize>>()
            })
            .collect();
        Family { objective, formats, groups, eligible, index }
    }

    /// The format the Random/Prefix baselines quantize to: the narrowest
    /// menu entry no wider than BF16, preferring the most mantissa bits at
    /// equal width (FP8-E4M3 on the paper menu — and on any menu ordering,
    /// unlike a first-entry rule, which would pick FP32 from a menu listing
    /// it first).  A menu with nothing to narrow to (e.g. collapsed to
    /// [BF16] by the device mask) quantizes nothing.
    pub fn baseline_target(&self) -> Format {
        self.formats
            .iter()
            .copied()
            .filter(|f| *f != Format::Bf16 && f.bytes() <= Format::Bf16.bytes())
            .min_by_key(|f| (f.bytes(), std::cmp::Reverse(f.mbits())))
            .unwrap_or(Format::Bf16)
    }

    /// Column index of `key` in group j's configuration enumeration.
    pub fn config_column(&self, j: usize, key: &[Format]) -> Option<usize> {
        self.index[j].get(key).copied()
    }

    /// Objective-family gain of a full configuration: sum over groups of the
    /// gain at the group's matching configuration column.  Layers not
    /// covered by the family (e.g. BGEMM under IP-M) contribute nothing.
    pub fn gain_of(&self, cfg: &MpConfig) -> Result<f64> {
        let mut total = 0.0;
        for (j, g) in self.groups.iter().enumerate() {
            let key: Vec<Format> = g.qidxs.iter().map(|&q| cfg.get(q)).collect();
            let p = self
                .config_column(j, &key)
                .ok_or_else(|| anyhow!("configuration not in group {j}'s enumeration"))?;
            total += g.gains[p];
        }
        Ok(total)
    }
}

/// Build the IP groups + baseline eligibility for one objective family on
/// one device.  Baselines in the Memory family may only touch linear
/// layers (paper §3.1); ET/TT families may quantize everything.  `formats`
/// must already be restricted to the device's supported mask (the Engine
/// does this when staging).
pub fn build_family(
    objective: Objective,
    partition: &Partition,
    qlayers: &[QLayer],
    formats: &[Format],
    tm: &TimeMeasurements,
    device: &DeviceProfile,
) -> Family {
    let groups = match objective {
        Objective::EmpiricalTime => metrics::empirical_groups(tm),
        Objective::TheoreticalTime => {
            metrics::theoretical_groups(partition, qlayers, formats, device)
        }
        Objective::Memory => metrics::memory_groups(qlayers, formats),
    };
    let eligible = match objective {
        Objective::Memory => qlayers.iter().map(|q| q.kind == LayerKind::Linear).collect(),
        _ => vec![true; qlayers.len()],
    };
    Family::new(objective, formats.to_vec(), groups, eligible)
}

/// Strategy selector (paper §3.1 comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Ip,
    Random,
    Prefix,
}

impl Strategy {
    /// Every strategy, in the paper's presentation order.
    pub const ALL: [Strategy; 3] = [Strategy::Ip, Strategy::Random, Strategy::Prefix];

    pub fn name(self) -> &'static str {
        match self {
            Strategy::Ip => "IP",
            Strategy::Random => "Random",
            Strategy::Prefix => "Prefix",
        }
    }

    /// Short machine-readable key (CLI flags, Plan serialization).
    pub fn key(self) -> &'static str {
        match self {
            Strategy::Ip => "ip",
            Strategy::Random => "random",
            Strategy::Prefix => "prefix",
        }
    }

    pub fn from_key(s: &str) -> Option<Strategy> {
        Some(match s {
            "ip" => Strategy::Ip,
            "random" => Strategy::Random,
            "prefix" => Strategy::Prefix,
            _ => return None,
        })
    }
}

/// Produce the MP configuration a strategy chooses at threshold tau.  The
/// IP strategies route their MCKP solve through `pool` (bit-identical at
/// any thread count); the baselines are closed-form.
pub fn select_config(
    family: &Family,
    strategy: Strategy,
    calibration: &Calibration,
    tau: f64,
    seed: u64,
    pool: &ExecPool,
) -> Result<MpConfig> {
    Ok(match strategy {
        Strategy::Ip => super::ip::optimize(&family.groups, calibration, tau, pool)?.config,
        Strategy::Random => {
            let mut rng = Rng::new(0xA11CE ^ seed);
            super::baselines::random_config(
                calibration,
                tau,
                &family.eligible,
                family.baseline_target(),
                &mut rng,
            )
        }
        Strategy::Prefix => super::baselines::prefix_config(
            calibration,
            tau,
            &family.eligible,
            family.baseline_target(),
        ),
    })
}

/// Multi-constraint selection: like [`select_config`], but the IP strategy
/// additionally optimizes under an optional weight-byte cap (a second
/// knapsack dimension).  Baselines pick by loss budget alone — a resulting
/// cap violation surfaces through the plan's `feasible` flag.
pub fn select_config_constrained(
    family: &Family,
    strategy: Strategy,
    calibration: &Calibration,
    tau: f64,
    memory: Option<(&[QLayer], f64)>,
    seed: u64,
    pool: &ExecPool,
) -> Result<MpConfig> {
    match (strategy, memory) {
        (Strategy::Ip, Some(_)) => Ok(super::ip::optimize_with_caps(
            &family.groups,
            calibration,
            tau,
            memory,
            pool,
        )?
        .config),
        _ => select_config(family, strategy, calibration, tau, seed, pool),
    }
}

/// The paper's tau sweep (§3.2): {0, 0.1%, ..., 0.7%} plus all-FP8.
pub fn paper_tau_grid() -> Vec<f64> {
    (0..=7).map(|i| i as f64 * 0.001).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_grid_matches_paper() {
        let g = paper_tau_grid();
        assert_eq!(g.len(), 8);
        assert_eq!(g[0], 0.0);
        assert!((g[7] - 0.007).abs() < 1e-12);
    }

    #[test]
    fn strategy_names() {
        assert_eq!(Strategy::Ip.name(), "IP");
        assert_eq!(Strategy::Random.name(), "Random");
        assert_eq!(Strategy::Prefix.name(), "Prefix");
    }

    #[test]
    fn strategy_key_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::from_key(s.key()), Some(s));
        }
        assert_eq!(Strategy::from_key("nope"), None);
    }

    #[test]
    fn objective_key_roundtrip() {
        for o in Objective::ALL {
            assert_eq!(Objective::from_key(o.key()), Some(o));
        }
        assert_eq!(Objective::from_key("x"), None);
    }

    #[test]
    fn collapsed_menu_baselines_quantize_nothing() {
        // A device mask that leaves only BF16: baselines fall back to the
        // baseline format (i.e. a no-op config).
        let fam = Family::new(Objective::EmpiricalTime, vec![Format::Bf16], vec![], vec![]);
        assert_eq!(fam.baseline_target(), Format::Bf16);
    }

    #[test]
    fn baseline_target_is_menu_order_independent() {
        // FP32 listed first must not become the baseline "quantization"
        // target; the narrowest/highest-precision format wins.
        let full = Family::new(Objective::EmpiricalTime, Format::ALL.to_vec(), vec![], vec![]);
        assert_eq!(full.baseline_target(), Format::Fp8E4m3);
        // No sub-BF16 width available: fp16 (same width, finer mantissa).
        let wide = Family::new(
            Objective::EmpiricalTime,
            vec![Format::Fp32, Format::Fp16, Format::Bf16],
            vec![],
            vec![],
        );
        assert_eq!(wide.baseline_target(), Format::Fp16);
        // FP32 alone never becomes a target (upcasting is not quantizing).
        let up = Family::new(
            Objective::EmpiricalTime,
            vec![Format::Fp32, Format::Bf16],
            vec![],
            vec![],
        );
        assert_eq!(up.baseline_target(), Format::Bf16);
    }

    #[test]
    fn family_index_matches_linear_scan() {
        let groups = vec![GroupChoices {
            qidxs: vec![0, 1],
            configs: vec![
                vec![Format::Bf16, Format::Bf16],
                vec![Format::Bf16, Format::Fp8E4m3],
                vec![Format::Fp8E4m3, Format::Bf16],
                vec![Format::Fp8E4m3, Format::Fp8E4m3],
            ],
            gains: vec![0.0, 1.0, 2.0, 3.5],
        }];
        let fam = Family::new(
            Objective::EmpiricalTime,
            vec![Format::Bf16, Format::Fp8E4m3],
            groups,
            vec![true, true],
        );
        assert_eq!(fam.baseline_target(), Format::Fp8E4m3);
        for (p, cfg) in fam.groups[0].configs.clone().iter().enumerate() {
            assert_eq!(fam.config_column(0, cfg), Some(p));
        }
        assert_eq!(fam.config_column(0, &[Format::Fp32, Format::Bf16]), None);
        let gain = fam
            .gain_of(&MpConfig(vec![Format::Fp8E4m3, Format::Fp8E4m3]))
            .unwrap();
        assert_eq!(gain, 3.5);
        assert!(fam.gain_of(&MpConfig(vec![Format::Fp32, Format::Bf16])).is_err());
    }
}
