//! Strategy families and selection (paper §3.1 comparison set), shared by
//! the staged planning API (`plan::Planner`) and the deprecated `Pipeline`.

use crate::gaudisim::MpConfig;
use crate::graph::partition::Partition;
use crate::metrics::{self, GroupChoices, Objective};
use crate::model::{LayerKind, QLayer};
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::timing::TimeMeasurements;
use crate::util::Rng;
use anyhow::Result;

/// One strategy family: the IP objective + the baseline eligibility mask.
#[derive(Clone, Debug, PartialEq)]
pub struct Family {
    pub objective: Objective,
    pub groups: Vec<GroupChoices>,
    pub eligible: Vec<bool>,
}

/// Build the IP groups + baseline eligibility for one objective family.
/// Baselines in the Memory family may only touch linear layers (paper §3.1);
/// ET/TT families may quantize everything.
pub fn build_family(
    objective: Objective,
    partition: &Partition,
    qlayers: &[QLayer],
    formats: &[Format],
    tm: &TimeMeasurements,
) -> Family {
    let groups = match objective {
        Objective::EmpiricalTime => metrics::empirical_groups(tm),
        Objective::TheoreticalTime => metrics::theoretical_groups(partition, qlayers, formats),
        Objective::Memory => metrics::memory_groups(qlayers, formats),
    };
    let eligible = match objective {
        Objective::Memory => qlayers.iter().map(|q| q.kind == LayerKind::Linear).collect(),
        _ => vec![true; qlayers.len()],
    };
    Family { objective, groups, eligible }
}

/// Strategy selector (paper §3.1 comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Ip,
    Random,
    Prefix,
}

impl Strategy {
    /// Every strategy, in the paper's presentation order.
    pub const ALL: [Strategy; 3] = [Strategy::Ip, Strategy::Random, Strategy::Prefix];

    pub fn name(self) -> &'static str {
        match self {
            Strategy::Ip => "IP",
            Strategy::Random => "Random",
            Strategy::Prefix => "Prefix",
        }
    }

    /// Short machine-readable key (CLI flags, Plan serialization).
    pub fn key(self) -> &'static str {
        match self {
            Strategy::Ip => "ip",
            Strategy::Random => "random",
            Strategy::Prefix => "prefix",
        }
    }

    pub fn from_key(s: &str) -> Option<Strategy> {
        Some(match s {
            "ip" => Strategy::Ip,
            "random" => Strategy::Random,
            "prefix" => Strategy::Prefix,
            _ => return None,
        })
    }
}

/// Produce the MP configuration a strategy chooses at threshold tau.
pub fn select_config(
    family: &Family,
    strategy: Strategy,
    calibration: &Calibration,
    tau: f64,
    seed: u64,
) -> Result<MpConfig> {
    Ok(match strategy {
        Strategy::Ip => super::ip::optimize(&family.groups, calibration, tau)?.config,
        Strategy::Random => {
            let mut rng = Rng::new(0xA11CE ^ seed);
            super::baselines::random_config(
                calibration,
                tau,
                &family.eligible,
                Format::Fp8E4m3,
                &mut rng,
            )
        }
        Strategy::Prefix => super::baselines::prefix_config(
            calibration,
            tau,
            &family.eligible,
            Format::Fp8E4m3,
        ),
    })
}

/// The paper's tau sweep (§3.2): {0, 0.1%, ..., 0.7%} plus all-FP8.
pub fn paper_tau_grid() -> Vec<f64> {
    (0..=7).map(|i| i as f64 * 0.001).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_grid_matches_paper() {
        let g = paper_tau_grid();
        assert_eq!(g.len(), 8);
        assert_eq!(g[0], 0.0);
        assert!((g[7] - 0.007).abs() < 1e-12);
    }

    #[test]
    fn strategy_names() {
        assert_eq!(Strategy::Ip.name(), "IP");
        assert_eq!(Strategy::Random.name(), "Random");
        assert_eq!(Strategy::Prefix.name(), "Prefix");
    }

    #[test]
    fn strategy_key_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::from_key(s.key()), Some(s));
        }
        assert_eq!(Strategy::from_key("nope"), None);
    }

    #[test]
    fn objective_key_roundtrip() {
        for o in Objective::ALL {
            assert_eq!(Objective::from_key(o.key()), Some(o));
        }
        assert_eq!(Objective::from_key("x"), None);
    }
}
