//! End-to-end Algorithm 1: partition -> sensitivity calibration ->
//! per-group time-gain measurement -> IP optimization, plus the strategy
//! families and baselines the paper evaluates against.

use crate::gaudisim::{HwModel, MpConfig, Simulator};
use crate::graph::partition::{partition, Partition};
use crate::graph::Graph;
use crate::metrics::{self, GroupChoices, Objective};
use crate::model::{LayerKind, Manifest, ModelInfo};
use crate::numerics::Format;
use crate::runtime::{FwdMode, ModelRuntime, Runtime};
use crate::sensitivity::{calibrate, Calibration};
use crate::timing::{measure_groups, SimTtft, TimeMeasurements};
use crate::util::Rng;
use anyhow::Result;

/// Everything Algorithm 1 needs, loaded once per model.
pub struct Pipeline {
    pub info: ModelInfo,
    pub graph: Graph,
    pub partition: Partition,
    pub mr: ModelRuntime,
    pub calibration: Calibration,
    pub hw: HwModel,
    pub formats: Vec<Format>,
}

impl Pipeline {
    /// Steps 1-2 of Algorithm 1: analyze/partition + sensitivity calibration.
    pub fn new(
        manifest: &Manifest,
        model: &str,
        mode: FwdMode,
        hw: HwModel,
        formats: Vec<Format>,
    ) -> Result<Pipeline> {
        let rt = Runtime::new()?;
        let info = manifest.model(model)?.clone();
        let graph = info.load_graph(&manifest.root)?;
        let part = partition(&graph)?;
        let mr = ModelRuntime::load(&rt, &manifest.root, &info, mode)?;
        let calib_tokens = info.load_calib(&manifest.root)?;
        let calibration = calibrate(&mr, &calib_tokens)?;
        Ok(Pipeline { info, graph, partition: part, mr, calibration, hw, formats })
    }

    /// Step 3: per-group empirical time-gain measurement on the simulator
    /// (paper protocol: mean of `reps` TTFT iterations; 5 in the paper).
    pub fn measure_time(&self, seed: u64, reps: usize) -> Result<TimeMeasurements> {
        let sim = Simulator::new(&self.graph, self.hw.clone());
        let mut src = SimTtft { sim, rng: Rng::new(seed), reps };
        measure_groups(&mut src, &self.partition, &self.formats)
    }

    /// Simulated TTFT of a full config (for reporting accuracy-vs-TTFT).
    pub fn simulated_ttft(&self, cfg: &MpConfig, seed: u64, reps: usize) -> f64 {
        let sim = Simulator::new(&self.graph, self.hw.clone());
        let mut rng = Rng::new(seed);
        sim.measure_ttft(cfg, &mut rng, reps)
    }

    /// Build the IP groups for one objective family.
    pub fn family(&self, objective: Objective, tm: &TimeMeasurements) -> Family {
        let groups = match objective {
            Objective::EmpiricalTime => metrics::empirical_groups(tm),
            Objective::TheoreticalTime => {
                metrics::theoretical_groups(&self.partition, &self.info.qlayers, &self.formats)
            }
            Objective::Memory => metrics::memory_groups(&self.info.qlayers, &self.formats),
        };
        // Baselines in the Memory family may only touch linear layers
        // (paper §3.1); ET/TT families may quantize everything.
        let eligible = match objective {
            Objective::Memory => self
                .info
                .qlayers
                .iter()
                .map(|q| q.kind == LayerKind::Linear)
                .collect(),
            _ => vec![true; self.info.n_qlayers],
        };
        Family { objective, groups, eligible }
    }
}

/// One strategy family: the IP objective + the baseline eligibility mask.
pub struct Family {
    pub objective: Objective,
    pub groups: Vec<GroupChoices>,
    pub eligible: Vec<bool>,
}

/// Strategy selector (paper §3.1 comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    Ip,
    Random,
    Prefix,
}

impl Strategy {
    pub fn name(self) -> &'static str {
        match self {
            Strategy::Ip => "IP",
            Strategy::Random => "Random",
            Strategy::Prefix => "Prefix",
        }
    }
}

/// Produce the MP configuration a strategy chooses at threshold tau.
pub fn select_config(
    family: &Family,
    strategy: Strategy,
    calibration: &Calibration,
    tau: f64,
    seed: u64,
) -> Result<MpConfig> {
    Ok(match strategy {
        Strategy::Ip => super::ip::optimize(&family.groups, calibration, tau)?.config,
        Strategy::Random => {
            let mut rng = Rng::new(0xA11CE ^ seed);
            super::baselines::random_config(
                calibration,
                tau,
                &family.eligible,
                Format::Fp8E4m3,
                &mut rng,
            )
        }
        Strategy::Prefix => super::baselines::prefix_config(
            calibration,
            tau,
            &family.eligible,
            Format::Fp8E4m3,
        ),
    })
}

/// The paper's tau sweep (§3.2): {0, 0.1%, ..., 0.7%} plus all-FP8.
pub fn paper_tau_grid() -> Vec<f64> {
    (0..=7).map(|i| i as f64 * 0.001).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_grid_matches_paper() {
        let g = paper_tau_grid();
        assert_eq!(g.len(), 8);
        assert_eq!(g[0], 0.0);
        assert!((g[7] - 0.007).abs() < 1e-12);
    }

    #[test]
    fn strategy_names() {
        assert_eq!(Strategy::Ip.name(), "IP");
        assert_eq!(Strategy::Random.name(), "Random");
        assert_eq!(Strategy::Prefix.name(), "Prefix");
    }
}
