//! Deprecated single-shot `Pipeline` — the pre-0.2 monolithic surface.
//!
//! `Pipeline::new` eagerly fuses partition + calibration, so every tau or
//! objective query re-pays Algorithm 1's calibrate-once stages.  The staged
//! planning API ([`crate::plan::Engine`] producing cacheable
//! `Partitioned -> Calibrated -> Measured` artifacts and a
//! [`crate::plan::Planner`] answering `plan(objective, strategy, tau)` in
//! microseconds) replaces it; this shim is kept for one release so existing
//! callers migrate smoothly (see DESIGN.md "Staged planning API").

use crate::coordinator::strategy::{build_family, Family};
use crate::gaudisim::{HwModel, MpConfig, Simulator};
use crate::graph::partition::{partition, Partition};
use crate::graph::Graph;
use crate::metrics::Objective;
use crate::model::{Manifest, ModelInfo};
use crate::numerics::Format;
use crate::runtime::{FwdMode, ModelRuntime, Runtime};
use crate::sensitivity::{calibrate, Calibration};
use crate::timing::{measure_groups, SimTtft, TimeMeasurements};
use crate::util::Rng;
use anyhow::Result;

/// Everything Algorithm 1 needs, loaded once per model.
#[deprecated(
    since = "0.2.0",
    note = "use plan::Engine / plan::Planner (staged planning API, DESIGN.md): \
            artifacts are cacheable and a tau sweep no longer re-calibrates"
)]
pub struct Pipeline {
    pub info: ModelInfo,
    pub graph: Graph,
    pub partition: Partition,
    pub mr: ModelRuntime,
    pub calibration: Calibration,
    pub hw: HwModel,
    pub formats: Vec<Format>,
}

#[allow(deprecated)]
impl Pipeline {
    /// Steps 1-2 of Algorithm 1: analyze/partition + sensitivity calibration.
    pub fn new(
        manifest: &Manifest,
        model: &str,
        mode: FwdMode,
        hw: HwModel,
        formats: Vec<Format>,
    ) -> Result<Pipeline> {
        let rt = Runtime::new()?;
        let info = manifest.model(model)?.clone();
        let graph = info.load_graph(&manifest.root)?;
        let part = partition(&graph)?;
        let mr = ModelRuntime::load(&rt, &manifest.root, &info, mode)?;
        let calib_tokens = info.load_calib(&manifest.root)?;
        let calibration = calibrate(&mr, &calib_tokens)?;
        Ok(Pipeline { info, graph, partition: part, mr, calibration, hw, formats })
    }

    /// Step 3: per-group empirical time-gain measurement on the simulator
    /// (paper protocol: mean of `reps` TTFT iterations; 5 in the paper).
    pub fn measure_time(&self, seed: u64, reps: usize) -> Result<TimeMeasurements> {
        let sim = Simulator::new(&self.graph, self.hw.clone());
        let mut src = SimTtft { sim, rng: Rng::new(seed), reps };
        measure_groups(&mut src, &self.partition, &self.formats)
    }

    /// Simulated TTFT of a full config (for reporting accuracy-vs-TTFT).
    pub fn simulated_ttft(&self, cfg: &MpConfig, seed: u64, reps: usize) -> f64 {
        let sim = Simulator::new(&self.graph, self.hw.clone());
        let mut rng = Rng::new(seed);
        sim.measure_ttft(cfg, &mut rng, reps)
    }

    /// Build the IP groups for one objective family.
    pub fn family(&self, objective: Objective, tm: &TimeMeasurements) -> Family {
        build_family(objective, &self.partition, &self.info.qlayers, &self.formats, tm)
    }
}
