//! L3 coordinator — the paper's system contribution, end to end:
//! partitioning (Algorithm 2 via graph::partition), sensitivity calibration,
//! per-group time-gain measurement, IP optimization (eq. 5), and the
//! Random/Prefix baselines used in §3.

pub mod baselines;
pub mod ip;
pub mod pipeline;

pub use ip::{optimize, IpOutcome};
pub use pipeline::{paper_tau_grid, select_config, Family, Pipeline, Strategy};
