//! L3 coordinator — the paper's system contribution, end to end:
//! partitioning (Algorithm 2 via graph::partition), sensitivity calibration,
//! per-group time-gain measurement, IP optimization (eq. 5), and the
//! Random/Prefix baselines used in §3.
//!
//! Since 0.2 the entry point is the staged planning API in [`crate::plan`];
//! this module keeps the shared strategy machinery.  (The pre-0.2 one-shot
//! `Pipeline` shim, deprecated for one release, is gone as of 0.4.)

pub mod baselines;
pub mod ip;
pub mod strategy;

pub use ip::{optimize, optimize_with_caps, IpOutcome};
pub use strategy::{
    build_family, paper_tau_grid, select_config, select_config_constrained, Family, Strategy,
};
