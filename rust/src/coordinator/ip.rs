//! The paper's IP strategy (eq. 5): assemble the MCKP from per-group gain
//! vectors c_j and loss-MSE vectors d_j, solve, and materialize the chosen
//! MpConfig.

use crate::gaudisim::MpConfig;
use crate::metrics::{covered_layers, GroupChoices};
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::solver::{self, Mckp, Solution};
use anyhow::Result;

/// Result of one IP solve.
#[derive(Clone, Debug)]
pub struct IpOutcome {
    pub config: MpConfig,
    pub solution: Solution,
    /// Predicted loss MSE of the FULL config (covered + default-BF16 layers).
    pub predicted_mse: f64,
    pub budget: f64,
}

/// Solve eq. (5) at threshold `tau`.
///
/// Layers not covered by any group (e.g. BGEMM under IP-M) are fixed at
/// BF16; their (constant) loss-MSE contribution is charged against the
/// budget so the constraint covers the whole model.
pub fn optimize(
    groups: &[GroupChoices],
    calib: &Calibration,
    tau: f64,
) -> Result<IpOutcome> {
    let nq = calib.s.len();
    let covered = covered_layers(groups, nq);
    let uncovered_mse: f64 = (0..nq)
        .filter(|&l| !covered[l])
        .map(|l| calib.layer_mse(l, Format::Bf16))
        .sum();

    let budget_total = calib.budget(tau);
    let budget = (budget_total - uncovered_mse).max(0.0);

    let gains: Vec<Vec<f64>> = groups.iter().map(|g| g.gains.clone()).collect();
    let costs: Vec<Vec<f64>> = groups
        .iter()
        .map(|g| {
            g.configs
                .iter()
                .map(|cfg| calib.group_mse(&g.qidxs, cfg))
                .collect()
        })
        .collect();
    let problem = Mckp::new(gains, costs, budget)?;
    let solution = solver::solve(&problem);

    let mut config = MpConfig::all_bf16(nq);
    for (g, &p) in groups.iter().zip(&solution.choice) {
        for (&q, &f) in g.qidxs.iter().zip(&g.configs[p]) {
            config.set(q, f);
        }
    }
    let predicted_mse = calib.loss_mse(&config);
    Ok(IpOutcome { config, solution, predicted_mse, budget: budget_total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::PAPER_FORMATS;

    fn calib4() -> Calibration {
        Calibration { s: vec![1.0, 10.0, 0.1, 2.0], eg2: 1.0, g_mean: 1.0, n_samples: 4 }
    }

    fn singleton_groups(gains_fp8: &[f64]) -> Vec<GroupChoices> {
        gains_fp8
            .iter()
            .enumerate()
            .map(|(l, &g)| GroupChoices {
                qidxs: vec![l],
                configs: vec![vec![Format::Bf16], vec![Format::Fp8E4m3]],
                gains: vec![0.0, g],
            })
            .collect()
    }

    #[test]
    fn spends_budget_on_low_sensitivity_layers_first() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]); // equal gains
        // Budget enough for ~2 cheap layers but not the sensitive one.
        let d_cheap = calib.layer_mse(2, Format::Fp8E4m3) + calib.layer_mse(0, Format::Fp8E4m3);
        let tau = ((d_cheap * 1.5 + calib.loss_mse(&MpConfig::all_bf16(4))) / calib.eg2).sqrt();
        let out = optimize(&groups, &calib, tau).unwrap();
        assert!(out.solution.feasible);
        // Layer 2 (s=0.1) must be quantized before layer 1 (s=10).
        assert_eq!(out.config.get(2), Format::Fp8E4m3);
        assert_eq!(out.config.get(1), Format::Bf16);
        assert!(out.predicted_mse <= out.budget + 1e-12);
    }

    #[test]
    fn generous_budget_quantizes_everything() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]);
        let out = optimize(&groups, &calib, 10.0).unwrap();
        assert_eq!(out.config.n_quantized(), 4);
    }

    #[test]
    fn tau_zero_falls_back_to_baseline() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]);
        let out = optimize(&groups, &calib, 0.0).unwrap();
        // All-BF16 has nonzero d, so tau=0 is infeasible: fall back to
        // the min-cost (all-BF16) configuration.
        assert!(!out.solution.feasible);
        assert_eq!(out.config.n_quantized(), 0);
    }

    #[test]
    fn uncovered_layers_charge_budget() {
        let calib = calib4();
        // Only layers {0, 2} participate (like IP-M skipping BGEMMs).
        let groups: Vec<GroupChoices> = singleton_groups(&[1.0, 1.0, 1.0, 1.0])
            .into_iter()
            .enumerate()
            .filter(|(l, _)| *l == 0 || *l == 2)
            .map(|(_, g)| g)
            .collect();
        let out = optimize(&groups, &calib, 0.5).unwrap();
        assert_eq!(out.config.get(1), Format::Bf16);
        assert_eq!(out.config.get(3), Format::Bf16);
        // Full-model predicted MSE includes the uncovered layers.
        let full = calib.loss_mse(&out.config);
        assert!((full - out.predicted_mse).abs() < 1e-15);
    }

    #[test]
    fn monotone_in_tau() {
        let calib = calib4();
        let groups = singleton_groups(&[3.0, 1.0, 2.0, 1.5]);
        let mut last_gain = -1.0;
        for tau in [0.01, 0.05, 0.1, 0.5, 1.0] {
            let out = optimize(&groups, &calib, tau).unwrap();
            assert!(out.solution.gain >= last_gain - 1e-12);
            last_gain = out.solution.gain;
        }
    }
}
