//! The paper's IP strategy (eq. 5): assemble the MCKP from per-group gain
//! vectors c_j and loss-MSE vectors d_j, solve, and materialize the chosen
//! MpConfig.  Since 0.3 the solve optionally carries a second knapsack
//! dimension capping total stored weight bytes (multi-constraint requests).

use crate::exec::ExecPool;
use crate::gaudisim::MpConfig;
use crate::metrics::{covered_layers, group_weight_bytes, GroupChoices};
use crate::model::QLayer;
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::solver::{self, parametric, CostDim, Mckp, Solution};
use anyhow::{bail, Result};

/// Result of one IP solve.
#[derive(Clone, Debug)]
pub struct IpOutcome {
    pub config: MpConfig,
    pub solution: Solution,
    /// Predicted loss MSE of the FULL config (covered + default-BF16 layers).
    pub predicted_mse: f64,
    pub budget: f64,
    /// Full-model stored weight bytes of `config`; Some when a memory cap
    /// was part of the solve.
    pub weight_bytes: Option<f64>,
}

/// The budget bookkeeping every constraint dimension shares: layers no
/// group covers stay at BF16, so their constant per-layer cost is charged
/// up front and the groups solve against the clamped residual budget.
fn charge_uncovered<F>(covered: &[bool], budget: f64, layer_cost: F) -> f64
where
    F: Fn(usize) -> f64,
{
    let uncovered: f64 = (0..covered.len())
        .filter(|&l| !covered[l])
        .map(layer_cost)
        .sum();
    (budget - uncovered).max(0.0)
}

/// The per-group gain and loss-MSE cost tables of eq. 5 — ONE assembly
/// shared by the pointwise solves and the parametric frontier, so the two
/// paths can never desynchronize.
fn gain_mse_tables(
    groups: &[GroupChoices],
    calib: &Calibration,
) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let gains: Vec<Vec<f64>> = groups.iter().map(|g| g.gains.clone()).collect();
    let mse_costs: Vec<Vec<f64>> = groups
        .iter()
        .map(|g| {
            g.configs
                .iter()
                .map(|cfg| calib.group_mse(&g.qidxs, cfg))
                .collect()
        })
        .collect();
    (gains, mse_costs)
}

/// Solve eq. (5) at threshold `tau` (single loss-MSE constraint).
pub fn optimize(
    groups: &[GroupChoices],
    calib: &Calibration,
    tau: f64,
    pool: &ExecPool,
) -> Result<IpOutcome> {
    optimize_with_caps(groups, calib, tau, None, pool)
}

/// Solve eq. (5) at threshold `tau`, optionally under a second knapsack
/// dimension capping total stored weight bytes at `memory = (qlayers, cap)`.
///
/// Layers not covered by any group (e.g. BGEMM under IP-M) are fixed at
/// BF16; their (constant) loss-MSE — and, when capped, weight-byte —
/// contributions are charged against the budgets (see [`charge_uncovered`])
/// so the constraints cover the whole model.  The MCKP solve fans out over
/// `pool` on large instances with bit-identical output at any thread count.
pub fn optimize_with_caps(
    groups: &[GroupChoices],
    calib: &Calibration,
    tau: f64,
    memory: Option<(&[QLayer], f64)>,
    pool: &ExecPool,
) -> Result<IpOutcome> {
    let nq = calib.s.len();
    let covered = covered_layers(groups, nq);

    let budget_total = calib.budget(tau);
    let budget =
        charge_uncovered(&covered, budget_total, |l| calib.layer_mse(l, Format::Bf16));

    let (gains, mse_costs) = gain_mse_tables(groups, calib);

    let problem = match memory {
        None => Mckp::new(gains, mse_costs, budget)?,
        Some((qlayers, cap)) => {
            if qlayers.len() != nq {
                bail!("memory cap layer table covers {} layers, calibration {nq}", qlayers.len());
            }
            let bytes_table: Vec<Vec<f64>> = groups
                .iter()
                .map(|g| {
                    g.configs
                        .iter()
                        .map(|cfg| group_weight_bytes(qlayers, &g.qidxs, cfg))
                        .collect()
                })
                .collect();
            let bytes_budget = charge_uncovered(&covered, cap, |l| {
                qlayers[l].params as f64 * Format::Bf16.bytes() as f64
            });
            Mckp::multi(
                gains,
                vec![
                    CostDim::new("loss_mse", mse_costs),
                    CostDim::new("weight_bytes", bytes_table),
                ],
                vec![budget, bytes_budget],
            )?
        }
    };
    let solution = solver::solve_with(&problem, pool);

    let mut config = MpConfig::all_bf16(nq);
    for (g, &p) in groups.iter().zip(&solution.choice) {
        for (&q, &f) in g.qidxs.iter().zip(&g.configs[p]) {
            config.set(q, f);
        }
    }
    let predicted_mse = calib.loss_mse(&config);
    let weight_bytes = memory.map(|(qlayers, _)| crate::metrics::weight_bytes(qlayers, &config));
    Ok(IpOutcome { config, solution, predicted_mse, budget: budget_total, weight_bytes })
}

/// One knot of the full eq.-5 frontier, materialized as a model
/// configuration: the Pareto-optimal plan at its own loss-MSE level.
#[derive(Clone, Debug)]
pub struct FrontierSolve {
    pub config: MpConfig,
    /// Objective-family gain of `config` (the DP's sum — bit-equal to
    /// `Family::gain_of`, which folds the same per-group values in the
    /// same order).
    pub gain: f64,
    /// Predicted FULL-model loss MSE of `config` (covered groups plus the
    /// default-BF16 uncovered layers), recomputed via
    /// [`Calibration::loss_mse`] so it is bit-equal to a pointwise
    /// `Plan::predicted_mse` for the same configuration.
    pub predicted_mse: f64,
    /// False only when the parametric state cap thinned the sweep (never
    /// observed at paper scale — single-constraint sweeps are exact).
    pub exact: bool,
}

/// The full eq.-5 frontier: its knots, plus whether the knot SET is
/// provably complete.
pub struct FrontierSolves {
    pub knots: Vec<FrontierSolve>,
    /// False when the parametric state cap thinned the sweep: surviving
    /// knots may be sub-optimal and knots BETWEEN them may be missing —
    /// callers wanting the pointwise-agreement contract must fall back to
    /// per-tau solves (see `Planner::frontier`).
    pub complete: bool,
}

/// The ENTIRE gain-vs-loss-MSE Pareto curve of eq. 5 in one parametric DP
/// sweep (`solver::parametric`) — one pass instead of one branch & bound
/// solve per tau knot.  `tau_max` caps the curve: knots beyond its budget
/// cannot be reached by any tau the frontier serves.  Uncovered layers
/// are charged exactly like [`optimize_with_caps`].
///
/// No hardening happens here: when the state cap thinned the sweep
/// (`complete = false`, never observed at paper scale) the knot SET may
/// be missing entries that per-knot branch & bound cannot restore, so the
/// sole production caller (`Planner::frontier`) abandons the curve for
/// the bisection sweep — paying `solver::parametric::harden_with` first
/// would be pure wasted work on that path.  Callers that consume
/// incomplete curves directly can harden them via the solver API.
pub fn optimize_frontier(
    groups: &[GroupChoices],
    calib: &Calibration,
    tau_max: f64,
    pool: &ExecPool,
) -> Result<FrontierSolves> {
    let problem = frontier_instance(groups, calib, tau_max)?;
    let curve = parametric::frontier_with(&problem, pool);
    Ok(materialize_curve(groups, calib, &problem, &curve))
}

/// [`optimize_frontier`] through a persistent [`parametric::FrontierDp`]
/// arena: when only `tau_max` (the budget) or a single group's gain table
/// changed since the arena's last commit, the DP reuses every level solved
/// before the first divergent group and re-merges from there rightward.
/// The returned curve is bit-identical to a from-scratch
/// [`optimize_frontier`] on the same instance; the
/// [`parametric::FrontierDelta`] reports how much work the reuse skipped.
pub fn optimize_frontier_incremental(
    groups: &[GroupChoices],
    calib: &Calibration,
    tau_max: f64,
    pool: &ExecPool,
    dp: &mut parametric::FrontierDp,
) -> Result<(FrontierSolves, parametric::FrontierDelta)> {
    let problem = frontier_instance(groups, calib, tau_max)?;
    let (curve, delta) = dp.solve_delta(&problem, pool);
    Ok((materialize_curve(groups, calib, &problem, &curve), delta))
}

/// Assemble the eq.-5 single-constraint MCKP instance the frontier sweep
/// solves — shared by the in-process path above and the distributed
/// coordinator (`crate::dist`), which ships THIS instance to workers so
/// both sides expand identical DP states.
pub(crate) fn frontier_instance(
    groups: &[GroupChoices],
    calib: &Calibration,
    tau_max: f64,
) -> Result<Mckp> {
    let nq = calib.s.len();
    let covered = covered_layers(groups, nq);
    let budget =
        charge_uncovered(&covered, calib.budget(tau_max), |l| calib.layer_mse(l, Format::Bf16));
    let (gains, mse_costs) = gain_mse_tables(groups, calib);
    Mckp::new(gains, mse_costs, budget)
}

/// Materialize a parametric curve's knots as model configurations — the
/// single reduction from DP choices to [`FrontierSolves`], shared with the
/// distributed path so remotely-expanded curves yield byte-identical
/// knots.
pub(crate) fn materialize_curve(
    groups: &[GroupChoices],
    calib: &Calibration,
    problem: &Mckp,
    curve: &parametric::ParametricCurve,
) -> FrontierSolves {
    let nq = calib.s.len();
    let materialize = |choice: &[usize], gain: f64, exact: bool| {
        let mut config = MpConfig::all_bf16(nq);
        for (g, &p) in groups.iter().zip(choice) {
            for (&q, &f) in g.qidxs.iter().zip(&g.configs[p]) {
                config.set(q, f);
            }
        }
        let predicted_mse = calib.loss_mse(&config);
        FrontierSolve { config, gain, predicted_mse, exact }
    };
    if curve.points.is_empty() {
        // Even the min-cost assignment exceeds the tau_max budget (cannot
        // happen for planner-built tau_max, which has headroom for the
        // maximal configuration): the curve is the lone fallback plan every
        // pointwise solve would return.
        let fb = problem.fallback();
        return FrontierSolves {
            knots: vec![materialize(&fb.choice, fb.gain, true)],
            complete: true,
        };
    }
    FrontierSolves {
        knots: curve
            .points
            .iter()
            .map(|pt| materialize(&pt.choice, pt.gain, pt.exact))
            .collect(),
        complete: curve.exact,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecPool;
    use crate::model::LayerKind;
    use crate::numerics::PAPER_FORMATS;

    fn calib4() -> Calibration {
        Calibration { s: vec![1.0, 10.0, 0.1, 2.0], eg2: 1.0, g_mean: 1.0, n_samples: 4 }
    }

    fn singleton_groups(gains_fp8: &[f64]) -> Vec<GroupChoices> {
        gains_fp8
            .iter()
            .enumerate()
            .map(|(l, &g)| GroupChoices {
                qidxs: vec![l],
                configs: vec![vec![Format::Bf16], vec![Format::Fp8E4m3]],
                gains: vec![0.0, g],
            })
            .collect()
    }

    fn qlayers4() -> Vec<QLayer> {
        (0..4)
            .map(|l| QLayer {
                name: format!("l{l}"),
                kind: LayerKind::Linear,
                c: 8,
                k: 8,
                macs: 1000,
                params: 100,
            })
            .collect()
    }

    #[test]
    fn spends_budget_on_low_sensitivity_layers_first() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]); // equal gains
        // Budget enough for ~2 cheap layers but not the sensitive one.
        let d_cheap = calib.layer_mse(2, Format::Fp8E4m3) + calib.layer_mse(0, Format::Fp8E4m3);
        let tau = ((d_cheap * 1.5 + calib.loss_mse(&MpConfig::all_bf16(4))) / calib.eg2).sqrt();
        let out = optimize(&groups, &calib, tau, &ExecPool::sequential()).unwrap();
        assert!(out.solution.feasible);
        // Layer 2 (s=0.1) must be quantized before layer 1 (s=10).
        assert_eq!(out.config.get(2), Format::Fp8E4m3);
        assert_eq!(out.config.get(1), Format::Bf16);
        assert!(out.predicted_mse <= out.budget + 1e-12);
        assert!(out.weight_bytes.is_none());
    }

    #[test]
    fn generous_budget_quantizes_everything() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]);
        let out = optimize(&groups, &calib, 10.0, &ExecPool::sequential()).unwrap();
        assert_eq!(out.config.n_quantized(), 4);
    }

    #[test]
    fn tau_zero_falls_back_to_baseline() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]);
        let out = optimize(&groups, &calib, 0.0, &ExecPool::sequential()).unwrap();
        // All-BF16 has nonzero d, so tau=0 is infeasible: fall back to
        // the min-cost (all-BF16) configuration.
        assert!(!out.solution.feasible);
        assert_eq!(out.config.n_quantized(), 0);
    }

    #[test]
    fn uncovered_layers_charge_budget() {
        let calib = calib4();
        // Only layers {0, 2} participate (like IP-M skipping BGEMMs).
        let groups: Vec<GroupChoices> = singleton_groups(&[1.0, 1.0, 1.0, 1.0])
            .into_iter()
            .enumerate()
            .filter(|(l, _)| *l == 0 || *l == 2)
            .map(|(_, g)| g)
            .collect();
        let out = optimize(&groups, &calib, 0.5, &ExecPool::sequential()).unwrap();
        assert_eq!(out.config.get(1), Format::Bf16);
        assert_eq!(out.config.get(3), Format::Bf16);
        // Full-model predicted MSE includes the uncovered layers.
        let full = calib.loss_mse(&out.config);
        assert!((full - out.predicted_mse).abs() < 1e-15);
    }

    #[test]
    fn monotone_in_tau() {
        let calib = calib4();
        let groups = singleton_groups(&[3.0, 1.0, 2.0, 1.5]);
        let mut last_gain = -1.0;
        for tau in [0.01, 0.05, 0.1, 0.5, 1.0] {
            let out = optimize(&groups, &calib, tau, &ExecPool::sequential()).unwrap();
            assert!(out.solution.gain >= last_gain - 1e-12);
            last_gain = out.solution.gain;
        }
    }

    #[test]
    fn memory_cap_forces_unprofitable_quantization() {
        let calib = calib4();
        // Quantizing layers 0/1 LOSES time gain; 2/3 win.  4 layers x 100
        // params: all-BF16 = 800 bytes, all-FP8 = 400.  Unconstrained the IP
        // quantizes only 2 and 3 (600 bytes); a 500-byte cap forces one of
        // the unprofitable layers to FP8 as well.
        let groups = singleton_groups(&[-1.0, -1.0, 2.0, 2.0]);
        let qlayers = qlayers4();
        let pool = ExecPool::sequential();
        let free =
            optimize_with_caps(&groups, &calib, 10.0, Some((&qlayers, 1e9)), &pool).unwrap();
        assert_eq!(free.config.n_quantized(), 2);
        assert_eq!(free.weight_bytes.unwrap(), 600.0);
        let capped =
            optimize_with_caps(&groups, &calib, 10.0, Some((&qlayers, 500.0)), &pool).unwrap();
        assert!(capped.solution.feasible);
        let bytes = capped.weight_bytes.unwrap();
        assert!(bytes <= 500.0 + 1e-9, "bytes {bytes}");
        assert_eq!(capped.config.n_quantized(), 3);
        assert!((capped.solution.gain - 3.0).abs() < 1e-12);
        assert!(capped.predicted_mse <= capped.budget + 1e-12);
    }

    #[test]
    fn memory_cap_plus_tight_loss_budget_matches_brute_force() {
        let calib = calib4();
        let groups = singleton_groups(&[3.0, 9.0, 1.0, 2.0]);
        let qlayers = qlayers4();
        // Loss budget fits roughly the two cheapest-sensitivity upgrades.
        let d_cheap = calib.layer_mse(2, Format::Fp8E4m3) + calib.layer_mse(0, Format::Fp8E4m3);
        let tau = ((d_cheap * 1.2 + calib.loss_mse(&MpConfig::all_bf16(4))) / calib.eg2).sqrt();
        let out = optimize_with_caps(
            &groups,
            &calib,
            tau,
            Some((&qlayers, 700.0)),
            &ExecPool::sequential(),
        )
        .unwrap();
        // Cross-check against the brute-force oracle on the same instance.
        let mse_costs: Vec<Vec<f64>> = groups
            .iter()
            .map(|g| g.configs.iter().map(|c| calib.group_mse(&g.qidxs, c)).collect())
            .collect();
        let bytes: Vec<Vec<f64>> = groups
            .iter()
            .map(|g| {
                g.configs
                    .iter()
                    .map(|c| group_weight_bytes(&qlayers, &g.qidxs, c))
                    .collect()
            })
            .collect();
        let p = Mckp::multi(
            groups.iter().map(|g| g.gains.clone()).collect(),
            vec![CostDim::new("loss_mse", mse_costs), CostDim::new("weight_bytes", bytes)],
            vec![calib.budget(tau), 700.0],
        )
        .unwrap();
        let oracle = p.brute_force();
        assert_eq!(out.solution.feasible, oracle.feasible);
        assert!((out.solution.gain - oracle.gain).abs() < 1e-9);
        assert!(out.weight_bytes.unwrap() <= 700.0 + 1e-9);
    }

    #[test]
    fn frontier_solves_match_pointwise_optimize() {
        let calib = calib4();
        let groups = singleton_groups(&[3.0, 1.0, 2.0, 1.5]);
        let pool = ExecPool::sequential();
        let solves = optimize_frontier(&groups, &calib, 10.0, &pool).unwrap();
        assert!(solves.complete);
        let knots = solves.knots;
        assert!(knots.len() >= 2, "expected several knots, got {}", knots.len());
        for w in knots.windows(2) {
            assert!(w[1].predicted_mse > w[0].predicted_mse);
            assert!(w[1].gain > w[0].gain);
        }
        for k in &knots {
            assert!(k.exact);
            // A pointwise solve at the knot's own NRMSE level must agree.
            let tau = (k.predicted_mse / calib.eg2).sqrt();
            let out = optimize(&groups, &calib, tau, &pool).unwrap();
            assert!(
                (out.solution.gain - k.gain).abs() < 1e-9,
                "knot gain {} vs pointwise {}",
                k.gain,
                out.solution.gain
            );
        }
    }

    #[test]
    fn incremental_frontier_matches_from_scratch_bitwise() {
        let calib = calib4();
        let groups = singleton_groups(&[3.0, 1.0, 2.0, 1.5]);
        let pool = ExecPool::sequential();
        let mut dp = parametric::FrontierDp::default();
        for (trial, tau_max) in [10.0, 10.0, 2.5, 10.0].into_iter().enumerate() {
            let scratch = optimize_frontier(&groups, &calib, tau_max, &pool).unwrap();
            let (inc, delta) =
                optimize_frontier_incremental(&groups, &calib, tau_max, &pool, &mut dp).unwrap();
            assert_eq!(inc.complete, scratch.complete);
            assert_eq!(inc.knots.len(), scratch.knots.len());
            for (a, b) in inc.knots.iter().zip(&scratch.knots) {
                assert_eq!(a.gain.to_bits(), b.gain.to_bits());
                assert_eq!(a.predicted_mse.to_bits(), b.predicted_mse.to_bits());
                assert_eq!(a.config, b.config);
                assert_eq!(a.exact, b.exact);
            }
            if trial == 0 {
                assert!(delta.full_solve, "cold arena must solve from scratch");
            } else {
                // Only tau_max (the budget) varies: every committed level is
                // reusable, so no group re-merges.
                assert!(!delta.full_solve);
                assert_eq!(delta.solved_groups, 0);
                assert_eq!(delta.reused_levels, groups.len());
            }
        }
    }

    #[test]
    fn frontier_charges_uncovered_layers() {
        let calib = calib4();
        // Only layers {0, 2} participate; 1 and 3 stay BF16 and their MSE
        // must appear in every knot's predicted (full-model) MSE.
        let groups: Vec<GroupChoices> = singleton_groups(&[1.0, 1.0, 1.0, 1.0])
            .into_iter()
            .enumerate()
            .filter(|(l, _)| *l == 0 || *l == 2)
            .map(|(_, g)| g)
            .collect();
        let knots = optimize_frontier(&groups, &calib, 10.0, &ExecPool::sequential())
            .unwrap()
            .knots;
        let uncovered = calib.layer_mse(1, Format::Bf16) + calib.layer_mse(3, Format::Bf16);
        for k in &knots {
            assert_eq!(k.config.get(1), Format::Bf16);
            assert_eq!(k.config.get(3), Format::Bf16);
            assert!(k.predicted_mse >= uncovered - 1e-15);
            assert_eq!(k.predicted_mse, calib.loss_mse(&k.config));
        }
    }

    #[test]
    fn impossible_memory_cap_falls_back_infeasible() {
        let calib = calib4();
        let groups = singleton_groups(&[1.0, 1.0, 1.0, 1.0]);
        let qlayers = qlayers4();
        // Even all-FP8 needs 400 bytes.
        let out = optimize_with_caps(
            &groups,
            &calib,
            10.0,
            Some((&qlayers, 100.0)),
            &ExecPool::sequential(),
        )
        .unwrap();
        assert!(!out.solution.feasible);
    }
}
