//! Terminal line/scatter plots — every figure gets a results/*.txt render
//! alongside its CSV so the reproduction is inspectable without matplotlib.

/// A named series of (x, y) points.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

const MARKS: &[char] = &['o', 'x', '+', '*', '#', '@'];

/// Render multiple series on one grid with axes and a legend.
pub fn plot(title: &str, xlabel: &str, ylabel: &str, series: &[Series]) -> String {
    let width = 72usize;
    let height = 22usize;
    let pts: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if pts.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut xmin, mut xmax) = (f64::MAX, f64::MIN);
    let (mut ymin, mut ymax) = (f64::MAX, f64::MIN);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-300 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-300 {
        ymax = ymin + 1.0;
    }
    // Pad the y-range slightly so extremes are visible.
    let ypad = (ymax - ymin) * 0.05;
    ymin -= ypad;
    ymax += ypad;

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = mark;
        }
    }

    let mut out = String::new();
    out.push_str(&format!("{title}\n"));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  [{}] {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out.push_str(&format!("  y: {ylabel}  [{:.4e} .. {:.4e}]\n", ymin, ymax));
    for row in &grid {
        out.push_str("  |");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("   x: {xlabel}  [{:.4e} .. {:.4e}]\n", xmin, xmax));
    out
}

/// Quantization-pattern heat strip (paper Fig. 2): rows = configurations,
/// cols = layers; '#' = FP8, '.' = BF16.
pub fn pattern_grid(title: &str, rows: &[(String, String)]) -> String {
    let mut out = format!("{title}\n");
    let label_w = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(4);
    for (label, bits) in rows {
        let strip: String = bits.chars().map(|c| if c == '1' { '#' } else { '.' }).collect();
        out.push_str(&format!("  {label:>label_w$} |{strip}|\n"));
    }
    out.push_str(&format!(
        "  {:>label_w$}  ('#' = FP8, '.' = BF16; columns = layer index)\n",
        ""
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_renders_points() {
        let s = vec![Series {
            name: "line".into(),
            points: (0..10).map(|i| (i as f64, i as f64 * 2.0)).collect(),
        }];
        let out = plot("test", "x", "y", &s);
        assert!(out.contains("test"));
        assert!(out.contains("[o] line"));
        assert!(out.matches('o').count() >= 8);
    }

    #[test]
    fn plot_handles_empty() {
        assert!(plot("t", "x", "y", &[]).contains("no data"));
    }

    #[test]
    fn plot_handles_degenerate_range() {
        let s = vec![Series { name: "p".into(), points: vec![(1.0, 1.0), (1.0, 1.0)] }];
        let out = plot("t", "x", "y", &s);
        assert!(out.contains('o'));
    }

    #[test]
    fn pattern_grid_renders() {
        let out = pattern_grid("fig2", &[("tau=0.1".into(), "0110".into())]);
        assert!(out.contains("|.##.|"));
    }
}
