//! Reporting: CSV emitters, ASCII plots, and aligned tables — every paper
//! figure/table is regenerated as a CSV plus a terminal rendering under
//! results/ (see DESIGN.md §5 for the experiment index).

pub mod ascii;

use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Write a CSV file with a header row.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Format an aligned text table (paper Table 1 style).
pub fn format_table(header: &[String], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(header, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Save a string artifact under results/.
pub fn save_text(path: &Path, content: &str) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

/// f64 cell formatting helpers.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 || x.abs() < 1.0e-3 {
        format!("{x:.4e}")
    } else {
        format!("{x:.4}")
    }
}

pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:+.3} ± {std:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_written() {
        let p = std::env::temp_dir().join(format!("ampq_csv_{}.csv", std::process::id()));
        write_csv(&p, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn table_alignment() {
        let t = format_table(
            &["name".into(), "value".into()],
            &[vec!["x".into(), "1.5".into()], vec!["longer".into(), "2".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[3].contains("longer"));
    }

    #[test]
    fn num_format() {
        assert_eq!(f(0.0), "0");
        assert!(f(1234.5).contains('e'));
        assert_eq!(f(1.5), "1.5000");
        assert!(pm(0.1234, 0.05).starts_with("+0.123"));
    }
}
