//! The determinism & soundness rule catalog (D1–D5).
//!
//! Every rule is a token-level check over a [`SourceFile`]'s masked text.
//! Rules are deliberately narrow: each encodes ONE project invariant the
//! dynamic test suite can only sample, stated in DESIGN.md §4i.  False
//! positives are handled by the audited `// lint: allow(…)` directives or
//! the baseline file, never by weakening the rule.

use super::scanner::{find_from, SourceFile};

/// One rule violation (pre-suppression, pre-baseline).
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub message: String,
    pub hint: &'static str,
}

/// Catalog entry, surfaced in `--json` reports and the docs.
pub struct RuleInfo {
    pub id: &'static str,
    pub title: &'static str,
    pub detail: &'static str,
}

pub const CATALOG: &[RuleInfo] = &[
    RuleInfo {
        id: "D1",
        title: "no partial_cmp().unwrap() float ordering",
        detail: "floats must be ordered with f64::total_cmp: partial_cmp \
                 panics on NaN and its unwrap hides a non-total order from \
                 every sort it feeds",
    },
    RuleInfo {
        id: "D2",
        title: "no hash-order iteration into serialized/reduced output",
        detail: "HashMap/HashSet iteration order is randomized per process; \
                 in files that build Json or wire frames it must pass \
                 through a key sort (or a `// lint: sorted` audit) before \
                 feeding any output",
    },
    RuleInfo {
        id: "D3",
        title: "wall clocks only in obs/, timing/, and the daemon",
        detail: "Instant::now/SystemTime outside the sanctioned wall-clock \
                 sources makes outputs time-dependent; planning and solver \
                 code must stay replayable",
    },
    RuleInfo {
        id: "D4",
        title: "no unwrap/expect/panic on user-reachable request paths",
        detail: "serve/, dist/proto, and plan/request parse attacker-shaped \
                 bytes; they must return errors, not panic (lock-poison \
                 witnesses on Mutex/Condvar are exempt: a poisoned lock is \
                 itself a prior panic)",
    },
    RuleInfo {
        id: "D5",
        title: "encoder/decoder field-name symmetry",
        detail: "every *to_json encoder must have a *from_json decoder \
                 reading exactly the field names it writes; a one-sided \
                 field is a silent wire-schema drift",
    },
];

pub fn run_all(sf: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    out.extend(d1_partial_cmp_unwrap(sf));
    out.extend(d2_hash_iteration(sf));
    out.extend(d3_wall_clock(sf));
    out.extend(d4_request_path_panics(sf));
    out.extend(d5_codec_symmetry(sf));
    out
}

fn finding(
    sf: &SourceFile,
    rule: &'static str,
    at: usize,
    message: String,
    hint: &'static str,
) -> Finding {
    let line = sf.line_of(at);
    Finding {
        rule,
        file: sf.logical.clone(),
        line,
        excerpt: sf.line_text(line).to_string(),
        message,
        hint,
    }
}

/// Is the logical path inside the crate's library/binary source (as opposed
/// to integration tests, benches, or fixtures)?  Scope filter for the rules
/// that only bind production code.
fn is_src(sf: &SourceFile) -> bool {
    let p = &sf.logical;
    (p.contains("src/") || p.starts_with("src"))
        && !p.contains("tests/")
        && !p.contains("benches/")
}

fn path_has_dir(sf: &SourceFile, dir: &str) -> bool {
    sf.logical.split('/').any(|c| c == dir)
}

// ---- D1 ------------------------------------------------------------------

fn d1_partial_cmp_unwrap(sf: &SourceFile) -> Vec<Finding> {
    let m = &sf.masked;
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(at) = find_token(m, b"partial_cmp", from) {
        from = at + 1;
        let mut j = at + "partial_cmp".len();
        j = skip_ws(m, j);
        let Some(close) = skip_group(m, j, b'(', b')') else { continue };
        let j = skip_ws(m, close);
        if m[j..].starts_with(b".unwrap") || m[j..].starts_with(b".expect") {
            out.push(finding(
                sf,
                "D1",
                at,
                "float ordering via partial_cmp().unwrap()/.expect(): a \
                 non-total order that panics on NaN"
                    .to_string(),
                "order floats with f64::total_cmp: `a.total_cmp(&b)` in the comparator",
            ));
        }
    }
    out
}

// ---- D2 ------------------------------------------------------------------

/// Bytes of forward context inspected for an intervening sort after a hash
/// iteration before it is flagged.
const D2_SORT_WINDOW: usize = 280;

fn d2_hash_iteration(sf: &SourceFile) -> Vec<Finding> {
    if !is_src(sf) {
        return Vec::new();
    }
    let m = &sf.masked;
    // Gate: only files that build serialized output care about iteration
    // order at the lint level (reductions elsewhere are covered by the
    // exec layer's key-sorted merges).
    let serializes = find_token(m, b"Json", 0).is_some()
        || find_token(m, b"write_frame", 0).is_some();
    if !serializes {
        return Vec::new();
    }
    let names = hash_typed_names(m);
    if names.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    for name in &names {
        let pat = name.as_bytes();
        let mut from = 0usize;
        while let Some(at) = find_token(m, pat, from) {
            from = at + 1;
            let after = at + pat.len();
            let iterates = [".iter()", ".values()", ".keys()", ".into_iter()", ".drain("]
                .iter()
                .any(|s| m[after..].starts_with(s.as_bytes()));
            let in_for = preceded_by_in(m, at);
            if !(iterates || in_for) {
                continue;
            }
            // An explicit sort (or a BTree re-keying, or an order-free
            // count) within the statement window makes the order harmless.
            let window = &m[after..(after + D2_SORT_WINDOW).min(m.len())];
            let harmless = [".sort", "BTreeMap", "BTreeSet", ".count()", ".len()"]
                .iter()
                .any(|s| find_from(window, s.as_bytes(), 0).is_some());
            if harmless {
                continue;
            }
            out.push(finding(
                sf,
                "D2",
                at,
                format!(
                    "iteration over hash-ordered '{name}' in a serializing \
                     file without an intervening key sort"
                ),
                "collect and `.sort()` the keys first (or switch to BTreeMap); \
                 if the order is provably irrelevant, audit it with `// lint: sorted`",
            ));
        }
    }
    out
}

/// Identifiers declared with a HashMap/HashSet type or constructor.
fn hash_typed_names(m: &[u8]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for ty in [&b"HashMap"[..], &b"HashSet"[..]] {
        let mut from = 0usize;
        while let Some(at) = find_token(m, ty, from) {
            from = at + 1;
            // Walk back over `&`, `&mut`, `&'a`, and whitespace so
            // `name: &HashMap<…>` and `name: &'a mut HashMap<…>` both
            // resolve to `name`.
            let mut k = at;
            loop {
                let k0 = k;
                while k > 0 && (m[k - 1] as char).is_whitespace() {
                    k -= 1;
                }
                if k > 0 && m[k - 1] == b'&' {
                    k -= 1;
                    continue;
                }
                if k >= 3 && m[k - 3..k] == b"mut"[..] && !(k >= 4 && is_ident(m[k - 4])) {
                    k -= 3;
                    continue;
                }
                // Lifetime: `'a` — identifier run led by a tick.
                let mut t = k;
                while t > 0 && is_ident(m[t - 1]) {
                    t -= 1;
                }
                if t > 0 && t < k && m[t - 1] == b'\'' {
                    k = t - 1;
                    continue;
                }
                if k == k0 {
                    break;
                }
            }
            // `name: HashMap<…>` (let binding, field, or param) …
            if k > 0 && m[k - 1] == b':' {
                if let Some(name) = ident_before(m, k - 1) {
                    push_unique(&mut names, name);
                    continue;
                }
            }
            // … or `let name = HashMap::new()` style.
            if k > 0 && m[k - 1] == b'=' {
                if let Some(name) = ident_before(m, k - 1) {
                    push_unique(&mut names, name);
                }
            }
        }
    }
    names
}

fn push_unique(names: &mut Vec<String>, name: String) {
    if name != "mut" && !name.is_empty() && !names.contains(&name) {
        names.push(name);
    }
}

fn preceded_by_in(m: &[u8], at: usize) -> bool {
    let mut k = at;
    while k > 0 && (m[k - 1] == b'&' || m[k - 1] == b' ') {
        k -= 1;
    }
    // `for x in name` / `for x in &name` / `for x in &mut name`
    if k >= 3 && m[k - 3..k] == b"mut"[..] && !(k >= 4 && is_ident(m[k - 4])) {
        return preceded_by_in(m, k - 3);
    }
    k >= 2 && m[k - 2..k] == b"in"[..] && !(k >= 3 && is_ident(m[k - 3]))
}

// ---- D3 ------------------------------------------------------------------

const D3_ALLOWED_DIRS: &[&str] = &["obs", "timing", "serve"];

fn d3_wall_clock(sf: &SourceFile) -> Vec<Finding> {
    if !is_src(sf) || D3_ALLOWED_DIRS.iter().any(|d| path_has_dir(sf, d)) {
        return Vec::new();
    }
    let m = &sf.masked;
    let mut out = Vec::new();
    for pat in [&b"Instant::now"[..], &b"SystemTime"[..]] {
        let mut from = 0usize;
        while let Some(at) = find_token(m, pat, from) {
            from = at + 1;
            if sf.in_test_span(at) {
                continue;
            }
            out.push(finding(
                sf,
                "D3",
                at,
                format!(
                    "wall-clock source '{}' outside obs/, timing/, serve/",
                    String::from_utf8_lossy(pat)
                ),
                "route timing through timing::/obs:: sources; if this use is a \
                 sanctioned wall-clock (CLI stopwatch, supervision deadline), \
                 audit it with `// lint: allow(D3) reason` or allow-file",
            ));
        }
    }
    out
}

// ---- D4 ------------------------------------------------------------------

const D4_SCOPES: &[&str] = &["serve/", "dist/proto", "plan/request"];

fn d4_request_path_panics(sf: &SourceFile) -> Vec<Finding> {
    if !is_src(sf) || !D4_SCOPES.iter().any(|s| sf.logical.contains(s)) {
        return Vec::new();
    }
    let m = &sf.masked;
    let mut out = Vec::new();
    for pat in [&b".unwrap"[..], &b".expect"[..]] {
        let mut from = 0usize;
        while let Some(at) = find_token_suffix(m, pat, from) {
            from = at + 1;
            if sf.in_test_span(at) || !m[at + pat.len()..].starts_with(b"(") {
                continue;
            }
            if poison_witness(m, at) {
                continue;
            }
            out.push(finding(
                sf,
                "D4",
                at,
                format!(
                    "'{}()' on a user-reachable request path",
                    String::from_utf8_lossy(&pat[1..])
                ),
                "return a Result (bail!/anyhow!) so malformed input answers an \
                 error, not a worker panic",
            ));
        }
    }
    for pat in [&b"panic!"[..], &b"todo!"[..], &b"unimplemented!"[..]] {
        let mut from = 0usize;
        while let Some(at) = find_token(m, &pat[..pat.len() - 1], from) {
            from = at + 1;
            if m[at + pat.len() - 1..].first() != Some(&b'!') || sf.in_test_span(at) {
                continue;
            }
            out.push(finding(
                sf,
                "D4",
                at,
                format!("'{}' on a user-reachable request path", String::from_utf8_lossy(pat)),
                "return a Result (bail!/anyhow!) so malformed input answers an \
                 error, not a worker panic",
            ));
        }
    }
    out
}

/// `x.lock().expect(…)` / `cv.wait(g).expect(…)` / RwLock read/write: the
/// expect only fires if another thread already panicked while holding the
/// lock — it is a poison *witness*, not a new panic path.
fn poison_witness(m: &[u8], dot_at: usize) -> bool {
    if dot_at == 0 || m[dot_at - 1] != b')' {
        return false;
    }
    // Walk back over the balanced call group to its `(`.
    let mut depth = 0isize;
    let mut k = dot_at - 1;
    loop {
        match m[k] {
            b')' => depth += 1,
            b'(' => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        if k == 0 {
            return false;
        }
        k -= 1;
    }
    match ident_before(m, k) {
        Some(name) => matches!(name.as_str(), "lock" | "wait" | "read" | "write"),
        None => false,
    }
}

// ---- D5 ------------------------------------------------------------------

struct CodecFn {
    name: String,
    /// Name minus the `to_json`/`from_json` suffix (pairs share it).
    prefix: String,
    encoder: bool,
    sig_line: usize,
    body: (usize, usize),
}

fn d5_codec_symmetry(sf: &SourceFile) -> Vec<Finding> {
    let fns = codec_fns(sf);
    let mut out = Vec::new();
    let mut used: Vec<bool> = vec![false; fns.len()];
    for (i, enc) in fns.iter().enumerate() {
        if !enc.encoder {
            continue;
        }
        // Pair with the first unused same-prefix decoder (file order); the
        // repo convention keeps each pair adjacent within one impl block.
        let dec = fns.iter().enumerate().find(|(j, f)| {
            !f.encoder && f.prefix == enc.prefix && !used[*j]
        });
        let Some((j, dec)) = dec else {
            out.push(Finding {
                rule: "D5",
                file: sf.logical.clone(),
                line: enc.sig_line,
                excerpt: sf.line_text(enc.sig_line).to_string(),
                message: format!("encoder '{}' has no matching *from_json decoder", enc.name),
                hint: "add the inverse decoder (or rename the function if it is \
                       not a wire codec)",
            });
            continue;
        };
        used[j] = true;
        let enc_keys = encoder_keys(sf, enc.body);
        let dec_mentions = decoder_mentions(sf, dec.body);
        let dec_keys = decoder_reads(sf, dec.body);
        if enc_keys.is_empty() {
            // Dynamic keys (format!-built or pass-through): nothing to check.
            continue;
        }
        for k in &enc_keys {
            if !dec_mentions.contains(k) {
                out.push(Finding {
                    rule: "D5",
                    file: sf.logical.clone(),
                    line: enc.sig_line,
                    excerpt: sf.line_text(enc.sig_line).to_string(),
                    message: format!(
                        "field '{k}' written by '{}' is never read by '{}'",
                        enc.name, dec.name
                    ),
                    hint: "read the field in the decoder (or stop writing it); \
                           symmetric field sets are what keep wire schemas honest",
                });
            }
        }
        for k in &dec_keys {
            if !enc_keys.contains(k) {
                out.push(Finding {
                    rule: "D5",
                    file: sf.logical.clone(),
                    line: dec.sig_line,
                    excerpt: sf.line_text(dec.sig_line).to_string(),
                    message: format!(
                        "field '{k}' read by '{}' is never written by '{}'",
                        dec.name, enc.name
                    ),
                    hint: "write the field in the encoder (or stop reading it); \
                           symmetric field sets are what keep wire schemas honest",
                });
            }
        }
    }
    out
}

fn codec_fns(sf: &SourceFile) -> Vec<CodecFn> {
    let m = &sf.masked;
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(at) = find_token(m, b"fn", from) {
        from = at + 1;
        let mut j = skip_ws(m, at + 2);
        let start = j;
        while j < m.len() && is_ident(m[j]) {
            j += 1;
        }
        let name = String::from_utf8_lossy(&m[start..j]).to_string();
        let (encoder, prefix) = if let Some(p) = name.strip_suffix("to_json") {
            (true, p.to_string())
        } else if let Some(p) = name.strip_suffix("from_json") {
            (false, p.to_string())
        } else {
            continue;
        };
        let Some(open) = find_from(m, b"{", j) else { continue };
        let mut depth = 0isize;
        let mut k = open;
        while k < m.len() {
            match m[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        out.push(CodecFn {
            name,
            prefix,
            encoder,
            sig_line: sf.line_of(at),
            body: (open, k.min(m.len())),
        });
        from = open;
    }
    out
}

/// Field names an encoder writes: string literals shaped like
/// `("key".into(), …)` / `("key".to_string(), …)` inside the body.
fn encoder_keys(sf: &SourceFile, body: (usize, usize)) -> Vec<String> {
    let m = &sf.masked;
    let mut keys = Vec::new();
    for lit in &sf.strings {
        if lit.start < body.0 || lit.end > body.1 {
            continue;
        }
        let before = prev_non_ws(m, lit.start.saturating_sub(1));
        if before != Some(b'(') {
            continue;
        }
        let after = skip_ws(m, lit.end + 1);
        let past = if m[after..].starts_with(b".into()") {
            Some(after + ".into()".len())
        } else if m[after..].starts_with(b".to_string()") {
            Some(after + ".to_string()".len())
        } else {
            None
        };
        // The comma disambiguates a key position `("k".into(), v)` from a
        // string *value* like `Json::Str("frontier".into())`.
        if let Some(past) = past {
            if m.get(skip_ws(m, past)) == Some(&b',') && !keys.contains(&lit.value) {
                keys.push(lit.value.clone());
            }
        }
    }
    keys
}

/// Everything a decoder body could plausibly be reading, used for the
/// "written but never read" direction.  Deliberately generous — ANY string
/// literal in the body counts (keys reach `get()`/`opt()` through helper
/// closures like `read_edges("edges")`, so restricting to direct `get("k")`
/// calls would produce false asymmetry).  A body that calls `check_header`
/// implicitly reads the `schema`/`kind` envelope fields it validates.
fn decoder_mentions(sf: &SourceFile, body: (usize, usize)) -> Vec<String> {
    let mut keys = Vec::new();
    for lit in &sf.strings {
        if lit.start < body.0 || lit.end > body.1 {
            continue;
        }
        if !keys.contains(&lit.value) {
            keys.push(lit.value.clone());
        }
    }
    if find_token(&sf.masked[body.0..body.1], b"check_header", 0).is_some() {
        for k in ["schema", "kind"] {
            if !keys.iter().any(|s| s == k) {
                keys.push(k.to_string());
            }
        }
    }
    keys
}

/// Field names a decoder *definitely* reads — literals directly inside
/// `get("key")` / `opt("key")` — used for the strict "read but never
/// written" direction (a looser set would flag error-message text).
fn decoder_reads(sf: &SourceFile, body: (usize, usize)) -> Vec<String> {
    let m = &sf.masked;
    let mut keys = Vec::new();
    for lit in &sf.strings {
        if lit.start < body.0 || lit.end > body.1 || lit.start < 2 {
            continue;
        }
        if m[lit.start - 2] != b'(' {
            continue;
        }
        match ident_before(m, lit.start - 2) {
            Some(name) if name == "get" || name == "opt" => {
                if !keys.contains(&lit.value) {
                    keys.push(lit.value.clone());
                }
            }
            _ => {}
        }
    }
    keys
}

// ---- token helpers -------------------------------------------------------

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn skip_ws(m: &[u8], mut i: usize) -> usize {
    while i < m.len() && (m[i] as char).is_whitespace() {
        i += 1;
    }
    i
}

/// From `i` pointing at `open`, return the index just past the balanced
/// closing delimiter.
fn skip_group(m: &[u8], i: usize, open: u8, close: u8) -> Option<usize> {
    if m.get(i) != Some(&open) {
        return None;
    }
    let mut depth = 0isize;
    let mut k = i;
    while k < m.len() {
        if m[k] == open {
            depth += 1;
        } else if m[k] == close {
            depth -= 1;
            if depth == 0 {
                return Some(k + 1);
            }
        }
        k += 1;
    }
    None
}

/// Find `needle` at a word boundary on both sides.
fn find_token(m: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    let mut at = from;
    loop {
        let hit = find_from(m, needle, at)?;
        let left_ok = hit == 0 || !is_ident(m[hit - 1]);
        let end = hit + needle.len();
        let right_ok = end >= m.len() || !is_ident(m[end]);
        if left_ok && right_ok {
            return Some(hit);
        }
        at = hit + 1;
    }
}

/// Find `needle` (starting with `.`) where the trailing side is a word
/// boundary — catches `.unwrap(` but not `.unwrap_or(`.
fn find_token_suffix(m: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    let mut at = from;
    loop {
        let hit = find_from(m, needle, at)?;
        let end = hit + needle.len();
        if end >= m.len() || !is_ident(m[end]) {
            return Some(hit);
        }
        at = hit + 1;
    }
}

/// The identifier ending immediately before `i` (skipping whitespace).
fn ident_before(m: &[u8], i: usize) -> Option<String> {
    let mut k = i;
    while k > 0 && (m[k - 1] as char).is_whitespace() {
        k -= 1;
    }
    let end = k;
    while k > 0 && is_ident(m[k - 1]) {
        k -= 1;
    }
    if k == end {
        None
    } else {
        Some(String::from_utf8_lossy(&m[k..end]).to_string())
    }
}

fn prev_non_ws(m: &[u8], mut i: usize) -> Option<u8> {
    loop {
        // `i` indexes the quote byte; step left past it and any whitespace.
        if i == 0 {
            return None;
        }
        i -= 1;
        if !(m[i] as char).is_whitespace() && m[i] != b'"' {
            return Some(m[i]);
        }
        if m[i] == b'"' {
            continue;
        }
    }
}
