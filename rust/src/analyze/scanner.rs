//! Source scanner for the lint pass: loads one `.rs` file, masks comments
//! and literals out of a parallel "scan text", and collects the lint
//! directives the rules consume.
//!
//! The crate convention is std-only, so this is a hand-rolled lexer, not a
//! rustc plugin: it understands exactly as much Rust surface syntax as the
//! rules need — line/block comments (nested), string/raw-string/byte-string
//! literals, char literals vs. lifetimes — and nothing more.  Rules match
//! tokens against [`SourceFile::masked`], where every comment byte and every
//! string-literal *content* byte has been replaced by a space (quotes and
//! newlines survive, so byte offsets and line numbers are shared with the
//! raw text).  String literal values are kept separately in
//! [`SourceFile::strings`] for the rules that need them (D5's field-name
//! symmetry check).
//!
//! Directives are ordinary line comments:
//!
//! ```text
//! // lint: allow(D3) reason…       suppress rule D3 on this line (or the
//! //                               next line, when the comment stands alone)
//! // lint: allow-file(D3) reason…  suppress rule D3 for the whole file
//! // lint: sorted                  shorthand for allow(D2): the iteration
//! //                               order is made irrelevant by hand
//! // lint: path src/serve/x.rs     override the *logical* path used for
//! //                               rule scoping (fixture files use this)
//! ```
//!
//! Every suppression is recorded and surfaced in the report, so `// lint:`
//! comments are an audited escape hatch, not a silent one.

use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// One parsed `// lint: allow(...)` / `// lint: sorted` directive.
#[derive(Clone, Debug)]
pub struct Suppression {
    /// Rule id the suppression names (`"D1"`..`"D5"`).
    pub rule: String,
    /// 1-based line the directive sits on.
    pub line: usize,
    /// Whole-file suppression (`allow-file`)?
    pub file_wide: bool,
    /// Free-text justification (everything after the directive head).
    pub reason: String,
    /// True once a finding was actually silenced by this directive.
    pub used: bool,
}

/// A string literal in the raw text: byte span (content only, quotes
/// excluded) plus the unescaped-ish value (escapes left verbatim — the
/// rules only compare plain field names, which never contain escapes).
#[derive(Clone, Debug)]
pub struct StrLit {
    pub start: usize,
    pub end: usize,
    pub value: String,
}

/// One scanned source file, ready for the rules.
pub struct SourceFile {
    /// Path as discovered on disk (for diagnostics and reports).
    pub path: PathBuf,
    /// Path used for rule *scoping*: the on-disk path unless a
    /// `// lint: path …` directive overrides it (fixtures do).
    pub logical: String,
    /// Raw file contents.
    pub text: String,
    /// Same length as `text`: comments and literal contents are spaces.
    pub masked: Vec<u8>,
    /// Byte offset of each line start (index 0 = line 1).
    pub line_starts: Vec<usize>,
    /// Parsed suppression directives, in file order.
    pub suppressions: Vec<Suppression>,
    /// String literals outside comments, in file order.
    pub strings: Vec<StrLit>,
    /// Byte ranges of `#[cfg(test)] mod …` bodies (test-only code).
    pub test_spans: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn load(path: &Path) -> Result<SourceFile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {}: {e}", path.display()))?;
        Ok(Self::from_text(path, text))
    }

    pub fn from_text(path: &Path, text: String) -> SourceFile {
        let mut sf = SourceFile {
            path: path.to_path_buf(),
            logical: normalize(path),
            text,
            masked: Vec::new(),
            line_starts: vec![0],
            suppressions: Vec::new(),
            strings: Vec::new(),
            test_spans: Vec::new(),
        };
        sf.scan();
        sf.find_test_spans();
        sf
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// The trimmed raw source of a 1-based line.
    pub fn line_text(&self, line: usize) -> &str {
        let start = self.line_starts[line - 1];
        let end = self
            .line_starts
            .get(line)
            .map(|&e| e.saturating_sub(1))
            .unwrap_or(self.text.len());
        self.text[start..end.max(start)].trim()
    }

    /// Does a byte offset fall inside a `#[cfg(test)]` module body?
    pub fn in_test_span(&self, offset: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| offset >= s && offset < e)
    }

    /// Look for a suppression covering `rule` at `line`; marks it used.
    /// A directive covers its own line, the next line when the directive
    /// line holds nothing but the comment, and every line when file-wide.
    pub fn suppression_for(&mut self, rule: &str, line: usize) -> Option<usize> {
        for (i, s) in self.suppressions.iter_mut().enumerate() {
            if s.rule != rule {
                continue;
            }
            if s.file_wide || s.line == line || s.line + 1 == line {
                s.used = true;
                return Some(i);
            }
        }
        None
    }

    // ---- lexing ----------------------------------------------------------

    fn scan(&mut self) {
        let mut lx = Lexer {
            b: self.text.as_bytes(),
            masked: self.text.as_bytes().to_vec(),
            line_starts: vec![0],
            strings: Vec::new(),
            directives: Vec::new(),
        };
        lx.run();
        let Lexer { masked, line_starts, strings, directives, b: _ } = lx;
        self.masked = masked;
        self.line_starts = line_starts;
        self.strings = strings;
        for (comment, offset, only_comment) in directives {
            self.parse_directive(&comment, offset, only_comment);
        }
    }

    fn parse_directive(&mut self, comment: &str, offset: usize, only_comment: bool) {
        let body = comment.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("lint:") else { return };
        let rest = rest.trim();
        let line = self.line_of(offset);
        // A directive that stands alone on its line covers the next line;
        // model that by recording it on the directive line and letting
        // `suppression_for` also match `line + 1`.  A *trailing* directive
        // covers only its own line, so shift stand-alone ones are fine as-is.
        let _ = only_comment;
        if rest == "sorted" || rest.starts_with("sorted ") {
            self.suppressions.push(Suppression {
                rule: "D2".into(),
                line,
                file_wide: false,
                reason: rest.strip_prefix("sorted").unwrap_or("").trim().to_string(),
                used: false,
            });
        } else if let Some(tail) = rest.strip_prefix("allow-file(") {
            if let Some((rule, reason)) = split_allow(tail) {
                self.suppressions.push(Suppression {
                    rule,
                    line,
                    file_wide: true,
                    reason,
                    used: false,
                });
            }
        } else if let Some(tail) = rest.strip_prefix("allow(") {
            if let Some((rule, reason)) = split_allow(tail) {
                self.suppressions.push(Suppression {
                    rule,
                    line,
                    file_wide: false,
                    reason,
                    used: false,
                });
            }
        } else if let Some(tail) = rest.strip_prefix("path ") {
            self.logical = tail.trim().to_string();
        }
    }

    /// Locate `#[cfg(test)] mod … { … }` bodies via brace matching on the
    /// masked text (strings and comments no longer confuse the count).
    fn find_test_spans(&mut self) {
        let m = &self.masked;
        let mut from = 0usize;
        while let Some(at) = find_from(m, b"#[cfg(test)]", from) {
            from = at + 1;
            let mut j = at + b"#[cfg(test)]".len();
            // Skip whitespace and further attributes to the item keyword.
            loop {
                while j < m.len() && (m[j] as char).is_whitespace() {
                    j += 1;
                }
                if j < m.len() && m[j] == b'#' {
                    while j < m.len() && m[j] != b']' {
                        j += 1;
                    }
                    j += 1;
                } else {
                    break;
                }
            }
            // Only `mod` bodies are skipped wholesale; a stray
            // `#[cfg(test)] fn` would be rare and still brace-matched below.
            let rest = &m[j.min(m.len())..];
            if !(rest.starts_with(b"mod ") || rest.starts_with(b"pub mod ")) {
                continue;
            }
            let Some(open) = find_from(m, b"{", j) else { continue };
            let mut depth = 0isize;
            let mut k = open;
            while k < m.len() {
                match m[k] {
                    b'{' => depth += 1,
                    b'}' => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            self.test_spans.push((open, k.min(m.len())));
            from = k.min(m.len());
        }
    }
}

/// Standalone lexer state: borrows the raw bytes and owns every output, so
/// mutating `masked`/`line_starts`/`strings` never conflicts with the text
/// borrow (which it would inside `&mut SourceFile` methods).
struct Lexer<'a> {
    b: &'a [u8],
    masked: Vec<u8>,
    line_starts: Vec<usize>,
    strings: Vec<StrLit>,
    /// (comment text, byte offset, directive stands alone on its line).
    directives: Vec<(String, usize, bool)>,
}

impl<'a> Lexer<'a> {
    fn run(&mut self) {
        let n = self.b.len();
        let mut i = 0usize;
        while i < n {
            let c = self.b[i];
            if c == b'\n' {
                self.line_starts.push(i + 1);
                i += 1;
            } else if c == b'/' && i + 1 < n && self.b[i + 1] == b'/' {
                let start = i;
                while i < n && self.b[i] != b'\n' {
                    i += 1;
                }
                let comment = String::from_utf8_lossy(&self.b[start..i]).into_owned();
                if comment.contains("lint:") {
                    let ls = *self.line_starts.last().unwrap();
                    let only_comment = self.b[ls..start].iter().all(|c| c.is_ascii_whitespace());
                    self.directives.push((comment, start, only_comment));
                }
                mask(&mut self.masked, start, i);
            } else if c == b'/' && i + 1 < n && self.b[i + 1] == b'*' {
                let start = i;
                let mut depth = 1usize;
                i += 2;
                while i < n && depth > 0 {
                    if self.b[i] == b'\n' {
                        self.line_starts.push(i + 1);
                        i += 1;
                    } else if self.b[i] == b'/' && i + 1 < n && self.b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if self.b[i] == b'*' && i + 1 < n && self.b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                mask(&mut self.masked, start, i);
            } else if c == b'"' {
                i = self.string_lit(i);
            } else if (c == b'r' || c == b'b') && !ident_tail(self.b, i) {
                // r"…", r#"…"#, b"…", br#"…"# — only when `r`/`b` starts a
                // fresh token (not the tail of an identifier).
                if let Some(next) = self.raw_or_byte_lit(i) {
                    i = next;
                } else {
                    i += 1;
                }
            } else if c == b'\'' {
                i = self.char_or_lifetime(i);
            } else {
                i += 1;
            }
        }
    }

    /// Plain `"…"` literal starting at `i`; returns the index after it.
    fn string_lit(&mut self, i: usize) -> usize {
        let n = self.b.len();
        let content = i + 1;
        let mut j = content;
        while j < n {
            match self.b[j] {
                b'\\' => j = (j + 2).min(n),
                b'"' => break,
                b'\n' => {
                    self.line_starts.push(j + 1);
                    j += 1;
                }
                _ => j += 1,
            }
        }
        self.strings.push(StrLit {
            start: content,
            end: j.min(n),
            value: String::from_utf8_lossy(&self.b[content..j.min(n)]).into_owned(),
        });
        mask(&mut self.masked, content, j.min(n));
        (j + 1).min(n)
    }

    /// `r`/`b`-prefixed literal starting at `i`, or `None` if `i` is not
    /// actually a literal prefix.  Returns the index after the literal.
    fn raw_or_byte_lit(&mut self, i: usize) -> Option<usize> {
        let n = self.b.len();
        let mut j = i;
        let mut raw = false;
        if self.b[j] == b'b' {
            j += 1;
            if j < n && self.b[j] == b'r' {
                raw = true;
                j += 1;
            }
        } else {
            raw = true;
            j += 1;
        }
        let mut hashes = 0usize;
        while raw && j < n && self.b[j] == b'#' {
            hashes += 1;
            j += 1;
        }
        if j >= n || self.b[j] != b'"' {
            return None;
        }
        let content = j + 1;
        if raw {
            // Ends at `"` followed by the same number of `#`s; no escapes.
            let mut k = content;
            'outer: while k < n {
                if self.b[k] == b'\n' {
                    self.line_starts.push(k + 1);
                    k += 1;
                    continue;
                }
                if self.b[k] == b'"' {
                    let mut h = 0usize;
                    while h < hashes && k + 1 + h < n && self.b[k + 1 + h] == b'#' {
                        h += 1;
                    }
                    if h == hashes {
                        break 'outer;
                    }
                }
                k += 1;
            }
            self.strings.push(StrLit {
                start: content,
                end: k.min(n),
                value: String::from_utf8_lossy(&self.b[content..k.min(n)]).into_owned(),
            });
            mask(&mut self.masked, content, k.min(n));
            Some((k + 1 + hashes).min(n))
        } else {
            // b"…" with escapes, same shape as a plain string.
            let mut k = content;
            while k < n {
                match self.b[k] {
                    b'\\' => k = (k + 2).min(n),
                    b'"' => break,
                    b'\n' => {
                        self.line_starts.push(k + 1);
                        k += 1;
                    }
                    _ => k += 1,
                }
            }
            mask(&mut self.masked, content, k.min(n));
            Some((k + 1).min(n))
        }
    }

    /// `'c'` / `'\n'` char literal vs. `'a` lifetime at `i`.
    fn char_or_lifetime(&mut self, i: usize) -> usize {
        let n = self.b.len();
        if i + 1 >= n {
            return i + 1;
        }
        if self.b[i + 1] == b'\\' {
            // Escaped char literal: mask to the closing quote.
            let mut j = i + 2;
            while j < n && self.b[j] != b'\'' {
                j += 1;
            }
            mask(&mut self.masked, i + 1, j.min(n));
            return (j + 1).min(n);
        }
        // One UTF-8 scalar then a closing quote → char literal; anything
        // else (`'a>` / `'a,` / `'static`) is a lifetime: skip the quote.
        let len = utf8_len(self.b[i + 1]);
        if i + 1 + len < n && self.b[i + 1 + len] == b'\'' {
            mask(&mut self.masked, i + 1, i + 1 + len);
            i + 2 + len
        } else {
            i + 1
        }
    }
}

fn split_allow(tail: &str) -> Option<(String, String)> {
    let close = tail.find(')')?;
    let rule = tail[..close].trim().to_string();
    if rule.is_empty() {
        return None;
    }
    Some((rule, tail[close + 1..].trim().to_string()))
}

fn mask(masked: &mut [u8], start: usize, end: usize) {
    for b in masked[start..end].iter_mut() {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

fn ident_tail(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

pub(crate) fn find_from(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if from >= hay.len() || needle.is_empty() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}

/// Forward-slash path with a leading `./` stripped, for stable reports
/// across platforms and invocation styles.
pub(crate) fn normalize(path: &Path) -> String {
    let s = path.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(text: &str) -> SourceFile {
        SourceFile::from_text(Path::new("src/x.rs"), text.to_string())
    }

    #[test]
    fn masks_comments_and_strings() {
        let s = sf("let a = \"partial_cmp\"; // partial_cmp\nlet b = 1;\n");
        let m = String::from_utf8(s.masked.clone()).unwrap();
        assert!(!m.contains("partial_cmp"), "masked: {m}");
        assert!(m.contains("let b = 1;"));
        assert_eq!(s.strings.len(), 1);
        assert_eq!(s.strings[0].value, "partial_cmp");
    }

    #[test]
    fn masks_raw_and_byte_strings() {
        let s = sf("let a = r#\"Instant::now \"quoted\" \"#; let b = b\"SystemTime\";\n");
        let m = String::from_utf8(s.masked.clone()).unwrap();
        assert!(!m.contains("Instant::now"));
        assert!(!m.contains("SystemTime"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let s = sf("fn f<'a>(x: &'a str) -> char { let c = '\"'; let d = '\\n'; c }\n");
        let m = String::from_utf8(s.masked.clone()).unwrap();
        // The quote char literal must not open a string.
        assert!(m.contains("let d ="));
        assert_eq!(s.strings.len(), 0);
    }

    #[test]
    fn nested_block_comments() {
        let s = sf("/* outer /* Instant::now */ still comment */ let x = 1;\n");
        let m = String::from_utf8(s.masked.clone()).unwrap();
        assert!(!m.contains("Instant::now"));
        assert!(m.contains("let x = 1;"));
    }

    #[test]
    fn directives_parse() {
        let s = sf("// lint: path src/serve/h.rs\nlet a = 1; // lint: allow(D3) cli timing\n// lint: sorted keys collected below\nfor x in m {}\n");
        assert_eq!(s.logical, "src/serve/h.rs");
        assert_eq!(s.suppressions.len(), 2);
        assert_eq!(s.suppressions[0].rule, "D3");
        assert_eq!(s.suppressions[0].reason, "cli timing");
        assert_eq!(s.suppressions[1].rule, "D2");
    }

    #[test]
    fn suppression_covers_own_and_next_line() {
        let mut s = sf("// lint: allow(D1) reviewed\nrows.sort();\nother();\n");
        assert!(s.suppression_for("D1", 2).is_some());
        assert!(s.suppression_for("D1", 3).is_none());
        assert!(s.suppression_for("D2", 2).is_none());
    }

    #[test]
    fn test_spans_found() {
        let text = "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let s = sf(text);
        assert_eq!(s.test_spans.len(), 1);
        let unwrap_at = text.find("unwrap").unwrap();
        assert!(s.in_test_span(unwrap_at));
        assert!(!s.in_test_span(0));
    }

    #[test]
    fn line_numbers_stable_through_multiline_strings() {
        let s = sf("let a = \"one\ntwo\nthree\";\nlet b = 2;\n");
        let off = s.text.find("let b").unwrap();
        assert_eq!(s.line_of(off), 4);
    }
}
