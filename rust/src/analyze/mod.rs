//! `ampq lint` — the determinism & soundness static-analysis pass.
//!
//! The crate's core guarantee (additive sensitivities + per-group gains
//! composing into ONE answer, bit-identical at any `--threads`/`--workers`)
//! is enforced dynamically by equality tests that sample a few instances.
//! This module encodes the underlying *rules* as a static pass that fails
//! CI on any new violation:
//!
//! * **D1** — no `partial_cmp(..).unwrap()/.expect()` float orders
//! * **D2** — no hash-order iteration feeding serialized/reduced output
//! * **D3** — wall clocks only in `obs/`, `timing/`, and the daemon
//! * **D4** — no `unwrap`/`expect`/`panic!` on user-reachable request paths
//! * **D5** — encoder/decoder field-name symmetry for hand-rolled JSON
//!
//! Zero dependencies, no rustc plugin: a line/token-level scanner
//! ([`scanner`]) feeds rule matchers ([`rules`]).  Suppressions are audited
//! `// lint: …` comments; legacy findings can be parked in a baseline file
//! (`rust/lint-baseline.json`) and burned down deliberately — a finding is
//! only fatal when it is neither suppressed nor baselined.

pub mod rules;
pub mod scanner;

pub use rules::{Finding, CATALOG};
pub use scanner::SourceFile;

use crate::util::Json;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// Directory names never descended into during the walk: build output,
/// vendored third-party code, seeded lint fixtures (they contain deliberate
/// violations), and non-Rust corpora.
const SKIP_DIRS: &[&str] = &[
    "target",
    "vendor",
    "lint_fixtures",
    "corpus",
    ".git",
    "artifacts",
    "results",
];

#[derive(Clone, Debug, Default)]
pub struct LintConfig {
    /// Files or directories to scan (dirs walk recursively for `.rs`).
    pub paths: Vec<PathBuf>,
    /// Baseline file; missing file = empty baseline.
    pub baseline: Option<PathBuf>,
}

/// A finding silenced by a `// lint:` directive, kept for the audit trail.
#[derive(Clone, Debug)]
pub struct Suppressed {
    pub finding: Finding,
    pub reason: String,
}

/// One baseline entry.  Line numbers are deliberately absent: entries match
/// on (rule, file, excerpt) so routine edits elsewhere in a file do not
/// churn the baseline.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineEntry {
    pub rule: String,
    pub file: String,
    pub excerpt: String,
}

pub struct Report {
    /// Violations that fail the run (not suppressed, not baselined).
    pub findings: Vec<Finding>,
    /// Violations matched by a baseline entry (legacy debt, non-fatal).
    pub baselined: Vec<Finding>,
    /// Violations silenced by an audited `// lint:` directive.
    pub suppressed: Vec<Suppressed>,
    /// Baseline entries that matched nothing — debt already paid off.
    pub stale_baseline: Vec<BaselineEntry>,
    pub files_scanned: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    // lint: allow(D5) write-only report for CI artifacts; no decoder by design
    pub fn to_json(&self) -> Json {
        let finding_json = |f: &Finding| {
            Json::Obj(vec![
                ("rule".into(), Json::Str(f.rule.to_string())),
                ("file".into(), Json::Str(f.file.clone())),
                ("line".into(), Json::Num(f.line as f64)),
                ("excerpt".into(), Json::Str(f.excerpt.clone())),
                ("message".into(), Json::Str(f.message.clone())),
                ("hint".into(), Json::Str(f.hint.to_string())),
            ])
        };
        let rules = CATALOG
            .iter()
            .map(|r| {
                Json::Obj(vec![
                    ("id".into(), Json::Str(r.id.to_string())),
                    ("title".into(), Json::Str(r.title.to_string())),
                    ("detail".into(), Json::Str(r.detail.to_string())),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("tool".into(), Json::Str("ampq lint".to_string())),
            ("schema_version".into(), Json::Num(1.0)),
            ("clean".into(), Json::Bool(self.clean())),
            ("files_scanned".into(), Json::Num(self.files_scanned as f64)),
            ("rules".into(), Json::Arr(rules)),
            ("findings".into(), Json::Arr(self.findings.iter().map(finding_json).collect())),
            (
                "suppressed".into(),
                Json::Arr(
                    self.suppressed
                        .iter()
                        .map(|s| {
                            let mut kv = match finding_json(&s.finding) {
                                Json::Obj(kv) => kv,
                                _ => unreachable!("finding_json returns an object"),
                            };
                            kv.push(("reason".into(), Json::Str(s.reason.clone())));
                            Json::Obj(kv)
                        })
                        .collect(),
                ),
            ),
            ("baselined".into(), Json::Arr(self.baselined.iter().map(finding_json).collect())),
            (
                "stale_baseline".into(),
                Json::Arr(
                    self.stale_baseline
                        .iter()
                        .map(|e| {
                            Json::Obj(vec![
                                ("rule".into(), Json::Str(e.rule.clone())),
                                ("file".into(), Json::Str(e.file.clone())),
                                ("excerpt".into(), Json::Str(e.excerpt.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run the pass.  Deterministic: files are visited in sorted path order and
/// findings are sorted by (file, line, rule).
pub fn run(cfg: &LintConfig) -> Result<Report> {
    let mut files = Vec::new();
    for p in &cfg.paths {
        collect(p, &mut files)?;
    }
    files.sort();
    files.dedup();

    let mut raw: Vec<Finding> = Vec::new();
    let mut suppressed: Vec<Suppressed> = Vec::new();
    for path in &files {
        let mut sf = SourceFile::load(path)?;
        for f in rules::run_all(&sf) {
            match sf.suppression_for(f.rule, f.line) {
                Some(i) => {
                    let s = &sf.suppressions[i];
                    let reason = if s.reason.is_empty() {
                        "(no reason given)".to_string()
                    } else {
                        s.reason.clone()
                    };
                    suppressed.push(Suppressed { finding: f, reason });
                }
                None => raw.push(f),
            }
        }
    }
    raw.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    suppressed.sort_by(|a, b| {
        (&a.finding.file, a.finding.line, a.finding.rule)
            .cmp(&(&b.finding.file, b.finding.line, b.finding.rule))
    });

    // Baseline pass: each entry absorbs at most one matching finding.
    let mut entries = match &cfg.baseline {
        Some(p) if p.exists() => load_baseline(p)?,
        _ => Vec::new(),
    };
    let mut consumed = vec![false; entries.len()];
    let mut findings = Vec::new();
    let mut baselined = Vec::new();
    for f in raw {
        let hit = entries.iter().enumerate().position(|(i, e)| {
            !consumed[i] && e.rule == f.rule && e.file == f.file && e.excerpt == f.excerpt
        });
        match hit {
            Some(i) => {
                consumed[i] = true;
                baselined.push(f);
            }
            None => findings.push(f),
        }
    }
    let stale_baseline = entries
        .drain(..)
        .zip(consumed)
        .filter(|(_, used)| !used)
        .map(|(e, _)| e)
        .collect();

    Ok(Report {
        findings,
        baselined,
        suppressed,
        stale_baseline,
        files_scanned: files.len(),
    })
}

fn collect(path: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if path.is_file() {
        out.push(path.to_path_buf());
        return Ok(());
    }
    if !path.is_dir() {
        return Err(anyhow!("lint path not found: {}", path.display()));
    }
    let mut children: Vec<PathBuf> = std::fs::read_dir(path)
        .map_err(|e| anyhow!("read dir {}: {e}", path.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    children.sort();
    for child in children {
        let name = child.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if child.is_dir() {
            if SKIP_DIRS.contains(&name) {
                continue;
            }
            collect(&child, out)?;
        } else if name.ends_with(".rs") {
            out.push(child);
        }
    }
    Ok(())
}

// ---- baseline file -------------------------------------------------------

pub fn load_baseline(path: &Path) -> Result<Vec<BaselineEntry>> {
    let j = Json::parse_file(path)?;
    j.get("entries")?
        .arr()?
        .iter()
        .map(|e| {
            Ok(BaselineEntry {
                rule: e.get("rule")?.str()?.to_string(),
                file: e.get("file")?.str()?.to_string(),
                excerpt: e.get("excerpt")?.str()?.to_string(),
            })
        })
        .collect()
}

/// Serialize a baseline covering `findings` (both fresh and already
/// baselined ones — `--write-baseline` passes the union).
pub fn baseline_json(findings: &[&Finding]) -> Json {
    Json::Obj(vec![
        ("schema_version".into(), Json::Num(1.0)),
        (
            "entries".into(),
            Json::Arr(
                findings
                    .iter()
                    .map(|f| {
                        Json::Obj(vec![
                            ("rule".into(), Json::Str(f.rule.to_string())),
                            ("file".into(), Json::Str(f.file.clone())),
                            ("excerpt".into(), Json::Str(f.excerpt.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, text: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("ampq-analyze-tests");
        std::fs::create_dir_all(&dir).expect("temp dir");
        let p = dir.join(name);
        std::fs::write(&p, text).expect("write fixture");
        p
    }

    #[test]
    fn baseline_absorbs_then_goes_stale() {
        let p = tmp(
            "base_d1.rs",
            "// lint: path src/x.rs\npub fn s(v: &mut [f64]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n",
        );
        let report = run(&LintConfig { paths: vec![p.clone()], baseline: None }).unwrap();
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].rule, "D1");

        let base = tmp(
            "base_d1.json",
            &baseline_json(&report.findings.iter().collect::<Vec<_>>()).to_string(),
        );
        let report =
            run(&LintConfig { paths: vec![p.clone()], baseline: Some(base.clone()) }).unwrap();
        assert!(report.clean());
        assert_eq!(report.baselined.len(), 1);
        assert!(report.stale_baseline.is_empty());

        // Fix the violation: the entry must surface as stale, not linger.
        std::fs::write(&p, "// lint: path src/x.rs\npub fn s(v: &mut [f64]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n").unwrap();
        let report = run(&LintConfig { paths: vec![p], baseline: Some(base) }).unwrap();
        assert!(report.clean());
        assert!(report.baselined.is_empty());
        assert_eq!(report.stale_baseline.len(), 1);
    }

    #[test]
    fn report_json_parses_back() {
        let p = tmp(
            "rep_d3.rs",
            "// lint: path src/plan/x.rs\npub fn t() -> std::time::Instant { std::time::Instant::now() }\n",
        );
        let report = run(&LintConfig { paths: vec![p], baseline: None }).unwrap();
        assert_eq!(report.findings.len(), 1);
        let j = Json::parse(&report.to_json().to_string()).unwrap();
        assert!(!j.get("clean").unwrap().bool().unwrap());
        assert_eq!(j.get("rules").unwrap().arr().unwrap().len(), CATALOG.len());
        let f = &j.get("findings").unwrap().arr().unwrap()[0];
        assert_eq!(f.get("rule").unwrap().str().unwrap(), "D3");
    }

    #[test]
    fn missing_path_is_an_error() {
        let cfg = LintConfig {
            paths: vec![PathBuf::from("/nonexistent/lint/root")],
            baseline: None,
        };
        assert!(run(&cfg).is_err());
    }
}
