//! Reference fake-quant in rust — mirrors python/compile/quant.py exactly.
//!
//! Used by tests (cross-validating the .tbin/HLO pipeline) and by the
//! simulator's noise diagnostics.  The runtime model itself quantizes inside
//! the compiled HLO; this is NOT on the request path.

use super::Format;

/// Round-to-nearest of `v` at `m` stored mantissa bits.
pub fn round_mantissa(v: f32, m: u32) -> f32 {
    if v == 0.0 || !v.is_finite() {
        return v;
    }
    // Clamp the exponent like the jnp implementation: near-denormal inputs
    // would otherwise overflow exp2(m - e) to inf and produce inf/inf = NaN.
    let e = v.abs().log2().floor().clamp(-96.0, 120.0);
    let f = (m as f32 - e).exp2();
    (v * f).round() / f
}

/// Per-tensor scale with perturbation (matches quant.tensor_scale).
pub fn tensor_scale(vs: &[f32], fmt: Format, pert: f32) -> f32 {
    let s = match fmt.fmax() {
        Some(fmax) => {
            let amax = vs.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            (if amax > 0.0 { amax } else { 1.0 }) / fmax
        }
        None => 1.0,
    };
    s * pert
}

/// Quantize-dequantize a tensor to `fmt` (paper's noise injection).
pub fn fake_quant(vs: &[f32], fmt: Format, pert: f32) -> Vec<f32> {
    let s = tensor_scale(vs, fmt, pert);
    let fmax = fmt.fmax().unwrap_or(f32::MAX);
    vs.iter()
        .map(|&v| {
            let vn = v / s;
            let q = round_mantissa(vn, fmt.mbits()).clamp(-fmax, fmax);
            q * s
        })
        .collect()
}

/// Empirical relative MSE of quantizing `vs` to `fmt` — should track
/// Format::alpha() for dense data (used in model-validation tests).
pub fn relative_mse(vs: &[f32], fmt: Format) -> f64 {
    let q = fake_quant(vs, fmt, 1.0);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&v, &qv) in vs.iter().zip(&q) {
        num += ((qv - v) as f64).powi(2);
        den += (v as f64).powi(2);
    }
    if den > 0.0 { num / den } else { 0.0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn identity_at_fp32() {
        let mut r = Rng::new(0);
        for _ in 0..1000 {
            let v = (r.normal() * 10.0) as f32;
            let q = round_mantissa(v, 23);
            assert!((q - v).abs() <= v.abs() * 1e-6);
        }
    }

    #[test]
    fn relative_error_bound() {
        let mut r = Rng::new(1);
        for m in [2u32, 3, 7, 10] {
            for _ in 0..2000 {
                let v = (r.normal() * 100.0) as f32;
                let q = round_mantissa(v, m);
                let bound = v.abs() * 2.0f32.powi(-(m as i32)) * 0.5 * 1.0001;
                assert!((q - v).abs() <= bound + 1e-30, "m={m} v={v} q={q}");
            }
        }
    }

    #[test]
    fn idempotent() {
        let mut r = Rng::new(2);
        for _ in 0..500 {
            let v = (r.normal() * 3.0) as f32;
            let q1 = round_mantissa(v, 3);
            let q2 = round_mantissa(q1, 3);
            assert_eq!(q1, q2);
        }
    }

    #[test]
    fn zero_preserved() {
        assert_eq!(round_mantissa(0.0, 3), 0.0);
        let q = fake_quant(&[0.0, 1.0, -1.0], Format::Fp8E4m3, 1.0);
        assert_eq!(q[0], 0.0);
    }

    #[test]
    fn saturation_respected() {
        let vs = [1.0f32, 100.0, -1000.0, 0.5];
        let q = fake_quant(&vs, Format::Fp8E4m3, 1.0);
        let s = tensor_scale(&vs, Format::Fp8E4m3, 1.0);
        for &x in &q {
            assert!(x.abs() <= 448.0 * s * 1.000_01);
        }
        // The max element survives within format resolution.
        assert!((q[2] + 1000.0).abs() / 1000.0 < 0.1);
    }

    #[test]
    fn mse_tracks_alpha() {
        let mut r = Rng::new(3);
        let vs: Vec<f32> = (0..100_000).map(|_| (r.normal()).exp() as f32).collect();
        for fmt in [Format::Fp8E4m3, Format::Bf16] {
            let measured = relative_mse(&vs, fmt);
            let predicted = fmt.alpha();
            let ratio = measured / predicted;
            assert!(ratio > 0.3 && ratio < 3.0, "{fmt:?}: ratio {ratio}");
        }
    }

    #[test]
    fn perturbation_shifts_grid() {
        let vs: Vec<f32> = (0..64).map(|i| (i as f32 + 0.37) * 0.1).collect();
        let a = fake_quant(&vs, Format::Fp8E4m3, 1.0);
        let b = fake_quant(&vs, Format::Fp8E4m3, 1.05);
        assert_ne!(a, b);
    }
}
