//! Numerical formats and the paper's quantization-noise model.
//!
//! Mirrors python/compile/quant.py.  The rust side needs these for:
//!   * alpha_f in the loss-MSE predictor (eq. 22),
//!   * per-format byte widths in metrics + gaudisim,
//!   * a reference fake-quant for tests (validating against the jnp oracle).
//!
//! Everything here is a property of the *format* itself.  Per-device
//! throughput (the old `Format::mme_rate`) lives in
//! `backend::DeviceProfile` — hardware data, not format data.

pub mod fakequant;

/// Number of supported formats (sizes `backend::RateTable`).
pub const N_FORMATS: usize = 5;

/// A floating-point format an accelerator may support (paper's f index).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    Fp32,
    Fp16,
    Bf16,
    Fp8E4m3,
    Fp8E5m2,
}

impl Format {
    /// Every format, in declaration order ([`Format::index`] order).
    pub const ALL: [Format; N_FORMATS] =
        [Format::Fp32, Format::Fp16, Format::Bf16, Format::Fp8E4m3, Format::Fp8E5m2];

    /// Dense index into [`Format::ALL`] (rate-table slots).
    pub fn index(self) -> usize {
        match self {
            Format::Fp32 => 0,
            Format::Fp16 => 1,
            Format::Bf16 => 2,
            Format::Fp8E4m3 => 3,
            Format::Fp8E5m2 => 4,
        }
    }

    /// Stored mantissa bits m_f (paper §2.2).
    pub fn mbits(self) -> u32 {
        match self {
            Format::Fp32 => 23,
            Format::Fp16 => 10,
            Format::Bf16 => 7,
            Format::Fp8E4m3 => 3,
            Format::Fp8E5m2 => 2,
        }
    }

    /// Bytes per stored element (paper's memory-gain delta_M source).
    pub fn bytes(self) -> usize {
        match self {
            Format::Fp32 => 4,
            Format::Fp16 | Format::Bf16 => 2,
            Format::Fp8E4m3 | Format::Fp8E5m2 => 1,
        }
    }

    /// Saturation bound (None = effectively unbounded for our data).
    pub fn fmax(self) -> Option<f32> {
        match self {
            Format::Fp8E4m3 => Some(448.0),
            Format::Fp8E5m2 => Some(57344.0),
            _ => None,
        }
    }

    /// alpha_f = 2^-2m / 12 — relative MSE of one element's rounding noise
    /// (paper eq. after (16)).
    pub fn alpha(self) -> f64 {
        2.0f64.powi(-2 * self.mbits() as i32) / 12.0
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::Fp32 => "fp32",
            Format::Fp16 => "fp16",
            Format::Bf16 => "bf16",
            Format::Fp8E4m3 => "fp8_e4m3",
            Format::Fp8E5m2 => "fp8_e5m2",
        }
    }

    pub fn from_name(s: &str) -> Option<Format> {
        Some(match s {
            "fp32" => Format::Fp32,
            "fp16" => Format::Fp16,
            "bf16" => Format::Bf16,
            "fp8_e4m3" | "fp8" => Format::Fp8E4m3,
            "fp8_e5m2" => Format::Fp8E5m2,
            _ => return None,
        })
    }
}

/// The format menu used throughout the paper's experiments: F = 2,
/// BF16 (baseline, index 0) and FP8-E4M3 (index 1).
pub const PAPER_FORMATS: [Format; 2] = [Format::Bf16, Format::Fp8E4m3];

/// Per-element byte reduction of storing in f instead of BF16, delta_M,f
/// (eq. 25).  Purely format data; the time-side delta_T,f (eq. 24) is
/// device data — see `backend::RateTable::delta_t`.
pub fn delta_m(f: Format) -> f64 {
    Format::Bf16.bytes() as f64 - f.bytes() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_ordering() {
        assert!(Format::Fp8E5m2.alpha() > Format::Fp8E4m3.alpha());
        assert!(Format::Fp8E4m3.alpha() > Format::Bf16.alpha());
        assert!(Format::Bf16.alpha() > Format::Fp32.alpha());
    }

    #[test]
    fn alpha_values() {
        assert!((Format::Fp8E4m3.alpha() - 2.0f64.powi(-6) / 12.0).abs() < 1e-18);
        assert!((Format::Bf16.alpha() - 2.0f64.powi(-14) / 12.0).abs() < 1e-18);
    }

    #[test]
    fn deltas() {
        assert_eq!(delta_m(Format::Bf16), 0.0);
        assert_eq!(delta_m(Format::Fp8E4m3), 1.0);
    }

    #[test]
    fn name_roundtrip() {
        for f in Format::ALL {
            assert_eq!(Format::from_name(f.name()), Some(f));
        }
        assert_eq!(Format::from_name("fp8"), Some(Format::Fp8E4m3));
        assert_eq!(Format::from_name("int4"), None);
    }

    #[test]
    fn index_is_dense_over_all() {
        for (i, f) in Format::ALL.iter().enumerate() {
            assert_eq!(f.index(), i);
        }
    }
}
