//! Figure 2: layer-wise quantization patterns across MP configurations
//! (rows = tau values, columns = layers) for IP-ET, Prefix, and Random.
//!
//! Pure planner queries — no PJRT, no re-measurement.

use super::FigureCtx;
use crate::coordinator::Strategy;
use crate::metrics::Objective;
use crate::plan::PlanRequest;
use crate::report::{self, ascii};
use anyhow::Result;

pub fn run(ctx: &mut FigureCtx, model: &str) -> Result<()> {
    let planner = ctx.engine.planner(model)?;

    let mut sections = String::new();
    let mut csv_rows: Vec<Vec<String>> = Vec::new();
    for strategy in [Strategy::Ip, Strategy::Prefix, Strategy::Random] {
        let mut rows: Vec<(String, String)> = Vec::new();
        for &tau in &ctx.params.taus {
            let plan = planner.solve(
                &PlanRequest::new(Objective::EmpiricalTime)
                    .with_strategy(strategy)
                    .with_loss_budget(tau),
            )?;
            let bits = plan.config.bits_label();
            csv_rows.push(vec![
                strategy.name().to_string(),
                format!("{tau}"),
                bits.clone(),
            ]);
            rows.push((format!("tau={:.3}%", tau * 100.0), bits));
        }
        let title = match strategy {
            Strategy::Ip => "IP-ET (top)",
            Strategy::Prefix => "Prefix (middle)",
            Strategy::Random => "Random (bottom)",
        };
        sections.push_str(&ascii::pattern_grid(
            &format!("Fig 2 [{model}] — {title}"),
            &rows,
        ));
        sections.push('\n');
    }

    report::write_csv(
        &ctx.out.join(format!("fig2_{model}.csv")),
        &["strategy", "tau", "pattern_bits"],
        &csv_rows,
    )?;
    report::save_text(&ctx.out.join(format!("fig2_{model}.txt")), &sections)?;
    println!("fig2[{model}]: patterns for {} taus x 3 strategies", ctx.params.taus.len());
    Ok(())
}
