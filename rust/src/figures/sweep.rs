//! The shared strategy x tau x seed sweep behind Figs. 4, 5, 7, 8, 9 and
//! Table 1: query the `Planner` per (strategy, tau, seed), then attach
//! predicted loss MSE, simulated TTFT, theoretical/memory gains, and
//! per-task accuracy/perplexity.

use crate::coordinator::Strategy;
use crate::evalharness::{CachedEvaluator, EvalResult, TaskData};
use crate::gaudisim::{MpConfig, Simulator};
use crate::graph::Graph;
use crate::metrics::{mem_layer_gain, tt_layer_gain, Objective};
use crate::model::QLayer;
use crate::plan::{PlanRequest, Planner};
use crate::sensitivity::validate::draw_pscale;
use crate::util::Rng;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub strategy: Strategy,
    pub tau: f64,
    pub seed: u64,
    pub config: MpConfig,
    /// Predicted loss MSE d (eq. 6).
    pub predicted_mse: f64,
    /// Normalized RMSE sqrt(d / E[g^2]).
    pub nrmse: f64,
    /// Deterministic simulated TTFT (us).
    pub ttft_us: f64,
    /// Theoretical MAC-time gain (eq. 24) of the config.
    pub tt_gain: f64,
    /// Memory gain in bytes (eq. 25).
    pub mem_gain: f64,
    /// Per-task accuracy and perplexity (task order of `tasks`).
    pub task_acc: Vec<f64>,
    pub task_ppl: Vec<f64>,
}

/// Baseline (all-BF16, unperturbed) reference scores.
#[derive(Clone, Debug)]
pub struct Baseline {
    pub ttft_us: f64,
    pub task_acc: Vec<f64>,
    pub task_ppl: Vec<f64>,
}

pub struct Sweep {
    pub points: Vec<SweepPoint>,
    pub baseline: Baseline,
    pub task_names: Vec<String>,
}

/// Everything a sweep needs for one model, borrowed from the engine's
/// artifacts once (the planner answers every query without recomputation).
pub struct SweepInputs<'a> {
    pub planner: &'a Planner,
    pub qlayers: &'a [QLayer],
    pub graph: &'a Graph,
    pub device: crate::backend::DeviceProfile,
    pub tasks: &'a [TaskData],
}

/// Full sweep for one strategy family.
pub fn run_sweep(
    inp: &SweepInputs,
    objective: Objective,
    taus: &[f64],
    n_seeds: u64,
    sigma: f64,
    strategies: &[Strategy],
    eval: &mut CachedEvaluator,
) -> Result<Sweep> {
    let sim = Simulator::for_device(inp.graph, &inp.device);
    let nq = inp.planner.n_qlayers();

    let bf16 = MpConfig::all_bf16(nq);
    let ones = vec![1.0f32; nq];
    let base_results = eval_tasks(eval, &bf16, u64::MAX, &ones)?;
    let baseline = Baseline {
        ttft_us: sim.makespan(&bf16),
        task_acc: base_results.iter().map(|r| r.acc).collect(),
        task_ppl: base_results.iter().map(|r| r.ppl).collect(),
    };

    let mut points = Vec::new();
    for &strategy in strategies {
        for &tau in taus {
            for seed in 0..n_seeds {
                // Strategy selection: IP/Prefix are tau-deterministic; Random
                // re-draws per seed (paper Fig. 2 scattered patterns).
                let plan = inp.planner.solve(
                    &PlanRequest::new(objective)
                        .with_strategy(strategy)
                        .with_loss_budget(tau)
                        .with_seed(seed),
                )?;
                let config = plan.config;
                let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9));
                let ps = draw_pscale(nq, sigma, &mut rng);
                let results = eval_tasks(eval, &config, seed, &ps)?;
                points.push(SweepPoint {
                    strategy,
                    tau,
                    seed,
                    ttft_us: sim.makespan(&config),
                    tt_gain: total_tt_gain(inp.qlayers, &config, &inp.device),
                    mem_gain: total_mem_gain(inp.qlayers, &config),
                    nrmse: plan.nrmse,
                    predicted_mse: plan.predicted_mse,
                    task_acc: results.iter().map(|r| r.acc).collect(),
                    task_ppl: results.iter().map(|r| r.ppl).collect(),
                    config,
                });
            }
        }
    }
    Ok(Sweep {
        points,
        baseline,
        task_names: inp.tasks.iter().map(|t| t.meta.name.clone()).collect(),
    })
}

fn eval_tasks(
    eval: &mut CachedEvaluator,
    cfg: &MpConfig,
    seed: u64,
    pscale: &[f32],
) -> Result<Vec<EvalResult>> {
    eval.eval_all(cfg, seed, pscale)
}

pub fn total_tt_gain(
    qlayers: &[QLayer],
    cfg: &MpConfig,
    device: &crate::backend::DeviceProfile,
) -> f64 {
    qlayers
        .iter()
        .enumerate()
        .map(|(l, q)| tt_layer_gain(q, cfg.get(l), device))
        .sum()
}

pub fn total_mem_gain(qlayers: &[QLayer], cfg: &MpConfig) -> f64 {
    qlayers
        .iter()
        .enumerate()
        .map(|(l, q)| mem_layer_gain(q, cfg.get(l)))
        .sum()
}

/// Aggregate sweep points into per-(strategy, tau) mean +- std of the
/// task-averaged accuracy difference vs baseline.
pub struct AggPoint {
    pub strategy: Strategy,
    pub tau: f64,
    pub ttft_us: f64,
    pub tt_gain: f64,
    pub mem_gain: f64,
    pub nrmse: f64,
    pub acc_diff_mean: f64,
    pub acc_diff_std: f64,
    /// Per-task (mean, std) accuracy differences.
    pub per_task: Vec<(f64, f64)>,
    /// Per-task (mean, std) ppl relative difference in percent.
    pub per_task_ppl: Vec<(f64, f64)>,
}

pub fn aggregate(sweep: &Sweep, strategy: Strategy) -> Vec<AggPoint> {
    let mut taus: Vec<f64> = sweep
        .points
        .iter()
        .filter(|p| p.strategy == strategy)
        .map(|p| p.tau)
        .collect();
    taus.sort_by(f64::total_cmp);
    taus.dedup();
    let n_tasks = sweep.task_names.len();

    taus.iter()
        .map(|&tau| {
            let pts: Vec<&SweepPoint> = sweep
                .points
                .iter()
                .filter(|p| p.strategy == strategy && p.tau == tau)
                .collect();
            let avg_diffs: Vec<f64> = pts
                .iter()
                .map(|p| {
                    (0..n_tasks)
                        .map(|t| (p.task_acc[t] - sweep.baseline.task_acc[t]) * 100.0)
                        .sum::<f64>()
                        / n_tasks as f64
                })
                .collect();
            let per_task: Vec<(f64, f64)> = (0..n_tasks)
                .map(|t| {
                    let d: Vec<f64> = pts
                        .iter()
                        .map(|p| (p.task_acc[t] - sweep.baseline.task_acc[t]) * 100.0)
                        .collect();
                    (crate::util::stats::mean(&d), crate::util::stats::std(&d))
                })
                .collect();
            let per_task_ppl: Vec<(f64, f64)> = (0..n_tasks)
                .map(|t| {
                    let d: Vec<f64> = pts
                        .iter()
                        .map(|p| {
                            (p.task_ppl[t] / sweep.baseline.task_ppl[t] - 1.0) * 100.0
                        })
                        .collect();
                    (crate::util::stats::mean(&d), crate::util::stats::std(&d))
                })
                .collect();
            AggPoint {
                strategy,
                tau,
                ttft_us: crate::util::stats::mean(
                    &pts.iter().map(|p| p.ttft_us).collect::<Vec<_>>(),
                ),
                tt_gain: crate::util::stats::mean(
                    &pts.iter().map(|p| p.tt_gain).collect::<Vec<_>>(),
                ),
                mem_gain: crate::util::stats::mean(
                    &pts.iter().map(|p| p.mem_gain).collect::<Vec<_>>(),
                ),
                nrmse: crate::util::stats::mean(
                    &pts.iter().map(|p| p.nrmse).collect::<Vec<_>>(),
                ),
                acc_diff_mean: crate::util::stats::mean(&avg_diffs),
                acc_diff_std: crate::util::stats::std(&avg_diffs),
                per_task,
                per_task_ppl,
            }
        })
        .collect()
}
