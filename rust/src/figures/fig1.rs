//! Figure 1: measured per-group empirical time gain of the attention
//! sub-graph vs (a) the sum of per-layer gain measurements and (b) the
//! MAC-based theoretical gain (scale+bias fitted), across all 2^5 configs,
//! sorted by measured gain.  Demonstrates why per-group measurement is
//! needed (the paper's core §2.3.1 motivation).
//!
//! Needs only the stage-1 artifact + the simulator — no PJRT.

use super::FigureCtx;
use crate::gaudisim::Simulator;
use crate::metrics::tt_layer_gain;
use crate::numerics::Format;
use crate::report::{self, ascii};
use crate::timing::{measure_groups, measure_per_layer, SimTtft};
use crate::util::stats;
use anyhow::{anyhow, Result};

pub fn run(ctx: &mut FigureCtx, model: &str) -> Result<()> {
    let part = ctx.engine.partitioned(model)?;
    let graph = ctx.engine.graph(model)?;
    let formats = part.formats.clone();

    // The attention sub-graph = first group with 5 quantizable layers
    // (q, k, v, qk_matmul, av_matmul — paper Fig. 6's V1).
    let gi = part
        .partition
        .groups
        .iter()
        .position(|g| g.len() == 5)
        .ok_or_else(|| anyhow!("no 5-layer attention group found"))?;

    let device = ctx.params.device.clone();
    let pool = ctx.engine.pool();
    let sim = Simulator::for_device(&graph, &device);
    let src = SimTtft { sim, seed: 7, reps: ctx.params.reps };
    let tm = measure_groups(&src, &part.partition, &formats, &pool)?;
    let per_layer = measure_per_layer(&src, &formats, &pool)?;

    let group = &tm.groups[gi];
    let qidxs = &group.qidxs;

    // Per-config: measured group gain, sum-of-per-layer prediction,
    // theoretical gain.
    let mut rows: Vec<(String, f64, f64, f64)> = group
        .configs
        .iter()
        .zip(&group.gains)
        .map(|(cfg_fmts, &measured)| {
            let label: String = cfg_fmts
                .iter()
                .map(|f| if *f == Format::Bf16 { '0' } else { '1' })
                .collect();
            let summed: f64 = qidxs
                .iter()
                .zip(cfg_fmts)
                .map(|(&q, &f)| {
                    let fi = formats.iter().position(|x| *x == f).unwrap();
                    per_layer[q][fi]
                })
                .sum();
            let theo: f64 = qidxs
                .iter()
                .zip(cfg_fmts)
                .map(|(&q, &f)| tt_layer_gain(&part.qlayers[q], f, &device))
                .sum();
            (label, measured, summed, theo)
        })
        .collect();
    rows.sort_by(|a, b| a.1.total_cmp(&b.1));

    // Fit scale+bias of the theoretical gain onto the measured one
    // (paper: "we fit the theoretical and empirical time gains").
    let xs: Vec<f64> = rows.iter().map(|r| r.3).collect();
    let ys: Vec<f64> = rows.iter().map(|r| r.1).collect();
    let (a, b) = stats::linfit(&xs, &ys);

    let csv_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|(label, m, s, t)| {
            vec![
                label.clone(),
                report::f(*m),
                report::f(*s),
                report::f(a * t + b),
            ]
        })
        .collect();
    report::write_csv(
        &ctx.out.join(format!("fig1_{model}.csv")),
        &["config", "measured_group_gain_us", "sum_per_layer_us", "theoretical_fitted_us"],
        &csv_rows,
    )?;

    let idx: Vec<f64> = (0..rows.len()).map(|i| i as f64).collect();
    let plot = ascii::plot(
        &format!("Fig 1 [{model}]: attention sub-graph gain — measured vs per-layer sum vs theoretical (fitted)"),
        "config rank (ascending measured gain)",
        "time gain [us]",
        &[
            ascii::Series {
                name: "measured per-group (paper: blue)".into(),
                points: idx.iter().zip(&rows).map(|(&i, r)| (i, r.1)).collect(),
            },
            ascii::Series {
                name: "sum of per-layer (paper: orange)".into(),
                points: idx.iter().zip(&rows).map(|(&i, r)| (i, r.2)).collect(),
            },
            ascii::Series {
                name: "theoretical, fitted (paper: green)".into(),
                points: idx.iter().zip(&rows).map(|(&i, r)| (i, a * r.3 + b)).collect(),
            },
        ],
    );
    report::save_text(&ctx.out.join(format!("fig1_{model}.txt")), &plot)?;

    // Headline diagnostics mirrored into the summary.
    let gap: Vec<f64> = rows.iter().map(|r| (r.2 - r.1).abs()).collect();
    let max_gain = rows.last().map(|r| r.1).unwrap_or(0.0);
    let summary = format!(
        "fig1[{model}]: group={gi} layers={:?} max measured gain {:.1} us; \
         mean |per-layer-sum - measured| = {:.1} us ({:.0}% of max) — \
         per-layer summation mispredicts branched sub-graphs\n",
        qidxs,
        max_gain,
        stats::mean(&gap),
        100.0 * stats::mean(&gap) / max_gain.max(1e-9),
    );
    print!("{summary}");
    report::save_text(&ctx.out.join(format!("fig1_{model}_summary.txt")), &summary)?;
    Ok(())
}
