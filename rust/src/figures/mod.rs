//! Regeneration of every figure and table in the paper's evaluation
//! (DESIGN.md §5 experiment index).  Each figure lands in results/ as a CSV
//! plus an ASCII rendering.
//!
//! The accuracy experiments share one `run_sweep` product per
//! (model, objective family): strategy x tau x seed -> configuration ->
//! {predicted loss MSE, simulated TTFT, per-task accuracy/ppl}, with
//! config-level caching of forward passes (CachedEvaluator).

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod sweep;
pub mod table1;

use crate::coordinator::Pipeline;
use crate::gaudisim::HwModel;
use crate::model::Manifest;
use crate::numerics::{Format, PAPER_FORMATS};
use crate::runtime::FwdMode;
use anyhow::Result;
use std::path::PathBuf;

/// Experiment-scale parameters (paper defaults; benches shrink them).
#[derive(Clone, Debug)]
pub struct ExpParams {
    pub taus: Vec<f64>,
    pub n_seeds: u64,
    /// Scale-perturbation sigma (paper perturbs quantization scales).
    pub sigma: f64,
    /// TTFT measurement iterations (paper: 5).
    pub reps: usize,
    pub fwd_mode: FwdMode,
    pub hw: HwModel,
}

impl Default for ExpParams {
    fn default() -> Self {
        ExpParams {
            taus: crate::coordinator::paper_tau_grid(),
            n_seeds: 10,
            sigma: 0.02,
            reps: 5,
            fwd_mode: FwdMode::Ref,
            hw: HwModel::default(),
        }
    }
}

impl ExpParams {
    /// Reduced scale for smoke/bench runs.
    pub fn quick() -> Self {
        ExpParams {
            taus: vec![0.0, 0.002, 0.004, 0.007],
            n_seeds: 2,
            ..Default::default()
        }
    }
}

/// Shared context for figure generation.
pub struct FigureCtx {
    pub manifest: Manifest,
    pub params: ExpParams,
    pub out: PathBuf,
}

impl FigureCtx {
    pub fn new(manifest: Manifest, params: ExpParams, out: PathBuf) -> Self {
        std::fs::create_dir_all(&out).ok();
        FigureCtx { manifest, params, out }
    }

    pub fn formats(&self) -> Vec<Format> {
        PAPER_FORMATS.to_vec()
    }

    pub fn pipeline(&self, model: &str) -> Result<Pipeline> {
        Pipeline::new(
            &self.manifest,
            model,
            self.params.fwd_mode,
            self.params.hw.clone(),
            self.formats(),
        )
    }
}
