//! Regeneration of every figure and table in the paper's evaluation
//! (DESIGN.md §5 experiment index).  Each figure lands in results/ as a CSV
//! plus an ASCII rendering.
//!
//! Figures run against the staged planning API: a shared [`plan::Engine`]
//! materializes each model's stage artifacts once, and every figure queries
//! the resulting `Planner` — so regenerating all figures pays one
//! calibration and one time-measurement pass per model.
//!
//! The accuracy experiments share one `run_sweep` product per
//! (model, objective family): strategy x tau x seed -> configuration ->
//! {predicted loss MSE, simulated TTFT, per-task accuracy/ppl}, with
//! config-level caching of forward passes (CachedEvaluator).

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod sweep;
pub mod table1;

use crate::backend::DeviceProfile;
use crate::numerics::{Format, PAPER_FORMATS};
use crate::plan::engine::DEFAULT_MEASURE_SEED;
use crate::plan::Engine;
use crate::runtime::FwdMode;
use std::path::PathBuf;

/// Experiment-scale parameters (paper defaults; benches shrink them).
#[derive(Clone, Debug)]
pub struct ExpParams {
    pub taus: Vec<f64>,
    pub n_seeds: u64,
    /// Scale-perturbation sigma (paper perturbs quantization scales).
    pub sigma: f64,
    /// TTFT measurement iterations (paper: 5).
    pub reps: usize,
    pub fwd_mode: FwdMode,
    /// Hardware the simulated measurements run on.
    pub device: DeviceProfile,
}

impl Default for ExpParams {
    fn default() -> Self {
        ExpParams {
            taus: crate::coordinator::paper_tau_grid(),
            n_seeds: 10,
            sigma: 0.02,
            reps: 5,
            fwd_mode: FwdMode::Ref,
            device: DeviceProfile::gaudi2(),
        }
    }
}

impl ExpParams {
    /// Reduced scale for smoke/bench runs.
    pub fn quick() -> Self {
        ExpParams {
            taus: vec![0.0, 0.002, 0.004, 0.007],
            n_seeds: 2,
            ..Default::default()
        }
    }
}

/// Shared context for figure generation: the artifact engine + scales.
pub struct FigureCtx {
    pub engine: Engine,
    pub params: ExpParams,
    pub out: PathBuf,
}

impl FigureCtx {
    pub fn new(engine: Engine, params: ExpParams, out: PathBuf) -> Self {
        std::fs::create_dir_all(&out).ok();
        let engine = engine
            .with_device(params.device.clone())
            .with_fwd_mode(params.fwd_mode)
            .with_measure_protocol(DEFAULT_MEASURE_SEED, params.reps);
        FigureCtx { engine, params, out }
    }

    pub fn formats(&self) -> Vec<Format> {
        PAPER_FORMATS.to_vec()
    }
}
