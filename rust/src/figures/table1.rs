//! Table 1 + the accuracy-vs-performance figures (4, 5, 7, 8, 9).
//!
//! All of these consume the same strategy x tau x seed sweeps (one per
//! objective family) against one Planner, so they are generated together
//! per model, and Table 1 is then combined across models.

use super::sweep::{aggregate, run_sweep, Sweep, SweepInputs};
use super::FigureCtx;
use crate::coordinator::Strategy;
use crate::evalharness::{load_all_tasks, CachedEvaluator};
use crate::metrics::Objective;
use crate::numerics::Format;
use crate::report::{self, ascii};
use anyhow::{anyhow, Result};
use std::path::Path;

const STRATEGIES: [Strategy; 3] = [Strategy::Random, Strategy::Prefix, Strategy::Ip];

pub fn run(ctx: &mut FigureCtx, model: &str) -> Result<()> {
    let planner = ctx.engine.planner(model)?;
    let info = ctx.engine.info(model)?;
    let graph = ctx.engine.graph(model)?;
    let root = ctx
        .engine
        .artifacts_root()
        .ok_or_else(|| anyhow!("table1 needs an artifacts root (task datasets)"))?
        .to_path_buf();
    let tasks = load_all_tasks(&root, &info)?;
    let device = ctx.params.device.clone();
    let mr = ctx.engine.runtime(model)?;
    let mut eval = CachedEvaluator::new(mr, &tasks);
    let inputs = SweepInputs {
        planner: &planner,
        qlayers: &info.qlayers,
        graph: &graph,
        device,
        tasks: &tasks,
    };

    let mut table_rows: Vec<Vec<String>> = Vec::new();

    for objective in Objective::ALL {
        let sweep = run_sweep(
            &inputs,
            objective,
            &ctx.params.taus,
            ctx.params.n_seeds,
            ctx.params.sigma,
            &STRATEGIES,
            &mut eval,
        )?;

        emit_family_figures(&ctx.out, model, objective, &sweep)?;
        table_rows.extend(table1_rows(model, objective, &sweep));
        println!(
            "table1[{model}/{}]: {} sweep points, {} unique forward configs",
            objective.name(),
            sweep.points.len(),
            eval.cache_len()
        );
    }

    report::write_csv(
        &ctx.out.join(format!("table1_{model}.csv")),
        &TABLE1_HEADER,
        &table_rows,
    )?;
    Ok(())
}

const TABLE1_HEADER: [&str; 9] = [
    "model", "family", "strategy", "lamb_ppl_diff_pct", "lamb_acc_diff",
    "hella_acc_diff", "wino_acc_diff", "piqa_acc_diff", "tasks_avg_acc_diff",
];

/// Pool all (tau, seed) points of a strategy into mean +- std rows
/// (paper: "averaged over different quantization configurations from
/// high-precision (BF16) to low-precision (FP8)").
fn table1_rows(model: &str, objective: Objective, sweep: &Sweep) -> Vec<Vec<String>> {
    let t_idx = |name: &str| sweep.task_names.iter().position(|n| n == name).unwrap();
    let (ti_hella, ti_lamb, ti_wino, ti_piqa) =
        (t_idx("hella"), t_idx("lamb"), t_idx("wino"), t_idx("piqa"));

    STRATEGIES
        .iter()
        .map(|&strategy| {
            let pts: Vec<_> = sweep.points.iter().filter(|p| p.strategy == strategy).collect();
            let col = |ti: usize| -> (f64, f64) {
                let d: Vec<f64> = pts
                    .iter()
                    .map(|p| (p.task_acc[ti] - sweep.baseline.task_acc[ti]) * 100.0)
                    .collect();
                (crate::util::stats::mean(&d), crate::util::stats::std(&d))
            };
            let ppl: Vec<f64> = pts
                .iter()
                .map(|p| (p.task_ppl[ti_lamb] / sweep.baseline.task_ppl[ti_lamb] - 1.0) * 100.0)
                .collect();
            let avg: Vec<f64> = pts
                .iter()
                .map(|p| {
                    [ti_hella, ti_lamb, ti_wino, ti_piqa]
                        .iter()
                        .map(|&ti| (p.task_acc[ti] - sweep.baseline.task_acc[ti]) * 100.0)
                        .sum::<f64>()
                        / 4.0
                })
                .collect();
            let (lm, ls) = col(ti_lamb);
            let (hm, hs) = col(ti_hella);
            let (wm, ws) = col(ti_wino);
            let (pm_, ps) = col(ti_piqa);
            vec![
                model.to_string(),
                objective.name().to_string(),
                strategy.name().to_string(),
                report::pm(crate::util::stats::mean(&ppl), crate::util::stats::std(&ppl)),
                report::pm(lm, ls),
                report::pm(hm, hs),
                report::pm(wm, ws),
                report::pm(pm_, ps),
                report::pm(crate::util::stats::mean(&avg), crate::util::stats::std(&avg)),
            ]
        })
        .collect()
}

fn emit_family_figures(
    out: &Path,
    model: &str,
    objective: Objective,
    sweep: &Sweep,
) -> Result<()> {
    let aggs: Vec<_> = STRATEGIES.iter().map(|&s| (s, aggregate(sweep, s))).collect();

    // Per-point CSV (raw sweep) for downstream analysis.
    let mut rows = Vec::new();
    for p in &sweep.points {
        rows.push(vec![
            p.strategy.name().into(),
            format!("{}", p.tau),
            format!("{}", p.seed),
            p.config.bits_label(),
            report::f(p.predicted_mse),
            report::f(p.nrmse),
            report::f(p.ttft_us),
            report::f(p.tt_gain),
            report::f(p.mem_gain),
            p.task_acc.iter().map(|a| format!("{a:.4}")).collect::<Vec<_>>().join(";"),
        ]);
    }
    report::write_csv(
        &out.join(format!("sweep_{model}_{}.csv", objective.name())),
        &["strategy", "tau", "seed", "config", "pred_mse", "nrmse", "ttft_us", "tt_gain", "mem_gain", "task_acc"],
        &rows,
    )?;

    match objective {
        Objective::EmpiricalTime => {
            // Fig 4: loss MSE vs empirical time gain.
            let series4: Vec<ascii::Series> = aggs
                .iter()
                .map(|(s, ag)| ascii::Series {
                    name: s.name().into(),
                    points: ag
                        .iter()
                        .map(|a| (sweep.baseline.ttft_us - a.ttft_us, a.nrmse * a.nrmse))
                        .collect(),
                })
                .collect();
            report::save_text(
                &out.join(format!("fig4_{model}.txt")),
                &ascii::plot(
                    &format!("Fig 4 [{model}]: loss MSE vs empirical time gain"),
                    "time gain [us]",
                    "normalized loss MSE (d / E[g^2])",
                    &series4,
                ),
            )?;
            // Fig 5: avg accuracy diff vs TTFT.
            let series5: Vec<ascii::Series> = aggs
                .iter()
                .map(|(s, ag)| ascii::Series {
                    name: s.name().into(),
                    points: ag.iter().map(|a| (a.ttft_us, a.acc_diff_mean)).collect(),
                })
                .collect();
            report::save_text(
                &out.join(format!("fig5_{model}.txt")),
                &ascii::plot(
                    &format!("Fig 5 [{model}]: avg accuracy diff [%] vs TTFT [us]"),
                    "TTFT [us]",
                    "accuracy diff vs BF16 [%]",
                    &series5,
                ),
            )?;
            // Fig 7: per-task accuracy (and lamb ppl) vs TTFT.
            let mut fig7 = String::new();
            for (ti, tname) in sweep.task_names.iter().enumerate() {
                let series: Vec<ascii::Series> = aggs
                    .iter()
                    .map(|(s, ag)| ascii::Series {
                        name: s.name().into(),
                        points: ag.iter().map(|a| (a.ttft_us, a.per_task[ti].0)).collect(),
                    })
                    .collect();
                fig7.push_str(&ascii::plot(
                    &format!("Fig 7 [{model}/{tname}]: accuracy diff [%] vs TTFT [us]"),
                    "TTFT [us]",
                    "acc diff [%]",
                    &series,
                ));
                fig7.push('\n');
                if tname == "lamb" {
                    let series_p: Vec<ascii::Series> = aggs
                        .iter()
                        .map(|(s, ag)| ascii::Series {
                            name: s.name().into(),
                            points: ag
                                .iter()
                                .map(|a| (a.ttft_us, a.per_task_ppl[ti].0))
                                .collect(),
                        })
                        .collect();
                    fig7.push_str(&ascii::plot(
                        &format!("Fig 7 [{model}/lamb]: perplexity diff [%] vs TTFT [us]"),
                        "TTFT [us]",
                        "ppl diff [%]",
                        &series_p,
                    ));
                    fig7.push('\n');
                }
            }
            report::save_text(&out.join(format!("fig7_{model}.txt")), &fig7)?;
        }
        Objective::TheoreticalTime => {
            // Fig 8: accuracy diff vs theoretical (MAC) time.
            let base_tt: f64 = sweep
                .points
                .iter()
                .map(|p| p.tt_gain)
                .fold(0.0, f64::max);
            let series: Vec<ascii::Series> = aggs
                .iter()
                .map(|(s, ag)| ascii::Series {
                    name: s.name().into(),
                    points: ag
                        .iter()
                        .map(|a| (base_tt - a.tt_gain, a.acc_diff_mean))
                        .collect(),
                })
                .collect();
            report::save_text(
                &out.join(format!("fig8_{model}.txt")),
                &ascii::plot(
                    &format!("Fig 8 [{model}]: accuracy diff [%] vs MAC-time (lower = more quantized)"),
                    "theoretical time [BF16-MAC units, relative]",
                    "acc diff [%]",
                    &series,
                ),
            )?;
        }
        Objective::Memory => {
            // Fig 9: accuracy diff vs total model memory.
            let total_bytes = (pl_total_param_bytes(sweep)) as f64;
            let series: Vec<ascii::Series> = aggs
                .iter()
                .map(|(s, ag)| ascii::Series {
                    name: s.name().into(),
                    points: ag
                        .iter()
                        .map(|a| (total_bytes - a.mem_gain, a.acc_diff_mean))
                        .collect(),
                })
                .collect();
            report::save_text(
                &out.join(format!("fig9_{model}.txt")),
                &ascii::plot(
                    &format!("Fig 9 [{model}]: accuracy diff [%] vs total weight memory [bytes]"),
                    "total memory [bytes]",
                    "acc diff [%]",
                    &series,
                ),
            )?;
        }
    }
    Ok(())
}

/// Baseline weight bytes: the memory x-axis offset.  All sweeps carry the
/// same qlayer table, so infer from the largest possible gain at FP8
/// (delta_M = 1 byte/element -> gain == param count) plus BF16 2 B/element.
fn pl_total_param_bytes(sweep: &Sweep) -> u64 {
    // max mem_gain over points == sum over linear layers of params * 1 byte
    // only if some point quantizes everything; safer: recompute from configs
    // is overkill — use 2x the max observed gain as the BF16 total proxy,
    // falling back to max gain if nothing quantized.
    let max_gain = sweep.points.iter().map(|p| p.mem_gain).fold(0.0, f64::max);
    (2.0 * max_gain.max(1.0)) as u64
}

/// Merge per-model Table 1 CSVs into the final table + rendering.
pub fn combine(ctx: &FigureCtx, models: &[String]) -> Result<()> {
    let mut all_rows: Vec<Vec<String>> = Vec::new();
    for m in models {
        let path = ctx.out.join(format!("table1_{m}.csv"));
        let text = std::fs::read_to_string(&path)?;
        for line in text.lines().skip(1) {
            all_rows.push(line.split(',').map(|s| s.to_string()).collect());
        }
    }
    report::write_csv(&ctx.out.join("table1.csv"), &TABLE1_HEADER, &all_rows)?;
    let header: Vec<String> = TABLE1_HEADER.iter().map(|s| s.to_string()).collect();
    let rendered = report::format_table(&header, &all_rows);
    report::save_text(&ctx.out.join("table1.txt"), &rendered)?;
    println!("{rendered}");
    let _ = Format::Bf16; // anchor import
    Ok(())
}
