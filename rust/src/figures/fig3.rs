//! Figure 3 (§3.2 model validation) on IP-ET-chosen configurations over the
//! tau sweep plus all-FP8:
//!   3a: theoretical (additive) loss MSE vs measured E[(ghat - g)^2];
//!   3b: theoretical (group-additive) TTFT reduction vs direct measurement.
//!
//! Plans come from the cached artifacts; only the measured-loss validation
//! itself needs the compiled forward (PJRT).

use super::FigureCtx;
use crate::gaudisim::{MpConfig, Simulator};
use crate::metrics::Objective;
use crate::numerics::Format;
use crate::plan::PlanRequest;
use crate::report::{self, ascii};
use crate::sensitivity::validate::measured_loss_mse;
use crate::util::{stats, Rng};
use anyhow::{anyhow, Result};

pub fn run(ctx: &mut FigureCtx, model: &str) -> Result<()> {
    let planner = ctx.engine.planner(model)?;
    let graph = ctx.engine.graph(model)?;
    let info = ctx.engine.info(model)?;
    let root = ctx
        .engine
        .artifacts_root()
        .ok_or_else(|| anyhow!("fig3 needs an artifacts root (calibration tokens)"))?
        .to_path_buf();
    let calib_tokens = info.load_calib(&root)?;
    let sim = Simulator::for_device(&graph, &ctx.params.device);
    let nq = planner.n_qlayers();
    let base_ttft = sim.makespan(&MpConfig::all_bf16(nq));
    let tm = planner.measurements().clone();
    let calibration = planner.calibration().clone();

    // Configurations: IP-ET at each tau, plus all-FP8 (paper protocol).
    let mut configs: Vec<(String, MpConfig)> = Vec::new();
    for &tau in &ctx.params.taus {
        let plan = planner
            .solve(&PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau))?;
        configs.push((format!("{tau}"), plan.config));
    }
    configs.push(("all-fp8".into(), MpConfig::uniform(nq, Format::Fp8E4m3)));

    let mr = ctx.engine.runtime(model)?;
    let mut rng = Rng::new(33);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut a_pred = Vec::new();
    let mut a_meas = Vec::new();
    let mut b_pred = Vec::new();
    let mut b_meas = Vec::new();
    for (i, (tag, cfg)) in configs.iter().enumerate() {
        let d_pred = calibration.loss_mse(cfg);
        let d_meas = measured_loss_mse(
            mr,
            &calib_tokens,
            cfg,
            3,
            ctx.params.sigma,
            &mut rng,
        )?;
        // 3b: group-additive prediction vs direct simulator measurement,
        // as relative TTFT reduction.
        let pred_red = tm.predict_gain(cfg) / tm.base_ttft;
        let meas_red = (base_ttft - sim.makespan(cfg)) / base_ttft;
        rows.push(vec![
            tag.clone(),
            report::f(d_pred),
            report::f(d_meas),
            report::f(pred_red),
            report::f(meas_red),
        ]);
        a_pred.push((i as f64, d_pred));
        a_meas.push((i as f64, d_meas));
        b_pred.push((i as f64, pred_red));
        b_meas.push((i as f64, meas_red));
    }

    report::write_csv(
        &ctx.out.join(format!("fig3_{model}.csv")),
        &["tau", "pred_loss_mse", "measured_loss_mse", "pred_ttft_reduction", "measured_ttft_reduction"],
        &rows,
    )?;

    let plot_a = ascii::plot(
        &format!("Fig 3a [{model}]: loss MSE vs tau index — theoretical (o) vs measured (x)"),
        "tau index (last = all-FP8)",
        "loss MSE",
        &[
            ascii::Series { name: "theoretical (additive, eq. 6)".into(), points: a_pred.clone() },
            ascii::Series { name: "measured on chosen configs".into(), points: a_meas.clone() },
        ],
    );
    let plot_b = ascii::plot(
        &format!("Fig 3b [{model}]: relative TTFT reduction vs tau index"),
        "tau index (last = all-FP8)",
        "TTFT reduction fraction",
        &[
            ascii::Series { name: "theoretical (group-additive, eq. 7)".into(), points: b_pred.clone() },
            ascii::Series { name: "measured".into(), points: b_meas.clone() },
        ],
    );
    report::save_text(&ctx.out.join(format!("fig3a_{model}.txt")), &plot_a)?;
    report::save_text(&ctx.out.join(format!("fig3b_{model}.txt")), &plot_b)?;

    let corr_mse = stats::pearson(
        &a_pred.iter().map(|p| p.1).collect::<Vec<_>>(),
        &a_meas.iter().map(|p| p.1).collect::<Vec<_>>(),
    );
    let corr_ttft = stats::pearson(
        &b_pred.iter().map(|p| p.1).collect::<Vec<_>>(),
        &b_meas.iter().map(|p| p.1).collect::<Vec<_>>(),
    );
    println!(
        "fig3[{model}]: corr(pred, measured) loss-MSE = {corr_mse:.3}, TTFT reduction = {corr_ttft:.3}"
    );
    report::save_text(
        &ctx.out.join(format!("fig3_{model}_summary.txt")),
        &format!("corr_loss_mse={corr_mse:.4}\ncorr_ttft={corr_ttft:.4}\n"),
    )?;
    Ok(())
}
