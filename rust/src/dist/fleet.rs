//! Fleet mode: schedule the full models × devices calibration +
//! measurement + frontier matrix over one shared worker fleet
//! (`ampq fleet --models a,b --devices gaudi2,gaudi3 --workers W`).
//!
//! Artifacts land under `out/<model>/` with the same JSON encodings the
//! Engine cache uses; the run summary (timings, supervision metrics) goes
//! to stdout ONLY, so two output trees produced at different worker
//! counts can be compared with a plain `diff -r` — the determinism
//! acceptance check in `tests/dist.rs` and the `dist-smoke` CI job.
//!
//! `workers == 0` runs every cell in-process on a sequential pool — the
//! reference the distributed path must match byte-for-byte.

// lint: allow-file(D3) run-summary wall time for the fleet report; artifact bytes are produced by the deterministic planning path, not by these clocks

use super::coordinator::{Coordinator, DistConfig, DistMetrics};
use crate::backend::Registry;
use crate::coordinator::ip;
use crate::exec::{ExecCfg, ExecPool};
use crate::metrics::Objective;
use crate::numerics::{Format, PAPER_FORMATS};
use crate::plan::demo::demo_model;
use crate::plan::engine::{DEFAULT_MEASURE_REPS, DEFAULT_MEASURE_SEED};
use crate::plan::stage::{CalibSource, CalibrateStage, MeasureStage, PartitionStage, Stage};
use crate::plan::{Calibrated, Planner};
use crate::solver::parametric;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// One fleet run: the model × device matrix, the worker count (0 =
/// in-process reference path), and the supervision policy.
#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub models: Vec<String>,
    pub devices: Vec<String>,
    /// Worker processes; 0 runs everything in-process sequentially.
    pub workers: usize,
    /// Output root; artifacts land in `out/<model>/`.
    pub out: PathBuf,
    /// Synthetic transformer depth for demo models.
    pub blocks: usize,
    pub dist: DistConfig,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            models: vec!["demo".into()],
            devices: vec!["gaudi2".into()],
            workers: 0,
            out: PathBuf::from("fleet-out"),
            blocks: 2,
            dist: DistConfig::default(),
        }
    }
}

/// One completed (model, device) cell of the matrix.
#[derive(Clone, Debug)]
pub struct FleetCell {
    pub model: String,
    pub device: String,
    pub knots: usize,
    pub complete: bool,
    pub elapsed: Duration,
}

/// The full fleet run: every cell plus the coordinator's supervision
/// counters (all zero on the in-process path).
pub struct FleetReport {
    pub cells: Vec<FleetCell>,
    pub metrics: DistMetrics,
}

/// Deterministic per-model demo seed: FNV-1a 64 of the model name (the
/// same constants [`crate::backend::DeviceProfile::fs_key`] uses), so
/// every worker count — and every session — derives the same model.
pub fn model_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Mirror of `Engine::menu`: validate the device and restrict the paper
/// format menu to what it supports; BF16 must survive (it is the baseline
/// every gain is measured against).
fn device_menu(device: &crate::backend::DeviceProfile) -> Result<Vec<Format>> {
    device.validate()?;
    let menu = device.restrict_menu(&PAPER_FORMATS);
    if !menu.contains(&Format::Bf16) {
        bail!("device '{}' does not support BF16 (no baseline format)", device.name);
    }
    Ok(menu)
}

/// Run the matrix.  Every artifact is byte-identical at any `workers`
/// value; see the module docs for the contract.
pub fn run_fleet(cfg: &FleetConfig) -> Result<FleetReport> {
    if cfg.models.is_empty() || cfg.devices.is_empty() {
        bail!("fleet needs at least one model and one device");
    }
    let registry = Registry::builtin();
    let seq = ExecPool::sequential();
    let mut coord = if cfg.workers > 0 {
        let dist = DistConfig { workers: cfg.workers, ..cfg.dist.clone() };
        Some(Coordinator::new(dist)?)
    } else {
        None
    };

    let mut cells = Vec::new();
    for model in &cfg.models {
        let seed = model_seed(model);
        let (graph, qlayers, calibration) = demo_model(cfg.blocks.max(1), seed);
        let model_dir = cfg.out.join(model);
        std::fs::create_dir_all(&model_dir)
            .with_context(|| format!("creating {}", model_dir.display()))?;

        for (di, device_name) in cfg.devices.iter().enumerate() {
            let t0 = Instant::now();
            let device = registry.resolve(device_name)?;
            let menu = device_menu(&device)?;

            // Stage 1 — partition (cheap graph pass, always in-process).
            let partitioned =
                PartitionStage { model, graph: &graph, qlayers: &qlayers, menu: &menu }
                    .run(&seq)?;

            // Stage 2 — calibration.  The demo calibration is a pure
            // function of (n_qlayers, seed); the distributed path has a
            // worker recompute it, the reference path injects it — both
            // produce the identical artifact.
            let calibrated = match coord.as_mut() {
                Some(c) => Calibrated {
                    model: model.clone(),
                    calibration: c.calibrate_demo(qlayers.len(), seed)?,
                },
                None => CalibrateStage { model, source: CalibSource::Injected(&calibration) }
                    .run(&seq)?,
            };

            // Stage 3 — per-(group, config) TTFT measurement.
            let ms = MeasureStage {
                model,
                graph: &graph,
                partitioned: &partitioned,
                device: &device,
                seed: DEFAULT_MEASURE_SEED,
                reps: DEFAULT_MEASURE_REPS,
            };
            let measured = match coord.as_mut() {
                Some(c) => c.measure_stage(&ms)?,
                None => ms.run(&seq)?,
            };

            // Device-independent artifacts once per model; per-device ones
            // keyed by the profile's filesystem key.
            if di == 0 {
                write_text(&model_dir.join("partitioned.json"), &partitioned.to_json())?;
                write_text(&model_dir.join("calibrated.json"), &calibrated.to_json())?;
            }
            let key = device.fs_key();
            write_text(&model_dir.join(format!("measured-{key}.json")), &measured.to_json())?;

            // Frontier: the parametric chain-DP sweep, remote expansion
            // when a fleet is attached.
            let planner = Planner::new(partitioned, calibrated, measured)?
                .with_exec(ExecCfg::new(1));
            let obj = Objective::EmpiricalTime;
            let family = planner.family(obj);
            let problem =
                ip::frontier_instance(&family.groups, planner.calibration(), planner.tau_max(obj))?;
            let curve = match coord.as_mut() {
                Some(c) => c.frontier_curve(&problem)?,
                None => parametric::frontier_with(&problem, &seq),
            };
            let solves =
                ip::materialize_curve(&family.groups, planner.calibration(), &problem, &curve);
            write_text(
                &model_dir.join(format!("frontier-{key}.json")),
                &frontier_json(model, &device.name, planner.tau_max(obj), &solves),
            )?;

            cells.push(FleetCell {
                model: model.clone(),
                device: device.name.clone(),
                knots: solves.knots.len(),
                complete: solves.complete,
                elapsed: t0.elapsed(),
            });
        }
    }

    let metrics = match coord.as_mut() {
        Some(c) => {
            let m = c.metrics().clone();
            c.shutdown();
            m
        }
        None => DistMetrics::default(),
    };
    Ok(FleetReport { cells, metrics })
}

/// The frontier artifact: every knot as (gain, predicted MSE, config).
fn frontier_json(
    model: &str,
    device: &str,
    tau_max: f64,
    solves: &ip::FrontierSolves,
) -> Json {
    let knots = solves
        .knots
        .iter()
        .map(|k| {
            Json::Obj(vec![
                ("gain".into(), Json::Num(k.gain)),
                ("predicted_mse".into(), Json::Num(k.predicted_mse)),
                ("exact".into(), Json::Bool(k.exact)),
                (
                    "config".into(),
                    Json::Arr(
                        k.config.0.iter().map(|f| Json::Str(f.name().to_string())).collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::Num(crate::plan::SCHEMA_VERSION as f64)),
        ("kind".into(), Json::Str("frontier".into())),
        ("model".into(), Json::Str(model.to_string())),
        ("device".into(), Json::Str(device.to_string())),
        ("objective".into(), Json::Str("empirical_time".into())),
        ("tau_max".into(), Json::Num(tau_max)),
        ("complete".into(), Json::Bool(solves.complete)),
        ("knots".into(), Json::Arr(knots)),
    ])
}

fn write_text(path: &std::path::Path, j: &Json) -> Result<()> {
    std::fs::write(path, j.to_string())
        .with_context(|| format!("writing {}", path.display()))
}

/// Render the run summary (stdout-only; never written under `out`).
pub fn render_summary(report: &FleetReport, workers: usize) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "fleet: {} cell(s), {} worker(s)\n",
        report.cells.len(),
        workers
    ));
    for c in &report.cells {
        s.push_str(&format!(
            "  {:<12} {:<12} {:>4} knots  complete={}  {:>7.1}ms\n",
            c.model,
            c.device,
            c.knots,
            c.complete,
            c.elapsed.as_secs_f64() * 1e3
        ));
    }
    let m = &report.metrics;
    s.push_str(&format!(
        "  supervision: tasks={} retries={} deadline_expiries={} crashes={} respawns={}\n",
        m.tasks, m.retries, m.deadline_expiries, m.worker_crashes, m.respawns
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_seed_is_stable_fnv1a() {
        // Locked values: artifacts on disk depend on them.
        assert_eq!(model_seed(""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(model_seed("demo"), model_seed("demo2"));
        assert_eq!(model_seed("demo"), model_seed("demo"));
    }

    #[test]
    fn in_process_fleet_writes_the_full_matrix() {
        let out = std::env::temp_dir().join(format!("ampq_fleet_{}", std::process::id()));
        std::fs::remove_dir_all(&out).ok();
        let cfg = FleetConfig {
            models: vec!["demo".into()],
            devices: vec!["gaudi2".into(), "gaudi3".into()],
            workers: 0,
            out: out.clone(),
            blocks: 1,
            ..FleetConfig::default()
        };
        let report = run_fleet(&cfg).unwrap();
        assert_eq!(report.cells.len(), 2);
        assert_eq!(report.metrics, DistMetrics::default());
        for f in ["partitioned.json", "calibrated.json"] {
            assert!(out.join("demo").join(f).exists(), "{f} missing");
        }
        let entries: Vec<String> = std::fs::read_dir(out.join("demo"))
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(entries.iter().filter(|e| e.starts_with("measured-")).count(), 2);
        assert_eq!(entries.iter().filter(|e| e.starts_with("frontier-")).count(), 2);
        let summary = render_summary(&report, 0);
        assert!(summary.contains("2 cell(s)"));
        std::fs::remove_dir_all(&out).ok();
    }

    #[test]
    fn fleet_rejects_an_empty_matrix() {
        let cfg = FleetConfig { models: vec![], ..FleetConfig::default() };
        assert!(run_fleet(&cfg).is_err());
    }
}
