//! The distributed-planning coordinator: a supervised fleet of `ampq
//! worker` subprocesses plus the deterministic task scheduler that fans
//! measurement and frontier-DP work out to them.
//!
//! ## Determinism
//!
//! Workers are interchangeable pure-function evaluators.  Every task's
//! identity (noise-stream index, DP level + chunk start) is fixed by the
//! SAME enumeration the in-process path uses (`timing::measure_plan`,
//! `parametric::EXPAND_CHUNK` boundaries), results are stored by task
//! index and reduced in task order, and floats survive the JSON wire
//! bit-exactly — so any worker count, any assignment interleaving, and
//! any number of crash/retry cycles produce output byte-identical to
//! `--threads 1` in process.
//!
//! ## Supervision
//!
//! One in-flight task per worker.  Each assignment carries a deadline;
//! expiry kills the worker and re-issues the task to a healthy one.  A
//! worker EOF (crash) fails its assignment the same way.  Re-issues are
//! counted against a bounded per-task retry budget with a fixed backoff
//! before each respawn; exhausting the budget fails the batch (after
//! aborting in-flight work so the fleet stays usable).  Contexts (model +
//! device, MCKP instance) are installed once per worker and re-installed
//! transparently after a respawn.

// lint: allow-file(D3) supervision deadlines (worker spawn timeouts, retry backoff, heartbeats) are wall-clock by design; task *results* are merged in deterministic shard order regardless of arrival time

use super::protocol::{
    level_from_json, level_to_json, mckp_to_json, msg_id, read_frame, request, write_frame,
};
use super::worker::ctx_request;
use crate::backend::DeviceProfile;
use crate::gaudisim::MpConfig;
use crate::graph::partition::Partition;
use crate::graph::Graph;
use crate::numerics::Format;
use crate::plan::stage::MeasureStage;
use crate::plan::Measured;
use crate::sensitivity::Calibration;
use crate::solver::parametric::{self, ParametricCurve};
use crate::solver::Mckp;
use crate::timing::{measure_plan, MeasurePlan, TimeMeasurements, MEASURE_CHUNK};
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashSet, VecDeque};
use std::io::Write;
use std::net::TcpListener;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How coordinator and workers talk: stdin/stdout pipes (default) or a
/// loopback TCP socket each worker dials back to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Stdio,
    Tcp,
}

/// Fleet shape and supervision policy.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Worker process count (min 1).
    pub workers: usize,
    /// Worker executable; defaults to `$AMPQ_WORKER_BIN`, then the current
    /// executable when it IS `ampq`.
    pub worker_bin: Option<PathBuf>,
    pub transport: Transport,
    /// Per-assignment deadline; expiry kills the worker and re-issues.
    pub task_deadline: Duration,
    /// Re-issues allowed per task before the batch fails.
    pub max_retries: usize,
    /// Pause before each worker respawn.
    pub retry_backoff: Duration,
    /// Test hook: crash (SIGKILL) worker 0 after this many completed tasks,
    /// once — exercises the recovery path deterministically.
    pub debug_kill_after: Option<usize>,
}

impl Default for DistConfig {
    fn default() -> DistConfig {
        DistConfig {
            workers: 2,
            worker_bin: None,
            transport: Transport::Stdio,
            task_deadline: Duration::from_secs(30),
            max_retries: 3,
            retry_backoff: Duration::from_millis(50),
            debug_kill_after: None,
        }
    }
}

/// Supervision counters (progress/metrics summary of a fleet run).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DistMetrics {
    /// Tasks completed successfully.
    pub tasks: usize,
    /// Task re-issues (crash, deadline, or worker-reported error).
    pub retries: usize,
    pub deadline_expiries: usize,
    pub worker_crashes: usize,
    pub respawns: usize,
}

/// A context shared by several tasks, installed at most once per worker
/// (and re-installed after respawns).
pub struct CtxSpec {
    pub name: String,
    pub body: Json,
}

/// One schedulable unit of remote work.
#[derive(Clone)]
pub struct TaskSpec {
    pub kind: String,
    pub fields: Vec<(String, Json)>,
    pub ctx: Option<Arc<CtxSpec>>,
}

struct Assignment {
    task: usize,
    id: u64,
    deadline: Instant,
}

struct WorkerSlot {
    child: Child,
    writer: Box<dyn Write + Send>,
    /// Spawn generation; events from a previous incarnation are dropped.
    gen: u64,
    ctxs: HashSet<String>,
    /// Outstanding ctx-install message ids awaiting their (ignored) ack.
    ctx_acks: HashSet<u64>,
    assignment: Option<Assignment>,
    alive: bool,
}

enum Event {
    Msg { worker: usize, gen: u64, msg: Json },
    Eof { worker: usize, gen: u64 },
}

/// Resolve the worker executable (config -> env -> self).
pub fn resolve_worker_bin(cfg: &DistConfig) -> Result<PathBuf> {
    if let Some(b) = &cfg.worker_bin {
        return Ok(b.clone());
    }
    if let Ok(env) = std::env::var("AMPQ_WORKER_BIN") {
        if !env.is_empty() {
            return Ok(PathBuf::from(env));
        }
    }
    let exe = std::env::current_exe().context("cannot resolve current executable")?;
    if exe.file_stem().map(|s| s == "ampq").unwrap_or(false) {
        return Ok(exe);
    }
    bail!(
        "cannot locate the ampq worker binary from {}: set AMPQ_WORKER_BIN or \
         DistConfig.worker_bin",
        exe.display()
    )
}

pub struct Coordinator {
    cfg: DistConfig,
    bin: PathBuf,
    slots: Vec<WorkerSlot>,
    tx: mpsc::Sender<Event>,
    rx: mpsc::Receiver<Event>,
    listener: Option<TcpListener>,
    next_id: u64,
    next_ctx: u64,
    next_gen: u64,
    metrics: DistMetrics,
    debug_killed: bool,
    shut: bool,
    /// Open `dist.run_tasks` span id; adopted worker spans re-parent
    /// under it (0 = no batch in flight / tracing off).
    batch_span: u64,
}

impl Coordinator {
    /// Spawn the full worker fleet eagerly (fail fast on a bad binary).
    pub fn new(cfg: DistConfig) -> Result<Coordinator> {
        let bin = resolve_worker_bin(&cfg)?;
        let listener = match cfg.transport {
            Transport::Stdio => None,
            Transport::Tcp => {
                let l = TcpListener::bind("127.0.0.1:0")?;
                l.set_nonblocking(true)?;
                Some(l)
            }
        };
        let (tx, rx) = mpsc::channel();
        let mut c = Coordinator {
            cfg,
            bin,
            slots: Vec::new(),
            tx,
            rx,
            listener,
            next_id: 0,
            next_ctx: 0,
            next_gen: 0,
            metrics: DistMetrics::default(),
            debug_killed: false,
            shut: false,
            batch_span: 0,
        };
        for _ in 0..c.cfg.workers.max(1) {
            let slot = c.spawn_slot()?;
            c.slots.push(slot);
        }
        Ok(c)
    }

    pub fn metrics(&self) -> &DistMetrics {
        &self.metrics
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    fn spawn_slot(&mut self) -> Result<WorkerSlot> {
        let gen = self.next_gen;
        self.next_gen += 1;
        let idx = self.slots.len(); // corrected by caller on respawn
        self.spawn_slot_at(idx, gen)
    }

    fn spawn_slot_at(&mut self, idx: usize, gen: u64) -> Result<WorkerSlot> {
        let (child, writer, reader): (Child, Box<dyn Write + Send>, Box<dyn std::io::Read + Send>) =
            match self.cfg.transport {
                Transport::Stdio => {
                    let mut child = Command::new(&self.bin)
                        .arg("worker")
                        .stdin(Stdio::piped())
                        .stdout(Stdio::piped())
                        .stderr(Stdio::inherit())
                        .spawn()
                        .with_context(|| format!("spawning worker {}", self.bin.display()))?;
                    let stdin = child.stdin.take().expect("piped stdin");
                    let stdout = child.stdout.take().expect("piped stdout");
                    (child, Box::new(stdin), Box::new(stdout))
                }
                Transport::Tcp => {
                    let listener = self.listener.as_ref().expect("tcp listener");
                    let addr = listener.local_addr()?.to_string();
                    let child = Command::new(&self.bin)
                        .args(["worker", "--connect", &addr])
                        .stdin(Stdio::null())
                        .stdout(Stdio::inherit())
                        .stderr(Stdio::inherit())
                        .spawn()
                        .with_context(|| format!("spawning worker {}", self.bin.display()))?;
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let stream = loop {
                        match listener.accept() {
                            Ok((s, _)) => break s,
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                if Instant::now() >= deadline {
                                    bail!("worker did not dial back within 10s");
                                }
                                std::thread::sleep(Duration::from_millis(5));
                            }
                            Err(e) => return Err(e.into()),
                        }
                    };
                    stream.set_nodelay(true).ok();
                    let reader = stream.try_clone()?;
                    (child, Box::new(stream), Box::new(reader))
                }
            };
        let tx = self.tx.clone();
        let mut reader = reader;
        std::thread::spawn(move || loop {
            match read_frame(&mut reader) {
                Ok(Some(msg)) => {
                    if tx.send(Event::Msg { worker: idx, gen, msg }).is_err() {
                        return;
                    }
                }
                Ok(None) | Err(_) => {
                    let _ = tx.send(Event::Eof { worker: idx, gen });
                    return;
                }
            }
        });
        Ok(WorkerSlot {
            child,
            writer,
            gen,
            ctxs: HashSet::new(),
            ctx_acks: HashSet::new(),
            assignment: None,
            alive: true,
        })
    }

    fn respawn(&mut self, i: usize) -> Result<()> {
        std::thread::sleep(self.cfg.retry_backoff);
        let _ = self.slots[i].child.kill();
        let _ = self.slots[i].child.wait();
        let gen = self.next_gen;
        self.next_gen += 1;
        let slot = self.spawn_slot_at(i, gen)?;
        self.slots[i] = slot;
        self.metrics.respawns += 1;
        Ok(())
    }

    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    /// Run a task batch to completion; results in task order.  On failure
    /// (retry budget exhausted, unrecoverable spawn error) in-flight work
    /// is aborted so the fleet stays usable for the next batch.
    ///
    /// Observation only: with tracing on, the batch runs inside a
    /// `dist.run_tasks` span carrying task/retry counters and the
    /// process-global wire-byte deltas of the batch window, and every
    /// worker-shipped span tree is adopted under it.
    pub fn run_tasks(&mut self, tasks: &[TaskSpec]) -> Result<Vec<Json>> {
        let mut sp = crate::obs::span("dist.run_tasks");
        sp.counter("tasks", tasks.len() as f64);
        sp.counter("workers", self.slots.len() as f64);
        self.batch_span = sp.id();
        let (out0, in0) = crate::obs::wire_totals();
        let retries0 = self.metrics.retries;
        let r = self.run_tasks_inner(tasks);
        self.batch_span = 0;
        let (out1, in1) = crate::obs::wire_totals();
        sp.counter("wire_bytes_out", (out1 - out0) as f64);
        sp.counter("wire_bytes_in", (in1 - in0) as f64);
        sp.counter("retries", (self.metrics.retries - retries0) as f64);
        if r.is_err() {
            self.abort_in_flight();
        }
        r
    }

    fn run_tasks_inner(&mut self, tasks: &[TaskSpec]) -> Result<Vec<Json>> {
        let n = tasks.len();
        let mut results: Vec<Option<Json>> = (0..n).map(|_| None).collect();
        let mut retries = vec![0usize; n];
        let mut pending: VecDeque<usize> = (0..n).collect();
        let mut done = 0usize;
        while done < n {
            self.assign_pending(tasks, &mut retries, &mut pending)?;
            match self.rx.recv_timeout(Duration::from_millis(20)) {
                Ok(ev) => {
                    self.handle_event(ev, &mut results, &mut retries, &mut pending, &mut done)?
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    bail!("worker event channel closed unexpectedly")
                }
            }
            self.check_deadlines(&mut retries, &mut pending)?;
        }
        Ok(results.into_iter().map(|r| r.expect("completed")).collect())
    }

    fn assign_pending(
        &mut self,
        tasks: &[TaskSpec],
        retries: &mut [usize],
        pending: &mut VecDeque<usize>,
    ) -> Result<()> {
        for i in 0..self.slots.len() {
            if pending.is_empty() {
                break;
            }
            if !self.slots[i].alive {
                self.respawn(i)?;
            }
            if self.slots[i].assignment.is_some() {
                continue;
            }
            let t = match pending.pop_front() {
                Some(t) => t,
                None => break,
            };
            if let Err(e) = self.issue(i, t, &tasks[t]) {
                // Treat a write failure like a crash: the reader thread's
                // EOF event (if any) finds the slot already dead.
                eprintln!("warning: worker {i} write failed ({e:#}); re-issuing task {t}");
                self.metrics.worker_crashes += 1;
                self.slots[i].alive = false;
                self.slots[i].assignment = None;
                self.requeue(t, retries, pending)?;
            }
        }
        Ok(())
    }

    /// Send (ctx?, task) frames for one assignment.  Pipelined: the worker
    /// processes frames in order, so no ack round-trip is needed between
    /// the ctx install and the task.
    fn issue(&mut self, i: usize, t: usize, spec: &TaskSpec) -> Result<()> {
        if let Some(ctx) = &spec.ctx {
            if !self.slots[i].ctxs.contains(&ctx.name) {
                let id = self.fresh_id();
                let frame = ctx_request(id, &ctx.name, ctx.body.clone());
                write_frame(&mut self.slots[i].writer, &frame)?;
                self.slots[i].ctxs.insert(ctx.name.clone());
                self.slots[i].ctx_acks.insert(id);
            }
        }
        let id = self.fresh_id();
        let mut fields = spec.fields.clone();
        // Trace-context propagation: stamp the request so the worker can
        // record — and ship back — spans under the caller's trace.  Absent
        // when tracing is off, so traced and untraced request frames only
        // differ by this observation-only field.
        if crate::obs::enabled() {
            let trace = crate::obs::current_trace()
                .unwrap_or_else(|| crate::obs::LOCAL_TRACE.to_string());
            fields.push(("trace".to_string(), Json::Str(trace)));
        }
        let frame = request(id, &spec.kind, fields);
        write_frame(&mut self.slots[i].writer, &frame)?;
        self.slots[i].assignment = Some(Assignment {
            task: t,
            id,
            deadline: Instant::now() + self.cfg.task_deadline,
        });
        Ok(())
    }

    fn requeue(
        &mut self,
        t: usize,
        retries: &mut [usize],
        pending: &mut VecDeque<usize>,
    ) -> Result<()> {
        retries[t] += 1;
        self.metrics.retries += 1;
        if retries[t] > self.cfg.max_retries {
            bail!("task {t} failed after {} retries", self.cfg.max_retries);
        }
        pending.push_front(t);
        Ok(())
    }

    fn handle_event(
        &mut self,
        ev: Event,
        results: &mut [Option<Json>],
        retries: &mut [usize],
        pending: &mut VecDeque<usize>,
        done: &mut usize,
    ) -> Result<()> {
        match ev {
            Event::Eof { worker, gen } => {
                let slot = &mut self.slots[worker];
                if slot.gen != gen || !slot.alive {
                    return Ok(()); // stale, or a death we already handled
                }
                slot.alive = false;
                self.metrics.worker_crashes += 1;
                if let Some(a) = self.slots[worker].assignment.take() {
                    self.requeue(a.task, retries, pending)?;
                }
                Ok(())
            }
            Event::Msg { worker, gen, msg } => {
                {
                    let slot = &self.slots[worker];
                    if slot.gen != gen || !slot.alive {
                        return Ok(());
                    }
                }
                let id = match msg_id(&msg) {
                    Ok(id) => id,
                    Err(_) => return Ok(()), // malformed frame: ignore
                };
                let ok = matches!(msg.opt("ok"), Some(Json::Bool(true)));
                if self.slots[worker].ctx_acks.remove(&id) {
                    if !ok {
                        // A failed ctx install poisons this worker: its
                        // pipelined task cannot succeed either.  Kill it
                        // and let the crash path recover the task.
                        let err = msg
                            .opt("error")
                            .and_then(|e| e.str().ok())
                            .unwrap_or("ctx install failed")
                            .to_string();
                        eprintln!("warning: worker {worker} rejected ctx: {err}");
                        self.metrics.worker_crashes += 1;
                        let _ = self.slots[worker].child.kill();
                        self.slots[worker].alive = false;
                        if let Some(a) = self.slots[worker].assignment.take() {
                            self.requeue(a.task, retries, pending)?;
                        }
                    }
                    return Ok(());
                }
                let matches_assignment = self.slots[worker]
                    .assignment
                    .as_ref()
                    .map(|a| a.id == id)
                    .unwrap_or(false);
                if !matches_assignment {
                    return Ok(()); // stale response from a superseded task
                }
                let a = self.slots[worker].assignment.take().expect("checked");
                if ok {
                    let result = msg.get("result")?.clone();
                    // Stitch worker spans (if the response shipped any)
                    // into the local registry under the batch span.
                    if crate::obs::enabled() {
                        if let Some(Json::Arr(raw)) = msg.opt("spans") {
                            let spans: Vec<crate::obs::Span> = raw
                                .iter()
                                .filter_map(|s| crate::obs::Span::from_json(s).ok())
                                .collect();
                            let trace = crate::obs::current_trace()
                                .unwrap_or_else(|| crate::obs::LOCAL_TRACE.to_string());
                            crate::obs::adopt(spans, &trace, self.batch_span);
                        }
                    }
                    results[a.task] = Some(result);
                    *done += 1;
                    self.metrics.tasks += 1;
                    self.maybe_debug_kill();
                    Ok(())
                } else {
                    let err = msg
                        .opt("error")
                        .and_then(|e| e.str().ok())
                        .unwrap_or("worker error")
                        .to_string();
                    if retries[a.task] >= self.cfg.max_retries {
                        bail!("task {} failed on worker {worker}: {err}", a.task);
                    }
                    self.requeue(a.task, retries, pending)
                }
            }
        }
    }

    /// Test hook: after `debug_kill_after` completed tasks, SIGKILL worker
    /// 0's process WITHOUT marking it dead — the reader thread's EOF event
    /// then drives the normal crash-recovery path.
    fn maybe_debug_kill(&mut self) {
        if self.debug_killed {
            return;
        }
        if let Some(k) = self.cfg.debug_kill_after {
            if self.metrics.tasks >= k {
                self.debug_killed = true;
                let _ = self.slots[0].child.kill();
            }
        }
    }

    fn check_deadlines(
        &mut self,
        retries: &mut [usize],
        pending: &mut VecDeque<usize>,
    ) -> Result<()> {
        let now = Instant::now();
        for i in 0..self.slots.len() {
            let expired = self.slots[i].alive
                && self.slots[i]
                    .assignment
                    .as_ref()
                    .map(|a| now >= a.deadline)
                    .unwrap_or(false);
            if !expired {
                continue;
            }
            self.metrics.deadline_expiries += 1;
            let _ = self.slots[i].child.kill();
            self.slots[i].alive = false;
            if let Some(a) = self.slots[i].assignment.take() {
                self.requeue(a.task, retries, pending)?;
            }
        }
        Ok(())
    }

    /// Kill every worker with an in-flight assignment so a failed batch
    /// cannot leave stale responses for the next one.
    fn abort_in_flight(&mut self) {
        for i in 0..self.slots.len() {
            if self.slots[i].assignment.is_some() {
                let _ = self.slots[i].child.kill();
                self.slots[i].alive = false;
                self.slots[i].assignment = None;
            }
        }
    }

    /// Graceful drain: ask every worker to exit, give them a moment, then
    /// kill stragglers.  Idempotent (also runs on Drop).
    pub fn shutdown(&mut self) {
        if self.shut {
            return;
        }
        self.shut = true;
        for i in 0..self.slots.len() {
            if self.slots[i].alive {
                let id = self.fresh_id();
                let frame = request(id, "shutdown", vec![]);
                let _ = write_frame(&mut self.slots[i].writer, &frame);
            }
        }
        let deadline = Instant::now() + Duration::from_millis(500);
        for i in 0..self.slots.len() {
            loop {
                match self.slots[i].child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(10))
                    }
                    _ => {
                        let _ = self.slots[i].child.kill();
                        let _ = self.slots[i].child.wait();
                        break;
                    }
                }
            }
            self.slots[i].alive = false;
        }
    }

    // ---- high-level distributed operations ------------------------------

    fn fresh_ctx(&mut self, prefix: &str) -> String {
        self.next_ctx += 1;
        format!("{prefix}{}", self.next_ctx)
    }

    /// Distributed Measured stage: same plan, streams, and reduction as
    /// `timing::measure_groups`, with TTFT evaluation on the fleet.
    pub fn measure(
        &mut self,
        graph: &Graph,
        device: &DeviceProfile,
        seed: u64,
        reps: usize,
        part: &Partition,
        formats: &[Format],
    ) -> Result<TimeMeasurements> {
        let nq = graph.qlayers.len();
        let plan = measure_plan(part, formats, nq)?;
        let ctx_name = self.fresh_ctx("m");
        let ctx = Arc::new(CtxSpec {
            name: ctx_name.clone(),
            body: Json::Obj(vec![
                ("type".into(), Json::Str("measure".into())),
                ("graph".into(), graph.to_json()),
                ("device".into(), device.to_json()),
                ("seed".into(), Json::Str(seed.to_string())),
                ("reps".into(), Json::Num(reps as f64)),
            ]),
        });
        let cfg_json = |cfg: &MpConfig| {
            Json::Arr(cfg.0.iter().map(|f| Json::Str(f.name().to_string())).collect())
        };
        let task = |streams: Vec<Json>, cfgs: Vec<Json>| TaskSpec {
            kind: "measure".into(),
            fields: vec![
                ("ctx".to_string(), Json::Str(ctx_name.clone())),
                ("streams".to_string(), Json::Arr(streams)),
                ("cfgs".to_string(), Json::Arr(cfgs)),
            ],
            ctx: Some(ctx.clone()),
        };
        // Task 0: the stream-0 all-BF16 baseline; then the plan in
        // MEASURE_CHUNK batches mirroring the in-process par_chunks.
        let mut tasks =
            vec![task(vec![Json::Num(0.0)], vec![cfg_json(&MpConfig::all_bf16(nq))])];
        for (ci, chunk) in plan.tasks.chunks(MEASURE_CHUNK).enumerate() {
            let start = ci * MEASURE_CHUNK;
            let streams = (0..chunk.len())
                .map(|k| Json::Num(MeasurePlan::stream(start + k) as f64))
                .collect();
            let cfgs = chunk.iter().map(|t| cfg_json(&t.cfg)).collect();
            tasks.push(task(streams, cfgs));
        }
        let results = self.run_tasks(&tasks)?;
        let ttfts_of = |r: &Json| -> Result<Vec<f64>> {
            r.get("ttfts")?.arr()?.iter().map(|x| x.f64()).collect()
        };
        let base = *ttfts_of(&results[0])?
            .first()
            .ok_or_else(|| anyhow!("baseline task returned no TTFT"))?;
        let mut ttfts = Vec::with_capacity(plan.tasks.len());
        for r in &results[1..] {
            ttfts.extend(ttfts_of(r)?);
        }
        Ok(plan.assemble(base, &ttfts))
    }

    /// [`Coordinator::measure`] packaged as the Measured stage artifact —
    /// the Engine measure-hook entry point (`Engine::set_measure_hook`).
    pub fn measure_stage(&mut self, ms: &MeasureStage<'_>) -> Result<Measured> {
        let tm = self.measure(
            ms.graph,
            ms.device,
            ms.seed,
            ms.reps,
            &ms.partitioned.partition,
            &ms.partitioned.formats,
        )?;
        Ok(Measured {
            model: ms.model.to_string(),
            formats: ms.partitioned.formats.clone(),
            seed: ms.seed,
            reps: ms.reps,
            device: ms.device.clone(),
            measurements: tm,
        })
    }

    /// Distributed parametric frontier sweep: the coordinator runs the
    /// level loop and pruning; workers run `parametric::expand_chunk` on
    /// EXPAND_CHUNK-sized state chunks.  Chunk boundaries and
    /// concatenation order match `parametric::frontier_with` exactly, so
    /// the curve is bit-identical to the in-process sweep.
    pub fn frontier_curve(&mut self, p: &Mckp) -> Result<ParametricCurve> {
        let n = p.n_groups();
        let dims = p.n_dims();
        let ctx_name = self.fresh_ctx("f");
        let ctx = Arc::new(CtxSpec {
            name: ctx_name.clone(),
            body: Json::Obj(vec![
                ("type".into(), Json::Str("frontier".into())),
                ("mckp".into(), mckp_to_json(p)),
            ]),
        });
        let mut levels = Vec::with_capacity(n + 1);
        levels.push(parametric::root_level(dims));
        let mut truncated = false;
        for j in 0..n {
            let prev = &levels[j];
            let n_chunks = prev.len().div_ceil(parametric::EXPAND_CHUNK);
            let tasks: Vec<TaskSpec> = (0..n_chunks)
                .map(|ci| {
                    let lo = ci * parametric::EXPAND_CHUNK;
                    let hi = (lo + parametric::EXPAND_CHUNK).min(prev.len());
                    TaskSpec {
                        kind: "expand".into(),
                        fields: vec![
                            ("ctx".to_string(), Json::Str(ctx_name.clone())),
                            ("j".to_string(), Json::Num(j as f64)),
                            ("start".to_string(), Json::Num(lo as f64)),
                            ("nodes".to_string(), level_to_json(prev, lo, hi)),
                        ],
                        ctx: Some(ctx.clone()),
                    }
                })
                .collect();
            let results = self.run_tasks(&tasks)?;
            let mut cands = parametric::LevelSoa::new(dims);
            for r in &results {
                let mut frag = level_from_json(r)?;
                cands.append(&mut frag);
            }
            let (kept, thinned) = parametric::prune_level(p, &cands);
            truncated |= thinned;
            levels.push(kept);
        }
        Ok(parametric::finish(n, &levels, truncated, None))
    }

    /// Distributed demo calibration: the worker recomputes the pure
    /// `demo_calibration(n_qlayers, seed)` — one task, byte-identical to
    /// the in-process injection.
    pub fn calibrate_demo(&mut self, n_qlayers: usize, seed: u64) -> Result<Calibration> {
        let tasks = vec![TaskSpec {
            kind: "calibrate_demo".into(),
            fields: vec![
                ("n_qlayers".to_string(), Json::Num(n_qlayers as f64)),
                ("seed".to_string(), Json::Str(seed.to_string())),
            ],
            ctx: None,
        }];
        let r = &self.run_tasks(&tasks)?[0];
        Ok(Calibration {
            s: r.get("s")?.arr()?.iter().map(|x| x.f64()).collect::<Result<Vec<f64>>>()?,
            eg2: r.get("eg2")?.f64()?,
            g_mean: r.get("g_mean")?.f64()?,
            n_samples: r.get("n_samples")?.usize()?,
        })
    }

    /// Liveness probe: one ping round-trip through the scheduler.
    pub fn ping(&mut self) -> Result<()> {
        let tasks =
            vec![TaskSpec { kind: "ping".into(), fields: vec![], ctx: None }];
        let r = &self.run_tasks(&tasks)?[0];
        if r.str()? != "pong" {
            bail!("unexpected ping reply");
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}
