//! The `ampq worker` process body: a single-threaded request loop over the
//! length-prefixed JSON protocol.
//!
//! A worker is deliberately dumb: it installs contexts (a model + device to
//! measure, or an MCKP instance to expand), executes the pure task kinds
//! the coordinator sends, and replies in arrival order.  All determinism
//! lives in the shared library functions it calls — `TtftSource::measure`
//! per `(config, stream)`, `parametric::expand_chunk` per state chunk,
//! `demo_calibration` per `(n_qlayers, seed)` — so WHICH worker runs a
//! task (or how often it is retried elsewhere) cannot change a bit of the
//! result.
//!
//! Task kinds: `ping`, `ctx`, `measure`, `expand`, `calibrate_demo`,
//! `shutdown`, plus the test-only hostile-fleet hooks `sleep` and `exit`.

use super::protocol::{
    err_response, level_from_json, level_to_json, mckp_from_json, msg_id, ok_response,
    read_frame, request, write_frame,
};
use crate::backend::DeviceProfile;
use crate::gaudisim::MpConfig;
use crate::graph::Graph;
use crate::numerics::Format;
use crate::plan::demo::demo_calibration;
use crate::solver::parametric;
use crate::solver::Mckp;
use crate::timing::{SimTtft, TtftSource};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;

/// One installed context.
enum Ctx {
    /// A model + device + measurement protocol to time configurations on.
    Measure { graph: Graph, device: DeviceProfile, seed: u64, reps: usize },
    /// An MCKP instance (plus its precomputed suffix lower bounds) to
    /// expand DP state chunks against.
    Frontier { problem: Mckp, suffix_min: Vec<Vec<f64>> },
}

/// Serve requests until a `shutdown` frame or clean EOF.  The stdio entry
/// point of `ampq worker`.
pub fn serve(mut reader: impl Read, mut writer: impl Write) -> Result<()> {
    let mut ctxs: HashMap<String, Ctx> = HashMap::new();
    loop {
        let msg = match read_frame(&mut reader)? {
            Some(m) => m,
            None => return Ok(()), // coordinator closed the pipe: drain
        };
        let id = msg_id(&msg)?;
        let kind = msg.get("kind")?.str()?.to_string();
        if kind == "shutdown" {
            let _ = write_frame(&mut writer, &ok_response(id, Json::Null));
            return Ok(());
        }
        let reply = match traced_handle(&kind, &msg, &mut ctxs) {
            Ok((result, spans)) => {
                let mut resp = ok_response(id, result);
                // Ship the task's spans back for the coordinator to adopt.
                // A strictly additive, observation-only field: the `result`
                // the coordinator reduces is untouched.
                if !spans.is_empty() {
                    if let Json::Obj(kv) = &mut resp {
                        kv.push((
                            "spans".to_string(),
                            Json::Arr(spans.iter().map(|s| s.to_json()).collect()),
                        ));
                    }
                }
                resp
            }
            Err(e) => err_response(id, &format!("{e:#}")),
        };
        write_frame(&mut writer, &reply)?;
    }
}

/// Run one task, recording spans when the request carries a (valid)
/// `trace` field — the coordinator stamps one whenever tracing is on.
/// An invalid trace id is ignored, never an error: tracing must not be
/// able to fail a task.
fn traced_handle(
    kind: &str,
    msg: &Json,
    ctxs: &mut HashMap<String, Ctx>,
) -> Result<(Json, Vec<crate::obs::Span>)> {
    let trace = msg
        .opt("trace")
        .and_then(|t| t.str().ok())
        .filter(|t| crate::obs::validate_trace_id(t).is_ok())
        .map(str::to_string);
    let Some(trace) = trace else {
        return handle(kind, msg, ctxs).map(|r| (r, Vec::new()));
    };
    let (result, spans) = crate::obs::with_trace(&trace, || {
        crate::obs::capture(|| {
            let mut sp = crate::obs::span(&format!("worker.{kind}"));
            let res = handle(kind, msg, ctxs);
            sp.counter("ok", if res.is_ok() { 1.0 } else { 0.0 });
            drop(sp);
            res
        })
    });
    result.map(|r| (r, spans))
}

/// `ampq worker --connect ADDR`: same loop over a TCP socket the worker
/// dials back to the coordinator.
pub fn serve_tcp(addr: &str) -> Result<()> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let reader = stream.try_clone()?;
    serve(reader, stream)
}

fn parse_formats(j: &Json) -> Result<Vec<Format>> {
    j.arr()?
        .iter()
        .map(|x| {
            let name = x.str()?;
            Format::from_name(name).ok_or_else(|| anyhow!("unknown format '{name}'"))
        })
        .collect()
}

fn handle(kind: &str, msg: &Json, ctxs: &mut HashMap<String, Ctx>) -> Result<Json> {
    match kind {
        "ping" => Ok(Json::Str("pong".into())),

        "ctx" => {
            let name = msg.get("ctx")?.str()?.to_string();
            let body = msg.get("body")?;
            let ctx = match body.get("type")?.str()? {
                "measure" => Ctx::Measure {
                    graph: Graph::from_json(body.get("graph")?)?,
                    device: DeviceProfile::from_json(body.get("device")?)?,
                    seed: body.get("seed")?.str()?.parse::<u64>()?,
                    reps: body.get("reps")?.usize()?,
                },
                "frontier" => {
                    let problem = mckp_from_json(body.get("mckp")?)?;
                    // Recomputed here, not shipped: suffix_mins is a pure
                    // function of the instance, so both sides agree.
                    let suffix_min = parametric::suffix_mins(&problem);
                    Ctx::Frontier { problem, suffix_min }
                }
                t => bail!("unknown ctx type '{t}'"),
            };
            ctxs.insert(name, ctx);
            Ok(Json::Null)
        }

        "measure" => {
            let name = msg.get("ctx")?.str()?;
            let (graph, device, seed, reps) = match ctxs.get(name) {
                Some(Ctx::Measure { graph, device, seed, reps }) => {
                    (graph, device, *seed, *reps)
                }
                Some(_) => bail!("ctx '{name}' is not a measure context"),
                None => bail!("unknown ctx '{name}'"),
            };
            let src = SimTtft::for_device(graph, device, seed, reps);
            let streams = msg.get("streams")?.arr()?;
            let cfgs = msg.get("cfgs")?.arr()?;
            if streams.len() != cfgs.len() {
                bail!("measure batch: {} streams vs {} configs", streams.len(), cfgs.len());
            }
            let nq = src.n_qlayers();
            let mut ttfts = Vec::with_capacity(streams.len());
            for (s, c) in streams.iter().zip(cfgs) {
                let formats = parse_formats(c)?;
                if formats.len() != nq {
                    bail!("config covers {} layers, model has {nq}", formats.len());
                }
                let stream = s.f64()? as u64;
                ttfts.push(Json::Num(src.measure(&MpConfig(formats), stream)?));
            }
            Ok(Json::Obj(vec![("ttfts".into(), Json::Arr(ttfts))]))
        }

        "expand" => {
            let name = msg.get("ctx")?.str()?;
            let (problem, suffix_min) = match ctxs.get(name) {
                Some(Ctx::Frontier { problem, suffix_min }) => (problem, suffix_min),
                Some(_) => bail!("ctx '{name}' is not a frontier context"),
                None => bail!("unknown ctx '{name}'"),
            };
            let j = msg.get("j")?.usize()?;
            let start = msg.get("start")?.usize()?;
            if j >= problem.n_groups() {
                bail!("expand level {j} out of range ({} groups)", problem.n_groups());
            }
            let states = level_from_json(msg.get("nodes")?)?;
            if states.dims() != problem.n_dims() {
                bail!(
                    "state carries {} cost dims, instance has {}",
                    states.dims(),
                    problem.n_dims()
                );
            }
            let out = parametric::expand_chunk(problem, suffix_min, j, start, &states);
            Ok(level_to_json(&out, 0, out.len()))
        }

        "calibrate_demo" => {
            let n_qlayers = msg.get("n_qlayers")?.usize()?;
            let seed = msg.get("seed")?.str()?.parse::<u64>()?;
            let c = demo_calibration(n_qlayers, seed);
            Ok(Json::Obj(vec![
                ("s".into(), Json::Arr(c.s.iter().map(|&x| Json::Num(x)).collect())),
                ("eg2".into(), Json::Num(c.eg2)),
                ("g_mean".into(), Json::Num(c.g_mean)),
                ("n_samples".into(), Json::Num(c.n_samples as f64)),
            ]))
        }

        // Hostile-fleet test hooks: a worker that hangs, and one that dies.
        "sleep" => {
            let ms = msg.get("ms")?.usize()?;
            std::thread::sleep(std::time::Duration::from_millis(ms as u64));
            Ok(Json::Null)
        }
        "exit" => {
            let code = msg.get("code")?.i64()? as i32;
            std::process::exit(code);
        }

        k => bail!("unknown task kind '{k}'"),
    }
}

/// Build a `ctx` install request (coordinator side; lives here so the two
/// ends of the protocol are defined next to each other).
pub fn ctx_request(id: u64, name: &str, body: Json) -> Json {
    request(
        id,
        "ctx",
        vec![
            ("ctx".to_string(), Json::Str(name.to_string())),
            ("body".to_string(), body),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::demo::demo_model;
    use crate::solver::problem::gen::random;
    use crate::util::Rng;

    /// Run one in-memory request/response exchange against the serve loop.
    fn roundtrip(requests: Vec<Json>) -> Vec<Json> {
        let mut input: Vec<u8> = Vec::new();
        for r in &requests {
            write_frame(&mut input, r).unwrap();
        }
        let mut output: Vec<u8> = Vec::new();
        serve(std::io::Cursor::new(input), &mut output).unwrap();
        let mut cursor = std::io::Cursor::new(output);
        let mut replies = Vec::new();
        while let Some(j) = read_frame(&mut cursor).unwrap() {
            replies.push(j);
        }
        replies
    }

    #[test]
    fn ping_and_unknown_kind() {
        let replies = roundtrip(vec![
            request(1, "ping", vec![]),
            request(2, "no_such_kind", vec![]),
        ]);
        assert_eq!(replies.len(), 2);
        assert!(matches!(replies[0].get("ok").unwrap(), Json::Bool(true)));
        assert_eq!(replies[0].get("result").unwrap().str().unwrap(), "pong");
        assert!(matches!(replies[1].get("ok").unwrap(), Json::Bool(false)));
        assert!(replies[1].get("error").unwrap().str().unwrap().contains("no_such_kind"));
    }

    #[test]
    fn measure_tasks_match_local_source_bitwise() {
        let (graph, _, _) = demo_model(1, 5);
        let device = DeviceProfile::gaudi2();
        let (seed, reps) = (0x71_4e_33u64, 5usize);
        let nq = graph.qlayers.len();

        let body = Json::Obj(vec![
            ("type".into(), Json::Str("measure".into())),
            ("graph".into(), graph.to_json()),
            ("device".into(), device.to_json()),
            ("seed".into(), Json::Str(seed.to_string())),
            ("reps".into(), Json::Num(reps as f64)),
        ]);
        let mut cfg = MpConfig::all_bf16(nq);
        cfg.set(0, Format::Fp8E4m3);
        let cfg_json = Json::Arr(
            cfg.0.iter().map(|f| Json::Str(f.name().to_string())).collect(),
        );
        let replies = roundtrip(vec![
            ctx_request(1, "m0", body),
            request(
                2,
                "measure",
                vec![
                    ("ctx".to_string(), Json::Str("m0".into())),
                    ("streams".to_string(), Json::Arr(vec![Json::Num(0.0), Json::Num(7.0)])),
                    ("cfgs".to_string(), Json::Arr(vec![cfg_json.clone(), cfg_json])),
                ],
            ),
        ]);
        assert!(matches!(replies[1].get("ok").unwrap(), Json::Bool(true)));
        let ttfts = replies[1].get("result").unwrap().get("ttfts").unwrap().arr().unwrap();
        let src = SimTtft::for_device(&graph, &device, seed, reps);
        let want0 = src.measure(&cfg, 0).unwrap();
        let want7 = src.measure(&cfg, 7).unwrap();
        assert_eq!(ttfts[0].f64().unwrap().to_bits(), want0.to_bits());
        assert_eq!(ttfts[1].f64().unwrap().to_bits(), want7.to_bits());
    }

    #[test]
    fn expand_tasks_match_local_expansion_bitwise() {
        let mut rng = Rng::new(0xFA57);
        let p = random(&mut rng, 4, 4);
        let suffix_min = parametric::suffix_mins(&p);
        let root = parametric::root_level(p.n_dims());
        let want = parametric::expand_chunk(&p, &suffix_min, 0, 0, &root);

        let body = Json::Obj(vec![
            ("type".into(), Json::Str("frontier".into())),
            ("mckp".into(), super::super::protocol::mckp_to_json(&p)),
        ]);
        let replies = roundtrip(vec![
            ctx_request(1, "f0", body),
            request(
                2,
                "expand",
                vec![
                    ("ctx".to_string(), Json::Str("f0".into())),
                    ("j".to_string(), Json::Num(0.0)),
                    ("start".to_string(), Json::Num(0.0)),
                    ("nodes".to_string(), level_to_json(&root, 0, root.len())),
                ],
            ),
        ]);
        assert!(matches!(replies[1].get("ok").unwrap(), Json::Bool(true)));
        let got = level_from_json(replies[1].get("result").unwrap()).unwrap();
        assert_eq!(got.len(), want.len());
        for i in 0..want.len() {
            assert_eq!(want.gain(i).to_bits(), got.gain(i).to_bits());
            for (x, y) in want.costs(i).iter().zip(got.costs(i)) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            assert_eq!((want.parent(i), want.choice(i)), (got.parent(i), got.choice(i)));
        }
    }

    #[test]
    fn calibrate_demo_matches_local() {
        let (_, qlayers, want) = demo_model(2, 99);
        let replies = roundtrip(vec![request(
            1,
            "calibrate_demo",
            vec![
                ("n_qlayers".to_string(), Json::Num(qlayers.len() as f64)),
                ("seed".to_string(), Json::Str("99".into())),
            ],
        )]);
        let r = replies[0].get("result").unwrap();
        let s: Vec<f64> =
            r.get("s").unwrap().arr().unwrap().iter().map(|x| x.f64().unwrap()).collect();
        assert_eq!(s.len(), want.s.len());
        for (a, b) in s.iter().zip(&want.s) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(r.get("eg2").unwrap().f64().unwrap().to_bits(), want.eg2.to_bits());
    }

    #[test]
    fn shutdown_stops_the_loop_mid_stream() {
        let replies = roundtrip(vec![
            request(1, "shutdown", vec![]),
            request(2, "ping", vec![]), // never reached
        ]);
        assert_eq!(replies.len(), 1);
    }

    #[test]
    fn traced_requests_ship_spans_and_untouched_results() {
        let replies = roundtrip(vec![
            request(1, "ping", vec![("trace".to_string(), Json::Str("t-abc".into()))]),
            request(2, "ping", vec![]),
            request(3, "ping", vec![("trace".to_string(), Json::Str("bad id".into()))]),
        ]);
        // Traced: spans ride along, result is byte-identical "pong".
        let spans = replies[0].opt("spans").expect("spans on traced reply").arr().unwrap();
        assert!(!spans.is_empty());
        let sp = crate::obs::Span::from_json(&spans[0]).unwrap();
        assert_eq!(sp.trace, "t-abc");
        assert_eq!(sp.name, "worker.ping");
        assert_eq!(replies[0].get("result").unwrap().str().unwrap(), "pong");
        // Untraced and invalid-trace requests: no spans field at all.
        assert!(replies[1].opt("spans").is_none());
        assert!(replies[2].opt("spans").is_none());
        assert_eq!(replies[2].get("result").unwrap().str().unwrap(), "pong");
    }

    #[test]
    fn tasks_against_missing_ctx_error_cleanly() {
        let replies = roundtrip(vec![request(
            1,
            "measure",
            vec![
                ("ctx".to_string(), Json::Str("nope".into())),
                ("streams".to_string(), Json::Arr(vec![])),
                ("cfgs".to_string(), Json::Arr(vec![])),
            ],
        )]);
        assert!(matches!(replies[0].get("ok").unwrap(), Json::Bool(false)));
        assert!(replies[0].get("error").unwrap().str().unwrap().contains("unknown ctx"));
    }
}
