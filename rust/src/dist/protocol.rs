//! Length-prefixed JSON wire protocol between the planning coordinator
//! and `ampq worker` processes.
//!
//! Framing: a 4-byte big-endian length followed by that many bytes of
//! UTF-8 JSON.  Every request carries `{id, kind, ...}`; every response is
//! `{id, ok, result}` or `{id, ok: false, error}`.  Floats cross the wire
//! through `util::Json`'s shortest-round-trip `Display`, which Rust's
//! `str::parse::<f64>` reads back bit-identical — the reason remotely
//! computed DP states and TTFTs can be byte-equal to in-process ones.
//! u64 values that may exceed 2^53 (seeds) travel as strings.

use crate::solver::parametric::LevelSoa;
use crate::solver::{CostDim, Mckp};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

/// Upper bound on one frame; a length prefix beyond this is treated as a
/// corrupt stream, not an allocation request.
pub const MAX_FRAME: usize = 256 << 20;

/// Write one `length || payload` frame.
pub fn write_frame(w: &mut impl Write, j: &Json) -> Result<()> {
    let payload = j.to_string();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME {
        bail!("frame of {} bytes exceeds MAX_FRAME", bytes.len());
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    // Observation only: wire-byte introspection (spans, `ampq trace`).
    crate::obs::wire_count_out(4 + bytes.len());
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    // EOF before the first length byte is a clean close; mid-prefix is not.
    let mut filled = 0usize;
    while filled < 4 {
        let n = r.read(&mut len_buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(None);
            }
            bail!("stream closed mid frame header ({filled}/4 bytes)");
        }
        filled += n;
    }
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("frame length {len} exceeds MAX_FRAME (corrupt stream?)");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&payload)?;
    // Observation only: wire-byte introspection (spans, `ampq trace`).
    crate::obs::wire_count_in(4 + len);
    Ok(Some(Json::parse(text)?))
}

/// `{id, kind, ...fields}` request frame.
pub fn request(id: u64, kind: &str, fields: Vec<(String, Json)>) -> Json {
    let mut kv = vec![
        ("id".to_string(), Json::Str(id.to_string())),
        ("kind".to_string(), Json::Str(kind.to_string())),
    ];
    kv.extend(fields);
    Json::Obj(kv)
}

pub fn ok_response(id: u64, result: Json) -> Json {
    Json::Obj(vec![
        ("id".into(), Json::Str(id.to_string())),
        ("ok".into(), Json::Bool(true)),
        ("result".into(), result),
    ])
}

pub fn err_response(id: u64, msg: &str) -> Json {
    Json::Obj(vec![
        ("id".into(), Json::Str(id.to_string())),
        ("ok".into(), Json::Bool(false)),
        ("error".into(), Json::Str(msg.to_string())),
    ])
}

/// Message id of a request or response frame.
pub fn msg_id(j: &Json) -> Result<u64> {
    Ok(j.get("id")?.str()?.parse::<u64>()?)
}

// ---- DP state (de)serialization -----------------------------------------
//
// States travel as flat arrays — node-major costs — instead of one object
// per node: a level can hold tens of thousands of states and the flat form
// keeps frames small and parsing linear.  Since the solver itself stores
// levels in structure-of-arrays columns ([`LevelSoa`]), the encoder reads
// the columns straight through — the wire schema is the memory layout.

/// Serialize rows `lo..hi` of a DP level:
/// `{dims, g: [..], c: [..], p: [..], ch: [..]}` with `c` node-major
/// (`c[i*dims + d]`).  `expand_chunk` never reads its inputs'
/// parent/choice, but they are shipped anyway so the encoding is its own
/// inverse (and so worker->coordinator candidates carry them).
pub fn level_to_json(level: &LevelSoa, lo: usize, hi: usize) -> Json {
    let dims = level.dims();
    let mut g = Vec::with_capacity(hi - lo);
    let mut c = Vec::with_capacity((hi - lo) * dims);
    let mut p = Vec::with_capacity(hi - lo);
    let mut ch = Vec::with_capacity(hi - lo);
    for i in lo..hi {
        g.push(Json::Num(level.gain(i)));
        for &x in level.costs(i) {
            c.push(Json::Num(x));
        }
        // u32 fits f64 exactly (including the u32::MAX root sentinel).
        p.push(Json::Num(level.parent(i) as f64));
        ch.push(Json::Num(level.choice(i) as f64));
    }
    Json::Obj(vec![
        ("dims".into(), Json::Num(dims as f64)),
        ("g".into(), Json::Arr(g)),
        ("c".into(), Json::Arr(c)),
        ("p".into(), Json::Arr(p)),
        ("ch".into(), Json::Arr(ch)),
    ])
}

pub fn level_from_json(j: &Json) -> Result<LevelSoa> {
    let dims = j.get("dims")?.usize()?;
    if dims == 0 {
        bail!("node batch needs at least one cost dimension");
    }
    let g = j.get("g")?.arr()?;
    let c = j.get("c")?.arr()?;
    let p = j.get("p")?.arr()?;
    let ch = j.get("ch")?.arr()?;
    if c.len() != g.len() * dims || p.len() != g.len() || ch.len() != g.len() {
        bail!(
            "inconsistent node batch shape: {} gains, {} costs, {} parents, {} choices (dims {dims})",
            g.len(),
            c.len(),
            p.len(),
            ch.len()
        );
    }
    let mut level = LevelSoa::new(dims);
    level.reserve(g.len());
    let mut costs = vec![0.0f64; dims];
    for i in 0..g.len() {
        for (d, slot) in costs.iter_mut().enumerate() {
            *slot = c[i * dims + d].f64()?;
        }
        level.push(g[i].f64()?, &costs, p[i].f64()? as u32, ch[i].f64()? as u32);
    }
    Ok(level)
}

// ---- MCKP instance (de)serialization ------------------------------------

fn table_to_json(table: &[Vec<f64>]) -> Json {
    Json::Arr(
        table
            .iter()
            .map(|row| Json::Arr(row.iter().map(|&x| Json::Num(x)).collect()))
            .collect(),
    )
}

fn table_from_json(j: &Json) -> Result<Vec<Vec<f64>>> {
    j.arr()?
        .iter()
        .map(|row| row.arr()?.iter().map(|x| x.f64()).collect())
        .collect()
}

/// Serialize a full MCKP instance (the frontier ctx payload).
pub fn mckp_to_json(p: &Mckp) -> Json {
    let costs = p
        .costs
        .iter()
        .map(|dim| {
            Json::Obj(vec![
                ("label".into(), Json::Str(dim.label.clone())),
                ("table".into(), table_to_json(&dim.table)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("gains".into(), table_to_json(&p.gains)),
        ("costs".into(), Json::Arr(costs)),
        (
            "budgets".into(),
            Json::Arr(p.budgets.iter().map(|&b| Json::Num(b)).collect()),
        ),
    ])
}

pub fn mckp_from_json(j: &Json) -> Result<Mckp> {
    let gains = table_from_json(j.get("gains")?)?;
    let costs = j
        .get("costs")?
        .arr()?
        .iter()
        .map(|dim| {
            Ok(CostDim::new(
                dim.get("label")?.str()?.to_string(),
                table_from_json(dim.get("table")?)?,
            ))
        })
        .collect::<Result<Vec<CostDim>>>()?;
    let budgets = j
        .get("budgets")?
        .arr()?
        .iter()
        .map(|x| x.f64())
        .collect::<Result<Vec<f64>>>()?;
    Mckp::multi(gains, costs, budgets)
        .map_err(|e| anyhow!("invalid MCKP instance on the wire: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::problem::gen::random_multi;
    use crate::util::Rng;

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let msgs = vec![
            request(1, "ping", vec![]),
            ok_response(1, Json::Str("pong".into())),
            err_response(2, "boom"),
        ];
        let mut buf: Vec<u8> = Vec::new();
        for m in &msgs {
            write_frame(&mut buf, m).unwrap();
        }
        let mut cursor = std::io::Cursor::new(buf);
        for m in &msgs {
            let back = read_frame(&mut cursor).unwrap().expect("frame present");
            assert_eq!(back.to_string(), m.to_string());
        }
        assert!(read_frame(&mut cursor).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn truncated_frames_are_errors_not_eof() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &request(7, "ping", vec![])).unwrap();
        // Chop the payload short: the reader must error, not hang or
        // silently succeed.
        buf.truncate(buf.len() - 2);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
        // And a lone partial length prefix is also an error.
        let mut cursor = std::io::Cursor::new(vec![0u8, 0u8]);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let mut buf = (u32::MAX).to_be_bytes().to_vec();
        buf.extend_from_slice(b"junk");
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn levels_roundtrip_bitwise() {
        let mut level = LevelSoa::new(2);
        level.push(0.1 + 0.2, &[1.0 / 3.0, -0.0], u32::MAX, 0);
        level.push(f64::MIN_POSITIVE, &[1e300, 2.5e-17], 41, 3);
        let j = level_to_json(&level, 0, level.len());
        let back = level_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.len(), level.len());
        assert_eq!(back.dims(), level.dims());
        for i in 0..level.len() {
            assert_eq!(level.gain(i).to_bits(), back.gain(i).to_bits());
            assert_eq!(level.parent(i), back.parent(i));
            assert_eq!(level.choice(i), back.choice(i));
            for (x, y) in level.costs(i).iter().zip(back.costs(i)) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // Sub-range serialization ships exactly the requested rows.
        let tail = level_from_json(&level_to_json(&level, 1, 2)).unwrap();
        assert_eq!(tail.len(), 1);
        assert_eq!(tail.gain(0).to_bits(), level.gain(1).to_bits());
        assert_eq!(tail.parent(0), 41);
    }

    #[test]
    fn mckp_roundtrips_through_text() {
        let mut rng = Rng::new(0xD157);
        for _ in 0..20 {
            let p = random_multi(&mut rng, 5, 4, 2);
            let text = mckp_to_json(&p).to_string();
            let back = mckp_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.gains, p.gains);
            assert_eq!(back.budgets, p.budgets);
            assert_eq!(back.costs.len(), p.costs.len());
            for (a, b) in p.costs.iter().zip(&back.costs) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.table, b.table);
            }
        }
    }

    #[test]
    fn malformed_node_batches_are_rejected() {
        let j = Json::parse(r#"{"dims": 2, "g": [1.0], "c": [1.0], "p": [0], "ch": [0]}"#).unwrap();
        assert!(level_from_json(&j).is_err(), "cost array shorter than dims * nodes");
        let j = Json::parse(r#"{"dims": 0, "g": [], "c": [], "p": [], "ch": []}"#).unwrap();
        assert!(level_from_json(&j).is_err(), "zero dims");
    }
}
