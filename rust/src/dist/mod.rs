//! Distributed planning: a coordinator plus an `ampq worker` process
//! fleet that shards calibration, per-(group, config) TTFT measurement,
//! and parametric frontier DP expansion — deterministically.
//!
//! Layering (see DESIGN.md §4f):
//!
//! * [`protocol`] — the length-prefixed JSON wire format (framing,
//!   request/response envelopes, bit-exact DP-state and MCKP encodings).
//! * [`worker`] — the worker side: a stateless request loop over
//!   stdin/stdout pipes or a dialed-back TCP socket, evaluating pure
//!   tasks against installed contexts.
//! * [`coordinator`] — the supervision core: spawns the fleet, schedules
//!   tasks with per-assignment deadlines, re-issues work after crashes or
//!   hangs under a bounded retry budget, and reduces results in task
//!   order so any worker count W yields output byte-identical to the
//!   in-process path at `--threads 1`.
//! * [`fleet`] — `ampq fleet`: the full models × devices matrix over one
//!   shared worker pool, with a stdout-only progress/metrics summary so
//!   output trees stay `diff -r`-comparable across worker counts.
//!
//! The determinism argument, wire protocol reference, and supervision
//! state machine are documented in DESIGN.md §4f and exercised end-to-end
//! in `tests/dist.rs` (1-vs-N byte equality, worker-kill recovery,
//! deadline/retry accounting).

pub mod coordinator;
pub mod fleet;
pub mod protocol;
pub mod worker;

pub use coordinator::{
    resolve_worker_bin, Coordinator, CtxSpec, DistConfig, DistMetrics, TaskSpec, Transport,
};
pub use fleet::{model_seed, render_summary, run_fleet, FleetCell, FleetConfig, FleetReport};
