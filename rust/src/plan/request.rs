//! Multi-constraint planning queries.
//!
//! [`PlanRequest`] is the builder the 0.3 query surface resolves through
//! `Planner::solve`, replacing the scalar `plan(objective, strategy, tau,
//! seed)` signature: a request names the objective to maximize plus any
//! combination of constraints —
//!
//! ```no_run
//! use ampq::coordinator::Strategy;
//! use ampq::metrics::Objective;
//! use ampq::plan::PlanRequest;
//!
//! let req = PlanRequest::new(Objective::EmpiricalTime)
//!     .with_loss_budget(0.004)        // loss-NRMSE <= tau
//!     .with_memory_cap(1.5e6)         // AND stored weight bytes <= cap
//!     .with_strategy(Strategy::Ip);
//! ```
//!
//! Requests serialize to/from JSON (the `ampq serve --requests` batch
//! format); unknown keys are ignored so serve entries can carry extra
//! routing fields like `model`.

use crate::coordinator::Strategy;
use crate::metrics::Objective;
use crate::util::Json;
use anyhow::{anyhow, bail, Result};

/// Shared domain check for user-supplied budget-like scalars (taus,
/// memory caps): finite and non-negative.  "nan"/"-1" parse as valid
/// f64s, and tau enters the IP budget SQUARED (a negative value would
/// silently plan like its absolute value), so every boundary — CLI flags,
/// request JSON, `Planner::solve`, serve frontier lookups — rejects
/// through this one predicate.
pub fn check_budget(name: &str, value: f64) -> Result<()> {
    if !value.is_finite() || value < 0.0 {
        bail!("{name} must be finite and non-negative (got {value})");
    }
    Ok(())
}

/// One planning query: maximize `objective` under the requested constraints.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanRequest {
    pub objective: Objective,
    pub strategy: Strategy,
    /// Loss-NRMSE threshold tau (the paper's constraint).  None plans at
    /// the objective's tau_max — the loss constraint becomes vacuous and
    /// only the remaining constraints bind.
    pub tau: Option<f64>,
    /// Cap on total stored weight bytes (linear-layer params at their
    /// chosen format widths).
    pub memory_cap: Option<f64>,
    /// RNG seed for seeded strategies (Random).
    pub seed: u64,
    /// Target device profile name (see `backend::Registry`).  None plans
    /// on the serving default; `PlanService` routes named devices to the
    /// matching per-device planner.
    pub device: Option<String>,
}

impl PlanRequest {
    /// A request with paper defaults: IP strategy, no constraints, seed 0,
    /// default device.
    pub fn new(objective: Objective) -> PlanRequest {
        PlanRequest {
            objective,
            strategy: Strategy::Ip,
            tau: None,
            memory_cap: None,
            seed: 0,
            device: None,
        }
    }

    /// Constrain predicted loss NRMSE to `tau` (budget tau^2 E[g^2]).
    pub fn with_loss_budget(mut self, tau: f64) -> PlanRequest {
        self.tau = Some(tau);
        self
    }

    /// Additionally cap total stored weight bytes.
    pub fn with_memory_cap(mut self, bytes: f64) -> PlanRequest {
        self.memory_cap = Some(bytes);
        self
    }

    pub fn with_strategy(mut self, strategy: Strategy) -> PlanRequest {
        self.strategy = strategy;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> PlanRequest {
        self.seed = seed;
        self
    }

    /// Plan for a named device profile (routes to the per-device planner).
    pub fn with_device(mut self, device: impl Into<String>) -> PlanRequest {
        self.device = Some(device.into());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut kv = vec![
            ("objective".to_string(), Json::Str(self.objective.key().into())),
            ("strategy".to_string(), Json::Str(self.strategy.key().into())),
        ];
        if let Some(tau) = self.tau {
            kv.push(("tau".to_string(), Json::Num(tau)));
        }
        if let Some(cap) = self.memory_cap {
            kv.push(("memory_cap".to_string(), Json::Num(cap)));
        }
        if let Some(device) = &self.device {
            kv.push(("device".to_string(), Json::Str(device.clone())));
        }
        // u64 seeds go through a string so values >= 2^53 round-trip exactly.
        kv.push(("seed".to_string(), Json::Str(self.seed.to_string())));
        Json::Obj(kv)
    }

    /// Parse a request object; unknown keys (e.g. `model` in serve batch
    /// entries) are ignored.  `seed` may be a number or a string.
    pub fn from_json(j: &Json) -> Result<PlanRequest> {
        let okey = j.get("objective")?.str()?;
        let objective =
            Objective::from_key(okey).ok_or_else(|| anyhow!("unknown objective '{okey}'"))?;
        let strategy = match j.opt("strategy") {
            None => Strategy::Ip,
            Some(s) => {
                let k = s.str()?;
                Strategy::from_key(k).ok_or_else(|| anyhow!("unknown strategy '{k}'"))?
            }
        };
        let tau = match j.opt("tau") {
            None => None,
            Some(x) => Some(x.f64()?),
        };
        if let Some(t) = tau {
            check_budget("tau", t)?;
        }
        let memory_cap = match j.opt("memory_cap") {
            None => None,
            Some(x) => Some(x.f64()?),
        };
        if let Some(c) = memory_cap {
            check_budget("memory_cap", c)?;
        }
        let seed = match j.opt("seed") {
            None => 0,
            Some(Json::Str(s)) => s.parse::<u64>()?,
            Some(x) => {
                let v = x.f64()?;
                if v < 0.0 {
                    bail!("seed must be non-negative");
                }
                v as u64
            }
        };
        let device = match j.opt("device") {
            None => None,
            Some(x) => Some(x.str()?.to_string()),
        };
        Ok(PlanRequest { objective, strategy, tau, memory_cap, seed, device })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates() {
        let r = PlanRequest::new(Objective::Memory)
            .with_loss_budget(0.002)
            .with_memory_cap(4096.0)
            .with_strategy(Strategy::Prefix)
            .with_seed(9);
        assert_eq!(r.objective, Objective::Memory);
        assert_eq!(r.strategy, Strategy::Prefix);
        assert_eq!(r.tau, Some(0.002));
        assert_eq!(r.memory_cap, Some(4096.0));
        assert_eq!(r.seed, 9);
    }

    #[test]
    fn json_roundtrip_exact() {
        let full = PlanRequest::new(Objective::EmpiricalTime)
            .with_loss_budget(0.004)
            .with_memory_cap(1.5e6)
            .with_device("gaudi3")
            .with_seed(u64::MAX - 3);
        let sparse = PlanRequest::new(Objective::TheoreticalTime);
        for r in [full, sparse] {
            let text = r.to_json().to_string();
            let back = PlanRequest::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn from_json_defaults_and_validation() {
        let j = Json::parse(r#"{"objective":"et"}"#).unwrap();
        let r = PlanRequest::from_json(&j).unwrap();
        assert_eq!(r.strategy, Strategy::Ip);
        assert_eq!(r.tau, None);
        assert_eq!(r.seed, 0);
        // Numeric seeds are accepted too.
        let j = Json::parse(r#"{"objective":"et","seed":7}"#).unwrap();
        assert_eq!(PlanRequest::from_json(&j).unwrap().seed, 7);
        assert!(PlanRequest::from_json(&Json::parse(r#"{"objective":"bogus"}"#).unwrap()).is_err());
        assert!(
            PlanRequest::from_json(&Json::parse(r#"{"objective":"et","tau":-1}"#).unwrap())
                .is_err()
        );
    }
}
