//! Typed stage artifacts of Algorithm 1 — `Partitioned -> Calibrated ->
//! Measured` — each independently constructible, JSON-serializable through
//! `util::Json` (serde is not vendored in this image), and persistable
//! to/from the on-disk cache under `artifacts/cache/`.
//!
//! The JSON forms round-trip exactly: floats are emitted with Rust's
//! shortest-round-trip `Display` and parsed back bit-identical, so
//! `from_json(to_json(x)) == x` (covered by tests here and in
//! tests/staged_api.rs).

use crate::backend::DeviceProfile;
use crate::graph::partition::{Partition, SubGraph};
use crate::model::{LayerKind, QLayer};
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::timing::{GroupGains, TimeMeasurements};
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Version stamp embedded in every artifact and Plan.
///
/// 2 (0.5): the Measured stage draws per-measurement noise from
/// `Rng::stream(seed, index)` instead of one rolling generator, so gain
/// tables cached under schema 1 are NOT reproducible by the current code
/// at the same seed — they must miss and recompute.
pub const SCHEMA_VERSION: i64 = 2;

// ---- shared JSON helpers ------------------------------------------------

pub(crate) fn num(x: f64) -> Json {
    Json::Num(x)
}

pub(crate) fn unum(x: usize) -> Json {
    Json::Num(x as f64)
}

pub(crate) fn f64s(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

pub(crate) fn usizes(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| unum(x)).collect())
}

pub(crate) fn f64_vec(j: &Json) -> Result<Vec<f64>> {
    j.arr()?.iter().map(|x| x.f64()).collect()
}

pub(crate) fn usize_vec(j: &Json) -> Result<Vec<usize>> {
    j.arr()?.iter().map(|x| x.usize()).collect()
}

pub(crate) fn formats_to_json(fs: &[Format]) -> Json {
    Json::Arr(fs.iter().map(|f| Json::Str(f.name().to_string())).collect())
}

pub(crate) fn formats_from_json(j: &Json) -> Result<Vec<Format>> {
    j.arr()?
        .iter()
        .map(|x| {
            let name = x.str()?;
            Format::from_name(name).ok_or_else(|| anyhow!("unknown format '{name}'"))
        })
        .collect()
}

/// Validate the `{schema, kind}` header every artifact carries.
pub(crate) fn check_header(j: &Json, kind: &str) -> Result<()> {
    let schema = j.get("schema")?.i64()?;
    if schema != SCHEMA_VERSION {
        bail!("unsupported schema version {schema} (expected {SCHEMA_VERSION})");
    }
    let k = j.get("kind")?.str()?;
    if k != kind {
        bail!("artifact kind '{k}' (expected '{kind}')");
    }
    Ok(())
}

fn write_file(path: &Path, j: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

// ---- stage 1: Partitioned ----------------------------------------------

/// Stage-1 artifact: the Algorithm-2 partition plus the static layer table
/// and the format menu every later stage is keyed on.
#[derive(Clone, Debug, PartialEq)]
pub struct Partitioned {
    pub model: String,
    pub formats: Vec<Format>,
    pub qlayers: Vec<QLayer>,
    pub partition: Partition,
}

impl Partitioned {
    pub fn n_qlayers(&self) -> usize {
        self.qlayers.len()
    }

    pub fn to_json(&self) -> Json {
        let qlayers = self
            .qlayers
            .iter()
            .map(|q| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(q.name.clone())),
                    (
                        "kind".into(),
                        Json::Str(
                            match q.kind {
                                LayerKind::Linear => "linear",
                                LayerKind::Bgemm => "bgemm",
                            }
                            .to_string(),
                        ),
                    ),
                    ("c".into(), unum(q.c)),
                    ("k".into(), unum(q.k)),
                    ("macs".into(), num(q.macs as f64)),
                    ("params".into(), num(q.params as f64)),
                ])
            })
            .collect();
        let groups = self
            .partition
            .groups
            .iter()
            .map(|g| {
                Json::Obj(vec![
                    ("all_nodes".into(), usizes(&g.all_nodes)),
                    ("qnodes".into(), usizes(&g.qnodes)),
                    ("qidxs".into(), usizes(&g.qidxs)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("schema".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("kind".into(), Json::Str("partitioned".into())),
            ("model".into(), Json::Str(self.model.clone())),
            ("formats".into(), formats_to_json(&self.formats)),
            ("qlayers".into(), Json::Arr(qlayers)),
            ("groups".into(), Json::Arr(groups)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Partitioned> {
        check_header(j, "partitioned")?;
        let qlayers = j
            .get("qlayers")?
            .arr()?
            .iter()
            .map(|q| {
                Ok(QLayer {
                    name: q.get("name")?.str()?.to_string(),
                    kind: match q.get("kind")?.str()? {
                        "linear" => LayerKind::Linear,
                        "bgemm" => LayerKind::Bgemm,
                        k => bail!("unknown layer kind '{k}'"),
                    },
                    c: q.get("c")?.usize()?,
                    k: q.get("k")?.usize()?,
                    macs: q.get("macs")?.f64()? as u64,
                    params: q.get("params")?.f64()? as u64,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let groups = j
            .get("groups")?
            .arr()?
            .iter()
            .map(|g| {
                Ok(SubGraph {
                    all_nodes: usize_vec(g.get("all_nodes")?)?,
                    qnodes: usize_vec(g.get("qnodes")?)?,
                    qidxs: usize_vec(g.get("qidxs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Partitioned {
            model: j.get("model")?.str()?.to_string(),
            formats: formats_from_json(j.get("formats")?)?,
            qlayers,
            partition: Partition { groups },
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        write_file(path, &self.to_json())
    }

    pub fn load(path: &Path) -> Result<Partitioned> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

// ---- stage 2: Calibrated -----------------------------------------------

/// Stage-2 artifact: per-layer sensitivities s_l and loss moments (eq. 21),
/// the calibrate-once product a whole tau sweep reuses.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibrated {
    pub model: String,
    pub calibration: Calibration,
}

impl Calibrated {
    pub fn to_json(&self) -> Json {
        let c = &self.calibration;
        Json::Obj(vec![
            ("schema".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("kind".into(), Json::Str("calibrated".into())),
            ("model".into(), Json::Str(self.model.clone())),
            ("s".into(), f64s(&c.s)),
            ("eg2".into(), num(c.eg2)),
            ("g_mean".into(), num(c.g_mean)),
            ("n_samples".into(), unum(c.n_samples)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Calibrated> {
        check_header(j, "calibrated")?;
        Ok(Calibrated {
            model: j.get("model")?.str()?.to_string(),
            calibration: Calibration {
                s: f64_vec(j.get("s")?)?,
                eg2: j.get("eg2")?.f64()?,
                g_mean: j.get("g_mean")?.f64()?,
                n_samples: j.get("n_samples")?.usize()?,
            },
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        write_file(path, &self.to_json())
    }

    pub fn load(path: &Path) -> Result<Calibrated> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

// ---- stage 3: Measured --------------------------------------------------

/// Stage-3 artifact: the per-group empirical time-gain tables (Algorithm 1
/// line 3) plus the measurement protocol that produced them.  Gain tables
/// are meaningless without their hardware, so the full device profile is
/// embedded: it keys cache validity AND carries the rate table the
/// theoretical-time family is built from at Planner assembly.
#[derive(Clone, Debug, PartialEq)]
pub struct Measured {
    pub model: String,
    pub formats: Vec<Format>,
    pub seed: u64,
    pub reps: usize,
    /// The device the measurement ran on (simulated).
    pub device: DeviceProfile,
    pub measurements: TimeMeasurements,
}

impl Measured {
    pub fn to_json(&self) -> Json {
        let groups = self
            .measurements
            .groups
            .iter()
            .map(|g| {
                let configs =
                    Json::Arr(g.configs.iter().map(|c| formats_to_json(c)).collect());
                Json::Obj(vec![
                    ("group".into(), unum(g.group)),
                    ("qidxs".into(), usizes(&g.qidxs)),
                    ("configs".into(), configs),
                    ("gains".into(), f64s(&g.gains)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("schema".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("kind".into(), Json::Str("measured".into())),
            ("model".into(), Json::Str(self.model.clone())),
            ("formats".into(), formats_to_json(&self.formats)),
            // Seeds are u64: serialized as a string so values >= 2^53
            // survive the JSON round-trip exactly.
            ("seed".into(), Json::Str(self.seed.to_string())),
            ("reps".into(), unum(self.reps)),
            ("device".into(), self.device.to_json()),
            ("base_ttft".into(), num(self.measurements.base_ttft)),
            ("groups".into(), Json::Arr(groups)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Measured> {
        check_header(j, "measured")?;
        let groups = j
            .get("groups")?
            .arr()?
            .iter()
            .map(|g| {
                let configs = g
                    .get("configs")?
                    .arr()?
                    .iter()
                    .map(formats_from_json)
                    .collect::<Result<Vec<_>>>()?;
                Ok(GroupGains {
                    group: g.get("group")?.usize()?,
                    qidxs: usize_vec(g.get("qidxs")?)?,
                    configs,
                    gains: f64_vec(g.get("gains")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Measured {
            model: j.get("model")?.str()?.to_string(),
            formats: formats_from_json(j.get("formats")?)?,
            seed: j.get("seed")?.str()?.parse::<u64>()?,
            reps: j.get("reps")?.usize()?,
            device: DeviceProfile::from_json(j.get("device")?)?,
            measurements: TimeMeasurements {
                base_ttft: j.get("base_ttft")?.f64()?,
                groups,
            },
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        write_file(path, &self.to_json())
    }

    pub fn load(path: &Path) -> Result<Measured> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::PAPER_FORMATS;

    fn partitioned_fixture() -> Partitioned {
        Partitioned {
            model: "fixture".into(),
            formats: PAPER_FORMATS.to_vec(),
            qlayers: vec![
                QLayer {
                    name: "a".into(),
                    kind: LayerKind::Linear,
                    c: 8,
                    k: 16,
                    macs: 4096,
                    params: 128,
                },
                QLayer {
                    name: "b".into(),
                    kind: LayerKind::Bgemm,
                    c: 4,
                    k: 4,
                    macs: 1024,
                    params: 0,
                },
            ],
            partition: Partition {
                groups: vec![SubGraph {
                    all_nodes: vec![0, 1, 2],
                    qnodes: vec![1, 2],
                    qidxs: vec![0, 1],
                }],
            },
        }
    }

    #[test]
    fn partitioned_roundtrip() {
        let p = partitioned_fixture();
        let j = p.to_json();
        let text = j.to_string();
        let back = Partitioned::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn calibrated_roundtrip() {
        let c = Calibrated {
            model: "fixture".into(),
            calibration: Calibration {
                s: vec![0.125, 3.5e-4, 7.0],
                eg2: 16.25,
                g_mean: 4.03125,
                n_samples: 8,
            },
        };
        let back =
            Calibrated::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn measured_roundtrip() {
        let m = Measured {
            model: "fixture".into(),
            formats: PAPER_FORMATS.to_vec(),
            seed: u64::MAX - 1, // > 2^53: must survive the round-trip exactly
            reps: 5,
            device: DeviceProfile::gaudi3(),
            measurements: TimeMeasurements {
                base_ttft: 123.456,
                groups: vec![GroupGains {
                    group: 0,
                    qidxs: vec![0, 1],
                    configs: vec![
                        vec![Format::Bf16, Format::Bf16],
                        vec![Format::Bf16, Format::Fp8E4m3],
                        vec![Format::Fp8E4m3, Format::Bf16],
                        vec![Format::Fp8E4m3, Format::Fp8E4m3],
                    ],
                    gains: vec![0.0, 1.5, 2.25, 3.875],
                }],
            },
        };
        let back =
            Measured::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn wrong_kind_rejected() {
        let p = partitioned_fixture();
        assert!(Calibrated::from_json(&p.to_json()).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let p = partitioned_fixture();
        let dir = std::env::temp_dir().join(format!("ampq_artifact_{}", std::process::id()));
        let path = dir.join("fixture").join("partitioned.json");
        p.save(&path).unwrap();
        let back = Partitioned::load(&path).unwrap();
        assert_eq!(back, p);
        std::fs::remove_dir_all(&dir).ok();
    }
}
