//! A concurrent serving facade over per-(model, device) [`Planner`]s.
//!
//! [`PlanService`] is `Send + Sync + Clone` (clones share state): it holds
//! one `Arc<Planner>` per model — and per (model, device) for requests
//! carrying `PlanRequest::with_device` — plus an interior Pareto-frontier
//! cache, so a fleet of worker threads answers plan and frontier queries
//! without ever re-running calibration, measurement, or a frontier sweep.
//! This is the ROADMAP's serving seam: artifacts are staged once per
//! (model, device) (Engine), then query throughput is bounded only by MCKP
//! solves — and frontier lookups don't even pay those.
//!
//! `ampq serve --requests <file.json>` drives [`PlanService::serve_batch`]
//! over a JSON array of [`ServeRequest`]s; `ampq frontier` precomputes and
//! prints one frontier.

use super::engine::Engine;
use super::frontier::Frontier;
use super::planner::Planner;
use super::request::PlanRequest;
use crate::coordinator::Strategy;
use crate::exec::ExecPool;
use crate::metrics::Objective;
use crate::solver::parametric;
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// One frontier slot: None until its sweep completes.  The per-key lock is
/// held across the sweep, so racing threads for the SAME key wait for one
/// computation — while hits and sweeps for other keys proceed untouched.
type FrontierCell = Arc<Mutex<Option<Arc<Frontier>>>>;

/// Planner registry key: (model, resolved device); `None` is the model's
/// default planner.  Structured — an earlier '@'-joined string key made a
/// model registered as "llama@fp8" collide with model "llama"'s "fp8"
/// device alias, so model names were banned from containing '@'.  With a
/// tuple key any model name routes unambiguously.
type PlannerKey = (String, Option<String>);

/// Frontier cache key: (model, PLANNER IDENTITY, objective key, strategy
/// key) — structured for the same reason as [`PlannerKey`].  The second
/// component is the resolved `Arc<Planner>`'s address, not the device
/// name: the default alias and an explicit request resolving to the SAME
/// planner share one sweep, while two different planners that happen to
/// be measured on a same-named device (e.g. `register` + a separately
/// staged `register_for_device`) get separate cells instead of serving
/// each other's curves.  Address reuse cannot alias stale entries: every
/// registration drops the model's cells ([`PlanService::insert`]), and
/// keys from different models differ in the leading component.
type FrontierKey = (String, usize, &'static str, &'static str);

/// The frontier cache proper: cells plus a monotonic access clock for
/// LRU eviction.  A resident daemon serves unbounded (model, device,
/// objective, strategy) combinations over its lifetime; without a cap
/// the cell map — and the `Arc<Frontier>` curves it pins — would grow
/// without bound.
struct FrontierCache {
    /// value = (cell, last-access stamp).
    cells: BTreeMap<FrontierKey, (FrontierCell, u64)>,
    tick: u64,
}

struct Inner {
    planners: RwLock<BTreeMap<PlannerKey, Arc<Planner>>>,
    /// Frontier cells.  The outer lock guards only the map; computation
    /// happens under the per-key cell.
    frontiers: Mutex<FrontierCache>,
    frontier_solves: AtomicUsize,
    /// Lookups answered from an already-computed cell.  Every
    /// `frontier_for` call lands in exactly one of hits/solves (or
    /// errors), so hit rate is `hits / (hits + solves)`.
    frontier_hits: AtomicUsize,
    /// Maximum retained cells; 0 = unbounded (the library default — CLI
    /// one-shots don't live long enough to care).
    cache_cap: AtomicUsize,
}

/// Thread-safe handle answering plan/frontier queries for registered models.
#[derive(Clone)]
pub struct PlanService {
    inner: Arc<Inner>,
}

impl Default for PlanService {
    fn default() -> Self {
        PlanService::new()
    }
}

impl PlanService {
    pub fn new() -> PlanService {
        PlanService {
            inner: Arc::new(Inner {
                planners: RwLock::new(BTreeMap::new()),
                frontiers: Mutex::new(FrontierCache { cells: BTreeMap::new(), tick: 0 }),
                frontier_solves: AtomicUsize::new(0),
                frontier_hits: AtomicUsize::new(0),
                cache_cap: AtomicUsize::new(0),
            }),
        }
    }

    fn insert(&self, key: PlannerKey, planner: Arc<Planner>) {
        // (Re-)registering a planner invalidates the model's cached
        // frontiers: a replacement planner (new seed/protocol, edited
        // profile under the same name) must not serve its predecessor's
        // curves.  Frontier keys lead with the model, so dropping every
        // entry for it over-invalidates (other devices' curves) at worst.
        {
            let mut cache =
                self.inner.frontiers.lock().expect("frontier cache lock poisoned");
            cache.cells.retain(|k, _| k.0 != key.0);
        }
        let mut planners =
            self.inner.planners.write().expect("planner registry lock poisoned");
        // The curves are invalidated, but the predecessor's committed
        // frontier-DP levels carry over: if the replacement's tables match
        // (or diverge late in the chain), its first sweep re-solves
        // incrementally instead of from scratch.  Safe for ANY replacement —
        // the DP diffs the instances and falls back to a full solve on
        // mismatch.
        if let Some(old) = planners.get(&key) {
            planner.adopt_frontier_state(old);
        }
        planners.insert(key, planner);
    }

    /// Stage every model on `engine` and register its planner — both as
    /// the model's default and under the engine's device name, so
    /// device-scoped requests naming that device resolve too.
    pub fn from_engine(engine: &mut Engine, models: &[&str]) -> Result<PlanService> {
        let svc = PlanService::new();
        let device = engine.device().name.clone();
        for m in models {
            let planner = Arc::new(engine.planner(m)?);
            svc.insert((m.to_string(), None), planner.clone());
            svc.insert((m.to_string(), Some(device.clone())), planner);
        }
        Ok(svc)
    }

    /// Like [`PlanService::from_engine`], but lossy: a model that fails
    /// to stage is skipped and returned with its error instead of
    /// failing the whole set.  Its requests then answer with per-entry
    /// errors (`serve_batch_lossy`, the daemon) — one bad model never
    /// poisons a batch.  Successes share one planner `Arc` between the
    /// default and device alias, exactly like `from_engine`.
    pub fn stage_from_engine(
        &self,
        engine: &mut Engine,
        models: &[&str],
    ) -> Vec<(String, String)> {
        let device = engine.device().name.clone();
        let mut failed = Vec::new();
        for m in models {
            match engine.planner(m) {
                Ok(p) => {
                    let planner = Arc::new(p);
                    self.insert((m.to_string(), None), planner.clone());
                    self.insert((m.to_string(), Some(device.clone())), planner);
                }
                Err(e) => failed.push((m.to_string(), format!("{e:#}"))),
            }
        }
        failed
    }

    /// Register `planner` as the model's default (device-less requests).
    pub fn register(&self, model: &str, planner: Planner) {
        self.insert((model.to_string(), None), Arc::new(planner));
    }

    /// Register `planner` for requests targeting `device` explicitly.  The
    /// planner's own measured device must match.
    pub fn register_for_device(&self, model: &str, device: &str, planner: Planner) -> Result<()> {
        if planner.device().name != device {
            bail!(
                "planner for '{model}' was measured on '{}', not '{device}'",
                planner.device().name
            );
        }
        self.insert((model.to_string(), Some(device.to_string())), Arc::new(planner));
        Ok(())
    }

    /// Registered model names (device-scoped aliases excluded).
    pub fn models(&self) -> Vec<String> {
        self.inner
            .planners
            .read()
            .expect("planner registry lock poisoned")
            .keys()
            .filter(|(_, device)| device.is_none())
            .map(|(model, _)| model.clone())
            .collect()
    }

    pub fn planner(&self, model: &str) -> Result<Arc<Planner>> {
        self.planner_for(model, None)
    }

    /// The planner serving (model, optional device).
    pub fn planner_for(&self, model: &str, device: Option<&str>) -> Result<Arc<Planner>> {
        let key: PlannerKey = (model.to_string(), device.map(str::to_string));
        self.inner
            .planners
            .read()
            .expect("planner registry lock poisoned")
            .get(&key)
            .cloned()
            .ok_or_else(|| match device {
                Some(d) => anyhow!(
                    "model '{model}' has no planner for device '{d}' registered with the service"
                ),
                None => anyhow!("model '{model}' is not registered with the service"),
            })
    }

    /// Resolve one plan request against the matching (model, device)
    /// planner.
    pub fn solve(&self, model: &str, req: &PlanRequest) -> Result<super::Plan> {
        self.planner_for(model, req.device.as_deref())?.solve(req)
    }

    /// The (cached) Pareto frontier for one (model, objective, strategy)
    /// on the model's default device.
    pub fn frontier(
        &self,
        model: &str,
        objective: Objective,
        strategy: Strategy,
    ) -> Result<Arc<Frontier>> {
        self.frontier_for(model, None, objective, strategy)
    }

    /// The (cached) Pareto frontier for one (model, device, objective,
    /// strategy).  Each key is swept exactly once; a failed sweep leaves
    /// the cell empty so a later caller retries.  The cache is keyed by
    /// the RESOLVED planner's identity, so the default alias and an
    /// explicit request routing to the same planner share one sweep —
    /// while distinct planners never serve each other's curves, even when
    /// measured on a same-named device.
    pub fn frontier_for(
        &self,
        model: &str,
        device: Option<&str>,
        objective: Objective,
        strategy: Strategy,
    ) -> Result<Arc<Frontier>> {
        let planner = self.planner_for(model, device)?;
        let cell = self.frontier_cell(model, &planner, objective, strategy);
        let mut slot = cell.lock().expect("frontier cell lock poisoned");
        let mut sp = crate::obs::span("service.frontier");
        if let Some(f) = slot.as_ref() {
            self.inner.frontier_hits.fetch_add(1, Ordering::Relaxed);
            sp.counter("cache_hit", 1.0);
            return Ok(f.clone());
        }
        sp.counter("cache_hit", 0.0);
        let f = Arc::new(planner.frontier(objective, strategy)?);
        self.inner.frontier_solves.fetch_add(1, Ordering::Relaxed);
        sp.counter("points", f.points.len() as f64);
        *slot = Some(f.clone());
        Ok(f)
    }

    /// Recompute one (model, device, objective, strategy) frontier IN
    /// PLACE: the sweep always runs — a cached curve is replaced, never
    /// served — so callers refreshing after an artifact or budget change
    /// get a provably current curve.  The solve goes through
    /// [`Planner::frontier_delta`], so a planner that already committed DP
    /// levels for this objective re-solves incrementally; the returned
    /// [`parametric::FrontierDelta`] says how much it reused.  Counts as a
    /// solve (never a hit) in the service counters, and re-stamps the
    /// cell's LRU recency like any other access.
    pub fn refresh_frontier(
        &self,
        model: &str,
        device: Option<&str>,
        objective: Objective,
        strategy: Strategy,
    ) -> Result<(Arc<Frontier>, parametric::FrontierDelta)> {
        let planner = self.planner_for(model, device)?;
        let cell = self.frontier_cell(model, &planner, objective, strategy);
        let mut slot = cell.lock().expect("frontier cell lock poisoned");
        let mut sp = crate::obs::span("service.frontier");
        sp.counter("cache_hit", 0.0);
        let (f, delta) = planner.frontier_delta(objective, strategy)?;
        let f = Arc::new(f);
        self.inner.frontier_solves.fetch_add(1, Ordering::Relaxed);
        sp.counter("points", f.points.len() as f64);
        *slot = Some(f.clone());
        Ok((f, delta))
    }

    /// The cache cell for one resolved (model, planner, objective,
    /// strategy) — re-stamping its LRU recency, inserting (and evicting
    /// over the cap) when absent.  Shared by the hit-or-sweep path
    /// ([`PlanService::frontier_for`]) and the always-sweep path
    /// ([`PlanService::refresh_frontier`]) so both agree on keys and
    /// eviction.
    fn frontier_cell(
        &self,
        model: &str,
        planner: &Arc<Planner>,
        objective: Objective,
        strategy: Strategy,
    ) -> FrontierCell {
        let key: FrontierKey = (
            model.to_string(),
            Arc::as_ptr(planner) as usize,
            objective.key(),
            strategy.key(),
        );
        let mut cache = self.inner.frontiers.lock().expect("frontier cache lock poisoned");
        cache.tick += 1;
        let now = cache.tick;
        if let Some((cell, stamp)) = cache.cells.get_mut(&key) {
            *stamp = now;
            cell.clone()
        } else {
            let cell = FrontierCell::default();
            cache.cells.insert(key, (cell.clone(), now));
            // LRU eviction: drop least-recently-touched cells over the
            // cap (never the one just inserted — it holds the max
            // stamp).  Evicting a cell mid-sweep is safe: the sweeping
            // thread owns its own Arc to the cell; only the CACHING of
            // that curve is lost.
            let cap = self.inner.cache_cap.load(Ordering::Relaxed);
            if cap > 0 {
                while cache.cells.len() > cap {
                    let victim = cache
                        .cells
                        .iter()
                        .min_by_key(|(_, v)| v.1)
                        .map(|(k, _)| k.clone());
                    match victim {
                        Some(v) => {
                            cache.cells.remove(&v);
                        }
                        None => break,
                    }
                }
            }
            cell
        }
    }

    /// How many frontier sweeps actually ran (cache misses).
    pub fn frontier_solves(&self) -> usize {
        self.inner.frontier_solves.load(Ordering::Relaxed)
    }

    /// How many `frontier_for` calls were answered from the cache.
    pub fn frontier_hits(&self) -> usize {
        self.inner.frontier_hits.load(Ordering::Relaxed)
    }

    /// Cached frontier cells currently retained.
    pub fn frontier_cache_len(&self) -> usize {
        self.inner.frontiers.lock().expect("frontier cache lock poisoned").cells.len()
    }

    /// Cap the frontier cache at `cap` entries, evicting LRU cells over
    /// the cap now and on every future insert.  `0` removes the cap.
    pub fn set_cache_cap(&self, cap: usize) {
        self.inner.cache_cap.store(cap, Ordering::Relaxed);
        if cap == 0 {
            return;
        }
        let mut cache = self.inner.frontiers.lock().expect("frontier cache lock poisoned");
        while cache.cells.len() > cap {
            let victim =
                cache.cells.iter().min_by_key(|(_, v)| v.1).map(|(k, _)| k.clone());
            match victim {
                Some(v) => {
                    cache.cells.remove(&v);
                }
                None => break,
            }
        }
    }

    /// Answer one serve entry: a fresh solve, or (for `via_frontier`
    /// entries) an O(log n) lookup against the cached frontier.
    pub fn answer(&self, req: &ServeRequest) -> Result<Json> {
        if !req.via_frontier {
            return Ok(self.solve(&req.model, &req.request)?.to_json());
        }
        if req.request.strategy != Strategy::Ip || req.request.memory_cap.is_some() {
            bail!("frontier lookups serve IP requests without a memory cap");
        }
        let tau = req
            .request
            .tau
            .ok_or_else(|| anyhow!("a frontier lookup needs an explicit tau"))?;
        // Frontier lookups bypass Planner::solve's request validation, so
        // re-check here: a NaN/negative tau must fail THIS request, never
        // panic the batch.
        super::request::check_budget("frontier lookup tau", tau)?;
        // Stamp the RESOLVED device (like Plan answers do), so per-device
        // frontier lines in one batch are distinguishable.
        let device = self
            .planner_for(&req.model, req.request.device.as_deref())?
            .device()
            .name
            .clone();
        let f = self.frontier_for(
            &req.model,
            req.request.device.as_deref(),
            req.request.objective,
            req.request.strategy,
        )?;
        let p = f.at(tau);
        Ok(Json::Obj(vec![
            ("kind".into(), Json::Str("frontier_point".into())),
            ("model".into(), Json::Str(req.model.clone())),
            ("device".into(), Json::Str(device)),
            ("objective".into(), Json::Str(req.request.objective.key().into())),
            ("strategy".into(), Json::Str(req.request.strategy.key().into())),
            ("tau".into(), Json::Num(tau)),
            ("gain".into(), Json::Num(p.gain)),
            ("predicted_mse".into(), Json::Num(p.predicted_mse)),
            ("feasible".into(), Json::Bool(f.feasible_at(tau))),
            ("config".into(), super::artifact::formats_to_json(&p.config.0)),
        ]))
    }

    /// Answer a batch across `pool`'s workers; results keep request order.
    /// Requests are answered independently (the batch always runs to
    /// completion); if any failed, the earliest failure in request order is
    /// returned after the batch drains — exactly [`ExecPool::try_par_map`]'s
    /// semantics, so the surfaced answer set never depends on timing.
    pub fn serve_batch(&self, reqs: &[ServeRequest], pool: &ExecPool) -> Result<Vec<Json>> {
        pool.try_par_map(reqs.len(), |i| self.answer(&reqs[i]))
    }

    /// Answer a batch without failing it: every entry yields a line — the
    /// answer stamped with its request index, or an indexed error object
    /// ([`error_entry`]).  Same schema as the daemon's streaming batch
    /// path, so `ampq serve --requests` output and `POST /v1/plan` bodies
    /// are interchangeable downstream.
    pub fn serve_batch_lossy(&self, reqs: &[ServeRequest], pool: &ExecPool) -> Vec<Json> {
        pool.par_map(reqs.len(), |i| match self.answer(&reqs[i]) {
            Ok(j) => indexed(i, j),
            Err(e) => error_entry(i, &format!("{e:#}")),
        })
    }
}

/// Stamp an answer with its request index (leading key, so streaming
/// consumers can attribute a line before parsing the rest).
pub fn indexed(i: usize, j: Json) -> Json {
    let mut kv = vec![("index".to_string(), Json::Num(i as f64))];
    match j {
        Json::Obj(rest) => kv.extend(rest),
        other => kv.push(("answer".to_string(), other)),
    }
    Json::Obj(kv)
}

/// The per-request error object of a lossy batch: request index + message.
pub fn error_entry(i: usize, msg: &str) -> Json {
    Json::Obj(vec![
        ("kind".to_string(), Json::Str("error".to_string())),
        ("index".to_string(), Json::Num(i as f64)),
        ("error".to_string(), Json::Str(msg.to_string())),
    ])
}

/// One entry of a serve batch: a model to route to plus the request itself.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeRequest {
    pub model: String,
    pub request: PlanRequest,
    /// Answer from the cached Pareto frontier instead of a fresh IP solve.
    pub via_frontier: bool,
}

impl ServeRequest {
    pub fn new(model: impl Into<String>, request: PlanRequest) -> ServeRequest {
        ServeRequest { model: model.into(), request, via_frontier: false }
    }

    pub fn via_frontier(mut self) -> ServeRequest {
        self.via_frontier = true;
        self
    }

    /// Flattened JSON: the request fields plus `model` (and `via_frontier`
    /// when set).
    pub fn to_json(&self) -> Json {
        let mut kv = vec![("model".to_string(), Json::Str(self.model.clone()))];
        if let Json::Obj(rest) = self.request.to_json() {
            kv.extend(rest);
        }
        if self.via_frontier {
            kv.push(("via_frontier".to_string(), Json::Bool(true)));
        }
        Json::Obj(kv)
    }

    pub fn from_json(j: &Json) -> Result<ServeRequest> {
        let model = j.get("model")?.str()?.to_string();
        let request = PlanRequest::from_json(j)?;
        let via_frontier = match j.opt("via_frontier") {
            None => false,
            Some(v) => v.bool().map_err(|_| anyhow!("'via_frontier' must be a bool"))?,
        };
        Ok(ServeRequest { model, request, via_frontier })
    }
}

/// Parse a serve batch file: a top-level JSON array of request objects.
pub fn load_requests(j: &Json) -> Result<Vec<ServeRequest>> {
    j.arr()?.iter().map(ServeRequest::from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::demo::demo_model;

    fn demo_service() -> PlanService {
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        PlanService::from_engine(&mut engine, &["demo"]).unwrap()
    }

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn service_is_send_sync() {
        assert_send_sync::<PlanService>();
        assert_send_sync::<Planner>();
        assert_send_sync::<Frontier>();
    }

    #[test]
    fn unknown_model_errors() {
        let svc = demo_service();
        let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
        assert!(svc.solve("nope", &req).is_err());
        assert_eq!(svc.models(), vec!["demo".to_string()]);
    }

    #[test]
    fn reregistration_invalidates_cached_frontiers() {
        let svc = demo_service();
        let a = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 1);
        // Replacing the model's planner (a re-staged engine) must drop its
        // cached frontiers — the replacement may have new measurements.
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        svc.register("demo", engine.planner("demo").unwrap());
        let b = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "stale frontier served after re-registration");
        assert_eq!(svc.frontier_solves(), 2);
    }

    #[test]
    fn at_sign_model_names_do_not_collide_with_device_aliases() {
        // Regression: the old '@'-joined string cache key spelled model
        // "demo"'s gaudi2 alias as "demo@gaudi2" — colliding with a model
        // literally REGISTERED under that name (e.g. "llama@fp8"-style
        // names).  Structured (model, device) keys must keep them apart.
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        let (g1, q1, c1) = demo_model(1, 3); // different depth => different planner
        engine.register_synthetic("demo@gaudi2", g1, q1, c1);
        let svc =
            PlanService::from_engine(&mut engine, &["demo", "demo@gaudi2"]).unwrap();
        assert_eq!(
            svc.models(),
            vec!["demo".to_string(), "demo@gaudi2".to_string()]
        );

        let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
        let via_alias = svc.solve("demo", &req.clone().with_device("gaudi2")).unwrap();
        let default = svc.solve("demo", &req).unwrap();
        let literal = svc.solve("demo@gaudi2", &req).unwrap();
        // The alias resolves to "demo"'s planner, NOT the '@'-named model.
        assert_eq!(via_alias, default);
        assert_ne!(
            literal.config.len(),
            via_alias.config.len(),
            "'demo@gaudi2' answered with 'demo''s planner (cache key collision)"
        );

        // Frontier cache entries stay separate per model, too.
        let fa = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let fb = svc
            .frontier("demo@gaudi2", Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(!Arc::ptr_eq(&fa, &fb));
        assert_eq!(svc.frontier_solves(), 2);
        // Re-registering the '@' model drops only ITS cached curves.
        let (g2, q2, c2) = demo_model(1, 5);
        let mut e2 = Engine::new();
        e2.register_synthetic("demo@gaudi2", g2, q2, c2);
        svc.register("demo@gaudi2", e2.planner("demo@gaudi2").unwrap());
        let fa2 = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert!(Arc::ptr_eq(&fa, &fa2), "'demo' curve must survive");
        assert_eq!(svc.frontier_solves(), 2);
        let fb2 = svc
            .frontier("demo@gaudi2", Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(!Arc::ptr_eq(&fb, &fb2), "stale '@' model curve served");
        assert_eq!(svc.frontier_solves(), 3);
    }

    #[test]
    fn same_device_name_distinct_planners_do_not_share_frontiers() {
        // register() + register_for_device() can install two DIFFERENT
        // planners both measured on "gaudi2"; a device-name-keyed cache
        // would let whichever sweeps first answer for both.
        let svc = PlanService::new();
        let (g1, q1, c1) = demo_model(1, 3);
        let mut e1 = Engine::new();
        e1.register_synthetic("demo", g1, q1, c1);
        svc.register("demo", e1.planner("demo").unwrap());
        let (g2, q2, c2) = demo_model(1, 9); // different seed, same device
        let mut e2 = Engine::new();
        e2.register_synthetic("demo", g2, q2, c2);
        svc.register_for_device("demo", "gaudi2", e2.planner("demo").unwrap())
            .unwrap();
        let fd = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let fs = svc
            .frontier_for("demo", Some("gaudi2"), Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(!Arc::ptr_eq(&fd, &fs), "distinct planners shared a frontier cell");
        assert_eq!(svc.frontier_solves(), 2);
    }

    #[test]
    fn frontier_is_cached() {
        let svc = demo_service();
        let a = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let b = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(svc.frontier_solves(), 1);
        svc.frontier("demo", Objective::Memory, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 2);
    }

    #[test]
    fn device_requests_route_to_per_device_planners() {
        use crate::backend::DeviceProfile;
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut g2 = Engine::new();
        g2.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        let svc = PlanService::from_engine(&mut g2, &["demo"]).unwrap();
        assert_eq!(svc.models(), vec!["demo".to_string()], "aliases stay hidden");

        let req = PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004);
        let default_plan = svc.solve("demo", &req).unwrap();
        assert_eq!(default_plan.device, "gaudi2");
        // from_engine also registered the engine's own device name.
        let scoped = svc.solve("demo", &req.clone().with_device("gaudi2")).unwrap();
        assert_eq!(scoped, default_plan);
        // No gaudi3 planner registered yet.
        assert!(svc.solve("demo", &req.clone().with_device("gaudi3")).is_err());

        let mut g3 = Engine::new().with_device(DeviceProfile::gaudi3());
        g3.register_synthetic("demo", graph.clone(), qlayers.clone(), calibration.clone());
        svc.register_for_device("demo", "gaudi3", g3.planner("demo").unwrap())
            .unwrap();
        let p3 = svc.solve("demo", &req.clone().with_device("gaudi3")).unwrap();
        assert_eq!(p3.device, "gaudi3");
        // 2x MME/HBM: the faster device has a smaller baseline TTFT.
        assert!(p3.provenance.base_ttft_us < default_plan.provenance.base_ttft_us);

        // Registering a planner under the wrong device name is rejected.
        let mut g2b = Engine::new();
        g2b.register_synthetic("demo", graph, qlayers, calibration);
        assert!(svc
            .register_for_device("demo", "gaudi3", g2b.planner("demo").unwrap())
            .is_err());

        // Device-scoped frontiers cache independently of other devices...
        let fd = svc
            .frontier("demo", Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        let f3 = svc
            .frontier_for("demo", Some("gaudi3"), Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(!Arc::ptr_eq(&fd, &f3));
        assert_eq!(svc.frontier_solves(), 2);
        // ...but an explicit request for the DEFAULT device shares the
        // default's sweep (cache keys use the resolved device).
        let f2 = svc
            .frontier_for("demo", Some("gaudi2"), Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(Arc::ptr_eq(&fd, &f2));
        assert_eq!(svc.frontier_solves(), 2);
    }

    #[test]
    fn frontier_cache_evicts_lru_under_cap() {
        let svc = demo_service();
        svc.set_cache_cap(2);
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        svc.frontier("demo", Objective::Memory, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_cache_len(), 2);
        assert_eq!(svc.frontier_solves(), 2);
        // Touch ET so Memory becomes the LRU entry, then overflow the cap.
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_hits(), 1);
        svc.frontier("demo", Objective::TheoreticalTime, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_cache_len(), 2, "cap must hold");
        assert_eq!(svc.frontier_solves(), 3);
        // ET survived the eviction...
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 3);
        assert_eq!(svc.frontier_hits(), 2);
        // ...and Memory (the LRU victim) re-solves on demand.
        svc.frontier("demo", Objective::Memory, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 4);
        // Every call above was exactly one hit or one solve.
        assert_eq!(svc.frontier_hits() + svc.frontier_solves(), 6);
    }

    #[test]
    fn hot_entry_survives_an_eviction_burst() {
        // Regression for the LRU recency audit: the cache-hit path must
        // re-stamp the entry's tick, or a burst of fresh keys evicts the
        // hottest curve in the cache.
        let svc = demo_service();
        svc.set_cache_cap(2);
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        let burst = [
            (Objective::Memory, Strategy::Ip),
            (Objective::TheoreticalTime, Strategy::Ip),
            (Objective::EmpiricalTime, Strategy::Random),
            (Objective::Memory, Strategy::Random),
        ];
        for (objective, strategy) in burst {
            // Touch the hot entry, then push a cold key over the cap: the
            // eviction victim must always be the PREVIOUS burst key.
            svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
            svc.frontier("demo", objective, strategy).unwrap();
        }
        assert_eq!(svc.frontier_solves(), 5, "each burst key swept once");
        assert_eq!(svc.frontier_hits(), 4, "every hot touch must hit");
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 5, "hot entry evicted despite its recency");
        assert_eq!(svc.frontier_hits(), 5);
    }

    #[test]
    fn refresh_frontier_reuses_committed_dp_levels() {
        let svc = demo_service();
        let a = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 1);
        let (b, delta) = svc
            .refresh_frontier("demo", None, Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &b), "refresh must re-solve, not serve the cache");
        assert_eq!(*a, *b, "a warm re-solve must reproduce the curve");
        assert!(!delta.full_solve, "second solve must reuse the committed levels");
        assert_eq!(delta.solved_groups, 0, "nothing changed, so no group re-merges");
        assert_eq!(svc.frontier_solves(), 2);
        assert_eq!(svc.frontier_hits(), 0);
        // The refreshed curve now serves cached lookups.
        let c = svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        assert!(Arc::ptr_eq(&b, &c));
        assert_eq!(svc.frontier_hits(), 1);
    }

    #[test]
    fn reregistered_planner_inherits_frontier_dp_state() {
        let svc = demo_service();
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        // Re-stage the same artifacts under the same name: the replacement
        // planner adopts its predecessor's committed DP levels, so its
        // first sweep is incremental even though the curve cache was
        // (correctly) invalidated.
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        svc.register("demo", engine.planner("demo").unwrap());
        let (_, delta) = svc
            .refresh_frontier("demo", None, Objective::EmpiricalTime, Strategy::Ip)
            .unwrap();
        assert!(!delta.full_solve, "adopted DP state must survive re-registration");
        assert_eq!(delta.solved_groups, 0);
        assert_eq!(svc.frontier_solves(), 2);
    }

    #[test]
    fn shrinking_cache_cap_evicts_immediately() {
        let svc = demo_service();
        svc.frontier("demo", Objective::EmpiricalTime, Strategy::Ip).unwrap();
        svc.frontier("demo", Objective::TheoreticalTime, Strategy::Ip).unwrap();
        svc.frontier("demo", Objective::Memory, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_cache_len(), 3, "unbounded by default");
        svc.set_cache_cap(1);
        assert_eq!(svc.frontier_cache_len(), 1);
        // The survivor is the most recently touched curve: Memory.
        svc.frontier("demo", Objective::Memory, Strategy::Ip).unwrap();
        assert_eq!(svc.frontier_solves(), 3);
        assert_eq!(svc.frontier_hits(), 1);
    }

    #[test]
    fn lossy_batch_reports_indexed_errors_and_matches_answers() {
        let svc = demo_service();
        let good = ServeRequest::new(
            "demo",
            PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
        );
        let bad_model = ServeRequest::new(
            "nope",
            PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
        );
        let bad_tau = ServeRequest {
            model: "demo".to_string(),
            request: PlanRequest::new(Objective::EmpiricalTime)
                .with_loss_budget(f64::NAN),
            via_frontier: true,
        };
        let reqs = vec![good.clone(), bad_model, bad_tau, good.clone()];
        let out =
            svc.serve_batch_lossy(&reqs, &ExecPool::new(crate::exec::ExecCfg::new(4)));
        assert_eq!(out.len(), 4);
        // Good entries: the direct answer with a leading index stamp.
        assert_eq!(out[0], indexed(0, svc.answer(&good).unwrap()));
        assert_eq!(out[3], indexed(3, svc.answer(&good).unwrap()));
        // Bad entries: indexed error objects, batch not poisoned.
        for (i, line) in [(1usize, &out[1]), (2, &out[2])] {
            assert_eq!(line.get("kind").unwrap().str().unwrap(), "error");
            assert_eq!(line.get("index").unwrap().usize().unwrap(), i);
            assert!(!line.get("error").unwrap().str().unwrap().is_empty());
        }
        // The whole-batch path still fails fast on the earliest error.
        assert!(svc
            .serve_batch(&reqs, &ExecPool::new(crate::exec::ExecCfg::new(2)))
            .is_err());
    }

    #[test]
    fn serve_request_json_roundtrip() {
        let reqs = vec![
            ServeRequest::new(
                "demo",
                PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.004),
            ),
            ServeRequest::new(
                "demo",
                PlanRequest::new(Objective::Memory)
                    .with_loss_budget(0.002)
                    .with_memory_cap(1e6),
            ),
            ServeRequest::new(
                "demo",
                PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(0.003),
            )
            .via_frontier(),
        ];
        let batch = Json::Arr(reqs.iter().map(|r| r.to_json()).collect());
        let back = load_requests(&Json::parse(&batch.to_string()).unwrap()).unwrap();
        assert_eq!(back, reqs);
    }

    #[test]
    fn batch_results_keep_order_and_match_sequential() {
        let svc = demo_service();
        let reqs: Vec<ServeRequest> = [0.001, 0.002, 0.004, 0.006]
            .iter()
            .flat_map(|&tau| {
                vec![
                    ServeRequest::new(
                        "demo",
                        PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
                    ),
                    ServeRequest::new(
                        "demo",
                        PlanRequest::new(Objective::EmpiricalTime).with_loss_budget(tau),
                    )
                    .via_frontier(),
                ]
            })
            .collect();
        let sequential: Vec<Json> =
            reqs.iter().map(|r| svc.answer(r).unwrap()).collect();
        let parallel = svc
            .serve_batch(&reqs, &ExecPool::new(crate::exec::ExecCfg::new(4)))
            .unwrap();
        assert_eq!(parallel, sequential);
        assert_eq!(svc.frontier_solves(), 1, "frontier must be swept once");
    }
}
