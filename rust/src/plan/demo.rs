//! A synthetic transformer model for the staged planning API.
//!
//! `demo_model` builds a Fig.-6-shaped computation DAG (per block: the
//! 5-layer attention sub-graph, o_proj, {gate, up}, down_proj; plus a final
//! lm_head) with a deterministic pseudo-calibration, so partitioning,
//! time measurement (simulator), IP planning, and the `ampq sweep --demo`
//! batch entrypoint all run without AOT artifacts or PJRT.  Tests use it as
//! the acceptance fixture for the Engine/Planner surface.

use crate::graph::{Engine as GraphEngine, Graph, Node};
use crate::model::{LayerKind, QLayer};
use crate::sensitivity::Calibration;
use crate::util::Rng;

/// Model width the synthetic shapes are derived from.
const D: usize = 256;
/// Feed-forward width.
const FF: usize = 512;
/// Vocabulary (lm_head output dim).
const VOCAB: usize = 1024;
/// Tokens per forward (sets MAC counts / activation bytes).
const TOKENS: usize = 64;
/// Sum of per-layer sensitivities after normalization; together with EG2
/// this places the paper tau grid {0 .. 0.7%} across partial quantization.
const S_TOTAL: f64 = 0.3;
/// Loss second moment E[g^2] of the pseudo-calibration.
const EG2: f64 = 4.4;

struct Builder {
    nodes: Vec<Node>,
    edges: Vec<(usize, usize)>,
    qlayers: Vec<QLayer>,
}

impl Builder {
    fn tpc(&mut self, id: String, bytes: u64) -> usize {
        self.nodes.push(Node {
            id,
            kind: "op".into(),
            engine: GraphEngine::Tpc,
            qidx: -1,
            macs: 0,
            bytes_in: bytes,
            bytes_out: bytes,
            param_bytes: 0,
            c: 0,
            k: 0,
        });
        self.nodes.len() - 1
    }

    fn linear(&mut self, id: String, c: usize, k: usize) -> usize {
        let macs = (TOKENS * c * k) as u64;
        let params = (c * k) as u64;
        self.qlayers.push(QLayer {
            name: id.clone(),
            kind: LayerKind::Linear,
            c,
            k,
            macs,
            params,
        });
        self.nodes.push(Node {
            id,
            kind: "linear".into(),
            engine: GraphEngine::Mme,
            qidx: self.qlayers.len() as i32 - 1,
            macs,
            bytes_in: (TOKENS * c * 2) as u64,
            bytes_out: (TOKENS * k * 2) as u64,
            param_bytes: params * 2,
            c,
            k,
        });
        self.nodes.len() - 1
    }

    fn bgemm(&mut self, id: String, c: usize) -> usize {
        let macs = (TOKENS * TOKENS * c * 4) as u64;
        self.qlayers.push(QLayer {
            name: id.clone(),
            kind: LayerKind::Bgemm,
            c,
            k: c,
            macs,
            params: 0,
        });
        self.nodes.push(Node {
            id,
            kind: "bgemm".into(),
            engine: GraphEngine::Mme,
            qidx: self.qlayers.len() as i32 - 1,
            macs,
            bytes_in: (TOKENS * D * 2) as u64,
            bytes_out: (TOKENS * D * 2) as u64,
            param_bytes: 0,
            c,
            k: c,
        });
        self.nodes.len() - 1
    }

    fn edge(&mut self, from: usize, to: usize) {
        self.edges.push((from, to));
    }
}

/// Build a `blocks`-deep synthetic transformer: the graph, its quantizable
/// layer table, and a deterministic pseudo-calibration derived from `seed`.
pub fn demo_model(blocks: usize, seed: u64) -> (Graph, Vec<QLayer>, Calibration) {
    let act_bytes = (TOKENS * D * 2) as u64;
    let mut b = Builder { nodes: Vec::new(), edges: Vec::new(), qlayers: Vec::new() };

    let mut prev = b.tpc("embed".into(), act_bytes);
    for blk in 0..blocks {
        // Attention: {q, k, v} in parallel, qk_matmul, softmax, av_matmul.
        let q = b.linear(format!("blk{blk}.q_proj"), D, D);
        let k = b.linear(format!("blk{blk}.k_proj"), D, D);
        let v = b.linear(format!("blk{blk}.v_proj"), D, D);
        b.edge(prev, q);
        b.edge(prev, k);
        b.edge(prev, v);
        let qk = b.bgemm(format!("blk{blk}.qk_matmul"), D / 4);
        b.edge(q, qk);
        b.edge(k, qk);
        let sm = b.tpc(format!("blk{blk}.softmax"), act_bytes);
        b.edge(qk, sm);
        let av = b.bgemm(format!("blk{blk}.av_matmul"), D / 4);
        b.edge(sm, av);
        b.edge(v, av);
        let o = b.linear(format!("blk{blk}.o_proj"), D, D);
        b.edge(av, o);
        let res1 = b.tpc(format!("blk{blk}.res1"), act_bytes);
        b.edge(o, res1);
        // MLP: {gate, up} in parallel, elementwise, down.
        let gate = b.linear(format!("blk{blk}.gate_proj"), D, FF);
        let up = b.linear(format!("blk{blk}.up_proj"), D, FF);
        b.edge(res1, gate);
        b.edge(res1, up);
        let act = b.tpc(format!("blk{blk}.act_mul"), act_bytes * 2);
        b.edge(gate, act);
        b.edge(up, act);
        let down = b.linear(format!("blk{blk}.down_proj"), FF, D);
        b.edge(act, down);
        let res2 = b.tpc(format!("blk{blk}.res2"), act_bytes);
        b.edge(down, res2);
        prev = res2;
    }
    let head = b.linear("lm_head".into(), D, VOCAB);
    b.edge(prev, head);
    let out = b.tpc("out".into(), act_bytes);
    b.edge(head, out);

    let qlayers = b.qlayers;
    let graph = Graph::synthetic(b.nodes, b.edges);
    let calibration = demo_calibration(qlayers.len(), seed);
    (graph, qlayers, calibration)
}

/// Deterministic pseudo-calibration: log-uniform sensitivity spread over
/// ~2 decades, normalized so the paper tau grid lands across partial
/// quantization (neither nothing nor everything fits the budget).
pub fn demo_calibration(n_qlayers: usize, seed: u64) -> Calibration {
    let mut rng = Rng::new(seed ^ 0xCA11_B8A7E);
    let mut s: Vec<f64> = (0..n_qlayers)
        .map(|_| 10f64.powf(rng.f64() * 2.0 - 1.0))
        .collect();
    let total: f64 = s.iter().sum();
    for x in s.iter_mut() {
        *x *= S_TOTAL / total;
    }
    Calibration { s, eg2: EG2, g_mean: EG2.sqrt() * 0.95, n_samples: 16 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::partition::{partition, validate_sequential};

    #[test]
    fn demo_partition_matches_paper_fig6_shape() {
        let (graph, qlayers, _) = demo_model(2, 1);
        assert_eq!(qlayers.len(), 2 * 9 + 1);
        assert_eq!(graph.qlayers.len(), qlayers.len());
        let p = partition(&graph).unwrap();
        // Per block: V1 = 5-layer attention, V2 = o_proj, V3 = {gate, up},
        // V4 = down_proj; plus the final lm_head group.
        let sizes: Vec<usize> = p.groups.iter().map(|g| g.len()).collect();
        assert_eq!(sizes, vec![5, 1, 2, 1, 5, 1, 2, 1, 1]);
        validate_sequential(&graph, &p).unwrap();
    }

    #[test]
    fn demo_calibration_is_deterministic_and_spread() {
        let a = demo_calibration(19, 7);
        let b = demo_calibration(19, 7);
        assert_eq!(a, b);
        let max = a.s.iter().cloned().fold(f64::MIN, f64::max);
        let min = a.s.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min > 3.0, "spread {min}..{max}");
        let total: f64 = a.s.iter().sum();
        assert!((total - S_TOTAL).abs() < 1e-12);
    }

    #[test]
    fn qidx_table_aligns_with_graph() {
        let (graph, qlayers, _) = demo_model(1, 2);
        for (i, name) in graph.qlayers.iter().enumerate() {
            assert_eq!(name, &qlayers[i].name);
        }
    }
}
