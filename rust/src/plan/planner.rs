//! The query half of the staged API: a [`Planner`] is assembled from the
//! three stage artifacts and answers multi-constraint [`PlanRequest`]s in
//! microseconds — one MCKP solve over precomputed gain/cost tables, no
//! calibration or measurement.  `Planner::frontier` precomputes the whole
//! tau -> gain Pareto curve for O(log n) serving-time lookups.

use super::artifact::{Calibrated, Measured, Partitioned};
use super::frontier::{self, Frontier};
use super::request::PlanRequest;
use super::{Plan, Provenance};
use crate::coordinator::strategy::{
    build_family, select_config_constrained, Family, Strategy,
};
use crate::exec::{ExecCfg, ExecPool};
use crate::metrics::{covered_layers, weight_bytes, Objective};
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::solver::{parametric, EPS};
use crate::timing::TimeMeasurements;
use anyhow::{bail, Result};
use std::sync::Mutex;

/// Immutable planning state for one model: artifacts + the three
/// precomputed IP families.  Plain data — `Send + Sync`, so serving layers
/// can share one instance across threads (see `plan::service`).
pub struct Planner {
    partitioned: Partitioned,
    calibrated: Calibrated,
    measured: Measured,
    families: [Family; 3],
    /// Per-family tau_max, precomputed at assembly (pure function of the
    /// artifacts) so budget-less requests stay O(solve), not O(tables).
    tau_maxes: [f64; 3],
    /// Worker budget for solves, sweeps, and frontier refinement.  Plans
    /// are bit-identical at any setting (exec determinism contract), so
    /// this is pure throughput tuning.
    exec: ExecCfg,
    /// Per-objective parametric-DP arenas ([`Objective::ALL`] order).  Each
    /// holds the committed level columns of its family's last IP frontier
    /// sweep, so a re-solve after a budget tweak or a single-group gain
    /// change reuses the clean prefix (`FrontierDp::solve_delta`).  Interior
    /// mutability keeps `frontier` callable through `&self`/`Arc<Planner>`;
    /// curves are bit-identical whether the arena is cold or warm.
    frontier_dp: [Mutex<parametric::FrontierDp>; 3],
}

impl Planner {
    /// Assemble and cross-validate the stage artifacts, precomputing the
    /// gain/cost tables for all three objective families.
    pub fn new(
        partitioned: Partitioned,
        calibrated: Calibrated,
        measured: Measured,
    ) -> Result<Planner> {
        if partitioned.model != calibrated.model || partitioned.model != measured.model {
            bail!(
                "artifact model mismatch: partitioned '{}', calibrated '{}', measured '{}'",
                partitioned.model,
                calibrated.model,
                measured.model
            );
        }
        let nq = partitioned.n_qlayers();
        if calibrated.calibration.s.len() != nq {
            bail!(
                "calibration covers {} layers but partition has {nq}",
                calibrated.calibration.s.len()
            );
        }
        if measured.measurements.groups.len() != partitioned.partition.groups.len() {
            bail!(
                "measurement has {} groups but partition has {}",
                measured.measurements.groups.len(),
                partitioned.partition.groups.len()
            );
        }
        for (mg, pg) in measured
            .measurements
            .groups
            .iter()
            .zip(&partitioned.partition.groups)
        {
            if mg.qidxs != pg.qidxs {
                bail!("measurement group {} does not match the partition", mg.group);
            }
        }
        if measured.formats != partitioned.formats {
            bail!("measurement format menu differs from the partition artifact");
        }
        let families = [
            Objective::EmpiricalTime,
            Objective::TheoreticalTime,
            Objective::Memory,
        ]
        .map(|o| {
            build_family(
                o,
                &partitioned.partition,
                &partitioned.qlayers,
                &partitioned.formats,
                &measured.measurements,
                &measured.device,
            )
        });
        let tau_maxes = [
            family_tau_max(&families[0], &calibrated.calibration),
            family_tau_max(&families[1], &calibrated.calibration),
            family_tau_max(&families[2], &calibrated.calibration),
        ];
        Ok(Planner {
            partitioned,
            calibrated,
            measured,
            families,
            tau_maxes,
            exec: ExecCfg::from_env(),
            frontier_dp: Default::default(),
        })
    }

    /// Set the worker budget for this planner's solves and sweeps.
    pub fn with_exec(mut self, exec: ExecCfg) -> Planner {
        self.exec = exec;
        self
    }

    pub fn exec(&self) -> ExecCfg {
        self.exec
    }

    pub fn model(&self) -> &str {
        &self.partitioned.model
    }

    /// The device the Measured artifact was produced on; every Plan this
    /// planner emits is stamped with it.
    pub fn device(&self) -> &crate::backend::DeviceProfile {
        &self.measured.device
    }

    pub fn n_qlayers(&self) -> usize {
        self.partitioned.n_qlayers()
    }

    pub fn partitioned(&self) -> &Partitioned {
        &self.partitioned
    }

    pub fn calibration(&self) -> &Calibration {
        &self.calibrated.calibration
    }

    pub fn measurements(&self) -> &TimeMeasurements {
        &self.measured.measurements
    }

    pub fn family(&self, objective: Objective) -> &Family {
        match objective {
            Objective::EmpiricalTime => &self.families[0],
            Objective::TheoreticalTime => &self.families[1],
            Objective::Memory => &self.families[2],
        }
    }

    /// The tau beyond which an objective's loss constraint is vacuous: the
    /// NRMSE of the family's maximal-MSE configuration (uncovered layers at
    /// BF16), plus headroom so that configuration itself is feasible.
    /// Precomputed once at assembly.
    pub fn tau_max(&self, objective: Objective) -> f64 {
        match objective {
            Objective::EmpiricalTime => self.tau_maxes[0],
            Objective::TheoreticalTime => self.tau_maxes[1],
            Objective::Memory => self.tau_maxes[2],
        }
    }

    /// Resolve one multi-constraint planning query.  Pure function of the
    /// artifacts: no calibration, measurement, or IO happens here.
    pub fn solve(&self, req: &PlanRequest) -> Result<Plan> {
        self.solve_on(req, &ExecPool::new(self.exec))
    }

    /// [`Planner::solve`] on an explicit pool.  Batch layers (sweep,
    /// frontier) pass [`ExecPool::sequential`] here: they already fan out
    /// across cells, and nesting a second full-width pool per solve would
    /// oversubscribe the cores without buying throughput.
    fn solve_on(&self, req: &PlanRequest, pool: &ExecPool) -> Result<Plan> {
        let family = self.family(req.objective);
        let calib = &self.calibrated.calibration;
        let qlayers = &self.partitioned.qlayers;
        if let Some(t) = req.tau {
            super::request::check_budget("loss budget tau", t)?;
        }
        if let Some(c) = req.memory_cap {
            super::request::check_budget("memory cap", c)?;
        }
        // A device-scoped request must match the device this planner's
        // measurements ran on (PlanService routes by device; a direct
        // mismatch here is a caller bug worth failing loudly on).
        if let Some(d) = &req.device {
            if d != &self.measured.device.name {
                bail!(
                    "request targets device '{d}' but this planner was measured on '{}'",
                    self.measured.device.name
                );
            }
        }
        // No loss budget = plan at tau_max (the constraint is vacuous and
        // only the remaining constraints bind).
        let tau = req.tau.unwrap_or_else(|| self.tau_max(req.objective));
        let memory = req.memory_cap.map(|cap| (qlayers.as_slice(), cap));
        let config =
            select_config_constrained(family, req.strategy, calib, tau, memory, req.seed, pool)?;
        let gain = family.gain_of(&config)?;
        let predicted_mse = calib.loss_mse(&config);
        let budget = calib.budget(tau);
        let bytes = weight_bytes(qlayers, &config);
        let mem_ok = req.memory_cap.map_or(true, |cap| bytes <= cap + EPS);
        let tm = &self.measured.measurements;
        Ok(Plan {
            model: self.partitioned.model.clone(),
            device: self.measured.device.name.clone(),
            objective: req.objective,
            strategy: req.strategy,
            tau,
            seed: req.seed,
            feasible: predicted_mse <= budget + EPS && mem_ok,
            gain,
            predicted_mse,
            budget,
            nrmse: calib.normalized_rmse(&config),
            predicted_ttft_us: tm.predict_ttft(&config),
            memory_cap: req.memory_cap,
            weight_bytes: bytes,
            provenance: Provenance {
                calib_samples: calib.n_samples,
                eg2: calib.eg2,
                n_groups: self.partitioned.partition.groups.len(),
                base_ttft_us: tm.base_ttft,
            },
            config,
        })
    }

    /// Precompute the Pareto frontier of the tau -> gain tradeoff for one
    /// (objective, strategy).
    ///
    /// For the IP strategy this is ONE parametric DP sweep over the group
    /// chain (`solver::parametric`): gains and loss-MSE costs are additive
    /// over the sequential sub-graphs, so the exact full curve falls out of
    /// a single dominance-pruned pass instead of one branch & bound solve
    /// per tau knot.  The state merge fans out over this planner's pool
    /// (bit-identical at any thread count).  The closed-form baseline
    /// strategies (Random/Prefix) keep the per-tau bisection sweep
    /// ([`Planner::frontier_via_bisection`]) — their selections are not
    /// MCKP solves, so there is no chain DP to exploit.
    ///
    /// `frontier.at(tau)` answers any threshold in O(log n) and agrees
    /// with a pointwise IP solve (asserted in tests against the bisection
    /// oracle).
    pub fn frontier(&self, objective: Objective, strategy: Strategy) -> Result<Frontier> {
        Ok(self.frontier_delta(objective, strategy)?.0)
    }

    /// [`Planner::frontier`], reporting how much committed DP state the
    /// solve reused.  The IP path runs through the objective's persistent
    /// [`parametric::FrontierDp`] arena: a warm re-solve after a tau-range
    /// change re-filters committed levels instead of re-merging the chain,
    /// and a single-group gain change re-merges only from that group
    /// rightward.  The curve is bit-identical to a cold solve either way.
    /// Non-IP strategies keep the bisection sweep and report a full solve.
    pub fn frontier_delta(
        &self,
        objective: Objective,
        strategy: Strategy,
    ) -> Result<(Frontier, parametric::FrontierDelta)> {
        if strategy != Strategy::Ip {
            let f = self.frontier_via_bisection(objective, strategy)?;
            let delta = parametric::FrontierDelta { full_solve: true, ..Default::default() };
            return Ok((f, delta));
        }
        let exec = self.exec;
        let slot = &self.frontier_dp[objective_slot(objective)];
        let mut delta = parametric::FrontierDelta { full_solve: true, ..Default::default() };
        let f = self.frontier_via(objective, |groups, calib, tau_max| {
            let mut dp = slot.lock().expect("frontier DP arena lock poisoned");
            let (solves, d) = crate::coordinator::ip::optimize_frontier_incremental(
                groups,
                calib,
                tau_max,
                &ExecPool::new(exec),
                &mut dp,
            )?;
            delta = d;
            Ok(solves)
        })?;
        Ok((f, delta))
    }

    /// Hand over another planner's committed frontier-DP arenas to this
    /// one.  `PlanService` calls this when a model is re-registered, so the
    /// replacement planner's first frontier solve can still reuse whatever
    /// levels survive the artifact diff (`Mckp::first_divergent_group`
    /// guards correctness — incompatible state triggers a full solve).
    pub fn adopt_frontier_state(&self, prev: &Planner) {
        if std::ptr::eq(self, prev) {
            return;
        }
        for (dst, src) in self.frontier_dp.iter().zip(&prev.frontier_dp) {
            let mut src = src.lock().expect("frontier DP arena lock poisoned");
            // Only move live state: the same planner pair is adopted once
            // per registry alias, and a second pass over an already-drained
            // source must not wipe what the first pass handed over.
            if src.has_commit() {
                let mut dst = dst.lock().expect("frontier DP arena lock poisoned");
                *dst = std::mem::take(&mut *src);
            }
        }
    }

    /// Arena telemetry of an objective's last committed IP frontier solve
    /// (zeros while cold) — surfaced by the solver bench.
    pub fn frontier_dp_stats(&self, objective: Objective) -> parametric::DpStats {
        self.frontier_dp[objective_slot(objective)]
            .lock()
            .expect("frontier DP arena lock poisoned")
            .stats()
    }

    /// The IP frontier with the eq.-5 sweep supplied by `solve` — the seam
    /// the distributed coordinator (`crate::dist`) plugs into: it runs the
    /// chain DP across worker PROCESSES, while knot materialization, curve
    /// assembly, and the incomplete-curve bisection fallback stay this
    /// planner's code, so a distributed frontier is byte-identical to the
    /// in-process one.
    pub fn frontier_via<F>(&self, objective: Objective, solve: F) -> Result<Frontier>
    where
        F: FnOnce(
            &[crate::metrics::GroupChoices],
            &Calibration,
            f64,
        ) -> Result<crate::coordinator::ip::FrontierSolves>,
    {
        let tau_max = self.tau_max(objective);
        let family = self.family(objective);
        let calib = &self.calibrated.calibration;
        let solves = solve(&family.groups, calib, tau_max)?;
        if !solves.complete {
            // The dominance state cap thinned the sweep (never observed at
            // paper scale): the surviving knots are proven optima, but the
            // knot SET may be incomplete and `at(tau)` between survivors
            // would under-report.  Serve the per-tau sweep instead — slower
            // but unconditionally faithful to pointwise solves.
            return self.frontier_via_bisection(objective, Strategy::Ip);
        }
        frontier::build(
            self.model(),
            objective,
            Strategy::Ip,
            calib.eg2,
            tau_max,
            solves
                .knots
                .into_iter()
                .map(|k| (k.predicted_mse, k.gain, k.config))
                .collect(),
        )
    }

    /// The per-tau bisection sweep the parametric DP replaced: the paper
    /// tau grid plus an even cover of [0, tau_max], refined at every gain
    /// step, one pointwise solve per probe.  Kept as the property-test and
    /// bench oracle (and as [`Planner::frontier`]'s path for the
    /// closed-form baseline strategies).
    pub fn frontier_via_bisection(
        &self,
        objective: Objective,
        strategy: Strategy,
    ) -> Result<Frontier> {
        let tau_max = self.tau_max(objective);
        let mut grid: Vec<f64> =
            crate::coordinator::paper_tau_grid().into_iter().filter(|t| *t <= tau_max).collect();
        const COVER: usize = 24;
        for i in 0..=COVER {
            grid.push(tau_max * i as f64 / COVER as f64);
        }
        frontier::sweep(
            self.model(),
            objective,
            strategy,
            self.calibrated.calibration.eg2,
            tau_max,
            &grid,
            &ExecPool::new(self.exec),
            |tau| {
                // Sequential inner solve: the sweep itself is the fan-out.
                let plan = self.solve_on(
                    &PlanRequest::new(objective).with_strategy(strategy).with_loss_budget(tau),
                    &ExecPool::sequential(),
                )?;
                Ok((plan.predicted_mse, plan.gain, plan.config))
            },
        )
    }

    /// Batch-solve a full grid; plans come back in (objective, strategy,
    /// tau) iteration order, each cell solved independently across this
    /// planner's pool.
    pub fn sweep(
        &self,
        objectives: &[Objective],
        strategies: &[Strategy],
        taus: &[f64],
        seed: u64,
    ) -> Result<Vec<Plan>> {
        let mut cells =
            Vec::with_capacity(objectives.len() * strategies.len() * taus.len());
        for &objective in objectives {
            for &strategy in strategies {
                for &tau in taus {
                    cells.push(
                        PlanRequest::new(objective)
                            .with_strategy(strategy)
                            .with_loss_budget(tau)
                            .with_seed(seed),
                    );
                }
            }
        }
        // Each cell is an independent pure solve (run sequentially inside:
        // the grid is the fan-out); batching keeps request order, so
        // output is identical to the sequential loop.
        let pool = ExecPool::new(self.exec);
        pool.try_par_map(cells.len(), |i| self.solve_on(&cells[i], &ExecPool::sequential()))
    }
}

/// Index of an objective's slot in the planner's `[_; 3]` arrays
/// ([`Objective::ALL`] order — matches `families`/`tau_maxes`).
fn objective_slot(objective: Objective) -> usize {
    match objective {
        Objective::EmpiricalTime => 0,
        Objective::TheoreticalTime => 1,
        Objective::Memory => 2,
    }
}

/// NRMSE of a family's maximal-MSE configuration (uncovered layers at
/// BF16), with headroom so that configuration itself is feasible at the
/// returned tau.  Pure function of the artifacts — computed once per
/// family at `Planner::new`.
fn family_tau_max(family: &Family, calib: &Calibration) -> f64 {
    let nq = calib.s.len();
    let covered = covered_layers(&family.groups, nq);
    let uncovered: f64 = (0..nq)
        .filter(|&l| !covered[l])
        .map(|l| calib.layer_mse(l, Format::Bf16))
        .sum();
    let max_mse: f64 = family
        .groups
        .iter()
        .map(|g| {
            g.configs
                .iter()
                .map(|cfg| calib.group_mse(&g.qidxs, cfg))
                .fold(0.0, f64::max)
        })
        .sum::<f64>()
        + uncovered;
    (max_mse / calib.eg2).sqrt() * (1.0 + 1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::demo::demo_model;
    use crate::plan::Engine;

    fn demo_planner() -> Planner {
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        engine.planner("demo").unwrap()
    }

    fn req(objective: Objective, tau: f64) -> PlanRequest {
        PlanRequest::new(objective).with_loss_budget(tau)
    }

    #[test]
    fn ip_plans_respect_budget() {
        let planner = demo_planner();
        for objective in Objective::ALL {
            for tau in [0.001, 0.004, 0.007] {
                let plan = planner.solve(&req(objective, tau)).unwrap();
                assert!(plan.feasible, "{objective:?} tau {tau}");
                assert!(plan.predicted_mse <= plan.budget + 1e-12);
                assert_eq!(plan.config.len(), planner.n_qlayers());
            }
        }
    }

    #[test]
    fn negative_or_nan_constraints_are_rejected() {
        let planner = demo_planner();
        assert!(planner.solve(&req(Objective::EmpiricalTime, -0.004)).is_err());
        assert!(planner.solve(&req(Objective::EmpiricalTime, f64::NAN)).is_err());
        assert!(planner
            .solve(&req(Objective::EmpiricalTime, 0.004).with_memory_cap(-1.0))
            .is_err());
    }

    #[test]
    fn tau_zero_returns_all_bf16() {
        let planner = demo_planner();
        let plan = planner.solve(&req(Objective::EmpiricalTime, 0.0)).unwrap();
        assert_eq!(plan.config.n_quantized(), 0);
    }

    #[test]
    fn gain_monotone_in_tau_for_ip() {
        let planner = demo_planner();
        let mut last = -1.0;
        for tau in [0.001, 0.002, 0.004, 0.007] {
            let plan = planner.solve(&req(Objective::EmpiricalTime, tau)).unwrap();
            assert!(plan.gain >= last - 1e-9, "tau {tau}: {} < {last}", plan.gain);
            last = plan.gain;
        }
    }

    #[test]
    fn plans_are_stamped_with_the_planner_device() {
        let planner = demo_planner();
        assert_eq!(planner.device().name, "gaudi2");
        let plan = planner.solve(&req(Objective::EmpiricalTime, 0.004)).unwrap();
        assert_eq!(plan.device, "gaudi2");
        // Matching device-scoped requests resolve; mismatches fail loudly.
        let ok = planner
            .solve(&req(Objective::EmpiricalTime, 0.004).with_device("gaudi2"))
            .unwrap();
        assert_eq!(ok.config, plan.config);
        assert!(planner
            .solve(&req(Objective::EmpiricalTime, 0.004).with_device("gaudi3"))
            .is_err());
    }

    #[test]
    fn no_loss_budget_plans_at_tau_max() {
        let planner = demo_planner();
        let plan = planner.solve(&PlanRequest::new(Objective::EmpiricalTime)).unwrap();
        assert!(plan.feasible);
        // Loss constraint vacuous: everything profitable gets quantized.
        let at_max = planner
            .solve(&req(Objective::EmpiricalTime, planner.tau_max(Objective::EmpiricalTime)))
            .unwrap();
        assert_eq!(plan.config, at_max.config);
    }

    #[test]
    fn memory_cap_binds_and_is_reported() {
        let planner = demo_planner();
        let free = planner.solve(&req(Objective::EmpiricalTime, 0.007)).unwrap();
        assert!(free.memory_cap.is_none());
        // Cap strictly below the unconstrained plan's bytes.
        let cap = free.weight_bytes * 0.9;
        let capped = planner
            .solve(&req(Objective::EmpiricalTime, 0.007).with_memory_cap(cap))
            .unwrap();
        assert_eq!(capped.memory_cap, Some(cap));
        assert!(capped.weight_bytes <= cap + 1e-9, "{} > {cap}", capped.weight_bytes);
        assert!(capped.predicted_mse <= capped.budget + 1e-12);
        assert!(capped.feasible);
        assert!(capped.gain <= free.gain + 1e-9);
    }

    #[test]
    fn sweep_covers_grid() {
        let planner = demo_planner();
        let taus = [0.0, 0.004];
        let plans = planner
            .sweep(&Objective::ALL, &Strategy::ALL, &taus, 0)
            .unwrap();
        assert_eq!(plans.len(), 3 * 3 * 2);
        // Every plan round-trips through JSON exactly.
        for p in &plans {
            let text = p.to_json().to_string();
            let back = Plan::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(&back, p);
        }
    }

    #[test]
    fn memory_family_keeps_bgemm_at_baseline() {
        let planner = demo_planner();
        let plan = planner.solve(&req(Objective::Memory, 0.01)).unwrap();
        for (l, q) in planner.partitioned().qlayers.iter().enumerate() {
            if q.kind == crate::model::LayerKind::Bgemm {
                assert_eq!(plan.config.get(l), Format::Bf16, "{}", q.name);
            }
        }
    }

    #[test]
    fn tau_max_makes_every_family_fully_feasible() {
        let planner = demo_planner();
        for objective in Objective::ALL {
            let tmax = planner.tau_max(objective);
            assert!(tmax > 0.0);
            let plan = planner.solve(&req(objective, tmax)).unwrap();
            assert!(plan.feasible, "{objective:?}");
            // Larger taus change nothing.
            let beyond = planner.solve(&req(objective, tmax * 2.0)).unwrap();
            assert_eq!(plan.config, beyond.config, "{objective:?}");
        }
    }
}
