//! The query half of the staged API: a [`Planner`] is assembled from the
//! three stage artifacts and answers `plan(objective, strategy, tau)` in
//! microseconds — one MCKP solve over precomputed gain/cost tables, no
//! calibration or measurement.

use super::artifact::{Calibrated, Measured, Partitioned};
use super::{Plan, Provenance};
use crate::coordinator::strategy::{build_family, select_config, Family, Strategy};
use crate::gaudisim::MpConfig;
use crate::metrics::Objective;
use crate::numerics::Format;
use crate::sensitivity::Calibration;
use crate::timing::TimeMeasurements;
use anyhow::{anyhow, bail, Result};

/// Immutable planning state for one model: artifacts + the three
/// precomputed IP families.
pub struct Planner {
    partitioned: Partitioned,
    calibrated: Calibrated,
    measured: Measured,
    families: [Family; 3],
}

impl Planner {
    /// Assemble and cross-validate the stage artifacts, precomputing the
    /// gain/cost tables for all three objective families.
    pub fn new(
        partitioned: Partitioned,
        calibrated: Calibrated,
        measured: Measured,
    ) -> Result<Planner> {
        if partitioned.model != calibrated.model || partitioned.model != measured.model {
            bail!(
                "artifact model mismatch: partitioned '{}', calibrated '{}', measured '{}'",
                partitioned.model,
                calibrated.model,
                measured.model
            );
        }
        let nq = partitioned.n_qlayers();
        if calibrated.calibration.s.len() != nq {
            bail!(
                "calibration covers {} layers but partition has {nq}",
                calibrated.calibration.s.len()
            );
        }
        if measured.measurements.groups.len() != partitioned.partition.groups.len() {
            bail!(
                "measurement has {} groups but partition has {}",
                measured.measurements.groups.len(),
                partitioned.partition.groups.len()
            );
        }
        for (mg, pg) in measured
            .measurements
            .groups
            .iter()
            .zip(&partitioned.partition.groups)
        {
            if mg.qidxs != pg.qidxs {
                bail!("measurement group {} does not match the partition", mg.group);
            }
        }
        if measured.formats != partitioned.formats {
            bail!("measurement format menu differs from the partition artifact");
        }
        let families = [
            Objective::EmpiricalTime,
            Objective::TheoreticalTime,
            Objective::Memory,
        ]
        .map(|o| {
            build_family(
                o,
                &partitioned.partition,
                &partitioned.qlayers,
                &partitioned.formats,
                &measured.measurements,
            )
        });
        Ok(Planner { partitioned, calibrated, measured, families })
    }

    pub fn model(&self) -> &str {
        &self.partitioned.model
    }

    pub fn n_qlayers(&self) -> usize {
        self.partitioned.n_qlayers()
    }

    pub fn partitioned(&self) -> &Partitioned {
        &self.partitioned
    }

    pub fn calibration(&self) -> &Calibration {
        &self.calibrated.calibration
    }

    pub fn measurements(&self) -> &TimeMeasurements {
        &self.measured.measurements
    }

    pub fn family(&self, objective: Objective) -> &Family {
        match objective {
            Objective::EmpiricalTime => &self.families[0],
            Objective::TheoreticalTime => &self.families[1],
            Objective::Memory => &self.families[2],
        }
    }

    /// Answer one planning query.  Pure function of the artifacts: no
    /// calibration, measurement, or IO happens here.
    pub fn plan(
        &self,
        objective: Objective,
        strategy: Strategy,
        tau: f64,
        seed: u64,
    ) -> Result<Plan> {
        let family = self.family(objective);
        let calib = &self.calibrated.calibration;
        let config = select_config(family, strategy, calib, tau, seed)?;
        let gain = family_gain(family, &config)?;
        let predicted_mse = calib.loss_mse(&config);
        let budget = calib.budget(tau);
        let tm = &self.measured.measurements;
        Ok(Plan {
            model: self.partitioned.model.clone(),
            objective,
            strategy,
            tau,
            seed,
            feasible: predicted_mse <= budget + 1e-12,
            gain,
            predicted_mse,
            budget,
            nrmse: calib.normalized_rmse(&config),
            predicted_ttft_us: tm.predict_ttft(&config),
            provenance: Provenance {
                calib_samples: calib.n_samples,
                eg2: calib.eg2,
                n_groups: self.partitioned.partition.groups.len(),
                base_ttft_us: tm.base_ttft,
            },
            config,
        })
    }

    /// Batch-solve a full grid; plans come back in (objective, strategy,
    /// tau) iteration order.
    pub fn sweep(
        &self,
        objectives: &[Objective],
        strategies: &[Strategy],
        taus: &[f64],
        seed: u64,
    ) -> Result<Vec<Plan>> {
        let mut plans =
            Vec::with_capacity(objectives.len() * strategies.len() * taus.len());
        for &objective in objectives {
            for &strategy in strategies {
                for &tau in taus {
                    plans.push(self.plan(objective, strategy, tau, seed)?);
                }
            }
        }
        Ok(plans)
    }
}

/// Objective-family gain of a full configuration: sum over groups of the
/// gain at the group's matching configuration column.  Layers not covered
/// by the family (e.g. BGEMM under IP-M) contribute nothing.
fn family_gain(family: &Family, cfg: &MpConfig) -> Result<f64> {
    let mut total = 0.0;
    for g in &family.groups {
        let key: Vec<Format> = g.qidxs.iter().map(|&q| cfg.get(q)).collect();
        let p = g
            .configs
            .iter()
            .position(|c| c == &key)
            .ok_or_else(|| anyhow!("configuration not in the group's enumeration"))?;
        total += g.gains[p];
    }
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::demo::demo_model;
    use crate::plan::Engine;

    fn demo_planner() -> Planner {
        let (graph, qlayers, calibration) = demo_model(2, 7);
        let mut engine = Engine::new();
        engine.register_synthetic("demo", graph, qlayers, calibration);
        engine.planner("demo").unwrap()
    }

    #[test]
    fn ip_plans_respect_budget() {
        let planner = demo_planner();
        for objective in Objective::ALL {
            for tau in [0.001, 0.004, 0.007] {
                let plan = planner.plan(objective, Strategy::Ip, tau, 0).unwrap();
                assert!(plan.feasible, "{objective:?} tau {tau}");
                assert!(plan.predicted_mse <= plan.budget + 1e-12);
                assert_eq!(plan.config.len(), planner.n_qlayers());
            }
        }
    }

    #[test]
    fn tau_zero_returns_all_bf16() {
        let planner = demo_planner();
        let plan = planner
            .plan(Objective::EmpiricalTime, Strategy::Ip, 0.0, 0)
            .unwrap();
        assert_eq!(plan.config.n_quantized(), 0);
    }

    #[test]
    fn gain_monotone_in_tau_for_ip() {
        let planner = demo_planner();
        let mut last = -1.0;
        for tau in [0.001, 0.002, 0.004, 0.007] {
            let plan = planner
                .plan(Objective::EmpiricalTime, Strategy::Ip, tau, 0)
                .unwrap();
            assert!(plan.gain >= last - 1e-9, "tau {tau}: {} < {last}", plan.gain);
            last = plan.gain;
        }
    }

    #[test]
    fn sweep_covers_grid() {
        let planner = demo_planner();
        let taus = [0.0, 0.004];
        let plans = planner
            .sweep(&Objective::ALL, &Strategy::ALL, &taus, 0)
            .unwrap();
        assert_eq!(plans.len(), 3 * 3 * 2);
        // Every plan round-trips through JSON exactly.
        for p in &plans {
            let text = p.to_json().to_string();
            let back = Plan::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(&back, p);
        }
    }

    #[test]
    fn memory_family_keeps_bgemm_at_baseline() {
        let planner = demo_planner();
        let plan = planner
            .plan(Objective::Memory, Strategy::Ip, 0.01, 0)
            .unwrap();
        for (l, q) in planner.partitioned().qlayers.iter().enumerate() {
            if q.kind == crate::model::LayerKind::Bgemm {
                assert_eq!(plan.config.get(l), Format::Bf16, "{}", q.name);
            }
        }
    }
}
