//! Staged planning API (the 0.4 public surface).
//!
//! The paper's Algorithm 1 is explicitly staged — partition (Algorithm 2),
//! sensitivity calibration (eq. 21), per-group time-gain measurement
//! (§2.3.1), then one IP solve per query (eq. 5).  This module exposes
//! exactly that seam:
//!
//! * [`Engine`] owns the runtime and a multi-model registry and produces
//!   the typed stage artifacts [`Partitioned`] -> [`Calibrated`] ->
//!   [`Measured`], each cached in memory and (optionally) on disk under
//!   `artifacts/cache/<model>/<stage>.json`.  Each arrow is an explicit
//!   [`Stage`] value (see [`stage`]) whose inner loops fan out over the
//!   engine's `crate::exec::ExecPool` — bit-identical artifacts at any
//!   `--threads` setting;
//! * [`PlanRequest`] is the multi-constraint query builder — loss budget,
//!   memory cap, strategy, seed, target device — resolved by
//!   [`Planner::solve`] against the artifacts in microseconds, with no
//!   recomputation;
//! * [`Planner::frontier`] precomputes the whole tau -> gain Pareto curve
//!   ([`Frontier`], JSON-round-trippable) for O(log n) `at(tau)` lookups —
//!   for the IP strategy in ONE parametric chain-DP sweep
//!   (`solver::parametric`), not one IP solve per tau knot;
//! * [`PlanService`] is the `Send + Sync` serving handle: `Arc<Planner>`s
//!   per (model, device) plus an interior frontier cache for concurrent
//!   callers;
//! * [`Plan`] is the self-contained, JSON-round-trippable answer:
//!   configuration + predicted MSE + gain + weight bytes + device +
//!   provenance.
//!
//! Hardware enters through `backend::DeviceProfile`
//! (`Engine::with_device`): the Measured stage simulates that device and
//! its cache entries are keyed by it, so per-device measurements never
//! collide.
//!
//! ```no_run
//! use ampq::metrics::Objective;
//! use ampq::coordinator::{paper_tau_grid, Strategy};
//! use ampq::plan::{Engine, PlanRequest};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut engine = Engine::new()
//!     .with_artifacts_root("artifacts")
//!     .with_cache_dir("artifacts/cache");
//! let planner = engine.planner("tiny-s")?; // stages run (or load) once
//! let plan = planner.solve(
//!     &PlanRequest::new(Objective::EmpiricalTime)
//!         .with_loss_budget(0.004)
//!         .with_memory_cap(1.5e6)
//!         .with_strategy(Strategy::Ip),
//! )?;
//! println!("{}", plan.to_json().to_string());
//! let frontier = planner.frontier(Objective::EmpiricalTime, Strategy::Ip)?;
//! for tau in paper_tau_grid() {
//!     println!("tau {tau}: gain {}", frontier.at(tau).gain);
//! }
//! # Ok(())
//! # }
//! ```
//!
//! (The 0.2 scalar query `Planner::plan(...)` and the pre-0.2
//! `coordinator::Pipeline`, both deprecated for one release, are gone as
//! of 0.4 — see DESIGN.md §4 for the migration table.)

pub mod artifact;
pub mod demo;
pub mod engine;
pub mod frontier;
pub mod planner;
pub mod request;
pub mod service;
pub mod stage;

pub use self::artifact::{Calibrated, Measured, Partitioned, SCHEMA_VERSION};
pub use self::engine::{Engine, EngineCounters};
pub use self::frontier::{Frontier, FrontierPoint};
pub use self::planner::Planner;
pub use self::request::PlanRequest;
pub use self::service::{load_requests, PlanService, ServeRequest};
pub use self::stage::{CalibSource, CalibrateStage, MeasureStage, PartitionStage, Stage, StageIo};
// The IP solve outcome is part of the planning surface (Plans embed its
// numbers); re-exported so callers stop reaching into `coordinator`.
pub use crate::coordinator::IpOutcome;

use crate::coordinator::Strategy;
use crate::gaudisim::MpConfig;
use crate::metrics::Objective;
use crate::numerics::Format;
use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use self::artifact::{check_header, formats_to_json, num, unum};

/// Where a Plan's numbers came from — enough to audit or reproduce it.
#[derive(Clone, Debug, PartialEq)]
pub struct Provenance {
    /// Calibration sample count R behind the sensitivities.
    pub calib_samples: usize,
    /// Loss second moment E[g^2] the budget is scaled by.
    pub eg2: f64,
    /// Number of sequential sub-graphs in the partition.
    pub n_groups: usize,
    /// Baseline (all-BF16) TTFT of the measurement pass, microseconds.
    pub base_ttft_us: f64,
}

/// A self-contained planning answer for one (objective, strategy, tau)
/// query: the chosen configuration plus every number needed to act on it.
/// Round-trips through JSON exactly (`Plan::from_json(plan.to_json()) ==
/// plan`).
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub model: String,
    /// Name of the device profile the gain tables were measured on.
    pub device: String,
    pub objective: Objective,
    pub strategy: Strategy,
    pub tau: f64,
    /// Seed used by seeded strategies (Random); recorded for reproduction.
    pub seed: u64,
    pub config: MpConfig,
    /// False when even the all-baseline configuration exceeds the budget
    /// (the paper's tau = 0 edge); `config` is then all-BF16.
    pub feasible: bool,
    /// Objective-family gain of `config` (us for ET, BF16-MAC units for TT,
    /// bytes for M).
    pub gain: f64,
    /// Predicted loss MSE d of the full configuration (eq. 6).
    pub predicted_mse: f64,
    /// The constraint budget tau^2 E[g^2].
    pub budget: f64,
    /// Normalized RMSE sqrt(d / E[g^2]) — directly comparable to tau.
    pub nrmse: f64,
    /// Group-additive TTFT prediction for `config`, microseconds (eq. 7).
    pub predicted_ttft_us: f64,
    /// Weight-byte cap the request imposed (None = unconstrained).  When
    /// set, `feasible` also requires `weight_bytes <= memory_cap`.
    pub memory_cap: Option<f64>,
    /// Total stored weight bytes of `config` (params at chosen widths).
    pub weight_bytes: f64,
    pub provenance: Provenance,
}

impl Plan {
    pub fn to_json(&self) -> Json {
        let config = formats_to_json(&self.config.0);
        let prov = Json::Obj(vec![
            ("calib_samples".into(), unum(self.provenance.calib_samples)),
            ("eg2".into(), num(self.provenance.eg2)),
            ("n_groups".into(), unum(self.provenance.n_groups)),
            ("base_ttft_us".into(), num(self.provenance.base_ttft_us)),
        ]);
        let mut kv: Vec<(String, Json)> = vec![
            ("schema".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("kind".into(), Json::Str("plan".into())),
            ("model".into(), Json::Str(self.model.clone())),
            ("device".into(), Json::Str(self.device.clone())),
            ("objective".into(), Json::Str(self.objective.key().into())),
            ("strategy".into(), Json::Str(self.strategy.key().into())),
            ("tau".into(), num(self.tau)),
            // u64 seeds go through a string so values >= 2^53 round-trip
            // exactly (JSON numbers are f64).
            ("seed".into(), Json::Str(self.seed.to_string())),
            ("config".into(), config),
            ("feasible".into(), Json::Bool(self.feasible)),
            ("gain".into(), num(self.gain)),
            ("predicted_mse".into(), num(self.predicted_mse)),
            ("budget".into(), num(self.budget)),
            ("nrmse".into(), num(self.nrmse)),
            ("predicted_ttft_us".into(), num(self.predicted_ttft_us)),
        ];
        // Optional constraint field: emitted only when the request set it.
        if let Some(cap) = self.memory_cap {
            kv.push(("memory_cap".into(), num(cap)));
        }
        kv.push(("weight_bytes".into(), num(self.weight_bytes)));
        kv.push(("provenance".into(), prov));
        Json::Obj(kv)
    }

    pub fn from_json(j: &Json) -> Result<Plan> {
        check_header(j, "plan")?;
        let objective_key = j.get("objective")?.str()?;
        let objective = Objective::from_key(objective_key)
            .ok_or_else(|| anyhow!("unknown objective '{objective_key}'"))?;
        let strategy_key = j.get("strategy")?.str()?;
        let strategy = Strategy::from_key(strategy_key)
            .ok_or_else(|| anyhow!("unknown strategy '{strategy_key}'"))?;
        let config = j
            .get("config")?
            .arr()?
            .iter()
            .map(|x| {
                let name = x.str()?;
                Format::from_name(name).ok_or_else(|| anyhow!("unknown format '{name}'"))
            })
            .collect::<Result<Vec<_>>>()?;
        let feasible = match j.get("feasible")? {
            Json::Bool(b) => *b,
            _ => bail!("'feasible' must be a bool"),
        };
        let pj = j.get("provenance")?;
        Ok(Plan {
            model: j.get("model")?.str()?.to_string(),
            // 0.3-era Plans predate the backend subsystem; they were all
            // implicitly measured on the gaudi2 defaults.
            device: match j.opt("device") {
                None => crate::backend::DEFAULT_DEVICE.to_string(),
                Some(x) => x.str()?.to_string(),
            },
            objective,
            strategy,
            tau: j.get("tau")?.f64()?,
            seed: j.get("seed")?.str()?.parse::<u64>()?,
            config: MpConfig(config),
            feasible,
            gain: j.get("gain")?.f64()?,
            predicted_mse: j.get("predicted_mse")?.f64()?,
            budget: j.get("budget")?.f64()?,
            nrmse: j.get("nrmse")?.f64()?,
            predicted_ttft_us: j.get("predicted_ttft_us")?.f64()?,
            memory_cap: match j.opt("memory_cap") {
                None => None,
                Some(x) => Some(x.f64()?),
            },
            // 0.2-era Plans (same schema version) predate this field; 0.0
            // marks "unknown" so old artifacts keep parsing.
            weight_bytes: match j.opt("weight_bytes") {
                None => 0.0,
                Some(x) => x.f64()?,
            },
            provenance: Provenance {
                calib_samples: pj.get("calib_samples")?.usize()?,
                eg2: pj.get("eg2")?.f64()?,
                n_groups: pj.get("n_groups")?.usize()?,
                base_ttft_us: pj.get("base_ttft_us")?.f64()?,
            },
        })
    }

    /// One-line human summary (the CLI's non-JSON output row).
    pub fn summary(&self) -> String {
        let mem = match self.memory_cap {
            Some(cap) => format!(" bytes={:.3e}/cap={:.3e}", self.weight_bytes, cap),
            None => String::new(),
        };
        format!(
            "{} {} {} tau={:.4} nq={}/{} gain={:.3} mse={:.3e} budget={:.3e} ttft={:.1}us{}{}",
            self.model,
            self.objective.name(),
            self.strategy.name(),
            self.tau,
            self.config.n_quantized(),
            self.config.len(),
            self.gain,
            self.predicted_mse,
            self.budget,
            self.predicted_ttft_us,
            mem,
            if self.feasible { "" } else { " (infeasible: baseline fallback)" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_fixture() -> Plan {
        Plan {
            model: "demo".into(),
            device: "gaudi2".into(),
            objective: Objective::EmpiricalTime,
            strategy: Strategy::Ip,
            tau: 0.004,
            seed: u64::MAX - 7, // > 2^53: must survive the round-trip exactly
            config: MpConfig(vec![Format::Bf16, Format::Fp8E4m3, Format::Fp8E4m3]),
            feasible: true,
            gain: 41.625,
            predicted_mse: 3.0517578125e-5,
            budget: 7.04e-5,
            nrmse: 0.00263,
            predicted_ttft_us: 812.375,
            memory_cap: None,
            weight_bytes: 196608.0,
            provenance: Provenance {
                calib_samples: 16,
                eg2: 4.4,
                n_groups: 9,
                base_ttft_us: 854.0,
            },
        }
    }

    #[test]
    fn json_roundtrip_exact() {
        let p = plan_fixture();
        let text = p.to_json().to_string();
        let back = Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn summary_mentions_strategy_and_tau() {
        let s = plan_fixture().summary();
        assert!(s.contains("IP"));
        assert!(s.contains("0.0040"));
    }

    #[test]
    fn parses_02_era_plans_without_weight_bytes() {
        let p = plan_fixture();
        let mut j = p.to_json();
        if let Json::Obj(kv) = &mut j {
            kv.retain(|(k, _)| k != "weight_bytes");
        }
        let back = Plan::from_json(&j).unwrap();
        assert_eq!(back.weight_bytes, 0.0); // "unknown" marker
        assert_eq!(back.config, p.config);
    }

    #[test]
    fn parses_03_era_plans_without_device() {
        let p = plan_fixture();
        let mut j = p.to_json();
        if let Json::Obj(kv) = &mut j {
            kv.retain(|(k, _)| k != "device");
        }
        let back = Plan::from_json(&j).unwrap();
        // Pre-backend plans were all implicitly gaudi2.
        assert_eq!(back.device, "gaudi2");
        assert_eq!(back.config, p.config);
    }

    #[test]
    fn memory_cap_roundtrips_when_present() {
        let mut p = plan_fixture();
        p.memory_cap = Some(2.5e5);
        let text = p.to_json().to_string();
        let back = Plan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p);
        assert!(p.summary().contains("cap="));
        assert!(!plan_fixture().summary().contains("cap="));
    }

    #[test]
    fn rejects_other_kinds() {
        let p = plan_fixture();
        let mut j = p.to_json();
        if let Json::Obj(kv) = &mut j {
            kv[1].1 = Json::Str("partitioned".into());
        }
        assert!(Plan::from_json(&j).is_err());
    }
}
