//! Explicit stage declarations for the Engine's artifact pipeline.
//!
//! The paper's Algorithm 1 is a dataflow — partition (Algorithm 2) ->
//! sensitivity calibration (§2.2) -> per-group time-gain measurement
//! (§2.3.1) — and since 0.5 each arrow is a [`Stage`] value: a struct
//! holding the stage's declared inputs, producing its artifact through
//! [`Stage::run`] on an [`ExecPool`].  The [`StageIo`] constant names the
//! dataflow edges (what the stage consumes and what it produces) so the
//! wiring is inspectable — `Engine` drives the stages and keeps the
//! cache/counter bookkeeping around them:
//!
//! ```text
//!   graph ──> PartitionStage ──> Partitioned ─┬─> MeasureStage ──> Measured
//!   menu  ──────^                             │       ^── device, seed, reps
//!   calib set ──> CalibrateStage ─> Calibrated│
//!                     (per-sample fan-out)    │  (per-(group, config) fan-out)
//!                                             v
//!                              Planner::new(Partitioned, Calibrated, Measured)
//! ```
//!
//! Stages fan their inner loops out over the pool; every stage obeys the
//! exec layer's determinism contract (bit-identical artifacts at any
//! thread count), property-tested in `tests/parallel.rs`.

use super::artifact::{Calibrated, Measured, Partitioned};
use crate::backend::DeviceProfile;
use crate::exec::ExecPool;
use crate::graph::partition::partition;
use crate::graph::Graph;
use crate::model::QLayer;
use crate::numerics::Format;
use crate::runtime::ModelRuntime;
use crate::sensitivity::{calibrate, Calibration};
use crate::timing::{measure_groups, SimTtft};
use anyhow::Result;

/// Declared dataflow of one stage: its name plus the names of the inputs
/// it consumes and the artifacts it produces.
#[derive(Clone, Copy, Debug)]
pub struct StageIo {
    pub name: &'static str,
    pub inputs: &'static [&'static str],
    pub outputs: &'static [&'static str],
}

/// One Engine stage: inputs are held by the stage value, the output is the
/// stage artifact.  `run` may fan out over the pool but must return
/// bit-identical output at any thread count.
pub trait Stage {
    type Output;
    /// The stage's declared dataflow edges.
    const IO: StageIo;
    fn run(&self, pool: &ExecPool) -> Result<Self::Output>;
}

/// Stage 1 — Algorithm 2: partition the model DAG into sequential
/// sub-graphs and bind the (device-restricted) format menu.
pub struct PartitionStage<'a> {
    pub model: &'a str,
    pub graph: &'a Graph,
    pub qlayers: &'a [QLayer],
    pub menu: &'a [Format],
}

impl Stage for PartitionStage<'_> {
    type Output = Partitioned;
    const IO: StageIo = StageIo {
        name: "partition",
        inputs: &["graph", "qlayers", "menu"],
        outputs: &["partitioned"],
    };

    fn run(&self, _pool: &ExecPool) -> Result<Partitioned> {
        // The SESE walk is a cheap sequential graph pass; nothing to fan out.
        let part = partition(self.graph)?;
        Ok(Partitioned {
            model: self.model.to_string(),
            formats: self.menu.to_vec(),
            qlayers: self.qlayers.to_vec(),
            partition: part,
        })
    }
}

/// Where a calibration comes from: injected (synthetic models, tests) or
/// computed by the AOT sensitivity executable over the calibration set.
pub enum CalibSource<'a> {
    Injected(&'a Calibration),
    Runtime { mr: &'a ModelRuntime, samples: &'a [Vec<i32>] },
}

/// Stage 2 — sensitivity calibration (eq. 21): per-layer s_l and E[g^2],
/// averaged over the calibration samples (fanned out per sample).
pub struct CalibrateStage<'a> {
    pub model: &'a str,
    pub source: CalibSource<'a>,
}

impl Stage for CalibrateStage<'_> {
    type Output = Calibrated;
    const IO: StageIo = StageIo {
        name: "calibrate",
        inputs: &["calibration set", "sensitivity executable"],
        outputs: &["calibrated"],
    };

    fn run(&self, pool: &ExecPool) -> Result<Calibrated> {
        let calibration = match &self.source {
            CalibSource::Injected(c) => (*c).clone(),
            CalibSource::Runtime { mr, samples } => calibrate(mr, samples, pool)?,
        };
        Ok(Calibrated { model: self.model.to_string(), calibration })
    }
}

/// Stage 3 — per-group time-gain measurement (§2.3.1) on the device's
/// simulator, fanned out per (group, configuration) with per-measurement
/// noise streams.
pub struct MeasureStage<'a> {
    pub model: &'a str,
    pub graph: &'a Graph,
    pub partitioned: &'a Partitioned,
    pub device: &'a DeviceProfile,
    pub seed: u64,
    pub reps: usize,
}

impl Stage for MeasureStage<'_> {
    type Output = Measured;
    const IO: StageIo = StageIo {
        name: "measure",
        inputs: &["graph", "partitioned", "device", "seed", "reps"],
        outputs: &["measured"],
    };

    fn run(&self, pool: &ExecPool) -> Result<Measured> {
        let src = SimTtft::for_device(self.graph, self.device, self.seed, self.reps);
        let tm =
            measure_groups(&src, &self.partitioned.partition, &self.partitioned.formats, pool)?;
        Ok(Measured {
            model: self.model.to_string(),
            formats: self.partitioned.formats.clone(),
            seed: self.seed,
            reps: self.reps,
            device: self.device.clone(),
            measurements: tm,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecCfg;
    use crate::plan::demo::demo_model;

    #[test]
    fn stage_io_declarations_cover_the_dataflow() {
        assert_eq!(PartitionStage::IO.name, "partition");
        assert!(PartitionStage::IO.inputs.contains(&"graph"));
        assert_eq!(PartitionStage::IO.outputs, &["partitioned"]);
        assert_eq!(CalibrateStage::IO.name, "calibrate");
        assert_eq!(MeasureStage::IO.name, "measure");
        assert!(MeasureStage::IO.inputs.contains(&"partitioned"));
    }

    #[test]
    fn stages_compose_into_planner_inputs() {
        let (graph, qlayers, calibration) = demo_model(1, 3);
        let pool = ExecPool::new(ExecCfg::new(2));
        let menu = crate::numerics::PAPER_FORMATS.to_vec();
        let partitioned =
            PartitionStage { model: "demo", graph: &graph, qlayers: &qlayers, menu: &menu }
                .run(&pool)
                .unwrap();
        let calibrated =
            CalibrateStage { model: "demo", source: CalibSource::Injected(&calibration) }
                .run(&pool)
                .unwrap();
        let device = DeviceProfile::gaudi2();
        let measured = MeasureStage {
            model: "demo",
            graph: &graph,
            partitioned: &partitioned,
            device: &device,
            seed: 1,
            reps: 2,
        }
        .run(&pool)
        .unwrap();
        let planner = crate::plan::Planner::new(partitioned, calibrated, measured).unwrap();
        assert_eq!(planner.model(), "demo");
    }
}
